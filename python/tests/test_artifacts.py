"""AOT bridge contract: the lowered HLO artifacts stay faithful to the
jitted python model, and the manifest fully describes the ABI."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.golden import det_states

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _art(name):
    path = os.path.join(ART, name)
    if not os.path.exists(path):
        pytest.skip(f"artifact {name} not built (run `make artifacts`)")
    return path


@pytest.fixture(scope="module")
def manifest():
    with open(_art("manifest.json")) as f:
        return json.load(f)


def test_manifest_lists_all_files(manifest):
    for cname, c in manifest["configs"].items():
        assert os.path.exists(os.path.join(ART, c["init_params"]))
        for entry in c["entries"].values():
            assert os.path.exists(os.path.join(ART, entry["file"])), entry


def test_manifest_param_counts(manifest):
    for cname, c in manifest["configs"].items():
        cfg = M.make_config(cname, actions=manifest["actions"])
        assert c["param_count"] == M.param_count(cfg)
        n = sum(int(np.prod(p["shape"])) for p in c["param_spec"])
        assert n == c["param_count"]


def test_init_blob_matches_model(manifest):
    for cname, c in manifest["configs"].items():
        cfg = M.make_config(cname, actions=manifest["actions"])
        blob = np.fromfile(os.path.join(ART, c["init_params"]), np.float32)
        want = np.asarray(M.init_params(cfg, jax.random.PRNGKey(0)))
        np.testing.assert_array_equal(blob, want)


def test_golden_matches_live_model(manifest):
    """golden.json (what Rust pins against) must equal a live forward pass."""
    with open(_art("golden.json")) as f:
        golden = json.load(f)
    for cname, entry in golden.items():
        cfg = M.make_config(cname, actions=manifest["actions"])
        flat = jnp.asarray(np.fromfile(
            os.path.join(ART, f"{cname}_init.bin"), np.float32))
        h, w, c = cfg.frame
        for b in (1, 8):
            st = jnp.asarray(det_states(b, h, w, c))
            q = np.asarray(M.infer_jit(cfg, flat, st))
            np.testing.assert_allclose(
                q, np.asarray(entry[f"infer_b{b}"]), rtol=1e-4, atol=1e-4)


def test_hlo_artifacts_have_no_custom_calls(manifest):
    """interpret=True pallas must lower to plain HLO (no Mosaic custom-call
    the CPU PJRT client could not execute)."""
    for c in manifest["configs"].values():
        for entry in c["entries"].values():
            with open(os.path.join(ART, entry["file"])) as f:
                text = f.read()
            assert "custom-call" not in text, entry["file"]
            assert "mosaic" not in text.lower(), entry["file"]


def test_train_abi_documented(manifest):
    abi = manifest["train_abi"]
    assert abi["inputs"] == ["params", "target", "g", "s", "states", "actions",
                             "rewards", "next_states", "dones", "lr"]
    assert abi["outputs"] == ["params", "g", "s", "loss"]


def test_infer_entry_signatures(manifest):
    for cname, c in manifest["configs"].items():
        p = c["param_count"]
        h, w, ch = c["frame"]
        for ename, entry in c["entries"].items():
            if not ename.startswith("infer_b"):
                continue
            b = int(ename.split("_b")[1])
            sig = entry["inputs"]
            assert sig[0] == {"dtype": "float32", "shape": [p]}
            assert sig[1] == {"dtype": "uint8", "shape": [b, h, w, ch]}


def test_det_states_deterministic():
    a = det_states(2, 84, 84, 4)
    b = det_states(2, 84, 84, 4)
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.uint8
    # Spot values the Rust generator mirrors: (i*13 + y*7 + x*3 + c*11) % 256
    assert a[1, 2, 3, 1] == (13 + 14 + 9 + 11) % 256
