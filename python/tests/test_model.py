"""L2 model tests: architecture shapes, conv correctness vs lax, TD loss,
train-step semantics, and learnability on a toy problem."""

import jax
import jax.lax as lax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


@pytest.fixture(scope="module")
def tiny():
    cfg = M.make_config("tiny")
    flat = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, flat


def _states(key, b, cfg):
    h, w, c = cfg.frame
    return jax.random.randint(key, (b, h, w, c), 0, 256, dtype=jnp.uint8)


# ---------------------------------------------------------------------------
# Architecture / packing
# ---------------------------------------------------------------------------

def test_param_counts():
    # Hand-computed totals for the three architectures (A = 6 actions).
    assert M.param_count(M.make_config("tiny")) == 27_082
    assert M.param_count(M.make_config("small")) == 677_686
    assert M.param_count(M.make_config("nature")) == 1_687_206


def test_conv_output_sizes_match_nature_paper():
    cfg = M.make_config("nature")
    assert cfg.conv_out_hw() == [(20, 20), (9, 9), (7, 7)]


def test_pack_unpack_roundtrip(tiny):
    cfg, flat = tiny
    assert np.allclose(M.pack(cfg, M.unpack(cfg, flat)), flat)


def test_unpack_shapes(tiny):
    cfg, flat = tiny
    tree = M.unpack(cfg, flat)
    spec = dict(M.param_spec(cfg))
    assert set(tree) == set(spec)
    for name, arr in tree.items():
        assert arr.shape == spec[name]


def test_init_bias_zero_weights_bounded(tiny):
    cfg, flat = tiny
    tree = M.unpack(cfg, flat)
    assert np.all(tree["fc0_b"] == 0.0)
    w = tree["fc0_w"]
    bound = 1.0 / np.sqrt(w.shape[0])
    assert np.all(np.abs(w) <= bound)
    assert np.std(w) > 0.0


def test_init_deterministic(tiny):
    cfg, flat = tiny
    again = M.init_params(cfg, jax.random.PRNGKey(0))
    np.testing.assert_array_equal(flat, again)


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["tiny", "small"])
def test_forward_matches_lax_conv(name):
    """The im2col + Pallas-matmul conv equals XLA's native convolution."""
    cfg = M.make_config(name)
    flat = M.init_params(cfg, jax.random.PRNGKey(1))
    states = _states(jax.random.PRNGKey(2), 4, cfg)
    q = M.infer_jit(cfg, flat, states)

    p = M.unpack(cfg, flat)
    x = states.astype(jnp.float32) / 255.0
    for i, conv in enumerate(cfg.convs):
        x = lax.conv_general_dilated(
            x, p[f"conv{i}_w"], (conv.stride, conv.stride), "VALID",
            dimension_numbers=("NHWC", "HWIO", "NHWC")) + p[f"conv{i}_b"]
        x = jax.nn.relu(x)
    x = x.reshape(x.shape[0], -1)
    for i in range(len(cfg.hidden)):
        x = jax.nn.relu(x @ p[f"fc{i}_w"] + p[f"fc{i}_b"])
    qref = x @ p["out_w"] + p["out_b"]
    np.testing.assert_allclose(q, qref, rtol=1e-4, atol=1e-4)


def test_forward_batch_consistency(tiny):
    """Row j of a batched forward equals a singleton forward of row j —
    the invariant Synchronized Execution relies on."""
    cfg, flat = tiny
    states = _states(jax.random.PRNGKey(3), 8, cfg)
    q_batch = M.infer_jit(cfg, flat, states)
    for j in [0, 3, 7]:
        q_one = M.infer_jit(cfg, flat, states[j:j + 1])
        np.testing.assert_allclose(q_batch[j], q_one[0], rtol=1e-4, atol=1e-4)


def test_forward_scales_uint8(tiny):
    cfg, flat = tiny
    zeros = jnp.zeros((1,) + cfg.frame, jnp.uint8)
    full = jnp.full((1,) + cfg.frame, 255, jnp.uint8)
    qz = M.infer_jit(cfg, flat, zeros)
    qf = M.infer_jit(cfg, flat, full)
    assert not np.allclose(qz, qf)
    assert np.all(np.isfinite(qz)) and np.all(np.isfinite(qf))


# ---------------------------------------------------------------------------
# TD loss / train step
# ---------------------------------------------------------------------------

def _batch(cfg, b=8, seed=4):
    keys = jax.random.split(jax.random.PRNGKey(seed), 5)
    return dict(
        states=_states(keys[0], b, cfg),
        actions=jax.random.randint(keys[1], (b,), 0, cfg.actions, dtype=jnp.int32),
        rewards=jax.random.normal(keys[2], (b,)),
        next_states=_states(keys[3], b, cfg),
        dones=(jax.random.uniform(keys[4], (b,)) < 0.2).astype(jnp.float32),
    )


def test_td_loss_zero_when_q_equals_target(tiny):
    """If rewards make the target equal current Q, loss must be ~0."""
    cfg, flat = tiny
    b = 4
    batch = _batch(cfg, b)
    q = M.infer_jit(cfg, flat, batch["states"])
    qn = M.infer_jit(cfg, flat, batch["next_states"])
    qa = q[jnp.arange(b), batch["actions"]]
    dones = jnp.zeros((b,), jnp.float32)
    rewards = qa - 0.99 * jnp.max(qn, axis=1)
    loss = M.td_loss(cfg, flat, flat, batch["states"], batch["actions"],
                     rewards, batch["next_states"], dones)
    assert float(loss) < 1e-8


def test_td_loss_done_masks_bootstrap(tiny):
    cfg, flat = tiny
    b = 4
    batch = _batch(cfg, b)
    ones = jnp.ones((b,), jnp.float32)
    q = M.infer_jit(cfg, flat, batch["states"])
    qa = q[jnp.arange(b), batch["actions"]]
    # target == reward when done: loss is 0 iff reward == Q(s,a)
    loss = M.td_loss(cfg, flat, flat, batch["states"], batch["actions"],
                     qa, batch["next_states"], ones)
    assert float(loss) < 1e-8


def test_double_dqn_differs_from_vanilla(tiny):
    cfg, flat = tiny
    other = M.init_params(cfg, jax.random.PRNGKey(9))
    batch = _batch(cfg, 8)
    l1 = M.td_loss(cfg, flat, other, **batch, double=False)
    l2 = M.td_loss(cfg, flat, other, **batch, double=True)
    assert not np.isclose(float(l1), float(l2))


def test_train_step_updates_all_states(tiny):
    cfg, flat = tiny
    g = jnp.zeros_like(flat)
    s = jnp.zeros_like(flat)
    batch = _batch(cfg, 8)
    p2, g2, s2, loss = M.train_step(
        cfg, flat, flat, g, s, batch["states"], batch["actions"],
        batch["rewards"], batch["next_states"], batch["dones"],
        jnp.float32(2.5e-4))
    assert float(loss) > 0.0
    assert not np.allclose(p2, flat)
    assert float(jnp.sum(jnp.abs(g2))) > 0.0
    assert float(jnp.sum(s2)) > 0.0
    assert np.all(np.isfinite(p2))


def test_train_step_reduces_td_loss(tiny):
    """A few steps on a FIXED batch must reduce the TD loss (learnability)."""
    cfg, flat = tiny
    g = jnp.zeros_like(flat)
    s = jnp.zeros_like(flat)
    batch = _batch(cfg, 8)
    ts = jax.jit(lambda p, g, s: M.train_step(
        cfg, p, flat, g, s, batch["states"], batch["actions"],
        batch["rewards"], batch["next_states"], batch["dones"],
        jnp.float32(1e-3)))
    p = flat
    losses = []
    for _ in range(20):
        p, g, s, loss = ts(p, g, s)
        losses.append(float(loss))
    assert losses[-1] < losses[0] * 0.5, losses
