"""L1 matmul kernel vs the pure-jnp oracle — the core correctness signal."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import (
    matmul,
    matmul_pallas,
    mxu_utilization_estimate,
    vmem_footprint_bytes,
)
from compile.kernels.ref import matmul_ref

DIM = st.integers(min_value=1, max_value=96)


def _rand(key, shape, dtype):
    if dtype == jnp.uint8:
        return jax.random.randint(key, shape, 0, 256, dtype=jnp.uint8)
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


@settings(max_examples=25, deadline=None)
@given(m=DIM, k=DIM, n=DIM, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_shapes(m, k, n, seed):
    """Hypothesis sweep over arbitrary (non-tile-aligned) shapes."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k1, (m, k))
    y = jax.random.normal(k2, (k, n))
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(dtype=st.sampled_from([jnp.float32, jnp.bfloat16]),
       seed=st.integers(0, 2**31 - 1))
def test_matmul_dtypes(dtype, seed):
    """Kernel accepts non-f32 inputs and accumulates in f32."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _rand(k1, (17, 33), dtype)
    y = _rand(k2, (33, 9), dtype)
    got = matmul(x, y)
    want = matmul_ref(x.astype(jnp.float32), y.astype(jnp.float32))
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)
    assert got.dtype == jnp.float32


@pytest.mark.parametrize("shape", [(1, 1, 1), (128, 128, 128), (129, 257, 65),
                                   (32, 3136, 512), (200, 256, 16)])
def test_matmul_exact_shapes(shape):
    m, k, n = shape
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, (m, k))
    y = jax.random.normal(k2, (k, n))
    np.testing.assert_allclose(matmul(x, y), matmul_ref(x, y),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("bm,bn,bk", [(32, 32, 32), (64, 128, 32), (128, 128, 128)])
def test_matmul_block_sizes(bm, bn, bk):
    """Tiling configuration never changes the numbers (padding is exact)."""
    k1, k2 = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(k1, (70, 90))
    y = jax.random.normal(k2, (90, 50))
    np.testing.assert_allclose(matmul_pallas(x, y, bm=bm, bn=bn, bk=bk),
                               matmul_ref(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_grad_matches_ref():
    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(3), 3)
    x = jax.random.normal(k1, (13, 21))
    y = jax.random.normal(k2, (21, 5))
    ct = jax.random.normal(k3, (13, 5))

    def f(x, y):
        return jnp.sum(matmul(x, y) * ct)

    def fr(x, y):
        return jnp.sum(matmul_ref(x, y) * ct)

    gx, gy = jax.grad(f, argnums=(0, 1))(x, y)
    gxr, gyr = jax.grad(fr, argnums=(0, 1))(x, y)
    np.testing.assert_allclose(gx, gxr, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gy, gyr, rtol=1e-4, atol=1e-4)


def test_matmul_rejects_bad_shapes():
    with pytest.raises(ValueError):
        matmul_pallas(jnp.zeros((2, 3)), jnp.zeros((4, 5)))
    with pytest.raises(ValueError):
        matmul_pallas(jnp.zeros((2, 3, 4)), jnp.zeros((4, 5)))


def test_vmem_footprint_within_budget():
    """Default tiles must fit comfortably in a 16 MiB VMEM."""
    assert vmem_footprint_bytes() <= 1 << 21  # 2 MiB working set


def test_mxu_utilization_estimate():
    assert mxu_utilization_estimate(128, 128, 128) == 1.0
    assert 0.0 < mxu_utilization_estimate(1, 1, 1) < 0.01
    # DQN fc1 layer: (32, 3136) @ (3136, 512) — M pads 32->128, K 3136->3200.
    u = mxu_utilization_estimate(32, 512, 3136)
    assert abs(u - (32 * 3136) / (128 * 3200)) < 1e-9
