"""L1 fused centered-RMSProp kernel vs the pure-jnp oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.rmsprop import rmsprop_update
from compile.kernels.ref import rmsprop_ref


def _vecs(n, seed):
    keys = jax.random.split(jax.random.PRNGKey(seed), 4)
    p = jax.random.normal(keys[0], (n,))
    grad = jax.random.normal(keys[1], (n,))
    g = 0.1 * jax.random.normal(keys[2], (n,))
    s = jnp.abs(jax.random.normal(keys[3], (n,))) + 0.5
    return p, grad, g, s


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 5000), seed=st.integers(0, 2**31 - 1))
def test_rmsprop_matches_ref(n, seed):
    """Hypothesis sweep over non-block-aligned vector lengths."""
    p, grad, g, s = _vecs(n, seed)
    lr = jnp.float32(2.5e-4)
    got = rmsprop_update(p, grad, g, s, lr)
    want = rmsprop_ref(p, grad, g, s, lr)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("n", [1, 7, 65536, 65537, 677686])
def test_rmsprop_exact_sizes(n):
    p, grad, g, s = _vecs(n, 1)
    lr = jnp.float32(1e-3)
    got = rmsprop_update(p, grad, g, s, lr)
    want = rmsprop_ref(p, grad, g, s, lr)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("block", [64, 1024, 65536])
def test_rmsprop_block_invariance(block):
    """Blocking configuration never changes the numbers."""
    p, grad, g, s = _vecs(10_001, 2)
    lr = jnp.float32(2.5e-4)
    base = rmsprop_update(p, grad, g, s, lr)
    got = rmsprop_update(p, grad, g, s, lr, block=block)
    for a, b in zip(got, base):
        np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-7)


def test_rmsprop_hyperparams():
    """Alpha/eps thread through to the math (paper Table 5 values default)."""
    p, grad, g, s = _vecs(257, 3)
    lr = jnp.float32(2.5e-4)
    got = rmsprop_update(p, grad, g, s, lr, alpha=0.9, eps=0.1)
    want = rmsprop_ref(p, grad, g, s, lr, alpha=0.9, eps=0.1)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-6)


def test_rmsprop_zero_grad_is_noop_on_params():
    p, _, g, s = _vecs(100, 4)
    grad = jnp.zeros_like(p)
    p2, g2, s2 = rmsprop_update(p, grad, g, s, jnp.float32(1e-2))
    np.testing.assert_allclose(p2, p, rtol=0, atol=0)
    np.testing.assert_allclose(g2, 0.95 * g, rtol=1e-6, atol=1e-7)
    np.testing.assert_allclose(s2, 0.95 * s, rtol=1e-6, atol=1e-7)


def test_rmsprop_descends_quadratic():
    """End-to-end sanity: the optimizer actually minimizes x^2."""
    x = jnp.full((16,), 5.0)
    g = jnp.zeros_like(x)
    s = jnp.zeros_like(x)
    lr = jnp.float32(0.05)
    for _ in range(200):
        grad = 2.0 * x
        x, g, s = rmsprop_update(x, grad, g, s, lr)
    assert float(jnp.max(jnp.abs(x))) < 0.5
