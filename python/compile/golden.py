"""Generate golden outputs for Rust<->Python numeric cross-checks.

For every network config this runs the *jitted python* model on deterministic
inputs (same init params the artifacts ship) and records the results in
``artifacts/golden.json``.  The Rust integration tests execute the compiled
HLO artifacts on the same inputs and assert the numbers agree — proving the
AOT bridge is faithful end to end.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from . import model as M


def det_states(b: int, h: int, w: int, c: int) -> np.ndarray:
    """Deterministic uint8 frames both languages can regenerate exactly."""
    i = np.arange(b)[:, None, None, None]
    y = np.arange(h)[None, :, None, None]
    x = np.arange(w)[None, None, :, None]
    ch = np.arange(c)[None, None, None, :]
    return ((i * 13 + y * 7 + x * 3 + ch * 11) % 256).astype(np.uint8)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--configs", default="tiny,small")
    ap.add_argument("--actions", type=int, default=6)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    golden = {}
    for name in args.configs.split(","):
        cfg = M.make_config(name.strip(), actions=args.actions)
        flat = jnp.asarray(np.fromfile(
            os.path.join(out_dir, f"{cfg.name}_init.bin"), np.float32))
        h, w, c = cfg.frame
        entry = {}

        for b in (1, 8):
            st = jnp.asarray(det_states(b, h, w, c))
            q = M.infer_jit(cfg, flat, st)
            entry[f"infer_b{b}"] = np.asarray(q, np.float64).round(5).tolist()

        # One deterministic train step (batch 32).
        bsz = 32
        st = jnp.asarray(det_states(bsz, h, w, c))
        nst = jnp.asarray(det_states(bsz, h, w, c)[::-1].copy())
        acts = jnp.asarray(np.arange(bsz, dtype=np.int32) % cfg.actions)
        rews = jnp.asarray((np.arange(bsz) % 3 - 1).astype(np.float32))
        dones = jnp.asarray((np.arange(bsz) % 7 == 0).astype(np.float32))
        g = jnp.zeros_like(flat)
        s = jnp.zeros_like(flat)
        ts = jax.jit(lambda *a: M.train_step(cfg, *a))
        p2, g2, s2, loss = ts(flat, flat, g, s, st, acts, rews, nst, dones,
                              jnp.float32(2.5e-4))
        entry["train_b32_loss"] = float(loss)
        entry["train_b32_param_sum"] = float(jnp.sum(p2))
        entry["train_b32_param_head"] = np.asarray(p2[:8], np.float64).tolist()
        entry["train_b32_g_sum"] = float(jnp.sum(g2))
        entry["train_b32_s_sum"] = float(jnp.sum(s2))
        golden[cfg.name] = entry
        print(f"golden[{cfg.name}] loss={float(loss):.6f}")

    with open(os.path.join(out_dir, "golden.json"), "w") as f:
        json.dump(golden, f)
    print(f"wrote {out_dir}/golden.json")


if __name__ == "__main__":
    main()
