"""AOT compile path: lower the L2 model to HLO *text* artifacts for Rust.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids that the xla crate's pinned
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per network config:
    artifacts/<cfg>_infer_b<B>.hlo.txt      batched Q-value inference
    artifacts/<cfg>_train_b<B>.hlo.txt      full train step (TD + RMSProp)
    artifacts/<cfg>_train_double_b<B>.hlo.txt   Double-DQN variant
    artifacts/<cfg>_init.bin                f32-LE init parameter blob
    artifacts/manifest.json                 the ABI the Rust runtime reads

Run via ``make artifacts`` (no-op when inputs are unchanged); Python is never
on the request path.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model as M

MANIFEST_VERSION = 2


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args):
    return [
        {"dtype": str(a.dtype), "shape": list(a.shape)}
        for a in args
    ]


def lower_config(cfg: M.NetConfig, infer_batches, train_batches, gamma, seed, out_dir):
    """Lower every entry point for one network config; return manifest dict."""
    p = M.param_count(cfg)
    h, w, c = cfg.frame
    pvec = jax.ShapeDtypeStruct((p,), jnp.float32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)
    entries = {}

    def emit(name, fn, args):
        path = f"{cfg.name}_{name}.hlo.txt"
        text = to_hlo_text(jax.jit(fn).lower(*args))
        with open(os.path.join(out_dir, path), "w") as f:
            f.write(text)
        entries[name] = {"file": path, "inputs": _sig(args)}
        print(f"  {path}: {len(text)} chars")

    for b in infer_batches:
        states = jax.ShapeDtypeStruct((b, h, w, c), jnp.uint8)
        emit(f"infer_b{b}",
             lambda fl, st: (M.forward(cfg, fl, st),),
             (pvec, states))

    for b in train_batches:
        states = jax.ShapeDtypeStruct((b, h, w, c), jnp.uint8)
        acts = jax.ShapeDtypeStruct((b,), jnp.int32)
        fvec = jax.ShapeDtypeStruct((b,), jnp.float32)
        targs = (pvec, pvec, pvec, pvec, states, acts, fvec, states, fvec, scalar)
        for double in (False, True):
            tag = f"train_double_b{b}" if double else f"train_b{b}"
            emit(tag,
                 lambda fl, tf, g, s, st, a, r, ns, d, lr, _dbl=double:
                     M.train_step(cfg, fl, tf, g, s, st, a, r, ns, d, lr,
                                  gamma=gamma, double=_dbl),
                 targs)

    # Deterministic initial parameters shared by Rust and the pytest suite.
    init = np.asarray(M.init_params(cfg, jax.random.PRNGKey(seed)), np.float32)
    init_path = f"{cfg.name}_init.bin"
    init.tofile(os.path.join(out_dir, init_path))

    return {
        "param_count": p,
        "frame": [h, w, c],
        "actions": cfg.actions,
        "gamma": gamma,
        "init_params": init_path,
        "init_sha256": hashlib.sha256(init.tobytes()).hexdigest(),
        "param_spec": [{"name": n, "shape": list(s)} for n, s in M.param_spec(cfg)],
        "entries": entries,
        # Train entry ABI, for the Rust executor:
        # inputs  = params, target, g, s, states, actions, rewards,
        #           next_states, dones, lr
        # outputs = params', g', s', loss
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"))
    ap.add_argument("--configs", default="tiny,small,nature")
    ap.add_argument("--infer-batches", default="1,2,4,8,32")
    ap.add_argument("--train-batches", default="32")
    ap.add_argument("--actions", type=int, default=6)
    ap.add_argument("--gamma", type=float, default=0.99)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)
    infer_batches = [int(b) for b in args.infer_batches.split(",") if b]
    train_batches = [int(b) for b in args.train_batches.split(",") if b]

    manifest = {
        "version": MANIFEST_VERSION,
        "actions": args.actions,
        "train_abi": {
            "inputs": ["params", "target", "g", "s", "states", "actions",
                        "rewards", "next_states", "dones", "lr"],
            "outputs": ["params", "g", "s", "loss"],
        },
        "configs": {},
    }
    for name in args.configs.split(","):
        cfg = M.make_config(name.strip(), actions=args.actions)
        print(f"lowering config {cfg.name!r} (P={M.param_count(cfg)})")
        manifest["configs"][cfg.name] = lower_config(
            cfg, infer_batches, train_batches, args.gamma, args.seed, out_dir)

    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
