"""L1 Pallas kernel: tiled matmul with a custom VJP.

This is the compute hot-spot of the DQN network: every dense layer and every
im2col-lowered convolution bottoms out in this kernel.  The BlockSpec
expresses the HBM<->VMEM staging schedule that CUDA code would express with
threadblocks + shared memory: (bm x bk) and (bk x bn) tiles are streamed
through VMEM and contracted on the MXU, accumulating into the (bm x bn)
output tile which is revisited across the K grid dimension.

Pallas is invoked with ``interpret=True`` so the kernel lowers to plain HLO
ops executable on the CPU PJRT client (real-TPU lowering emits a Mosaic
custom-call the CPU plugin cannot run).  Correctness is pinned against the
pure-jnp oracle in ``ref.py`` by ``python/tests/test_matmul.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU tile sizes.  128x128 output tiles match the MXU systolic array
# shape; 128-wide K panels keep the VMEM working set small:
#   (bm*bk + bk*bn + bm*bn) * 4B = 3 * 128*128 * 4B = 192 KiB << 16 MiB VMEM.
# These express the HBM<->VMEM schedule for REAL hardware and are exercised
# by the test suite; the default (bm=bn=bk=None) instead chooses the
# interpret-optimal schedule — a single grid step over the (lightly padded)
# full operands — because interpret-mode pallas pays ~5 ms of interpreter
# machinery PER GRID STEP on CPU (see EXPERIMENTS.md §Perf).
TPU_BM = 128
TPU_BN = 128
TPU_BK = 128
# Back-compat aliases.
DEFAULT_BM = TPU_BM
DEFAULT_BN = TPU_BN
DEFAULT_BK = TPU_BK


def _round8(n: int) -> int:
    """Pad dimension to a multiple of 8 (sublane alignment), minimum 8."""
    return max(8, -(-n // 8) * 8)


def _matmul_kernel(x_ref, y_ref, o_ref, *, nk: int):
    """One (i, j, k) grid step: o[i,j] (+)= x[i,k] @ y[k,j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    )


def _pad_to(x: jax.Array, m: int, axis: int) -> jax.Array:
    size = x.shape[axis]
    rem = (-size) % m
    if rem == 0:
        return x
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def matmul_pallas(
    x: jax.Array,
    y: jax.Array,
    *,
    bm: int | None = None,
    bn: int | None = None,
    bk: int | None = None,
) -> jax.Array:
    """Tiled Pallas matmul ``x @ y`` for f32 operands of any 2-D shape.

    Inputs are zero-padded up to tile multiples (zero padding is exact for
    matmul) and the result is sliced back to the true shape.  With the
    default ``None`` tile sizes the schedule is a single grid step over the
    lightly-padded operands (optimal under ``interpret=True`` on CPU); pass
    explicit sizes (e.g. ``TPU_BM``) to express the real-hardware
    HBM<->VMEM tiling.
    """
    if x.ndim != 2 or y.ndim != 2:
        raise ValueError(f"matmul_pallas expects 2-D operands, got {x.shape} @ {y.shape}")
    if x.shape[1] != y.shape[0]:
        raise ValueError(f"contraction mismatch: {x.shape} @ {y.shape}")
    m, k = x.shape
    _, n = y.shape
    # Default: one grid step (see docstring). Explicit tiles are shrunk for
    # small problems so the grid is never empty and padding stays bounded.
    bm = _round8(m) if bm is None else min(bm, max(8, 1 << (m - 1).bit_length()))
    bn = _round8(n) if bn is None else min(bn, max(8, 1 << (n - 1).bit_length()))
    bk = _round8(k) if bk is None else min(bk, max(8, 1 << (k - 1).bit_length()))

    xp = _pad_to(_pad_to(x.astype(jnp.float32), bm, 0), bk, 1)
    yp = _pad_to(_pad_to(y.astype(jnp.float32), bk, 0), bn, 1)
    mp, kp = xp.shape
    _, np_ = yp.shape
    nk = kp // bk

    out = pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=(mp // bm, np_ // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(xp, yp)
    return out[:m, :n]


@jax.custom_vjp
def matmul(x: jax.Array, y: jax.Array) -> jax.Array:
    """Differentiable tiled-Pallas matmul (the public kernel entry point)."""
    return matmul_pallas(x, y)


def _matmul_fwd(x, y):
    return matmul_pallas(x, y), (x, y)


def _matmul_bwd(res, g):
    x, y = res
    # dL/dx = g @ y^T ; dL/dy = x^T @ g — both through the same Pallas tiles.
    return matmul_pallas(g, y.T), matmul_pallas(x.T, g)


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def vmem_footprint_bytes(bm: int = TPU_BM, bn: int = TPU_BN, bk: int = TPU_BK) -> int:
    """Estimated VMEM working set of one grid step (f32)."""
    return 4 * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(m: int, n: int, k: int,
                             bm: int = TPU_BM, bn: int = TPU_BN,
                             bk: int = TPU_BK) -> float:
    """Fraction of MXU-issued MACs that are useful (non-padding) work."""
    mp = -(-m // bm) * bm
    np_ = -(-n // bn) * bn
    kp = -(-k // bk) * bk
    return (m * n * k) / float(mp * np_ * kp)
