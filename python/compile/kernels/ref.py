"""Pure-jnp correctness oracles for the L1 Pallas kernels.

These are the ground truth the pytest suite pins the kernels against
(``assert_allclose``).  They deliberately contain no Pallas code.
"""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, y):
    """Oracle for kernels.matmul.matmul."""
    return jnp.dot(x.astype(jnp.float32), y.astype(jnp.float32),
                   preferred_element_type=jnp.float32)


def rmsprop_ref(params, grad, g, s, lr, *, alpha=0.95, eps=0.01):
    """Oracle for kernels.rmsprop.rmsprop_update (centered RMSProp)."""
    g2 = alpha * g + (1.0 - alpha) * grad
    s2 = alpha * s + (1.0 - alpha) * grad * grad
    p2 = params - lr * grad / jnp.sqrt(s2 - g2 * g2 + eps)
    return p2, g2, s2


def huber(x, delta=1.0):
    """Huber loss (a.k.a. DQN's error clipping): quadratic inside delta."""
    absx = jnp.abs(x)
    return jnp.where(absx <= delta, 0.5 * x * x, delta * (absx - 0.5 * delta))
