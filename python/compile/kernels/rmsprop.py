"""L1 Pallas kernel: fused centered-RMSProp update.

Mnih et al. (2015) trained DQN with "centered" RMSProp (Hinton et al., 2012):

    g  <- a*g + (1-a)*grad          (first-moment EMA)
    s  <- a*s + (1-a)*grad^2        (second-moment EMA)
    p  <- p - lr * grad / sqrt(s - g^2 + eps)

with a = 0.95, lr = 2.5e-4, eps = 0.01 (Table 5 / Appendix B of the paper).

The update is purely elementwise over the flat parameter vector, so the
kernel is a VPU-shaped 1-D blocked map: each grid step streams one BLOCK-wide
panel of (p, grad, g, s) through VMEM and writes the three updated vectors.
Fusing the three EMAs + the update into one kernel means the parameter vector
makes exactly one round trip to HBM per optimizer step.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# TPU blocking: 64 Ki elements * (4 in + 3 out) * 4 B = 1.75 MiB per grid
# step through VMEM. Like the matmul kernel, the interpret-mode default is
# instead a SINGLE grid step over the whole (padded) vector — interpret
# pallas pays ~5 ms of interpreter machinery per grid step on CPU.
TPU_BLOCK = 65536
DEFAULT_BLOCK = TPU_BLOCK


def _rmsprop_kernel(p_ref, grad_ref, g_ref, s_ref, lr_ref, po_ref, go_ref, so_ref,
                    *, alpha: float, eps: float):
    grad = grad_ref[...]
    g = alpha * g_ref[...] + (1.0 - alpha) * grad
    s = alpha * s_ref[...] + (1.0 - alpha) * grad * grad
    denom = jnp.sqrt(s - g * g + eps)
    po_ref[...] = p_ref[...] - lr_ref[0] * grad / denom
    go_ref[...] = g
    so_ref[...] = s


@functools.partial(jax.jit, static_argnames=("alpha", "eps", "block"))
def rmsprop_update(
    params: jax.Array,
    grad: jax.Array,
    g: jax.Array,
    s: jax.Array,
    lr: jax.Array,
    *,
    alpha: float = 0.95,
    eps: float = 0.01,
    block: int | None = None,
):
    """Apply one centered-RMSProp step to the flat f32 parameter vector.

    Returns ``(params', g', s')``.  ``lr`` is a scalar array so the learning
    rate can be annealed without recompiling the artifact.
    """
    n = params.shape[0]
    if block is None:
        block = max(8, -(-n // 8) * 8)  # single grid step (see module docs)
    else:
        block = min(block, max(8, 1 << (n - 1).bit_length()))
    rem = (-n) % block
    pad = lambda v: jnp.pad(v, (0, rem)) if rem else v
    pp, gradp, gp, sp = pad(params), pad(grad), pad(g), pad(s)
    npad = pp.shape[0]
    lr_vec = jnp.reshape(lr.astype(jnp.float32), (1,))

    spec = pl.BlockSpec((block,), lambda i: (i,))
    out = pl.pallas_call(
        functools.partial(_rmsprop_kernel, alpha=alpha, eps=eps),
        grid=(npad // block,),
        in_specs=[spec, spec, spec, spec,
                  pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=(spec, spec, spec),
        out_shape=(
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
            jax.ShapeDtypeStruct((npad,), jnp.float32),
        ),
        interpret=True,
    )(pp, gradp, gp, sp, lr_vec)
    p2, g2, s2 = out
    return p2[:n], g2[:n], s2[:n]
