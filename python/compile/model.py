"""L2: the DQN Q-network and train step in JAX, on a FLAT parameter vector.

Everything the Rust runtime executes is defined here and lowered once by
``aot.py``.  The network's dense/conv compute bottoms out in the L1 Pallas
matmul kernel (``kernels.matmul``); the optimizer step is the L1 fused
centered-RMSProp kernel (``kernels.rmsprop``).

Flat-parameter ABI
------------------
All parameters live in one ``f32[P]`` vector.  The Rust coordinator only ever
handles four opaque buffers (theta, theta_minus, rmsprop g, rmsprop s); the
static pack/unpack lives here so layer structure never leaks across the
language boundary.  ``param_spec`` is recorded in the artifact manifest.

Entry points lowered to HLO (per network config):
  infer(params, states)                          -> q-values
  train(params, target, g, s, batch..., lr)      -> (params', g', s', loss)
  train_double(...)                              -> same, Double-DQN targets
"""

from __future__ import annotations

import dataclasses
import functools
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels.matmul import matmul
from .kernels.rmsprop import rmsprop_update
from .kernels.ref import huber

Shape = Tuple[int, ...]


@dataclasses.dataclass(frozen=True)
class ConvSpec:
    filters: int
    kernel: int
    stride: int


@dataclasses.dataclass(frozen=True)
class NetConfig:
    """Architecture of one Q-network variant."""

    name: str
    frame: Tuple[int, int, int]  # (H, W, stacked-channels)
    convs: Tuple[ConvSpec, ...]
    hidden: Tuple[int, ...]
    actions: int

    def conv_out_hw(self) -> List[Tuple[int, int]]:
        h, w, _ = self.frame
        out = []
        for c in self.convs:
            h = (h - c.kernel) // c.stride + 1
            w = (w - c.kernel) // c.stride + 1
            out.append((h, w))
        return out


def make_config(name: str, actions: int = 6) -> NetConfig:
    """The three supported architectures.

    * ``nature`` — the Mnih et al. (2015) network (~1.7M params @ 6 actions).
    * ``small``  — half-width variant for fast CPU end-to-end runs.
    * ``tiny``   — minimal conv net for unit tests and CI.
    """
    if name == "nature":
        return NetConfig(name, (84, 84, 4),
                         (ConvSpec(32, 8, 4), ConvSpec(64, 4, 2), ConvSpec(64, 3, 1)),
                         (512,), actions)
    if name == "small":
        return NetConfig(name, (84, 84, 4),
                         (ConvSpec(16, 8, 4), ConvSpec(32, 4, 2)),
                         (256,), actions)
    if name == "tiny":
        return NetConfig(name, (84, 84, 4),
                         (ConvSpec(4, 8, 8),),
                         (64,), actions)
    raise ValueError(f"unknown network config {name!r}")


# ---------------------------------------------------------------------------
# Flat parameter packing
# ---------------------------------------------------------------------------

def param_spec(cfg: NetConfig) -> List[Tuple[str, Shape]]:
    """Ordered (name, shape) list defining the flat layout."""
    spec: List[Tuple[str, Shape]] = []
    c_in = cfg.frame[2]
    for i, conv in enumerate(cfg.convs):
        spec.append((f"conv{i}_w", (conv.kernel, conv.kernel, c_in, conv.filters)))
        spec.append((f"conv{i}_b", (conv.filters,)))
        c_in = conv.filters
    h, w = cfg.conv_out_hw()[-1] if cfg.convs else cfg.frame[:2]
    dim = h * w * c_in
    for i, width in enumerate(cfg.hidden):
        spec.append((f"fc{i}_w", (dim, width)))
        spec.append((f"fc{i}_b", (width,)))
        dim = width
    spec.append(("out_w", (dim, cfg.actions)))
    spec.append(("out_b", (cfg.actions,)))
    return spec


def param_count(cfg: NetConfig) -> int:
    total = 0
    for _, shape in param_spec(cfg):
        n = 1
        for d in shape:
            n *= d
        total += n
    return total


def unpack(cfg: NetConfig, flat: jax.Array) -> dict:
    """Static-slice the flat vector into named tensors."""
    out = {}
    off = 0
    for name, shape in param_spec(cfg):
        n = 1
        for d in shape:
            n *= d
        out[name] = jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)
        off += n
    return out


def pack(cfg: NetConfig, tree: dict) -> jax.Array:
    return jnp.concatenate(
        [tree[name].reshape(-1).astype(jnp.float32) for name, _ in param_spec(cfg)]
    )


def init_params(cfg: NetConfig, key: jax.Array) -> jax.Array:
    """Uniform fan-in init (the torch-default scheme the original DQN used)."""
    leaves = {}
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith("_b"):
            leaves[name] = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            bound = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
            leaves[name] = jax.random.uniform(sub, shape, jnp.float32, -bound, bound)
    return pack(cfg, leaves)


# ---------------------------------------------------------------------------
# Forward pass (im2col conv -> Pallas matmul)
# ---------------------------------------------------------------------------

def _im2col(x: jax.Array, k: int, s: int) -> jax.Array:
    """[B,H,W,C] -> [B,OH,OW,k*k*C] patch matrix (VALID padding)."""
    b, h, w, c = x.shape
    oh = (h - k) // s + 1
    ow = (w - k) // s + 1
    ii = (jnp.arange(oh) * s)[:, None] + jnp.arange(k)[None, :]  # [OH,k]
    jj = (jnp.arange(ow) * s)[:, None] + jnp.arange(k)[None, :]  # [OW,k]
    # Advanced indexing broadcast: -> [B, OH, k, OW, k, C]
    patches = x[:, ii[:, :, None, None], jj[None, None, :, :], :]
    patches = patches.transpose(0, 1, 3, 2, 4, 5)  # [B,OH,OW,k,k,C]
    return patches.reshape(b, oh, ow, k * k * c)


def forward(cfg: NetConfig, flat: jax.Array, states: jax.Array) -> jax.Array:
    """Q-values for a batch of uint8 frame stacks: [B,H,W,C] -> [B,A]."""
    p = unpack(cfg, flat)
    x = states.astype(jnp.float32) / 255.0
    b = x.shape[0]
    for i, conv in enumerate(cfg.convs):
        patches = _im2col(x, conv.kernel, conv.stride)
        _, oh, ow, kdim = patches.shape
        w = p[f"conv{i}_w"].reshape(kdim, conv.filters)
        y = matmul(patches.reshape(b * oh * ow, kdim), w) + p[f"conv{i}_b"]
        x = jax.nn.relu(y).reshape(b, oh, ow, conv.filters)
    x = x.reshape(b, -1)
    for i in range(len(cfg.hidden)):
        x = jax.nn.relu(matmul(x, p[f"fc{i}_w"]) + p[f"fc{i}_b"])
    return matmul(x, p["out_w"]) + p["out_b"]


# ---------------------------------------------------------------------------
# TD loss + train step
# ---------------------------------------------------------------------------

def td_loss(cfg: NetConfig, flat: jax.Array, target_flat: jax.Array,
            states, actions, rewards, next_states, dones,
            *, gamma: float = 0.99, double: bool = False) -> jax.Array:
    """Mean Huber TD error (DQN's error clipping), eq. (1) of the paper."""
    b = states.shape[0]
    q = forward(cfg, flat, states)[jnp.arange(b), actions]
    qn_target = forward(cfg, target_flat, next_states)
    if double:
        # Double-DQN: argmax under theta, value under theta^-.
        a_star = jnp.argmax(forward(cfg, flat, next_states), axis=1)
        bootstrap = qn_target[jnp.arange(b), a_star]
    else:
        bootstrap = jnp.max(qn_target, axis=1)
    target = rewards + gamma * (1.0 - dones) * jax.lax.stop_gradient(bootstrap)
    return jnp.mean(huber(q - jax.lax.stop_gradient(target)))


def train_step(cfg: NetConfig, flat, target_flat, g, s,
               states, actions, rewards, next_states, dones, lr,
               *, gamma: float = 0.99, double: bool = False):
    """One full DQN gradient step: grad of TD loss + fused RMSProp update."""
    loss, grad = jax.value_and_grad(
        lambda p: td_loss(cfg, p, target_flat, states, actions, rewards,
                          next_states, dones, gamma=gamma, double=double)
    )(flat)
    p2, g2, s2 = rmsprop_update(flat, grad, g, s, lr)
    return p2, g2, s2, loss


# Convenience jitted closure for the test-suite.
@functools.partial(jax.jit, static_argnums=(0,))
def infer_jit(cfg: NetConfig, flat, states):
    return forward(cfg, flat, states)
