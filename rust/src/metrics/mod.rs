//! Metrics substrate: phase timers, throughput counters, Gantt traces
//! (Figure 2 reproduction), and CSV emission.

pub mod gantt;
pub mod timing;

pub use gantt::{GanttTrace, Phase, Span};
pub use timing::{PhaseTimers, Stopwatch, TrainPhase, TrainTimers};
