//! Gantt traces: per-thread phase spans for the Figure 2 timing diagrams.
//!
//! The paper's Figure 2 shows abstract timing diagrams of how sampling and
//! training interleave under each execution model. `GanttTrace` records the
//! real spans so `speed_ablation --gantt` can print the measured version.

use std::sync::Mutex;
use std::time::Instant;

/// Pipeline phases (also used by `PhaseTimers`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(usize)]
pub enum Phase {
    /// Environment simulation + preprocessing on a sampler thread.
    EnvStep = 0,
    /// Q-value inference on the device.
    Infer = 1,
    /// Minibatch gradient step on the device.
    Train = 2,
    /// Target-network sync + staging flush barrier.
    Sync = 3,
    /// Replay sampling / batch assembly.
    Sample = 4,
    /// Thread idle / waiting at a barrier.
    Wait = 5,
}

impl Phase {
    pub const COUNT: usize = 6;
    pub const ALL: [Phase; Phase::COUNT] = [
        Phase::EnvStep,
        Phase::Infer,
        Phase::Train,
        Phase::Sync,
        Phase::Sample,
        Phase::Wait,
    ];

    pub fn name(self) -> &'static str {
        match self {
            Phase::EnvStep => "env_step",
            Phase::Infer => "infer",
            Phase::Train => "train",
            Phase::Sync => "sync",
            Phase::Sample => "sample",
            Phase::Wait => "wait",
        }
    }

    fn glyph(self) -> char {
        match self {
            Phase::EnvStep => 'E',
            Phase::Infer => 'I',
            Phase::Train => 'T',
            Phase::Sync => 'S',
            Phase::Sample => 'B',
            Phase::Wait => '.',
        }
    }
}

/// One recorded span on one logical thread lane.
#[derive(Clone, Copy, Debug)]
pub struct Span {
    pub lane: usize,
    pub phase: Phase,
    pub start_ns: u64,
    pub end_ns: u64,
}

/// Bounded, thread-safe span recorder.
pub struct GanttTrace {
    origin: Instant,
    spans: Mutex<Vec<Span>>,
    max_spans: usize,
}

impl GanttTrace {
    pub fn new(max_spans: usize) -> Self {
        GanttTrace { origin: Instant::now(), spans: Mutex::new(Vec::new()), max_spans }
    }

    pub fn now_ns(&self) -> u64 {
        self.origin.elapsed().as_nanos() as u64
    }

    pub fn record(&self, lane: usize, phase: Phase, start_ns: u64, end_ns: u64) {
        let mut spans = self.spans.lock().unwrap();
        if spans.len() < self.max_spans {
            spans.push(Span { lane, phase, start_ns, end_ns });
        }
    }

    /// Time `f` on `lane`, recording the span.
    pub fn time<T>(&self, lane: usize, phase: Phase, f: impl FnOnce() -> T) -> T {
        let start = self.now_ns();
        let out = f();
        self.record(lane, phase, start, self.now_ns());
        out
    }

    pub fn spans(&self) -> Vec<Span> {
        self.spans.lock().unwrap().clone()
    }

    /// ASCII timing diagram: one row per lane, `cols` buckets wide, each
    /// cell showing the dominant phase in that time bucket (the measured
    /// analogue of the paper's Figure 2).
    pub fn render_ascii(&self, cols: usize) -> String {
        let spans = self.spans();
        if spans.is_empty() {
            return String::from("(no spans recorded)\n");
        }
        let t_end = spans.iter().map(|s| s.end_ns).max().unwrap().max(1);
        let lanes = spans.iter().map(|s| s.lane).max().unwrap() + 1;
        let bucket = (t_end / cols as u64).max(1);
        // occupancy[lane][col][phase] = ns
        let mut occ = vec![vec![[0u64; Phase::COUNT]; cols]; lanes];
        for s in &spans {
            let c0 = (s.start_ns / bucket).min(cols as u64 - 1) as usize;
            let c1 = (s.end_ns / bucket).min(cols as u64 - 1) as usize;
            for c in c0..=c1 {
                let bs = (c as u64) * bucket;
                let be = bs + bucket;
                let overlap = s.end_ns.min(be).saturating_sub(s.start_ns.max(bs));
                occ[s.lane][c][s.phase as usize] += overlap.max(if c0 == c1 { 1 } else { 0 });
            }
        }
        let mut out = String::new();
        out.push_str(&format!("time -> ({:.1} ms total, {} lanes)\n", t_end as f64 / 1e6, lanes));
        for (lane, row) in occ.iter().enumerate() {
            out.push_str(&format!("lane {lane:>2} |"));
            for cell in row {
                let (mut best, mut best_ns) = (None, 0u64);
                for (p, &ns) in cell.iter().enumerate() {
                    if ns > best_ns {
                        best_ns = ns;
                        best = Some(Phase::ALL[p]);
                    }
                }
                out.push(best.map(|p| p.glyph()).unwrap_or(' '));
            }
            out.push_str("|\n");
        }
        out.push_str("legend: E=env I=infer T=train S=sync B=batch-assembly .=wait\n");
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_renders() {
        let g = GanttTrace::new(100);
        g.record(0, Phase::EnvStep, 0, 50);
        g.record(0, Phase::Infer, 50, 100);
        g.record(1, Phase::Train, 0, 100);
        let ascii = g.render_ascii(10);
        assert!(ascii.contains("lane  0"));
        assert!(ascii.contains("lane  1"));
        assert!(ascii.contains('T'));
        assert!(ascii.contains('E'));
    }

    #[test]
    fn bounded_capacity() {
        let g = GanttTrace::new(2);
        for i in 0..10 {
            g.record(0, Phase::Wait, i, i + 1);
        }
        assert_eq!(g.spans().len(), 2);
    }

    #[test]
    fn time_closure_spans_monotonic() {
        let g = GanttTrace::new(10);
        g.time(3, Phase::Sample, || std::thread::sleep(std::time::Duration::from_millis(1)));
        let spans = g.spans();
        assert_eq!(spans.len(), 1);
        assert!(spans[0].end_ns > spans[0].start_ns);
        assert_eq!(spans[0].lane, 3);
    }

    #[test]
    fn empty_render() {
        let g = GanttTrace::new(10);
        assert!(g.render_ascii(5).contains("no spans"));
    }
}
