//! Phase timers: accumulate wall-clock per pipeline phase, thread-safely.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::gantt::Phase;

/// Accumulated nanoseconds + call counts per phase.
#[derive(Debug, Default)]
pub struct PhaseTimers {
    ns: [AtomicU64; Phase::COUNT],
    calls: [AtomicU64; Phase::COUNT],
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, phase: Phase, ns: u64) {
        self.ns[phase as usize].fetch_add(ns, Ordering::Relaxed);
        self.calls[phase as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Time `f`, attributing its duration to `phase`.
    pub fn time<T>(&self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(phase, t0.elapsed().as_nanos() as u64);
        out
    }

    pub fn total_ns(&self, phase: Phase) -> u64 {
        self.ns[phase as usize].load(Ordering::Relaxed)
    }

    pub fn calls(&self, phase: Phase) -> u64 {
        self.calls[phase as usize].load(Ordering::Relaxed)
    }

    pub fn mean_us(&self, phase: Phase) -> f64 {
        let calls = self.calls(phase);
        if calls == 0 {
            return 0.0;
        }
        self.total_ns(phase) as f64 / calls as f64 / 1_000.0
    }

    pub fn reset(&self) {
        for i in 0..Phase::COUNT {
            self.ns[i].store(0, Ordering::Relaxed);
            self.calls[i].store(0, Ordering::Relaxed);
        }
    }

    /// One summary line per phase with any activity.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for phase in Phase::ALL {
            let calls = self.calls(phase);
            if calls == 0 {
                continue;
            }
            out.push_str(&format!(
                "{:<12} calls {:>9}  total {:>9.3}s  mean {:>9.1}us\n",
                phase.name(),
                calls,
                self.total_ns(phase) as f64 / 1e9,
                self.mean_us(phase),
            ));
        }
        out
    }
}

/// Kernel-level phases of one native-engine train step — the
/// `speedtest --breakdown` axis (rust/DESIGN.md §13). Distinct from the
/// pipeline-level [`Phase`]: these subdivide what [`Phase::Train`] lumps
/// together, so kernel wins (e.g. patch-free convolution) are visible
/// without a profiler.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TrainPhase {
    /// Conv-stack forward passes (online, target, and double-DQN nets).
    ConvForward,
    /// Conv input-gradient + conv weight-gradient reductions.
    ConvBackward,
    /// Dense/head forward, backward, and weight-gradient reductions.
    Dense,
    /// Centered-RMSProp parameter update.
    Rmsprop,
    /// Replay minibatch assembly (recorded by the caller that samples).
    Assembly,
}

impl TrainPhase {
    pub const COUNT: usize = 5;
    pub const ALL: [TrainPhase; TrainPhase::COUNT] = [
        TrainPhase::ConvForward,
        TrainPhase::ConvBackward,
        TrainPhase::Dense,
        TrainPhase::Rmsprop,
        TrainPhase::Assembly,
    ];

    pub fn name(self) -> &'static str {
        match self {
            TrainPhase::ConvForward => "conv_forward",
            TrainPhase::ConvBackward => "conv_backward",
            TrainPhase::Dense => "dense",
            TrainPhase::Rmsprop => "rmsprop",
            TrainPhase::Assembly => "assembly",
        }
    }
}

/// [`PhaseTimers`] over the [`TrainPhase`] axis. Phases that run sharded
/// over the compute pool accumulate every worker's duration, so totals
/// are aggregate CPU time (they can exceed wall-clock at
/// `learner_threads > 1`); shares within one report stay comparable.
#[derive(Debug, Default)]
pub struct TrainTimers {
    ns: [AtomicU64; TrainPhase::COUNT],
    calls: [AtomicU64; TrainPhase::COUNT],
}

impl TrainTimers {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, phase: TrainPhase, ns: u64) {
        self.ns[phase as usize].fetch_add(ns, Ordering::Relaxed);
        self.calls[phase as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Time `f`, attributing its duration to `phase`.
    pub fn time<T>(&self, phase: TrainPhase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(phase, t0.elapsed().as_nanos() as u64);
        out
    }

    pub fn total_ns(&self, phase: TrainPhase) -> u64 {
        self.ns[phase as usize].load(Ordering::Relaxed)
    }

    pub fn calls(&self, phase: TrainPhase) -> u64 {
        self.calls[phase as usize].load(Ordering::Relaxed)
    }

    pub fn mean_us(&self, phase: TrainPhase) -> f64 {
        let calls = self.calls(phase);
        if calls == 0 {
            return 0.0;
        }
        self.total_ns(phase) as f64 / calls as f64 / 1_000.0
    }

    pub fn reset(&self) {
        for i in 0..TrainPhase::COUNT {
            self.ns[i].store(0, Ordering::Relaxed);
            self.calls[i].store(0, Ordering::Relaxed);
        }
    }

    /// One line per active phase, with its share of the accumulated total.
    pub fn report(&self) -> String {
        let grand: u64 = TrainPhase::ALL.iter().map(|&p| self.total_ns(p)).sum();
        let mut out = String::new();
        for phase in TrainPhase::ALL {
            let calls = self.calls(phase);
            if calls == 0 {
                continue;
            }
            let ns = self.total_ns(phase);
            let share = if grand == 0 { 0.0 } else { 100.0 * ns as f64 / grand as f64 };
            out.push_str(&format!(
                "{:<14} calls {:>9}  total {:>9.3}s  mean {:>9.1}us  {:>5.1}%\n",
                phase.name(),
                calls,
                ns as f64 / 1e9,
                self.mean_us(phase),
                share,
            ));
        }
        out
    }
}

/// Simple scoped stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed_ns(&self) -> u64 {
        self.0.elapsed().as_nanos() as u64
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_phase() {
        let t = PhaseTimers::new();
        t.record(Phase::EnvStep, 1000);
        t.record(Phase::EnvStep, 3000);
        t.record(Phase::Train, 500);
        assert_eq!(t.total_ns(Phase::EnvStep), 4000);
        assert_eq!(t.calls(Phase::EnvStep), 2);
        assert!((t.mean_us(Phase::EnvStep) - 2.0).abs() < 1e-9);
        assert_eq!(t.calls(Phase::Infer), 0);
        let rep = t.report();
        assert!(rep.contains("env_step"));
        assert!(!rep.contains("infer"));
    }

    #[test]
    fn time_closure() {
        let t = PhaseTimers::new();
        let x = t.time(Phase::Sync, || 41 + 1);
        assert_eq!(x, 42);
        assert_eq!(t.calls(Phase::Sync), 1);
        assert!(t.total_ns(Phase::Sync) > 0);
    }

    #[test]
    fn reset_clears() {
        let t = PhaseTimers::new();
        t.record(Phase::Train, 10);
        t.reset();
        assert_eq!(t.calls(Phase::Train), 0);
    }

    #[test]
    fn train_timers_accumulate_and_report_shares() {
        let t = TrainTimers::new();
        t.record(TrainPhase::ConvForward, 3000);
        t.record(TrainPhase::ConvForward, 1000);
        t.record(TrainPhase::Rmsprop, 4000);
        assert_eq!(t.total_ns(TrainPhase::ConvForward), 4000);
        assert_eq!(t.calls(TrainPhase::ConvForward), 2);
        assert!((t.mean_us(TrainPhase::ConvForward) - 2.0).abs() < 1e-9);
        assert_eq!(t.calls(TrainPhase::Dense), 0);
        let rep = t.report();
        assert!(rep.contains("conv_forward"));
        assert!(rep.contains("rmsprop"));
        assert!(rep.contains("50.0%"));
        assert!(!rep.contains("dense"));
        let x = t.time(TrainPhase::Assembly, || 7);
        assert_eq!(x, 7);
        assert_eq!(t.calls(TrainPhase::Assembly), 1);
        t.reset();
        assert_eq!(t.calls(TrainPhase::ConvForward), 0);
        assert_eq!(t.total_ns(TrainPhase::Rmsprop), 0);
    }
}
