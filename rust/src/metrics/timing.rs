//! Phase timers: accumulate wall-clock per pipeline phase, thread-safely.

use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use super::gantt::Phase;

/// Accumulated nanoseconds + call counts per phase.
#[derive(Debug, Default)]
pub struct PhaseTimers {
    ns: [AtomicU64; Phase::COUNT],
    calls: [AtomicU64; Phase::COUNT],
}

impl PhaseTimers {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record(&self, phase: Phase, ns: u64) {
        self.ns[phase as usize].fetch_add(ns, Ordering::Relaxed);
        self.calls[phase as usize].fetch_add(1, Ordering::Relaxed);
    }

    /// Time `f`, attributing its duration to `phase`.
    pub fn time<T>(&self, phase: Phase, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.record(phase, t0.elapsed().as_nanos() as u64);
        out
    }

    pub fn total_ns(&self, phase: Phase) -> u64 {
        self.ns[phase as usize].load(Ordering::Relaxed)
    }

    pub fn calls(&self, phase: Phase) -> u64 {
        self.calls[phase as usize].load(Ordering::Relaxed)
    }

    pub fn mean_us(&self, phase: Phase) -> f64 {
        let calls = self.calls(phase);
        if calls == 0 {
            return 0.0;
        }
        self.total_ns(phase) as f64 / calls as f64 / 1_000.0
    }

    pub fn reset(&self) {
        for i in 0..Phase::COUNT {
            self.ns[i].store(0, Ordering::Relaxed);
            self.calls[i].store(0, Ordering::Relaxed);
        }
    }

    /// One summary line per phase with any activity.
    pub fn report(&self) -> String {
        let mut out = String::new();
        for phase in Phase::ALL {
            let calls = self.calls(phase);
            if calls == 0 {
                continue;
            }
            out.push_str(&format!(
                "{:<12} calls {:>9}  total {:>9.3}s  mean {:>9.1}us\n",
                phase.name(),
                calls,
                self.total_ns(phase) as f64 / 1e9,
                self.mean_us(phase),
            ));
        }
        out
    }
}

/// Simple scoped stopwatch.
pub struct Stopwatch(Instant);

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch(Instant::now())
    }

    pub fn elapsed_ns(&self) -> u64 {
        self.0.elapsed().as_nanos() as u64
    }

    pub fn elapsed_s(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulates_per_phase() {
        let t = PhaseTimers::new();
        t.record(Phase::EnvStep, 1000);
        t.record(Phase::EnvStep, 3000);
        t.record(Phase::Train, 500);
        assert_eq!(t.total_ns(Phase::EnvStep), 4000);
        assert_eq!(t.calls(Phase::EnvStep), 2);
        assert!((t.mean_us(Phase::EnvStep) - 2.0).abs() < 1e-9);
        assert_eq!(t.calls(Phase::Infer), 0);
        let rep = t.report();
        assert!(rep.contains("env_step"));
        assert!(!rep.contains("infer"));
    }

    #[test]
    fn time_closure() {
        let t = PhaseTimers::new();
        let x = t.time(Phase::Sync, || 41 + 1);
        assert_eq!(x, 42);
        assert_eq!(t.calls(Phase::Sync), 1);
        assert!(t.total_ns(Phase::Sync) > 0);
    }

    #[test]
    fn reset_clears() {
        let t = PhaseTimers::new();
        t.record(Phase::Train, 10);
        t.reset();
        assert_eq!(t.calls(Phase::Train), 0);
    }
}
