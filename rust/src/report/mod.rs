//! Report formatting: regenerate the paper's tables from measured or
//! simulated data in the same row/column layout the paper prints.

use std::collections::BTreeMap;

use crate::config::ExecMode;
use crate::eval::{normalized_score, EvalPoint};

/// Runtime grid indexed by (mode, threads) in hours — the Table 1 payload.
#[derive(Clone, Debug, Default)]
pub struct RuntimeGrid {
    cells: BTreeMap<(String, usize), (f64, f64)>, // (mean_h, std_h)
    pub threads: Vec<usize>,
}

impl RuntimeGrid {
    pub fn new(threads: &[usize]) -> RuntimeGrid {
        RuntimeGrid { cells: BTreeMap::new(), threads: threads.to_vec() }
    }

    pub fn set(&mut self, mode: ExecMode, threads: usize, mean_h: f64, std_h: f64) {
        self.cells.insert((mode.name().to_string(), threads), (mean_h, std_h));
    }

    pub fn get(&self, mode: ExecMode, threads: usize) -> Option<(f64, f64)> {
        self.cells.get(&(mode.name().to_string(), threads)).copied()
    }

    fn baseline(&self) -> Option<f64> {
        self.get(ExecMode::Standard, 1).map(|(m, _)| m)
    }

    /// Table 1: measured runtimes (hours), mean ± std.
    pub fn table1(&self) -> String {
        let mut out = String::from(
            "Table 1: runtimes (hours) per execution mode and sampler threads\n",
        );
        out.push_str(&format!(
            "{:>8} {:>16} {:>16} {:>16} {:>16}\n",
            "Threads", "Standard", "Concurrent", "Synchronized", "Both"
        ));
        for &w in &self.threads {
            out.push_str(&format!("{w:>8}"));
            for mode in ExecMode::ALL {
                match self.get(mode, w) {
                    Some((m, s)) => out.push_str(&format!(" {:>9.2} ± {:<4.2}", m, s)),
                    None => out.push_str(&format!(" {:>16}", "—")),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Table 2: percentage of the standard W=1 runtime.
    pub fn table2(&self) -> String {
        let base = self.baseline().unwrap_or(1.0);
        let mut out = String::from("Table 2: runtime as % of DQN (standard, 1 thread)\n");
        out.push_str(&format!(
            "{:>8} {:>10} {:>10} {:>10} {:>10}\n",
            "Threads", "Std.", "Conc.", "Sync.", "Both"
        ));
        for &w in &self.threads {
            out.push_str(&format!("{w:>8}"));
            for mode in ExecMode::ALL {
                match self.get(mode, w) {
                    Some((m, _)) => out.push_str(&format!(" {:>9.1}%", 100.0 * m / base)),
                    None => out.push_str(&format!(" {:>10}", "—")),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Table 3: speedup relative to the standard W=1 runtime.
    pub fn table3(&self) -> String {
        let base = self.baseline().unwrap_or(1.0);
        let mut out = String::from("Table 3: speedup relative to DQN (standard, 1 thread)\n");
        out.push_str(&format!(
            "{:>8} {:>10} {:>10} {:>10} {:>10}\n",
            "Threads", "Std.", "Conc.", "Sync.", "Both"
        ));
        for &w in &self.threads {
            out.push_str(&format!("{w:>8}"));
            for mode in ExecMode::ALL {
                match self.get(mode, w) {
                    Some((m, _)) => out.push_str(&format!(" {:>9.2}x", base / m)),
                    None => out.push_str(&format!(" {:>10}", "—")),
                }
            }
            out.push('\n');
        }
        out
    }

    /// Headline speedup (standard-1 vs best cell), like the abstract's
    /// "25 hours to just 9 hours".
    pub fn headline(&self) -> Option<(f64, f64, f64)> {
        let base = self.baseline()?;
        let best = self
            .cells
            .values()
            .map(|(m, _)| *m)
            .fold(f64::INFINITY, f64::min);
        Some((base, best, base / best))
    }
}

/// One game row of the Table 4 analog.
#[derive(Clone, Debug)]
pub struct GameRow {
    pub game: String,
    pub random: EvalPoint,
    pub human: EvalPoint,
    pub baseline_dqn: f64,
    pub ours: f64,
}

impl GameRow {
    pub fn norm_baseline(&self) -> f64 {
        normalized_score(self.baseline_dqn, self.random.mean_return, self.human.mean_return)
    }

    pub fn norm_ours(&self) -> f64 {
        normalized_score(self.ours, self.random.mean_return, self.human.mean_return)
    }
}

/// Table 4 analog: per-game scores with human-normalized percentages.
pub fn table4(rows: &[GameRow]) -> String {
    let mut out = String::from(
        "Table 4 (suite analog): Random / Human-proxy / standard-DQN / tempo-dqn\n",
    );
    out.push_str(&format!(
        "{:<10} {:>9} {:>9} {:>9} {:>9} {:>11} {:>11}\n",
        "Game", "Random", "Human", "DQN", "Ours", "DQN(norm)", "Ours(norm)"
    ));
    let mut human_level = 0;
    let mut beats_baseline = 0;
    for r in rows {
        let nb = r.norm_baseline();
        let no = r.norm_ours();
        if no >= 75.0 {
            human_level += 1;
        }
        if r.ours >= r.baseline_dqn {
            beats_baseline += 1;
        }
        out.push_str(&format!(
            "{:<10} {:>9.1} {:>9.1} {:>9.1} {:>9.1} {:>10.1}% {:>10.1}%\n",
            r.game, r.random.mean_return, r.human.mean_return, r.baseline_dqn, r.ours, nb, no
        ));
    }
    out.push_str(&format!(
        "human-level (>=75% norm): {human_level}/{}; ours >= baseline: {beats_baseline}/{}\n",
        rows.len(),
        rows.len()
    ));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid() -> RuntimeGrid {
        let mut g = RuntimeGrid::new(&[1, 8]);
        g.set(ExecMode::Standard, 1, 25.08, 0.52);
        g.set(ExecMode::Concurrent, 1, 20.64, 0.29);
        g.set(ExecMode::Standard, 8, 16.92, 0.23);
        g.set(ExecMode::Both, 8, 9.02, 0.16);
        g
    }

    #[test]
    fn table1_formats_cells_and_gaps() {
        let t = grid().table1();
        assert!(t.contains("25.08"));
        assert!(t.contains("9.02"));
        assert!(t.contains("—"), "{t}");
    }

    #[test]
    fn table2_and_3_are_relative() {
        let g = grid();
        let t2 = g.table2();
        assert!(t2.contains("100.0%"), "{t2}");
        let t3 = g.table3();
        assert!(t3.contains("1.00x"));
        assert!(t3.contains("2.78x"), "{t3}");
    }

    #[test]
    fn headline_matches_paper() {
        let (base, best, speedup) = grid().headline().unwrap();
        assert_eq!(base, 25.08);
        assert_eq!(best, 9.02);
        assert!((speedup - 2.78).abs() < 0.01);
    }

    #[test]
    fn table4_counts_thresholds() {
        let ep = |m| EvalPoint { step: 0, mean_return: m, std_return: 0.0, episodes: 30 };
        let rows = vec![
            GameRow { game: "pong".into(), random: ep(-20.7), human: ep(9.3), baseline_dqn: 18.9, ours: 18.7 },
            GameRow { game: "x".into(), random: ep(0.0), human: ep(100.0), baseline_dqn: 10.0, ours: 80.0 },
        ];
        let t = table4(&rows);
        assert!(t.contains("human-level (>=75% norm): 2/2"), "{t}");
        assert!(t.contains("ours >= baseline: 1/2"), "{t}");
    }
}
