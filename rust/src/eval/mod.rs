//! Periodic evaluation harness (paper §5.2): run an epsilon-greedy policy
//! (eps = 0.05) for a fixed number of episodes in a *separate* environment
//! instance and report mean/std of the raw (un-clipped) episode returns.

use anyhow::Result;

use crate::agent::EpsGreedy;
use crate::ckpt::Snapshot;
use crate::env::{make_env, AtariEnv, STATE_BYTES};
use crate::runtime::{Policy, QNet};

/// One evaluation result.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalPoint {
    /// Training step at which this evaluation ran.
    pub step: u64,
    pub mean_return: f64,
    pub std_return: f64,
    pub episodes: usize,
}

pub struct Evaluator {
    env: AtariEnv,
    policy: EpsGreedy,
    eps: f64,
    episodes: usize,
    max_steps_per_episode: usize,
    /// Persistent state buffer for [`Self::run`]'s per-step inference —
    /// scratch only (rewritten every step, never snapshotted), so the
    /// hot eval loop allocates nothing.
    state_buf: Vec<u8>,
}

impl Evaluator {
    pub fn new(game: &str, seed: u64, episodes: usize, eps: f64) -> Result<Evaluator> {
        let env = make_env(game, seed ^ 0xE7A1)?;
        let actions = env.num_actions();
        Ok(Evaluator {
            env,
            policy: EpsGreedy::new(seed, 0xEEE, actions),
            eps,
            episodes,
            max_steps_per_episode: 27_000,
            state_buf: vec![0u8; STATE_BYTES],
        })
    }

    pub fn with_max_steps(mut self, n: usize) -> Self {
        self.max_steps_per_episode = n;
        self
    }

    /// Run the full evaluation (blocking). Acts with theta (the online
    /// network) like DQN's periodic evaluations.
    pub fn run(&mut self, qnet: &QNet, step: u64) -> Result<EvalPoint> {
        let mut returns = Vec::with_capacity(self.episodes);
        let state = &mut self.state_buf;
        for _ in 0..self.episodes {
            self.env.reset();
            let mut steps = 0;
            loop {
                self.env.write_state(state);
                let q = qnet.infer(Policy::Theta, state, 1)?;
                let a = self.policy.select(&q, self.eps);
                let actions = self.env.num_actions();
                // The policy is constructed with the env's action count, so
                // an out-of-range action is a wiring bug (wrong net config,
                // mismatched policy), not something to clamp away silently.
                debug_assert!(a < actions, "policy selected action {a}, env has {actions}");
                if a >= actions {
                    anyhow::bail!(
                        "evaluation policy selected action {a} but the environment \
                         has only {actions} actions (policy/action-space mismatch)"
                    );
                }
                let r = self.env.step(a);
                steps += 1;
                if r.done || steps >= self.max_steps_per_episode {
                    returns.push(self.env.episode_raw_return());
                    break;
                }
            }
        }
        let n = returns.len().max(1) as f64;
        let mean = returns.iter().sum::<f64>() / n;
        let var = returns.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / n;
        Ok(EvalPoint { step, mean_return: mean, std_return: var.sqrt(), episodes: returns.len() })
    }

    /// Score a fixed policy (random or scripted expert) — the Table 4
    /// anchor measurements.
    pub fn run_anchor(&mut self, kind: AnchorKind) -> Result<EvalPoint> {
        let mut returns = Vec::with_capacity(self.episodes);
        for _ in 0..self.episodes {
            self.env.reset();
            let mut steps = 0;
            loop {
                let a = match kind {
                    AnchorKind::Random => self.policy.random(),
                    AnchorKind::Expert => self.env.expert_action(),
                };
                let r = self.env.step(a);
                steps += 1;
                if r.done || steps >= self.max_steps_per_episode {
                    returns.push(self.env.episode_raw_return());
                    break;
                }
            }
        }
        let n = returns.len().max(1) as f64;
        let mean = returns.iter().sum::<f64>() / n;
        let var = returns.iter().map(|r| (r - mean) * (r - mean)).sum::<f64>() / n;
        Ok(EvalPoint { step: 0, mean_return: mean, std_return: var.sqrt(), episodes: returns.len() })
    }
}

/// Checkpoint the evaluator: its environment and policy RNG stream, so
/// resumed runs produce the exact evaluation points the uninterrupted run
/// would (the eval env reseeds per episode from its own counter, and the
/// policy RNG advances across evaluations).
impl crate::ckpt::Snapshot for Evaluator {
    fn kind(&self) -> &'static str {
        "evaluator"
    }

    fn save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.put_f64(self.eps);
        w.put_usize(self.episodes);
        w.put_usize(self.max_steps_per_episode);
        w.put_rng(self.policy.rng_state());
        self.env.save(w);
    }

    fn load(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> Result<()> {
        let eps = r.f64()?;
        let episodes = r.usize()?;
        if eps != self.eps || episodes != self.episodes {
            anyhow::bail!(
                "checkpoint evaluator ran eps={eps} episodes={episodes}, \
                 this run configures eps={} episodes={}",
                self.eps, self.episodes
            );
        }
        let max_steps = r.usize()?;
        if max_steps != self.max_steps_per_episode {
            anyhow::bail!(
                "checkpoint evaluator ran max_steps_per_episode={max_steps}, \
                 this run configures max_steps_per_episode={}",
                self.max_steps_per_episode
            );
        }
        self.policy.set_rng_state(r.rng()?);
        self.env.load(r)
    }
}

/// Fixed anchor policies for human-normalized scoring.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AnchorKind {
    Random,
    Expert,
}

/// Human-normalized score: 100 * (score - random) / (human - random),
/// the Mnih et al. (2015) normalization used throughout Table 4.
pub fn normalized_score(score: f64, random: f64, human: f64) -> f64 {
    if (human - random).abs() < 1e-12 {
        return 0.0;
    }
    100.0 * (score - random) / (human - random)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalization_matches_paper_formula() {
        // Pong row of Table 4: random -20.7, human 9.3, DQN 18.9 -> 132.0%.
        let n = normalized_score(18.9, -20.7, 9.3);
        assert!((n - 132.0).abs() < 0.5, "{n}");
        assert_eq!(normalized_score(5.0, 5.0, 5.0), 0.0);
    }

    #[test]
    fn out_of_range_action_is_refused_not_clamped() {
        use std::panic::{catch_unwind, AssertUnwindSafe};
        let device = std::sync::Arc::new(crate::runtime::Device::cpu().unwrap());
        let manifest =
            crate::runtime::Manifest::load_or_builtin(&crate::runtime::default_artifact_dir())
                .unwrap();
        let qnet = QNet::load(device, &manifest, "tiny", false, 32).unwrap();
        let mut ev = Evaluator::new("seeker", 3, 2, 0.05).unwrap().with_max_steps(200);
        // Recreate the mismatch the old clamp masked: a policy sized to the
        // net's 6-entry Q-rows acting in seeker's 5-action env. Pure-random
        // selection makes the out-of-range draw land within a few steps.
        ev.policy = EpsGreedy::new(3, 0xEEE, qnet.spec().actions);
        ev.eps = 1.0;
        assert!(qnet.spec().actions > ev.env.num_actions());
        match catch_unwind(AssertUnwindSafe(|| ev.run(&qnet, 0))) {
            // Debug builds (cargo test keeps debug assertions): the
            // assertion fires before the named error path.
            Err(_) => {}
            Ok(outcome) => {
                let err = format!(
                    "{:#}",
                    outcome.expect_err("out-of-range action must be refused, not clamped")
                );
                assert!(err.contains("policy/action-space mismatch"), "{err}");
            }
        }
    }

    #[test]
    fn snapshot_refuses_max_steps_mismatch_by_name() {
        let ev = Evaluator::new("seeker", 3, 2, 0.05).unwrap().with_max_steps(400);
        let mut w = crate::ckpt::ByteWriter::new();
        ev.save(&mut w);
        let bytes = w.into_bytes();

        // Matching configuration restores cleanly.
        let mut same = Evaluator::new("seeker", 9, 2, 0.05).unwrap().with_max_steps(400);
        let mut r = crate::ckpt::ByteReader::new(&bytes);
        same.load(&mut r).unwrap();
        r.finish().unwrap();
        assert_eq!(same.max_steps_per_episode, 400);

        // A different cap is refused with the field named — the checkpoint
        // must not silently override `with_max_steps`.
        let mut other = Evaluator::new("seeker", 9, 2, 0.05).unwrap().with_max_steps(300);
        let mut r = crate::ckpt::ByteReader::new(&bytes);
        let err = other.load(&mut r).unwrap_err().to_string();
        assert!(err.contains("max_steps_per_episode=400"), "{err}");
        assert!(err.contains("max_steps_per_episode=300"), "{err}");
        assert_eq!(other.max_steps_per_episode, 300);
    }

    #[test]
    fn anchors_rank_expert_above_random() {
        let mut ev = Evaluator::new("seeker", 3, 2, 0.05)
            .unwrap()
            .with_max_steps(400);
        let rand = ev.run_anchor(AnchorKind::Random).unwrap();
        let expert = ev.run_anchor(AnchorKind::Expert).unwrap();
        assert!(
            expert.mean_return > rand.mean_return,
            "expert {} <= random {}",
            expert.mean_return,
            rand.mean_return
        );
    }
}
