//! # tempo-dqn
//!
//! Production-grade reproduction of *Human-Level Control without
//! Server-Grade Hardware* (Daley & Amato, 2021): a fast DQN built on
//! **Concurrent Training** (act with the target network so sampling and
//! training parallelize) and **Synchronized Execution** (batch all sampler
//! threads' inference into one accelerator transaction).
//!
//! Three-layer architecture:
//! * L1/L2 (build time): JAX + Pallas kernels lowered to HLO text
//!   (`python/compile/`), never imported at runtime.
//! * L3 (this crate): the coordinator — environments (W×B vectorized
//!   streams), replay, execution modes, evaluation, metrics,
//!   hardware-model simulator — plus a pluggable execution engine: a
//!   pure-Rust native backend by default, or PJRT executing the AOT
//!   artifacts (`--features xla`).
//!
//! See rust/DESIGN.md for the system inventory (§2 engines, §5 the W×B
//! execution model, §7 determinism invariants).

pub mod agent;
pub mod benchkit;
pub mod campaign;
pub mod ckpt;
pub mod config;
pub mod coordinator;
pub mod eval;
pub mod hwsim;
pub mod env;
pub mod metrics;
pub mod net;
pub mod replay;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod util;
