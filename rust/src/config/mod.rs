//! Configuration system: typed experiment config + TOML-subset file loader
//! + CLI overrides. The `paper` preset reproduces Table 5 of Daley & Amato
//! (2021) / Mnih et al. (2015) exactly.

pub mod schema;
pub mod toml;

pub use schema::{EpsSchedule, ExecMode, ExperimentConfig, HeadKind, ReplayStrategy};
pub use toml::TomlDoc;
