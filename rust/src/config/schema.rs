//! Typed experiment configuration (launcher-level, Megatron-style: preset
//! file -> CLI overrides -> validated struct).

use std::path::Path;

use anyhow::{bail, Result};

use crate::runtime::{Head, KernelMode};
use crate::util::cli::Args;

use super::toml::TomlDoc;

/// Which coordinator drives the run (the paper's §5.1 ablation axes).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// Original DQN control flow: alternate sampling and training.
    Standard,
    /// Concurrent Training only (paper §3).
    Concurrent,
    /// Synchronized Execution only (paper §4).
    Synchronized,
    /// Both combined (paper Algorithm 1).
    Both,
}

impl ExecMode {
    pub fn parse(s: &str) -> Result<ExecMode> {
        Ok(match s {
            "standard" => ExecMode::Standard,
            "concurrent" => ExecMode::Concurrent,
            "synchronized" | "sync" => ExecMode::Synchronized,
            "both" | "combined" => ExecMode::Both,
            other => bail!("unknown exec mode {other:?} (standard|concurrent|synchronized|both)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ExecMode::Standard => "standard",
            ExecMode::Concurrent => "concurrent",
            ExecMode::Synchronized => "synchronized",
            ExecMode::Both => "both",
        }
    }

    pub fn concurrent_training(self) -> bool {
        matches!(self, ExecMode::Concurrent | ExecMode::Both)
    }

    pub fn synchronized_execution(self) -> bool {
        matches!(self, ExecMode::Synchronized | ExecMode::Both)
    }

    pub const ALL: [ExecMode; 4] = [
        ExecMode::Standard,
        ExecMode::Concurrent,
        ExecMode::Synchronized,
        ExecMode::Both,
    ];
}

/// Which replay sampling strategy feeds the trainer (rust/DESIGN.md §11).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplayStrategy {
    /// Uniform over all stored transitions — the paper's (and seed
    /// machine's) sampler; with `n_step = 1` bit-identical to it.
    Uniform,
    /// Proportional prioritized experience replay (Schaul et al. 2015)
    /// over a deterministic sum-tree, TD-error priorities updated at
    /// window barriers, IS weights in the loss.
    Proportional,
}

impl ReplayStrategy {
    pub fn parse(s: &str) -> Result<ReplayStrategy> {
        Ok(match s {
            "uniform" => ReplayStrategy::Uniform,
            "proportional" | "prioritized" | "per" => ReplayStrategy::Proportional,
            other => bail!("unknown replay strategy {other:?} (uniform|proportional)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            ReplayStrategy::Uniform => "uniform",
            ReplayStrategy::Proportional => "proportional",
        }
    }
}

/// Q-head variant on the shared conv trunk (rust/DESIGN.md §16).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HeadKind {
    /// Single dense tail emitting Q(s,a) — the seed machine.
    Dqn,
    /// Dueling streams (Wang et al. 2016): Q = V + A − mean(A).
    Dueling,
    /// Distributional C51 (Bellemare et al. 2017): per-action atom
    /// distributions over a fixed support, cross-entropy training,
    /// expected-value Q for acting.
    C51,
}

impl HeadKind {
    pub fn parse(s: &str) -> Result<HeadKind> {
        Ok(match s {
            "dqn" => HeadKind::Dqn,
            "dueling" => HeadKind::Dueling,
            "c51" | "distributional" => HeadKind::C51,
            other => bail!("unknown head {other:?} (dqn|dueling|c51)"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            HeadKind::Dqn => "dqn",
            HeadKind::Dueling => "dueling",
            HeadKind::C51 => "c51",
        }
    }
}

/// Linear epsilon-greedy schedule (Mnih et al. 2015: 1.0 -> 0.1 over 1M
/// steps, then fixed).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct EpsSchedule {
    pub start: f64,
    pub end: f64,
    pub decay_steps: u64,
}

impl EpsSchedule {
    pub fn at(&self, step: u64) -> f64 {
        if self.decay_steps == 0 || step >= self.decay_steps {
            return self.end;
        }
        let frac = step as f64 / self.decay_steps as f64;
        self.start + (self.end - self.start) * frac
    }

    pub const fn fixed(eps: f64) -> EpsSchedule {
        EpsSchedule { start: eps, end: eps, decay_steps: 0 }
    }
}

/// Full experiment configuration.
#[derive(Clone, Debug)]
pub struct ExperimentConfig {
    // Run identity
    pub game: String,
    pub seed: u64,
    pub mode: ExecMode,

    // Hardware model
    /// W sampler threads (the paper's abstract machine executes W CPU
    /// program threads + 1 accelerator task).
    pub threads: usize,
    /// B environments per sampler thread. The coordinator runs W×B
    /// environment streams; in synchronized modes one device transaction
    /// serves all W×B steps of a round. B=1 reproduces the paper's
    /// one-env-per-thread machine exactly (rust/DESIGN.md §5).
    pub envs_per_thread: usize,
    /// Learner compute-pool width: the native engine shards each minibatch
    /// forward/backward over this many lanes with an order-preserving
    /// gradient reduction, so every value produces bit-identical results
    /// (rust/DESIGN.md §9). 1 = the serial learner.
    pub learner_threads: usize,
    /// Minibatches the replay prefetch pipeline assembles ahead of the
    /// trainer (windowed modes only). 0 disables prefetch (sample inline,
    /// the historical behavior); any value yields the identical training
    /// trajectory — the pipeline is quota-gated at window barriers.
    pub prefetch_batches: usize,
    /// Kernel dispatch tier for the native engine (rust/DESIGN.md §12).
    /// `deterministic` is the bit-pinned serial-order tiled path (default,
    /// golden reference); `fast` enables the vectorized lane-reordered
    /// kernels under a bounded divergence contract — still bit-identical
    /// run-to-run and across `learner_threads`, but not vs `deterministic`.
    pub kernel_mode: KernelMode,

    // Network / artifacts
    pub net: String,
    pub double: bool,
    /// Q-head variant (`dqn` keeps the seed machine bit-for-bit).
    pub head: HeadKind,
    /// C51 only: atoms per action distribution.
    pub atoms: usize,
    /// C51 only: support lower bound.
    pub v_min: f64,
    /// C51 only: support upper bound.
    pub v_max: f64,

    // DQN hyperparameters (paper Table 5 defaults)
    pub total_steps: u64,
    pub minibatch: usize,
    pub replay_capacity: usize,
    /// C: target update period.
    pub target_update_period: u64,
    /// F: training period (one minibatch per F steps).
    pub train_period: u64,
    pub gamma: f64,
    pub prepopulate: usize,
    pub lr: f64,
    pub eps: EpsSchedule,

    // Replay sampling strategy (rust/DESIGN.md §11)
    /// Trainer-side draw distribution. `uniform` + `n_step = 1` is the
    /// seed machine bit-for-bit; `proportional` is deterministic PER.
    pub replay_strategy: ReplayStrategy,
    /// PER priority exponent α: p = (|δ| + ε)^α (0 = uniform mass).
    pub per_alpha: f64,
    /// PER initial importance-sampling exponent β₀.
    pub per_beta0: f64,
    /// Trainer minibatches over which β anneals linearly from β₀ to 1
    /// (paper scale: total_steps / F = 12.5M updates).
    pub per_beta_anneal: u64,
    /// Multi-step return horizon n (1 = classic one-step targets,
    /// reproducing the seed trajectory exactly under `uniform`).
    pub n_step: usize,

    // Evaluation
    pub eval_period: u64,
    pub eval_episodes: usize,
    pub eval_eps: f64,
    /// Root seed of every evaluator (training-time evals, anchors, suite
    /// scoring). Separate from `seed` so resumed runs and campaigns control
    /// evaluation randomness independently of the training trajectory.
    pub eval_seed: u64,

    // Checkpointing (rust/DESIGN.md §10)
    /// Checkpoint directory; None disables checkpointing.
    pub ckpt_dir: Option<String>,
    /// Steps between checkpoints (quantized up to the mode's next quiesce
    /// point — a C-aligned window boundary in concurrent modes).
    pub ckpt_period: u64,

    // Distributed sampler fleet (rust/DESIGN.md §14)
    /// Local sampler worker processes spawned by the `fleet` convenience
    /// subcommand (0 = plain single-process execution). NOT part of the
    /// resume fingerprint: a replicated fleet run IS the single-process
    /// trajectory, so checkpoints cross the single↔fleet boundary freely.
    pub fleet_samplers: usize,
    /// Fleet parameter staleness, in target windows. 0 = **replicated**
    /// mode: samplers act window j with exactly the theta_minus the
    /// single-process machine would, and the digest is bit-identical to
    /// it. K >= 1 = **relaxed** mode: samplers act window j with the
    /// parameters broadcast K barriers earlier (deterministic bounded
    /// staleness — reproducible, but a deliberately different
    /// trajectory). Fingerprinted: it changes what is learned.
    pub fleet_lag: u64,
    /// Fleet socket read timeout / heartbeat window, milliseconds. A peer
    /// silent for this long is reported as a heartbeat timeout. Not
    /// fingerprinted (wall-clock only; cannot move the trajectory).
    pub fleet_timeout_ms: u64,

    // Policy-serving daemon (rust/DESIGN.md §15). Deployment knobs, not
    // training knobs: none is fingerprinted or serialized by to_cli_args.
    /// Max states the serve collector coalesces into one device
    /// transaction (the daemon's W×B analog).
    pub serve_max_batch: usize,
    /// Collector flush deadline, microseconds: how long the first request
    /// of a batch may wait for co-riders before the batch is dispatched.
    /// 0 = dispatch immediately (no coalescing beyond what is queued).
    pub serve_flush_us: u64,
    /// Checkpoint-watcher poll interval, milliseconds.
    pub serve_poll_ms: u64,
}

impl Default for ExperimentConfig {
    /// The paper's Table 5 values, on the `small` net and pong.
    fn default() -> Self {
        ExperimentConfig {
            game: "pong".into(),
            seed: 0,
            mode: ExecMode::Both,
            threads: 8,
            envs_per_thread: 1,
            learner_threads: 1,
            prefetch_batches: 1,
            kernel_mode: KernelMode::Deterministic,
            net: "small".into(),
            double: false,
            head: HeadKind::Dqn,
            atoms: 51,
            v_min: -10.0,
            v_max: 10.0,
            total_steps: 50_000_000,
            minibatch: 32,
            replay_capacity: 1_000_000,
            target_update_period: 10_000,
            train_period: 4,
            gamma: 0.99,
            prepopulate: 50_000,
            lr: 2.5e-4,
            eps: EpsSchedule { start: 1.0, end: 0.1, decay_steps: 1_000_000 },
            replay_strategy: ReplayStrategy::Uniform,
            per_alpha: 0.6,
            per_beta0: 0.4,
            per_beta_anneal: 12_500_000,
            n_step: 1,
            eval_period: 250_000,
            eval_episodes: 30,
            eval_eps: 0.05,
            eval_seed: 7,
            ckpt_dir: None,
            ckpt_period: 250_000,
            fleet_samplers: 0,
            fleet_lag: 0,
            fleet_timeout_ms: 60_000,
            serve_max_batch: 32,
            serve_flush_us: 500,
            serve_poll_ms: 200,
        }
    }
}

impl ExperimentConfig {
    /// Named presets. `paper` = Table 5; `speedtest` = the §5.1 setup
    /// (eps fixed at 0.1, 1M steps); `smoke` = seconds-scale CI run.
    pub fn preset(name: &str) -> Result<ExperimentConfig> {
        let mut c = ExperimentConfig::default();
        match name {
            "paper" => {}
            "speedtest" => {
                c.total_steps = 1_000_000;
                c.eps = EpsSchedule::fixed(0.1);
                c.eval_period = u64::MAX;
            }
            "smoke" => {
                c.net = "tiny".into();
                c.total_steps = 400;
                c.replay_capacity = 4_000;
                c.prepopulate = 200;
                c.target_update_period = 100;
                c.eps = EpsSchedule { start: 1.0, end: 0.1, decay_steps: 200 };
                c.eval_period = u64::MAX;
                c.threads = 2;
            }
            other => bail!("unknown preset {other:?} (paper|speedtest|smoke)"),
        }
        Ok(c)
    }

    /// Load a TOML config file over a preset base.
    pub fn from_toml(doc: &TomlDoc) -> Result<ExperimentConfig> {
        let base = Self::preset(&doc.str_or("preset", "paper")?)?;
        let mut c = base;
        c.game = doc.str_or("run.game", &c.game)?;
        c.seed = doc.usize_or("run.seed", c.seed as usize)? as u64;
        c.mode = ExecMode::parse(&doc.str_or("run.mode", c.mode.name())?)?;
        c.threads = doc.usize_or("run.threads", c.threads)?;
        c.envs_per_thread = doc.usize_or("run.envs_per_thread", c.envs_per_thread)?;
        c.learner_threads = doc.usize_or("learner.threads", c.learner_threads)?;
        c.prefetch_batches = doc.usize_or("learner.prefetch_batches", c.prefetch_batches)?;
        c.kernel_mode =
            KernelMode::parse(&doc.str_or("learner.kernel_mode", c.kernel_mode.name())?)?;
        c.net = doc.str_or("net.config", &c.net)?;
        c.double = doc.bool_or("net.double", c.double)?;
        c.head = HeadKind::parse(&doc.str_or("net.head", c.head.name())?)?;
        c.atoms = doc.usize_or("net.atoms", c.atoms)?;
        c.v_min = doc.f64_or("net.v_min", c.v_min)?;
        c.v_max = doc.f64_or("net.v_max", c.v_max)?;
        c.total_steps = doc.usize_or("dqn.total_steps", c.total_steps as usize)? as u64;
        c.minibatch = doc.usize_or("dqn.minibatch", c.minibatch)?;
        c.replay_capacity = doc.usize_or("dqn.replay_capacity", c.replay_capacity)?;
        c.target_update_period =
            doc.usize_or("dqn.target_update_period", c.target_update_period as usize)? as u64;
        c.train_period = doc.usize_or("dqn.train_period", c.train_period as usize)? as u64;
        c.gamma = doc.f64_or("dqn.gamma", c.gamma)?;
        c.prepopulate = doc.usize_or("dqn.prepopulate", c.prepopulate)?;
        c.lr = doc.f64_or("dqn.lr", c.lr)?;
        c.eps = EpsSchedule {
            start: doc.f64_or("eps.start", c.eps.start)?,
            end: doc.f64_or("eps.end", c.eps.end)?,
            decay_steps: doc.usize_or("eps.decay_steps", c.eps.decay_steps as usize)? as u64,
        };
        c.replay_strategy = ReplayStrategy::parse(&doc.str_or("replay.strategy", c.replay_strategy.name())?)?;
        c.per_alpha = doc.f64_or("replay.per_alpha", c.per_alpha)?;
        c.per_beta0 = doc.f64_or("replay.per_beta0", c.per_beta0)?;
        c.per_beta_anneal = doc.usize_or("replay.per_beta_anneal", c.per_beta_anneal as usize)? as u64;
        c.n_step = doc.usize_or("replay.n_step", c.n_step)?;
        c.eval_period = doc.usize_or("eval.period", c.eval_period as usize)? as u64;
        c.eval_episodes = doc.usize_or("eval.episodes", c.eval_episodes)?;
        c.eval_eps = doc.f64_or("eval.eps", c.eval_eps)?;
        c.eval_seed = doc.usize_or("eval.seed", c.eval_seed as usize)? as u64;
        if let Some(crate::config::toml::TomlValue::Str(dir)) = doc.get("ckpt.dir") {
            c.ckpt_dir = Some(dir.clone());
        }
        c.ckpt_period = doc.usize_or("ckpt.period", c.ckpt_period as usize)? as u64;
        c.fleet_samplers = doc.usize_or("fleet.samplers", c.fleet_samplers)?;
        c.fleet_lag = doc.usize_or("fleet.lag", c.fleet_lag as usize)? as u64;
        c.fleet_timeout_ms = doc.usize_or("fleet.timeout_ms", c.fleet_timeout_ms as usize)? as u64;
        c.serve_max_batch = doc.usize_or("serve.max_batch", c.serve_max_batch)?;
        c.serve_flush_us = doc.usize_or("serve.flush_us", c.serve_flush_us as usize)? as u64;
        c.serve_poll_ms = doc.usize_or("serve.poll_ms", c.serve_poll_ms as usize)? as u64;
        c.validate()?;
        Ok(c)
    }

    /// Apply CLI overrides (highest priority).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.str_opt("game") {
            self.game = v.to_string();
        }
        if let Some(v) = args.str_opt("mode") {
            self.mode = ExecMode::parse(v)?;
        }
        if let Some(v) = args.str_opt("net") {
            self.net = v.to_string();
        }
        if args.flag("double") {
            self.double = true;
        }
        if let Some(v) = args.str_opt("head") {
            self.head = HeadKind::parse(v)?;
        }
        self.atoms = args.usize_or("atoms", self.atoms)?;
        self.v_min = args.f64_or("v-min", self.v_min)?;
        self.v_max = args.f64_or("v-max", self.v_max)?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.threads = args.usize_or("threads", self.threads)?;
        self.envs_per_thread = args.usize_or("envs-per-thread", self.envs_per_thread)?;
        self.learner_threads = args.usize_or("learner-threads", self.learner_threads)?;
        self.prefetch_batches = args.usize_or("prefetch-batches", self.prefetch_batches)?;
        if let Some(v) = args.str_opt("kernel-mode") {
            self.kernel_mode = KernelMode::parse(v)?;
        }
        self.total_steps = args.u64_or("steps", self.total_steps)?;
        self.minibatch = args.usize_or("minibatch", self.minibatch)?;
        self.replay_capacity = args.usize_or("replay-capacity", self.replay_capacity)?;
        self.target_update_period = args.u64_or("target-period", self.target_update_period)?;
        self.train_period = args.u64_or("train-period", self.train_period)?;
        self.gamma = args.f64_or("gamma", self.gamma)?;
        self.prepopulate = args.usize_or("prepopulate", self.prepopulate)?;
        self.lr = args.f64_or("lr", self.lr)?;
        self.eps = EpsSchedule {
            start: args.f64_or("eps-start", self.eps.start)?,
            end: args.f64_or("eps-end", self.eps.end)?,
            decay_steps: args.u64_or("eps-decay-steps", self.eps.decay_steps)?,
        };
        if let Some(v) = args.str_opt("replay-strategy") {
            self.replay_strategy = ReplayStrategy::parse(v)?;
        }
        self.per_alpha = args.f64_or("per-alpha", self.per_alpha)?;
        self.per_beta0 = args.f64_or("per-beta0", self.per_beta0)?;
        self.per_beta_anneal = args.u64_or("per-beta-anneal", self.per_beta_anneal)?;
        self.n_step = args.usize_or("n-step", self.n_step)?;
        self.eval_period = args.u64_or("eval-period", self.eval_period)?;
        self.eval_episodes = args.usize_or("eval-episodes", self.eval_episodes)?;
        self.eval_eps = args.f64_or("eval-eps", self.eval_eps)?;
        self.eval_seed = args.u64_or("eval-seed", self.eval_seed)?;
        if let Some(dir) = args.str_opt("ckpt-dir") {
            self.ckpt_dir = Some(dir.to_string());
        }
        self.ckpt_period = args.u64_or("ckpt-period", self.ckpt_period)?;
        self.fleet_samplers = args.usize_or("fleet-samplers", self.fleet_samplers)?;
        self.fleet_lag = args.u64_or("fleet-lag", self.fleet_lag)?;
        self.fleet_timeout_ms = args.u64_or("fleet-timeout-ms", self.fleet_timeout_ms)?;
        self.serve_max_batch = args.usize_or("serve-max-batch", self.serve_max_batch)?;
        self.serve_flush_us = args.u64_or("serve-flush-us", self.serve_flush_us)?;
        self.serve_poll_ms = args.u64_or("serve-poll-ms", self.serve_poll_ms)?;
        self.validate()
    }

    /// Build from preset/--config file/CLI in priority order.
    pub fn resolve(args: &Args) -> Result<ExperimentConfig> {
        let mut cfg = if let Some(path) = args.str_opt("config") {
            ExperimentConfig::from_toml(&TomlDoc::load(Path::new(path))?)?
        } else {
            ExperimentConfig::preset(args.get_or("preset", "paper"))?
        };
        cfg.apply_args(args)?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.threads == 0 {
            bail!("threads must be >= 1");
        }
        if self.envs_per_thread == 0 {
            bail!("envs_per_thread must be >= 1");
        }
        if self.learner_threads == 0 {
            bail!("learner_threads must be >= 1 (1 = serial learner)");
        }
        if self.learner_threads > 128 {
            bail!(
                "learner_threads = {} is not a plausible compute-pool width (max 128); \
                 each lane is a persistent OS thread",
                self.learner_threads
            );
        }
        if self.prefetch_batches > 64 {
            bail!(
                "prefetch_batches = {} would preallocate that many minibatch buffers \
                 (max 64); depth 1-2 already hides assembly latency",
                self.prefetch_batches
            );
        }
        if self.train_period == 0 || self.target_update_period == 0 {
            bail!("train_period and target_update_period must be >= 1");
        }
        if self.target_update_period % self.train_period != 0 {
            bail!(
                "target_update_period (C={}) must be a multiple of train_period (F={}) — paper §3 footnote 3",
                self.target_update_period, self.train_period
            );
        }
        if !(0.0..=1.0).contains(&self.gamma) {
            bail!("gamma must be in [0,1]");
        }
        if self.minibatch == 0 {
            bail!("minibatch must be >= 1");
        }
        if !(2..=255).contains(&self.atoms) {
            bail!(
                "atoms = {} is out of range 2..=255 (the C51 support needs at least two \
                 atoms; beyond 255 the distributional tail dominates the network)",
                self.atoms
            );
        }
        if self.v_min >= self.v_max {
            bail!("v_min ({}) must be < v_max ({})", self.v_min, self.v_max);
        }
        if !(0.0..=1.0).contains(&self.per_alpha) {
            bail!("per_alpha must be in [0,1] (0 = uniform mass, 1 = fully proportional)");
        }
        if !(self.per_beta0 > 0.0 && self.per_beta0 <= 1.0) {
            bail!("per_beta0 must be in (0,1]");
        }
        if self.per_beta_anneal == 0 {
            bail!("per_beta_anneal must be >= 1 trainer minibatch");
        }
        if self.n_step == 0 || self.n_step > 64 {
            bail!(
                "n_step = {} is out of range 1..=64 (64-step windows already exceed any \
                 useful credit horizon at γ = {})",
                self.n_step, self.gamma
            );
        }
        if self.ckpt_dir.is_some() && self.ckpt_period == 0 {
            bail!("ckpt_period must be >= 1 step when checkpointing is enabled");
        }
        if self.eval_period == 0 {
            bail!("eval_period must be >= 1 step (use a period >= total_steps to disable evals)");
        }
        if self.fleet_lag > 32 {
            bail!(
                "fleet_lag = {} is out of range 0..=32 (the learner retains one theta_minus \
                 version per lagged window; staleness beyond 32 windows has no training value)",
                self.fleet_lag
            );
        }
        if self.fleet_timeout_ms == 0 {
            bail!("fleet_timeout_ms must be >= 1 (it is the peer liveness window)");
        }
        if self.serve_max_batch == 0 || self.serve_max_batch > 4_096 {
            bail!(
                "serve_max_batch = {} is out of range 1..=4096 (one device transaction's \
                 worth of states; the engine pads to the next loaded infer entry)",
                self.serve_max_batch
            );
        }
        if self.serve_poll_ms == 0 {
            bail!("serve_poll_ms must be >= 1 (it is the checkpoint-watcher poll interval)");
        }
        Ok(())
    }

    /// The runtime head this config selects (atoms/v_min/v_max only reach
    /// the engine for C51 — they are inert knobs under dqn/dueling).
    pub fn head_spec(&self) -> Head {
        match self.head {
            HeadKind::Dqn => Head::Dqn,
            HeadKind::Dueling => Head::Dueling,
            HeadKind::C51 => Head::C51 {
                atoms: self.atoms,
                v_min: self.v_min as f32,
                v_max: self.v_max as f32,
            },
        }
    }

    /// Minibatches trained per target window (C / F).
    pub fn batches_per_window(&self) -> u64 {
        self.target_update_period / self.train_period
    }

    /// Total environment streams (W × B). Stream `slot*B + j` is environment
    /// j of sampler thread `slot`; replay streams, policy RNG streams, and
    /// env seeds are all indexed by this global stream id.
    pub fn streams(&self) -> usize {
        self.threads * self.envs_per_thread
    }

    /// Serialize every behavior-relevant knob as CLI arguments that
    /// [`apply_args`](Self::apply_args) parses back to this exact config —
    /// how the `fleet` subcommand and campaign runner hand a config to a
    /// spawned sampler process. `--key=value` form keeps the grammar
    /// unambiguous; floats print via Rust's shortest round-trip `Display`.
    /// Deliberately omitted: `ckpt_dir`/`ckpt_period` (samplers never
    /// checkpoint), `fleet_samplers` (topology, not trajectory), and the
    /// `serve_*` knobs (deployment-side; a serving daemon has no training
    /// trajectory at all). The fingerprint handshake backstops any drift
    /// this list might develop.
    pub fn to_cli_args(&self) -> Vec<String> {
        let mut a: Vec<String> = Vec::new();
        let mut kv = |k: &str, v: String| a.push(format!("--{k}={v}"));
        kv("game", self.game.clone());
        kv("mode", self.mode.name().to_string());
        kv("net", self.net.clone());
        kv("head", self.head.name().to_string());
        kv("atoms", self.atoms.to_string());
        kv("v-min", format!("{}", self.v_min));
        kv("v-max", format!("{}", self.v_max));
        kv("seed", self.seed.to_string());
        kv("threads", self.threads.to_string());
        kv("envs-per-thread", self.envs_per_thread.to_string());
        kv("learner-threads", self.learner_threads.to_string());
        kv("prefetch-batches", self.prefetch_batches.to_string());
        kv("kernel-mode", self.kernel_mode.name().to_string());
        kv("steps", self.total_steps.to_string());
        kv("minibatch", self.minibatch.to_string());
        kv("replay-capacity", self.replay_capacity.to_string());
        kv("target-period", self.target_update_period.to_string());
        kv("train-period", self.train_period.to_string());
        kv("gamma", format!("{}", self.gamma));
        kv("prepopulate", self.prepopulate.to_string());
        kv("lr", format!("{}", self.lr));
        kv("eps-start", format!("{}", self.eps.start));
        kv("eps-end", format!("{}", self.eps.end));
        kv("eps-decay-steps", self.eps.decay_steps.to_string());
        kv("replay-strategy", self.replay_strategy.name().to_string());
        kv("per-alpha", format!("{}", self.per_alpha));
        kv("per-beta0", format!("{}", self.per_beta0));
        kv("per-beta-anneal", self.per_beta_anneal.to_string());
        kv("n-step", self.n_step.to_string());
        kv("eval-period", self.eval_period.to_string());
        kv("eval-episodes", self.eval_episodes.to_string());
        kv("eval-eps", format!("{}", self.eval_eps));
        kv("eval-seed", self.eval_seed.to_string());
        kv("fleet-lag", self.fleet_lag.to_string());
        kv("fleet-timeout-ms", self.fleet_timeout_ms.to_string());
        if self.double {
            a.push("--double".to_string());
        }
        a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_preset_matches_table5() {
        let c = ExperimentConfig::preset("paper").unwrap();
        assert_eq!(c.minibatch, 32);
        assert_eq!(c.replay_capacity, 1_000_000);
        assert_eq!(c.target_update_period, 10_000);
        assert_eq!(c.train_period, 4);
        assert_eq!(c.gamma, 0.99);
        assert_eq!(c.prepopulate, 50_000);
        assert!((c.lr - 2.5e-4).abs() < 1e-12);
        assert_eq!(c.batches_per_window(), 2_500);
    }

    #[test]
    fn speedtest_preset_matches_section_5_1() {
        let c = ExperimentConfig::preset("speedtest").unwrap();
        assert_eq!(c.total_steps, 1_000_000);
        assert_eq!(c.eps.at(0), 0.1);
        assert_eq!(c.eps.at(999_999), 0.1);
    }

    #[test]
    fn eps_schedule_linear() {
        let e = EpsSchedule { start: 1.0, end: 0.1, decay_steps: 1_000_000 };
        assert_eq!(e.at(0), 1.0);
        assert!((e.at(500_000) - 0.55).abs() < 1e-9);
        assert_eq!(e.at(1_000_000), 0.1);
        assert_eq!(e.at(50_000_000), 0.1);
    }

    #[test]
    fn validation_rejects_c_not_multiple_of_f() {
        let mut c = ExperimentConfig::preset("paper").unwrap();
        c.target_update_period = 10_001;
        assert!(c.validate().is_err());
    }

    #[test]
    fn toml_and_cli_override() {
        let doc = TomlDoc::parse(
            "preset = \"smoke\"\n[run]\nmode = \"concurrent\"\nthreads = 4\nenvs_per_thread = 8\n[dqn]\ntrain_period = 2\ntarget_update_period = 50\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.mode, ExecMode::Concurrent);
        assert_eq!(c.threads, 4);
        assert_eq!(c.envs_per_thread, 8);
        assert_eq!(c.streams(), 32);
        assert_eq!(c.batches_per_window(), 25);
        let args = Args::parse(
            ["--threads", "2", "--envs-per-thread", "4"].map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.threads, 2);
        assert_eq!(c.envs_per_thread, 4);
        assert_eq!(c.streams(), 8);
    }

    #[test]
    fn envs_per_thread_defaults_to_one_and_rejects_zero() {
        let c = ExperimentConfig::preset("paper").unwrap();
        assert_eq!(c.envs_per_thread, 1, "B=1 is the paper's machine");
        assert_eq!(c.streams(), c.threads);
        let mut bad = c;
        bad.envs_per_thread = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn learner_knobs_default_parse_and_validate() {
        let c = ExperimentConfig::preset("paper").unwrap();
        assert_eq!(c.learner_threads, 1, "serial learner is the default machine");
        assert_eq!(c.prefetch_batches, 1, "double-buffered prefetch by default");
        let mut bad = c.clone();
        bad.learner_threads = 0;
        assert!(bad.validate().is_err());
        bad.learner_threads = 100_000; // would spawn 100k OS threads
        assert!(bad.validate().is_err());
        let mut off = c.clone();
        off.prefetch_batches = 0; // prefetch off is a valid (historical) config
        off.validate().unwrap();
        off.prefetch_batches = 1_000_000_000; // would preallocate 1e9 buffers
        assert!(off.validate().is_err());

        let doc = TomlDoc::parse("preset = \"smoke\"\n[learner]\nthreads = 4\nprefetch_batches = 2\n")
            .unwrap();
        let mut c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.learner_threads, 4);
        assert_eq!(c.prefetch_batches, 2);
        let args = Args::parse(
            ["--learner-threads", "2", "--prefetch-batches", "0"].map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.learner_threads, 2);
        assert_eq!(c.prefetch_batches, 0);
    }

    #[test]
    fn eval_seed_and_ckpt_knobs_plumb_through() {
        let c = ExperimentConfig::preset("paper").unwrap();
        assert_eq!(c.eval_seed, 7, "historical evaluator seed is the default");
        assert_eq!(c.ckpt_dir, None, "checkpointing is opt-in");
        assert_eq!(c.ckpt_period, 250_000);

        let doc = TomlDoc::parse(
            "preset = \"smoke\"\n[eval]\nseed = 123\n[ckpt]\ndir = \"ckpts\"\nperiod = 5_000\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.eval_seed, 123);
        assert_eq!(c.ckpt_dir.as_deref(), Some("ckpts"));
        assert_eq!(c.ckpt_period, 5_000);

        let args = Args::parse(
            ["--eval-seed", "9", "--ckpt-dir", "/tmp/x", "--ckpt-period", "100"].map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.eval_seed, 9);
        assert_eq!(c.ckpt_dir.as_deref(), Some("/tmp/x"));
        assert_eq!(c.ckpt_period, 100);

        c.ckpt_period = 0;
        assert!(c.validate().is_err(), "period 0 with a ckpt dir must be rejected");
        c.ckpt_dir = None;
        c.validate().unwrap();
    }

    #[test]
    fn replay_strategy_knobs_default_parse_and_validate() {
        let c = ExperimentConfig::preset("paper").unwrap();
        assert_eq!(c.replay_strategy, ReplayStrategy::Uniform, "seed machine by default");
        assert_eq!(c.n_step, 1, "one-step targets by default");
        assert_eq!(c.per_alpha, 0.6);
        assert_eq!(c.per_beta0, 0.4);
        assert_eq!(c.per_beta_anneal, 12_500_000, "total_steps / F at paper scale");

        let doc = TomlDoc::parse(
            "preset = \"smoke\"\n[replay]\nstrategy = \"proportional\"\nper_alpha = 0.5\n\
             per_beta0 = 0.3\nper_beta_anneal = 1000\nn_step = 3\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.replay_strategy, ReplayStrategy::Proportional);
        assert_eq!(c.per_alpha, 0.5);
        assert_eq!(c.per_beta0, 0.3);
        assert_eq!(c.per_beta_anneal, 1000);
        assert_eq!(c.n_step, 3);

        let args = Args::parse(
            ["--replay-strategy", "uniform", "--n-step", "5", "--per-alpha", "1.0"].map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.replay_strategy, ReplayStrategy::Uniform);
        assert_eq!(c.n_step, 5);
        assert_eq!(c.per_alpha, 1.0);

        let mut bad = c.clone();
        bad.per_alpha = 1.5;
        assert!(bad.validate().is_err(), "alpha > 1 rejected");
        bad = c.clone();
        bad.per_beta0 = 0.0;
        assert!(bad.validate().is_err(), "beta0 = 0 rejected");
        bad = c.clone();
        bad.per_beta_anneal = 0;
        assert!(bad.validate().is_err(), "anneal 0 rejected");
        bad = c.clone();
        bad.n_step = 0;
        assert!(bad.validate().is_err(), "n_step 0 rejected");
        bad.n_step = 100_000;
        assert!(bad.validate().is_err(), "absurd n_step rejected");

        assert!(ReplayStrategy::parse("per").is_ok(), "alias accepted");
        assert!(ReplayStrategy::parse("bogus").is_err());
        for s in [ReplayStrategy::Uniform, ReplayStrategy::Proportional] {
            assert_eq!(ReplayStrategy::parse(s.name()).unwrap(), s);
        }
    }

    #[test]
    fn kernel_mode_knob_default_parse_and_validate() {
        let c = ExperimentConfig::preset("paper").unwrap();
        assert_eq!(
            c.kernel_mode,
            KernelMode::Deterministic,
            "bit-pinned tier is the default machine"
        );

        let doc = TomlDoc::parse("preset = \"smoke\"\n[learner]\nkernel_mode = \"fast\"\n").unwrap();
        let mut c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.kernel_mode, KernelMode::Fast);

        let args = Args::parse(["--kernel-mode", "deterministic"].map(String::from)).unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.kernel_mode, KernelMode::Deterministic);
        let args = Args::parse(["--kernel-mode", "simd"].map(String::from)).unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.kernel_mode, KernelMode::Fast, "simd alias accepted");

        let bad = Args::parse(["--kernel-mode", "bogus"].map(String::from)).unwrap();
        assert!(c.apply_args(&bad).is_err());
        for m in KernelMode::ALL {
            assert_eq!(KernelMode::parse(m.name()).unwrap(), m);
        }
    }

    #[test]
    fn head_knobs_default_parse_and_validate() {
        let c = ExperimentConfig::preset("paper").unwrap();
        assert_eq!(c.head, HeadKind::Dqn, "seed machine's head by default");
        assert_eq!(c.atoms, 51);
        assert_eq!(c.v_min, -10.0);
        assert_eq!(c.v_max, 10.0);
        assert_eq!(c.head_spec(), Head::Dqn);

        let doc = TomlDoc::parse(
            "preset = \"smoke\"\n[net]\nhead = \"c51\"\natoms = 21\nv_min = -5.0\nv_max = 5.0\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.head, HeadKind::C51);
        assert_eq!(c.head_spec(), Head::C51 { atoms: 21, v_min: -5.0, v_max: 5.0 });

        let args = Args::parse(
            ["--head=dueling", "--atoms=11", "--v-min=-3.5", "--v-max=3.5"].map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.head, HeadKind::Dueling);
        assert_eq!(c.head_spec(), Head::Dueling, "atoms/v_min/v_max inert under dueling");
        assert_eq!(c.atoms, 11);

        let mut bad = c.clone();
        bad.atoms = 1;
        assert!(bad.validate().is_err(), "one-atom support rejected");
        bad.atoms = 1000;
        assert!(bad.validate().is_err(), "absurd atom count rejected");
        bad = c.clone();
        bad.v_min = 2.0;
        bad.v_max = 2.0;
        assert!(bad.validate().is_err(), "empty support rejected");

        assert!(HeadKind::parse("distributional").is_ok(), "alias accepted");
        assert!(HeadKind::parse("bogus").is_err());
        for h in [HeadKind::Dqn, HeadKind::Dueling, HeadKind::C51] {
            assert_eq!(HeadKind::parse(h.name()).unwrap(), h);
        }
    }

    #[test]
    fn mode_parse_roundtrip() {
        for m in ExecMode::ALL {
            assert_eq!(ExecMode::parse(m.name()).unwrap(), m);
        }
        assert!(ExecMode::parse("bogus").is_err());
    }

    #[test]
    fn fleet_knobs_default_parse_and_validate() {
        let c = ExperimentConfig::preset("paper").unwrap();
        assert_eq!(c.fleet_samplers, 0, "single-process is the default machine");
        assert_eq!(c.fleet_lag, 0, "replicated mode is the default");
        assert_eq!(c.fleet_timeout_ms, 60_000);

        let doc = TomlDoc::parse(
            "preset = \"smoke\"\n[fleet]\nsamplers = 2\nlag = 1\ntimeout_ms = 5_000\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.fleet_samplers, 2);
        assert_eq!(c.fleet_lag, 1);
        assert_eq!(c.fleet_timeout_ms, 5_000);

        let args = Args::parse(
            ["--fleet-samplers", "3", "--fleet-lag", "0", "--fleet-timeout-ms", "100"]
                .map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.fleet_samplers, 3);
        assert_eq!(c.fleet_lag, 0);
        assert_eq!(c.fleet_timeout_ms, 100);

        let mut bad = c.clone();
        bad.fleet_lag = 33;
        assert!(bad.validate().is_err(), "absurd staleness rejected");
        bad = c.clone();
        bad.fleet_timeout_ms = 0;
        assert!(bad.validate().is_err(), "zero liveness window rejected");
    }

    #[test]
    fn serve_knobs_default_parse_and_validate() {
        let c = ExperimentConfig::preset("paper").unwrap();
        assert_eq!(c.serve_max_batch, 32, "one train-minibatch worth of states");
        assert_eq!(c.serve_flush_us, 500);
        assert_eq!(c.serve_poll_ms, 200);

        let doc = TomlDoc::parse(
            "preset = \"smoke\"\n[serve]\nmax_batch = 64\nflush_us = 1_000\npoll_ms = 50\n",
        )
        .unwrap();
        let mut c = ExperimentConfig::from_toml(&doc).unwrap();
        assert_eq!(c.serve_max_batch, 64);
        assert_eq!(c.serve_flush_us, 1_000);
        assert_eq!(c.serve_poll_ms, 50);

        let args = Args::parse(
            ["--serve-max-batch", "8", "--serve-flush-us", "0", "--serve-poll-ms", "25"]
                .map(String::from),
        )
        .unwrap();
        c.apply_args(&args).unwrap();
        assert_eq!(c.serve_max_batch, 8);
        assert_eq!(c.serve_flush_us, 0, "flush 0 (dispatch immediately) is valid");
        assert_eq!(c.serve_poll_ms, 25);

        let mut bad = c.clone();
        bad.serve_max_batch = 0;
        assert!(bad.validate().is_err(), "zero batch rejected");
        bad.serve_max_batch = 1_000_000;
        assert!(bad.validate().is_err(), "absurd batch rejected");
        bad = c.clone();
        bad.serve_poll_ms = 0;
        assert!(bad.validate().is_err(), "zero poll interval rejected");
    }

    /// `to_cli_args` → `Args::parse` → `apply_args` over a fresh preset
    /// must land on the exact config (Debug repr compares every field).
    /// This is how `fleet` hands the learner's config to spawned sampler
    /// processes, so drift here would surface as fingerprint refusals.
    #[test]
    fn to_cli_args_round_trips_the_config() {
        let mut c = ExperimentConfig::preset("smoke").unwrap();
        c.game = "seeker".into();
        c.mode = ExecMode::Both;
        c.double = true;
        c.seed = 0xDEAD_BEEF;
        c.threads = 3;
        c.envs_per_thread = 2;
        c.learner_threads = 4;
        c.prefetch_batches = 2;
        c.kernel_mode = KernelMode::Fast;
        c.total_steps = 12_000;
        c.minibatch = 16;
        c.replay_capacity = 9_000;
        c.target_update_period = 48;
        c.train_period = 2;
        c.gamma = 0.925;
        c.prepopulate = 123;
        c.lr = 2.5e-4;
        c.eps = EpsSchedule { start: 0.9, end: 0.05, decay_steps: 10_000 };
        c.replay_strategy = ReplayStrategy::Proportional;
        c.per_alpha = 0.55;
        c.per_beta0 = 0.45;
        c.per_beta_anneal = 777;
        c.n_step = 3;
        c.eval_period = 1_000;
        c.eval_episodes = 2;
        c.eval_eps = 0.01;
        c.eval_seed = 99;
        c.fleet_lag = 2;
        c.fleet_timeout_ms = 5_000;
        c.validate().unwrap();

        let args = Args::parse(c.to_cli_args()).unwrap();
        let mut back = ExperimentConfig::preset("paper").unwrap();
        back.apply_args(&args).unwrap();
        // Deliberately not serialized: checkpoint placement and fleet
        // topology (neither moves the trajectory).
        back.ckpt_dir = c.ckpt_dir.clone();
        back.ckpt_period = c.ckpt_period;
        back.fleet_samplers = c.fleet_samplers;
        assert_eq!(format!("{back:?}"), format!("{c:?}"), "to_cli_args round trip drifted");
    }
}
