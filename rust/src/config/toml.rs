//! Minimal TOML-subset parser (the `toml` crate is unavailable offline).
//!
//! Supports what experiment configs need: `[section]` headers, `key = value`
//! with string / integer / float / boolean values, `#` comments, and dotted
//! lookup (`section.key`). Arrays of integers are supported for sweep lists.
//!
//! Example experiment file (see `ExperimentConfig::from_toml` for the full
//! key set):
//!
//! ```toml
//! preset = "speedtest"
//! [run]
//! mode = "both"
//! threads = 4
//! envs_per_thread = 8   # W×B = 32 streams
//! [learner]
//! threads = 4           # shard each minibatch over 4 lanes (bit-identical)
//! prefetch_batches = 1  # double-buffer replay batch assembly
//! ```

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum TomlValue {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    IntArray(Vec<i64>),
}

#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    values: BTreeMap<String, TomlValue>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[').and_then(|s| s.strip_suffix(']')) {
                section = name.trim().to_string();
                if section.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                continue;
            }
            let Some((key, value)) = line.split_once('=') else {
                bail!("line {}: expected `key = value`, got {raw:?}", lineno + 1);
            };
            let key = key.trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let full = if section.is_empty() { key.to_string() } else { format!("{section}.{key}") };
            doc.values.insert(full, parse_value(value.trim(), lineno + 1)?);
        }
        Ok(doc)
    }

    pub fn load(path: &std::path::Path) -> Result<TomlDoc> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Self::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&TomlValue> {
        self.values.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> Result<String> {
        match self.values.get(key) {
            None => Ok(default.to_string()),
            Some(TomlValue::Str(s)) => Ok(s.clone()),
            Some(v) => bail!("{key}: expected string, got {v:?}"),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> Result<usize> {
        match self.values.get(key) {
            None => Ok(default),
            Some(TomlValue::Int(i)) if *i >= 0 => Ok(*i as usize),
            Some(v) => bail!("{key}: expected non-negative integer, got {v:?}"),
        }
    }

    pub fn f64_or(&self, key: &str, default: f64) -> Result<f64> {
        match self.values.get(key) {
            None => Ok(default),
            Some(TomlValue::Float(f)) => Ok(*f),
            Some(TomlValue::Int(i)) => Ok(*i as f64),
            Some(v) => bail!("{key}: expected number, got {v:?}"),
        }
    }

    pub fn bool_or(&self, key: &str, default: bool) -> Result<bool> {
        match self.values.get(key) {
            None => Ok(default),
            Some(TomlValue::Bool(b)) => Ok(*b),
            Some(v) => bail!("{key}: expected bool, got {v:?}"),
        }
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.values.keys().map(|s| s.as_str())
    }
}

fn strip_comment(line: &str) -> &str {
    // A '#' outside quotes starts a comment.
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<TomlValue> {
    if let Some(inner) = s.strip_prefix('"').and_then(|x| x.strip_suffix('"')) {
        return Ok(TomlValue::Str(inner.to_string()));
    }
    if s == "true" {
        return Ok(TomlValue::Bool(true));
    }
    if s == "false" {
        return Ok(TomlValue::Bool(false));
    }
    if let Some(inner) = s.strip_prefix('[').and_then(|x| x.strip_suffix(']')) {
        let items: Result<Vec<i64>> = inner
            .split(',')
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .map(|t| t.parse::<i64>().map_err(|_| anyhow::anyhow!("line {lineno}: bad int {t:?}")))
            .collect();
        return Ok(TomlValue::IntArray(items?));
    }
    if let Ok(i) = s.replace('_', "").parse::<i64>() {
        return Ok(TomlValue::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(TomlValue::Float(f));
    }
    bail!("line {lineno}: cannot parse value {s:?}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let doc = TomlDoc::parse(
            r#"
            # experiment
            name = "paper"
            [dqn]
            target_update_period = 10_000
            lr = 2.5e-4
            double = false
            [sweep]
            threads = [1, 2, 4, 8]
            "#,
        )
        .unwrap();
        assert_eq!(doc.str_or("name", "").unwrap(), "paper");
        assert_eq!(doc.usize_or("dqn.target_update_period", 0).unwrap(), 10_000);
        assert!((doc.f64_or("dqn.lr", 0.0).unwrap() - 2.5e-4).abs() < 1e-12);
        assert!(!doc.bool_or("dqn.double", true).unwrap());
        assert_eq!(doc.get("sweep.threads"),
                   Some(&TomlValue::IntArray(vec![1, 2, 4, 8])));
    }

    #[test]
    fn run_section_carries_the_wxb_knobs() {
        let doc = TomlDoc::parse("[run]\nthreads = 2\nenvs_per_thread = 4\n").unwrap();
        assert_eq!(doc.usize_or("run.threads", 1).unwrap(), 2);
        assert_eq!(doc.usize_or("run.envs_per_thread", 1).unwrap(), 4);
        assert_eq!(doc.usize_or("run.envs_per_thread_missing", 1).unwrap(), 1);
    }

    #[test]
    fn defaults_apply() {
        let doc = TomlDoc::parse("").unwrap();
        assert_eq!(doc.usize_or("missing", 42).unwrap(), 42);
    }

    #[test]
    fn type_mismatch_errors() {
        let doc = TomlDoc::parse("x = \"hi\"").unwrap();
        assert!(doc.usize_or("x", 0).is_err());
    }

    #[test]
    fn comments_and_hash_in_string() {
        let doc = TomlDoc::parse("a = 1 # trailing\nb = \"x#y\"").unwrap();
        assert_eq!(doc.usize_or("a", 0).unwrap(), 1);
        assert_eq!(doc.str_or("b", "").unwrap(), "x#y");
    }

    #[test]
    fn rejects_garbage() {
        assert!(TomlDoc::parse("just words").is_err());
        assert!(TomlDoc::parse("[]").is_err());
        assert!(TomlDoc::parse("k = @@").is_err());
    }
}
