//! Checkpoint/resume subsystem (rust/DESIGN.md §10).
//!
//! A checkpoint is a directory `step_<N>/` containing:
//!
//! * `manifest.json` — self-describing JSON (via `util/json.rs`): format
//!   tag, format version, global step, a free-form `meta` object the
//!   coordinator fills with its config fingerprint, and the section table
//!   (name, per-layer version, offset, length, FNV-1a checksum).
//! * `state.bin` — the concatenated binary sections.
//!
//! Every stateful layer implements [`Snapshot`]: it serializes its fields
//! through the bit-exact [`codec`] and restores them in place. The
//! coordinator composes the layers into one file at a *quiesce point* — a
//! window boundary where no transaction is in flight — so killing the
//! process and resuming lands on the same trajectory to the bit.
//!
//! Durability: the directory is assembled under a dot-prefixed temp name
//! and atomically renamed into place, so a crash mid-write never leaves a
//! checkpoint that parses. Loading verifies the format version, section
//! lengths, and checksums before any layer state is touched; a truncated
//! or mismatched checkpoint fails with a clear error instead of corrupting
//! the machine.

pub mod codec;

use std::collections::BTreeMap;
use std::ops::Range;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::util::json::{obj, Json};

pub use codec::{fnv1a, ByteReader, ByteWriter};

/// Container format version. Bump on any layout change; loaders reject
/// versions they do not understand.
pub const FORMAT_VERSION: u64 = 1;
/// Format tag in the manifest (guards against pointing --resume at some
/// unrelated JSON+binary pair).
pub const FORMAT_TAG: &str = "tempo-dqn-checkpoint";

const MANIFEST_FILE: &str = "manifest.json";
const STATE_FILE: &str = "state.bin";

/// One stateful layer's save/restore hooks.
///
/// `save` appends the layer's fields to the writer; `load` reads them back
/// in the same order and applies them in place. Implementations version
/// their own payload via [`Snapshot::version`] — the container checks it
/// before calling `load`, so a layer never parses a payload written by a
/// different layout of itself.
pub trait Snapshot {
    /// Stable section name (unique per checkpoint).
    fn kind(&self) -> &'static str;

    /// Layer payload version (bump when the field layout changes).
    fn version(&self) -> u32 {
        1
    }

    fn save(&self, w: &mut ByteWriter);

    fn load(&mut self, r: &mut ByteReader<'_>) -> Result<()>;
}

/// Builder for one checkpoint directory.
pub struct CheckpointWriter {
    step: u64,
    meta: Vec<(String, Json)>,
    names: Vec<String>,
    sections: BTreeMap<String, (u32, Vec<u8>)>,
}

impl CheckpointWriter {
    pub fn new(step: u64) -> CheckpointWriter {
        CheckpointWriter { step, meta: Vec::new(), names: Vec::new(), sections: BTreeMap::new() }
    }

    /// Attach a free-form manifest field (config fingerprint, timestamps…).
    pub fn meta(&mut self, key: &str, value: Json) {
        self.meta.push((key.to_string(), value));
    }

    /// Serialize one layer into its own section.
    pub fn add(&mut self, snap: &dyn Snapshot) -> Result<()> {
        let mut w = ByteWriter::new();
        snap.save(&mut w);
        self.add_raw(snap.kind(), snap.version(), w.into_bytes())
    }

    /// Add a pre-serialized section.
    pub fn add_raw(&mut self, name: &str, version: u32, bytes: Vec<u8>) -> Result<()> {
        if self.sections.contains_key(name) {
            bail!("duplicate checkpoint section {name:?}");
        }
        self.names.push(name.to_string());
        self.sections.insert(name.to_string(), (version, bytes));
        Ok(())
    }

    /// Write the checkpoint as `<dir>/step_<N>` atomically: assemble under
    /// a temp name, stream the sections to disk (no second in-memory copy
    /// of the concatenated state — at 1M-frame replay scale that copy
    /// would double a multi-GB footprint), fsync both files, rename into
    /// place, and fsync the parent directory so the rename itself is
    /// durable. Returns the final directory path.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        use std::io::Write;

        let final_dir = dir.join(format!("step_{:012}", self.step));
        let tmp_dir = dir.join(format!(".tmp_step_{:012}", self.step));
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating checkpoint dir {}", dir.display()))?;
        // A leftover temp dir from a crashed writer is dead weight; replace.
        if tmp_dir.exists() {
            std::fs::remove_dir_all(&tmp_dir)?;
        }
        std::fs::create_dir(&tmp_dir)?;

        // Stream sections in insertion order, building the table as we go.
        let state_path = tmp_dir.join(STATE_FILE);
        let mut state = std::fs::File::create(&state_path)
            .with_context(|| format!("creating {}", state_path.display()))?;
        let mut table = Vec::new();
        let mut offset = 0usize;
        for name in &self.names {
            let (version, bytes) = &self.sections[name];
            table.push(obj(vec![
                ("name", Json::Str(name.clone())),
                ("version", Json::Num(*version as f64)),
                ("offset", Json::Num(offset as f64)),
                ("len", Json::Num(bytes.len() as f64)),
                ("fnv1a", Json::Str(format!("{:016x}", fnv1a(bytes)))),
            ]));
            state.write_all(bytes)?;
            offset += bytes.len();
        }
        state.sync_all()?;
        drop(state);

        let manifest = obj(vec![
            ("format", Json::Str(FORMAT_TAG.to_string())),
            ("version", Json::Num(FORMAT_VERSION as f64)),
            ("step", Json::Num(self.step as f64)),
            ("meta", Json::Obj(self.meta.iter().cloned().collect())),
            ("sections", Json::Arr(table)),
        ]);
        let manifest_path = tmp_dir.join(MANIFEST_FILE);
        let mut mf = std::fs::File::create(&manifest_path)?;
        mf.write_all(manifest.to_string().as_bytes())?;
        mf.sync_all()?;
        drop(mf);

        // Replace any previous checkpoint at the same step.
        if final_dir.exists() {
            std::fs::remove_dir_all(&final_dir)?;
        }
        std::fs::rename(&tmp_dir, &final_dir)
            .with_context(|| format!("publishing checkpoint {}", final_dir.display()))?;
        // Make the rename durable. Directory fsync is a Unix-ism; where the
        // platform refuses, the file-level syncs above still hold.
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
        Ok(final_dir)
    }
}

/// A validated, loaded checkpoint.
pub struct CheckpointReader {
    step: u64,
    meta: Json,
    data: Vec<u8>,
    sections: BTreeMap<String, (u32, Range<usize>)>,
    path: PathBuf,
}

impl CheckpointReader {
    /// Open `<dir>` (a `step_<N>` directory): parse the manifest, check the
    /// format tag/version, and verify every section's length and checksum
    /// against `state.bin` before returning.
    pub fn open(dir: &Path) -> Result<CheckpointReader> {
        let manifest_path = dir.join(MANIFEST_FILE);
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading checkpoint manifest {}", manifest_path.display()))?;
        let manifest = Json::parse(&text)
            .map_err(|e| anyhow::anyhow!("checkpoint manifest {}: {e}", manifest_path.display()))?;

        let format = manifest.get("format").and_then(Json::as_str).unwrap_or("");
        if format != FORMAT_TAG {
            bail!(
                "{} is not a tempo-dqn checkpoint (format tag {format:?})",
                dir.display()
            );
        }
        let version = manifest
            .at(&["version"])?
            .as_usize()
            .ok_or_else(|| anyhow::anyhow!("checkpoint manifest: bad version field"))? as u64;
        if version != FORMAT_VERSION {
            bail!(
                "checkpoint {} has format version {version}, this build reads version {FORMAT_VERSION}; \
                 re-create the checkpoint with a matching build",
                dir.display()
            );
        }
        let step = manifest
            .at(&["step"])?
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("checkpoint manifest: bad step field"))? as u64;
        let meta = manifest.get("meta").cloned().unwrap_or(Json::Obj(BTreeMap::new()));

        let data = std::fs::read(dir.join(STATE_FILE))
            .with_context(|| format!("reading checkpoint state {}", dir.join(STATE_FILE).display()))?;

        let mut sections = BTreeMap::new();
        for entry in manifest.at(&["sections"])?.as_arr().unwrap_or(&[]) {
            let name = entry
                .at(&["name"])?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("checkpoint manifest: section without name"))?
                .to_string();
            let ver = entry.at(&["version"])?.as_usize().unwrap_or(0) as u32;
            let off = entry.at(&["offset"])?.as_usize().unwrap_or(usize::MAX);
            let len = entry.at(&["len"])?.as_usize().unwrap_or(usize::MAX);
            let end = off.checked_add(len).filter(|&e| e <= data.len()).ok_or_else(|| {
                anyhow::anyhow!(
                    "checkpoint {}: section {name:?} [{off}..+{len}] exceeds state.bin ({} bytes) — truncated file?",
                    dir.display(),
                    data.len()
                )
            })?;
            let want = entry.at(&["fnv1a"])?.as_str().unwrap_or("").to_string();
            let got = format!("{:016x}", fnv1a(&data[off..end]));
            if want != got {
                bail!(
                    "checkpoint {}: section {name:?} checksum mismatch (manifest {want}, data {got}) — corrupt file",
                    dir.display()
                );
            }
            sections.insert(name, (ver, off..end));
        }
        Ok(CheckpointReader { step, meta, data, sections, path: dir.to_path_buf() })
    }

    pub fn step(&self) -> u64 {
        self.step
    }

    pub fn meta(&self) -> &Json {
        &self.meta
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    pub fn has_section(&self, name: &str) -> bool {
        self.sections.contains_key(name)
    }

    pub fn section_names(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(String::as_str)
    }

    /// Open a raw section for manual decoding (composite sections the
    /// coordinator writes with `add_raw`). The caller drives the reader and
    /// should call [`ByteReader::finish`] when done.
    pub fn read_section(&self, name: &str, expect_version: u32) -> Result<ByteReader<'_>> {
        let (ver, range) = self
            .sections
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint {} has no section {name:?}", self.path.display()))?;
        if *ver != expect_version {
            bail!("checkpoint section {name:?} has version {ver}, this build reads version {expect_version}");
        }
        Ok(ByteReader::new(&self.data[range.clone()]))
    }

    /// Restore one layer from its section. Errors if the section is
    /// missing, its per-layer version differs, or any byte is left over.
    pub fn restore(&self, snap: &mut dyn Snapshot) -> Result<()> {
        let name = snap.kind();
        let (ver, range) = self
            .sections
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("checkpoint {} has no section {name:?}", self.path.display()))?;
        if *ver != snap.version() {
            bail!(
                "checkpoint section {name:?} has version {ver}, this build reads version {}",
                snap.version()
            );
        }
        let mut r = ByteReader::new(&self.data[range.clone()]);
        snap.load(&mut r).with_context(|| format!("restoring checkpoint section {name:?}"))?;
        r.finish().with_context(|| format!("restoring checkpoint section {name:?}"))
    }
}

/// Find the newest `step_<N>` checkpoint under `dir` (None when the
/// directory is absent or holds no complete checkpoint).
pub fn latest_checkpoint(dir: &Path) -> Result<Option<PathBuf>> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(None),
    };
    let mut best: Option<(u64, PathBuf)> = None;
    for entry in entries {
        let entry = entry?;
        let name = entry.file_name();
        let Some(step) = name.to_str().and_then(|n| n.strip_prefix("step_")) else {
            continue;
        };
        let Ok(step) = step.parse::<u64>() else { continue };
        // Only complete checkpoints count (the temp dir never matches the
        // prefix, but a manually truncated dir might).
        if !entry.path().join(MANIFEST_FILE).exists() {
            continue;
        }
        if best.as_ref().map(|(s, _)| step > *s).unwrap_or(true) {
            best = Some((step, entry.path()));
        }
    }
    Ok(best.map(|(_, p)| p))
}

/// Open the newest complete checkpoint under `dir`, fully verified (format
/// tag, version, and every section checksum — [`CheckpointReader::open`]'s
/// contract). `Ok(None)` when the directory is absent or holds none. This is
/// the serving daemon's swap guard: a torn or corrupt checkpoint surfaces
/// here as an error *before* any state moves.
pub fn open_latest(dir: &Path) -> Result<Option<CheckpointReader>> {
    match latest_checkpoint(dir)? {
        Some(path) => CheckpointReader::open(&path).map(Some),
        None => Ok(None),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    struct Toy {
        a: u64,
        v: Vec<f32>,
    }

    impl Snapshot for Toy {
        fn kind(&self) -> &'static str {
            "toy"
        }
        fn save(&self, w: &mut ByteWriter) {
            w.put_u64(self.a);
            w.put_f32_slice(&self.v);
        }
        fn load(&mut self, r: &mut ByteReader<'_>) -> Result<()> {
            self.a = r.u64()?;
            self.v = r.f32_vec()?;
            Ok(())
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("tempo-ckpt-test-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn write_read_round_trip() {
        let dir = tmpdir("roundtrip");
        let toy = Toy { a: 99, v: vec![1.25, -3.5] };
        let mut w = CheckpointWriter::new(4096);
        w.meta("game", Json::Str("pong".into()));
        w.add(&toy).unwrap();
        let path = w.write(&dir).unwrap();
        assert!(path.ends_with("step_000000004096"));

        let r = CheckpointReader::open(&path).unwrap();
        assert_eq!(r.step(), 4096);
        assert_eq!(r.meta().get("game").unwrap().as_str(), Some("pong"));
        let mut back = Toy { a: 0, v: vec![] };
        r.restore(&mut back).unwrap();
        assert_eq!(back.a, 99);
        assert_eq!(back.v, vec![1.25, -3.5]);

        assert_eq!(latest_checkpoint(&dir).unwrap(), Some(path));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn latest_picks_highest_step() {
        let dir = tmpdir("latest");
        for step in [5u64, 100, 20] {
            let mut w = CheckpointWriter::new(step);
            w.add(&Toy { a: step, v: vec![] }).unwrap();
            w.write(&dir).unwrap();
        }
        let latest = latest_checkpoint(&dir).unwrap().unwrap();
        assert!(latest.ends_with("step_000000000100"));
        assert_eq!(latest_checkpoint(Path::new("/no/such/dir")).unwrap(), None);

        // open_latest: same pick, fully verified; None off the end.
        let r = open_latest(&dir).unwrap().unwrap();
        assert_eq!(r.step(), 100);
        assert!(open_latest(Path::new("/no/such/dir")).unwrap().is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_and_truncated_checkpoints_fail_clearly() {
        let dir = tmpdir("corrupt");
        let mut w = CheckpointWriter::new(1);
        w.add(&Toy { a: 7, v: vec![2.0; 8] }).unwrap();
        let path = w.write(&dir).unwrap();

        // Flip one byte of state.bin -> checksum mismatch.
        let state = path.join("state.bin");
        let mut bytes = std::fs::read(&state).unwrap();
        bytes[3] ^= 0xFF;
        std::fs::write(&state, &bytes).unwrap();
        let err = CheckpointReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "{err}");

        // Truncate state.bin -> out-of-range section.
        std::fs::write(&state, &bytes[..4]).unwrap();
        let err = CheckpointReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("truncated"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn version_and_format_mismatch_fail_clearly() {
        let dir = tmpdir("version");
        let mut w = CheckpointWriter::new(2);
        w.add(&Toy { a: 1, v: vec![] }).unwrap();
        let path = w.write(&dir).unwrap();

        let manifest = path.join("manifest.json");
        let text = std::fs::read_to_string(&manifest).unwrap();
        // Container version bump (keys are sorted, so the top-level version
        // is the one that follows "step").
        std::fs::write(&manifest, text.replace("\"step\":2,\"version\":1", "\"step\":2,\"version\":999"))
            .unwrap();
        let err = CheckpointReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("format version 999"), "{err}");

        // Foreign format tag.
        std::fs::write(&manifest, text.replace(FORMAT_TAG, "something-else")).unwrap();
        let err = CheckpointReader::open(&path).unwrap_err().to_string();
        assert!(err.contains("not a tempo-dqn checkpoint"), "{err}");

        // Per-section version mismatch (the version that follows "offset").
        std::fs::write(&manifest, text.replace("\"offset\":0,\"version\":1", "\"offset\":0,\"version\":9"))
            .unwrap();
        let r = CheckpointReader::open(&path).unwrap();
        let err = r.restore(&mut Toy { a: 0, v: vec![] }).unwrap_err().to_string();
        assert!(err.contains("version 9"), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn missing_section_and_duplicates_rejected() {
        let dir = tmpdir("missing");
        let mut w = CheckpointWriter::new(3);
        w.add_raw("other", 1, vec![1, 2, 3]).unwrap();
        assert!(w.add_raw("other", 1, vec![]).is_err(), "duplicate section");
        let path = w.write(&dir).unwrap();
        let r = CheckpointReader::open(&path).unwrap();
        let err = r.restore(&mut Toy { a: 0, v: vec![] }).unwrap_err().to_string();
        assert!(err.contains("no section \"toy\""), "{err}");
        let _ = std::fs::remove_dir_all(&dir);
    }
}
