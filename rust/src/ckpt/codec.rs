//! Binary codec for checkpoint sections.
//!
//! Little-endian, length-prefixed, append-only: every stateful layer
//! serializes its fields in a fixed order through [`ByteWriter`] and reads
//! them back through [`ByteReader`], which errors (instead of panicking or
//! silently wrapping) on truncation. Floats are stored as raw IEEE-754 bits
//! so a save/load round trip is exact to the bit — the foundation of the
//! bit-exact-resume guarantee (rust/DESIGN.md §10).

use anyhow::{bail, Result};

/// Append-only buffer of little-endian fields.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn with_capacity(n: usize) -> ByteWriter {
        ByteWriter { buf: Vec::with_capacity(n) }
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn put_u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn put_bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn put_u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_usize(&mut self, v: usize) {
        self.put_u64(v as u64);
    }

    /// f32 as raw bits (exact round trip).
    pub fn put_f32(&mut self, v: f32) {
        self.put_u32(v.to_bits());
    }

    /// f64 as raw bits (exact round trip).
    pub fn put_f64(&mut self, v: f64) {
        self.put_u64(v.to_bits());
    }

    /// Length-prefixed byte slice.
    pub fn put_bytes(&mut self, v: &[u8]) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v);
    }

    /// Raw bytes, no length prefix (caller wrote its own framing).
    pub fn put_raw(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    /// Length-prefixed UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_bytes(v.as_bytes());
    }

    /// Length-prefixed f32 slice (raw bits).
    pub fn put_f32_slice(&mut self, v: &[f32]) {
        self.put_u64(v.len() as u64);
        self.buf.reserve(v.len() * 4);
        for &x in v {
            self.buf.extend_from_slice(&x.to_bits().to_le_bytes());
        }
    }

    /// Length-prefixed bool slice (one byte each).
    pub fn put_bool_slice(&mut self, v: &[bool]) {
        self.put_u64(v.len() as u64);
        self.buf.extend(v.iter().map(|&b| b as u8));
    }

    /// xoshiro256++ state (4 lanes).
    pub fn put_rng(&mut self, s: [u64; 4]) {
        for lane in s {
            self.put_u64(lane);
        }
    }
}

/// Checked reader over a section's bytes. Every accessor errors on
/// truncation with the byte position, so a cut-off checkpoint file fails
/// loudly instead of corrupting state.
pub struct ByteReader<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(b: &'a [u8]) -> ByteReader<'a> {
        ByteReader { b, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.pos
    }

    /// Error unless every byte was consumed (catches format drift).
    pub fn finish(&self) -> Result<()> {
        if self.pos != self.b.len() {
            bail!(
                "checkpoint section has {} trailing bytes (read {} of {})",
                self.b.len() - self.pos,
                self.pos,
                self.b.len()
            );
        }
        Ok(())
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        // checked_add: a corrupt length prefix near usize::MAX must error
        // like any other truncation, not wrap the bounds check and panic.
        let end = self.pos.checked_add(n).filter(|&e| e <= self.b.len()).ok_or_else(|| {
            anyhow::anyhow!(
                "checkpoint section truncated: need {n} bytes at offset {}, only {} left",
                self.pos,
                self.b.len() - self.pos
            )
        })?;
        let out = &self.b[self.pos..end];
        self.pos = end;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        match self.u8()? {
            0 => Ok(false),
            1 => Ok(true),
            v => bail!("checkpoint section corrupt: bool byte {v} at offset {}", self.pos - 1),
        }
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize> {
        let v = self.u64()?;
        usize::try_from(v).map_err(|_| anyhow::anyhow!("checkpoint value {v} overflows usize"))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    /// Length-prefixed byte slice (borrowed).
    pub fn bytes(&mut self) -> Result<&'a [u8]> {
        let n = self.usize()?;
        self.take(n)
    }

    pub fn str(&mut self) -> Result<&'a str> {
        std::str::from_utf8(self.bytes()?)
            .map_err(|_| anyhow::anyhow!("checkpoint string is not UTF-8"))
    }

    pub fn f32_vec(&mut self) -> Result<Vec<f32>> {
        let n = self.usize()?;
        let raw = self.take(n.checked_mul(4).ok_or_else(|| anyhow::anyhow!("f32 slice overflow"))?)?;
        Ok(raw
            .chunks_exact(4)
            .map(|c| f32::from_bits(u32::from_le_bytes(c.try_into().unwrap())))
            .collect())
    }

    pub fn bool_vec(&mut self) -> Result<Vec<bool>> {
        let n = self.usize()?;
        self.take(n)?.iter().map(|&v| match v {
            0 => Ok(false),
            1 => Ok(true),
            other => bail!("checkpoint section corrupt: bool byte {other}"),
        }).collect()
    }

    pub fn rng(&mut self) -> Result<[u64; 4]> {
        Ok([self.u64()?, self.u64()?, self.u64()?, self.u64()?])
    }
}

/// FNV-1a 64-bit checksum — guards every checkpoint section against
/// silent corruption (not cryptographic; a corrupt-detection hash).
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trip_all_field_types() {
        let mut w = ByteWriter::new();
        w.put_u8(7);
        w.put_bool(true);
        w.put_u32(0xDEAD_BEEF);
        w.put_u64(u64::MAX - 3);
        w.put_f32(-0.0);
        w.put_f64(f64::from_bits(0x7FF8_0000_0000_1234)); // NaN payload survives
        w.put_bytes(b"abc");
        w.put_str("héllo");
        w.put_f32_slice(&[1.5, -2.25, f32::INFINITY]);
        w.put_bool_slice(&[true, false, true]);
        w.put_rng([1, 2, 3, 4]);

        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 3);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.f64().unwrap().to_bits(), 0x7FF8_0000_0000_1234);
        assert_eq!(r.bytes().unwrap(), b"abc");
        assert_eq!(r.str().unwrap(), "héllo");
        let v = r.f32_vec().unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[0], 1.5);
        assert_eq!(v[2], f32::INFINITY);
        assert_eq!(r.bool_vec().unwrap(), vec![true, false, true]);
        assert_eq!(r.rng().unwrap(), [1, 2, 3, 4]);
        r.finish().unwrap();
    }

    #[test]
    fn truncation_errors_instead_of_panicking() {
        let mut w = ByteWriter::new();
        w.put_u64(42);
        w.put_f32_slice(&[1.0, 2.0]);
        let bytes = w.into_bytes();
        for cut in 0..bytes.len() {
            let mut r = ByteReader::new(&bytes[..cut]);
            let ok = r.u64().and_then(|_| r.f32_vec());
            assert!(ok.is_err(), "cut at {cut} must error");
        }
    }

    #[test]
    fn finish_rejects_trailing_bytes() {
        let mut w = ByteWriter::new();
        w.put_u32(1);
        w.put_u32(2);
        let bytes = w.into_bytes();
        let mut r = ByteReader::new(&bytes);
        r.u32().unwrap();
        assert!(r.finish().is_err());
        r.u32().unwrap();
        r.finish().unwrap();
    }

    #[test]
    fn fnv_is_stable_and_sensitive() {
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
        assert_ne!(fnv1a(b"\x00"), fnv1a(b"\x00\x00"));
    }
}
