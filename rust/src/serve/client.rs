//! Thin blocking client for the serving daemon.
//!
//! One request in flight per connection; request ids correlate replies so
//! a desynchronized stream is caught by name rather than silently
//! mispaired. Used by `tempo-dqn serve-probe`, the e2e tests, and the
//! `serve_qps` bench.

use std::time::Duration;

use anyhow::{bail, Result};

use crate::net::{Conn, Endpoint, Msg, ServeStats};

/// One answered `act` request.
#[derive(Clone, Debug)]
pub struct ActReply {
    /// Checkpoint step whose theta produced the rows.
    pub step: u64,
    /// Greedy action per submitted state.
    pub actions: Vec<u8>,
    /// Q-rows, `n * actions` values, in submission order.
    pub q: Vec<f32>,
}

pub struct ServeClient {
    conn: Conn,
    next_id: u64,
}

impl ServeClient {
    /// Connect to a daemon at `addr` (`unix:PATH` / `tcp:HOST:PORT`).
    /// `timeout` bounds both the connect retries and every reply wait, so
    /// it must exceed the daemon's flush deadline.
    pub fn connect(addr: &str, timeout: Duration) -> Result<ServeClient> {
        let ep = Endpoint::parse(addr)?;
        let conn = Conn::connect(&ep, timeout)?;
        conn.set_read_timeout(Some(timeout))?;
        Ok(ServeClient { conn, next_id: 1 })
    }

    /// Submit `n` stacked frames and block for the batched answer.
    pub fn act(&mut self, states: &[u8], n: usize) -> Result<ActReply> {
        let id = self.next_id;
        self.next_id += 1;
        Msg::Act { id, n: n as u64, states: states.to_vec() }.send(&mut self.conn)?;
        loop {
            match Msg::recv(&mut self.conn)? {
                Msg::ActResult { id: rid, step, actions, q } => {
                    if rid != id {
                        bail!("serve reply correlates to request {rid}, expected {id}");
                    }
                    if actions.len() != n {
                        bail!("serve reply carries {} actions for {n} states", actions.len());
                    }
                    return Ok(ActReply { step, actions, q });
                }
                Msg::Heartbeat => continue,
                Msg::Shutdown { reason } => bail!("serve daemon closed the connection: {reason}"),
                other => bail!("serve: expected act-result, daemon sent {}", other.name()),
            }
        }
    }

    /// Fetch the daemon's observability counters.
    pub fn stats(&mut self) -> Result<ServeStats> {
        Msg::Stats.send(&mut self.conn)?;
        loop {
            match Msg::recv(&mut self.conn)? {
                Msg::StatsResult(stats) => return Ok(stats),
                Msg::Heartbeat => continue,
                Msg::Shutdown { reason } => bail!("serve daemon closed the connection: {reason}"),
                other => bail!("serve: expected stats-result, daemon sent {}", other.name()),
            }
        }
    }

    /// Ask the daemon to stop (whole-daemon shutdown, not just this
    /// connection) and consume this client.
    pub fn shutdown(mut self, reason: &str) -> Result<()> {
        Msg::Shutdown { reason: reason.to_string() }.send(&mut self.conn)
    }
}
