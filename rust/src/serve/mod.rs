//! Policy-serving daemon (rust/DESIGN.md §15).
//!
//! `tempo-dqn serve` turns a checkpoint directory into an inference
//! service: it restores the newest `step_<N>/` checkpoint's theta (nothing
//! else — no replay, no optimizer state), listens on the fleet wire
//! protocol, and answers `act` requests with greedy actions plus the raw
//! Q-rows. Three moving parts:
//!
//! * [`collector`] — the micro-batching heart. Concurrent client requests
//!   coalesce into single engine transactions (the same W×B batched shape
//!   the training coordinator uses), bounded by `max_batch` states and a
//!   flush deadline counted from the first queued request.
//! * [`swap`] — a background watcher that polls the checkpoint directory
//!   and hot-swaps theta when a newer checkpoint lands. Verification is
//!   checksums-first: a torn or corrupt checkpoint is skipped with a named
//!   warning and the daemon keeps serving the old parameters.
//! * the server loop here — one handler thread per connection, all feeding
//!   the shared collector.
//!
//! Determinism contract: *which* requests share a batch is wall-clock
//! (deliberately not deterministic); the *rows* are — the native engine's
//! forward pass is per-sample, so a batched reply is bit-identical to a
//! single-sample `QNet::infer` under the same theta. The swap lock makes
//! (theta, step) one atomic pair: every reply's Q-row was computed under
//! exactly the checkpoint step it reports.

pub mod client;
pub mod collector;
pub mod swap;

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Context, Result};

use crate::config::ExperimentConfig;
use crate::net::{Conn, Endpoint, Listener, Msg, ServeStats};
use crate::runtime::{Device, Head, Manifest, QNet, QNetTheta};

pub use client::{ActReply, ServeClient};
pub use collector::Collector;

/// Serving knobs (`[serve]` in config TOML, `--serve-*` on the CLI).
#[derive(Clone, Copy, Debug)]
pub struct ServeOpts {
    /// Max states coalesced into one device transaction.
    pub max_batch: usize,
    /// How long the first request of a batch waits for co-riders.
    pub flush: Duration,
    /// Checkpoint-watcher poll interval.
    pub poll: Duration,
}

impl Default for ServeOpts {
    fn default() -> Self {
        ServeOpts {
            max_batch: 32,
            flush: Duration::from_micros(500),
            poll: Duration::from_millis(200),
        }
    }
}

impl ServeOpts {
    pub fn from_config(cfg: &ExperimentConfig) -> ServeOpts {
        ServeOpts {
            max_batch: cfg.serve_max_batch,
            flush: Duration::from_micros(cfg.serve_flush_us),
            poll: Duration::from_millis(cfg.serve_poll_ms),
        }
    }
}

/// State shared by the collector, the swapper, and every connection
/// handler.
pub struct ServeShared {
    pub(crate) qnet: QNet,
    /// Guards the (theta, step) pair: the swapper holds it across
    /// `set_theta` + step store, the collector across step load + infer —
    /// so a reply can never pair one checkpoint's parameters with
    /// another's step.
    pub(crate) swap_lock: Mutex<()>,
    pub(crate) step: AtomicU64,
    pub(crate) swaps: AtomicU64,
    pub(crate) swap_skips: AtomicU64,
    pub(crate) metrics: collector::Metrics,
    started: Instant,
}

impl ServeShared {
    /// Snapshot the daemon's observability counters.
    pub fn stats(&self) -> ServeStats {
        let (batch_hist, lat_us) = self.metrics.snapshot();
        ServeStats {
            uptime_ms: self.started.elapsed().as_millis() as u64,
            step: self.step.load(Ordering::SeqCst),
            swaps: self.swaps.load(Ordering::Relaxed),
            swap_skips: self.swap_skips.load(Ordering::Relaxed),
            requests: self.metrics.requests.load(Ordering::Relaxed),
            states: self.metrics.states.load(Ordering::Relaxed),
            batch_hist,
            lat_us,
        }
    }
}

/// Daemon-wide stop signal. `trigger` also pokes the listener with a
/// throwaway connection so a blocked `accept` observes the flag.
struct StopToken {
    flag: AtomicBool,
    addr: String,
}

impl StopToken {
    fn is_set(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }

    fn trigger(&self) {
        if self.flag.swap(true, Ordering::SeqCst) {
            return;
        }
        if let Ok(ep) = Endpoint::parse(&self.addr) {
            let _ = Conn::connect(&ep, Duration::from_millis(250));
        }
    }
}

/// The serving daemon. [`Server::start`] restores the newest checkpoint,
/// binds the endpoint, and spawns the collector, the swap watcher, and the
/// accept loop; the returned handle owns their lifetimes.
pub struct Server;

impl Server {
    pub fn start(
        ckpt_dir: &Path,
        artifact_dir: &Path,
        bind: &str,
        opts: ServeOpts,
    ) -> Result<ServerHandle> {
        // Checksums-first restore of the serving parameters: open_latest
        // verifies the whole checkpoint before a byte of state moves.
        let reader = crate::ckpt::open_latest(ckpt_dir)
            .with_context(|| format!("scanning checkpoint dir {}", ckpt_dir.display()))?
            .ok_or_else(|| {
                anyhow!(
                    "no checkpoint under {} — train with --ckpt-dir first",
                    ckpt_dir.display()
                )
            })?;
        let mut r = reader.read_section("qnet", 1)?;
        let t = QNetTheta::decode(&mut r)
            .with_context(|| format!("reading qnet section of {}", reader.path().display()))?;

        // The checkpoint names its own network config *and* head (the
        // `{config}+{head}` tag `QNetSnapshot` writes); the daemon needs no
        // --net flag and refuses a head it was not built for by name.
        // Single compute lane: serving is latency-bound, not
        // minibatch-bound.
        let manifest = Manifest::load_or_builtin(artifact_dir)?;
        let device = Arc::new(Device::cpu()?);
        let (base, head) = Head::split(&t.name)
            .with_context(|| format!("parsing checkpoint network name {:?}", t.name))?;
        let qnet = QNet::load_with_head(device, &manifest, &base, t.double, 32, head)
            .with_context(|| format!("loading network {:?} for serving", t.name))?;
        qnet.set_theta(&t.theta)?;

        let shared = Arc::new(ServeShared {
            qnet,
            swap_lock: Mutex::new(()),
            step: AtomicU64::new(reader.step()),
            swaps: AtomicU64::new(0),
            swap_skips: AtomicU64::new(0),
            metrics: collector::Metrics::new(),
            started: Instant::now(),
        });

        let listener = Endpoint::parse(bind)?.bind()?;
        let addr = listener.local_addr_string()?;
        let stop = Arc::new(StopToken { flag: AtomicBool::new(false), addr: addr.clone() });

        let (collector, worker) = Collector::spawn(shared.clone(), opts.max_batch, opts.flush);
        let watcher = swap::spawn_watcher(
            shared.clone(),
            ckpt_dir.to_path_buf(),
            opts.poll,
            stop.clone(),
        );
        let accept = {
            let shared = shared.clone();
            let collector = collector.clone();
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("serve-accept".into())
                .spawn(move || accept_loop(listener, shared, collector, stop))
                .expect("spawn serve-accept thread")
        };

        Ok(ServerHandle {
            shared,
            stop,
            addr,
            collector,
            threads: vec![accept, watcher, worker],
        })
    }
}

pub struct ServerHandle {
    shared: Arc<ServeShared>,
    stop: Arc<StopToken>,
    addr: String,
    collector: Collector,
    threads: Vec<JoinHandle<()>>,
}

impl ServerHandle {
    /// The bound address in `Endpoint::parse` form (`unix:…` / `tcp:…`).
    pub fn addr(&self) -> &str {
        &self.addr
    }

    /// Local (in-process) stats snapshot — same payload a `stats` request
    /// returns over the wire.
    pub fn stats(&self) -> ServeStats {
        self.shared.stats()
    }

    /// Stop the daemon: unblock the accept loop, flush in-flight requests,
    /// and join every owned thread.
    pub fn stop(self) -> Result<()> {
        self.stop.trigger();
        self.join()
    }

    /// Block until a client sends `shutdown` (the CLI daemon's main loop).
    pub fn wait(self) -> Result<()> {
        self.join()
    }

    fn join(mut self) -> Result<()> {
        // Accept loop first (it exits once the stop token is triggered —
        // by `stop()` above or by a client's shutdown message), then the
        // collector drains what is queued, then the watcher notices.
        let accept = self.threads.remove(0);
        accept
            .join()
            .map_err(|_| anyhow!("serve accept loop panicked"))?;
        self.stop.trigger();
        self.collector.stop();
        for t in self.threads {
            t.join().map_err(|_| anyhow!("serve worker thread panicked"))?;
        }
        Ok(())
    }
}

fn accept_loop(
    listener: Listener,
    shared: Arc<ServeShared>,
    collector: Collector,
    stop: Arc<StopToken>,
) {
    loop {
        match listener.accept() {
            Ok(conn) => {
                if stop.is_set() {
                    return;
                }
                let shared = shared.clone();
                let collector = collector.clone();
                let stop = stop.clone();
                // Handlers are detached: each lives exactly as long as its
                // connection and owns nothing the daemon must reclaim.
                let _ = std::thread::Builder::new()
                    .name("serve-conn".into())
                    .spawn(move || handle_conn(conn, shared, collector, stop));
            }
            Err(e) => {
                if stop.is_set() {
                    return;
                }
                eprintln!("serve: accept failed: {e:#}");
            }
        }
    }
}

/// One connection's message loop. A wire fault (corrupt frame, bad
/// checksum, disconnect) drops *this* connection only — the daemon and
/// every other client keep running.
fn handle_conn(
    mut conn: Conn,
    shared: Arc<ServeShared>,
    collector: Collector,
    stop: Arc<StopToken>,
) {
    loop {
        let msg = match Msg::recv(&mut conn) {
            Ok(m) => m,
            Err(_) => return,
        };
        match msg {
            Msg::Act { id, n, states } => match act(&shared, &collector, n as usize, states) {
                Ok(reply) => {
                    let out = Msg::ActResult {
                        id,
                        step: reply.step,
                        actions: reply.actions,
                        q: reply.q,
                    };
                    if out.send(&mut conn).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    // The protocol has no error kind; a refused request is
                    // answered with a reasoned shutdown of this connection.
                    let _ = Msg::Shutdown { reason: format!("act refused: {e:#}") }.send(&mut conn);
                    return;
                }
            },
            Msg::Stats => {
                if Msg::StatsResult(shared.stats()).send(&mut conn).is_err() {
                    return;
                }
            }
            Msg::Heartbeat => {}
            Msg::Shutdown { reason } => {
                // Client-initiated daemon stop (ops / tests / CI smoke).
                println!("serve: shutdown requested: {reason}");
                stop.trigger();
                return;
            }
            other => {
                let _ = Msg::Shutdown {
                    reason: format!("unexpected {} message on a serve connection", other.name()),
                }
                .send(&mut conn);
                return;
            }
        }
    }
}

fn act(
    shared: &ServeShared,
    collector: &Collector,
    n: usize,
    states: Vec<u8>,
) -> Result<collector::Reply> {
    let [h, w, c] = shared.qnet.spec().frame;
    let frame = h * w * c;
    if n == 0 {
        anyhow::bail!("act request carries zero states");
    }
    if states.len() != n * frame {
        anyhow::bail!(
            "act request carries {} bytes for {n} states; this network takes {frame} bytes each",
            states.len()
        );
    }
    let rx = collector.submit(states, n);
    rx.recv()
        .map_err(|_| anyhow!("serve collector stopped before replying"))?
}
