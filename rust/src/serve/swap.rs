//! Checkpoint watcher: polls the serving directory and hot-swaps theta.
//!
//! Safety order is verify-then-swap: `CheckpointReader::open` checks the
//! format tag, version, and every section checksum *before* any daemon
//! state moves, so a torn or corrupt checkpoint surfaces as a named
//! warning (and a `swap_skips` tick) while the old parameters keep
//! serving. A failed checkpoint is warned about once and then left alone
//! — no log spam at poll frequency — but the guard keys on the directory's
//! *content stamp* (newest mtime + total size), not the path alone, so a
//! checkpoint repaired in place is re-probed on the next poll even when no
//! newer step ever lands.
//!
//! Swaps only move forward: a checkpoint whose step is <= the loaded step
//! is stale and ignored.

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, SystemTime};

use anyhow::{bail, Result};

use crate::ckpt::CheckpointReader;
use crate::runtime::QNetTheta;

use super::{ServeShared, StopToken};

pub(crate) fn spawn_watcher(
    shared: Arc<ServeShared>,
    dir: PathBuf,
    poll: Duration,
    stop: Arc<StopToken>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("serve-swap".into())
        .spawn(move || {
            let mut failed: Option<FailedProbe> = None;
            while !stop.is_set() {
                poll_once(&shared, &dir, &mut failed);
                // Sleep in slices so stop stays responsive under long
                // poll intervals.
                let mut slept = Duration::ZERO;
                while slept < poll && !stop.is_set() {
                    let slice = (poll - slept).min(Duration::from_millis(20));
                    std::thread::sleep(slice);
                    slept += slice;
                }
            }
        })
        .expect("spawn serve-swap thread")
}

/// Warn-once record for a checkpoint that failed to verify. The stamp is
/// the directory's content fingerprint at probe time; a later poll with a
/// different stamp means the files changed underneath the same path
/// (repair-in-place, finished rewrite), so the checkpoint is probed again.
struct FailedProbe {
    path: PathBuf,
    stamp: Option<(SystemTime, u64)>,
}

/// Content stamp of a checkpoint directory: (newest mtime, total byte
/// size) across its immediate entries. Cheap enough for poll frequency,
/// and any repair — even one that keeps every file the same length —
/// advances an mtime. `None` (scan race, permission blip) is treated as
/// "unknown", which never matches and therefore re-probes.
fn dir_stamp(path: &Path) -> Option<(SystemTime, u64)> {
    let mut newest = SystemTime::UNIX_EPOCH;
    let mut total: u64 = 0;
    for entry in std::fs::read_dir(path).ok()? {
        let meta = entry.ok()?.metadata().ok()?;
        if let Ok(m) = meta.modified() {
            if m > newest {
                newest = m;
            }
        }
        total = total.wrapping_add(meta.len());
    }
    Some((newest, total))
}

fn poll_once(shared: &ServeShared, dir: &Path, failed: &mut Option<FailedProbe>) {
    let path = match crate::ckpt::latest_checkpoint(dir) {
        Ok(Some(p)) => p,
        Ok(None) => return,
        Err(e) => {
            eprintln!("serve: checkpoint scan of {} failed: {e:#}", dir.display());
            return;
        }
    };
    if let Some(f) = failed {
        // Already warned about this checkpoint — but only wait it out
        // while its bytes are unchanged. A differing (or unknown) stamp
        // means someone rewrote the files in place; probe again.
        let unchanged =
            f.path == path && f.stamp.is_some() && f.stamp == dir_stamp(&path);
        if unchanged {
            return;
        }
    }
    // Stamp BEFORE probing: a repair racing the probe itself then shows
    // up as a changed stamp on the next poll instead of being captured
    // post-write and mistaken for "unchanged".
    let stamp = dir_stamp(&path);
    match try_swap(shared, &path) {
        Ok(Swapped::Fresh(step)) => {
            *failed = None;
            println!("serve: hot-swapped to step {step} ({})", path.display());
        }
        Ok(Swapped::Stale) => {}
        Err(e) => {
            shared.swap_skips.fetch_add(1, Ordering::Relaxed);
            *failed = Some(FailedProbe { path: path.clone(), stamp });
            eprintln!(
                "serve: skipping checkpoint {} — still serving step {}: {e:#}",
                path.display(),
                shared.step.load(Ordering::SeqCst)
            );
        }
    }
}

enum Swapped {
    Fresh(u64),
    Stale,
}

/// Verify `path` in full, then (if it is newer) install its theta and step
/// as one atomic pair under the swap lock.
fn try_swap(shared: &ServeShared, path: &Path) -> Result<Swapped> {
    let reader = CheckpointReader::open(path)?;
    if reader.step() <= shared.step.load(Ordering::SeqCst) {
        return Ok(Swapped::Stale);
    }
    let mut r = reader.read_section("qnet", 1)?;
    let t = QNetTheta::decode(&mut r)?;
    let spec = shared.qnet.spec();
    let want = spec.runtime_name();
    if t.name != want {
        bail!(
            "checkpoint holds network {:?} (config+head), this daemon serves {:?}",
            t.name,
            want
        );
    }
    if t.param_count != spec.param_count {
        bail!(
            "checkpoint carries {} parameters, network {:?} takes {}",
            t.param_count,
            want,
            spec.param_count
        );
    }

    {
        let _pair = shared.swap_lock.lock().unwrap();
        shared.qnet.set_theta(&t.theta)?;
        shared.step.store(reader.step(), Ordering::SeqCst);
    }
    shared.swaps.fetch_add(1, Ordering::Relaxed);
    Ok(Swapped::Fresh(reader.step()))
}
