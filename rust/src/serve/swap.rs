//! Checkpoint watcher: polls the serving directory and hot-swaps theta.
//!
//! Safety order is verify-then-swap: `CheckpointReader::open` checks the
//! format tag, version, and every section checksum *before* any daemon
//! state moves, so a torn or corrupt checkpoint surfaces as a named
//! warning (and a `swap_skips` tick) while the old parameters keep
//! serving. A failed path is warned about once and then left alone until
//! a newer checkpoint supersedes it — no log spam at poll frequency.
//!
//! Swaps only move forward: a checkpoint whose step is <= the loaded step
//! is stale and ignored.

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{bail, Result};

use crate::ckpt::CheckpointReader;
use crate::runtime::QNetTheta;

use super::{ServeShared, StopToken};

pub(crate) fn spawn_watcher(
    shared: Arc<ServeShared>,
    dir: PathBuf,
    poll: Duration,
    stop: Arc<StopToken>,
) -> JoinHandle<()> {
    std::thread::Builder::new()
        .name("serve-swap".into())
        .spawn(move || {
            let mut failed: Option<PathBuf> = None;
            while !stop.is_set() {
                poll_once(&shared, &dir, &mut failed);
                // Sleep in slices so stop stays responsive under long
                // poll intervals.
                let mut slept = Duration::ZERO;
                while slept < poll && !stop.is_set() {
                    let slice = (poll - slept).min(Duration::from_millis(20));
                    std::thread::sleep(slice);
                    slept += slice;
                }
            }
        })
        .expect("spawn serve-swap thread")
}

fn poll_once(shared: &ServeShared, dir: &Path, failed: &mut Option<PathBuf>) {
    let path = match crate::ckpt::latest_checkpoint(dir) {
        Ok(Some(p)) => p,
        Ok(None) => return,
        Err(e) => {
            eprintln!("serve: checkpoint scan of {} failed: {e:#}", dir.display());
            return;
        }
    };
    if failed.as_deref() == Some(path.as_path()) {
        // Already warned about this exact checkpoint; wait it out.
        return;
    }
    match try_swap(shared, &path) {
        Ok(Swapped::Fresh(step)) => {
            *failed = None;
            println!("serve: hot-swapped to step {step} ({})", path.display());
        }
        Ok(Swapped::Stale) => {}
        Err(e) => {
            shared.swap_skips.fetch_add(1, Ordering::Relaxed);
            *failed = Some(path.clone());
            eprintln!(
                "serve: skipping checkpoint {} — still serving step {}: {e:#}",
                path.display(),
                shared.step.load(Ordering::SeqCst)
            );
        }
    }
}

enum Swapped {
    Fresh(u64),
    Stale,
}

/// Verify `path` in full, then (if it is newer) install its theta and step
/// as one atomic pair under the swap lock.
fn try_swap(shared: &ServeShared, path: &Path) -> Result<Swapped> {
    let reader = CheckpointReader::open(path)?;
    if reader.step() <= shared.step.load(Ordering::SeqCst) {
        return Ok(Swapped::Stale);
    }
    let mut r = reader.read_section("qnet", 1)?;
    let t = QNetTheta::decode(&mut r)?;
    let spec = shared.qnet.spec();
    if t.name != spec.name {
        bail!(
            "checkpoint holds network {:?}, this daemon serves {:?}",
            t.name,
            spec.name
        );
    }
    if t.param_count != spec.param_count {
        bail!(
            "checkpoint carries {} parameters, network {:?} takes {}",
            t.param_count,
            spec.name,
            spec.param_count
        );
    }

    {
        let _pair = shared.swap_lock.lock().unwrap();
        shared.qnet.set_theta(&t.theta)?;
        shared.step.store(reader.step(), Ordering::SeqCst);
    }
    shared.swaps.fetch_add(1, Ordering::Relaxed);
    Ok(Swapped::Fresh(reader.step()))
}
