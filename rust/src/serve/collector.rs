//! Micro-batching collector: coalesces concurrent `act` requests into
//! single engine transactions.
//!
//! Connection handlers enqueue [`Pending`] entries; one worker thread
//! drains the queue into a batch bounded two ways — at most `max_batch`
//! states, and at most `flush` of waiting counted from the *first* queued
//! request (so a lone request under light load pays one flush deadline,
//! never more). The whole batch runs as one `QNet::infer` under the swap
//! lock; rows are then split back per request.
//!
//! Per-sample forwards make this free of accuracy trade-offs: a row in a
//! 32-wide batch is bit-identical to the same state inferred alone
//! (`runtime/native.rs` documents the invariance; `tests/serve.rs` pins
//! it end-to-end).

use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::agent::argmax;
use crate::runtime::Policy;

use super::ServeShared;

/// One batched-inference answer, pre-split for a single request.
#[derive(Clone, Debug)]
pub struct Reply {
    /// Checkpoint step whose theta produced these rows.
    pub step: u64,
    /// Greedy action per state (argmax of the matching Q-row).
    pub actions: Vec<u8>,
    /// Q-rows, `n * actions` values, request order.
    pub q: Vec<f32>,
}

struct Pending {
    states: Vec<u8>,
    n: usize,
    enqueued: Instant,
    reply: mpsc::Sender<Result<Reply>>,
}

/// Latency ring capacity: enough for percentile stability, bounded so a
/// long-lived daemon never grows.
const LAT_RING: usize = 4096;

/// Observability counters owned by the collector, snapshotted by `stats`.
pub struct Metrics {
    pub requests: AtomicU64,
    pub states: AtomicU64,
    /// batch width -> number of flushes at that width.
    hist: Mutex<BTreeMap<u64, u64>>,
    /// Ring of recent per-request latencies (enqueue -> reply), in µs.
    lats: Mutex<LatRing>,
}

struct LatRing {
    buf: Vec<u64>,
    next: usize,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics {
            requests: AtomicU64::new(0),
            states: AtomicU64::new(0),
            hist: Mutex::new(BTreeMap::new()),
            lats: Mutex::new(LatRing { buf: Vec::new(), next: 0 }),
        }
    }

    fn record_flush(&self, width: u64) {
        *self.hist.lock().unwrap().entry(width).or_insert(0) += 1;
    }

    fn record_request(&self, n: u64, latency: Duration) {
        self.requests.fetch_add(1, Ordering::Relaxed);
        self.states.fetch_add(n, Ordering::Relaxed);
        let us = latency.as_micros().min(u64::MAX as u128) as u64;
        let mut ring = self.lats.lock().unwrap();
        if ring.buf.len() < LAT_RING {
            ring.buf.push(us);
        } else {
            let slot = ring.next;
            ring.buf[slot] = us;
        }
        ring.next = (ring.next + 1) % LAT_RING;
    }

    /// (batch histogram ascending by width, [p50, p90, p99, max] µs).
    pub fn snapshot(&self) -> (Vec<(u64, u64)>, [u64; 4]) {
        let hist = self
            .hist
            .lock()
            .unwrap()
            .iter()
            .map(|(&w, &c)| (w, c))
            .collect();
        let mut lats = self.lats.lock().unwrap().buf.clone();
        let lat_us = if lats.is_empty() {
            [0; 4]
        } else {
            lats.sort_unstable();
            let pick = |p: f64| lats[((lats.len() - 1) as f64 * p) as usize];
            [pick(0.50), pick(0.90), pick(0.99), *lats.last().unwrap()]
        };
        (hist, lat_us)
    }
}

struct Inner {
    shared: Arc<ServeShared>,
    queue: Mutex<VecDeque<Pending>>,
    cv: Condvar,
    stop: AtomicBool,
    max_batch: usize,
    flush: Duration,
}

/// Cloneable handle to the batching queue; one worker thread serves all
/// clones.
#[derive(Clone)]
pub struct Collector {
    inner: Arc<Inner>,
}

impl Collector {
    pub fn spawn(
        shared: Arc<ServeShared>,
        max_batch: usize,
        flush: Duration,
    ) -> (Collector, JoinHandle<()>) {
        let inner = Arc::new(Inner {
            shared,
            queue: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            stop: AtomicBool::new(false),
            max_batch: max_batch.max(1),
            flush,
        });
        let worker = {
            let inner = inner.clone();
            std::thread::Builder::new()
                .name("serve-collect".into())
                .spawn(move || worker_loop(&inner))
                .expect("spawn serve-collect thread")
        };
        (Collector { inner }, worker)
    }

    /// Enqueue `n` states for the next batch; the receiver yields exactly
    /// one `Reply` (or the named error that refused the whole batch).
    pub fn submit(&self, states: Vec<u8>, n: usize) -> mpsc::Receiver<Result<Reply>> {
        let (tx, rx) = mpsc::channel();
        if self.inner.stop.load(Ordering::SeqCst) {
            let _ = tx.send(Err(anyhow!("serve collector is stopped")));
            return rx;
        }
        let pending = Pending { states, n, enqueued: Instant::now(), reply: tx };
        self.inner.queue.lock().unwrap().push_back(pending);
        self.inner.cv.notify_one();
        rx
    }

    /// Stop the worker after it drains everything already queued.
    pub fn stop(&self) {
        self.inner.stop.store(true, Ordering::SeqCst);
        self.inner.cv.notify_all();
    }
}

fn worker_loop(inner: &Inner) {
    loop {
        let mut q = inner.queue.lock().unwrap();
        // Sleep until work arrives. Stop only returns once the queue is
        // empty: in-flight requests always complete. The idle wait is
        // untimed — `submit` and `stop` both notify the condvar, so there
        // is nothing to poll for and shutdown latency is one wakeup, not a
        // timeout tick.
        while q.is_empty() {
            if inner.stop.load(Ordering::SeqCst) {
                return;
            }
            q = inner.cv.wait(q).unwrap();
        }

        // Batch window: wait for co-riders until the first request's flush
        // deadline or the state budget fills, whichever comes first.
        let deadline = q.front().unwrap().enqueued + inner.flush;
        loop {
            let total: usize = q.iter().map(|p| p.n).sum();
            if total >= inner.max_batch || inner.stop.load(Ordering::SeqCst) {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            let (guard, _) = inner.cv.wait_timeout(q, deadline - now).unwrap();
            q = guard;
        }

        // Drain whole requests up to max_batch; a single oversize request
        // still goes through alone (QNet::infer pads past loaded batch
        // sizes in chunks, so correctness is unaffected).
        let mut batch: Vec<Pending> = Vec::new();
        let mut total = 0usize;
        while let Some(p) = q.front() {
            if !batch.is_empty() && total + p.n > inner.max_batch {
                break;
            }
            total += p.n;
            batch.push(q.pop_front().unwrap());
        }
        drop(q);
        flush_batch(inner, batch, total);
    }
}

fn flush_batch(inner: &Inner, batch: Vec<Pending>, total: usize) {
    let shared = &inner.shared;
    let mut states = Vec::with_capacity(batch.iter().map(|p| p.states.len()).sum());
    for p in &batch {
        states.extend_from_slice(&p.states);
    }

    // Atomic (theta, step) pair: the step we report is the checkpoint the
    // forward pass actually ran under (see ServeShared::swap_lock).
    let outcome = {
        let _pair = shared.swap_lock.lock().unwrap();
        let step = shared.step.load(Ordering::SeqCst);
        shared
            .qnet
            .infer(Policy::Theta, &states, total)
            .map(|q| (step, q))
    };

    match outcome {
        Ok((step, q)) => {
            let actions_per = shared.qnet.spec().actions;
            let done = Instant::now();
            let mut row = 0usize;
            for p in batch {
                let rows = q[row * actions_per..(row + p.n) * actions_per].to_vec();
                row += p.n;
                let acts: Vec<u8> = rows
                    .chunks(actions_per)
                    .map(|r| argmax(r) as u8)
                    .collect();
                shared
                    .metrics
                    .record_request(p.n as u64, done.duration_since(p.enqueued));
                let _ = p.reply.send(Ok(Reply { step, actions: acts, q: rows }));
            }
            shared.metrics.record_flush(total as u64);
        }
        Err(e) => {
            // anyhow::Error is not Clone; every rider gets the same text.
            let msg = format!("batched inference failed: {e:#}");
            for p in batch {
                let _ = p.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}
