//! Multi-game campaign runner (`run-suite` subcommand).
//!
//! A campaign is a TOML-declared list of (game, seed, config-override)
//! experiment *legs* executed back-to-back — the Atari-style suite of
//! Stooke & Abbeel's many-game evaluations, made operable: every leg
//! checkpoints into its own directory (`<ckpt_dir>/<leg id>/`), so killing
//! the process at any point loses at most one checkpoint period, and
//! re-running the same campaign resumes every unfinished leg bit-exactly
//! (rust/DESIGN.md §10) and skips completed ones.
//!
//! Two execution orders:
//! * `sequential` — run each leg to completion before the next.
//! * `round_robin` — advance each unfinished leg by `slice` steps per
//!   turn, cycling until all are done. Legs are swapped through their
//!   checkpoints, so only one machine is in memory at a time.
//!
//! File format (parsed by the in-tree TOML subset, `config/toml.rs`):
//!
//! ```toml
//! [campaign]
//! name = "atari-suite"
//! ckpt_dir = "campaign-ckpts"
//! order = "round_robin"        # or "sequential" (default)
//! slice = 50_000               # steps per round-robin turn
//! games = "pong,breakout"      # shorthand: one leg per game, or use [leg.*]
//!
//! # Base experiment config: same keys as a train --config file.
//! preset = "paper"
//! [run]
//! mode = "both"
//! threads = 4
//!
//! # Explicit legs override the base; executed in section-name order.
//! [leg.00_pong]
//! game = "pong"
//! seed = 1
//! steps = 200_000
//! [leg.01_breakout]
//! game = "breakout"
//! seed = 2
//! fleet_samplers = 2           # run this leg's samplers as a local fleet
//! fleet_lag = 0                # 0 = replicated (bit-identical digest)
//! ```
//!
//! Legs with `fleet_samplers >= 1` (set per leg or via the base `[fleet]`
//! section) execute through the distributed sampler fleet
//! (rust/DESIGN.md §14): the runner spawns that many local
//! `fleet-sampler` worker processes of this very binary (override with
//! `campaign.sampler_bin`) against a private unix socket and hosts the
//! learner in-process. Replicated legs (`fleet_lag = 0`) publish the same
//! `state_digest` the single-process run would; round-robin slicing works
//! unchanged — each turn detaches the fleet at a window barrier and the
//! next turn re-handshakes from the checkpoint.

use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::ckpt::latest_checkpoint;
use crate::config::toml::TomlDoc;
use crate::config::{ExecMode, ExperimentConfig};
use crate::coordinator::{spawn_local_samplers, Coordinator, FleetOpts};
use crate::util::json::{obj, Json};

/// Leg execution order.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Order {
    Sequential,
    RoundRobin,
}

impl Order {
    pub fn parse(s: &str) -> Result<Order> {
        Ok(match s {
            "sequential" => Order::Sequential,
            "round_robin" | "round-robin" => Order::RoundRobin,
            other => bail!("unknown campaign order {other:?} (sequential|round_robin)"),
        })
    }
}

/// One experiment of the campaign.
#[derive(Clone, Debug)]
pub struct CampaignLeg {
    /// Stable id: the `[leg.<id>]` section name (or the game name for the
    /// `games = "..."` shorthand). Doubles as the checkpoint subdirectory.
    pub id: String,
    pub cfg: ExperimentConfig,
}

/// A parsed campaign.
pub struct Campaign {
    pub name: String,
    pub ckpt_root: PathBuf,
    pub order: Order,
    /// Steps each round-robin turn advances a leg by.
    pub slice: u64,
    /// Binary to spawn for fleet legs' sampler workers (default: this
    /// very executable via `std::env::current_exe`).
    pub sampler_bin: Option<PathBuf>,
    pub legs: Vec<CampaignLeg>,
}

/// Completion report of one leg.
#[derive(Clone, Debug)]
pub struct LegReport {
    pub id: String,
    pub game: String,
    pub steps: u64,
    pub episodes: u64,
    pub trains: u64,
    pub recent_mean_return: f64,
    pub state_digest: u64,
}

impl Campaign {
    pub fn load(path: &Path) -> Result<Campaign> {
        let doc = TomlDoc::load(path)?;
        Self::from_toml(&doc)
    }

    pub fn from_toml(doc: &TomlDoc) -> Result<Campaign> {
        let base = ExperimentConfig::from_toml(doc)
            .context("campaign base experiment config")?;
        let name = doc.str_or("campaign.name", "campaign")?;
        let ckpt_root = PathBuf::from(doc.str_or("campaign.ckpt_dir", "campaign-ckpts")?);
        let order = Order::parse(&doc.str_or("campaign.order", "sequential")?)?;
        let slice = doc.usize_or("campaign.slice", 50_000)? as u64;
        if slice == 0 {
            bail!("campaign.slice must be >= 1 step");
        }
        let sampler_bin = {
            let s = doc.str_or("campaign.sampler_bin", "")?;
            (!s.is_empty()).then(|| PathBuf::from(s))
        };

        // Explicit [leg.<id>] sections, in section-name order (the TOML
        // subset stores keys sorted, so ids like 00_pong order the suite).
        let mut leg_ids: Vec<String> = Vec::new();
        for key in doc.keys() {
            let Some(rest) = key.strip_prefix("leg.") else { continue };
            let Some((id, _)) = rest.split_once('.') else { continue };
            // Keys are sorted, so a new id differs from the last one seen.
            if leg_ids.last().map(|l| l.as_str()) != Some(id) {
                leg_ids.push(id.to_string());
            }
        }

        let mut legs = Vec::new();
        if leg_ids.is_empty() {
            // Shorthand: one leg per game, base config + per-game seed.
            let games = doc.str_or("campaign.games", "")?;
            if games.is_empty() {
                bail!("campaign declares no [leg.*] sections and no campaign.games list");
            }
            for game in games.split(',').map(str::trim).filter(|g| !g.is_empty()) {
                let mut cfg = base.clone();
                cfg.game = game.to_string();
                cfg.validate()?;
                legs.push(CampaignLeg { id: game.to_string(), cfg });
            }
        } else {
            for id in leg_ids {
                let key = |field: &str| format!("leg.{id}.{field}");
                let mut cfg = base.clone();
                cfg.game = doc.str_or(&key("game"), &cfg.game)?;
                cfg.seed = doc.usize_or(&key("seed"), cfg.seed as usize)? as u64;
                cfg.net = doc.str_or(&key("net"), &cfg.net)?;
                cfg.mode = ExecMode::parse(&doc.str_or(&key("mode"), cfg.mode.name())?)?;
                cfg.threads = doc.usize_or(&key("threads"), cfg.threads)?;
                cfg.envs_per_thread = doc.usize_or(&key("envs_per_thread"), cfg.envs_per_thread)?;
                cfg.total_steps = doc.usize_or(&key("steps"), cfg.total_steps as usize)? as u64;
                cfg.eval_seed = doc.usize_or(&key("eval_seed"), cfg.eval_seed as usize)? as u64;
                cfg.fleet_samplers = doc.usize_or(&key("fleet_samplers"), cfg.fleet_samplers)?;
                cfg.fleet_lag = doc.usize_or(&key("fleet_lag"), cfg.fleet_lag as usize)? as u64;
                cfg.validate().with_context(|| format!("leg {id:?}"))?;
                legs.push(CampaignLeg { id, cfg });
            }
        }
        if legs.is_empty() {
            bail!("campaign has no legs");
        }
        Ok(Campaign { name, ckpt_root, order, slice, sampler_bin, legs })
    }

    fn leg_dir(&self, leg: &CampaignLeg) -> PathBuf {
        self.ckpt_root.join(&leg.id)
    }

    fn result_path(&self, leg: &CampaignLeg) -> PathBuf {
        self.leg_dir(leg).join("result.json")
    }

    /// True when the leg has a published result (ran to completion in some
    /// earlier invocation).
    pub fn leg_done(&self, leg: &CampaignLeg) -> bool {
        self.result_path(leg).exists()
    }

    /// Advance one leg by at most `limit` steps (None = to completion):
    /// build a coordinator, resume its newest checkpoint if one exists,
    /// run, and drop the machine (its state lives on in the checkpoint the
    /// run wrote at its final quiesce point). Returns the report when the
    /// leg reached its step budget.
    fn advance_leg(
        &self,
        leg: &CampaignLeg,
        artifact_dir: &Path,
        limit: Option<u64>,
        log: &mut impl FnMut(&str),
    ) -> Result<Option<LegReport>> {
        let dir = self.leg_dir(leg);
        let mut cfg = leg.cfg.clone();
        cfg.ckpt_dir = Some(dir.to_string_lossy().into_owned());
        let total = cfg.total_steps;
        let fleet_cfg = (cfg.fleet_samplers > 0).then(|| cfg.clone());
        let mut coord = Coordinator::new(cfg, artifact_dir)?;
        if let Some(ckpt) = latest_checkpoint(&dir)? {
            let step = coord.resume_from(&ckpt)?;
            log(&format!("[{}] resumed {} at step {step}", self.name, leg.id));
        }
        let res = match &fleet_cfg {
            None => coord.run_for(limit)?,
            Some(fcfg) => self.advance_fleet_leg(leg, fcfg, &mut coord, limit, log)?,
        };
        log(&format!(
            "[{}] {} at {}/{total} steps ({:.0} steps/s this turn)",
            self.name, leg.id, res.steps, res.steps_per_sec
        ));
        if res.steps < total {
            return Ok(None);
        }
        let report = LegReport {
            id: leg.id.clone(),
            game: leg.cfg.game.clone(),
            steps: res.steps,
            episodes: res.episodes,
            trains: res.trains,
            recent_mean_return: res.recent_mean_return(100),
            state_digest: coord.state_digest()?,
        };
        let json = obj(vec![
            ("leg", Json::Str(report.id.clone())),
            ("game", Json::Str(report.game.clone())),
            ("steps", Json::Num(report.steps as f64)),
            ("episodes", Json::Num(report.episodes as f64)),
            ("trains", Json::Num(report.trains as f64)),
            ("recent_mean_return", Json::Num(report.recent_mean_return)),
            ("state_digest", Json::Str(format!("{:016x}", report.state_digest))),
            (
                "evals",
                Json::Arr(
                    res.evals
                        .iter()
                        .map(|e| {
                            obj(vec![
                                ("step", Json::Num(e.step as f64)),
                                ("mean", Json::Num(e.mean_return)),
                                ("std", Json::Num(e.std_return)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        std::fs::create_dir_all(&dir)?;
        std::fs::write(self.result_path(leg), json.to_string())
            .with_context(|| format!("writing {}", self.result_path(leg).display()))?;
        Ok(Some(report))
    }

    /// Run one (slice of a) fleet leg: spawn the leg's local sampler
    /// workers against a private unix socket, host the learner on the
    /// already-resumed coordinator, then reap the workers — a clean run
    /// (or slice) shuts them down over the wire; an error kills them.
    fn advance_fleet_leg(
        &self,
        leg: &CampaignLeg,
        fcfg: &ExperimentConfig,
        coord: &mut Coordinator,
        limit: Option<u64>,
        log: &mut impl FnMut(&str),
    ) -> Result<crate::coordinator::TrainResult> {
        let samplers = fcfg.fleet_samplers;
        let bind = format!(
            "unix:{}",
            std::env::temp_dir()
                .join(format!("tempo-fleet-{}-{}.sock", std::process::id(), leg.id))
                .display()
        );
        let bin = match &self.sampler_bin {
            Some(path) => path.clone(),
            None => std::env::current_exe()
                .context("resolving this binary for fleet sampler spawns (campaign.sampler_bin overrides)")?,
        };
        log(&format!(
            "[{}] {}: fleet of {samplers} sampler process(es), lag {}",
            self.name, leg.id, fcfg.fleet_lag
        ));
        let mut children = spawn_local_samplers(&bin, fcfg, &bind, samplers)?;
        let run = coord.run_fleet(&FleetOpts { bind, samplers }, limit);
        if run.is_err() {
            for child in &mut children {
                let _ = child.kill();
            }
        }
        for child in &mut children {
            let _ = child.wait();
        }
        run
    }

    /// Strict: a result.json that lost fields (partial write, hand edit)
    /// must fail loudly, not report a phantom zero-step leg and mask the
    /// loss — delete the file to make the campaign re-run the leg.
    fn load_report(&self, leg: &CampaignLeg) -> Result<LegReport> {
        let path = self.result_path(leg);
        let text = std::fs::read_to_string(&path)?;
        let v = Json::parse(&text).map_err(|e| anyhow::anyhow!("{}: {e}", path.display()))?;
        let num = |field: &str| -> Result<f64> {
            v.get(field)
                .and_then(Json::as_f64)
                .ok_or_else(|| anyhow::anyhow!("{}: missing or non-numeric {field:?}", path.display()))
        };
        let digest = v
            .get("state_digest")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("{}: missing state_digest", path.display()))?;
        Ok(LegReport {
            id: leg.id.clone(),
            game: leg.cfg.game.clone(),
            steps: num("steps")? as u64,
            episodes: num("episodes")? as u64,
            trains: num("trains")? as u64,
            recent_mean_return: num("recent_mean_return")?,
            state_digest: u64::from_str_radix(digest, 16)
                .map_err(|_| anyhow::anyhow!("{}: malformed state_digest {digest:?}", path.display()))?,
        })
    }

    /// Execute the campaign (resuming any prior partial execution) and
    /// return one report per leg, in declaration order.
    pub fn run(&self, artifact_dir: &Path, mut log: impl FnMut(&str)) -> Result<Vec<LegReport>> {
        let mut reports: Vec<Option<LegReport>> = self
            .legs
            .iter()
            .map(|leg| {
                if self.leg_done(leg) {
                    log(&format!("[{}] {} already complete, skipping", self.name, leg.id));
                    self.load_report(leg).map(Some)
                } else {
                    Ok(None)
                }
            })
            .collect::<Result<_>>()?;

        match self.order {
            Order::Sequential => {
                for (leg, slot) in self.legs.iter().zip(reports.iter_mut()) {
                    if slot.is_some() {
                        continue;
                    }
                    *slot = self.advance_leg(leg, artifact_dir, None, &mut log)?;
                    debug_assert!(slot.is_some(), "unlimited run must finish the leg");
                }
            }
            Order::RoundRobin => {
                while reports.iter().any(Option::is_none) {
                    for (leg, slot) in self.legs.iter().zip(reports.iter_mut()) {
                        if slot.is_some() {
                            continue;
                        }
                        *slot = self.advance_leg(leg, artifact_dir, Some(self.slice), &mut log)?;
                    }
                }
            }
        }
        Ok(reports.into_iter().map(|r| r.unwrap()).collect())
    }
}

/// Plain-text summary table for the launcher.
pub fn summary_table(reports: &[LegReport]) -> String {
    let mut out = String::new();
    out.push_str(&format!(
        "{:<16} {:<10} {:>12} {:>9} {:>9} {:>14}  {}\n",
        "leg", "game", "steps", "episodes", "trains", "recent return", "state digest"
    ));
    for r in reports {
        out.push_str(&format!(
            "{:<16} {:<10} {:>12} {:>9} {:>9} {:>14.2}  {:016x}\n",
            r.id, r.game, r.steps, r.episodes, r.trains, r.recent_mean_return, r.state_digest
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_explicit_legs_in_order() {
        let doc = TomlDoc::parse(
            "preset = \"smoke\"\n\
             [campaign]\nname = \"t\"\nckpt_dir = \"/tmp/c\"\norder = \"round_robin\"\nslice = 64\n\
             [leg.10_breakout]\ngame = \"breakout\"\nseed = 2\nsteps = 128\n\
             [leg.05_pong]\ngame = \"pong\"\nseed = 1\nsteps = 64\n",
        )
        .unwrap();
        let c = Campaign::from_toml(&doc).unwrap();
        assert_eq!(c.order, Order::RoundRobin);
        assert_eq!(c.slice, 64);
        let ids: Vec<&str> = c.legs.iter().map(|l| l.id.as_str()).collect();
        assert_eq!(ids, vec!["05_pong", "10_breakout"], "section-name order");
        assert_eq!(c.legs[0].cfg.game, "pong");
        assert_eq!(c.legs[0].cfg.seed, 1);
        assert_eq!(c.legs[0].cfg.total_steps, 64);
        assert_eq!(c.legs[1].cfg.game, "breakout");
        assert_eq!(c.legs[1].cfg.total_steps, 128);
    }

    #[test]
    fn games_shorthand_builds_one_leg_per_game() {
        let doc = TomlDoc::parse(
            "preset = \"smoke\"\n[campaign]\ngames = \"pong, seeker\"\n",
        )
        .unwrap();
        let c = Campaign::from_toml(&doc).unwrap();
        assert_eq!(c.legs.len(), 2);
        assert_eq!(c.legs[0].id, "pong");
        assert_eq!(c.legs[1].cfg.game, "seeker");
        assert_eq!(c.order, Order::Sequential);
    }

    #[test]
    fn fleet_keys_parse_per_leg_and_from_base() {
        let doc = TomlDoc::parse(
            "preset = \"smoke\"\n\
             [fleet]\nsamplers = 2\n\
             [campaign]\nname = \"f\"\nsampler_bin = \"/opt/bin/tempo-dqn\"\n\
             [leg.a]\ngame = \"pong\"\n\
             [leg.b]\ngame = \"seeker\"\nfleet_samplers = 3\nfleet_lag = 1\n",
        )
        .unwrap();
        let c = Campaign::from_toml(&doc).unwrap();
        assert_eq!(c.sampler_bin.as_deref(), Some(Path::new("/opt/bin/tempo-dqn")));
        assert_eq!(c.legs[0].cfg.fleet_samplers, 2, "base [fleet] inherited");
        assert_eq!(c.legs[0].cfg.fleet_lag, 0);
        assert_eq!(c.legs[1].cfg.fleet_samplers, 3, "per-leg override");
        assert_eq!(c.legs[1].cfg.fleet_lag, 1);
    }

    #[test]
    fn rejects_empty_and_bad_campaigns() {
        let doc = TomlDoc::parse("preset = \"smoke\"\n[campaign]\nname = \"x\"\n").unwrap();
        assert!(Campaign::from_toml(&doc).is_err(), "no legs");
        let doc =
            TomlDoc::parse("preset = \"smoke\"\n[campaign]\ngames = \"pong\"\norder = \"bogus\"\n")
                .unwrap();
        assert!(Campaign::from_toml(&doc).is_err(), "bad order");
        let doc =
            TomlDoc::parse("preset = \"smoke\"\n[campaign]\ngames = \"pong\"\nslice = 0\n").unwrap();
        assert!(Campaign::from_toml(&doc).is_err(), "zero slice");
    }
}
