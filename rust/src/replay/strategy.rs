//! Pluggable replay sampling strategies (rust/DESIGN.md §11).
//!
//! The trainer's draw half is abstracted behind [`SamplingStrategy`]:
//!
//! * [`Uniform`] — wraps the historical [`IndexSampler`]: same "REPL" RNG
//!   stream, same call sequence, so `replay_strategy = "uniform"` with
//!   `n_step = 1` is **bit-identical to the pre-strategy machine**
//!   (the draw/assemble pair is literally the old code path).
//! * [`Proportional`] — prioritized experience replay (Schaul et al. 2015)
//!   over a deterministic fixed-capacity [`SumTree`], with
//!   importance-sampling weights applied in the native engine's loss and
//!   β annealed on the trainer's minibatch counter.
//!
//! **Determinism** (the crate's core guarantee): draws advance one RNG on
//! one mutex in consumption order, exactly like the uniform sampler, and
//! TD-error priority updates are *deferred* in windowed modes — queued at
//! train time and applied only at the window barrier, after the staging
//! flush, right where the next window's grant is issued. Within a window
//! the tree is therefore frozen: the prefetch worker drawing batch t+1
//! early sees exactly the tree the inline sampler would have seen, and
//! any `learner_threads` width produces bit-identical TD errors (§9), so
//! prioritized trajectories are invariant across learner_threads ×
//! prefetch × kill-and-resume (pinned by `tests/strategy_equivalence.rs`).
//! Non-windowed modes (standard / synchronized-inline) interleave training
//! with replay writes sequentially, so there updates apply immediately
//! after each train step — the same machine order every run.
//!
//! Updates are guarded by per-slot *generations* (the replay push counter
//! at write time): an update whose transition was overwritten by the
//! barrier's staging flush is skipped deterministically instead of
//! re-prioritizing an unrelated transition.

use std::collections::VecDeque;

use anyhow::{anyhow, bail, Result};

use crate::config::ReplayStrategy;
use crate::runtime::TrainBatch;
use crate::util::rng::Rng;

use super::ring::{IndexSampler, ReplayMemory};

/// Additive constant before the α exponent: p = (|δ| + ε)^α. Keeps every
/// priority strictly positive so no stored transition starves forever.
pub const PER_EPS: f64 = 0.01;

/// Everything a driver needs to build the configured strategy (a plain
/// data carrier so the replay layer stays independent of the launcher
/// config; see `coordinator::shared::strategy_plan`).
#[derive(Clone, Copy, Debug)]
pub struct StrategyPlan {
    pub kind: ReplayStrategy,
    /// Priority exponent α (0 = uniform, 1 = fully proportional).
    pub per_alpha: f64,
    /// Initial importance-sampling exponent β₀.
    pub per_beta0: f64,
    /// Trainer minibatches over which β anneals linearly from β₀ to 1.
    pub per_beta_anneal: u64,
    /// Multi-step return horizon (1 = classic one-step targets).
    pub n_step: usize,
    /// Discount γ (needed by n-step assembly and the IS-weighted target).
    pub gamma: f64,
}

/// One queued priority update: the tree leaf, the generation guard, and
/// the new (already α-exponentiated) priority.
#[derive(Clone, Copy, Debug)]
struct PendingUpdate {
    leaf: usize,
    gen: u64,
    priority: f64,
}

/// The trainer-facing draw/update seam. One strategy instance exists per
/// run segment, behind the batch source's mutex; its RNG position is the
/// `SegmentState::draw_rng` carried across segments and checkpoints.
pub trait SamplingStrategy: Send {
    /// Draw-stream RNG position (segment/checkpoint persistence).
    fn rng_state(&self) -> [u64; 4];

    /// Draw `minibatch` transition indices and assemble them into `out`
    /// (n-step aware; fills `weights` / `boot_gammas` when the strategy
    /// or horizon needs them, leaves them empty on the legacy path).
    /// Records pick provenance so a later [`SamplingStrategy::record_td`]
    /// can be paired with this batch. Errors until replay holds enough
    /// transitions.
    fn fill_batch(
        &mut self,
        replay: &ReplayMemory,
        minibatch: usize,
        out: &mut TrainBatch,
    ) -> Result<()>;

    /// Pair one trained batch's TD errors (consumption order — batches are
    /// trained in draw order) with the oldest outstanding draw and queue
    /// the priority updates.
    fn record_td(&mut self, td: &[f32]);

    /// Apply every queued update to the replay's priority index. Windowed
    /// drivers call this at the window barrier (after the staging flush);
    /// non-windowed sources call it immediately after each `record_td`.
    fn apply_updates(&mut self, replay: &mut ReplayMemory);

    /// Any updates queued? (Lets callers skip taking the write lock.)
    fn has_pending(&self) -> bool;
}

/// Build the configured strategy with its draw stream resumed at
/// `rng_state` and its β anneal based at `trains_done` minibatches (both
/// come from the machine's persistent segment state, so segmentation and
/// checkpoint/resume are trajectory-neutral).
pub fn build_strategy(
    plan: &StrategyPlan,
    rng_state: [u64; 4],
    trains_done: u64,
) -> Box<dyn SamplingStrategy> {
    match plan.kind {
        ReplayStrategy::Uniform => Box::new(Uniform::resumed(plan, rng_state)),
        ReplayStrategy::Proportional => {
            Box::new(Proportional::resumed(plan, rng_state, trains_done))
        }
    }
}

// ---------------------------------------------------------------------------
// Uniform
// ---------------------------------------------------------------------------

/// The historical uniform sampler behind the strategy seam. With
/// `n_step = 1` this is byte-for-byte the pre-strategy trainer path:
/// identical RNG stream, identical draw sequence, identical assembly,
/// `weights` / `boot_gammas` left empty so the engine takes the legacy
/// 10-input entry.
pub struct Uniform {
    sampler: IndexSampler,
    n_step: usize,
    gamma: f32,
}

impl Uniform {
    pub fn new(seed: u64, n_step: usize, gamma: f64) -> Uniform {
        Uniform::from_sampler(IndexSampler::new(seed), n_step, gamma)
    }

    pub fn from_sampler(sampler: IndexSampler, n_step: usize, gamma: f64) -> Uniform {
        Uniform { sampler, n_step: n_step.max(1), gamma: gamma as f32 }
    }

    fn resumed(plan: &StrategyPlan, rng_state: [u64; 4]) -> Uniform {
        Uniform::from_sampler(IndexSampler::from_rng_state(rng_state), plan.n_step, plan.gamma)
    }
}

impl SamplingStrategy for Uniform {
    fn rng_state(&self) -> [u64; 4] {
        self.sampler.rng_state()
    }

    fn fill_batch(
        &mut self,
        replay: &ReplayMemory,
        minibatch: usize,
        out: &mut TrainBatch,
    ) -> Result<()> {
        let picks = self.sampler.draw(replay, minibatch)?;
        if self.n_step == 1 {
            // The legacy path: no weights, no per-sample discounts, the
            // engine's 10-input entry — bit-identical to the seed machine.
            out.weights.clear();
            out.boot_gammas.clear();
            replay.assemble(&picks, out);
        } else {
            // Same draws (uniform n-step reuses the 1-step index
            // distribution); assembly widens to the n-step window. The
            // all-ones weights keep the engine's weighted path exact
            // (x * 1.0 is the identity on every finite f32).
            out.weights.clear();
            out.weights.resize(minibatch, 1.0);
            replay.assemble_nstep(&picks, self.n_step, self.gamma, out);
        }
        Ok(())
    }

    fn record_td(&mut self, _td: &[f32]) {}

    fn apply_updates(&mut self, _replay: &mut ReplayMemory) {}

    fn has_pending(&self) -> bool {
        false
    }
}

// ---------------------------------------------------------------------------
// Proportional (prioritized experience replay)
// ---------------------------------------------------------------------------

/// Proportional PER: P(i) = pᵢ / Σp over the sum-tree's active leaves,
/// IS weight wᵢ = (N·P(i))^(−β) normalized by the batch maximum,
/// β = β₀ + (1−β₀)·min(1, trains / anneal).
pub struct Proportional {
    rng: Rng,
    alpha: f64,
    beta0: f64,
    beta_anneal: u64,
    n_step: usize,
    gamma: f32,
    /// Minibatches drawn so far over the whole run (β anneal clock;
    /// resumes from the machine's `trains_done`, since every drawn batch
    /// is trained exactly once in order).
    draws: u64,
    /// Pick provenance of drawn-but-not-yet-recorded batches (FIFO — the
    /// prefetch worker may run several draws ahead of the trainer).
    pending_picks: VecDeque<Vec<(usize, u64)>>,
    /// Updates queued for the next barrier (windowed modes).
    queued: Vec<PendingUpdate>,
}

impl Proportional {
    fn resumed(plan: &StrategyPlan, rng_state: [u64; 4], trains_done: u64) -> Proportional {
        Proportional {
            rng: Rng::from_state(rng_state),
            alpha: plan.per_alpha,
            beta0: plan.per_beta0,
            beta_anneal: plan.per_beta_anneal.max(1),
            n_step: plan.n_step.max(1),
            gamma: plan.gamma as f32,
            draws: trains_done,
            pending_picks: VecDeque::new(),
            queued: Vec::new(),
        }
    }

    /// Current IS exponent β for the `draws`-th minibatch.
    fn beta(&self) -> f64 {
        let frac = (self.draws as f64 / self.beta_anneal as f64).min(1.0);
        self.beta0 + (1.0 - self.beta0) * frac
    }
}

impl SamplingStrategy for Proportional {
    fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    fn fill_batch(
        &mut self,
        replay: &ReplayMemory,
        minibatch: usize,
        out: &mut TrainBatch,
    ) -> Result<()> {
        let pi = replay.priorities().ok_or_else(|| {
            anyhow!("proportional strategy needs a priority-indexed replay (enable_priorities)")
        })?;
        let n_avail = replay.sampleable();
        let total = pi.total();
        if n_avail == 0 || total <= 0.0 {
            bail!("replay has no sampleable transitions yet (len {})", replay.len());
        }
        let beta = self.beta();
        let mut picks = Vec::with_capacity(minibatch);
        let mut provenance = Vec::with_capacity(minibatch);
        let mut weights = Vec::with_capacity(minibatch);
        let mut w_max = 0.0f64;
        for _ in 0..minibatch {
            let u = self.rng.f64() * total;
            let leaf = pi.sample(u);
            let idx = replay.leaf_to_index(leaf).ok_or_else(|| {
                anyhow!("sum-tree sampled an inactive leaf {leaf} (index corrupt)")
            })?;
            picks.push(idx);
            provenance.push((leaf, pi.gen(leaf)));
            let p = pi.value(leaf) / total;
            let w = (n_avail as f64 * p).powf(-beta);
            w_max = w_max.max(w);
            weights.push(w);
        }
        out.weights.clear();
        out.weights.extend(weights.iter().map(|&w| (w / w_max) as f32));
        replay.assemble_nstep(&picks, self.n_step, self.gamma, out);
        self.pending_picks.push_back(provenance);
        self.draws += 1;
        Ok(())
    }

    fn record_td(&mut self, td: &[f32]) {
        let Some(picks) = self.pending_picks.pop_front() else {
            debug_assert!(false, "record_td without an outstanding draw");
            return;
        };
        debug_assert_eq!(picks.len(), td.len(), "TD errors must match the drawn batch");
        for ((leaf, gen), &d) in picks.into_iter().zip(td.iter()) {
            let priority = (d.abs() as f64 + PER_EPS).powf(self.alpha);
            self.queued.push(PendingUpdate { leaf, gen, priority });
        }
    }

    fn apply_updates(&mut self, replay: &mut ReplayMemory) {
        if self.queued.is_empty() {
            return;
        }
        let pi = replay
            .priorities_mut()
            .expect("proportional strategy needs a priority-indexed replay");
        for u in self.queued.drain(..) {
            // Generation-guarded: a transition the staging flush already
            // overwrote keeps the *new* occupant's max-priority seed.
            pi.update(u.leaf, u.gen, u.priority);
        }
    }

    fn has_pending(&self) -> bool {
        !self.queued.is_empty()
    }
}

// ---------------------------------------------------------------------------
// Sum-tree + priority index
// ---------------------------------------------------------------------------

/// Deterministic fixed-capacity sum-tree over f64 masses.
///
/// Implemented as a flat perfect binary tree (`tree[1]` = root,
/// children of `i` at `2i`/`2i+1`, leaves in the last level). `set`
/// recomputes every ancestor as the *fresh* sum of its two children, so
/// each internal node is a pure function of the current leaf values —
/// the tree's state (and therefore every sampled index) depends only on
/// the leaf history, never on update interleaving.
pub struct SumTree {
    tree: Vec<f64>,
    base: usize,
    leaves: usize,
}

impl SumTree {
    pub fn new(leaves: usize) -> SumTree {
        let base = leaves.max(1).next_power_of_two();
        SumTree { tree: vec![0.0; 2 * base], base, leaves }
    }

    pub fn len(&self) -> usize {
        self.leaves
    }

    pub fn is_empty(&self) -> bool {
        self.leaves == 0
    }

    pub fn total(&self) -> f64 {
        self.tree[1]
    }

    pub fn get(&self, leaf: usize) -> f64 {
        debug_assert!(leaf < self.leaves);
        self.tree[self.base + leaf]
    }

    pub fn set(&mut self, leaf: usize, mass: f64) {
        debug_assert!(leaf < self.leaves);
        debug_assert!(mass >= 0.0 && mass.is_finite());
        let mut i = self.base + leaf;
        self.tree[i] = mass;
        while i > 1 {
            i /= 2;
            self.tree[i] = self.tree[2 * i] + self.tree[2 * i + 1];
        }
    }

    /// Leaf index whose cumulative-mass interval contains `u`. Zero-mass
    /// subtrees are never entered, so for `total() > 0` the returned leaf
    /// always carries positive mass even when `u` rounds up to `total()`.
    pub fn sample(&self, u: f64) -> usize {
        let mut u = u.max(0.0);
        let mut i = 1usize;
        while i < self.base {
            let left = self.tree[2 * i];
            if u < left || self.tree[2 * i + 1] == 0.0 {
                i = 2 * i;
            } else {
                u -= left;
                i = 2 * i + 1;
            }
        }
        (i - self.base).min(self.leaves.saturating_sub(1))
    }

    /// Structural invariant check (tests): every parent equals the exact
    /// f64 sum of its children.
    #[cfg(test)]
    pub(crate) fn check_conservation(&self) -> bool {
        (1..self.base).all(|i| self.tree[i] == self.tree[2 * i] + self.tree[2 * i + 1])
    }
}

/// Per-transition priority state living inside [`ReplayMemory`], indexed
/// by *physical* leaf (`stream * per_stream_cap + physical_slot`), which
/// is stable until the slot is overwritten.
///
/// Each leaf carries a *latent* priority (the transition's stored
/// priority), an *active* flag (is the transition currently sampleable —
/// maintained by `ReplayMemory::push` as slots gain successors, fall
/// below the history threshold, or are overwritten), and a *generation*
/// (the replay push counter at write time, the update guard). The tree
/// holds `latent` for active leaves and 0 otherwise.
pub struct PriorityIndex {
    tree: SumTree,
    latent: Vec<f64>,
    active: Vec<bool>,
    gen: Vec<u64>,
    active_count: usize,
    max_priority: f64,
}

impl PriorityIndex {
    pub fn new(leaves: usize) -> PriorityIndex {
        PriorityIndex {
            tree: SumTree::new(leaves),
            latent: vec![0.0; leaves],
            active: vec![false; leaves],
            gen: vec![0; leaves],
            active_count: 0,
            max_priority: 1.0,
        }
    }

    pub fn total(&self) -> f64 {
        self.tree.total()
    }

    /// Effective (sampling) mass of a leaf: latent if active, else 0.
    pub fn value(&self, leaf: usize) -> f64 {
        self.tree.get(leaf)
    }

    pub fn gen(&self, leaf: usize) -> u64 {
        self.gen[leaf]
    }

    pub fn active_count(&self) -> usize {
        self.active_count
    }

    pub fn max_priority(&self) -> f64 {
        self.max_priority
    }

    pub fn sample(&self, u: f64) -> usize {
        self.tree.sample(u)
    }

    /// A new transition was written at `leaf`: seed it at the running max
    /// priority (every transition is drawn at least once before its first
    /// TD error exists), mark it inactive (the newest slot has no stored
    /// successor yet), and stamp its generation.
    pub(crate) fn insert(&mut self, leaf: usize, gen: u64) {
        self.latent[leaf] = self.max_priority;
        self.gen[leaf] = gen;
        if self.active[leaf] {
            self.active[leaf] = false;
            self.active_count -= 1;
        }
        if self.tree.get(leaf) != 0.0 {
            self.tree.set(leaf, 0.0);
        }
    }

    /// The transition at `leaf` became sampleable.
    pub(crate) fn activate(&mut self, leaf: usize) {
        if !self.active[leaf] {
            self.active[leaf] = true;
            self.active_count += 1;
            self.tree.set(leaf, self.latent[leaf]);
        }
    }

    /// The transition at `leaf` fell out of the sampleable window.
    pub(crate) fn deactivate(&mut self, leaf: usize) {
        if self.active[leaf] {
            self.active[leaf] = false;
            self.active_count -= 1;
            self.tree.set(leaf, 0.0);
        }
    }

    /// Generation-guarded priority update. Returns false (and does
    /// nothing) when the slot was overwritten since the draw.
    pub fn update(&mut self, leaf: usize, gen: u64, priority: f64) -> bool {
        if self.gen[leaf] != gen {
            return false;
        }
        debug_assert!(priority > 0.0 && priority.is_finite());
        self.latent[leaf] = priority;
        self.max_priority = self.max_priority.max(priority);
        if self.active[leaf] {
            self.tree.set(leaf, priority);
        }
        true
    }

    /// Raw per-leaf state (checkpointing; see
    /// `ReplayMemory::save_priorities`).
    pub(crate) fn latent(&self, leaf: usize) -> f64 {
        self.latent[leaf]
    }

    pub(crate) fn set_restored(&mut self, leaf: usize, latent: f64, gen: u64) {
        self.latent[leaf] = latent;
        self.gen[leaf] = gen;
        if self.active[leaf] {
            self.tree.set(leaf, latent);
        }
    }

    pub(crate) fn set_max_priority(&mut self, v: f64) {
        self.max_priority = v;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sumtree_set_get_total() {
        let mut t = SumTree::new(5);
        assert_eq!(t.total(), 0.0);
        t.set(0, 1.0);
        t.set(3, 2.5);
        t.set(4, 0.5);
        assert_eq!(t.get(3), 2.5);
        assert_eq!(t.total(), 4.0);
        t.set(3, 0.0);
        assert_eq!(t.total(), 1.5);
        assert!(t.check_conservation());
    }

    #[test]
    fn sumtree_sample_never_lands_on_zero_mass() {
        let mut t = SumTree::new(8);
        t.set(2, 1.0);
        t.set(5, 3.0);
        // Probe the whole mass range including the exact upper edge.
        for k in 0..=100 {
            let u = t.total() * k as f64 / 100.0;
            let leaf = t.sample(u);
            assert!(leaf == 2 || leaf == 5, "u {u} -> leaf {leaf}");
            assert!(t.get(leaf) > 0.0);
        }
        assert_eq!(t.sample(0.0), 2);
        assert_eq!(t.sample(0.999), 2);
        assert_eq!(t.sample(1.0), 5);
        assert_eq!(t.sample(4.0), 5, "u == total clamps into the last positive leaf");
    }

    #[test]
    fn priority_index_insert_activate_update() {
        let mut pi = PriorityIndex::new(4);
        pi.insert(0, 1);
        assert_eq!(pi.active_count(), 0);
        assert_eq!(pi.total(), 0.0);
        pi.activate(0);
        assert_eq!(pi.active_count(), 1);
        assert_eq!(pi.total(), 1.0, "fresh transitions carry max_priority");
        assert!(pi.update(0, 1, 4.0));
        assert_eq!(pi.total(), 4.0);
        assert_eq!(pi.max_priority(), 4.0);
        // Wrong generation: guarded out.
        assert!(!pi.update(0, 9, 100.0));
        assert_eq!(pi.total(), 4.0);
        // Overwrite: new occupant seeds at the (raised) max priority.
        pi.insert(0, 2);
        assert_eq!(pi.total(), 0.0);
        pi.activate(0);
        assert_eq!(pi.total(), 4.0);
        pi.deactivate(0);
        assert_eq!(pi.total(), 0.0);
        assert_eq!(pi.active_count(), 0);
    }

    #[test]
    fn uniform_strategy_preserves_legacy_draw_sequence() {
        let fill = |r: &mut ReplayMemory| {
            for v in 0..40u8 {
                r.push(0, &[v; 8], v, v as f32 * 0.25, v % 9 == 8, v == 0 || v % 9 == 0);
            }
        };
        let mut legacy = ReplayMemory::new(64, 1, 8, 4, 11).unwrap();
        let mut strat_mem = ReplayMemory::new(64, 1, 8, 4, 11).unwrap();
        fill(&mut legacy);
        fill(&mut strat_mem);
        let mut strat = Uniform::new(11, 1, 0.99);
        for _ in 0..5 {
            let mut want = TrainBatch::default();
            legacy.sample(16, &mut want).unwrap();
            let mut got = TrainBatch::default();
            strat.fill_batch(&strat_mem, 16, &mut got).unwrap();
            assert_eq!(want.states, got.states);
            assert_eq!(want.actions, got.actions);
            assert_eq!(want.rewards, got.rewards);
            assert_eq!(want.dones, got.dones);
            assert!(got.weights.is_empty(), "legacy path must not emit weights");
            assert!(got.boot_gammas.is_empty(), "legacy path must not emit discounts");
        }
    }

    #[test]
    fn proportional_beta_anneals_on_the_train_clock() {
        let plan = StrategyPlan {
            kind: ReplayStrategy::Proportional,
            per_alpha: 0.6,
            per_beta0: 0.4,
            per_beta_anneal: 100,
            n_step: 1,
            gamma: 0.99,
        };
        let mut p = Proportional::resumed(&plan, Rng::new(1).state(), 0);
        assert!((p.beta() - 0.4).abs() < 1e-12);
        p.draws = 50;
        assert!((p.beta() - 0.7).abs() < 1e-12);
        p.draws = 100;
        assert!((p.beta() - 1.0).abs() < 1e-12);
        p.draws = 10_000;
        assert!((p.beta() - 1.0).abs() < 1e-12, "β caps at 1");
        // Resuming from a checkpointed train count lands on the exact β
        // the uninterrupted machine would use for that minibatch.
        p.draws = 50;
        let r = Proportional::resumed(&plan, Rng::new(1).state(), 50);
        assert_eq!(r.beta().to_bits(), p.beta().to_bits());
    }
}
