//! Frame-chained ring-buffer replay memory with stacked reconstruction.
//!
//! Storage layout per stream (one stream per environment instance):
//!   slot t: frame f_t (newest plane of state s_t), action a_t, clipped
//!           reward r_t, done_t, start_t (f_t begins an episode).
//!
//! The stacked state s_t = frames ending at slot t; frames from before the
//! episode start are replaced by replicating the episode's first frame
//! (exactly what AtariEnv::reset does to its history). The successor state
//! s'_t ends at slot t+1; when done_t the bootstrap is masked by `done`, so
//! the (new-episode) successor content is irrelevant but still well-formed.
//!
//! Sampling is split into two halves so the prefetch pipeline
//! (`replay/prefetch.rs`) can overlap batch assembly with training:
//!
//! * [`IndexSampler::draw`] — the RNG half: picks uniform transition
//!   indices. Needs `&mut` (it advances the RNG) but is O(batch).
//! * [`ReplayMemory::assemble`] — the frame half: reconstructs the stacked
//!   states for drawn indices. Read-only (`&self`), so it runs under a
//!   shared lock while samplers only contend for the brief write half.
//!
//! [`ReplayMemory::sample`] composes the two with an internally-owned
//! sampler, byte-for-byte equivalent to the historical single-call API.

use anyhow::{bail, Result};

use crate::runtime::TrainBatch;
use crate::util::rng::Rng;

use super::strategy::PriorityIndex;

struct Stream {
    frames: Vec<u8>, // cap * frame_size
    actions: Vec<u8>,
    rewards: Vec<f32>,
    dones: Vec<bool>,
    starts: Vec<bool>,
    cap: usize,
    next: usize,
    len: usize,
}

impl Stream {
    fn new(cap: usize, frame_size: usize) -> Self {
        Stream {
            frames: vec![0; cap * frame_size],
            actions: vec![0; cap],
            rewards: vec![0.0; cap],
            dones: vec![false; cap],
            starts: vec![false; cap],
            cap,
            next: 0,
            len: 0,
        }
    }

    /// Physical slot of logical index l (0 = oldest valid).
    fn phys(&self, l: usize) -> usize {
        debug_assert!(l < self.len);
        (self.next + self.cap - self.len + l) % self.cap
    }

    /// Number of sampleable transitions (needs `stack-1` history slots and
    /// one successor slot).
    fn valid(&self, stack: usize) -> usize {
        self.len.saturating_sub(stack.max(1))
    }
}

/// One drawn minibatch element: a stream id plus the logical slot of the
/// transition's newest frame.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SampleIndex {
    pub stream: usize,
    pub slot: usize,
}

/// The index-sampling RNG, split from frame assembly.
///
/// Uses the exact stream derivation `ReplayMemory` used historically
/// (root seed, stream id `"REPL"`), so an external sampler constructed
/// from the same seed reproduces the memory's internal draw sequence
/// bit-for-bit.
pub struct IndexSampler {
    rng: Rng,
}

impl IndexSampler {
    pub fn new(seed: u64) -> IndexSampler {
        IndexSampler { rng: Rng::stream(seed, 0x5245504c) } // "REPL"
    }

    /// RNG stream position (checkpointing).
    pub fn rng_state(&self) -> [u64; 4] {
        self.rng.state()
    }

    /// Resume the draw stream at a saved position (checkpoint restore).
    pub fn from_rng_state(s: [u64; 4]) -> IndexSampler {
        IndexSampler { rng: Rng::from_state(s) }
    }

    /// Draw `n` transition indices uniformly over all streams' sampleable
    /// transitions. Errors until enough transitions are stored.
    pub fn draw(&mut self, replay: &ReplayMemory, n: usize) -> Result<Vec<SampleIndex>> {
        draw_indices(&mut self.rng, &replay.streams, replay.stack, n)
    }
}

/// The RNG half of sampling, shared by [`IndexSampler::draw`] and
/// [`ReplayMemory::sample`] (identical call sequence on the RNG).
fn draw_indices(rng: &mut Rng, streams: &[Stream], stack: usize, n: usize) -> Result<Vec<SampleIndex>> {
    let total: usize = streams.iter().map(|s| s.valid(stack)).sum();
    if total == 0 {
        let len: usize = streams.iter().map(|s| s.len).sum();
        bail!("replay has no sampleable transitions yet (len {len})");
    }
    let mut picks = Vec::with_capacity(n);
    for _ in 0..n {
        // Pick a global transition index, then locate its stream.
        let mut k = rng.below_usize(total);
        let mut stream = 0;
        for (si, s) in streams.iter().enumerate() {
            let v = s.valid(stack);
            if k < v {
                stream = si;
                break;
            }
            k -= v;
        }
        // Logical slot: skip the first stack-1 slots, keep successor room.
        picks.push(SampleIndex { stream, slot: stack - 1 + k });
    }
    Ok(picks)
}

pub struct ReplayMemory {
    streams: Vec<Stream>,
    frame_size: usize,
    stack: usize,
    sampler: IndexSampler,
    pushes: u64,
    /// Per-transition priority state for the proportional sampling
    /// strategy (None = uniform-only memory; no tree is allocated or
    /// maintained). See `replay/strategy.rs` and rust/DESIGN.md §11.
    priority: Option<PriorityIndex>,
}

impl ReplayMemory {
    /// `capacity` frames total, split evenly over `n_streams` environment
    /// streams. `frame_size` = bytes per plane (84*84), `stack` = 4.
    pub fn new(capacity: usize, n_streams: usize, frame_size: usize, stack: usize, seed: u64) -> Result<Self> {
        if n_streams == 0 {
            bail!("replay needs at least one stream");
        }
        let per = capacity / n_streams;
        if per < stack + 2 {
            bail!("capacity {capacity} too small for {n_streams} streams (need >= {} per stream)", stack + 2);
        }
        Ok(ReplayMemory {
            streams: (0..n_streams).map(|_| Stream::new(per, frame_size)).collect(),
            frame_size,
            stack,
            sampler: IndexSampler::new(seed),
            pushes: 0,
            priority: None,
        })
    }

    /// Frames per stream (all streams share one capacity).
    fn per_cap(&self) -> usize {
        self.streams[0].cap
    }

    /// Attach (or rebuild) the proportional strategy's priority index.
    /// Existing contents are re-indexed with the running max priority —
    /// exactly what per-push seeding would have assigned them, since no
    /// TD update has touched them yet. Idempotent geometry-wise; call
    /// before (or right after) filling the memory.
    pub fn enable_priorities(&mut self) {
        let mut pi = PriorityIndex::new(self.streams.len() * self.per_cap());
        if let Some(old) = &self.priority {
            pi.set_max_priority(old.max_priority());
        }
        self.priority = Some(pi);
        self.reindex_priorities();
    }

    /// Recompute active flags / tree masses / latent seeds from the
    /// current ring geometry (fresh enable and checkpoint restore — the
    /// restored ring is re-based, so physical leaves move).
    fn reindex_priorities(&mut self) {
        let per = self.per_cap();
        let stack = self.stack;
        let pushes = self.pushes;
        let Some(pi) = &mut self.priority else { return };
        for (si, st) in self.streams.iter().enumerate() {
            for l in 0..st.len {
                let leaf = si * per + st.phys(l);
                pi.insert(leaf, pushes);
                if l + 1 >= stack && l + 1 < st.len {
                    pi.activate(leaf);
                }
            }
        }
        debug_assert_eq!(
            pi.active_count(),
            self.streams.iter().map(|s| s.valid(stack)).sum::<usize>()
        );
    }

    /// The proportional strategy's priority index, when enabled.
    pub fn priorities(&self) -> Option<&PriorityIndex> {
        self.priority.as_ref()
    }

    pub fn priorities_mut(&mut self) -> Option<&mut PriorityIndex> {
        self.priority.as_mut()
    }

    /// Map a sum-tree leaf (`stream * per_cap + physical_slot`) back to a
    /// logical [`SampleIndex`]. None when the leaf does not address a
    /// currently sampleable transition.
    pub fn leaf_to_index(&self, leaf: usize) -> Option<SampleIndex> {
        let per = self.per_cap();
        let stream = leaf / per;
        let phys = leaf % per;
        let st = self.streams.get(stream)?;
        // Invert phys(l) = (next + cap - len + l) % cap.
        let base = (st.next + st.cap - st.len) % st.cap;
        let l = (phys + st.cap - base) % st.cap;
        if l + 1 >= self.stack && l + 1 < st.len {
            Some(SampleIndex { stream, slot: l })
        } else {
            None
        }
    }

    pub fn n_streams(&self) -> usize {
        self.streams.len()
    }

    pub fn len(&self) -> usize {
        self.streams.iter().map(|s| s.len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn capacity(&self) -> usize {
        self.streams.iter().map(|s| s.cap).sum()
    }

    /// Total transitions currently eligible for sampling.
    pub fn sampleable(&self) -> usize {
        self.streams.iter().map(|s| s.valid(self.stack)).sum()
    }

    /// Append one transition to `stream`.
    pub fn push(&mut self, stream: usize, frame: &[u8], action: u8, reward: f32, done: bool, start: bool) {
        debug_assert_eq!(frame.len(), self.frame_size);
        // Priority maintenance plan (computed against the pre-push
        // geometry; one slot gains sampleability per push, one loses it
        // once the ring is full — mirroring `Stream::valid` exactly):
        //  * full ring: the slot at logical `stack-1` drops below the
        //    history threshold after the eviction shift;
        //  * the previous newest slot gains its stored successor when the
        //    post-push window reaches it.
        let (deactivated, activated) = {
            let st = &self.streams[stream];
            let full = st.len == st.cap;
            let deact = (self.priority.is_some() && full).then(|| st.phys(self.stack - 1));
            let act = (self.priority.is_some() && st.len >= 1)
                .then(|| {
                    // Post-push logical index of the previous newest slot
                    // is new_len - 2; it activates at stack - 1.
                    let new_len = (st.len + 1).min(st.cap);
                    (new_len >= self.stack + 1).then_some((st.next + st.cap - 1) % st.cap)
                })
                .flatten();
            (deact, act)
        };
        let st = &mut self.streams[stream];
        let i = st.next;
        st.frames[i * self.frame_size..(i + 1) * self.frame_size].copy_from_slice(frame);
        st.actions[i] = action;
        st.rewards[i] = reward;
        st.dones[i] = done;
        st.starts[i] = start;
        st.next = (st.next + 1) % st.cap;
        st.len = (st.len + 1).min(st.cap);
        self.pushes += 1;
        if let Some(pi) = &mut self.priority {
            let base = stream * self.streams[stream].cap;
            if let Some(p) = deactivated {
                pi.deactivate(base + p);
            }
            pi.insert(base + i, self.pushes);
            if let Some(p) = activated {
                pi.activate(base + p);
            }
            debug_assert_eq!(
                pi.active_count(),
                self.streams.iter().map(|s| s.valid(self.stack)).sum::<usize>(),
                "priority index drifted from the sampleable set"
            );
        }
    }

    /// Write the stacked state ending at logical slot `l` of `stream` into
    /// `out`, channel-last interleaved (`out[pixel*stack + c]`), replicating
    /// the episode's first frame past episode starts.
    fn state_into(&self, stream: usize, l: usize, out: &mut [u8]) {
        let st = &self.streams[stream];
        debug_assert_eq!(out.len(), self.frame_size * self.stack);
        // Walk back from l, honoring episode starts.
        let mut slots = vec![0usize; self.stack];
        let mut cur = l;
        for c in (0..self.stack).rev() {
            slots[c] = st.phys(cur);
            let at_start = st.starts[st.phys(cur)];
            if cur > 0 && !at_start {
                cur -= 1;
            }
            // else: replicate this frame for all older channels.
        }
        for (c, &slot) in slots.iter().enumerate() {
            let plane = &st.frames[slot * self.frame_size..(slot + 1) * self.frame_size];
            for (i, &p) in plane.iter().enumerate() {
                out[i * self.stack + c] = p;
            }
        }
    }

    /// Sample a uniform minibatch into `batch` (buffers are resized) using
    /// the memory's internal [`IndexSampler`]. Returns an error until
    /// enough transitions are stored.
    pub fn sample(&mut self, batch_size: usize, batch: &mut TrainBatch) -> Result<()> {
        let picks = draw_indices(&mut self.sampler.rng, &self.streams, self.stack, batch_size)?;
        self.assemble(&picks, batch);
        Ok(())
    }

    /// Assemble the minibatch for `picks` into `batch` (buffers are
    /// resized). Read-only: frame reconstruction never touches the RNG, so
    /// this half runs under a shared borrow. `picks` must have been drawn
    /// against the current contents — slots invalidated by later pushes
    /// are a logic error upstream (the coordinator freezes replay between
    /// draw and assemble; see replay/prefetch.rs).
    pub fn assemble(&self, picks: &[SampleIndex], batch: &mut TrainBatch) {
        let batch_size = picks.len();
        let state_bytes = self.frame_size * self.stack;
        batch.states.resize(batch_size * state_bytes, 0);
        batch.next_states.resize(batch_size * state_bytes, 0);
        batch.actions.resize(batch_size, 0);
        batch.rewards.resize(batch_size, 0.0);
        batch.dones.resize(batch_size, 0.0);
        // Legacy 1-step path: the engine takes its historical 10-input
        // entry, so neither per-sample array may be present.
        batch.weights.clear();
        batch.boot_gammas.clear();

        for (b, pick) in picks.iter().enumerate() {
            let (stream, l) = (pick.stream, pick.slot);
            let st = &self.streams[stream];
            debug_assert!(l + 1 < st.len);
            let phys = st.phys(l);
            batch.actions[b] = st.actions[phys] as i32;
            batch.rewards[b] = st.rewards[phys];
            batch.dones[b] = if st.dones[phys] { 1.0 } else { 0.0 };
            let done = st.dones[phys];
            self.state_into(stream, l, &mut batch.states[b * state_bytes..(b + 1) * state_bytes]);
            if done {
                // Successor is masked by `done`; reuse s (in-distribution).
                batch.next_states[b * state_bytes..(b + 1) * state_bytes]
                    .copy_from_slice(&batch.states[b * state_bytes..(b + 1) * state_bytes]);
            } else {
                self.state_into(stream, l + 1, &mut batch.next_states[b * state_bytes..(b + 1) * state_bytes]);
            }
        }
    }

    /// [`ReplayMemory::assemble`] generalized to n-step returns
    /// (rust/DESIGN.md §11): for a pick at logical slot `l`, accumulate
    /// `R = Σ_{k<m} γᵏ·r_{l+k}` over `m = min(n, steps to the episode
    /// boundary or the stored-frontier)` transitions, bootstrap from the
    /// state ending at `l+m` scaled by `boot_gammas[b] = γᵐ`, and mask the
    /// bootstrap with `dones[b] = 1` when a terminal fell inside the
    /// window. `n = 1` reproduces [`ReplayMemory::assemble`]'s
    /// rewards/dones/states bit-for-bit (plus `boot_gammas = γ`, which the
    /// engine's per-sample-discount path multiplies in the same order the
    /// legacy path multiplied the scalar γ). Draws are shared with the
    /// 1-step path — only assembly widens — so the index distribution and
    /// RNG stream are untouched by the horizon.
    ///
    /// Truncation rules, in order, at each extension step k > 0:
    /// * a `start` flag at `l+k` (a new episode began) stops *before*
    ///   including that transition;
    /// * a transition without a stored successor (`l+k` is the stream's
    ///   newest slot) is included only if it is terminal — otherwise the
    ///   window ends at `m = k` and bootstraps from the frontier state;
    /// * a terminal (`done`) transition is included and closes the window
    ///   with the bootstrap masked.
    pub fn assemble_nstep(&self, picks: &[SampleIndex], n: usize, gamma: f32, batch: &mut TrainBatch) {
        let n = n.max(1);
        let batch_size = picks.len();
        let state_bytes = self.frame_size * self.stack;
        batch.states.resize(batch_size * state_bytes, 0);
        batch.next_states.resize(batch_size * state_bytes, 0);
        batch.actions.resize(batch_size, 0);
        batch.rewards.resize(batch_size, 0.0);
        batch.dones.resize(batch_size, 0.0);
        batch.boot_gammas.resize(batch_size, 0.0);

        for (b, pick) in picks.iter().enumerate() {
            let (stream, l) = (pick.stream, pick.slot);
            let st = &self.streams[stream];
            debug_assert!(l + 1 < st.len);
            batch.actions[b] = st.actions[st.phys(l)] as i32;

            let mut ret = 0.0f32;
            let mut disc = 1.0f32;
            let mut m = 0usize;
            let mut done = false;
            for k in 0..n {
                let slot = l + k;
                if k > 0 {
                    if slot >= st.len {
                        break;
                    }
                    let ph = st.phys(slot);
                    if st.starts[ph] {
                        break; // next episode began; never cross it
                    }
                    if !st.dones[ph] && slot + 1 >= st.len {
                        break; // no stored successor to bootstrap past
                    }
                }
                let ph = st.phys(slot);
                if k == 0 {
                    ret = st.rewards[ph];
                } else {
                    ret += disc * st.rewards[ph];
                }
                m = k + 1;
                if st.dones[ph] {
                    done = true;
                    break;
                }
                disc *= gamma;
            }
            debug_assert!(m >= 1);
            batch.rewards[b] = ret;
            batch.dones[b] = if done { 1.0 } else { 0.0 };
            let mut bg = gamma;
            for _ in 1..m {
                bg *= gamma;
            }
            batch.boot_gammas[b] = bg;
            self.state_into(stream, l, &mut batch.states[b * state_bytes..(b + 1) * state_bytes]);
            if done {
                // Bootstrap is masked; reuse s (in-distribution), exactly
                // like the 1-step path.
                batch.next_states[b * state_bytes..(b + 1) * state_bytes]
                    .copy_from_slice(&batch.states[b * state_bytes..(b + 1) * state_bytes]);
            } else {
                self.state_into(stream, l + m, &mut batch.next_states[b * state_bytes..(b + 1) * state_bytes]);
            }
        }
    }

    /// Reconstruct the state ending at the *most recent* slot of `stream`
    /// into a caller-owned buffer of `frame_size * stack` bytes — the
    /// allocation-free variant for callers polling a stream every round.
    /// Returns `false` (leaving `out` untouched) when the stream is empty.
    pub fn latest_state_into(&self, stream: usize, out: &mut [u8]) -> bool {
        let st = &self.streams[stream];
        if st.len < 1 {
            return false;
        }
        self.state_into(stream, st.len - 1, out);
        true
    }

    /// Reconstruct the state ending at the *most recent* slot of `stream`
    /// (testing / debugging; allocates — see [`Self::latest_state_into`]).
    pub fn latest_state(&self, stream: usize) -> Option<Vec<u8>> {
        let mut out = vec![0u8; self.frame_size * self.stack];
        if self.latest_state_into(stream, &mut out) {
            Some(out)
        } else {
            None
        }
    }

    pub fn pushes(&self) -> u64 {
        self.pushes
    }

    /// FNV-1a digest over every stream's logical contents (tests and the
    /// resume-smoke trajectory hash). Position-independent: two rings with
    /// the same logical transition history digest identically regardless of
    /// where the ring head physically sits.
    pub fn content_digest(&self) -> u64 {
        let mut w = crate::ckpt::ByteWriter::new();
        self.write_contents(&mut w);
        crate::ckpt::fnv1a(&w.into_bytes())
    }

    /// Serialize the logical (valid) contents of every stream, oldest to
    /// newest — not the physical ring layout. All reads go through logical
    /// indices, so re-basing the ring at restore time is behaviorally
    /// exact while keeping checkpoints proportional to *stored* frames.
    fn write_contents(&self, w: &mut crate::ckpt::ByteWriter) {
        w.put_usize(self.frame_size);
        w.put_usize(self.stack);
        w.put_usize(self.streams.len());
        for st in &self.streams {
            w.put_usize(st.cap);
            w.put_usize(st.len);
            w.put_u64((st.len * self.frame_size) as u64);
            for l in 0..st.len {
                let p = st.phys(l);
                // Raw frame bytes (the length prefix above covers them all).
                w.put_raw(&st.frames[p * self.frame_size..(p + 1) * self.frame_size]);
            }
            let order: Vec<usize> = (0..st.len).map(|l| st.phys(l)).collect();
            w.put_u64(st.len as u64);
            for &p in &order {
                w.put_u8(st.actions[p]);
            }
            let rewards: Vec<f32> = order.iter().map(|&p| st.rewards[p]).collect();
            w.put_f32_slice(&rewards);
            let dones: Vec<bool> = order.iter().map(|&p| st.dones[p]).collect();
            w.put_bool_slice(&dones);
            let starts: Vec<bool> = order.iter().map(|&p| st.starts[p]).collect();
            w.put_bool_slice(&starts);
        }
        w.put_u64(self.pushes);
    }

    /// Serialize the priority index in *logical* order (per-slot latent
    /// priority + generation, oldest to newest, plus the running max), so
    /// restoring into a re-based ring lands on the right physical leaves.
    /// Written as its own checkpoint section by the coordinator (only for
    /// proportional runs — uniform checkpoints are unchanged).
    pub fn save_priorities(&self, w: &mut crate::ckpt::ByteWriter) -> Result<()> {
        let Some(pi) = &self.priority else {
            bail!("replay has no priority index to checkpoint");
        };
        let per = self.per_cap();
        w.put_f64(pi.max_priority());
        w.put_usize(self.streams.len());
        for (si, st) in self.streams.iter().enumerate() {
            w.put_usize(st.len);
            for l in 0..st.len {
                let leaf = si * per + st.phys(l);
                w.put_f64(pi.latent(leaf));
                w.put_u64(pi.gen(leaf));
            }
        }
        Ok(())
    }

    /// Restore [`ReplayMemory::save_priorities`] bytes. Must run *after*
    /// the ring contents are restored: `Snapshot::load` already rebuilt
    /// the index's active flags against the re-based geometry (an index
    /// enabled here from scratch gets the same rebuild), so this overlay
    /// only has to land the latent priorities and generations on the
    /// right physical leaves — no second full tree rebuild.
    pub fn load_priorities(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> Result<()> {
        if self.priority.is_none() {
            self.enable_priorities();
        }
        let per = self.per_cap();
        let max_priority = r.f64()?;
        if !(max_priority.is_finite() && max_priority > 0.0) {
            bail!("checkpoint priority index has corrupt max priority {max_priority}");
        }
        let n_streams = r.usize()?;
        if n_streams != self.streams.len() {
            bail!(
                "checkpoint priority index covers {n_streams} streams, this run has {}",
                self.streams.len()
            );
        }
        // Collect first: the overlay below needs &mut self.priority while
        // reading stream geometry.
        let mut overlays = Vec::with_capacity(n_streams);
        for st in &self.streams {
            let len = r.usize()?;
            if len != st.len {
                bail!("checkpoint priority index has {len} slots for a stream holding {}", st.len);
            }
            let mut rows = Vec::with_capacity(len);
            for _ in 0..len {
                let latent = r.f64()?;
                if !(latent.is_finite() && latent > 0.0) {
                    bail!("checkpoint priority index has corrupt priority {latent}");
                }
                rows.push((latent, r.u64()?));
            }
            overlays.push(rows);
        }
        let leaves: Vec<(usize, f64, u64)> = overlays
            .iter()
            .enumerate()
            .flat_map(|(si, rows)| {
                let st = &self.streams[si];
                rows.iter()
                    .enumerate()
                    .map(move |(l, &(latent, gen))| (si * per + st.phys(l), latent, gen))
            })
            .collect();
        let pi = self.priority.as_mut().expect("enabled above");
        pi.set_max_priority(max_priority);
        for (leaf, latent, gen) in leaves {
            pi.set_restored(leaf, latent, gen);
        }
        Ok(())
    }
}

/// Checkpoint the replay memory: logical stream contents plus the internal
/// draw-stream RNG position. Restoring re-bases each ring at physical slot
/// 0 (`next = len % cap`), which is invisible to every consumer — sampling,
/// assembly, and future pushes all address slots logically.
impl crate::ckpt::Snapshot for ReplayMemory {
    fn kind(&self) -> &'static str {
        "replay"
    }

    fn save(&self, w: &mut crate::ckpt::ByteWriter) {
        self.write_contents(w);
        w.put_rng(self.sampler.rng_state());
    }

    fn load(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> Result<()> {
        let frame_size = r.usize()?;
        let stack = r.usize()?;
        let n_streams = r.usize()?;
        if frame_size != self.frame_size || stack != self.stack || n_streams != self.streams.len() {
            bail!(
                "checkpoint replay geometry (frame {frame_size}, stack {stack}, {n_streams} streams) \
                 does not match this run (frame {}, stack {}, {} streams)",
                self.frame_size, self.stack, self.streams.len()
            );
        }
        for st in &mut self.streams {
            let cap = r.usize()?;
            let len = r.usize()?;
            if cap != st.cap {
                bail!("checkpoint stream capacity {cap} != configured {}", st.cap);
            }
            if len > cap {
                bail!("checkpoint stream holds {len} slots, capacity is {cap}");
            }
            let frames = r.bytes()?;
            if frames.len() != len * self.frame_size {
                bail!("checkpoint stream frames truncated ({} bytes for {len} slots)", frames.len());
            }
            st.frames[..frames.len()].copy_from_slice(frames);
            let n_act = r.usize()?;
            if n_act != len {
                bail!("checkpoint stream has {n_act} actions for {len} slots");
            }
            for a in st.actions.iter_mut().take(len) {
                *a = r.u8()?;
            }
            let rewards = r.f32_vec()?;
            let dones = r.bool_vec()?;
            let starts = r.bool_vec()?;
            if rewards.len() != len || dones.len() != len || starts.len() != len {
                bail!("checkpoint stream scalar arrays do not match {len} slots");
            }
            st.rewards[..len].copy_from_slice(&rewards);
            st.dones[..len].copy_from_slice(&dones);
            st.starts[..len].copy_from_slice(&starts);
            st.len = len;
            st.next = len % cap;
        }
        self.pushes = r.u64()?;
        self.sampler = IndexSampler::from_rng_state(r.rng()?);
        // A priority-indexed memory must re-derive its active set from the
        // re-based geometry (a fresh index, so no stale leaves survive);
        // latent priorities/generations are overlaid afterwards by
        // `load_priorities` (proportional checkpoints).
        if self.priority.is_some() {
            self.enable_priorities();
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const FS: usize = 16; // tiny frames for tests
    const STACK: usize = 4;

    fn frame(v: u8) -> Vec<u8> {
        vec![v; FS]
    }

    fn mk(cap: usize, streams: usize) -> ReplayMemory {
        ReplayMemory::new(cap, streams, FS, STACK, 7).unwrap()
    }

    #[test]
    fn rejects_tiny_capacity() {
        assert!(ReplayMemory::new(4, 1, FS, STACK, 0).is_err());
        assert!(ReplayMemory::new(100, 0, FS, STACK, 0).is_err());
    }

    #[test]
    fn stacks_replicate_at_episode_start() {
        let mut r = mk(64, 1);
        r.push(0, &frame(10), 0, 0.0, false, true);
        r.push(0, &frame(20), 1, 0.0, false, false);
        let s = r.latest_state(0).unwrap();
        // Channels oldest..newest = [10, 10, 10, 20] replicated past start.
        assert_eq!(s[0 * STACK], 10);
        assert_eq!(s[1], 10);
        assert_eq!(s[2], 10);
        assert_eq!(s[3], 20);
    }

    #[test]
    fn stacks_are_consecutive_frames() {
        let mut r = mk(64, 1);
        for (i, v) in [1u8, 2, 3, 4, 5, 6].iter().enumerate() {
            r.push(0, &frame(*v), 0, 0.0, false, i == 0);
        }
        let s = r.latest_state(0).unwrap();
        assert_eq!([s[0], s[1], s[2], s[3]], [3, 4, 5, 6]);
    }

    #[test]
    fn latest_state_into_matches_allocating_variant() {
        let mut r = mk(64, 2);
        let mut buf = vec![0xAAu8; FS * STACK];
        // Empty stream: refused, buffer untouched.
        assert!(!r.latest_state_into(0, &mut buf));
        assert!(buf.iter().all(|&b| b == 0xAA));
        assert!(r.latest_state(0).is_none());
        for (i, v) in [1u8, 2, 3, 4, 5].iter().enumerate() {
            r.push(0, &frame(*v), 0, 0.0, false, i == 0);
            r.push(1, &frame(*v + 100), 0, 0.0, false, i == 0);
        }
        for stream in 0..2 {
            assert!(r.latest_state_into(stream, &mut buf));
            assert_eq!(buf, r.latest_state(stream).unwrap(), "stream {stream}");
        }
    }

    #[test]
    fn sample_masks_done_and_matches_chain() {
        let mut r = mk(64, 1);
        // Episode A: frames 1..=6, done at 6. Episode B: frames 11..=16.
        for v in 1u8..=6 {
            r.push(0, &frame(v), v, (v % 2) as f32, v == 6, v == 1);
        }
        for v in 11u8..=16 {
            r.push(0, &frame(v), v, 0.5, false, v == 11);
        }
        let mut batch = TrainBatch::default();
        r.sample(64, &mut batch).unwrap();
        let sb = FS * STACK;
        for b in 0..64 {
            let s = &batch.states[b * sb..(b + 1) * sb];
            let ns = &batch.next_states[b * sb..(b + 1) * sb];
            let newest = s[3];
            // Action/reward recorded at the newest frame's slot.
            assert_eq!(batch.actions[b] as u8, newest);
            if batch.dones[b] == 1.0 {
                assert_eq!(newest, 6);
                assert_eq!(ns, s, "done successor masked to s");
            } else if newest < 6 {
                // In-episode successor: next frame value is newest+1.
                assert_eq!(ns[3], newest + 1);
                // And channels shift by one.
                assert_eq!(&ns[..3], &s[1..4]);
            } else {
                assert!(newest >= 11 && newest < 16);
                assert_eq!(ns[3], newest + 1);
            }
            // No stack mixes the two episodes.
            let chans = [s[0], s[1], s[2], s[3]];
            assert!(chans.iter().all(|&c| c <= 6) || chans.iter().all(|&c| c >= 11),
                    "mixed episodes in stack: {chans:?}");
        }
    }

    #[test]
    fn ring_overwrites_oldest() {
        let mut r = mk(8, 1); // cap 8
        for v in 0..40u8 {
            r.push(0, &frame(v), v, 0.0, false, v == 0);
        }
        assert_eq!(r.len(), 8);
        let s = r.latest_state(0).unwrap();
        assert_eq!([s[0], s[1], s[2], s[3]], [36, 37, 38, 39]);
        // Sampling never touches overwritten frames.
        let mut batch = TrainBatch::default();
        r.sample(32, &mut batch).unwrap();
        for b in 0..32 {
            let newest = batch.states[b * FS * STACK + 3];
            assert!((32..39).contains(&newest), "newest {newest}");
        }
    }

    #[test]
    fn streams_never_mix() {
        let mut r = mk(128, 2);
        for v in 0..20u8 {
            r.push(0, &frame(v), 0, 0.0, false, v == 0);
            r.push(1, &frame(100 + v), 1, 0.0, false, v == 0);
        }
        let mut batch = TrainBatch::default();
        r.sample(64, &mut batch).unwrap();
        let sb = FS * STACK;
        for b in 0..64 {
            let s = &batch.states[b * sb..(b + 1) * sb];
            let chans = [s[0], s[1], s[2], s[3]];
            assert!(chans.iter().all(|&c| c < 100) || chans.iter().all(|&c| c >= 100),
                    "streams mixed: {chans:?}");
            // Action identifies the stream.
            let is_s1 = chans[0] >= 100;
            assert_eq!(batch.actions[b], is_s1 as i32);
        }
    }

    /// W×B regime: pushes interleaved round-robin across 8 streams (2
    /// threads × 4 envs), with per-stream episode lengths all different so
    /// episode boundaries land at different rounds per stream. Sampled
    /// stacks must stay per-stream, per-episode, and correctly chained.
    #[test]
    fn multi_stream_interleaved_episode_boundaries() {
        const STREAMS: usize = 8;
        let mut r = mk(64 * STREAMS, STREAMS);
        // Stream s emits frames (s*30 + k) and ends an episode every s+2
        // pushes; pushed round-robin like W×B samplers do.
        let mut counts = [0usize; STREAMS];
        let mut starts = [true; STREAMS];
        for _round in 0..24 {
            for s in 0..STREAMS {
                let v = (s * 30 + counts[s]) as u8;
                let done = (counts[s] + 1) % (s + 2) == 0;
                r.push(s, &frame(v), s as u8, 0.0, done, starts[s]);
                starts[s] = done; // next push begins a new episode
                counts[s] += 1;
            }
        }
        let mut batch = TrainBatch::default();
        r.sample(256, &mut batch).unwrap();
        let sb = FS * STACK;
        for b in 0..256 {
            let st = &batch.states[b * sb..(b + 1) * sb];
            let chans = [st[0], st[1], st[2], st[3]];
            // The action identifies the stream; every channel must come
            // from that stream's 30-value band.
            let s = batch.actions[b] as usize;
            let lo = (s * 30) as u8;
            let hi = lo + 24;
            assert!(
                chans.iter().all(|&ch| ch >= lo && ch < hi),
                "stream {s}: foreign frames in stack {chans:?}"
            );
            // Within the stream, channels are the k, k+1 ... chain with the
            // episode's start frame replicated on the left.
            let ep_len = s + 2;
            for c in 0..STACK - 1 {
                let cur = (chans[c + 1] - lo) as usize;
                let prev = (chans[c] - lo) as usize;
                let ep_start = (cur / ep_len) * ep_len;
                let expect = if cur == ep_start { cur } else { cur - 1 };
                assert_eq!(
                    prev, expect,
                    "stream {s}: chain break at chan {c} in {chans:?} (ep_len {ep_len})"
                );
                // Never reach across the episode boundary.
                assert!(prev >= ep_start, "stream {s}: stack crosses episode start");
            }
            // Done-masked successors: done rows replicate s as s'.
            let ns = &batch.next_states[b * sb..(b + 1) * sb];
            let cur = (chans[3] - lo) as usize;
            if batch.dones[b] == 1.0 {
                assert_eq!((cur + 1) % ep_len, 0, "done flag must sit on episode ends");
                assert_eq!(ns, st, "done successor must be masked to s");
            } else {
                assert_eq!(ns[3], chans[3] + 1, "in-episode successor chains forward");
                assert_eq!(&ns[..3], &st[1..4], "successor channels shift by one");
            }
        }
    }

    /// After a reset, the first pushed frame of the new episode must be
    /// replicated across all older channels — exactly what AtariEnv::reset
    /// does to its own history buffer.
    #[test]
    fn start_frame_replication_after_reset() {
        let mut r = mk(64, 1);
        // Episode A: 3 frames, ends done. Episode B begins with frame 50.
        r.push(0, &frame(1), 0, 0.0, false, true);
        r.push(0, &frame(2), 0, 0.0, false, false);
        r.push(0, &frame(3), 0, 1.0, true, false);
        r.push(0, &frame(50), 0, 0.0, false, true); // reset boundary
        let s = r.latest_state(0).unwrap();
        assert_eq!([s[0], s[1], s[2], s[3]], [50, 50, 50, 50], "fresh episode replicates start");
        r.push(0, &frame(51), 0, 0.0, false, false);
        let s = r.latest_state(0).unwrap();
        assert_eq!([s[0], s[1], s[2], s[3]], [50, 50, 50, 51]);
        r.push(0, &frame(52), 0, 0.0, false, false);
        r.push(0, &frame(53), 0, 0.0, false, false);
        r.push(0, &frame(54), 0, 0.0, false, false);
        let s = r.latest_state(0).unwrap();
        assert_eq!([s[0], s[1], s[2], s[3]], [51, 52, 53, 54], "replication ends past start");
    }

    /// Stream counts in the W×B range must partition capacity and keep
    /// sampling uniform over all streams' transitions.
    #[test]
    fn wxb_stream_counts_partition_capacity() {
        for streams in [1usize, 2, 4, 8, 16] {
            let r = mk(32 * streams, streams);
            assert_eq!(r.n_streams(), streams);
            assert_eq!(r.capacity(), 32 * streams);
        }
        // Too many streams for the capacity must be rejected, not UB.
        assert!(ReplayMemory::new(64, 16, FS, STACK, 0).is_err());
    }

    /// The RNG/assembly split must be byte-for-byte equivalent to the
    /// historical single-call `sample`: an external `IndexSampler` built
    /// from the same seed draws the same indices, and `assemble` (read-only)
    /// produces the same batch.
    #[test]
    fn split_draw_assemble_matches_sample() {
        let fill = |r: &mut ReplayMemory| {
            for v in 0..40u8 {
                r.push(0, &frame(v), v, v as f32 * 0.25, v % 9 == 8, v == 0 || v % 9 == 0);
                r.push(1, &frame(100 + v), v, 0.0, v % 7 == 6, v == 0 || v % 7 == 0);
            }
        };
        let mut a = mk(256, 2);
        let mut b = mk(256, 2);
        fill(&mut a);
        fill(&mut b);
        let mut sampler = IndexSampler::new(7); // same seed as mk()
        for _ in 0..5 {
            let mut batch_a = TrainBatch::default();
            a.sample(16, &mut batch_a).unwrap();
            let picks = sampler.draw(&b, 16).unwrap();
            let mut batch_b = TrainBatch::default();
            b.assemble(&picks, &mut batch_b);
            assert_eq!(batch_a.states, batch_b.states);
            assert_eq!(batch_a.next_states, batch_b.next_states);
            assert_eq!(batch_a.actions, batch_b.actions);
            assert_eq!(batch_a.rewards, batch_b.rewards);
            assert_eq!(batch_a.dones, batch_b.dones);
        }
    }

    /// Snapshot round trip: a wrapped ring serialized logically and
    /// restored into a fresh memory must sample identically (same draws,
    /// same assembled batches) and accept further pushes identically —
    /// even though the restored ring is physically re-based at slot 0.
    #[test]
    fn snapshot_roundtrip_is_behaviorally_exact() {
        use crate::ckpt::{ByteReader, ByteWriter, Snapshot};
        let mut a = mk(8 * 2, 2); // tiny caps so both streams wrap
        for v in 0..23u8 {
            a.push(0, &frame(v), v, v as f32 * 0.5, v % 7 == 6, v == 0 || v % 7 == 0);
            a.push(1, &frame(100 + v), v, 0.25, v % 5 == 4, v == 0 || v % 5 == 0);
        }
        // Advance the internal draw stream so its position is non-trivial.
        let mut scratch = TrainBatch::default();
        a.sample(8, &mut scratch).unwrap();

        let mut w = ByteWriter::new();
        a.save(&mut w);
        let bytes = w.into_bytes();
        let mut b = mk(8 * 2, 2);
        let mut r = ByteReader::new(&bytes);
        b.load(&mut r).unwrap();
        r.finish().unwrap();

        assert_eq!(a.len(), b.len());
        assert_eq!(a.pushes(), b.pushes());
        assert_eq!(a.content_digest(), b.content_digest(), "logical contents differ");
        assert_eq!(a.latest_state(0), b.latest_state(0));
        assert_eq!(a.latest_state(1), b.latest_state(1));

        // Same future: pushes keep wrapping, draws keep matching.
        for v in 23..40u8 {
            a.push(0, &frame(v), v, 0.0, false, false);
            b.push(0, &frame(v), v, 0.0, false, false);
        }
        for _ in 0..4 {
            let (mut ba, mut bb) = (TrainBatch::default(), TrainBatch::default());
            a.sample(8, &mut ba).unwrap();
            b.sample(8, &mut bb).unwrap();
            assert_eq!(ba.states, bb.states);
            assert_eq!(ba.actions, bb.actions);
            assert_eq!(ba.rewards, bb.rewards);
            assert_eq!(ba.dones, bb.dones);
        }

        // Geometry mismatches are refused.
        let mut wrong = ReplayMemory::new(8 * 3, 3, FS, STACK, 7).unwrap();
        let mut r = ByteReader::new(&bytes);
        assert!(wrong.load(&mut r).is_err(), "stream-count mismatch must fail");
    }

    /// n = 1 through the n-step assembler reproduces `assemble` exactly
    /// (same rewards/dones/states bitwise) plus `boot_gammas = γ`.
    #[test]
    fn nstep_one_matches_assemble_bitwise() {
        let mut r = mk(256, 2);
        for v in 0..50u8 {
            r.push(0, &frame(v), v, v as f32 * 0.25 - 3.0, v % 9 == 8, v == 0 || v % 9 == 0);
            r.push(1, &frame(100 + v), v, 0.5, v % 7 == 6, v == 0 || v % 7 == 0);
        }
        let mut sampler = IndexSampler::new(7);
        let picks = sampler.draw(&r, 64).unwrap();
        let mut one = TrainBatch::default();
        r.assemble(&picks, &mut one);
        let mut n1 = TrainBatch::default();
        r.assemble_nstep(&picks, 1, 0.99, &mut n1);
        assert_eq!(one.states, n1.states);
        assert_eq!(one.next_states, n1.next_states);
        assert_eq!(one.actions, n1.actions);
        assert_eq!(
            one.rewards.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            n1.rewards.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(one.dones, n1.dones);
        assert!(one.boot_gammas.is_empty());
        assert!(n1.boot_gammas.iter().all(|&g| g.to_bits() == 0.99f32.to_bits()));
    }

    /// Mid-episode n-step windows chain rewards with γ discounting and
    /// bootstrap from the state n steps ahead.
    #[test]
    fn nstep_accumulates_discounted_rewards() {
        let mut r = mk(64, 1);
        // One long episode: frame v, reward v.
        for v in 0..20u8 {
            r.push(0, &frame(v), v, v as f32, false, v == 0);
        }
        let gamma = 0.5f32;
        let pick = [SampleIndex { stream: 0, slot: 5 }];
        let mut b = TrainBatch::default();
        r.assemble_nstep(&pick, 3, gamma, &mut b);
        // R = r5 + γ r6 + γ² r7 = 5 + 3 + 1.75.
        assert_eq!(b.rewards[0], 5.0 + 0.5 * 6.0 + 0.25 * 7.0);
        assert_eq!(b.dones[0], 0.0);
        assert_eq!(b.boot_gammas[0], 0.125, "γ³ scales the bootstrap");
        // Successor is the state ending at slot 8.
        assert_eq!(b.next_states[3], 8, "newest channel of s' is frame 8");
        assert_eq!(b.states[3], 5);
    }

    /// Episode terminal inside the window truncates the return, masks the
    /// bootstrap, and never crosses into the next episode.
    #[test]
    fn nstep_truncates_at_episode_terminal() {
        let mut r = mk(64, 1);
        // Episode A: frames 0..=6, done at 6 (reward 10). Episode B after.
        for v in 0..=6u8 {
            r.push(0, &frame(v), v, if v == 6 { 10.0 } else { 1.0 }, v == 6, v == 0);
        }
        for v in 50..=58u8 {
            r.push(0, &frame(v), v, 7.0, false, v == 50);
        }
        let gamma = 0.5f32;
        // Window starting at 5: r5 + γ·r6(terminal), done, m = 2.
        let mut b = TrainBatch::default();
        r.assemble_nstep(&[SampleIndex { stream: 0, slot: 5 }], 4, gamma, &mut b);
        assert_eq!(b.rewards[0], 1.0 + 0.5 * 10.0);
        assert_eq!(b.dones[0], 1.0, "terminal inside the window masks the bootstrap");
        assert_eq!(b.boot_gammas[0], 0.25, "γ² even though masked (well-formed)");
        let sb = FS * STACK;
        assert_eq!(&b.next_states[..sb], &b.states[..sb], "masked successor = s");
        // Window starting at 4 with n far beyond the episode end: same
        // truncation (n > episode remainder).
        let mut b7 = TrainBatch::default();
        r.assemble_nstep(&[SampleIndex { stream: 0, slot: 4 }], 32, gamma, &mut b7);
        assert_eq!(b7.rewards[0], 1.0 + 0.5 * 1.0 + 0.25 * 10.0);
        assert_eq!(b7.dones[0], 1.0);
        // No frame of episode B leaks into either state.
        for px in 0..sb {
            assert!(b7.states[px] <= 6 && b7.next_states[px] <= 6);
        }
    }

    /// The stored frontier (newest slot has no successor) truncates a
    /// non-terminal window: bootstrap from the last reachable state.
    #[test]
    fn nstep_truncates_at_stored_frontier() {
        let mut r = mk(64, 1);
        for v in 0..8u8 {
            r.push(0, &frame(v), v, 1.0, false, v == 0);
        }
        // Sampleable slots are [3, 6]; slot 6's transition is the last
        // one with a stored successor (slot 7 has none).
        let gamma = 0.5f32;
        let mut b = TrainBatch::default();
        r.assemble_nstep(&[SampleIndex { stream: 0, slot: 6 }], 5, gamma, &mut b);
        // Only r6 fits (slot 7 has no successor and is not terminal).
        assert_eq!(b.rewards[0], 1.0);
        assert_eq!(b.dones[0], 0.0);
        assert_eq!(b.boot_gammas[0], 0.5, "m = 1");
        assert_eq!(b.next_states[3], 7);
        // One step back: r6 then r7 is excluded the same way -> m = 2.
        let mut b5 = TrainBatch::default();
        r.assemble_nstep(&[SampleIndex { stream: 0, slot: 5 }], 5, gamma, &mut b5);
        assert_eq!(b5.rewards[0], 1.0 + 0.5);
        assert_eq!(b5.boot_gammas[0], 0.25, "m = 2");
        assert_eq!(b5.next_states[3], 7);
    }

    /// n-step windows stay correct across the physical ring seam.
    #[test]
    fn nstep_handles_ring_wraparound() {
        let mut r = mk(8, 1); // cap 8: plenty of wrapping
        for v in 0..30u8 {
            r.push(0, &frame(v), v, v as f32, false, v == 0);
        }
        // Stored frames are 22..=29; logical slot l holds frame 22+l.
        let gamma = 0.5f32;
        let mut b = TrainBatch::default();
        r.assemble_nstep(&[SampleIndex { stream: 0, slot: 3 }], 3, gamma, &mut b);
        let (r0, r1, r2) = (25.0f32, 26.0, 27.0);
        assert_eq!(b.rewards[0], r0 + 0.5 * r1 + 0.25 * r2);
        assert_eq!(b.states[3], 25);
        assert_eq!(b.next_states[3], 28);
        assert_eq!(b.boot_gammas[0], 0.125);
    }

    /// The priority index tracks the sampleable set exactly under pushes,
    /// episode boundaries, and ring wraparound — and the snapshot round
    /// trip (logical re-basing included) preserves it.
    #[test]
    fn priority_index_tracks_sampleable_set() {
        use crate::ckpt::{ByteReader, ByteWriter};
        let mut r = mk(8 * 2, 2);
        r.enable_priorities();
        for v in 0..40u8 {
            r.push(0, &frame(v), v, 1.0, v % 5 == 4, v == 0 || v % 5 == 0);
            assert_eq!(r.priorities().unwrap().active_count(), r.sampleable());
            if v % 3 == 0 {
                r.push(1, &frame(v), v, 0.0, false, v == 0);
                assert_eq!(r.priorities().unwrap().active_count(), r.sampleable());
            }
        }
        // Every active leaf maps back to a valid pick; inactive leaves
        // return None.
        let pi = r.priorities().unwrap();
        let mut active_leaves = 0;
        for leaf in 0..r.capacity() {
            if pi.value(leaf) > 0.0 {
                active_leaves += 1;
                let idx = r.leaf_to_index(leaf).expect("active leaf must map to a pick");
                assert!(idx.slot + 1 >= STACK && idx.slot + 1 < 8);
            }
        }
        assert_eq!(active_leaves, r.sampleable());

        // Priority snapshot round trip through a re-based restore.
        let mut w = ByteWriter::new();
        crate::ckpt::Snapshot::save(&r, &mut w);
        let bytes = w.into_bytes();
        let mut pw = ByteWriter::new();
        r.save_priorities(&mut pw).unwrap();
        let pbytes = pw.into_bytes();

        let mut b = mk(8 * 2, 2);
        b.enable_priorities();
        let mut rd = ByteReader::new(&bytes);
        crate::ckpt::Snapshot::load(&mut b, &mut rd).unwrap();
        let mut prd = ByteReader::new(&pbytes);
        b.load_priorities(&mut prd).unwrap();
        prd.finish().unwrap();
        assert_eq!(b.priorities().unwrap().active_count(), r.priorities().unwrap().active_count());
        assert_eq!(b.priorities().unwrap().total(), r.priorities().unwrap().total());
        // Logical leaves carry identical latent/gen state: re-serialize.
        let mut pw2 = ByteWriter::new();
        b.save_priorities(&mut pw2).unwrap();
        assert_eq!(pbytes, pw2.into_bytes(), "priority snapshot not re-base invariant");
    }

    #[test]
    fn sample_before_ready_errors() {
        let mut r = mk(64, 1);
        let mut batch = TrainBatch::default();
        assert!(r.sample(4, &mut batch).is_err());
        for v in 0..3u8 {
            r.push(0, &frame(v), 0, 0.0, false, v == 0);
        }
        assert!(r.sample(4, &mut batch).is_err(), "needs stack+1 slots");
        for v in 3..8u8 {
            r.push(0, &frame(v), 0, 0.0, false, false);
        }
        assert!(r.sample(4, &mut batch).is_ok());
    }
}
