//! Per-stream staging buffers for Concurrent Training.
//!
//! Paper §3: "To avoid a race condition between the threads, we temporarily
//! buffer the experiences collected by the sampler thread and transfer them
//! to the replay memory D only when the threads are synchronized. This
//! ensures that D does not change during training, which would produce
//! non-deterministic results."
//!
//! One `StagingBuffer` per environment stream (W×B of them), bound to the
//! stream's replay slot. [`StagingSet`] owns all of them behind per-stream
//! mutexes so both execution drivers share one flush path: samplers push to
//! their own streams contention-free, and the main thread flushes every
//! buffer at the target-sync barrier.

use std::sync::Mutex;

use super::ring::ReplayMemory;

/// One buffered transition (frame + scalars), pending flush.
#[derive(Clone, Debug)]
pub struct StagedTransition {
    pub frame: Vec<u8>,
    pub action: u8,
    pub reward: f32,
    pub done: bool,
    pub start: bool,
}

#[derive(Default)]
pub struct StagingBuffer {
    items: Vec<StagedTransition>,
    /// Total transitions ever staged through this buffer.
    staged_total: u64,
}

impl StagingBuffer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, frame: &[u8], action: u8, reward: f32, done: bool, start: bool) {
        self.items.push(StagedTransition {
            frame: frame.to_vec(),
            action,
            reward,
            done,
            start,
        });
        self.staged_total += 1;
    }

    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }

    pub fn staged_total(&self) -> u64 {
        self.staged_total
    }

    /// Move every buffered transition into replay `stream`, preserving
    /// order (the stream's frame chain stays contiguous).
    pub fn flush_into(&mut self, replay: &mut ReplayMemory, stream: usize) {
        for t in self.items.drain(..) {
            replay.push(stream, &t.frame, t.action, t.reward, t.done, t.start);
        }
    }
}

/// All staging buffers of one run: buffer `i` feeds replay stream `i`.
pub struct StagingSet {
    bufs: Vec<Mutex<StagingBuffer>>,
}

impl StagingSet {
    pub fn new(n_streams: usize) -> StagingSet {
        StagingSet { bufs: (0..n_streams).map(|_| Mutex::new(StagingBuffer::new())).collect() }
    }

    pub fn n_streams(&self) -> usize {
        self.bufs.len()
    }

    /// Stage one transition for `stream` (called by that stream's sampler).
    pub fn push(&self, stream: usize, frame: &[u8], action: u8, reward: f32, done: bool, start: bool) {
        self.bufs[stream].lock().unwrap().push(frame, action, reward, done, start);
    }

    /// Move every buffered transition into its replay stream, in stream
    /// order (the synchronization-point flush).
    pub fn flush_into(&self, replay: &mut ReplayMemory) {
        for (stream, buf) in self.bufs.iter().enumerate() {
            buf.lock().unwrap().flush_into(replay, stream);
        }
    }

    /// Buffered transitions across all streams (testing / diagnostics).
    pub fn pending(&self) -> usize {
        self.bufs.iter().map(|b| b.lock().unwrap().len()).sum()
    }

    /// Take every buffered transition out, keyed by stream id in stream
    /// order, skipping empty streams — the fleet sampler's window upload.
    /// `staged_total` keeps counting: the transitions were staged here,
    /// they just flush into replay on the learner instead.
    pub fn drain_streams(&self) -> Vec<(usize, Vec<StagedTransition>)> {
        let mut out = Vec::new();
        for (stream, buf) in self.bufs.iter().enumerate() {
            let mut buf = buf.lock().unwrap();
            if !buf.items.is_empty() {
                out.push((stream, std::mem::take(&mut buf.items)));
            }
        }
        out
    }

    /// Push a drained batch back in (the learner's ingest side: uploads
    /// land here so the one shared sync-point flush path moves them into
    /// replay in stream order).
    pub fn extend(&self, stream: usize, items: Vec<StagedTransition>) {
        let mut buf = self.bufs[stream].lock().unwrap();
        buf.staged_total += items.len() as u64;
        buf.items.extend(items);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn staging_set_routes_streams_and_flushes_all() {
        let mut replay = ReplayMemory::new(128, 2, 4, 4, 0).unwrap();
        let set = StagingSet::new(2);
        for v in 0..6u8 {
            set.push(0, &[v; 4], 0, 0.0, false, v == 0);
            set.push(1, &[100 + v; 4], 1, 0.0, false, v == 0);
        }
        assert_eq!(set.pending(), 12);
        assert_eq!(replay.len(), 0, "staging must not touch replay");
        set.flush_into(&mut replay);
        assert_eq!(set.pending(), 0);
        assert_eq!(replay.len(), 12);
        // Stream identity preserved: newest frames differ per stream.
        assert_eq!(replay.latest_state(0).unwrap()[3], 5);
        assert_eq!(replay.latest_state(1).unwrap()[3], 105);
    }

    #[test]
    fn flush_preserves_order_and_empties() {
        let mut replay = ReplayMemory::new(64, 1, 4, 4, 0).unwrap();
        let mut staging = StagingBuffer::new();
        for v in 0..10u8 {
            staging.push(&[v; 4], v, v as f32, false, v == 0);
        }
        assert_eq!(staging.len(), 10);
        assert_eq!(replay.len(), 0);
        staging.flush_into(&mut replay, 0);
        assert!(staging.is_empty());
        assert_eq!(replay.len(), 10);
        assert_eq!(staging.staged_total(), 10);
        let s = replay.latest_state(0).unwrap();
        assert_eq!(s[3], 9, "newest channel (pixel 0) holds last staged frame");
    }

    #[test]
    fn replay_unchanged_until_flush() {
        let mut replay = ReplayMemory::new(64, 1, 4, 4, 0).unwrap();
        for v in 0..8u8 {
            replay.push(0, &[v; 4], 0, 0.0, false, v == 0);
        }
        let before = replay.pushes();
        let mut staging = StagingBuffer::new();
        staging.push(&[99; 4], 1, 1.0, false, false);
        assert_eq!(replay.pushes(), before, "staging must not touch replay");
        staging.flush_into(&mut replay, 0);
        assert_eq!(replay.pushes(), before + 1);
    }
}
