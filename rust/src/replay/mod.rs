//! Replay memory substrate.
//!
//! Memory-efficient DQN replay: stores single 84x84 uint8 frames (not
//! stacked states) and reconstructs 4-frame stacks at sample time, like the
//! original DQN's 1M-frame buffer. Multiple environment streams feed one
//! globally-shared memory; frame chaining is kept per stream so stacks
//! never mix frames from different simulators, while *sampling* is uniform
//! over all transitions in all streams (the paper's "globally shared replay
//! memory ... fully deterministic order" — unlike Stooke & Abbeel's
//! statically partitioned workers, a sample here may come from any stream).
//!
//! `staging` holds the per-thread temporary buffers Concurrent Training
//! uses so the replay contents never change during a training window
//! (paper §3: flush only when the threads are synchronized).
//!
//! `prefetch` is the trainer-facing batch pipeline: index sampling (RNG,
//! `&mut`) is split from frame assembly (read-only, `&self`) so a
//! quota-gated worker can double-buffer minibatches ahead of the learner
//! without changing the training trajectory by a single bit.
//!
//! `strategy` is the pluggable draw half (rust/DESIGN.md §11): uniform
//! (the seed machine, bit-exact) or proportional prioritized replay over
//! a deterministic sum-tree, with n-step return assembly in `ring`.

pub mod prefetch;
pub mod ring;
pub mod staging;
pub mod strategy;

pub use prefetch::{BatchSource, DirectSource, PrefetchPipeline, TrainerSource};
pub use ring::{IndexSampler, ReplayMemory, SampleIndex};
pub use staging::{StagedTransition, StagingBuffer, StagingSet};
pub use strategy::{build_strategy, PriorityIndex, SamplingStrategy, StrategyPlan, SumTree, Uniform};
