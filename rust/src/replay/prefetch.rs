//! Double-buffered replay prefetch: overlap minibatch assembly with the
//! learner's compute.
//!
//! The trainer's critical path used to be `sample → assemble → train_step`,
//! serialized. After the RNG/assembly split in `replay/ring.rs`, assembly
//! is read-only, so a background worker can build batch t+1 while the
//! compute pool grinds through step t. The trainer consumes batches through
//! the [`BatchSource`] trait and never touches the replay lock itself.
//!
//! **Determinism** (the whole point — paper §3 demands bit-reproducible
//! training): the pipeline must never assemble a batch against replay
//! contents the serial path would not have seen. Replay only changes at
//! synchronization points (staging flushes between C-step windows), so the
//! worker is *quota-gated*: [`BatchSource::grant`] is called next to every
//! window dispatch — after the flush — and the worker only assembles up to
//! the granted total. At a window barrier the trainer has consumed exactly
//! the granted batches, the worker is provably idle, and the flush cannot
//! race or reorder any draw. The draw sequence itself is a single
//! [`SamplingStrategy`] advancing one RNG in consumption order, so
//! prefetch on/off yields the identical trajectory (pinned in
//! `tests/parallel_learner.rs` and `tests/strategy_equivalence.rs`).
//!
//! [`DirectSource`] is the `prefetch_batches = 0` path (and the path of
//! the non-windowed modes, whose training interleaves with replay writes):
//! draw + assemble inline under the read lock, exactly the historical
//! behavior.

use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, RwLock};
use std::time::Duration;

use anyhow::{bail, Result};

use crate::runtime::TrainBatch;

use super::ring::ReplayMemory;
use super::strategy::{SamplingStrategy, Uniform};

/// Where the trainer gets its minibatches.
///
/// `next_batch` fills `out` and returns `Ok(true)`, or `Ok(false)` when the
/// run is stopping and no further batch will arrive (a clean shutdown, not
/// an error). `grant` raises the number of batches a pipelined source may
/// assemble ahead; the direct source ignores it. `record_td` hands one
/// trained batch's TD errors back to the sampling strategy (priority
/// updates; a no-op for uniform), and `barrier_update` applies queued
/// priority updates — windowed drivers call it at the window barrier,
/// right after the staging flush and before the next grant
/// (rust/DESIGN.md §11).
pub trait BatchSource: Sync {
    fn next_batch(&self, out: &mut TrainBatch, should_stop: &dyn Fn() -> bool) -> Result<bool>;

    fn grant(&self, _n: u64) {}

    fn record_td(&self, _td: &[f32]) {}

    fn barrier_update(&self) {}
}

/// Inline sampling: draw under the strategy mutex, assemble under the
/// replay read lock. With the uniform strategy this is byte-for-byte the
/// historical `ReplayMemory::sample` behavior (same RNG stream, same call
/// sequence).
pub struct DirectSource<'a> {
    replay: &'a RwLock<ReplayMemory>,
    strategy: Mutex<Box<dyn SamplingStrategy>>,
    minibatch: usize,
    /// Apply priority updates immediately after each `record_td` (the
    /// non-windowed modes, whose sequential train/push interleaving makes
    /// that order deterministic). Windowed runs leave them queued for
    /// `barrier_update`, so prefetch on/off stays trajectory-identical.
    immediate: bool,
}

impl<'a> DirectSource<'a> {
    /// Uniform 1-step source (the historical constructor; benches/tests).
    pub fn new(replay: &'a RwLock<ReplayMemory>, seed: u64, minibatch: usize) -> DirectSource<'a> {
        Self::with_strategy(replay, Box::new(Uniform::new(seed, 1, 1.0)), minibatch, true)
    }

    /// Resume the configured strategy mid-run (segment continuation).
    pub fn with_strategy(
        replay: &'a RwLock<ReplayMemory>,
        strategy: Box<dyn SamplingStrategy>,
        minibatch: usize,
        immediate: bool,
    ) -> DirectSource<'a> {
        DirectSource { replay, strategy: Mutex::new(strategy), minibatch, immediate }
    }

    /// Draw-stream RNG position (checkpointing; call only when quiesced).
    pub fn sampler_state(&self) -> [u64; 4] {
        self.strategy.lock().unwrap().rng_state()
    }
}

impl BatchSource for DirectSource<'_> {
    fn next_batch(&self, out: &mut TrainBatch, _should_stop: &dyn Fn() -> bool) -> Result<bool> {
        let mut strategy = self.strategy.lock().unwrap();
        let replay = self.replay.read().unwrap();
        strategy.fill_batch(&replay, self.minibatch, out)?;
        Ok(true)
    }

    fn record_td(&self, td: &[f32]) {
        // Lock order everywhere: strategy, then replay — fill_batch takes
        // the read half, updates the write half.
        let mut strategy = self.strategy.lock().unwrap();
        strategy.record_td(td);
        if self.immediate && strategy.has_pending() {
            let mut replay = self.replay.write().unwrap();
            strategy.apply_updates(&mut replay);
        }
    }

    fn barrier_update(&self) {
        let mut strategy = self.strategy.lock().unwrap();
        if strategy.has_pending() {
            let mut replay = self.replay.write().unwrap();
            strategy.apply_updates(&mut replay);
        }
    }
}

struct Buffers {
    filled: VecDeque<TrainBatch>,
    free: Vec<TrainBatch>,
}

/// The double-buffered (depth-`prefetch_batches`) pipeline. One worker
/// thread assembles ahead; the trainer swaps finished batches out in O(1).
pub struct PrefetchPipeline<'a> {
    replay: &'a RwLock<ReplayMemory>,
    minibatch: usize,
    strategy: Mutex<Box<dyn SamplingStrategy>>,
    /// Total batches the coordinator has authorized (monotone).
    granted: AtomicU64,
    /// Batches fully assembled by the worker (monotone).
    produced: AtomicU64,
    state: Mutex<Buffers>,
    cv: Condvar,
    error: Mutex<Option<String>>,
}

impl<'a> PrefetchPipeline<'a> {
    /// `depth` >= 1 batches may sit assembled-but-unconsumed (1 = classic
    /// double buffering: one in flight, one being built). Uniform 1-step
    /// (the historical constructor; tests).
    pub fn new(
        replay: &'a RwLock<ReplayMemory>,
        seed: u64,
        minibatch: usize,
        depth: usize,
    ) -> PrefetchPipeline<'a> {
        Self::with_strategy(replay, Box::new(Uniform::new(seed, 1, 1.0)), minibatch, depth)
    }

    /// Resume the configured strategy mid-run (segment continuation).
    pub fn with_strategy(
        replay: &'a RwLock<ReplayMemory>,
        strategy: Box<dyn SamplingStrategy>,
        minibatch: usize,
        depth: usize,
    ) -> PrefetchPipeline<'a> {
        let depth = depth.max(1);
        PrefetchPipeline {
            replay,
            minibatch,
            strategy: Mutex::new(strategy),
            granted: AtomicU64::new(0),
            produced: AtomicU64::new(0),
            state: Mutex::new(Buffers {
                filled: VecDeque::with_capacity(depth),
                free: (0..depth).map(|_| TrainBatch::default()).collect(),
            }),
            cv: Condvar::new(),
            error: Mutex::new(None),
        }
    }

    /// Batches assembled so far (tests / diagnostics).
    pub fn produced(&self) -> u64 {
        self.produced.load(Ordering::SeqCst)
    }

    /// Draw-stream RNG position. Only meaningful when the pipeline is
    /// quiesced (every granted batch consumed, worker parked) — i.e. at a
    /// window barrier.
    pub fn sampler_state(&self) -> [u64; 4] {
        self.strategy.lock().unwrap().rng_state()
    }

    /// The worker body: assemble granted batches ahead of the trainer.
    /// Spawn exactly one per pipeline; returns when `should_stop`.
    pub fn worker_loop(&self, should_stop: &dyn Fn() -> bool) {
        loop {
            if should_stop() {
                return;
            }
            if self.produced.load(Ordering::SeqCst) >= self.granted.load(Ordering::SeqCst) {
                // No quota: the window barrier is (or will be) flushing
                // replay. Parking here is what keeps draws deterministic.
                self.park();
                continue;
            }
            let Some(mut buf) = self.state.lock().unwrap().free.pop() else {
                self.park();
                continue;
            };
            let result = {
                let mut strategy = self.strategy.lock().unwrap();
                let replay = self.replay.read().unwrap();
                strategy.fill_batch(&replay, self.minibatch, &mut buf)
            };
            match result {
                Ok(()) => {
                    self.state.lock().unwrap().filled.push_back(buf);
                    self.produced.fetch_add(1, Ordering::SeqCst);
                    self.cv.notify_all();
                }
                Err(e) => {
                    *self.error.lock().unwrap() = Some(e.to_string());
                    self.cv.notify_all();
                    return;
                }
            }
        }
    }

    fn park(&self) {
        let g = self.state.lock().unwrap();
        let _ = self.cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
    }
}

impl BatchSource for PrefetchPipeline<'_> {
    fn next_batch(&self, out: &mut TrainBatch, should_stop: &dyn Fn() -> bool) -> Result<bool> {
        loop {
            {
                let mut st = self.state.lock().unwrap();
                if let Some(mut b) = st.filled.pop_front() {
                    std::mem::swap(out, &mut b);
                    st.free.push(b);
                    drop(st);
                    self.cv.notify_all();
                    return Ok(true);
                }
            }
            if let Some(e) = self.error.lock().unwrap().take() {
                bail!("prefetch worker: {e}");
            }
            if should_stop() {
                return Ok(false);
            }
            self.park();
        }
    }

    fn grant(&self, n: u64) {
        self.granted.fetch_add(n, Ordering::SeqCst);
        self.cv.notify_all();
    }

    fn record_td(&self, td: &[f32]) {
        // Windowed by construction: queue only; `barrier_update` applies
        // at the flush barrier, so the worker's look-ahead draws see the
        // same frozen tree inline draws would have.
        self.strategy.lock().unwrap().record_td(td);
    }

    fn barrier_update(&self) {
        let mut strategy = self.strategy.lock().unwrap();
        if strategy.has_pending() {
            let mut replay = self.replay.write().unwrap();
            strategy.apply_updates(&mut replay);
        }
    }
}

/// The coordinator-facing source selector, shared by both drivers so the
/// prefetch-eligibility rule lives in exactly one place: the pipeline only
/// applies to a *windowed* trainer (its grant protocol needs window
/// barriers); inline training paths always sample directly — and apply
/// priority updates immediately, since their train/push interleaving is
/// already sequential.
pub enum TrainerSource<'a> {
    Direct(DirectSource<'a>),
    Prefetch(PrefetchPipeline<'a>),
}

impl<'a> TrainerSource<'a> {
    /// `windowed`: the run has a window-dispatched trainer thread
    /// (concurrent / both modes). The strategy arrives resumed at its
    /// segment position (see `replay::strategy::build_strategy`).
    pub fn with_strategy(
        replay: &'a RwLock<ReplayMemory>,
        strategy: Box<dyn SamplingStrategy>,
        minibatch: usize,
        prefetch_batches: usize,
        windowed: bool,
    ) -> TrainerSource<'a> {
        if windowed && prefetch_batches > 0 {
            TrainerSource::Prefetch(PrefetchPipeline::with_strategy(
                replay, strategy, minibatch, prefetch_batches,
            ))
        } else {
            TrainerSource::Direct(DirectSource::with_strategy(replay, strategy, minibatch, !windowed))
        }
    }

    /// The pipeline needing a worker thread, when prefetch is active.
    pub fn pipeline(&self) -> Option<&PrefetchPipeline<'a>> {
        match self {
            TrainerSource::Prefetch(p) => Some(p),
            TrainerSource::Direct(_) => None,
        }
    }

    /// Draw-stream RNG position (checkpointing; call only at a quiesce
    /// point — see [`PrefetchPipeline::sampler_state`]).
    pub fn sampler_state(&self) -> [u64; 4] {
        match self {
            TrainerSource::Direct(d) => d.sampler_state(),
            TrainerSource::Prefetch(p) => p.sampler_state(),
        }
    }
}

impl BatchSource for TrainerSource<'_> {
    fn next_batch(&self, out: &mut TrainBatch, should_stop: &dyn Fn() -> bool) -> Result<bool> {
        match self {
            TrainerSource::Direct(d) => d.next_batch(out, should_stop),
            TrainerSource::Prefetch(p) => p.next_batch(out, should_stop),
        }
    }

    fn grant(&self, n: u64) {
        if let TrainerSource::Prefetch(p) = self {
            p.grant(n);
        }
    }

    fn record_td(&self, td: &[f32]) {
        match self {
            TrainerSource::Direct(d) => d.record_td(td),
            TrainerSource::Prefetch(p) => p.record_td(td),
        }
    }

    fn barrier_update(&self) {
        match self {
            TrainerSource::Direct(d) => d.barrier_update(),
            TrainerSource::Prefetch(p) => p.barrier_update(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    const FS: usize = 8;
    const STACK: usize = 4;

    fn filled_replay(seed: u64) -> ReplayMemory {
        let mut r = ReplayMemory::new(256, 2, FS, STACK, seed).unwrap();
        for v in 0..60u8 {
            r.push(0, &[v; FS], v, 0.5, v % 11 == 10, v == 0 || v % 11 == 0);
            r.push(1, &[200u8.wrapping_sub(v); FS], v, 0.0, v % 13 == 12, v == 0 || v % 13 == 0);
        }
        r
    }

    #[test]
    fn direct_source_matches_inline_sample() {
        let replay = RwLock::new(filled_replay(5));
        let mut reference = filled_replay(5);
        let source = DirectSource::new(&replay, 5, 16);
        let never = || false;
        for _ in 0..4 {
            let mut got = TrainBatch::default();
            assert!(source.next_batch(&mut got, &never).unwrap());
            let mut want = TrainBatch::default();
            reference.sample(16, &mut want).unwrap();
            assert_eq!(got.states, want.states);
            assert_eq!(got.actions, want.actions);
            assert_eq!(got.rewards, want.rewards);
        }
    }

    #[test]
    fn pipeline_respects_grants_and_preserves_order() {
        let replay = RwLock::new(filled_replay(9));
        let pipeline = PrefetchPipeline::new(&replay, 9, 8, 2);
        let stop = AtomicBool::new(false);
        let mut reference = filled_replay(9);
        std::thread::scope(|scope| {
            scope.spawn(|| pipeline.worker_loop(&|| stop.load(Ordering::SeqCst)));

            // No grant yet: nothing may be produced.
            std::thread::sleep(Duration::from_millis(20));
            assert_eq!(pipeline.produced(), 0, "worker ran ahead of its grant");

            pipeline.grant(5);
            let should_stop = || stop.load(Ordering::SeqCst);
            for _ in 0..5 {
                let mut got = TrainBatch::default();
                assert!(pipeline.next_batch(&mut got, &should_stop).unwrap());
                let mut want = TrainBatch::default();
                reference.sample(8, &mut want).unwrap();
                assert_eq!(got.states, want.states, "prefetched batch out of order");
                assert_eq!(got.actions, want.actions);
            }
            // Quota exhausted: produced stays at the grant.
            std::thread::sleep(Duration::from_millis(20));
            assert_eq!(pipeline.produced(), 5);
            stop.store(true, Ordering::SeqCst);
        });
    }

    #[test]
    fn next_batch_reports_clean_stop() {
        let replay = RwLock::new(filled_replay(1));
        let pipeline = PrefetchPipeline::new(&replay, 1, 8, 1);
        // No worker, no grant; a stopping run must get Ok(false), not hang.
        let mut out = TrainBatch::default();
        assert!(!pipeline.next_batch(&mut out, &|| true).unwrap());
    }
}
