//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` targets (`cargo bench`). Provides
//! warmup, adaptive iteration counts, and mean/σ/min reporting in a stable
//! plain-text format so bench output can be diffed across runs.
//!
//! ## Machine-readable perf trajectory
//!
//! When `TEMPO_BENCH_JSON` names a file, [`Bench::emit_json`] merges every
//! result into it as `{"format": 1, "suites": {<suite>: {<bench>: record}}}`
//! — each bench binary is its own process, so the file is read-modify-write
//! and a full bench sweep accumulates one `BENCH_<pr>.json` snapshot at the
//! repo root. [`compare`] / [`compare_files`] diff two snapshots and flag
//! any bench whose mean regressed beyond a noise fraction; the
//! `bench-compare` subcommand and the CI bench-trajectory job drive it
//! (rust/DESIGN.md §12, README "Perf trajectory").
//!
//! A snapshot row with `iters == 0` is a **placeholder**: an estimate
//! admitted into the trajectory without a measurement (a real
//! [`Bench::run`]/[`Bench::record`] always yields `iters >= 1`). Compare
//! still pairs such rows, but flags them informationally in
//! [`CompareReport::render`] so a carried estimate can't silently pass as
//! a measurement.

use std::collections::BTreeMap;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::{obj, Json};

/// Env var naming the JSON snapshot file benches merge results into.
pub const BENCH_JSON_ENV: &str = "TEMPO_BENCH_JSON";

/// One measured result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns <= 0.0 {
            return 0.0;
        }
        1e9 / self.mean_ns
    }

    /// The structured record `emit_json` persists per bench.
    pub fn to_json(&self) -> Json {
        obj(vec![
            ("iters", Json::Num(self.iters as f64)),
            ("mean_ns", Json::Num(self.mean_ns)),
            ("std_ns", Json::Num(self.std_ns)),
            ("min_ns", Json::Num(self.min_ns)),
            ("per_sec", Json::Num(self.throughput_per_sec())),
        ])
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bench {
    target_time: Duration,
    warmup: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        // Respect a quick mode for CI: TEMPO_BENCH_MS=200 etc.
        let ms = std::env::var("TEMPO_BENCH_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(1_000);
        Bench {
            target_time: Duration::from_millis(ms),
            warmup: Duration::from_millis(ms / 5),
            results: Vec::new(),
        }
    }

    /// Measure `f` (one call = one iteration).
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup + estimate per-iter cost.
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup || warm_iters < 3 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let est = w0.elapsed().as_nanos() as f64 / warm_iters as f64;
        // Aim for ~30 samples of batched iterations within target_time.
        let total_iters = (self.target_time.as_nanos() as f64 / est).max(3.0) as u64;
        let samples = 30u64.min(total_iters).max(3);
        let per_sample = (total_iters / samples).max(1);

        let mut times = Vec::with_capacity(samples as usize);
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..per_sample {
                std::hint::black_box(f());
            }
            times.push(t0.elapsed().as_nanos() as f64 / per_sample as f64);
        }
        let n = times.len() as f64;
        let mean = times.iter().sum::<f64>() / n;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
        let min = times.iter().copied().fold(f64::INFINITY, f64::min);
        let res = BenchResult {
            name: name.to_string(),
            iters: samples * per_sample,
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: min,
        };
        println!(
            "bench {:<44} {:>12}/iter (σ {:>10}, min {:>10}, {} iters)",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.std_ns),
            fmt_ns(res.min_ns),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    /// Record an externally measured result (for benches that time a whole
    /// run themselves instead of calling [`Bench::run`], e.g. the Figure 3
    /// transaction sweep). `total_ns` covers all `iters` iterations.
    pub fn record(&mut self, name: &str, iters: u64, total_ns: f64) -> &BenchResult {
        let mean = total_ns / iters.max(1) as f64;
        let res = BenchResult {
            name: name.to_string(),
            iters: iters.max(1),
            mean_ns: mean,
            std_ns: 0.0,
            min_ns: mean,
        };
        println!(
            "bench {:<44} {:>12}/iter (recorded, {} iters)",
            res.name,
            fmt_ns(res.mean_ns),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Last result for `name`.
    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().rev().find(|r| r.name == name)
    }

    /// Merge every result into the snapshot named by `TEMPO_BENCH_JSON`
    /// under `suites.<suite>`. No-op (Ok) when the env var is unset, so
    /// plain `cargo bench` runs stay file-free.
    pub fn emit_json(&self, suite: &str) -> Result<()> {
        match std::env::var(BENCH_JSON_ENV) {
            Ok(path) if !path.is_empty() => self.emit_json_to(suite, Path::new(&path)),
            _ => Ok(()),
        }
    }

    /// Read-modify-write `path` (each bench binary is a separate process;
    /// the sweep accumulates one file). Existing suites are preserved;
    /// same-name benches within `suite` are overwritten.
    pub fn emit_json_to(&self, suite: &str, path: &Path) -> Result<()> {
        let mut root = match std::fs::read_to_string(path) {
            Ok(text) => Json::parse(&text)
                .map_err(|e| anyhow!("{}: not a bench snapshot: {e}", path.display()))?,
            Err(_) => obj(vec![("format", Json::Num(1.0))]),
        };
        let Json::Obj(top) = &mut root else {
            bail!("{}: bench snapshot root must be an object", path.display());
        };
        top.entry("format".to_string()).or_insert(Json::Num(1.0));
        let suites = top
            .entry("suites".to_string())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        let Json::Obj(suites) = suites else {
            bail!("{}: \"suites\" must be an object", path.display());
        };
        let entry = suites
            .entry(suite.to_string())
            .or_insert_with(|| Json::Obj(BTreeMap::new()));
        let Json::Obj(entry) = entry else {
            bail!("{}: suite {suite:?} must be an object", path.display());
        };
        for r in &self.results {
            entry.insert(r.name.clone(), r.to_json());
        }
        let mut out = String::new();
        pretty(&root, 0, &mut out);
        out.push('\n');
        std::fs::write(path, out)
            .with_context(|| format!("writing bench snapshot {}", path.display()))?;
        Ok(())
    }
}

/// Two-space-indented writer so `BENCH_<pr>.json` diffs line-by-line in
/// review (the compact `Json::to_string` would put a whole snapshot on one
/// line). Output reparses to the identical value.
fn pretty(v: &Json, depth: usize, out: &mut String) {
    match v {
        Json::Obj(m) if !m.is_empty() => {
            out.push_str("{\n");
            for (i, (k, x)) in m.iter().enumerate() {
                if i > 0 {
                    out.push_str(",\n");
                }
                out.push_str(&"  ".repeat(depth + 1));
                out.push_str(&Json::Str(k.clone()).to_string());
                out.push_str(": ");
                pretty(x, depth + 1, out);
            }
            out.push('\n');
            out.push_str(&"  ".repeat(depth));
            out.push('}');
        }
        other => out.push_str(&other.to_string()),
    }
}

/// One prev-vs-cur bench pairing from [`compare`].
#[derive(Clone, Debug)]
pub struct Comparison {
    pub suite: String,
    pub name: String,
    pub prev_mean_ns: f64,
    pub cur_mean_ns: f64,
    /// cur / prev — > 1 means slower.
    pub ratio: f64,
    /// Either snapshot marks this row `iters == 0`: a carried estimate,
    /// not a measurement. Compared like any row, flagged in render.
    pub placeholder: bool,
}

/// Result of diffing two bench snapshots.
#[derive(Debug)]
pub struct CompareReport {
    pub rows: Vec<Comparison>,
    /// "suite/name" present only in the current snapshot.
    pub added: Vec<String>,
    /// "suite/name" present only in the previous snapshot.
    pub removed: Vec<String>,
    pub noise_frac: f64,
}

impl CompareReport {
    /// Rows whose mean regressed beyond the noise fraction.
    pub fn regressions(&self) -> Vec<&Comparison> {
        self.rows.iter().filter(|c| c.ratio > 1.0 + self.noise_frac).collect()
    }

    /// Rows where either snapshot carries a placeholder (`iters == 0`).
    /// Informational: these never fail a compare by themselves.
    pub fn placeholders(&self) -> Vec<&Comparison> {
        self.rows.iter().filter(|c| c.placeholder).collect()
    }

    pub fn render(&self) -> String {
        let mut s = format!(
            "bench-compare: {} paired benches, noise threshold ±{:.0}%\n",
            self.rows.len(),
            self.noise_frac * 100.0
        );
        for c in &self.rows {
            let status = if c.ratio > 1.0 + self.noise_frac {
                "REGRESSED"
            } else if c.ratio < 1.0 - self.noise_frac {
                "improved"
            } else {
                "ok"
            };
            s.push_str(&format!(
                "  {status:<9} {:<52} {:>10} -> {:>10}  x{:.2}{}\n",
                format!("{}/{}", c.suite, c.name),
                fmt_ns(c.prev_mean_ns),
                fmt_ns(c.cur_mean_ns),
                c.ratio,
                if c.placeholder { "  [placeholder]" } else { "" }
            ));
        }
        let ph = self.placeholders().len();
        if ph > 0 {
            s.push_str(&format!(
                "  note: {ph} placeholder row(s) (iters == 0) — admitted estimates, \
                 not measurements; re-run the bench on real hardware to retire them\n"
            ));
        }
        for name in &self.added {
            s.push_str(&format!("  new       {name}\n"));
        }
        for name in &self.removed {
            s.push_str(&format!("  dropped   {name}\n"));
        }
        s
    }
}

/// Per-bench (mean_ns, placeholder?) — a row is a placeholder when its
/// `iters` field is 0; snapshots predating the field count as measured.
fn snapshot_suites(
    root: &Json,
    which: &str,
) -> Result<BTreeMap<String, BTreeMap<String, (f64, bool)>>> {
    let suites = root
        .get("suites")
        .and_then(Json::as_obj)
        .ok_or_else(|| anyhow!("{which} snapshot has no \"suites\" object"))?;
    let mut out = BTreeMap::new();
    for (sname, benches) in suites {
        let benches = benches
            .as_obj()
            .ok_or_else(|| anyhow!("{which} snapshot: suite {sname:?} is not an object"))?;
        let mut means = BTreeMap::new();
        for (bname, rec) in benches {
            let mean = rec.get("mean_ns").and_then(Json::as_f64).ok_or_else(|| {
                anyhow!("{which} snapshot: {sname}/{bname} lacks a numeric mean_ns")
            })?;
            let placeholder = rec.get("iters").and_then(Json::as_f64) == Some(0.0);
            means.insert(bname.clone(), (mean, placeholder));
        }
        out.insert(sname.clone(), means);
    }
    Ok(out)
}

/// Diff two parsed snapshots: pair benches present in both, list the rest.
/// Fails (via [`CompareReport::regressions`] at the caller) only on paired
/// regressions — added/removed benches are reported, not fatal, so the
/// bench roster can evolve between PRs.
pub fn compare(prev: &Json, cur: &Json, noise_frac: f64) -> Result<CompareReport> {
    let prev = snapshot_suites(prev, "previous")?;
    let cur = snapshot_suites(cur, "current")?;
    let mut rows = Vec::new();
    let mut added = Vec::new();
    let mut removed = Vec::new();
    for (sname, benches) in &cur {
        for (bname, &(cur_mean, cur_ph)) in benches {
            match prev.get(sname).and_then(|b| b.get(bname)) {
                Some(&(prev_mean, prev_ph)) if prev_mean > 0.0 => rows.push(Comparison {
                    suite: sname.clone(),
                    name: bname.clone(),
                    prev_mean_ns: prev_mean,
                    cur_mean_ns: cur_mean,
                    ratio: cur_mean / prev_mean,
                    placeholder: prev_ph || cur_ph,
                }),
                _ => added.push(format!("{sname}/{bname}")),
            }
        }
    }
    for (sname, benches) in &prev {
        for bname in benches.keys() {
            if cur.get(sname).map_or(true, |b| !b.contains_key(bname)) {
                removed.push(format!("{sname}/{bname}"));
            }
        }
    }
    Ok(CompareReport { rows, added, removed, noise_frac })
}

/// [`compare`] over two snapshot files.
pub fn compare_files(prev: &Path, cur: &Path, noise_frac: f64) -> Result<CompareReport> {
    let read = |p: &Path| -> Result<Json> {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("reading bench snapshot {}", p.display()))?;
        Json::parse(&text).map_err(|e| anyhow!("{}: {e}", p.display()))
    };
    compare(&read(prev)?, &read(cur)?, noise_frac)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_reasonable() {
        std::env::set_var("TEMPO_BENCH_MS", "50");
        let mut b = Bench::new();
        let r = b.run("noop-ish", || std::hint::black_box(1u64 + 1)).clone();
        assert!(r.mean_ns < 1e6, "{}", r.mean_ns);
        assert!(r.iters >= 3);
        assert!(b.get("noop-ish").is_some());
        assert!(r.throughput_per_sec() > 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(1.2e4).contains("us"));
        assert!(fmt_ns(3.4e6).contains("ms"));
        assert!(fmt_ns(2.1e9).contains(" s"));
    }

    fn fake(name: &str, mean_ns: f64) -> BenchResult {
        BenchResult {
            name: name.to_string(),
            iters: 100,
            mean_ns,
            std_ns: mean_ns / 10.0,
            min_ns: mean_ns * 0.9,
        }
    }

    fn bench_with(results: Vec<BenchResult>) -> Bench {
        let mut b = Bench::new();
        b.results = results;
        b
    }

    #[test]
    fn record_reports_external_timings() {
        let mut b = bench_with(vec![]);
        let r = b.record("env/steps", 2_000, 4e9).clone();
        assert_eq!(r.iters, 2_000);
        assert_eq!(r.mean_ns, 2e6);
        assert!((r.throughput_per_sec() - 500.0).abs() < 1e-9);
        assert!(b.get("env/steps").is_some());
    }

    /// emit_json_to is read-modify-write: two "processes" (Bench values)
    /// writing different suites accumulate into one snapshot, and
    /// re-emitting a suite overwrites its benches in place.
    #[test]
    fn emit_json_merges_across_processes() {
        let path = std::env::temp_dir().join(format!("tempo_bench_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);

        bench_with(vec![fake("a", 100.0), fake("b", 200.0)])
            .emit_json_to("suite1", &path)
            .unwrap();
        bench_with(vec![fake("c", 300.0)]).emit_json_to("suite2", &path).unwrap();
        bench_with(vec![fake("b", 250.0)]).emit_json_to("suite1", &path).unwrap();

        let root = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        assert_eq!(root.at(&["format"]).unwrap().as_f64(), Some(1.0));
        assert_eq!(
            root.at(&["suites", "suite1", "a", "mean_ns"]).unwrap().as_f64(),
            Some(100.0)
        );
        assert_eq!(
            root.at(&["suites", "suite1", "b", "mean_ns"]).unwrap().as_f64(),
            Some(250.0),
            "re-emit overwrites in place"
        );
        assert_eq!(
            root.at(&["suites", "suite2", "c", "per_sec"]).unwrap().as_f64(),
            Some(1e9 / 300.0)
        );
        // Pretty output reparses to the same value as compact output.
        let mut p = String::new();
        pretty(&root, 0, &mut p);
        assert_eq!(Json::parse(&p).unwrap(), root);
        std::fs::remove_file(&path).unwrap();
    }

    #[test]
    fn emit_json_is_noop_without_env() {
        // The env var is unset (or set by CI to a real path) — exercise the
        // explicit no-op branch with an empty override.
        std::env::remove_var(BENCH_JSON_ENV);
        bench_with(vec![fake("x", 1.0)]).emit_json("nowhere").unwrap();
    }

    #[test]
    fn compare_flags_regressions_beyond_noise() {
        let prev_b = bench_with(vec![fake("stable", 100.0), fake("regressed", 100.0), fake("gone", 5.0)]);
        let cur_b = bench_with(vec![fake("stable", 110.0), fake("regressed", 200.0), fake("fresh", 7.0)]);
        let to_json = |b: &Bench| {
            let mut m = BTreeMap::new();
            for r in b.results() {
                m.insert(r.name.clone(), r.to_json());
            }
            obj(vec![
                ("format", Json::Num(1.0)),
                ("suites", obj(vec![("train", Json::Obj(m))])),
            ])
        };
        let report = compare(&to_json(&prev_b), &to_json(&cur_b), 0.30).unwrap();
        assert_eq!(report.rows.len(), 2);
        let regs = report.regressions();
        assert_eq!(regs.len(), 1);
        assert_eq!(regs[0].name, "regressed");
        assert!((regs[0].ratio - 2.0).abs() < 1e-9);
        assert_eq!(report.added, vec!["train/fresh".to_string()]);
        assert_eq!(report.removed, vec!["train/gone".to_string()]);
        let rendered = report.render();
        assert!(rendered.contains("REGRESSED"), "{rendered}");
        assert!(rendered.contains("train/stable"), "{rendered}");

        // Within-noise drift passes clean.
        assert!(compare(&to_json(&prev_b), &to_json(&prev_b), 0.30)
            .unwrap()
            .regressions()
            .is_empty());
    }

    /// A row with `iters == 0` (an admitted placeholder, e.g. the fleet
    /// estimates in BENCH_8/BENCH_9) is paired and rendered with an
    /// informational flag, but never fails the compare by itself — and a
    /// record lacking the iters field entirely counts as measured.
    #[test]
    fn compare_flags_placeholder_rows_informationally() {
        let mut ph = fake("fleet_row", 1_000.0);
        ph.iters = 0;
        let prev_b = bench_with(vec![fake("real", 100.0), ph.clone()]);
        let cur_b = bench_with(vec![fake("real", 100.0), ph]);
        let to_json = |b: &Bench| {
            let mut m = BTreeMap::new();
            for r in b.results() {
                m.insert(r.name.clone(), r.to_json());
            }
            obj(vec![
                ("format", Json::Num(1.0)),
                ("suites", obj(vec![("fleet", Json::Obj(m))])),
            ])
        };
        let report = compare(&to_json(&prev_b), &to_json(&cur_b), 0.30).unwrap();
        assert_eq!(report.rows.len(), 2);
        let phs = report.placeholders();
        assert_eq!(phs.len(), 1);
        assert_eq!(phs[0].name, "fleet_row");
        assert!(report.regressions().is_empty(), "placeholders are informational");
        let rendered = report.render();
        assert_eq!(rendered.matches("[placeholder]").count(), 1, "{rendered}");
        assert!(rendered.contains("1 placeholder row(s)"), "{rendered}");

        // Pre-field snapshots: strip iters, nothing is a placeholder.
        let mut legacy = to_json(&prev_b);
        if let Json::Obj(top) = &mut legacy {
            if let Some(Json::Obj(suites)) = top.get_mut("suites") {
                if let Some(Json::Obj(benches)) = suites.get_mut("fleet") {
                    for rec in benches.values_mut() {
                        if let Json::Obj(fields) = rec {
                            fields.remove("iters");
                        }
                    }
                }
            }
        }
        let report = compare(&legacy, &legacy, 0.30).unwrap();
        assert!(report.placeholders().is_empty());
        assert!(!report.render().contains("placeholder"));
    }

    #[test]
    fn compare_files_roundtrip() {
        let dir = std::env::temp_dir();
        let prev = dir.join(format!("tempo_bench_prev_{}.json", std::process::id()));
        let cur = dir.join(format!("tempo_bench_cur_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&prev);
        let _ = std::fs::remove_file(&cur);
        bench_with(vec![fake("k", 100.0)]).emit_json_to("s", &prev).unwrap();
        bench_with(vec![fake("k", 500.0)]).emit_json_to("s", &cur).unwrap();
        let report = compare_files(&prev, &cur, 0.30).unwrap();
        assert_eq!(report.regressions().len(), 1);
        assert!(compare_files(&prev, Path::new("/nonexistent/b.json"), 0.3).is_err());
        std::fs::remove_file(&prev).unwrap();
        std::fs::remove_file(&cur).unwrap();
    }
}
