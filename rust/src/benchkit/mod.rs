//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! Used by the `rust/benches/*.rs` targets (`cargo bench`). Provides
//! warmup, adaptive iteration counts, and mean/σ/min reporting in a stable
//! plain-text format so bench output can be diffed across runs.

use std::time::{Duration, Instant};

/// One measured result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub std_ns: f64,
    pub min_ns: f64,
}

impl BenchResult {
    pub fn throughput_per_sec(&self) -> f64 {
        if self.mean_ns <= 0.0 {
            return 0.0;
        }
        1e9 / self.mean_ns
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns >= 1e9 {
        format!("{:.3} s", ns / 1e9)
    } else if ns >= 1e6 {
        format!("{:.3} ms", ns / 1e6)
    } else if ns >= 1e3 {
        format!("{:.3} us", ns / 1e3)
    } else {
        format!("{ns:.0} ns")
    }
}

/// Benchmark runner with a global time budget per benchmark.
pub struct Bench {
    target_time: Duration,
    warmup: Duration,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Bench {
        // Respect a quick mode for CI: TEMPO_BENCH_MS=200 etc.
        let ms = std::env::var("TEMPO_BENCH_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(1_000);
        Bench {
            target_time: Duration::from_millis(ms),
            warmup: Duration::from_millis(ms / 5),
            results: Vec::new(),
        }
    }

    /// Measure `f` (one call = one iteration).
    pub fn run<T>(&mut self, name: &str, mut f: impl FnMut() -> T) -> &BenchResult {
        // Warmup + estimate per-iter cost.
        let w0 = Instant::now();
        let mut warm_iters = 0u64;
        while w0.elapsed() < self.warmup || warm_iters < 3 {
            std::hint::black_box(f());
            warm_iters += 1;
            if warm_iters > 1_000_000 {
                break;
            }
        }
        let est = w0.elapsed().as_nanos() as f64 / warm_iters as f64;
        // Aim for ~30 samples of batched iterations within target_time.
        let total_iters = (self.target_time.as_nanos() as f64 / est).max(3.0) as u64;
        let samples = 30u64.min(total_iters).max(3);
        let per_sample = (total_iters / samples).max(1);

        let mut times = Vec::with_capacity(samples as usize);
        for _ in 0..samples {
            let t0 = Instant::now();
            for _ in 0..per_sample {
                std::hint::black_box(f());
            }
            times.push(t0.elapsed().as_nanos() as f64 / per_sample as f64);
        }
        let n = times.len() as f64;
        let mean = times.iter().sum::<f64>() / n;
        let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n;
        let min = times.iter().copied().fold(f64::INFINITY, f64::min);
        let res = BenchResult {
            name: name.to_string(),
            iters: samples * per_sample,
            mean_ns: mean,
            std_ns: var.sqrt(),
            min_ns: min,
        };
        println!(
            "bench {:<44} {:>12}/iter (σ {:>10}, min {:>10}, {} iters)",
            res.name,
            fmt_ns(res.mean_ns),
            fmt_ns(res.std_ns),
            fmt_ns(res.min_ns),
            res.iters
        );
        self.results.push(res);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Last result for `name`.
    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().rev().find(|r| r.name == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_reasonable() {
        std::env::set_var("TEMPO_BENCH_MS", "50");
        let mut b = Bench::new();
        let r = b.run("noop-ish", || std::hint::black_box(1u64 + 1)).clone();
        assert!(r.mean_ns < 1e6, "{}", r.mean_ns);
        assert!(r.iters >= 3);
        assert!(b.get("noop-ish").is_some());
        assert!(r.throughput_per_sec() > 0.0);
    }

    #[test]
    fn fmt_scales() {
        assert!(fmt_ns(12.0).contains("ns"));
        assert!(fmt_ns(1.2e4).contains("us"));
        assert!(fmt_ns(3.4e6).contains("ms"));
        assert!(fmt_ns(2.1e9).contains(" s"));
    }
}
