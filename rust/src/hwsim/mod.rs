//! Hardware-model simulator (discrete-event) for the Table 1-3 and
//! Figure 2-3 reproductions.
//!
//! This container has one CPU core and no GPU, so the paper's wall-clock
//! thread-scaling results cannot physically manifest here. Tables 1-3 are
//! scheduling outcomes: given per-task durations and the machine's resource
//! constraints (W CPU lanes, one serial accelerator, per-transaction bus
//! overhead), the runtime of each execution model is fully determined. The
//! DES plays out the *same dependency structures* as the real coordinator
//! drivers with calibrated task costs — either fitted to the paper's own
//! single-thread anchors (`CostModel::gtx1080_i7`) or measured live on this
//! machine (`CostModel::from_measured`) for validation against real runs.
//! See rust/DESIGN.md §3.

pub mod cost;
pub mod des;
pub mod modes;

pub use cost::CostModel;
pub use des::{Machine, SimStats};
pub use modes::{simulate, SimRun};
