//! Discrete-event machine: one serial accelerator + a pool of CPU lanes.
//!
//! Time is f64 milliseconds. The accelerator is a single FIFO server (the
//! paper's one-GPU assumption); CPU env work runs on `cores` parallel
//! lanes. Contention inflates per-transaction overhead as a function of
//! how many entities are waiting (Figure 3(a)'s bus-saturation effect).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use super::cost::CostModel;

/// Totally-ordered f64 for heaps.
#[derive(Clone, Copy, Debug, PartialEq)]
pub(crate) struct F(pub f64);
impl Eq for F {}
impl PartialOrd for F {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for F {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Aggregate counters for a simulated run.
#[derive(Clone, Copy, Debug, Default)]
pub struct SimStats {
    pub gpu_busy_ms: f64,
    pub gpu_transactions: u64,
    pub env_steps: u64,
    pub trains: u64,
    pub syncs: u64,
    pub makespan_ms: f64,
}

impl SimStats {
    pub fn hours(&self) -> f64 {
        self.makespan_ms / 3_600_000.0
    }

    pub fn gpu_utilization(&self) -> f64 {
        if self.makespan_ms <= 0.0 {
            return 0.0;
        }
        self.gpu_busy_ms / self.makespan_ms
    }
}

/// The simulated machine.
pub struct Machine {
    pub model: CostModel,
    gpu_free: f64,
    serial_free: f64,
    lanes: BinaryHeap<Reverse<F>>,
    pub stats: SimStats,
}

impl Machine {
    pub fn new(model: CostModel) -> Machine {
        let mut lanes = BinaryHeap::new();
        for _ in 0..model.cores {
            lanes.push(Reverse(F(0.0)));
        }
        Machine { model, gpu_free: 0.0, serial_free: 0.0, lanes, stats: SimStats::default() }
    }

    pub fn gpu_free_at(&self) -> f64 {
        self.gpu_free
    }

    /// Execute one accelerator transaction arriving at `arrival` with
    /// compute duration `compute_ms`, with `waiting` other contenders.
    /// Returns the completion time.
    pub fn gpu(&mut self, arrival: f64, compute_ms: f64, waiting: usize) -> f64 {
        let start = arrival.max(self.gpu_free);
        let dur = self.model.txn_eff(waiting + 1) + compute_ms;
        let end = start + dur;
        self.gpu_free = end;
        self.stats.gpu_busy_ms += dur;
        self.stats.gpu_transactions += 1;
        self.stats.makespan_ms = self.stats.makespan_ms.max(end);
        end
    }

    /// Execute one env step: first the host-serialized portion (dispatch,
    /// action selection, frame bookkeeping — one global "interpreter"
    /// resource, the GIL of the paper's reference implementation), then
    /// the parallel simulation portion on the earliest-free CPU lane.
    pub fn cpu(&mut self, arrival: f64) -> f64 {
        self.cpu_scaled(arrival, 1.0)
    }

    /// `cpu` with the host-serial portion scaled (Synchronized Execution's
    /// batched bookkeeping).
    pub fn cpu_scaled(&mut self, arrival: f64, serial_scale: f64) -> f64 {
        let s_start = arrival.max(self.serial_free);
        let s_end = s_start + self.model.serial_ms * serial_scale;
        self.serial_free = s_end;
        let Reverse(F(lane_free)) = self.lanes.pop().expect("cores >= 1");
        let start = s_end.max(lane_free);
        let end = start + self.model.env_step_ms;
        self.lanes.push(Reverse(F(end)));
        self.stats.env_steps += 1;
        self.stats.makespan_ms = self.stats.makespan_ms.max(end);
        end
    }

    /// Run `n` env steps all arriving at `arrival`; return when ALL finish.
    /// The host-serial portion is charged at the batched discount.
    pub fn cpu_phase(&mut self, arrival: f64, n: usize) -> f64 {
        let scale = self.model.batch_host_discount;
        let mut latest = arrival;
        for _ in 0..n {
            latest = latest.max(self.cpu_scaled(arrival, scale));
        }
        latest
    }

    /// A synchronization barrier at `time` costing `sync_ms`.
    pub fn sync(&mut self, time: f64) -> f64 {
        let end = time + self.model.sync_ms;
        self.stats.syncs += 1;
        self.stats.makespan_ms = self.stats.makespan_ms.max(end);
        end
    }

    pub fn note_train(&mut self) {
        self.stats.trains += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CostModel {
        CostModel {
            env_step_ms: 1.0,
            serial_ms: 0.0,
            txn_ms: 0.5,
            infer_per_sample_ms: 0.1,
            train_ms: 2.0,
            train_parallel_frac: 0.8,
            sample_ms: 0.0,
            tree_ms: 0.0,
            sync_ms: 1.0,
            net_ms: 0.0,
            cores: 2,
            contention: 0.0,
            batch_host_discount: 1.0,
        }
    }

    #[test]
    fn gpu_serializes() {
        let mut m = Machine::new(model());
        let a = m.gpu(0.0, 1.0, 0); // 0 .. 1.5
        let b = m.gpu(0.0, 1.0, 0); // 1.5 .. 3.0 (waits)
        assert_eq!(a, 1.5);
        assert_eq!(b, 3.0);
        assert_eq!(m.stats.gpu_transactions, 2);
        assert!((m.stats.gpu_busy_ms - 3.0).abs() < 1e-9);
    }

    #[test]
    fn cpu_pool_parallelism() {
        let mut m = Machine::new(model()); // 2 cores
        let done = m.cpu_phase(0.0, 4); // 4 tasks, 2 lanes -> 2 waves
        assert!((done - 2.0).abs() < 1e-9, "{done}");
        assert_eq!(m.stats.env_steps, 4);
    }

    #[test]
    fn makespan_tracks_max() {
        let mut m = Machine::new(model());
        m.cpu(5.0);
        assert!((m.stats.makespan_ms - 6.0).abs() < 1e-9);
        m.gpu(10.0, 0.5, 0);
        assert!((m.stats.makespan_ms - 11.0).abs() < 1e-9);
    }

    #[test]
    fn contention_inflates_service() {
        let mut cm = model();
        cm.contention = 1.0;
        let mut m = Machine::new(cm);
        let solo = m.gpu(0.0, 0.0, 0);
        assert!((solo - 0.5).abs() < 1e-9);
        let crowded_end = m.gpu(solo, 0.0, 3); // txn * (1+3)
        assert!((crowded_end - solo - 2.0).abs() < 1e-9);
    }
}
