//! Per-mode workload simulators: each function plays out the exact task
//! dependency structure of one execution model on the simulated machine
//! and returns the resulting schedule statistics.
//!
//! The dependency structures mirror the real coordinator drivers
//! (`coordinator::async_exec`, `coordinator::sync_exec`) one-to-one; only
//! task *durations* come from the cost model.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::config::ExecMode;

use super::cost::CostModel;
use super::des::{Machine, SimStats, F};

/// Simulation parameters (paper §5.1: 1M steps, C=10k, F=4).
#[derive(Clone, Copy, Debug)]
pub struct SimRun {
    pub steps: u64,
    pub c: u64,
    pub f: u64,
    pub threads: usize,
    /// Learner compute-pool width (the real coordinator's
    /// `learner_threads`); shards `train_ms` per the cost model's Amdahl
    /// split.
    pub learner_threads: usize,
    /// Replay prefetch on: batch assembly (`sample_ms`) leaves the
    /// trainer's critical path. Only the windowed trainer benefits —
    /// mirroring the real drivers, the standard/synchronized inline
    /// training paths always pay it.
    pub prefetch: bool,
    /// Prioritized replay on: every train step pays the sum-tree cost
    /// (`tree_ms`) — split out of `sample_ms` because prefetch cannot
    /// hide it (priority updates run at the window barrier).
    pub prioritized: bool,
    /// Sampler fleet processes (rust/DESIGN.md §14): each window barrier
    /// additionally pays `net_ms * fleet_procs` for the upload drain and
    /// parameter broadcast. 0 = single-process (no wire). Fleet execution
    /// is concurrent-mode-only, so the synchronized simulators ignore it —
    /// exactly like the real coordinator, which refuses the combination.
    pub fleet_procs: usize,
}

impl Default for SimRun {
    fn default() -> Self {
        SimRun {
            steps: 1_000_000,
            c: 10_000,
            f: 4,
            threads: 1,
            learner_threads: 1,
            prefetch: false,
            prioritized: false,
            fleet_procs: 0,
        }
    }
}

/// Simulate `mode` and return schedule statistics.
pub fn simulate(model: CostModel, run: SimRun, mode: ExecMode) -> SimStats {
    match mode {
        ExecMode::Standard => sim_async(model, run, false),
        ExecMode::Concurrent => sim_async(model, run, true),
        ExecMode::Synchronized => sim_sync(model, run, false),
        ExecMode::Both => sim_sync(model, run, true),
    }
}

/// Asynchronous execution: W samplers each do size-1 inference on the
/// shared device, then an env step on a CPU lane. In concurrent mode the
/// trainer is one more FIFO entity contending for the device (exactly like
/// the real driver, where the device mutex serializes all callers).
fn sim_async(model: CostModel, run: SimRun, concurrent: bool) -> SimStats {
    if !concurrent {
        return sim_standard(model, run);
    }
    let mut m = Machine::new(model);
    let w = run.threads;
    let total = run.steps;
    let trainer_id = w; // entity id for the trainer
    // Windowed trainer: sharded learner, prefetch hides assembly (never
    // the tree ops).
    let train_cost = model.train_step_ms(run.learner_threads, run.prefetch, run.prioritized);
    // Fleet wire cost rides on every window barrier: the learner drains
    // one upload per sampler process and broadcasts theta_minus before
    // the next window opens. Zero for single-process runs.
    let net_cost = model.net_ms * run.fleet_procs as f64;

    // Ready-queue of entities: (ready_time, id). Samplers are 0..w.
    let mut ready: BinaryHeap<Reverse<(F, usize)>> = BinaryHeap::new();
    for id in 0..w {
        ready.push(Reverse((F(0.0), id)));
    }
    ready.push(Reverse((F(0.0), trainer_id)));

    let mut steps: u64 = 0;
    let mut trains: u64 = 0;
    let mut window_end = run.c.min(total);
    let mut trainer_pending = run.c.min(total) / run.f;
    // Samplers parked at the window barrier.
    let mut parked: Vec<usize> = Vec::new();
    let mut parked_time = 0.0f64;
    let mut trainer_parked = false;

    while steps < total {
        let Reverse((F(t_ready), id)) = ready.pop().unwrap_or_else(|| {
            panic!("deadlock: all entities parked with steps={steps}/{total}")
        });
        // Bus contention: in asynchronous execution all W samplers keep
        // individual transaction streams open against the device
        // (Figure 3(a)); the trainer does not add bus pressure for infers.
        let waiting = w.saturating_sub(1);

        if id == trainer_id {
            if trainer_pending == 0 {
                trainer_parked = true;
                parked_time = parked_time.max(t_ready);
                // The trainer may be the last entity to park: fire the
                // window barrier here as well.
                if parked.len() == w && steps < total {
                    let barrier = m.sync(parked_time.max(m.gpu_free_at())) + net_cost;
                    for pid in parked.drain(..) {
                        ready.push(Reverse((F(barrier), pid)));
                    }
                    window_end = (window_end + run.c).min(total);
                    trainer_pending = ((window_end - steps) / run.f).max(1);
                    trainer_parked = false;
                    ready.push(Reverse((F(barrier), trainer_id)));
                }
                continue;
            }
            // Inference has priority on the device (prediction latency is
            // on the sampling critical path; training is not): if any
            // sampler is already waiting for the device, yield to it and
            // retry once the device frees up.
            let now = t_ready.max(m.gpu_free_at());
            let sampler_waiting = ready
                .iter()
                .any(|Reverse((F(r), sid))| *sid != trainer_id && *r <= now);
            if sampler_waiting {
                ready.push(Reverse((F(now + 1e-6), trainer_id)));
                continue;
            }
            let end = m.gpu(t_ready, train_cost, waiting);
            m.note_train();
            trains += 1;
            trainer_pending -= 1;
            ready.push(Reverse((F(end), trainer_id)));
            continue;
        }

        // Sampler taking global step `t`.
        let t = steps;
        if t >= window_end {
            // Park at the window barrier.
            parked.push(id);
            parked_time = parked_time.max(t_ready);
            // Window completes when every sampler is parked and the
            // trainer has drained its quota.
            if parked.len() == w && trainer_parked {
                let barrier = m.sync(parked_time.max(m.gpu_free_at())) + net_cost;
                for pid in parked.drain(..) {
                    ready.push(Reverse((F(barrier), pid)));
                }
                window_end = (window_end + run.c).min(total);
                trainer_pending = ((window_end - t) / run.f).max(1);
                trainer_parked = false;
                ready.push(Reverse((F(barrier), trainer_id)));
            }
            continue;
        }
        steps += 1;

        // Size-1 inference (one transaction), then an env step on a lane.
        let infer_end = m.gpu(t_ready, model.infer_per_sample_ms, waiting);
        let env_end = m.cpu(infer_end);
        ready.push(Reverse((F(env_end), id)));
    }
    // Account the final partial window's training.
    while trainer_pending > 0 {
        m.gpu(m.gpu_free_at(), train_cost, 0);
        m.note_train();
        trains += 1;
        trainer_pending -= 1;
    }
    m.stats.trains = trains;
    m.stats
}

/// Standard DQN with W asynchronous samplers: sampling and training
/// strictly alternate (the sequential dependency of paper §3). Between two
/// consecutive minibatch updates exactly F steps are taken — by up to
/// min(W, F) threads in parallel — and the update itself is a global
/// barrier, since the next actions depend on the new parameters. This is
/// the structural reason Table 1's Standard column stops improving past
/// W = F = 4 threads.
fn sim_standard(model: CostModel, run: SimRun) -> SimStats {
    let mut m = Machine::new(model);
    let w = run.threads;
    let total = run.steps;
    let mut steps: u64 = 0;
    let mut now = 0.0f64;
    // Inline training: sharded learner, but assembly always on the path
    // (the real standard driver uses the direct source regardless).
    let train_cost = model.train_step_ms(run.learner_threads, false, run.prioritized);

    while steps < total {
        // One cycle: F env steps — round-robin over min(W, F) threads,
        // each thread's steps chained — then one training update that is
        // a global barrier (the next actions depend on the new theta).
        let k = (run.f.min(total - steps)) as usize;
        let contenders = k.min(w);
        let mut thread_ready = vec![now; contenders];
        for i in 0..k {
            let j = i % contenders;
            let infer_end = m.gpu(thread_ready[j], model.infer_per_sample_ms, contenders - 1);
            thread_ready[j] = m.cpu(infer_end);
        }
        let cycle_end = thread_ready.iter().copied().fold(now, f64::max);
        steps += k as u64;
        // The update: a global barrier on the device.
        now = m.gpu(cycle_end, train_cost, 0);
        m.note_train();
    }
    m.stats
}

/// Synchronized execution: rounds of one batched inference + W parallel
/// env steps.
fn sim_sync(model: CostModel, run: SimRun, concurrent: bool) -> SimStats {
    let mut m = Machine::new(model);
    let w = run.threads;
    let total = run.steps;
    // Concurrent trainer may overlap assembly via prefetch; the inline
    // (synchronized-only) path always pays it, like the real driver.
    let train_cost =
        model.train_step_ms(run.learner_threads, concurrent && run.prefetch, run.prioritized);

    let mut steps: u64 = 0;
    let mut trains: u64 = 0;
    let mut states_ready = 0.0f64;
    let mut window_end = run.c.min(total);
    let mut trainer_pending = if concurrent { run.c.min(total) / run.f } else { 0 };
    let mut trainer_free = 0.0f64;

    while steps < total {
        if concurrent {
            // Trainer fills device idle time before the round's inference.
            while trainer_pending > 0
                && trainer_free.max(m.gpu_free_at()) + model.txn_eff(1) + train_cost <= states_ready
            {
                let end = m.gpu(trainer_free, train_cost, 0);
                m.note_train();
                trains += 1;
                trainer_pending -= 1;
                trainer_free = end;
            }
        }
        // One batched inference for all W samplers (a single transaction).
        let infer_end = m.gpu(states_ready, model.infer_per_sample_ms * w as f64, 0);
        // W env steps in parallel on the CPU pool.
        states_ready = m.cpu_phase(infer_end, w);
        steps += w as u64;

        if concurrent {
            if steps >= window_end {
                while trainer_pending > 0 {
                    let end = m.gpu(trainer_free.max(states_ready), train_cost, 0);
                    m.note_train();
                    trains += 1;
                    trainer_pending -= 1;
                    trainer_free = end;
                }
                states_ready = m.sync(states_ready.max(trainer_free));
                trainer_free = states_ready;
                if steps < total {
                    window_end = (window_end + run.c).min(total);
                    trainer_pending = ((window_end - steps) / run.f).max(1);
                }
            }
        } else {
            // Training blocks the loop after the round.
            while trains < steps / run.f {
                states_ready = m.gpu(states_ready, train_cost, 0);
                m.note_train();
                trains += 1;
            }
        }
    }
    m.stats.trains = trains;
    m.stats
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExecMode;

    fn run(threads: usize) -> SimRun {
        // Scaled-down: 20k steps, C=1000 — same ratios as the paper setup.
        SimRun { steps: 20_000, c: 1_000, f: 4, threads, ..SimRun::default() }
    }

    fn hours(mode: ExecMode, threads: usize) -> f64 {
        // Scale to 50M steps like the paper (x50 of 1M; here x2500 of 20k).
        let s = simulate(CostModel::gtx1080_i7(), run(threads), mode);
        s.makespan_ms * (50_000_000.0 / 20_000.0) / 3_600_000.0
    }

    #[test]
    fn single_thread_matches_paper_anchors() {
        let std1 = hours(ExecMode::Standard, 1);
        let conc1 = hours(ExecMode::Concurrent, 1);
        assert!((std1 - 25.08).abs() < 2.0, "std-1 {std1:.2} h (paper 25.08)");
        assert!((conc1 - 20.64).abs() < 2.5, "conc-1 {conc1:.2} h (paper 20.64)");
        assert!(conc1 < std1, "concurrency must help at W=1");
    }

    #[test]
    fn orderings_match_table1() {
        for w in [2usize, 4, 8] {
            let std = hours(ExecMode::Standard, w);
            let conc = hours(ExecMode::Concurrent, w);
            let sync = hours(ExecMode::Synchronized, w);
            let both = hours(ExecMode::Both, w);
            assert!(conc < std, "W={w}: conc {conc:.1} !< std {std:.1}");
            assert!(sync < std * 1.02, "W={w}: sync {sync:.1} !<= std {std:.1}");
            assert!(both < sync, "W={w}: both {both:.1} !< sync {sync:.1}");
            assert!(both < conc, "W={w}: both {both:.1} !< conc {conc:.1}");
        }
    }

    #[test]
    fn threads_help_each_mode() {
        for mode in [ExecMode::Standard, ExecMode::Concurrent, ExecMode::Synchronized, ExecMode::Both] {
            let h2 = hours(mode, 2);
            let h8 = hours(mode, 8);
            assert!(h8 < h2 * 1.01, "{mode:?}: 8 threads {h8:.1} !<= 2 threads {h2:.1}");
        }
    }

    #[test]
    fn standard_plateaus_but_both_keeps_scaling() {
        let std4 = hours(ExecMode::Standard, 4);
        let std8 = hours(ExecMode::Standard, 8);
        // Paper: 16.84 -> 16.92 (no gain past W = F = 4 threads).
        assert!((std8 - std4).abs() < std4 * 0.05,
                "standard should plateau: {std4:.1} -> {std8:.1}");
        let both4 = hours(ExecMode::Both, 4);
        let both8 = hours(ExecMode::Both, 8);
        assert!(both8 < both4, "both must keep scaling: {both4:.1} -> {both8:.1}");
    }

    #[test]
    fn headline_speedup_in_range() {
        let std1 = hours(ExecMode::Standard, 1);
        let both8 = hours(ExecMode::Both, 8);
        let speedup = std1 / both8;
        // Paper headline: 2.78x (25.08 h -> 9.02 h).
        assert!((2.3..3.3).contains(&speedup), "speedup {speedup:.2}x (paper 2.78x)");
    }

    #[test]
    fn parallel_learner_and_prefetch_shrink_makespan() {
        // On a model where training and sampling genuinely cost time on
        // the trainer path, sharding the learner and overlapping batch
        // assembly must both shorten the simulated schedule.
        let mut model = CostModel::gtx1080_i7();
        model.train_ms = 3.0; // train-dominated regime
        model.train_parallel_frac = 0.9;
        model.sample_ms = 0.4;
        let base = simulate(model, run(4), ExecMode::Both);
        let sharded = simulate(
            model,
            SimRun { learner_threads: 4, ..run(4) },
            ExecMode::Both,
        );
        let piped = simulate(
            model,
            SimRun { learner_threads: 4, prefetch: true, ..run(4) },
            ExecMode::Both,
        );
        assert!(
            sharded.makespan_ms < base.makespan_ms,
            "4 learner lanes must beat 1: {} vs {}",
            sharded.makespan_ms,
            base.makespan_ms
        );
        assert!(
            piped.makespan_ms <= sharded.makespan_ms,
            "prefetch must not lengthen the schedule: {} vs {}",
            piped.makespan_ms,
            sharded.makespan_ms
        );
        // Work accounting is unchanged — only the schedule compresses.
        assert_eq!(base.env_steps, piped.env_steps);
        assert_eq!(base.trains, piped.trains);
    }

    #[test]
    fn prioritized_replay_adds_tree_cost_prefetch_cannot_hide() {
        let mut model = CostModel::gtx1080_i7();
        model.train_ms = 3.0;
        model.sample_ms = 0.4;
        model.tree_ms = 0.3;
        let uniform = simulate(
            model,
            SimRun { prefetch: true, ..run(4) },
            ExecMode::Both,
        );
        let prioritized = simulate(
            model,
            SimRun { prefetch: true, prioritized: true, ..run(4) },
            ExecMode::Both,
        );
        assert!(
            prioritized.makespan_ms > uniform.makespan_ms,
            "tree ops must lengthen the schedule: {} vs {}",
            prioritized.makespan_ms,
            uniform.makespan_ms
        );
        assert_eq!(uniform.trains, prioritized.trains, "same work, different cost");
        // The paper calibration (tree_ms = 0) keeps Tables 1-3 pinned.
        let paper = CostModel::gtx1080_i7();
        let a = simulate(paper, run(8), ExecMode::Both);
        let b = simulate(paper, SimRun { prioritized: true, ..run(8) }, ExecMode::Both);
        assert_eq!(a.makespan_ms, b.makespan_ms);
    }

    #[test]
    fn learner_knobs_are_neutral_on_the_paper_calibration() {
        // gtx1080_i7 folds sampling into train_ms (sample_ms = 0) and
        // models the GPU's fused train step (train_parallel_frac = 0), so
        // Tables 1-3 stay pinned regardless of BOTH knobs.
        let m = CostModel::gtx1080_i7();
        let a = simulate(m, run(8), ExecMode::Both);
        let b = simulate(
            m,
            SimRun { learner_threads: 4, prefetch: true, ..run(8) },
            ExecMode::Both,
        );
        assert_eq!(a.makespan_ms, b.makespan_ms);
    }

    #[test]
    fn fleet_procs_are_neutral_on_the_paper_calibration() {
        // gtx1080_i7 models the paper's one-process testbed (net_ms = 0),
        // so the fleet knob is a structural no-op and the Table 1-3
        // anchors stay pinned exactly.
        let m = CostModel::gtx1080_i7();
        for w in [1usize, 4, 8] {
            let a = simulate(m, run(w), ExecMode::Concurrent);
            let b = simulate(m, SimRun { fleet_procs: 4, ..run(w) }, ExecMode::Concurrent);
            assert_eq!(a.makespan_ms, b.makespan_ms, "W={w}");
            assert_eq!(a.env_steps, b.env_steps, "W={w}");
            assert_eq!(a.trains, b.trains, "W={w}");
        }
        let std1 = hours(ExecMode::Standard, 1);
        let conc1 = hours(ExecMode::Concurrent, 1);
        let both8 = hours(ExecMode::Both, 8);
        assert!((std1 - 25.08).abs() < 2.0, "Table 1 anchor moved: {std1:.2} h");
        assert!((conc1 - 20.64).abs() < 2.5, "Table 2 anchor moved: {conc1:.2} h");
        assert!(
            (2.3..3.3).contains(&(std1 / both8)),
            "Table 3 headline moved: {:.2}x",
            std1 / both8
        );
    }

    #[test]
    fn fleet_wire_cost_lengthens_barriers_when_modeled() {
        // A calibration with a real wire cost: every window barrier pays
        // net_ms per sampler process, so makespan grows monotonically with
        // the process count while the work accounting stays identical.
        let mut m = CostModel::gtx1080_i7();
        m.net_ms = 1.5;
        let solo = simulate(m, run(4), ExecMode::Concurrent);
        let two = simulate(m, SimRun { fleet_procs: 2, ..run(4) }, ExecMode::Concurrent);
        let four = simulate(m, SimRun { fleet_procs: 4, ..run(4) }, ExecMode::Concurrent);
        assert!(
            solo.makespan_ms < two.makespan_ms && two.makespan_ms < four.makespan_ms,
            "wire cost must lengthen the schedule: {} / {} / {}",
            solo.makespan_ms,
            two.makespan_ms,
            four.makespan_ms
        );
        assert_eq!(solo.env_steps, four.env_steps);
        assert_eq!(solo.trains, four.trains);
        // 19 inter-window barriers x 1.5 ms x 4 procs bounds the damage.
        assert!(four.makespan_ms - solo.makespan_ms <= 19.0 * 1.5 * 4.0 + 1e-6);
    }

    #[test]
    fn sync_cuts_transactions_by_w() {
        let model = CostModel::gtx1080_i7();
        let a = simulate(model, run(8), ExecMode::Standard);
        let s = simulate(model, run(8), ExecMode::Synchronized);
        let a_infers = a.gpu_transactions - a.trains;
        let s_infers = s.gpu_transactions - s.trains;
        assert!(
            (s_infers as f64) < (a_infers as f64) / 6.0,
            "SE infers {s_infers} vs async {a_infers}"
        );
    }
}
