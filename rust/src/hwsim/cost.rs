//! Cost model for the simulated machine.
//!
//! The paper's testbed (4-core/8-thread i7-7700K + GTX 1080) is not
//! available in this container (1 CPU core, no GPU), so Tables 1-3 are
//! regenerated through a discrete-event simulation whose per-task costs
//! come from one of two calibrations:
//!
//! * [`CostModel::gtx1080_i7`] — fitted to the paper's own single-thread
//!   measurements (Table 1, column "Standard"/"Concurrent", W=1), which
//!   pin d_env + d_infer(1) + d_train/F; the contention coefficient is
//!   fitted to the standard-mode thread plateau. rust/DESIGN.md §3 documents
//!   the derivation.
//! * [`CostModel::from_measured`] — calibrated from live benchmarks of
//!   THIS container's env-step / infer / train costs (see
//!   `examples/speed_ablation.rs --calibrate`), so the DES can be
//!   validated against real scaled runs on the same machine.

/// All durations in milliseconds.
#[derive(Clone, Copy, Debug)]
pub struct CostModel {
    /// Parallelizable part of one agent-level env step (simulation +
    /// rendering + preprocessing on a CPU lane).
    pub env_step_ms: f64,
    /// Host-serialized per-step cost (action selection, dispatch,
    /// bookkeeping) on one global resource — the reference
    /// implementation's Python GIL. Zero for a GIL-free host.
    pub serial_ms: f64,
    /// Fixed per-transaction device overhead (dispatch + transfer setup).
    pub txn_ms: f64,
    /// Per-sample inference compute on the device.
    pub infer_per_sample_ms: f64,
    /// One minibatch gradient step on the device (serial learner).
    pub train_ms: f64,
    /// Fraction of `train_ms` that shards across learner compute lanes
    /// (the per-sample forward/backward); the residue is serial (phase
    /// scheduling, optimizer tail, reduction stitch-up). Amdahl governs
    /// the sharded cost — see [`CostModel::train_ms_sharded`].
    pub train_parallel_frac: f64,
    /// Host-side minibatch assembly (replay sample + stack reconstruction)
    /// on the trainer's critical path. The prefetch pipeline overlaps it
    /// with compute, removing it from the path entirely.
    pub sample_ms: f64,
    /// Barrier-side sum-tree cost of prioritized replay per train step:
    /// the O(B log N) TD-priority updates that run at the window barrier
    /// and can never be hidden by prefetch. The *descent* half of
    /// prioritized sampling rides in `sample_ms` (it is batch-assembly
    /// work the prefetch worker overlaps exactly like uniform draws).
    /// Zero for uniform replay; calibrate from the `update_b32` row of
    /// `cargo bench --bench replay_sample` (and fold the cycle-minus-
    /// update remainder into `sample_ms` for prioritized projections).
    pub tree_ms: f64,
    /// Target sync + staging flush at a window barrier.
    pub sync_ms: f64,
    /// Per-sampler-process fleet wire cost at a window barrier
    /// (rust/DESIGN.md §14): draining one process's window upload plus its
    /// share of the theta_minus broadcast. The barrier pays
    /// `net_ms * fleet_procs`; zero whenever the run is single-process
    /// (`SimRun::fleet_procs == 0`).
    pub net_ms: f64,
    /// Physical CPU lanes usable by env simulation.
    pub cores: usize,
    /// Bus-contention coefficient: when q callers contend for the device,
    /// each transaction's overhead becomes txn_ms * (1 + contention*(q-1)).
    /// This is the Figure 3(a) saturation effect.
    pub contention: f64,
    /// Host-serial discount under Synchronized Execution: batching the
    /// per-step bookkeeping (action selection over a W-row Q matrix, one
    /// dispatch instead of W) shrinks the serialized host cost per step.
    pub batch_host_discount: f64,
}

impl CostModel {
    /// Device time for one inference transaction of `batch` samples,
    /// given `q` concurrent contenders.
    pub fn infer_ms(&self, batch: usize, q: usize) -> f64 {
        self.txn_eff(q) + self.infer_per_sample_ms * batch as f64
    }

    pub fn train_total_ms(&self, q: usize) -> f64 {
        self.txn_eff(q) + self.train_ms
    }

    /// Train compute with the minibatch sharded over `learner_threads`
    /// lanes (capped at the machine's cores): serial residue + parallel
    /// fraction / lanes. `learner_threads = 1` is exactly `train_ms`.
    pub fn train_ms_sharded(&self, learner_threads: usize) -> f64 {
        let lanes = learner_threads.clamp(1, self.cores.max(1)) as f64;
        self.train_ms * ((1.0 - self.train_parallel_frac) + self.train_parallel_frac / lanes)
    }

    /// One trainer-visible train step: sharded compute, plus the batch
    /// assembly cost unless the prefetch pipeline hides it, plus the
    /// (never-hidden) barrier-side sum-tree update cost when replay is
    /// prioritized.
    pub fn train_step_ms(&self, learner_threads: usize, prefetch: bool, prioritized: bool) -> f64 {
        self.train_ms_sharded(learner_threads)
            + if prefetch { 0.0 } else { self.sample_ms }
            + if prioritized { self.tree_ms } else { 0.0 }
    }

    pub fn txn_eff(&self, q: usize) -> f64 {
        self.txn_ms * (1.0 + self.contention * (q.saturating_sub(1)) as f64)
    }

    /// Fitted to the paper's Table 1:
    ///   concurrent W=1 (train fully masked): serial + env + txn + infer
    ///     = 20.64 h / 50M steps = 1.486 ms/step
    ///   standard W=1 adds (txn + train)/F   = (25.08-20.64) h -> 0.32 ms
    /// Full masking at W=1 requires txn+train <= serial+env; the split
    /// between `serial_ms` (GIL-serialized host work) and `env_step_ms`
    /// (parallel simulation) plus `contention` are fitted to the paper's
    /// thread-scaling columns. The standard-mode plateau at W >= F falls
    /// out structurally (only F steps fit between mandatory trains).
    pub fn gtx1080_i7() -> CostModel {
        CostModel {
            env_step_ms: 0.58,
            serial_ms: 0.72,
            txn_ms: 0.16,
            infer_per_sample_ms: 0.026,
            train_ms: 1.16,
            // The paper's GPU executes one fused train step whose internal
            // parallelism is already inside train_ms, and sampling cost is
            // folded into the Table 1 calibration — so BOTH learner knobs
            // are structural no-ops on this model (tables stay pinned):
            // nothing of train_ms reshards across host lanes, and there is
            // no separate assembly cost to overlap. tree_ms likewise: the
            // paper trains uniform replay, so Tables 1-3 stay pinned.
            train_parallel_frac: 0.0,
            sample_ms: 0.0,
            tree_ms: 0.0,
            sync_ms: 2.0,
            // The paper's testbed is one process on one box — no wire.
            // Zero keeps Tables 1-3 pinned regardless of `fleet_procs`
            // (structural no-op, like the learner knobs above).
            net_ms: 0.0,
            cores: 6,
            contention: 0.25,
            batch_host_discount: 0.65,
        }
    }

    /// Build from live measurements (milliseconds). `sample_ms` can be
    /// measured with `cargo bench --bench train_throughput` (the
    /// `sample/assemble_b32` row) and patched onto the returned model.
    pub fn from_measured(
        env_step_ms: f64,
        infer_b1_ms: f64,
        infer_b8_ms: f64,
        train_ms: f64,
        cores: usize,
    ) -> CostModel {
        // Linear fit: infer(b) = txn + per_sample*b through the two points.
        let per_sample = ((infer_b8_ms - infer_b1_ms) / 7.0).max(1e-6);
        let txn = (infer_b1_ms - per_sample).max(1e-6);
        CostModel {
            env_step_ms,
            serial_ms: 0.0, // rust host: no GIL-equivalent serial section
            txn_ms: txn,
            infer_per_sample_ms: per_sample,
            train_ms,
            // Structural estimate, NOT a measurement: Phase A/B dominate
            // the native train step and shard cleanly, with the optimizer
            // tail + phase barriers as serial residue. Calibrate with
            // `cargo bench --bench train_throughput` and overwrite this
            // field (and sample_ms, from its sample/assemble_b32 row;
            // tree_ms from `cargo bench --bench replay_sample`) before
            // trusting learner-thread projections in --real mode.
            train_parallel_frac: 0.9,
            sample_ms: 0.0,
            tree_ms: 0.0,
            sync_ms: 2.0 * train_ms.max(1.0),
            // Calibrate from `cargo bench --bench fleet_throughput`
            // (param_broadcast + upload rows) before trusting fleet
            // projections.
            net_ms: 0.0,
            cores,
            contention: 0.55,
            batch_host_discount: 1.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_fit_reproduces_single_thread_hours() {
        let m = CostModel::gtx1080_i7();
        // Standard W=1: every step pays infer + host + env; every 4th a train.
        let step_base = m.infer_ms(1, 1) + m.serial_ms + m.env_step_ms;
        let hours = (step_base + m.train_total_ms(1) / 4.0) * 50e6 / 3_600e3;
        assert!((hours - 25.08).abs() < 1.5, "std-1: {hours:.2} h");
        // Near-full masking feasible: one train ~fits inside one env gap.
        assert!(m.train_total_ms(1) <= (m.serial_ms + m.env_step_ms) * 1.05);
        // Concurrent W=1: train fully masked.
        let hours_c = step_base * 50e6 / 3_600e3;
        assert!((hours_c - 20.64).abs() < 1.0, "conc-1: {hours_c:.2} h");
    }

    #[test]
    fn batching_amortizes_txn() {
        let m = CostModel::gtx1080_i7();
        let one_by_one = 8.0 * m.infer_ms(1, 8);
        let batched = m.infer_ms(8, 1);
        assert!(batched < one_by_one / 2.0, "{batched} vs {one_by_one}");
    }

    #[test]
    fn contention_inflates_txn() {
        let m = CostModel::gtx1080_i7();
        assert!(m.txn_eff(8) > 2.0 * m.txn_eff(1));
        assert_eq!(m.txn_eff(1), m.txn_ms);
    }

    #[test]
    fn measured_fit_roundtrip() {
        let m = CostModel::from_measured(2.0, 1.0, 2.4, 10.0, 1);
        assert!((m.infer_ms(1, 1) - 1.0).abs() < 1e-9);
        assert!((m.infer_ms(8, 1) - 2.4).abs() < 1e-9);
    }

    #[test]
    fn sharded_train_follows_amdahl() {
        let mut m = CostModel::gtx1080_i7();
        m.train_parallel_frac = 0.8;
        // One lane is exactly the serial cost.
        assert!((m.train_ms_sharded(1) - m.train_ms).abs() < 1e-12);
        // More lanes monotonically shrink it...
        assert!(m.train_ms_sharded(2) < m.train_ms_sharded(1));
        assert!(m.train_ms_sharded(4) < m.train_ms_sharded(2));
        // ...down to the serial residue, never below.
        let floor = m.train_ms * (1.0 - m.train_parallel_frac);
        assert!(m.train_ms_sharded(64) >= floor - 1e-12);
        // Lanes cap at the machine's cores.
        assert!((m.train_ms_sharded(64) - m.train_ms_sharded(m.cores)).abs() < 1e-12);
    }

    #[test]
    fn prefetch_removes_sample_cost_from_train_path() {
        let mut m = CostModel::gtx1080_i7();
        m.sample_ms = 0.3;
        let inline = m.train_step_ms(1, false, false);
        let overlapped = m.train_step_ms(1, true, false);
        assert!((inline - overlapped - 0.3).abs() < 1e-12);
        // Default calibration folds sampling into train_ms, so the paper
        // tables are insensitive to the prefetch knob.
        let paper = CostModel::gtx1080_i7();
        assert_eq!(paper.train_step_ms(1, false, false), paper.train_step_ms(1, true, false));
    }

    #[test]
    fn tree_cost_is_prioritized_only_and_prefetch_cannot_hide_it() {
        let mut m = CostModel::gtx1080_i7();
        m.sample_ms = 0.3;
        m.tree_ms = 0.2;
        // Uniform path is untouched by the tree knob.
        assert_eq!(m.train_step_ms(1, false, false), m.train_ms + 0.3);
        // Prioritized adds the tree cost on top of assembly...
        assert!((m.train_step_ms(1, false, true) - (m.train_ms + 0.3 + 0.2)).abs() < 1e-12);
        // ...and prefetch hides assembly but NOT the tree ops.
        assert!((m.train_step_ms(1, true, true) - (m.train_ms + 0.2)).abs() < 1e-12);
        // Paper calibration: prioritized is a structural no-op (tables
        // stay pinned).
        let paper = CostModel::gtx1080_i7();
        assert_eq!(paper.train_step_ms(1, true, true), paper.train_step_ms(1, true, false));
    }
}
