//! Minimal JSON parser + writer (serde/serde_json are unavailable offline).
//!
//! Supports the full JSON grammar the artifact manifest, golden files, and
//! report outputs need: objects, arrays, strings (with escapes), numbers,
//! bools, null. Numbers are held as f64 — adequate for every field we read.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj["a"]["b"]` chained access that errors with the full path.
    pub fn at(&self, path: &[&str]) -> anyhow::Result<&Json> {
        let mut cur = self;
        for (i, key) in path.iter().enumerate() {
            cur = cur.get(key).ok_or_else(|| {
                anyhow::anyhow!("missing json key {:?}", &path[..=i])
            })?;
        }
        Ok(cur)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Numeric array -> Vec<f64>.
    pub fn as_f64_vec(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    // -- writer ------------------------------------------------------------

    pub fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    x.write(out);
                }
                out.push('}');
            }
        }
    }

    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("unexpected end"))? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek().ok_or_else(|| self.err("unterminated string"))? {
                b'"' => {
                    self.i += 1;
                    return Ok(s);
                }
                b'\\' => {
                    self.i += 1;
                    match self.peek().ok_or_else(|| self.err("bad escape"))? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                _ => {
                    // Consume one UTF-8 scalar.
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    if start + len > self.b.len() {
                        return Err(self.err("bad utf8"));
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..start + len])
                            .map_err(|_| self.err("bad utf8"))?,
                    );
                    self.i += len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9' | b'.' | b'e' | b'E' | b'+' | b'-')) {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

/// Convenience builder for report output.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": "hi\nthere", "c": true, "d": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.at(&["a"]).unwrap().as_f64_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().as_str().unwrap(), "hi\nthere");
        assert_eq!(v.get("c").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("d"), Some(&Json::Null));
        // write -> parse roundtrip
        let again = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, again);
    }

    #[test]
    fn nested_objects() {
        let v = Json::parse(r#"{"x":{"y":{"z":[{"w":7}]}}}"#).unwrap();
        let w = v.at(&["x", "y", "z"]).unwrap().as_arr().unwrap()[0]
            .get("w")
            .unwrap()
            .as_usize()
            .unwrap();
        assert_eq!(w, 7);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("").is_err());
    }

    #[test]
    fn escapes() {
        let v = Json::parse(r#""A\t\\""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\\");
        let out = Json::Str("a\"b\\c\n".into()).to_string();
        assert_eq!(Json::parse(&out).unwrap().as_str().unwrap(), "a\"b\\c\n");
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    /// Deep nesting round trip: the checkpoint manifest nests objects in
    /// arrays in objects; write -> parse must be the identity at any depth.
    #[test]
    fn roundtrip_deeply_nested() {
        let mut inner = Json::Obj(BTreeMap::new());
        for depth in 0..24 {
            let mut m = BTreeMap::new();
            m.insert("d".to_string(), Json::Num(depth as f64));
            m.insert("child".to_string(), inner);
            m.insert(
                "arr".to_string(),
                Json::Arr(vec![Json::Null, Json::Bool(depth % 2 == 0), Json::Str(format!("level {depth}"))]),
            );
            inner = Json::Obj(m);
        }
        let text = inner.to_string();
        assert_eq!(Json::parse(&text).unwrap(), inner);
        // And a second write is byte-stable (canonical key order).
        assert_eq!(Json::parse(&text).unwrap().to_string(), text);
    }

    /// Escape round trip over every escape the writer emits plus \u forms
    /// the parser must accept.
    #[test]
    fn roundtrip_escapes_exhaustive() {
        let nasty = "quote:\" backslash:\\ newline:\n tab:\t cr:\r ctrl:\u{1} high:\u{7f} é漢🤖";
        let v = Json::Obj(
            [(nasty.to_string(), Json::Str(nasty.to_string()))].into_iter().collect(),
        );
        let text = v.to_string();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, v, "escaped keys and values survive");
        // Parser-side \u escapes (the writer emits them only for control chars).
        assert_eq!(Json::parse(r#""Aé""#).unwrap().as_str().unwrap(), "Aé");
        assert_eq!(Json::parse(r#""\b\f\/""#).unwrap().as_str().unwrap(), "\u{8}\u{c}/");
    }

    /// Large integers: the checkpoint manifest stores step counters and
    /// byte offsets; every integer up to 2^53 - 1 must round-trip exactly
    /// (f64 holds them losslessly and the writer prints them as integers).
    #[test]
    fn roundtrip_large_integers() {
        for n in [
            0u64,
            1,
            4_294_967_296,            // 2^32
            999_999_999_999_999,      // largest 15-digit int (< 1e15 writer cutoff)
            9_007_199_254_740_991,    // 2^53 - 1, f64-exact
        ] {
            let v = Json::Num(n as f64);
            let text = v.to_string();
            let back = Json::parse(&text).unwrap();
            assert_eq!(back.as_f64().unwrap() as u64, n, "{n} survived");
            // Integers below the writer's 1e15 cutoff print without an
            // exponent or fraction, so offsets stay grep-able.
            if n < 1_000_000_000_000_000 {
                assert_eq!(text, n.to_string());
            }
        }
        // Negative and boundary floats still round trip as numbers.
        for v in [-1.0f64, -2.5, 1e300, -1e-300, 0.1] {
            let back = Json::parse(&Json::Num(v).to_string()).unwrap();
            assert_eq!(back.as_f64().unwrap(), v);
        }
    }

    /// Arrays of objects (the manifest's section table shape).
    #[test]
    fn roundtrip_section_table_shape() {
        let table = Json::Arr(
            (0..5)
                .map(|i| {
                    obj(vec![
                        ("name", Json::Str(format!("section-{i}"))),
                        ("offset", Json::Num((i * 1_000_003) as f64)),
                        ("fnv1a", Json::Str(format!("{:016x}", 0xdead_beefu64 + i))),
                    ])
                })
                .collect(),
        );
        let back = Json::parse(&table.to_string()).unwrap();
        assert_eq!(back, table);
        assert_eq!(back.as_arr().unwrap().len(), 5);
        assert_eq!(
            back.as_arr().unwrap()[3].at(&["offset"]).unwrap().as_usize().unwrap(),
            3 * 1_000_003
        );
    }

    #[test]
    fn real_manifest_shape() {
        let src = r#"{"version":2,"configs":{"tiny":{"param_count":27082,
            "entries":{"infer_b1":{"file":"tiny_infer_b1.hlo.txt",
            "inputs":[{"dtype":"float32","shape":[27082]}]}}}}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(
            v.at(&["configs", "tiny", "param_count"]).unwrap().as_usize().unwrap(),
            27082
        );
    }
}
