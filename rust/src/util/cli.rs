//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `tempo-dqn <subcommand> [--key value | --key=value | --flag] ...`
//! Unknown keys are collected so the caller can reject them with a helpful
//! message listing valid options.

use std::collections::BTreeMap;

#[derive(Debug, Default, Clone)]
pub struct Args {
    pub subcommand: Option<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of raw arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(rest) = tok.strip_prefix("--") {
                if rest.is_empty() {
                    // `--` ends option parsing.
                    args.positional.extend(it);
                    break;
                }
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                    let v = it.next().unwrap();
                    args.options.insert(rest.to_string(), v);
                } else {
                    args.flags.push(rest.to_string());
                }
            } else if args.subcommand.is_none() && args.positional.is_empty() {
                args.subcommand = Some(tok);
            } else {
                args.positional.push(tok);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn str_opt(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.str_opt(name).unwrap_or(default)
    }

    pub fn usize_opt(&self, name: &str) -> anyhow::Result<Option<usize>> {
        self.options
            .get(name)
            .map(|v| v.parse::<usize>().map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")))
            .transpose()
    }

    pub fn u64_or(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects an integer, got {v:?}")),
        }
    }

    pub fn usize_or(&self, name: &str, default: usize) -> anyhow::Result<usize> {
        Ok(self.usize_opt(name)?.unwrap_or(default))
    }

    pub fn f64_or(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.options.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow::anyhow!("--{name} expects a number, got {v:?}")),
        }
    }

    /// Comma-separated list option, e.g. `--threads 1,2,4,8`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> anyhow::Result<Vec<usize>> {
        match self.options.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .filter(|s| !s.is_empty())
                .map(|s| s.trim().parse::<usize>().map_err(|_| anyhow::anyhow!("--{name}: bad integer {s:?}")))
                .collect(),
        }
    }

    /// Error if any provided option key is not in `valid`.
    pub fn check_known(&self, valid: &[&str]) -> anyhow::Result<()> {
        for key in self.options.keys().chain(self.flags.iter()) {
            if !valid.contains(&key.as_str()) {
                anyhow::bail!("unknown option --{key}; valid options: {}",
                              valid.iter().map(|v| format!("--{v}")).collect::<Vec<_>>().join(" "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse("train --config small --steps 1000 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_opt("config"), Some("small"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 1000);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn wxb_knobs_parse_in_both_forms() {
        // The coordinator's W×B knobs: --threads W --envs-per-thread B.
        let a = parse("train --threads 2 --envs-per-thread 4");
        assert_eq!(a.usize_or("threads", 1).unwrap(), 2);
        assert_eq!(a.usize_or("envs-per-thread", 1).unwrap(), 4);
        let b = parse("train --envs-per-thread=8");
        assert_eq!(b.usize_or("envs-per-thread", 1).unwrap(), 8);
        assert_eq!(b.usize_or("envs-per-thread-missing", 1).unwrap(), 1);
    }

    #[test]
    fn learner_knobs_parse_in_both_forms() {
        // The parallel-learner knobs: --learner-threads N --prefetch-batches N.
        let a = parse("train --learner-threads 4 --prefetch-batches 2");
        assert_eq!(a.usize_or("learner-threads", 1).unwrap(), 4);
        assert_eq!(a.usize_or("prefetch-batches", 1).unwrap(), 2);
        let b = parse("train --learner-threads=8 --prefetch-batches=0");
        assert_eq!(b.usize_or("learner-threads", 1).unwrap(), 8);
        assert_eq!(b.usize_or("prefetch-batches", 1).unwrap(), 0);
    }

    #[test]
    fn equals_form() {
        let a = parse("bench --mode=both --threads=8");
        assert_eq!(a.str_opt("mode"), Some("both"));
        assert_eq!(a.usize_or("threads", 1).unwrap(), 8);
    }

    #[test]
    fn lists_and_defaults() {
        let a = parse("bench --threads 1,2,4,8");
        assert_eq!(a.usize_list_or("threads", &[]).unwrap(), vec![1, 2, 4, 8]);
        assert_eq!(a.usize_list_or("missing", &[3]).unwrap(), vec![3]);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse("x --steps abc");
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn unknown_rejected() {
        let a = parse("x --bogus 1");
        assert!(a.check_known(&["steps"]).is_err());
        assert!(a.check_known(&["bogus"]).is_ok());
    }

    #[test]
    fn double_dash_positional() {
        let a = parse("run -- --not-an-option");
        assert_eq!(a.positional, vec!["--not-an-option"]);
    }
}
