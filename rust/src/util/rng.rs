//! Deterministic PRNG for the coordinator (no external crates available).
//!
//! xoshiro256++ seeded via SplitMix64 — fast, high-quality, and trivially
//! reproducible across runs, which the determinism invariants in
//! rust/DESIGN.md §7 rely on. Every thread owns its own stream derived from a
//! root seed + stream id, so per-thread action sequences are independent of
//! scheduling order.

/// SplitMix64 step — used for seeding and as a cheap standalone generator.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// xoshiro256++ PRNG.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a root seed; all four lanes are SplitMix64-derived.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Self { s: [splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm), splitmix64(&mut sm)] }
    }

    /// Independent stream `id` under the same root seed (per-thread RNGs).
    pub fn stream(seed: u64, id: u64) -> Self {
        Self::new(seed ^ id.wrapping_mul(0xA076_1D64_78BD_642F).wrapping_add(0x9E37_79B9))
    }

    /// The full generator state (checkpointing). Restoring via
    /// [`Rng::from_state`] resumes the stream at exactly this position.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator mid-stream from a saved [`Rng::state`].
    pub fn from_state(s: [u64; 4]) -> Self {
        Rng { s }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n). Lemire's unbiased rejection method.
    #[inline]
    pub fn below(&mut self, n: u32) -> u32 {
        debug_assert!(n > 0);
        let mut x = self.next_u32();
        let mut m = (x as u64).wrapping_mul(n as u64);
        let mut lo = m as u32;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u32();
                m = (x as u64).wrapping_mul(n as u64);
                lo = m as u32;
            }
        }
        (m >> 32) as u32
    }

    /// Uniform usize in [0, n).
    #[inline]
    pub fn below_usize(&mut self, n: usize) -> usize {
        debug_assert!(n > 0 && n <= u32::MAX as usize);
        self.below(n as u32) as usize
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.f32()
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller (used by the hwsim jitter model).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-12 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Rng::stream(42, 0);
        let mut b = Rng::stream(42, 1);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn state_round_trip_resumes_mid_stream() {
        let mut a = Rng::new(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let mut b = Rng::from_state(a.state());
        for _ in 0..64 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(9);
        let mut seen = [false; 6];
        for _ in 0..10_000 {
            let x = r.below(6);
            assert!(x < 6);
            seen[x as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn below_unbiased_enough() {
        let mut r = Rng::new(11);
        let n = 3u32;
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.below(n) as usize] += 1;
        }
        for &c in &counts {
            assert!((9_000..11_000).contains(&c), "{counts:?}");
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }
}
