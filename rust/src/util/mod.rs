//! From-scratch utility substrates (this build is fully offline; only the
//! `xla` crate's vendored closure is available — see Cargo.toml).

pub mod cli;
pub mod json;
pub mod rng;
