//! The fleet message catalog (rust/DESIGN.md §14).
//!
//! Every payload is encoded with the checkpoint codec
//! ([`crate::ckpt::ByteWriter`]/[`ByteReader`]): floats travel as raw
//! IEEE-754 bits, so the parameters a sampler acts with are bit-identical
//! to the learner's — the transport half of replicated-mode determinism.
//! Decoders call `ByteReader::finish()`, so a payload with trailing bytes
//! (format drift between peers that somehow share a protocol version)
//! fails loudly with the message named.
//!
//! | kind | message          | direction         | when                      |
//! |------|------------------|-------------------|---------------------------|
//! | 1    | hello            | sampler → learner | connect                   |
//! | 2    | hello-ack        | learner → sampler | after fingerprint check   |
//! | 3    | param-broadcast  | learner → sampler | after every window barrier|
//! | 4    | window-upload    | sampler → learner | after acting each window  |
//! | 5    | heartbeat        | both              | while the other side waits|
//! | 6    | shutdown         | learner → sampler | end of run / slice        |
//! | 7    | act              | client → daemon   | serve inference request   |
//! | 8    | act-result       | daemon → client   | reply to `act`            |
//! | 9    | stats            | client → daemon   | serve stats request       |
//! | 10   | stats-result     | daemon → client   | reply to `stats`          |
//!
//! Kinds 7–10 are the policy-serving daemon's catalog (rust/DESIGN.md §15):
//! same frame layer, same codec, same `PROTOCOL_VERSION` — a fleet peer and
//! a serve client speak the identical transport and differ only in which
//! kinds they exchange.

use anyhow::{bail, Context, Result};

use crate::ckpt::{ByteReader, ByteWriter};
use crate::replay::StagedTransition;

use super::frame::{read_frame, write_frame};

pub const KIND_HELLO: u8 = 1;
pub const KIND_HELLO_ACK: u8 = 2;
pub const KIND_PARAM_BROADCAST: u8 = 3;
pub const KIND_WINDOW_UPLOAD: u8 = 4;
pub const KIND_HEARTBEAT: u8 = 5;
pub const KIND_SHUTDOWN: u8 = 6;
pub const KIND_ACT: u8 = 7;
pub const KIND_ACT_RESULT: u8 = 8;
pub const KIND_STATS: u8 = 9;
pub const KIND_STATS_RESULT: u8 = 10;

/// Human name of a message kind, used by every named wire error.
pub fn kind_name(kind: u8) -> &'static str {
    match kind {
        KIND_HELLO => "hello",
        KIND_HELLO_ACK => "hello-ack",
        KIND_PARAM_BROADCAST => "param-broadcast",
        KIND_WINDOW_UPLOAD => "window-upload",
        KIND_HEARTBEAT => "heartbeat",
        KIND_SHUTDOWN => "shutdown",
        KIND_ACT => "act",
        KIND_ACT_RESULT => "act-result",
        KIND_STATS => "stats",
        KIND_STATS_RESULT => "stats-result",
        _ => "unknown",
    }
}

/// One sampler's complete product for one target window: everything the
/// learner needs to make its shadow of the sampler bit-exact at the
/// barrier. `streams` lists `(global stream id, transitions)` in stream
/// order; `ctxs` carries one [`SamplerCtx::save_state`] blob per owned
/// slot (the same encoding the checkpoint "samplers" section uses).
#[derive(Clone, Debug, Default)]
pub struct WindowUpload {
    /// Absolute target-window index this upload covers.
    pub window: u64,
    /// Environment steps taken (the sampler's `completed` delta).
    pub steps: u64,
    /// Episodes finished (the sampler's `episodes` delta).
    pub episodes: u64,
    /// `(step, raw episode return)` samples finished this window.
    pub returns: Vec<(u64, f64)>,
    /// One sampler-context snapshot per owned slot, in slot order.
    pub ctxs: Vec<Vec<u8>>,
    /// Staged transitions per global stream id, in stream order.
    pub streams: Vec<(u64, Vec<StagedTransition>)>,
}

/// The serving daemon's answer to a `stats` request: enough to watch a
/// deployment without scraping logs — liveness, which checkpoint is live,
/// how the collector is batching, and where the latency mass sits.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ServeStats {
    pub uptime_ms: u64,
    /// Training step of the currently loaded checkpoint.
    pub step: u64,
    /// Successful hot-swaps since startup.
    pub swaps: u64,
    /// Checkpoints the watcher refused (torn / corrupt / wrong network).
    pub swap_skips: u64,
    /// Act requests answered.
    pub requests: u64,
    /// States inferred (>= requests: one request may carry several states).
    pub states: u64,
    /// `(batch width, flush count)` pairs, ascending by width — the
    /// collector's coalescing histogram.
    pub batch_hist: Vec<(u64, u64)>,
    /// Request latency percentiles in microseconds: p50, p90, p99, max.
    pub lat_us: [u64; 4],
}

/// A typed fleet message. See the module table for the protocol roles.
#[derive(Clone, Debug)]
pub enum Msg {
    /// Sampler's opening claim: the JSON config fingerprint of the run it
    /// was launched for (`Coordinator::config_fingerprint` text). The
    /// learner refuses mismatches field-by-field, by name, exactly like a
    /// checkpoint resumed under the wrong configuration.
    Hello { fingerprint: String },
    /// Learner's reply: the sampler owns slots
    /// `first_slot .. first_slot + n_slots`, resumes acting at absolute
    /// step `start` out of `total` (relaxed samplers run ahead of the
    /// learner, so they must stop at the step budget themselves), lags
    /// parameters by `lag` windows, and receives its slots' context
    /// snapshots plus every theta_minus version its first window can
    /// legally act with (`(version tag, parameters)`).
    HelloAck {
        first_slot: u64,
        n_slots: u64,
        start: u64,
        total: u64,
        lag: u64,
        params: Vec<(u64, Vec<f32>)>,
        ctxs: Vec<Vec<u8>>,
    },
    /// theta_minus version `tag` (fresh after the barrier of window
    /// `tag - 1`), broadcast to every sampler.
    ParamBroadcast { tag: u64, theta_minus: Vec<f32> },
    Upload(WindowUpload),
    /// Liveness only; either side skips these wherever a real message is
    /// awaited.
    Heartbeat,
    /// Learner is done with this sampler (run complete or slice bound
    /// reached); the sampler exits cleanly.
    Shutdown { reason: String },
    /// Serve request: `n` stacked frames (`n * STATE_BYTES` bytes,
    /// row-major). `id` is an opaque client token echoed in the reply so a
    /// pipelining client can correlate responses.
    Act { id: u64, n: u64, states: Vec<u8> },
    /// Reply to [`Msg::Act`]: greedy action per state plus the full Q-row
    /// (`n * actions` f32s, raw IEEE-754 bits — bit-identical to a local
    /// `QNet::infer` under the same theta). `step` names the checkpoint the
    /// answer was computed under, so clients observe hot-swaps.
    ActResult { id: u64, step: u64, actions: Vec<u8>, q: Vec<f32> },
    /// Serve stats request (empty payload).
    Stats,
    /// Reply to [`Msg::Stats`].
    StatsResult(ServeStats),
}

impl Msg {
    pub fn kind(&self) -> u8 {
        match self {
            Msg::Hello { .. } => KIND_HELLO,
            Msg::HelloAck { .. } => KIND_HELLO_ACK,
            Msg::ParamBroadcast { .. } => KIND_PARAM_BROADCAST,
            Msg::Upload(_) => KIND_WINDOW_UPLOAD,
            Msg::Heartbeat => KIND_HEARTBEAT,
            Msg::Shutdown { .. } => KIND_SHUTDOWN,
            Msg::Act { .. } => KIND_ACT,
            Msg::ActResult { .. } => KIND_ACT_RESULT,
            Msg::Stats => KIND_STATS,
            Msg::StatsResult(_) => KIND_STATS_RESULT,
        }
    }

    pub fn name(&self) -> &'static str {
        kind_name(self.kind())
    }

    /// Encode the payload (framing is [`super::frame`]'s job).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = ByteWriter::new();
        match self {
            Msg::Hello { fingerprint } => w.put_str(fingerprint),
            Msg::HelloAck { first_slot, n_slots, start, total, lag, params, ctxs } => {
                w.put_u64(*first_slot);
                w.put_u64(*n_slots);
                w.put_u64(*start);
                w.put_u64(*total);
                w.put_u64(*lag);
                w.put_usize(params.len());
                for (tag, theta) in params {
                    w.put_u64(*tag);
                    w.put_f32_slice(theta);
                }
                w.put_usize(ctxs.len());
                for ctx in ctxs {
                    w.put_bytes(ctx);
                }
            }
            Msg::ParamBroadcast { tag, theta_minus } => {
                w.put_u64(*tag);
                w.put_f32_slice(theta_minus);
            }
            Msg::Upload(u) => {
                w.put_u64(u.window);
                w.put_u64(u.steps);
                w.put_u64(u.episodes);
                w.put_usize(u.returns.len());
                for &(step, ret) in &u.returns {
                    w.put_u64(step);
                    w.put_f64(ret);
                }
                w.put_usize(u.ctxs.len());
                for ctx in &u.ctxs {
                    w.put_bytes(ctx);
                }
                w.put_usize(u.streams.len());
                for (stream, items) in &u.streams {
                    w.put_u64(*stream);
                    w.put_usize(items.len());
                    for t in items {
                        w.put_bytes(&t.frame);
                        w.put_u8(t.action);
                        w.put_f32(t.reward);
                        w.put_bool(t.done);
                        w.put_bool(t.start);
                    }
                }
            }
            Msg::Heartbeat => {}
            Msg::Shutdown { reason } => w.put_str(reason),
            Msg::Act { id, n, states } => {
                w.put_u64(*id);
                w.put_u64(*n);
                w.put_bytes(states);
            }
            Msg::ActResult { id, step, actions, q } => {
                w.put_u64(*id);
                w.put_u64(*step);
                w.put_bytes(actions);
                w.put_f32_slice(q);
            }
            Msg::Stats => {}
            Msg::StatsResult(s) => {
                w.put_u64(s.uptime_ms);
                w.put_u64(s.step);
                w.put_u64(s.swaps);
                w.put_u64(s.swap_skips);
                w.put_u64(s.requests);
                w.put_u64(s.states);
                w.put_usize(s.batch_hist.len());
                for &(width, count) in &s.batch_hist {
                    w.put_u64(width);
                    w.put_u64(count);
                }
                for v in s.lat_us {
                    w.put_u64(v);
                }
            }
        }
        w.into_bytes()
    }

    /// Decode a payload of the given kind. Every byte must be consumed
    /// (`finish`), so format drift fails with the message named.
    pub fn decode(kind: u8, payload: &[u8]) -> Result<Msg> {
        let mut r = ByteReader::new(payload);
        let msg = Self::decode_body(kind, &mut r)
            .and_then(|m| r.finish().map(|_| m))
            .with_context(|| format!("decoding {} message", kind_name(kind)))?;
        Ok(msg)
    }

    fn decode_body(kind: u8, r: &mut ByteReader<'_>) -> Result<Msg> {
        Ok(match kind {
            KIND_HELLO => Msg::Hello { fingerprint: r.str()?.to_string() },
            KIND_HELLO_ACK => {
                let first_slot = r.u64()?;
                let n_slots = r.u64()?;
                let start = r.u64()?;
                let total = r.u64()?;
                let lag = r.u64()?;
                let n = r.usize()?;
                let mut params = Vec::with_capacity(n);
                for _ in 0..n {
                    params.push((r.u64()?, r.f32_vec()?));
                }
                let n = r.usize()?;
                let mut ctxs = Vec::with_capacity(n);
                for _ in 0..n {
                    ctxs.push(r.bytes()?.to_vec());
                }
                Msg::HelloAck { first_slot, n_slots, start, total, lag, params, ctxs }
            }
            KIND_PARAM_BROADCAST => {
                Msg::ParamBroadcast { tag: r.u64()?, theta_minus: r.f32_vec()? }
            }
            KIND_WINDOW_UPLOAD => {
                let window = r.u64()?;
                let steps = r.u64()?;
                let episodes = r.u64()?;
                let n = r.usize()?;
                let mut returns = Vec::with_capacity(n);
                for _ in 0..n {
                    returns.push((r.u64()?, r.f64()?));
                }
                let n = r.usize()?;
                let mut ctxs = Vec::with_capacity(n);
                for _ in 0..n {
                    ctxs.push(r.bytes()?.to_vec());
                }
                let n = r.usize()?;
                let mut streams = Vec::with_capacity(n);
                for _ in 0..n {
                    let stream = r.u64()?;
                    let m = r.usize()?;
                    let mut items = Vec::with_capacity(m);
                    for _ in 0..m {
                        items.push(StagedTransition {
                            frame: r.bytes()?.to_vec(),
                            action: r.u8()?,
                            reward: r.f32()?,
                            done: r.bool()?,
                            start: r.bool()?,
                        });
                    }
                    streams.push((stream, items));
                }
                Msg::Upload(WindowUpload { window, steps, episodes, returns, ctxs, streams })
            }
            KIND_HEARTBEAT => Msg::Heartbeat,
            KIND_SHUTDOWN => Msg::Shutdown { reason: r.str()?.to_string() },
            KIND_ACT => Msg::Act {
                id: r.u64()?,
                n: r.u64()?,
                states: r.bytes()?.to_vec(),
            },
            KIND_ACT_RESULT => Msg::ActResult {
                id: r.u64()?,
                step: r.u64()?,
                actions: r.bytes()?.to_vec(),
                q: r.f32_vec()?,
            },
            KIND_STATS => Msg::Stats,
            KIND_STATS_RESULT => {
                let uptime_ms = r.u64()?;
                let step = r.u64()?;
                let swaps = r.u64()?;
                let swap_skips = r.u64()?;
                let requests = r.u64()?;
                let states = r.u64()?;
                let n = r.usize()?;
                let mut batch_hist = Vec::with_capacity(n);
                for _ in 0..n {
                    batch_hist.push((r.u64()?, r.u64()?));
                }
                let mut lat_us = [0u64; 4];
                for v in &mut lat_us {
                    *v = r.u64()?;
                }
                Msg::StatsResult(ServeStats {
                    uptime_ms,
                    step,
                    swaps,
                    swap_skips,
                    requests,
                    states,
                    batch_hist,
                    lat_us,
                })
            }
            other => bail!("unknown fleet message kind {other}"),
        })
    }

    /// Frame and send this message.
    pub fn send(&self, w: &mut impl std::io::Write) -> Result<()> {
        write_frame(w, self.kind(), &self.encode())
    }

    /// Receive and decode the next message (heartbeats included; callers
    /// that await a specific message skip them — see `coordinator::fleet`).
    pub fn recv(r: &mut impl std::io::Read) -> Result<Msg> {
        let (kind, payload) = read_frame(r)?;
        Msg::decode(kind, &payload)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn round_trip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        msg.send(&mut buf).unwrap();
        Msg::recv(&mut Cursor::new(&buf)).unwrap()
    }

    #[test]
    fn hello_and_shutdown_round_trip() {
        match round_trip(&Msg::Hello { fingerprint: "{\"seed\":\"2a\"}".into() }) {
            Msg::Hello { fingerprint } => assert_eq!(fingerprint, "{\"seed\":\"2a\"}"),
            other => panic!("decoded {other:?}"),
        }
        match round_trip(&Msg::Shutdown { reason: "complete".into() }) {
            Msg::Shutdown { reason } => assert_eq!(reason, "complete"),
            other => panic!("decoded {other:?}"),
        }
        assert!(matches!(round_trip(&Msg::Heartbeat), Msg::Heartbeat));
    }

    #[test]
    fn param_broadcast_is_bit_exact() {
        // Raw-bits transport: NaN payloads, -0.0, and denormals all survive.
        let theta = vec![f32::from_bits(0x7FC0_1234), -0.0, 1.5e-42, 3.25];
        let msg = Msg::ParamBroadcast { tag: 7, theta_minus: theta.clone() };
        match round_trip(&msg) {
            Msg::ParamBroadcast { tag, theta_minus } => {
                assert_eq!(tag, 7);
                let got: Vec<u32> = theta_minus.iter().map(|v| v.to_bits()).collect();
                let want: Vec<u32> = theta.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, want);
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn window_upload_round_trips_transitions() {
        let upload = WindowUpload {
            window: 3,
            steps: 64,
            episodes: 2,
            returns: vec![(130, 4.5), (190, -1.0)],
            ctxs: vec![vec![1, 2, 3], vec![]],
            streams: vec![
                (
                    2,
                    vec![StagedTransition {
                        frame: vec![9u8; 16],
                        action: 3,
                        reward: -0.5,
                        done: true,
                        start: false,
                    }],
                ),
                (3, vec![]),
            ],
        };
        match round_trip(&Msg::Upload(upload)) {
            Msg::Upload(u) => {
                assert_eq!(u.window, 3);
                assert_eq!(u.steps, 64);
                assert_eq!(u.episodes, 2);
                assert_eq!(u.returns, vec![(130, 4.5), (190, -1.0)]);
                assert_eq!(u.ctxs, vec![vec![1, 2, 3], vec![]]);
                assert_eq!(u.streams.len(), 2);
                assert_eq!(u.streams[0].0, 2);
                let t = &u.streams[0].1[0];
                assert_eq!(t.frame, vec![9u8; 16]);
                assert_eq!(t.action, 3);
                assert_eq!(t.reward, -0.5);
                assert!(t.done && !t.start);
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn hello_ack_round_trips_params_and_ctxs() {
        let msg = Msg::HelloAck {
            first_slot: 1,
            n_slots: 2,
            start: 128,
            total: 512,
            lag: 1,
            params: vec![(1, vec![0.5, -0.25]), (2, vec![1.0, 2.0])],
            ctxs: vec![vec![0xAB; 8], vec![0xCD; 4]],
        };
        match round_trip(&msg) {
            Msg::HelloAck { first_slot, n_slots, start, total, lag, params, ctxs } => {
                assert_eq!((first_slot, n_slots, start, total, lag), (1, 2, 128, 512, 1));
                assert_eq!(params[1].0, 2);
                assert_eq!(params[1].1, vec![1.0, 2.0]);
                assert_eq!(ctxs[0].len(), 8);
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn act_and_result_round_trip_bit_exact() {
        match round_trip(&Msg::Act { id: 42, n: 2, states: vec![7u8; 32] }) {
            Msg::Act { id, n, states } => {
                assert_eq!((id, n), (42, 2));
                assert_eq!(states, vec![7u8; 32]);
            }
            other => panic!("decoded {other:?}"),
        }
        // Q-rows travel as raw bits like param broadcasts: NaN, -0.0 and
        // denormals must survive so daemon replies stay bit-comparable to
        // a local infer.
        let q = vec![f32::from_bits(0x7FC0_0042), -0.0, 1.5e-42, -2.25];
        let msg = Msg::ActResult { id: 9, step: 1280, actions: vec![3, 0], q: q.clone() };
        match round_trip(&msg) {
            Msg::ActResult { id, step, actions, q: got } => {
                assert_eq!((id, step), (9, 1280));
                assert_eq!(actions, vec![3, 0]);
                let got: Vec<u32> = got.iter().map(|v| v.to_bits()).collect();
                let want: Vec<u32> = q.iter().map(|v| v.to_bits()).collect();
                assert_eq!(got, want);
            }
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn stats_round_trips() {
        assert!(matches!(round_trip(&Msg::Stats), Msg::Stats));
        let stats = ServeStats {
            uptime_ms: 12_500,
            step: 256,
            swaps: 2,
            swap_skips: 1,
            requests: 900,
            states: 1_024,
            batch_hist: vec![(1, 700), (4, 40), (32, 5)],
            lat_us: [90, 240, 900, 4_000],
        };
        match round_trip(&Msg::StatsResult(stats.clone())) {
            Msg::StatsResult(got) => assert_eq!(got, stats),
            other => panic!("decoded {other:?}"),
        }
    }

    #[test]
    fn serve_kinds_fail_trailing_bytes_with_name() {
        let mut payload = Msg::Act { id: 1, n: 1, states: vec![0u8; 4] }.encode();
        payload.push(0xFF);
        let err = format!("{:#}", Msg::decode(KIND_ACT, &payload).unwrap_err());
        assert!(err.contains("act"), "unexpected error: {err}");
        assert!(err.contains("trailing"), "unexpected error: {err}");
    }

    #[test]
    fn trailing_bytes_fail_with_message_named() {
        let mut payload = Msg::Heartbeat.encode();
        payload.push(0); // drifted peer appended a field we don't know
        let err = format!("{:#}", Msg::decode(KIND_HEARTBEAT, &payload).unwrap_err());
        assert!(err.contains("heartbeat"), "unexpected error: {err}");
        assert!(err.contains("trailing"), "unexpected error: {err}");
    }

    #[test]
    fn unknown_kind_is_rejected() {
        let err = Msg::decode(99, &[]).unwrap_err().to_string();
        assert!(err.contains("unknown fleet message kind 99"), "{err}");
    }
}
