//! Message framing: `magic | version | kind | length | checksum | payload`.
//!
//! An 18-byte little-endian header guards every payload:
//!
//! ```text
//! offset  size  field
//! 0       4     magic  b"TDQW"  (catches a non-fleet peer immediately)
//! 4       1     protocol version (bumped on any incompatible change)
//! 5       1     message kind (so corruption errors can name the message)
//! 6       4     payload length (u32; capped, overflow-safe)
//! 10      8     FNV-1a of the payload (crate::ckpt::fnv1a — the same
//!               checksum that guards checkpoint sections)
//! 18      ...   payload
//! ```
//!
//! Failure taxonomy (each is a distinct, greppable, named error — the wire
//! analogue of the checkpoint corruption matrix in
//! tests/checkpoint_resume.rs):
//!
//! * a peer speaking something else entirely → "not a tempo-dqn fleet frame"
//! * a protocol version bump → "wire protocol version" (refused at the
//!   first frame, i.e. at the handshake)
//! * a corrupt length prefix → "frame length ... exceeds" (checked before
//!   any allocation; a near-`u32::MAX` length cannot wrap or OOM)
//! * a flipped payload byte → "checksum mismatch in <message> frame"
//! * a cut connection mid-frame → "truncated"
//! * a cleanly closed connection → "connection closed"
//! * no bytes within the socket read-timeout → "heartbeat timeout"

use std::io::{ErrorKind, Read, Write};

use anyhow::{bail, Context, Result};

use crate::ckpt::fnv1a;

use super::msg::kind_name;

/// Frame magic: present on every frame so a mis-connected peer (an HTTP
/// client, a different tool) is rejected by name, not by a parse error.
pub const MAGIC: [u8; 4] = *b"TDQW";

/// Wire protocol version. Bump on any incompatible frame or message
/// change; peers refuse mismatches at the handshake (the first frame).
pub const PROTOCOL_VERSION: u8 = 1;

/// Hard cap on a single payload (64 MiB). A window upload is bounded by
/// C steps × frame bytes per sampler — far below this; anything larger is
/// a corrupt length prefix, not a real message.
pub const MAX_FRAME: usize = 64 << 20;

const HEADER_LEN: usize = 18;

/// Write one frame. The payload is already codec-encoded bytes (see
/// [`super::msg::Msg::encode`]).
pub fn write_frame(w: &mut impl Write, kind: u8, payload: &[u8]) -> Result<()> {
    if payload.len() > MAX_FRAME {
        bail!(
            "refusing to send a {} frame of {} bytes (cap {})",
            kind_name(kind),
            payload.len(),
            MAX_FRAME
        );
    }
    let mut header = [0u8; HEADER_LEN];
    header[0..4].copy_from_slice(&MAGIC);
    header[4] = PROTOCOL_VERSION;
    header[5] = kind;
    header[6..10].copy_from_slice(&(payload.len() as u32).to_le_bytes());
    header[10..18].copy_from_slice(&fnv1a(payload).to_le_bytes());
    w.write_all(&header)
        .and_then(|_| w.write_all(payload))
        .and_then(|_| w.flush())
        .with_context(|| format!("sending {} frame", kind_name(kind)))?;
    Ok(())
}

/// Read exactly `buf.len()` bytes. `what` names the expectation for the
/// error; `at_boundary` marks a read that may legitimately see a clean
/// close (between frames) as opposed to a mid-frame truncation.
fn read_exact_named(r: &mut impl Read, buf: &mut [u8], what: &str, at_boundary: bool) -> Result<()> {
    let mut got = 0usize;
    while got < buf.len() {
        match r.read(&mut buf[got..]) {
            Ok(0) if got == 0 && at_boundary => bail!("connection closed by peer"),
            Ok(0) => bail!(
                "truncated {what}: connection closed after {got} of {} bytes",
                buf.len()
            ),
            Ok(n) => got += n,
            Err(e) if e.kind() == ErrorKind::Interrupted => continue,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                bail!("heartbeat timeout: no bytes of {what} within the read-timeout window")
            }
            Err(e) => return Err(e).with_context(|| format!("reading {what}")),
        }
    }
    Ok(())
}

/// Read one frame, returning `(kind, payload)` after every header check
/// and the payload checksum have passed.
pub fn read_frame(r: &mut impl Read) -> Result<(u8, Vec<u8>)> {
    let mut header = [0u8; HEADER_LEN];
    read_exact_named(r, &mut header, "frame header", true)?;
    if header[0..4] != MAGIC {
        bail!(
            "not a tempo-dqn fleet frame (magic {:02x?}, expected {:02x?})",
            &header[0..4],
            MAGIC
        );
    }
    let version = header[4];
    if version != PROTOCOL_VERSION {
        bail!(
            "peer speaks wire protocol version {version}, this binary speaks \
             {PROTOCOL_VERSION}; refusing (rebuild both ends from the same revision)"
        );
    }
    let kind = header[5];
    // The length is checked against the cap BEFORE any allocation, so a
    // corrupt prefix near u32::MAX errors here instead of attempting a
    // 4 GiB allocation (the wire analogue of ByteReader's checked take).
    let len = u32::from_le_bytes(header[6..10].try_into().unwrap()) as usize;
    if len > MAX_FRAME {
        bail!(
            "frame length {len} exceeds the {MAX_FRAME}-byte cap in a {} frame \
             (corrupt length prefix?)",
            kind_name(kind)
        );
    }
    let want_sum = u64::from_le_bytes(header[10..18].try_into().unwrap());
    let mut payload = vec![0u8; len];
    read_exact_named(r, &mut payload, &format!("{} frame payload", kind_name(kind)), false)?;
    let got_sum = fnv1a(&payload);
    if got_sum != want_sum {
        bail!(
            "checksum mismatch in {} frame: payload hashes to {got_sum:016x}, \
             header says {want_sum:016x} (corrupt or tampered wire data)",
            kind_name(kind)
        );
    }
    Ok((kind, payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn framed(kind: u8, payload: &[u8]) -> Vec<u8> {
        let mut out = Vec::new();
        write_frame(&mut out, kind, payload).unwrap();
        out
    }

    #[test]
    fn round_trip() {
        let bytes = framed(5, b"hello fleet");
        let (kind, payload) = read_frame(&mut Cursor::new(&bytes)).unwrap();
        assert_eq!(kind, 5);
        assert_eq!(payload, b"hello fleet");
    }

    #[test]
    fn empty_payload_round_trips() {
        let bytes = framed(5, b"");
        let (kind, payload) = read_frame(&mut Cursor::new(&bytes)).unwrap();
        assert_eq!(kind, 5);
        assert!(payload.is_empty());
    }

    #[test]
    fn flipped_payload_byte_is_a_named_checksum_error() {
        let mut bytes = framed(4, b"window data");
        let last = bytes.len() - 2;
        bytes[last] ^= 0xFF;
        let err = read_frame(&mut Cursor::new(&bytes)).unwrap_err().to_string();
        assert!(err.contains("checksum mismatch"), "unexpected error: {err}");
        assert!(err.contains("window-upload"), "must name the message: {err}");
    }

    #[test]
    fn truncated_frame_is_a_named_truncation_error() {
        let bytes = framed(3, &[7u8; 64]);
        for cut in [bytes.len() - 1, bytes.len() - 30, HEADER_LEN + 1] {
            let err = read_frame(&mut Cursor::new(&bytes[..cut])).unwrap_err().to_string();
            assert!(err.contains("truncated"), "cut at {cut}: unexpected error: {err}");
            assert!(err.contains("param-broadcast"), "must name the message: {err}");
        }
        // A header cut is still a truncation, just of the header itself.
        let err = read_frame(&mut Cursor::new(&bytes[..7])).unwrap_err().to_string();
        assert!(err.contains("truncated"), "unexpected error: {err}");
    }

    #[test]
    fn clean_close_between_frames_is_not_truncation() {
        let err = read_frame(&mut Cursor::new(&[])).unwrap_err().to_string();
        assert!(err.contains("connection closed"), "unexpected error: {err}");
        assert!(!err.contains("truncated"), "a clean close is not corruption: {err}");
    }

    #[test]
    fn version_bump_is_refused_by_name() {
        let mut bytes = framed(1, b"fingerprint");
        bytes[4] = PROTOCOL_VERSION + 1;
        let err = read_frame(&mut Cursor::new(&bytes)).unwrap_err().to_string();
        assert!(err.contains("wire protocol version"), "unexpected error: {err}");
        assert!(err.contains(&format!("{}", PROTOCOL_VERSION + 1)), "{err}");
    }

    #[test]
    fn foreign_magic_is_rejected() {
        let mut bytes = framed(1, b"x");
        bytes[0..4].copy_from_slice(b"HTTP");
        let err = read_frame(&mut Cursor::new(&bytes)).unwrap_err().to_string();
        assert!(err.contains("not a tempo-dqn fleet frame"), "unexpected error: {err}");
    }

    #[test]
    fn corrupt_length_prefix_errors_before_allocating() {
        let mut bytes = framed(2, b"ack");
        bytes[6..10].copy_from_slice(&u32::MAX.to_le_bytes());
        let err = read_frame(&mut Cursor::new(&bytes)).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "unexpected error: {err}");
        assert!(err.contains("hello-ack"), "must name the message: {err}");
    }
}
