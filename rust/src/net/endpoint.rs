//! Fleet endpoints: `tcp:HOST:PORT` and `unix:PATH` behind one pair of
//! enums. [`Listener`] is the learner's side, [`Conn`] is a connected
//! byte stream (either side). `Conn` implements `Read + Write`, so the
//! frame and message layers never know which transport they run on.
//!
//! Unix sockets are the default for same-machine fleets (`fleet
//! --samplers N`): no port allocation, no firewall interaction, and the
//! socket file lives in the run's artifact directory. TCP covers the
//! multi-machine case; binding port 0 and reporting the actual address
//! via [`Listener::local_addr_string`] lets tests and the local-fleet
//! spawner avoid port races.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
#[cfg(unix)]
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

/// A parsed fleet address. The scheme prefix is mandatory so a bare
/// `host:port` typo cannot silently pick the wrong transport.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Endpoint {
    /// `tcp:HOST:PORT` — e.g. `tcp:127.0.0.1:7400`, `tcp:0.0.0.0:0`.
    Tcp(String),
    /// `unix:PATH` — a filesystem socket path.
    Unix(PathBuf),
}

impl Endpoint {
    /// Parse `tcp:HOST:PORT` or `unix:PATH`; anything else is refused by
    /// name with the accepted forms spelled out.
    pub fn parse(addr: &str) -> Result<Endpoint> {
        if let Some(rest) = addr.strip_prefix("tcp:") {
            if !rest.contains(':') {
                bail!("tcp endpoint \"{addr}\" is missing a port (expected tcp:HOST:PORT)");
            }
            Ok(Endpoint::Tcp(rest.to_string()))
        } else if let Some(rest) = addr.strip_prefix("unix:") {
            if rest.is_empty() {
                bail!("unix endpoint \"{addr}\" is missing a path (expected unix:PATH)");
            }
            #[cfg(not(unix))]
            bail!("unix endpoint \"{addr}\" is not supported on this platform; use tcp:HOST:PORT");
            #[cfg(unix)]
            Ok(Endpoint::Unix(PathBuf::from(rest)))
        } else {
            bail!(
                "unrecognized fleet address \"{addr}\": expected tcp:HOST:PORT or unix:PATH"
            );
        }
    }

    /// Bind a listener at this endpoint (the learner side).
    pub fn bind(&self) -> Result<Listener> {
        match self {
            Endpoint::Tcp(addr) => {
                let l = TcpListener::bind(addr)
                    .with_context(|| format!("binding fleet listener at tcp:{addr}"))?;
                Ok(Listener::Tcp(l))
            }
            #[cfg(unix)]
            Endpoint::Unix(path) => {
                // A stale socket file from a crashed learner would make
                // bind fail with AddrInUse; remove it first. A *live*
                // learner is not protected against — the artifact dir is
                // per-run, so two learners sharing one is already a
                // configuration error.
                if path.exists() {
                    std::fs::remove_file(path).with_context(|| {
                        format!("removing stale fleet socket {}", path.display())
                    })?;
                }
                let l = UnixListener::bind(path)
                    .with_context(|| format!("binding fleet listener at unix:{}", path.display()))?;
                Ok(Listener::Unix(l, path.clone()))
            }
            #[cfg(not(unix))]
            Endpoint::Unix(path) => {
                bail!("unix endpoint {} is not supported on this platform", path.display())
            }
        }
    }

    /// Connect to this endpoint (the sampler side), retrying for up to
    /// `timeout` so a sampler process spawned alongside the learner wins
    /// the race against the listener coming up.
    pub fn connect(&self, timeout: Duration) -> Result<Conn> {
        let deadline = std::time::Instant::now() + timeout;
        let mut backoff = Duration::from_millis(10);
        loop {
            let attempt = match self {
                Endpoint::Tcp(addr) => TcpStream::connect(addr).map(Conn::Tcp).map_err(Into::into),
                #[cfg(unix)]
                Endpoint::Unix(path) => {
                    UnixStream::connect(path).map(Conn::Unix).map_err(Into::into)
                }
                #[cfg(not(unix))]
                Endpoint::Unix(path) => Err(anyhow::anyhow!(
                    "unix endpoint {} is not supported on this platform",
                    path.display()
                )),
            };
            match attempt {
                Ok(conn) => return Ok(conn),
                Err(e) => {
                    // Spend the whole budget: clamp the final sleep to
                    // whatever remains so the last attempt lands *at* the
                    // deadline. (Giving up whenever `now + backoff` crossed
                    // the deadline surrendered up to one full backoff —
                    // 250ms — of the caller's timeout, losing races against
                    // a listener that came up late but in budget.)
                    let now = std::time::Instant::now();
                    if now >= deadline {
                        return Err(e).with_context(|| {
                            format!("connecting to fleet learner at {}", self.display())
                        });
                    }
                    std::thread::sleep(backoff.min(deadline - now));
                    backoff = (backoff * 2).min(Duration::from_millis(250));
                }
            }
        }
    }

    /// The canonical string form (round-trips through [`Endpoint::parse`]).
    pub fn display(&self) -> String {
        match self {
            Endpoint::Tcp(addr) => format!("tcp:{addr}"),
            Endpoint::Unix(path) => format!("unix:{}", path.display()),
        }
    }
}

/// A bound fleet listener.
pub enum Listener {
    Tcp(TcpListener),
    #[cfg(unix)]
    Unix(UnixListener, PathBuf),
}

impl Listener {
    /// Accept one sampler connection.
    pub fn accept(&self) -> Result<Conn> {
        match self {
            Listener::Tcp(l) => {
                let (stream, _) = l.accept().context("accepting fleet sampler connection")?;
                stream.set_nodelay(true).ok();
                Ok(Conn::Tcp(stream))
            }
            #[cfg(unix)]
            Listener::Unix(l, _) => {
                let (stream, _) = l.accept().context("accepting fleet sampler connection")?;
                Ok(Conn::Unix(stream))
            }
        }
    }

    /// The address samplers should connect to, in [`Endpoint::parse`]
    /// form. For TCP this reports the *actual* bound address, so binding
    /// port 0 yields a usable `tcp:IP:PORT`.
    pub fn local_addr_string(&self) -> Result<String> {
        match self {
            Listener::Tcp(l) => {
                let addr = l.local_addr().context("reading fleet listener address")?;
                Ok(format!("tcp:{addr}"))
            }
            #[cfg(unix)]
            Listener::Unix(_, path) => Ok(format!("unix:{}", path.display())),
        }
    }
}

#[cfg(unix)]
impl Drop for Listener {
    fn drop(&mut self) {
        if let Listener::Unix(_, path) = self {
            std::fs::remove_file(path).ok();
        }
    }
}

/// One connected fleet byte stream. The heartbeat clock is a plain
/// socket read timeout ([`Conn::set_read_timeout`]); the frame layer
/// translates `WouldBlock`/`TimedOut` into the named heartbeat error.
pub enum Conn {
    Tcp(TcpStream),
    #[cfg(unix)]
    Unix(UnixStream),
}

impl Conn {
    /// Connect with [`Endpoint::connect`]'s retry loop.
    pub fn connect(endpoint: &Endpoint, timeout: Duration) -> Result<Conn> {
        let conn = endpoint.connect(timeout)?;
        if let Conn::Tcp(s) = &conn {
            s.set_nodelay(true).ok();
        }
        Ok(conn)
    }

    /// Set the read timeout — how long a blocked read waits before the
    /// frame layer reports a heartbeat timeout. `None` blocks forever.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        match self {
            Conn::Tcp(s) => s.set_read_timeout(timeout).context("setting fleet read timeout"),
            #[cfg(unix)]
            Conn::Unix(s) => s.set_read_timeout(timeout).context("setting fleet read timeout"),
        }
    }

    /// A second handle onto the same socket (shared OS descriptor). The
    /// learner gives each connection's *write* half to a dedicated writer
    /// thread so parameter broadcasts and heartbeats can never block the
    /// barrier loop against a sampler that is itself blocked mid-upload
    /// (the classic write–write deadlock).
    pub fn try_clone(&self) -> Result<Conn> {
        match self {
            Conn::Tcp(s) => {
                Ok(Conn::Tcp(s.try_clone().context("cloning fleet connection handle")?))
            }
            #[cfg(unix)]
            Conn::Unix(s) => {
                Ok(Conn::Unix(s.try_clone().context("cloning fleet connection handle")?))
            }
        }
    }
}

impl Read for Conn {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.read(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.read(buf),
        }
    }
}

impl Write for Conn {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match self {
            Conn::Tcp(s) => s.write(buf),
            #[cfg(unix)]
            Conn::Unix(s) => s.write(buf),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        match self {
            Conn::Tcp(s) => s.flush(),
            #[cfg(unix)]
            Conn::Unix(s) => s.flush(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::msg::Msg;

    #[test]
    fn parse_accepts_both_schemes_and_names_failures() {
        assert_eq!(Endpoint::parse("tcp:127.0.0.1:7400").unwrap(), Endpoint::Tcp("127.0.0.1:7400".into()));
        #[cfg(unix)]
        assert_eq!(Endpoint::parse("unix:/tmp/fleet.sock").unwrap(), Endpoint::Unix(PathBuf::from("/tmp/fleet.sock")));
        let err = Endpoint::parse("127.0.0.1:7400").unwrap_err().to_string();
        assert!(err.contains("unrecognized fleet address"), "{err}");
        let err = Endpoint::parse("tcp:localhost").unwrap_err().to_string();
        assert!(err.contains("missing a port"), "{err}");
        let err = Endpoint::parse("unix:").unwrap_err().to_string();
        assert!(err.contains("missing a path"), "{err}");
    }

    #[test]
    fn display_round_trips() {
        for addr in ["tcp:0.0.0.0:0", "unix:/tmp/x.sock"] {
            #[cfg(not(unix))]
            if addr.starts_with("unix:") {
                continue;
            }
            assert_eq!(Endpoint::parse(addr).unwrap().display(), addr);
        }
    }

    #[test]
    fn tcp_loopback_carries_fleet_messages() {
        let listener = Endpoint::parse("tcp:127.0.0.1:0").unwrap().bind().unwrap();
        let addr = listener.local_addr_string().unwrap();
        assert!(addr.starts_with("tcp:127.0.0.1:"), "{addr}");
        let client = std::thread::spawn(move || {
            let mut conn = Endpoint::parse(&addr)
                .unwrap()
                .connect(Duration::from_secs(5))
                .unwrap();
            Msg::Hello { fingerprint: "fp".into() }.send(&mut conn).unwrap();
            match Msg::recv(&mut conn).unwrap() {
                Msg::Shutdown { reason } => reason,
                other => panic!("expected shutdown, got {other:?}"),
            }
        });
        let mut server = listener.accept().unwrap();
        match Msg::recv(&mut server).unwrap() {
            Msg::Hello { fingerprint } => assert_eq!(fingerprint, "fp"),
            other => panic!("expected hello, got {other:?}"),
        }
        Msg::Shutdown { reason: "done".into() }.send(&mut server).unwrap();
        assert_eq!(client.join().unwrap(), "done");
    }

    #[cfg(unix)]
    #[test]
    fn unix_socket_binds_over_stale_file_and_cleans_up() {
        let dir = std::env::temp_dir().join(format!("tempo-fleet-ep-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("fleet.sock");
        std::fs::write(&path, b"stale").unwrap(); // crashed-learner leftover
        let ep = Endpoint::parse(&format!("unix:{}", path.display())).unwrap();
        {
            let listener = ep.bind().unwrap();
            let path2 = path.clone();
            let client = std::thread::spawn(move || {
                let mut conn = Endpoint::Unix(path2).connect(Duration::from_secs(5)).unwrap();
                Msg::Heartbeat.send(&mut conn).unwrap();
            });
            let mut server = listener.accept().unwrap();
            assert!(matches!(Msg::recv(&mut server).unwrap(), Msg::Heartbeat));
            client.join().unwrap();
        }
        assert!(!path.exists(), "listener drop must remove the socket file");
        std::fs::remove_dir_all(&dir).ok();
    }

    /// Pre-fix, `connect` gave up as soon as `now + backoff` crossed the
    /// deadline, surrendering up to one full backoff (250ms) of the
    /// caller's budget. With the 10ms-doubling schedule the attempts land
    /// at ~0/10/30/70/150/310ms; a listener that binds at ~350ms into a
    /// 550ms budget therefore sat squarely in the old dead zone (give-up
    /// at ~310ms). The clamped final sleep must land one more attempt at
    /// the deadline and reach it.
    #[cfg(unix)]
    #[test]
    fn connect_spends_its_full_budget_on_a_late_listener() {
        let dir = std::env::temp_dir().join(format!("tempo-fleet-late-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("late.sock");
        let ep = Endpoint::parse(&format!("unix:{}", path.display())).unwrap();
        let ep2 = ep.clone();
        let server = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(350));
            let listener = ep2.bind().unwrap();
            let mut conn = listener.accept().unwrap();
            assert!(matches!(Msg::recv(&mut conn).unwrap(), Msg::Heartbeat));
        });
        let mut conn = ep
            .connect(Duration::from_millis(550))
            .expect("late-but-in-budget listener must be reached");
        Msg::Heartbeat.send(&mut conn).unwrap();
        server.join().unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn connect_still_fails_cleanly_when_nothing_listens() {
        // Unroutable port on loopback: refused fast, retried until the
        // deadline, then surfaced with the endpoint named.
        let ep = Endpoint::parse("tcp:127.0.0.1:1").unwrap();
        let t0 = std::time::Instant::now();
        let err = ep.connect(Duration::from_millis(80)).unwrap_err().to_string();
        assert!(err.contains("connecting to fleet learner"), "{err}");
        assert!(t0.elapsed() >= Duration::from_millis(80), "must exhaust the budget");
    }

    #[test]
    fn read_timeout_surfaces_as_heartbeat_error() {
        let listener = Endpoint::parse("tcp:127.0.0.1:0").unwrap().bind().unwrap();
        let addr = listener.local_addr_string().unwrap();
        let mut conn = Endpoint::parse(&addr).unwrap().connect(Duration::from_secs(5)).unwrap();
        let _held = listener.accept().unwrap(); // peer stays silent
        conn.set_read_timeout(Some(Duration::from_millis(50))).unwrap();
        let err = Msg::recv(&mut conn).unwrap_err().to_string();
        assert!(err.contains("heartbeat timeout"), "unexpected error: {err}");
    }
}
