//! Wire layer for the distributed sampler fleet (rust/DESIGN.md §14).
//!
//! Three small pieces, each reusing an existing guarantee instead of
//! inventing a new one:
//!
//! * [`frame`] — length-prefixed, FNV-checksummed, versioned message
//!   frames. The payload bytes are produced by the same bit-exact
//!   [`crate::ckpt::ByteWriter`]/[`crate::ckpt::ByteReader`] codec the
//!   checkpoint container uses, so a float crossing the wire round-trips
//!   to the bit — the transport half of the replicated-mode guarantee.
//! * [`msg`] — the typed message catalog (handshake, parameter
//!   broadcast, window upload, heartbeat, shutdown). Every decode error
//!   names the message it was parsing, mirroring the checkpoint
//!   section-naming convention.
//! * [`endpoint`] — `tcp:HOST:PORT` / `unix:PATH` listeners and
//!   connections behind one `Read + Write` enum, with read timeouts (the
//!   fleet's heartbeat clock).
//!
//! The protocols built on top (who sends what when) live in
//! `coordinator::fleet` (sampler fleet) and [`crate::serve`] (policy
//! serving daemon — kinds 7–10); this module knows only bytes and
//! messages.

pub mod endpoint;
pub mod frame;
pub mod msg;

pub use endpoint::{Conn, Endpoint, Listener};
pub use frame::{read_frame, write_frame, MAX_FRAME, PROTOCOL_VERSION};
pub use msg::{Msg, ServeStats, WindowUpload};
