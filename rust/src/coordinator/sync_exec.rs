//! Synchronized-Execution driver (paper §4, Figure 3(b), Algorithm 1).
//!
//! W sampler threads each take one environment step per round, then block;
//! the main thread aggregates all W states into ONE batched device
//! inference and distributes the Q-rows back through shared slots (no
//! message passing). Device transactions per W steps: 1, instead of W.
//!
//! Variants:
//! * **synchronized** (Concurrent Training OFF): after each round the main
//!   thread performs the due minibatch updates inline — training still
//!   blocks sampling, acting uses theta.
//! * **both** (Algorithm 1): a trainer thread runs C/F minibatches per
//!   C-step window concurrently; acting uses theta_minus; staging flushes
//!   and theta_minus <- theta at the window barrier.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Condvar, Mutex};

use anyhow::{anyhow, Result};

use crate::env::STATE_BYTES;
use crate::metrics::Phase;
use crate::replay::StagingBuffer;
use crate::runtime::{Policy, TrainBatch};

use super::shared::{SamplerCtx, Shared};

/// Per-slot shared mailbox: the "shared memory arrays" of the paper.
struct Slot {
    io: Mutex<SlotIo>,
}

struct SlotIo {
    state: Vec<u8>,
    q: Vec<f32>,
    staging: StagingBuffer,
}

/// Run the synchronized driver. `concurrent` selects Algorithm 1 vs
/// synchronized-only.
pub fn run_sync(
    shared: &Shared<'_>,
    concurrent: bool,
    mut on_progress: impl FnMut(u64) + Send,
) -> Result<()> {
    let w = shared.cfg.threads;
    let total = shared.cfg.total_steps;
    let c = shared.cfg.target_update_period;
    let f = shared.cfg.train_period;
    let actions = shared.qnet.spec().actions;

    let slots: Vec<Slot> = (0..w)
        .map(|_| Slot {
            io: Mutex::new(SlotIo {
                state: vec![0u8; STATE_BYTES],
                q: vec![0f32; actions],
                staging: StagingBuffer::new(),
            }),
        })
        .collect();

    // Round barriers: main + W samplers.
    let round_start = Barrier::new(w + 1);
    let round_done = Barrier::new(w + 1);
    // Base global-step index of the current round (sampler k acts at
    // round_base + k — the paper's `i = t mod W` dispatch).
    let round_base = AtomicU64::new(0);

    // Trainer window protocol (both-mode only).
    let dispatched = AtomicU64::new(0);
    let trainer_done = AtomicU64::new(0);
    let trainer_cv = (Mutex::new(()), Condvar::new());

    std::thread::scope(|scope| -> Result<()> {
        // ---- sampler threads --------------------------------------------
        for slot_id in 0..w {
            let shared = &shared;
            let slots = &slots;
            let round_start = &round_start;
            let round_done = &round_done;
            let round_base = &round_base;
            scope.spawn(move || {
                let mut ctx = match SamplerCtx::new(shared.cfg, slot_id) {
                    Ok(c) => c,
                    Err(e) => {
                        shared.fail(format!("sampler {slot_id}: {e}"));
                        // Still participate in barriers so nobody deadlocks.
                        round_done.wait(); // initial state-publish barrier
                        loop {
                            round_start.wait();
                            if shared.should_stop() {
                                return;
                            }
                            round_done.wait();
                        }
                    }
                };
                // Publish the initial state, then enter the round loop.
                {
                    let mut io = slots[slot_id].io.lock().unwrap();
                    ctx.env.write_state(&mut io.state);
                }
                round_done.wait();
                loop {
                    round_start.wait();
                    if shared.should_stop() {
                        break;
                    }
                    let t = round_base.load(Ordering::SeqCst) + slot_id as u64;
                    let mut io = slots[slot_id].io.lock().unwrap();
                    let q = io.q.clone();
                    if concurrent {
                        let SlotIo { staging, .. } = &mut *io;
                        ctx.act(shared, t, &q, |frame, a, r, done, start| {
                            staging.push(frame, a, r, done, start);
                        });
                    } else {
                        drop(io);
                        let replay = shared.replay;
                        ctx.act(shared, t, &q, |frame, a, r, done, start| {
                            replay.lock().unwrap().push(slot_id, frame, a, r, done, start);
                        });
                        io = slots[slot_id].io.lock().unwrap();
                    }
                    ctx.env.write_state(&mut io.state);
                    drop(io);
                    round_done.wait();
                }
            });
        }

        // ---- trainer thread (both-mode) ----------------------------------
        if concurrent {
            let shared = &shared;
            let dispatched = &dispatched;
            let trainer_done = &trainer_done;
            let trainer_cv = &trainer_cv;
            scope.spawn(move || {
                let mut batch = TrainBatch::default();
                loop {
                    loop {
                        if shared.should_stop() {
                            return;
                        }
                        if trainer_done.load(Ordering::SeqCst)
                            < dispatched.load(Ordering::SeqCst)
                        {
                            break;
                        }
                        let g = trainer_cv.0.lock().unwrap();
                        let _ = trainer_cv
                            .1
                            .wait_timeout(g, std::time::Duration::from_millis(1))
                            .unwrap();
                    }
                    for _ in 0..shared.cfg.batches_per_window() {
                        if shared.should_stop() {
                            return;
                        }
                        if let Err(e) = shared.do_one_train(&mut batch) {
                            return shared.fail(format!("trainer: {e}"));
                        }
                    }
                    trainer_done.fetch_add(1, Ordering::SeqCst);
                    trainer_cv.1.notify_all();
                }
            });
        }

        // ---- main thread: Algorithm 1's dispatch loop --------------------
        let mut batch_states = vec![0u8; w * STATE_BYTES];
        let mut train_batch = TrainBatch::default();
        let mut completed: u64 = 0;
        let mut window_end = c.min(total);
        if concurrent {
            dispatched.fetch_add(1, Ordering::SeqCst);
            trainer_cv.1.notify_all();
        }

        round_done.wait(); // initial states published
        let result: Result<()> = (|| {
            loop {
                if shared.error.lock().unwrap().is_some() {
                    shared.stop.store(true, Ordering::SeqCst);
                    round_start.wait();
                    return Err(anyhow!("worker failed"));
                }
                if completed >= total {
                    shared.stop.store(true, Ordering::SeqCst);
                    round_start.wait(); // release samplers to observe stop
                    break;
                }

                // Aggregate states -> one batched inference -> scatter Q.
                shared.span(shared.main_lane(), Phase::Sample, || {
                    for (k, slot) in slots.iter().enumerate() {
                        let io = slot.io.lock().unwrap();
                        batch_states[k * STATE_BYTES..(k + 1) * STATE_BYTES]
                            .copy_from_slice(&io.state);
                    }
                });
                let policy = if concurrent { Policy::ThetaMinus } else { Policy::Theta };
                let q = match shared
                    .span(shared.main_lane(), Phase::Infer, || shared.qnet.infer(policy, &batch_states, w))
                {
                    Ok(q) => q,
                    Err(e) => {
                        shared.stop.store(true, Ordering::SeqCst);
                        round_start.wait(); // release samplers to observe stop
                        return Err(anyhow!("infer: {e}"));
                    }
                };
                for (k, slot) in slots.iter().enumerate() {
                    let mut io = slot.io.lock().unwrap();
                    io.q.copy_from_slice(&q[k * actions..(k + 1) * actions]);
                }

                round_base.store(completed, Ordering::SeqCst);
                round_start.wait(); // samplers act
                round_done.wait(); // all done
                completed += w as u64;

                if concurrent {
                    // Window boundary: wait for the trainer, flush, sync.
                    if completed >= window_end {
                        while trainer_done.load(Ordering::SeqCst)
                            < dispatched.load(Ordering::SeqCst)
                        {
                            if shared.should_stop() {
                                break;
                            }
                            std::thread::sleep(std::time::Duration::from_micros(100));
                        }
                        shared.span(shared.main_lane(), Phase::Sync, || {
                            let mut replay = shared.replay.lock().unwrap();
                            for (slot_id, slot) in slots.iter().enumerate() {
                                slot.io
                                    .lock()
                                    .unwrap()
                                    .staging
                                    .flush_into(&mut replay, slot_id);
                            }
                            shared.qnet.sync_target();
                        });
                        if window_end < total {
                            window_end = (window_end + c).min(total);
                            dispatched.fetch_add(1, Ordering::SeqCst);
                            trainer_cv.1.notify_all();
                        }
                    }
                } else {
                    // Training blocks the loop (no concurrency).
                    while shared.trains_done.load(Ordering::SeqCst) < completed / f {
                        if let Err(e) = shared.do_one_train(&mut train_batch) {
                            shared.stop.store(true, Ordering::SeqCst);
                            round_start.wait();
                            return Err(anyhow!("train: {e}"));
                        }
                    }
                }
                on_progress(completed);
            }
            Ok(())
        })();
        // Ensure everyone is released on error paths.
        shared.stop.store(true, Ordering::SeqCst);
        trainer_cv.1.notify_all();
        result
    })?;

    if let Some(err) = shared.error.lock().unwrap().take() {
        return Err(anyhow!(err));
    }
    Ok(())
}
