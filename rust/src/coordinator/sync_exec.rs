//! Synchronized-Execution driver (paper §4, Figure 3(b), Algorithm 1).
//!
//! W sampler threads each take B environment steps per round, then block;
//! the main thread aggregates all W×B states into ONE batched device
//! inference and distributes the Q-rows back through shared slots (no
//! message passing). Device transactions per W×B steps: 1, instead of W×B.
//!
//! Variants:
//! * **synchronized** (Concurrent Training OFF): after each round the main
//!   thread performs the due minibatch updates inline — training still
//!   blocks sampling, acting uses theta.
//! * **both** (Algorithm 1): a trainer thread runs C/F minibatches per
//!   C-step window concurrently ([`WindowCtrl`]); acting uses theta_minus;
//!   staging flushes and theta_minus <- theta at the window barrier.
//!
//! Step dispatch: sampler k acts at steps `round_base + k*B .. + B` — the
//! paper's `i = t mod W` generalized to B-wide stream blocks (B=1 is
//! exactly the original dispatch). Rounds are always whole: the run
//! overshoots `total_steps` by up to W×B-1 steps (the paper's W-round
//! quantization, amplified by B), preserving the one-transaction-per-round
//! invariant; the async drivers clamp instead because their blocks are
//! per-thread.
//!
//! **Segments & quiesce points** (rust/DESIGN.md §10): one invocation runs
//! whole rounds until coverage of `seg.until` and exits quiesced — in both
//! mode, always immediately after a window's flush with the trainer's full
//! quota consumed, so the machine state at exit is exactly the state the
//! uninterrupted run passes through at that boundary. Sampler contexts
//! persist outside the driver and the draw stream is written back to
//! `seg.draw_rng`. In both mode, evaluation fires only at window barriers
//! (trainer idle, theta frozen); in synchronized mode every round end is
//! already quiesced, so it fires per round as before.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Barrier, Mutex};

use anyhow::{anyhow, Result};

use crate::env::STATE_BYTES;
use crate::metrics::Phase;
use crate::replay::{build_strategy, BatchSource, StagingSet, TrainerSource};
use crate::runtime::{Policy, TrainBatch};

use super::shared::{strategy_plan, SamplerCtx, SegmentState, Shared, WindowCtrl};

/// Per-slot shared mailbox: the "shared memory arrays" of the paper,
/// widened to B states / B Q-rows per sampler thread.
struct Slot {
    io: Mutex<SlotIo>,
}

struct SlotIo {
    /// B stacked states, contiguous (`B * STATE_BYTES`).
    states: Vec<u8>,
    /// B Q-rows, contiguous (`B * actions`).
    q: Vec<f32>,
}

/// Run one synchronized segment. `concurrent` selects Algorithm 1 vs
/// synchronized-only.
pub fn run_sync(
    shared: &Shared<'_>,
    concurrent: bool,
    ctxs: &mut [SamplerCtx],
    seg: &mut SegmentState,
    mut on_progress: impl FnMut(u64) + Send,
) -> Result<()> {
    let w = shared.cfg.threads;
    let b = shared.cfg.envs_per_thread;
    let until = seg.until.min(shared.cfg.total_steps);
    let c = shared.cfg.target_update_period;
    let f = shared.cfg.train_period;
    let actions = shared.qnet.spec().actions;
    let round = (w * b) as u64;
    debug_assert_eq!(ctxs.len(), w, "one persistent SamplerCtx per thread");

    let slots: Vec<Slot> = (0..w)
        .map(|_| Slot {
            io: Mutex::new(SlotIo {
                states: vec![0u8; b * STATE_BYTES],
                q: vec![0f32; b * actions],
            }),
        })
        .collect();
    let staging = StagingSet::new(w * b);

    // Round barriers: main + W samplers.
    let round_start = Barrier::new(w + 1);
    let round_done = Barrier::new(w + 1);
    // Base global-step index of the current round (sampler k acts at
    // round_base + k*B + j).
    let round_base = AtomicU64::new(0);

    let winctrl = WindowCtrl::new();
    let bpw = shared.cfg.batches_per_window();

    // Batch source: prefetch pipeline for the windowed trainer (both-mode)
    // when enabled, inline sampling otherwise — including synchronized-only
    // inline training, which interleaves with replay writes every round
    // (TrainerSource owns the eligibility rule). The configured sampling
    // strategy resumes at the segment's saved draw position and β-anneal
    // clock.
    let source = TrainerSource::with_strategy(
        shared.replay,
        build_strategy(
            &strategy_plan(shared.cfg, shared.qnet.spec().gamma),
            seg.draw_rng,
            shared.trains_done.load(Ordering::SeqCst),
        ),
        shared.cfg.minibatch,
        shared.cfg.prefetch_batches,
        concurrent,
    );

    let result = std::thread::scope(|scope| -> Result<()> {
        // ---- prefetch worker (both-mode + prefetch only) -----------------
        if let Some(pipeline) = source.pipeline() {
            let shared = &shared;
            scope.spawn(move || pipeline.worker_loop(&|| shared.should_stop()));
        }

        // ---- sampler threads --------------------------------------------
        for ctx in ctxs.iter_mut() {
            let shared = &shared;
            let slots = &slots;
            let staging = &staging;
            let round_start = &round_start;
            let round_done = &round_done;
            let round_base = &round_base;
            scope.spawn(move || {
                let slot_id = ctx.slot;
                // Publish the initial states, then enter the round loop.
                {
                    let mut io = slots[slot_id].io.lock().unwrap();
                    ctx.envs.write_states(&mut io.states);
                }
                round_done.wait();
                loop {
                    round_start.wait();
                    if shared.should_stop() {
                        break;
                    }
                    let t = round_base.load(Ordering::SeqCst) + (slot_id * b) as u64;
                    let q = slots[slot_id].io.lock().unwrap().q.clone();
                    if concurrent {
                        ctx.act_block(shared, t, &q, b, |stream, frame, a, r, done, start| {
                            staging.push(stream, frame, a, r, done, start);
                        });
                    } else {
                        let replay = shared.replay;
                        ctx.act_block(shared, t, &q, b, |stream, frame, a, r, done, start| {
                            replay.write().unwrap().push(stream, frame, a, r, done, start);
                        });
                    }
                    {
                        let mut io = slots[slot_id].io.lock().unwrap();
                        ctx.envs.write_states(&mut io.states);
                    }
                    round_done.wait();
                }
            });
        }

        // ---- trainer thread (both-mode) ----------------------------------
        if concurrent {
            let shared = &shared;
            let winctrl = &winctrl;
            let source: &dyn BatchSource = &source;
            scope.spawn(move || winctrl.trainer_loop(shared, source));
        }

        // ---- main thread: Algorithm 1's dispatch loop --------------------
        let mut batch_states = vec![0u8; w * b * STATE_BYTES];
        let mut train_batch = TrainBatch::default();
        let mut completed: u64 = shared.completed.load(Ordering::SeqCst);
        let mut window_end = ((seg.windows_flushed + 1) * c).min(until);
        if concurrent {
            winctrl.dispatch();
            source.grant(bpw);
        }

        round_done.wait(); // initial states published
        let result: Result<()> = (|| {
            loop {
                if shared.error.lock().unwrap().is_some() {
                    shared.stop.store(true, Ordering::SeqCst);
                    round_start.wait();
                    return Err(anyhow!("worker failed"));
                }
                if completed >= until {
                    shared.stop.store(true, Ordering::SeqCst);
                    round_start.wait(); // release samplers to observe stop
                    break;
                }

                // Aggregate W×B states -> one batched inference -> scatter Q.
                let chunk = b * STATE_BYTES;
                shared.span(shared.main_lane(), Phase::Sample, || {
                    for (k, slot) in slots.iter().enumerate() {
                        let io = slot.io.lock().unwrap();
                        batch_states[k * chunk..(k + 1) * chunk].copy_from_slice(&io.states);
                    }
                });
                let policy = if concurrent { Policy::ThetaMinus } else { Policy::Theta };
                let q = match shared.span(shared.main_lane(), Phase::Infer, || {
                    shared.qnet.infer(policy, &batch_states, w * b)
                }) {
                    Ok(q) => q,
                    Err(e) => {
                        shared.stop.store(true, Ordering::SeqCst);
                        round_start.wait(); // release samplers to observe stop
                        return Err(anyhow!("infer: {e}"));
                    }
                };
                let qchunk = b * actions;
                for (k, slot) in slots.iter().enumerate() {
                    let mut io = slot.io.lock().unwrap();
                    io.q.copy_from_slice(&q[k * qchunk..(k + 1) * qchunk]);
                }

                round_base.store(completed, Ordering::SeqCst);
                round_start.wait(); // samplers act
                round_done.wait(); // all done
                completed += round;

                if concurrent {
                    // Window boundary: wait for the trainer's full quota,
                    // flush, sync. The quiesce state right after this flush
                    // is what checkpoints capture and what evaluation may
                    // observe (trainer idle, theta frozen).
                    if completed >= window_end {
                        winctrl.wait_caught_up(shared);
                        shared.sync_point(&staging);
                        // Apply the window's queued TD-error priority
                        // updates (generation-guarded) after the flush,
                        // before the next window's grant (§11).
                        source.barrier_update();
                        seg.windows_flushed += 1;
                        on_progress(completed);
                        if window_end < until {
                            window_end = (window_end + c).min(until);
                            winctrl.dispatch();
                            // Grant after the flush: the prefetch worker's
                            // next draws see exactly post-flush replay.
                            source.grant(bpw);
                        }
                    }
                } else {
                    // Training blocks the loop (no concurrency).
                    while shared.trains_done.load(Ordering::SeqCst) < completed / f {
                        match shared.do_one_train(&source, &mut train_batch) {
                            Ok(true) => {}
                            Ok(false) => break,
                            Err(e) => {
                                shared.stop.store(true, Ordering::SeqCst);
                                round_start.wait();
                                return Err(anyhow!("train: {e}"));
                            }
                        }
                    }
                    on_progress(completed);
                }
            }
            Ok(())
        })();
        // Ensure everyone is released on error paths.
        shared.stop.store(true, Ordering::SeqCst);
        winctrl.notify_all();
        result
    });
    // Write the draw stream back for the next segment / checkpoint (safe:
    // all threads joined, the source is quiesced).
    seg.draw_rng = source.sampler_state();
    result?;

    if let Some(err) = shared.error.lock().unwrap().take() {
        return Err(anyhow!(err));
    }
    Ok(())
}
