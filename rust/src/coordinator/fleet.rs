//! Distributed sampler fleet (rust/DESIGN.md §14).
//!
//! One **learner** process hosts the full training machine — replay,
//! trainer, evaluator, checkpoints — and listens on a fleet endpoint.
//! N **sampler** processes each own a contiguous chunk of the W sampler
//! slots: they run the exact [`SamplerCtx`] streams the single-process
//! async driver would run on threads, acting with theta_minus received
//! over the wire and uploading each target window's product (staged
//! transitions, episode returns, context snapshots) back to the learner
//! at the window barrier.
//!
//! Determinism contract, two tiers:
//!
//! * **replicated** (`fleet_lag = 0`, the default): a sampler may not act
//!   window j before receiving theta_minus version j — exactly the window
//!   barrier the single-process machine enforces — so the fleet trajectory
//!   is *bit-identical* to the single-process one (`state_digest`
//!   equality, pinned in tests/fleet.rs), and checkpoints cross the
//!   single↔fleet boundary freely.
//! * **relaxed** (`fleet_lag = K >= 1`): window j acts with the version
//!   broadcast K barriers earlier, so samplers run up to K windows ahead
//!   of the learner instead of blocking on the freshest parameters. The
//!   staleness is *deterministic* (a pure function of the window index,
//!   not of thread or network timing), so relaxed runs are reproducible
//!   and checkpoint-resumable — they are simply a different trajectory,
//!   which the divergence test characterizes.
//!
//! Fleet execution requires mode `concurrent`: the standard mode
//! interlocks every acting step with training (nothing to distribute),
//! and the synchronized modes compute one batched W×B inference per round
//! whose bitwise results cannot be partitioned across processes.
//! Geometry must be window-exact (`C % (W*B) == 0`,
//! `total_steps % C == 0`) so barriers, segment bounds, and the run end
//! all land on block-aligned window edges.
//!
//! Liveness: both sides run socket read timeouts (`fleet_timeout_ms`) and
//! send [`Msg::Heartbeat`] whenever they will be silent for a while (a
//! sampler between acting blocks, the learner through a trainer barrier
//! or a checkpoint write). A silent peer surfaces as the frame layer's
//! named "heartbeat timeout" error. The learner's write half of every
//! connection lives on a dedicated writer thread, so a parameter
//! broadcast can never block the barrier loop against a sampler that is
//! itself blocked mid-upload (write–write deadlock); flow control is the
//! sampler's upload write, which the learner drains in connection order.

use std::path::Path;
use std::sync::atomic::Ordering;
use std::sync::mpsc;
use std::sync::RwLock;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context, Result};

use crate::ckpt::{ByteReader, ByteWriter};
use crate::config::{ExecMode, ExperimentConfig};
use crate::env::{NET_FRAME, STACK};
use crate::metrics::PhaseTimers;
use crate::net::{Conn, Endpoint, Msg, WindowUpload};
use crate::replay::{build_strategy, BatchSource, ReplayMemory, StagingSet, TrainerSource};
use crate::runtime::{Device, Manifest, Policy, QNet};
use crate::util::json::Json;

use super::shared::{strategy_plan, ResumePoint, SamplerCtx, SegmentState, Shared, WindowCtrl};
use super::{Coordinator, Machine, TrainResult};

/// Learner-side launch parameters (the config holds everything else).
#[derive(Clone, Debug)]
pub struct FleetOpts {
    /// Listen address: `tcp:HOST:PORT` or `unix:PATH`.
    pub bind: String,
    /// Sampler processes to accept before training starts.
    pub samplers: usize,
}

/// The serialized trajectory fingerprint a sampler's `hello` carries.
pub fn fingerprint_text(cfg: &ExperimentConfig) -> String {
    super::config_fingerprint(cfg).to_string()
}

/// Mode/geometry prerequisites shared by learner and sampler; every
/// refusal names the offending knob (rust/DESIGN.md §14).
pub fn validate_fleet_geometry(cfg: &ExperimentConfig) -> Result<()> {
    match cfg.mode {
        ExecMode::Concurrent => {}
        ExecMode::Standard => bail!(
            "fleet execution requires Concurrent Training: mode \"standard\" interlocks \
             every acting step with the freshly-trained theta, so sampling cannot run in \
             another process (use --mode concurrent)"
        ),
        ExecMode::Synchronized | ExecMode::Both => bail!(
            "fleet execution requires mode \"concurrent\": mode {:?} uses Synchronized \
             Execution, whose single batched W×B inference per round cannot be partitioned \
             across processes without changing its results",
            cfg.mode.name()
        ),
    }
    let wb = cfg.streams() as u64;
    if cfg.target_update_period % wb != 0 {
        bail!(
            "fleet barriers must be block-exact: target_update_period (C={}) is not a \
             multiple of W*B={} (threads {} x envs_per_thread {})",
            cfg.target_update_period, wb, cfg.threads, cfg.envs_per_thread
        );
    }
    if cfg.total_steps % cfg.target_update_period != 0 {
        bail!(
            "fleet runs must end on a window barrier: total_steps {} is not a multiple of \
             target_update_period (C={})",
            cfg.total_steps, cfg.target_update_period
        );
    }
    Ok(())
}

/// Key-by-key fingerprint diff; empty means compatible. Mirrors the
/// checkpoint `check_compat` error shape so a mismatched fleet launch
/// reads exactly like a mismatched resume.
fn diff_fingerprints(want: &Json, got: &Json) -> Vec<String> {
    let (Json::Obj(want), Json::Obj(got)) = (want, got) else {
        return vec!["malformed config fingerprint (not a JSON object)".to_string()];
    };
    let mut out = Vec::new();
    for (key, want_v) in want {
        match got.get(key) {
            Some(got_v) if got_v == want_v => {}
            Some(got_v) => out.push(format!(
                "{key}: learner {}, sampler {}",
                want_v.to_string(),
                got_v.to_string()
            )),
            None => out.push(format!("{key}: missing from the sampler's fingerprint")),
        }
    }
    for key in got.keys() {
        if !want.contains_key(key) {
            out.push(format!("{key}: sent by the sampler, unknown to this learner"));
        }
    }
    out
}

/// One connected sampler, learner-side. Reads happen on the barrier loop;
/// writes go through `tx` to the connection's writer thread.
struct SamplerConn {
    conn: Conn,
    tx: mpsc::Sender<Msg>,
    writer: Option<std::thread::JoinHandle<()>>,
    first_slot: usize,
    n_slots: usize,
}

impl SamplerConn {
    fn who(&self) -> String {
        format!("sampler(slots {}..{})", self.first_slot, self.first_slot + self.n_slots)
    }

    /// Queue a message for the writer thread (never blocks; a dead
    /// connection surfaces as a named error at the next *read*).
    fn queue(&self, msg: Msg) {
        let _ = self.tx.send(msg);
    }

    /// Read the next non-heartbeat message.
    fn recv(&mut self) -> Result<Msg> {
        loop {
            match Msg::recv(&mut self.conn)
                .with_context(|| format!("receiving from {}", self.who()))?
            {
                Msg::Heartbeat => continue,
                msg => return Ok(msg),
            }
        }
    }
}

fn beat(conns: &[SamplerConn]) {
    for sc in conns {
        sc.queue(Msg::Heartbeat);
    }
}

impl Coordinator {
    /// Host the training machine for a sampler fleet: bind `opts.bind`,
    /// accept and handshake `opts.samplers` connections, then run to the
    /// step budget (or `limit` more steps, quantized to a window bound)
    /// with every target window's transitions arriving over the wire.
    /// Checkpoints, evaluation, and the returned [`TrainResult`] behave
    /// exactly as in [`Coordinator::run_for`].
    pub fn run_fleet(&mut self, opts: &FleetOpts, limit: Option<u64>) -> Result<TrainResult> {
        validate_fleet_geometry(&self.cfg)?;
        if opts.samplers == 0 {
            bail!("fleet learner needs at least one sampler process (--fleet-samplers)");
        }
        if opts.samplers > self.cfg.threads {
            bail!(
                "fleet has more sampler processes ({}) than sampler slots (threads W={}); \
                 each process needs at least one slot",
                opts.samplers, self.cfg.threads
            );
        }
        if self.machine.is_none() {
            self.machine = Some(self.build_machine(true)?);
        }
        if self.ckpt_dir.is_some() {
            self.validate_ckpt_config()?;
        }

        let listener = Endpoint::parse(&opts.bind)?.bind()?;
        println!(
            "fleet learner listening at {} for {} sampler(s)",
            listener.local_addr_string()?,
            opts.samplers
        );
        let timeout = Duration::from_millis(self.cfg.fleet_timeout_ms);
        let want_fp = super::config_fingerprint(&self.cfg);

        // Accept + handshake. Slots are dealt as contiguous chunks in
        // connection order (the first W % N connections get one extra);
        // which process owns which slot cannot move the trajectory —
        // every upload is keyed by absolute slot and stream ids.
        let mut conns: Vec<SamplerConn> = Vec::with_capacity(opts.samplers);
        let base = self.cfg.threads / opts.samplers;
        let extra = self.cfg.threads % opts.samplers;
        let mut next_slot = 0usize;
        for i in 0..opts.samplers {
            let mut conn = listener.accept()?;
            conn.set_read_timeout(Some(timeout))?;
            let fingerprint = loop {
                match Msg::recv(&mut conn).context("fleet handshake")? {
                    Msg::Hello { fingerprint } => break fingerprint,
                    Msg::Heartbeat => continue,
                    other => bail!(
                        "fleet handshake: expected hello, connection {i} sent {}",
                        other.name()
                    ),
                }
            };
            let got_fp = Json::parse(&fingerprint).map_err(|e| {
                anyhow!("fleet handshake: connection {i} sent an unparsable fingerprint: {e}")
            })?;
            let mismatches = diff_fingerprints(&want_fp, &got_fp);
            if !mismatches.is_empty() {
                let reason = format!(
                    "sampler was launched under a different configuration; refusing \
                     (the fleet trajectory would not be the configured one):\n  {}",
                    mismatches.join("\n  ")
                );
                let _ = Msg::Shutdown { reason: reason.clone() }.send(&mut conn);
                bail!("fleet handshake: {reason}");
            }

            let n_slots = base + usize::from(i < extra);
            let m = self.machine.as_ref().unwrap();
            let lag = self.cfg.fleet_lag;
            let current_tag = m.windows_flushed;
            // Every theta_minus version the sampler's first windows can
            // legally act with: ring entries (older tags, relaxed mode
            // only) plus the current version.
            let mut params: Vec<(u64, Vec<f32>)> = m
                .fleet_theta_ring
                .iter()
                .filter(|(tag, _)| *tag >= current_tag.saturating_sub(lag))
                .cloned()
                .collect();
            params.push((current_tag, self.qnet.theta_minus_host()?));
            let ctxs = (next_slot..next_slot + n_slots)
                .map(|slot| {
                    let mut w = ByteWriter::new();
                    m.ctxs[slot].save_state(&mut w);
                    w.into_bytes()
                })
                .collect();
            Msg::HelloAck {
                first_slot: next_slot as u64,
                n_slots: n_slots as u64,
                start: m.completed,
                total: self.cfg.total_steps,
                lag,
                params,
                ctxs,
            }
            .send(&mut conn)?;

            let (tx, rx) = mpsc::channel::<Msg>();
            let wconn = conn.try_clone()?;
            let writer = std::thread::spawn(move || {
                let mut wconn = wconn;
                while let Ok(msg) = rx.recv() {
                    if msg.send(&mut wconn).is_err() {
                        // Stop writing; the learner's read side reports
                        // the connection failure by name.
                        break;
                    }
                }
            });
            conns.push(SamplerConn { conn, tx, writer: Some(writer), first_slot: next_slot, n_slots });
            next_slot += n_slots;
        }
        drop(listener); // stop accepting (and remove a unix socket file)

        // ---- segment loop (mirrors run_for) ------------------------------
        self.device.stats.reset();
        self.timers.reset();
        let start_step = self.machine.as_ref().unwrap().completed;
        let total = self.cfg.total_steps;
        let end = match limit {
            None => total,
            Some(n) => self.quantize_bound(start_step.saturating_add(n)),
        };
        let t0 = Instant::now();
        let run_result = (|| -> Result<()> {
            while self.machine.as_ref().unwrap().completed < end {
                let completed = self.machine.as_ref().unwrap().completed;
                let mut until = end;
                if self.ckpt_dir.is_some() {
                    until =
                        until.min(self.quantize_bound(completed.saturating_add(self.ckpt_period)));
                }
                self.fleet_segment(until, &mut conns)?;
                if self.ckpt_dir.is_some() {
                    // Keep the fleet alive through the write: samplers may
                    // already be acting the next window on the parameters
                    // broadcast at the final barrier of this segment.
                    beat(&conns);
                    self.save_checkpoint()?;
                    beat(&conns);
                }
            }
            Ok(())
        })();

        let reason = match &run_result {
            Ok(()) if end < total => "slice complete; learner detaching".to_string(),
            Ok(()) => "run complete".to_string(),
            Err(e) => format!("learner error: {e:#}"),
        };
        shutdown_conns(conns, &reason);
        run_result?;

        let wall_s = t0.elapsed().as_secs_f64();
        let m = self.machine.as_ref().unwrap();
        let mut losses = m.losses.clone();
        losses.sort_unstable_by_key(|(s, _)| *s);
        let mut returns = m.returns.clone();
        returns.sort_unstable_by_key(|(s, _)| *s);
        Ok(TrainResult {
            steps: m.completed,
            episodes: m.episodes,
            trains: m.trains_done,
            target_syncs: self.qnet.target_syncs.load(Ordering::SeqCst),
            wall_s,
            steps_per_sec: (m.completed - start_step) as f64 / wall_s.max(1e-9),
            losses,
            returns,
            evals: m.evals.clone(),
            bus: self.device.stats.snapshot(),
            timers_report: self.timers.report(),
        })
    }

    /// One fleet segment: the learner-side counterpart of the async
    /// driver's concurrent main loop, with the sampler threads replaced by
    /// window uploads read off the wire. Every barrier action (flush,
    /// target sync, priority update, eval, broadcast) happens in the same
    /// order the single-process machine performs it.
    fn fleet_segment(&mut self, until: u64, conns: &mut [SamplerConn]) -> Result<()> {
        let cfg = self.cfg.clone();
        let qnet = self.qnet.clone();
        let timers = self.timers.clone();
        let gantt = self.gantt.clone();
        let lag = cfg.fleet_lag;
        let c = cfg.target_update_period;
        let bpw = cfg.batches_per_window();
        let total = cfg.total_steps;
        let eval_period = cfg.eval_period;

        let m = self.machine.as_mut().unwrap();
        let at = ResumePoint {
            completed: m.completed,
            trains_done: m.trains_done,
            episodes: m.episodes,
        };
        let mut seg = SegmentState {
            until,
            windows_flushed: m.windows_flushed,
            draw_rng: m.draw_rng,
        };
        let Machine { replay, ctxs, evaluator, evals, next_eval, fleet_theta_ring, .. } = m;
        let shared = Shared::resumed(&cfg, &qnet, replay, &timers, gantt.as_deref(), at);
        let staging = StagingSet::new(cfg.streams());
        let winctrl = WindowCtrl::new();
        let source = TrainerSource::with_strategy(
            replay,
            build_strategy(
                &strategy_plan(&cfg, qnet.spec().gamma),
                seg.draw_rng,
                shared.trains_done.load(Ordering::SeqCst),
            ),
            cfg.minibatch,
            cfg.prefetch_batches,
            true,
        );

        let result = std::thread::scope(|scope| -> Result<()> {
            if let Some(pipeline) = source.pipeline() {
                let shared = &shared;
                scope.spawn(move || pipeline.worker_loop(&|| shared.should_stop()));
            }
            {
                let shared = &shared;
                let winctrl = &winctrl;
                let source: &dyn BatchSource = &source;
                scope.spawn(move || winctrl.trainer_loop(shared, source));
            }

            // Any error must release the trainer (it never sees `stop`
            // early on the success path, exactly like the async driver).
            let fail = |e: anyhow::Error| -> Result<()> {
                shared.stop.store(true, Ordering::SeqCst);
                winctrl.notify_all();
                Err(e)
            };

            let mut window_end = ((seg.windows_flushed + 1) * c).min(until);
            winctrl.dispatch();
            source.grant(bpw);
            loop {
                let j = seg.windows_flushed; // absolute window being collected
                let window_target = window_end.min(total);

                // Collect one upload per sampler, buffering all of them
                // before touching any machine state: a failure here leaves
                // the machine exactly at the previous barrier.
                let mut uploads: Vec<WindowUpload> = Vec::with_capacity(conns.len());
                for sc in conns.iter_mut() {
                    let up = match sc.recv() {
                        Ok(Msg::Upload(up)) => up,
                        Ok(Msg::Shutdown { reason }) => {
                            return fail(anyhow!(
                                "fleet {} shut down mid-run: {reason}",
                                sc.who()
                            ))
                        }
                        Ok(other) => {
                            return fail(anyhow!(
                                "fleet protocol error: expected the window-{j} upload from \
                                 {}, got {}",
                                sc.who(),
                                other.name()
                            ))
                        }
                        Err(e) => return fail(e),
                    };
                    if up.window != j {
                        return fail(anyhow!(
                            "fleet protocol error: {} uploaded window {}, learner is at \
                             window {j}",
                            sc.who(),
                            up.window
                        ));
                    }
                    uploads.push(up);
                }

                // Apply in connection order. Staged transitions land in the
                // learner's staging set keyed by absolute stream id, so the
                // one shared sync-point flush moves them into replay in
                // stream order — upload arrival order is irrelevant.
                for (sc, up) in conns.iter().zip(uploads) {
                    if up.ctxs.len() != sc.n_slots {
                        return fail(anyhow!(
                            "fleet protocol error: {} uploaded {} context snapshots for \
                             {} owned slots",
                            sc.who(),
                            up.ctxs.len(),
                            sc.n_slots
                        ));
                    }
                    shared.completed.fetch_add(up.steps, Ordering::SeqCst);
                    shared.episodes.fetch_add(up.episodes, Ordering::SeqCst);
                    shared.returns.lock().unwrap().extend(up.returns.iter().copied());
                    for (i, blob) in up.ctxs.iter().enumerate() {
                        let slot = sc.first_slot + i;
                        let mut r = ByteReader::new(blob);
                        ctxs[slot]
                            .load_state(&mut r)
                            .and_then(|_| r.finish())
                            .with_context(|| {
                                format!("applying the context snapshot of slot {slot} from {}", sc.who())
                            })?;
                    }
                    for (stream, items) in up.streams {
                        staging.extend(stream as usize, items);
                    }
                }
                let done = shared.completed.load(Ordering::SeqCst);
                if done != window_target {
                    return fail(anyhow!(
                        "fleet protocol error: samplers covered {done} of {window_target} \
                         steps for window {j} (a slot uploaded too few or too many blocks)"
                    ));
                }

                // Barrier: wait out the trainer's full window quota,
                // heartbeating so samplers (already blocked awaiting the
                // next broadcast) don't time out on a long barrier.
                winctrl.wait_caught_up_while(&shared, || beat(conns));
                if shared.aborted() {
                    return fail(anyhow!("trainer failed"));
                }

                // The theta_minus now retiring acted window j under tag j;
                // relaxed samplers may still need it for up to `lag` more
                // windows.
                let old_theta = if lag > 0 {
                    match qnet.theta_minus_host() {
                        Ok(theta) => Some(theta),
                        Err(e) => return fail(e),
                    }
                } else {
                    None
                };
                shared.sync_point(&staging);
                source.barrier_update();
                seg.windows_flushed += 1;
                if let Some(ev) = evaluator.as_mut() {
                    while done >= *next_eval {
                        if let Ok(point) = ev.run(&qnet, done) {
                            evals.push(point);
                        }
                        *next_eval = next_eval.saturating_add(eval_period);
                    }
                }
                if let Some(theta) = old_theta {
                    fleet_theta_ring.push((j, theta));
                    let keep_from = (j + 1).saturating_sub(lag);
                    fleet_theta_ring.retain(|(tag, _)| *tag >= keep_from);
                }
                // Broadcast the fresh version unconditionally — samplers
                // keep acting across learner checkpoint pauses, and a
                // sampler past the step budget just skips it while waiting
                // for shutdown.
                let theta = match qnet.theta_minus_host() {
                    Ok(theta) => theta,
                    Err(e) => return fail(e),
                };
                for sc in conns.iter() {
                    sc.queue(Msg::ParamBroadcast { tag: j + 1, theta_minus: theta.clone() });
                }

                if window_end >= until {
                    shared.stop.store(true, Ordering::SeqCst);
                    winctrl.notify_all();
                    break;
                }
                window_end = (window_end + c).min(until);
                winctrl.dispatch();
                source.grant(bpw);
            }
            Ok(())
        });
        seg.draw_rng = source.sampler_state();
        let worker_error = shared.error.lock().unwrap().take();

        let completed = shared.completed.load(Ordering::SeqCst);
        let trains_done = shared.trains_done.load(Ordering::SeqCst);
        let episodes = shared.episodes.load(Ordering::SeqCst);
        let new_losses = std::mem::take(&mut *shared.losses.lock().unwrap());
        let new_returns = std::mem::take(&mut *shared.returns.lock().unwrap());
        drop(shared);
        let m = self.machine.as_mut().unwrap();
        m.windows_flushed = seg.windows_flushed;
        m.draw_rng = seg.draw_rng;
        m.completed = completed;
        m.trains_done = trains_done;
        m.episodes = episodes;
        m.losses.extend(new_losses);
        m.returns.extend(new_returns);
        result?;
        if let Some(err) = worker_error {
            bail!(err);
        }
        Ok(())
    }
}

/// Send every sampler a shutdown, then drain and discard whatever they
/// were mid-writing (a relaxed sampler may be blocked in an upload write;
/// consuming it unblocks the write so the sampler reaches the shutdown
/// frame), until each connection closes cleanly or goes silent.
fn shutdown_conns(conns: Vec<SamplerConn>, reason: &str) {
    for sc in &conns {
        sc.queue(Msg::Shutdown { reason: reason.to_string() });
    }
    for mut sc in conns {
        let _ = sc.conn.set_read_timeout(Some(Duration::from_millis(2_000)));
        while Msg::recv(&mut sc.conn).is_ok() {}
        drop(sc.tx); // close the channel so the writer thread exits
        if let Some(writer) = sc.writer.take() {
            let _ = writer.join();
        }
    }
}

/// The sampler process body (`tempo-dqn fleet-sampler --connect ADDR`):
/// connect, handshake, then act the assigned slots' blocks window by
/// window under the wire-fed theta_minus until the learner shuts us down.
pub fn run_fleet_sampler(
    cfg: &ExperimentConfig,
    connect: &str,
    artifact_dir: &Path,
) -> Result<()> {
    validate_fleet_geometry(cfg)?;
    let timeout = Duration::from_millis(cfg.fleet_timeout_ms);
    let mut conn = Conn::connect(&Endpoint::parse(connect)?, timeout)?;
    conn.set_read_timeout(Some(timeout))?;
    Msg::Hello { fingerprint: fingerprint_text(cfg) }.send(&mut conn)?;
    let (first_slot, n_slots, start, total, lag, init_params, ctx_blobs) = loop {
        match Msg::recv(&mut conn).context("fleet handshake")? {
            Msg::HelloAck { first_slot, n_slots, start, total, lag, params, ctxs } => {
                break (first_slot as usize, n_slots as usize, start, total, lag, params, ctxs)
            }
            Msg::Heartbeat => continue,
            Msg::Shutdown { reason } => {
                bail!("fleet learner refused this sampler: {reason}")
            }
            other => bail!("fleet handshake: expected hello-ack, learner sent {}", other.name()),
        }
    };
    if n_slots == 0 || first_slot + n_slots > cfg.threads {
        bail!(
            "fleet handshake: learner assigned slots {first_slot}..{} but this config has \
             W={} sampler slots",
            first_slot + n_slots,
            cfg.threads
        );
    }
    if ctx_blobs.len() != n_slots {
        bail!(
            "fleet handshake: learner sent {} context snapshots for {n_slots} assigned slots",
            ctx_blobs.len()
        );
    }

    // The acting stack: a single-lane device (samplers never train), the
    // Q-net artifacts, and one SamplerCtx per assigned slot restored to
    // the learner's snapshot. The replay memory is a minimum-size stub —
    // acting never touches it (transitions stage for upload) — but the
    // Shared scaffolding wants one.
    let manifest = Manifest::load_or_builtin(artifact_dir)?;
    let device = std::sync::Arc::new(Device::cpu_with_opts(1, cfg.kernel_mode)?);
    let qnet =
        QNet::load_with_head(device, &manifest, &cfg.net, cfg.double, cfg.minibatch, cfg.head_spec())
            .context("loading Q-network artifacts")?;
    let replay = RwLock::new(ReplayMemory::new(
        cfg.streams() * (STACK + 2),
        cfg.streams(),
        NET_FRAME,
        STACK,
        cfg.seed,
    )?);
    let timers = PhaseTimers::new();
    let shared = Shared::resumed(
        cfg,
        &qnet,
        &replay,
        &timers,
        None,
        ResumePoint { completed: start, trains_done: 0, episodes: 0 },
    );
    let staging = StagingSet::new(cfg.streams());
    let mut ctxs = Vec::with_capacity(n_slots);
    for (i, blob) in ctx_blobs.iter().enumerate() {
        let slot = first_slot + i;
        let mut ctx = SamplerCtx::new(cfg, slot)?;
        let mut r = ByteReader::new(blob);
        ctx.load_state(&mut r)
            .and_then(|_| r.finish())
            .with_context(|| format!("restoring the learner's snapshot of slot {slot}"))?;
        ctxs.push(ctx);
    }
    let mut params: std::collections::BTreeMap<u64, Vec<f32>> = init_params.into_iter().collect();

    let w = cfg.threads as u64;
    let b = cfg.envs_per_thread;
    let bs = b as u64;
    let c = cfg.target_update_period;
    let beat_every = timeout / 4;
    let mut last_beat = Instant::now();
    println!(
        "fleet sampler: slots {first_slot}..{} of W={}, resuming at step {start}/{total}, lag {lag}",
        first_slot + n_slots,
        cfg.threads
    );

    let mut j = start / c; // `start` is window-aligned (fleet geometry)
    loop {
        let window_start = j * c;
        if window_start >= total {
            break;
        }
        let window_end = ((j + 1) * c).min(total);
        // Acquire the theta_minus version window j acts with. Replicated
        // mode (lag 0) blocks here for the freshest broadcast — this wait
        // IS the window barrier; relaxed mode already holds the lagged
        // version and runs ahead.
        let needed = j.saturating_sub(lag);
        while !params.contains_key(&needed) {
            match Msg::recv(&mut conn)
                .with_context(|| format!("awaiting theta_minus version {needed}"))?
            {
                Msg::ParamBroadcast { tag, theta_minus } => {
                    params.insert(tag, theta_minus);
                }
                Msg::Heartbeat => continue,
                Msg::Shutdown { reason } => {
                    println!("fleet sampler: learner shutdown: {reason}");
                    return Ok(());
                }
                other => bail!(
                    "fleet protocol error: expected a param broadcast, learner sent {}",
                    other.name()
                ),
            }
        }
        params.retain(|tag, _| *tag >= needed);
        qnet.set_theta_minus(&params[&needed])?;

        // Act every block of this window the static schedule assigns to
        // our slots, in ascending block order (each slot's streams see
        // their blocks in sequence, exactly as its thread would).
        let steps0 = shared.completed.load(Ordering::SeqCst);
        let episodes0 = shared.episodes.load(Ordering::SeqCst);
        for block in (window_start / bs)..window_end.div_ceil(bs) {
            let slot = (block % w) as usize;
            if slot < first_slot || slot >= first_slot + n_slots {
                continue;
            }
            let ctx = &mut ctxs[slot - first_slot];
            let t = block * bs;
            let width = (bs.min(total - t)) as usize;
            ctx.refresh_states();
            let q = qnet.infer(Policy::ThetaMinus, &ctx.states_buf, b)?;
            ctx.act_block(&shared, t, &q, width, |stream, frame, a, r, done, start| {
                staging.push(stream, frame, a, r, done, start);
            });
            if last_beat.elapsed() >= beat_every {
                Msg::Heartbeat.send(&mut conn)?;
                last_beat = Instant::now();
            }
        }

        let steps = shared.completed.load(Ordering::SeqCst) - steps0;
        let episodes = shared.episodes.load(Ordering::SeqCst) - episodes0;
        let returns = std::mem::take(&mut *shared.returns.lock().unwrap());
        let ctx_snaps = ctxs
            .iter()
            .map(|ctx| {
                let mut w = ByteWriter::new();
                ctx.save_state(&mut w);
                w.into_bytes()
            })
            .collect();
        let streams = staging
            .drain_streams()
            .into_iter()
            .map(|(stream, items)| (stream as u64, items))
            .collect();
        Msg::Upload(WindowUpload {
            window: j,
            steps,
            episodes,
            returns,
            ctxs: ctx_snaps,
            streams,
        })
        .send(&mut conn)?;
        last_beat = Instant::now();
        j += 1;
    }

    // Past the step budget: wait for the learner's shutdown (at most the
    // tail broadcasts and heartbeats precede it).
    loop {
        match Msg::recv(&mut conn).context("awaiting fleet shutdown")? {
            Msg::Shutdown { reason } => {
                println!("fleet sampler: learner shutdown: {reason}");
                return Ok(());
            }
            _ => continue,
        }
    }
}

/// Spawn `n` local `fleet-sampler` worker processes of `bin` against
/// `connect`, handing each the full config as CLI arguments (see
/// [`ExperimentConfig::to_cli_args`]). The `fleet` convenience subcommand
/// and the campaign runner both use this.
pub fn spawn_local_samplers(
    bin: &Path,
    cfg: &ExperimentConfig,
    connect: &str,
    n: usize,
) -> Result<Vec<std::process::Child>> {
    let mut children = Vec::with_capacity(n);
    for i in 0..n {
        let child = std::process::Command::new(bin)
            .arg("fleet-sampler")
            .args(cfg.to_cli_args())
            .arg(format!("--connect={connect}"))
            .spawn()
            .with_context(|| format!("spawning fleet sampler {i} ({})", bin.display()))?;
        children.push(child);
    }
    Ok(children)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet_cfg() -> ExperimentConfig {
        let mut cfg = ExperimentConfig::preset("smoke").unwrap();
        cfg.game = "seeker".into();
        cfg.mode = ExecMode::Concurrent;
        cfg.threads = 2;
        cfg.envs_per_thread = 2;
        cfg.total_steps = 400;
        cfg.target_update_period = 100;
        cfg
    }

    #[test]
    fn geometry_validation_names_every_refusal() {
        validate_fleet_geometry(&fleet_cfg()).unwrap();

        let mut bad = fleet_cfg();
        bad.mode = ExecMode::Standard;
        let err = validate_fleet_geometry(&bad).unwrap_err().to_string();
        assert!(err.contains("Concurrent Training"), "{err}");

        bad = fleet_cfg();
        bad.mode = ExecMode::Both;
        let err = validate_fleet_geometry(&bad).unwrap_err().to_string();
        assert!(err.contains("Synchronized Execution"), "{err}");

        bad = fleet_cfg();
        bad.target_update_period = 110; // not a multiple of W*B = 4
        bad.train_period = 11;
        let err = validate_fleet_geometry(&bad).unwrap_err().to_string();
        assert!(err.contains("W*B"), "{err}");

        bad = fleet_cfg();
        bad.total_steps = 450; // not a multiple of C = 100
        let err = validate_fleet_geometry(&bad).unwrap_err().to_string();
        assert!(err.contains("window barrier"), "{err}");
    }

    #[test]
    fn fingerprint_diff_names_keys_both_ways() {
        let a = fleet_cfg();
        let mut b = a.clone();
        assert!(diff_fingerprints(
            &crate::coordinator::config_fingerprint(&a),
            &crate::coordinator::config_fingerprint(&b)
        )
        .is_empty());

        b.seed = 999;
        b.fleet_lag = 2;
        let diffs = diff_fingerprints(
            &crate::coordinator::config_fingerprint(&a),
            &crate::coordinator::config_fingerprint(&b),
        );
        assert_eq!(diffs.len(), 2, "{diffs:?}");
        assert!(diffs.iter().any(|d| d.starts_with("fleet_lag:")), "{diffs:?}");
        assert!(diffs.iter().any(|d| d.starts_with("seed:")), "{diffs:?}");

        // Topology and liveness knobs must NOT appear in the fingerprint.
        let mut c = a.clone();
        c.fleet_samplers = 4;
        c.fleet_timeout_ms = 123;
        assert!(diff_fingerprints(
            &crate::coordinator::config_fingerprint(&a),
            &crate::coordinator::config_fingerprint(&c)
        )
        .is_empty());

        // The head variant and the C51 support ARE trajectory identity: a
        // learner must refuse a head-mismatched sampler by name.
        let mut d = a.clone();
        d.head = crate::config::HeadKind::C51;
        d.atoms = 21;
        let diffs = diff_fingerprints(
            &crate::coordinator::config_fingerprint(&a),
            &crate::coordinator::config_fingerprint(&d),
        );
        assert_eq!(diffs.len(), 2, "{diffs:?}");
        assert!(diffs.iter().any(|x| x.starts_with("head:")), "{diffs:?}");
        assert!(diffs.iter().any(|x| x.starts_with("atoms:")), "{diffs:?}");
    }
}
