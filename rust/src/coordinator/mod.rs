//! The coordinator: the paper's system contribution.
//!
//! Dispatches one of four execution models (paper §5.1's ablation grid)
//! over the shared substrates (device runtime, replay memory, environment
//! suite, metrics):
//!
//! | mode          | Concurrent Training | Synchronized Execution |
//! |---------------|---------------------|------------------------|
//! | standard      | off                 | off                    |
//! | concurrent    | on  (§3)            | off                    |
//! | synchronized  | off                 | on  (§4)               |
//! | both          | on                  | on  (Algorithm 1)      |

pub mod async_exec;
pub mod shared;
pub mod sync_exec;

use std::sync::atomic::Ordering;
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{Context, Result};

use crate::agent::EpsGreedy;
use crate::config::{ExecMode, ExperimentConfig};
use crate::env::{make_env, NET_FRAME};
use crate::eval::{EvalPoint, Evaluator};
use crate::metrics::{GanttTrace, PhaseTimers};
use crate::replay::ReplayMemory;
use crate::runtime::{BusSnapshot, Device, Manifest, QNet};

pub use shared::{SamplerCtx, Shared, TrainInterlock, WindowCtrl, WindowGate};

/// Result of one training run.
#[derive(Debug, Default)]
pub struct TrainResult {
    pub steps: u64,
    pub episodes: u64,
    pub trains: u64,
    pub target_syncs: u64,
    pub wall_s: f64,
    pub steps_per_sec: f64,
    /// (step, loss) samples.
    pub losses: Vec<(u64, f32)>,
    /// (step, raw episode return).
    pub returns: Vec<(u64, f64)>,
    pub evals: Vec<EvalPoint>,
    pub bus: BusSnapshot,
    pub timers_report: String,
}

impl TrainResult {
    /// Mean raw return over the last `n` episodes.
    pub fn recent_mean_return(&self, n: usize) -> f64 {
        let tail: Vec<f64> = self.returns.iter().rev().take(n).map(|(_, r)| *r).collect();
        if tail.is_empty() {
            return 0.0;
        }
        tail.iter().sum::<f64>() / tail.len() as f64
    }
}

/// The launcher-facing coordinator.
pub struct Coordinator {
    cfg: ExperimentConfig,
    qnet: Arc<QNet>,
    device: Arc<Device>,
    timers: Arc<PhaseTimers>,
    gantt: Option<Arc<GanttTrace>>,
    run_eval: bool,
}

impl Coordinator {
    /// Load artifacts (or the builtin manifest when none exist) and build
    /// the full stack for `cfg`.
    pub fn new(cfg: ExperimentConfig, artifact_dir: &std::path::Path) -> Result<Coordinator> {
        // Validate BEFORE sizing the compute pool: the learner_threads cap
        // must reject absurd widths while they are still just a number,
        // not a thread-spawn loop.
        cfg.validate()?;
        let manifest = Manifest::load_or_builtin(artifact_dir)?;
        // The engine's persistent compute pool is sized here; any width
        // yields bit-identical math (rust/DESIGN.md §9).
        let device = Arc::new(Device::cpu_with_threads(cfg.learner_threads)?);
        let qnet = Arc::new(
            QNet::load(device.clone(), &manifest, &cfg.net, cfg.double, cfg.minibatch)
                .context("loading Q-network artifacts")?,
        );
        Self::with_qnet(cfg, device, qnet)
    }

    /// Build around an existing device/network (artifact reuse in sweeps).
    pub fn with_qnet(cfg: ExperimentConfig, device: Arc<Device>, qnet: Arc<QNet>) -> Result<Coordinator> {
        cfg.validate()?;
        // Sanity: the env's action count must fit the compiled head.
        let probe = make_env(&cfg.game, 0)?;
        if probe.num_actions() > qnet.spec().actions {
            anyhow::bail!(
                "game {:?} has {} actions but artifacts were compiled for {}",
                cfg.game, probe.num_actions(), qnet.spec().actions
            );
        }
        // Sanity: the loaded infer entries must cover the largest batch the
        // drivers will request — all W×B streams at once in synchronized
        // modes, B per sampler thread otherwise. Failing here beats failing
        // mid-run after prepopulation and thread spawn.
        let largest = if cfg.mode.synchronized_execution() {
            cfg.streams()
        } else {
            cfg.envs_per_thread
        };
        qnet.infer_batch_for(largest).with_context(|| {
            format!(
                "mode {} needs one inference batch covering {largest} states \
                 (threads={} x envs_per_thread={}); reduce W x B or compile larger infer entries",
                cfg.mode.name(), cfg.threads, cfg.envs_per_thread
            )
        })?;
        Ok(Coordinator {
            cfg,
            qnet,
            device,
            timers: Arc::new(PhaseTimers::new()),
            gantt: None,
            run_eval: true,
        })
    }

    pub fn with_gantt(mut self, trace: Arc<GanttTrace>) -> Self {
        self.gantt = Some(trace);
        self
    }

    pub fn without_eval(mut self) -> Self {
        self.run_eval = false;
        self
    }

    pub fn timers(&self) -> &Arc<PhaseTimers> {
        &self.timers
    }

    pub fn device(&self) -> &Arc<Device> {
        &self.device
    }

    pub fn qnet(&self) -> &Arc<QNet> {
        &self.qnet
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Prepopulate the replay memory with `cfg.prepopulate` random-policy
    /// transitions, spread over all W×B streams (paper Table 5: N). Stream
    /// seeds depend only on the global stream id, so the fill is identical
    /// for any (W, B) factorization of the same stream count — and for B=1
    /// it is exactly the per-thread fill of the one-env-per-thread machine.
    fn prepopulate(&self, replay: &RwLock<ReplayMemory>) -> Result<()> {
        let streams = self.cfg.streams();
        let mut replay = replay.write().unwrap();
        let per_stream = self.cfg.prepopulate.div_ceil(streams);
        for stream in 0..streams {
            let mut env =
                make_env(&self.cfg.game, self.cfg.seed.wrapping_add(0xF00D + stream as u64))?;
            let mut policy = EpsGreedy::new(self.cfg.seed, 0xBEEF ^ stream as u64, env.num_actions());
            let mut frame = vec![0u8; NET_FRAME];
            let mut start = true;
            for _ in 0..per_stream {
                frame.copy_from_slice(env.latest_plane());
                let a = policy.random();
                let r = env.step(a);
                replay.push(stream, &frame, a as u8, r.reward, r.done, start);
                start = false;
                if r.done {
                    env.reset();
                    start = true;
                }
            }
        }
        Ok(())
    }

    /// Run the experiment to completion and return the collected stats.
    pub fn run(&mut self) -> Result<TrainResult> {
        let cfg = self.cfg.clone();
        let replay = RwLock::new(ReplayMemory::new(
            cfg.replay_capacity,
            cfg.streams(),
            NET_FRAME,
            crate::env::STACK,
            cfg.seed,
        )?);
        self.prepopulate(&replay)?;

        let mut evaluator = if self.run_eval && cfg.eval_period < cfg.total_steps {
            Some(Evaluator::new(&cfg.game, cfg.seed, cfg.eval_episodes, cfg.eval_eps)?)
        } else {
            None
        };
        let mut evals: Vec<EvalPoint> = Vec::new();
        let mut next_eval = cfg.eval_period;

        self.device.stats.reset();
        self.timers.reset();
        let shared = Shared::new(
            &cfg,
            &self.qnet,
            &replay,
            &self.timers,
            self.gantt.as_deref(),
        );

        let qnet = &self.qnet;
        let t0 = Instant::now();
        {
            let on_progress = |completed: u64| {
                if let Some(ev) = evaluator.as_mut() {
                    if completed >= next_eval {
                        if let Ok(point) = ev.run(qnet, completed) {
                            evals.push(point);
                        }
                        next_eval += cfg.eval_period;
                    }
                }
            };
            match cfg.mode {
                ExecMode::Standard => async_exec::run_async(&shared, false, on_progress)?,
                ExecMode::Concurrent => async_exec::run_async(&shared, true, on_progress)?,
                ExecMode::Synchronized => sync_exec::run_sync(&shared, false, on_progress)?,
                ExecMode::Both => sync_exec::run_sync(&shared, true, on_progress)?,
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();

        let steps = shared.completed.load(Ordering::SeqCst);
        let mut losses = std::mem::take(&mut *shared.losses.lock().unwrap());
        losses.sort_unstable_by_key(|(s, _)| *s);
        let mut returns = std::mem::take(&mut *shared.returns.lock().unwrap());
        returns.sort_unstable_by_key(|(s, _)| *s);

        Ok(TrainResult {
            steps,
            episodes: shared.episodes.load(Ordering::SeqCst),
            trains: shared.trains_done.load(Ordering::SeqCst),
            target_syncs: self.qnet.target_syncs.load(Ordering::SeqCst),
            wall_s,
            steps_per_sec: steps as f64 / wall_s.max(1e-9),
            losses,
            returns,
            evals,
            bus: self.device.stats.snapshot(),
            timers_report: self.timers.report(),
        })
    }
}
