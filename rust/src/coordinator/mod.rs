//! The coordinator: the paper's system contribution.
//!
//! Dispatches one of four execution models (paper §5.1's ablation grid)
//! over the shared substrates (device runtime, replay memory, environment
//! suite, metrics):
//!
//! | mode          | Concurrent Training | Synchronized Execution |
//! |---------------|---------------------|------------------------|
//! | standard      | off                 | off                    |
//! | concurrent    | on  (§3)            | off                    |
//! | synchronized  | off                 | on  (§4)               |
//! | both          | on                  | on  (Algorithm 1)      |
//!
//! Training runs as a sequence of **segments**: each driver invocation
//! carries the machine from its current step to a quiesce bound and tears
//! its threads down with every stateful layer quiesced (trainer quota
//! consumed, staging flushed, no transaction in flight). Between segments
//! the coordinator may atomically write a checkpoint (`--ckpt-dir` /
//! `--ckpt-period`) and `resume_from` reconstructs the exact machine from
//! one — kill the process at hour 8 of a 9-hour run and the resumed
//! trajectory is bit-identical to the uninterrupted one
//! (rust/DESIGN.md §10, pinned by tests/checkpoint_resume.rs).

pub mod async_exec;
pub mod fleet;
pub mod shared;
pub mod sync_exec;

use std::path::{Path, PathBuf};
use std::sync::atomic::Ordering;
use std::sync::{Arc, RwLock};
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::agent::EpsGreedy;
use crate::ckpt::{
    latest_checkpoint, ByteWriter, CheckpointReader, CheckpointWriter, Snapshot,
};
use crate::config::{ExecMode, ExperimentConfig, ReplayStrategy};
use crate::env::{make_env, NET_FRAME};
use crate::eval::{EvalPoint, Evaluator};
use crate::metrics::{GanttTrace, PhaseTimers};
use crate::replay::{IndexSampler, ReplayMemory};
use crate::runtime::{BusSnapshot, Device, Manifest, QNet, QNetSnapshot};
use crate::util::json::{obj, Json};

pub use fleet::{run_fleet_sampler, spawn_local_samplers, validate_fleet_geometry, FleetOpts};
pub use shared::{
    strategy_plan, ResumePoint, SamplerCtx, SegmentState, Shared, TrainInterlock, WindowCtrl,
    WindowGate,
};

/// Result of one training run.
#[derive(Debug, Default)]
pub struct TrainResult {
    pub steps: u64,
    pub episodes: u64,
    pub trains: u64,
    pub target_syncs: u64,
    pub wall_s: f64,
    pub steps_per_sec: f64,
    /// (step, loss) samples.
    pub losses: Vec<(u64, f32)>,
    /// (step, raw episode return).
    pub returns: Vec<(u64, f64)>,
    pub evals: Vec<EvalPoint>,
    pub bus: BusSnapshot,
    pub timers_report: String,
}

impl TrainResult {
    /// Mean raw return over the last `n` episodes. `n = 0` and an empty
    /// history yield 0.0; `n` larger than the history averages everything.
    pub fn recent_mean_return(&self, n: usize) -> f64 {
        let take = n.min(self.returns.len());
        if take == 0 {
            return 0.0;
        }
        let tail = &self.returns[self.returns.len() - take..];
        tail.iter().map(|(_, r)| *r).sum::<f64>() / take as f64
    }
}

/// The live training machine: every piece of state that survives across
/// segments (and, through a checkpoint, across processes).
struct Machine {
    replay: RwLock<ReplayMemory>,
    /// One persistent sampler context per thread (env streams + policy
    /// RNGs); drivers borrow them for the duration of a segment.
    ctxs: Vec<SamplerCtx>,
    windows_flushed: u64,
    draw_rng: [u64; 4],
    completed: u64,
    trains_done: u64,
    episodes: u64,
    losses: Vec<(u64, f32)>,
    returns: Vec<(u64, f64)>,
    evals: Vec<EvalPoint>,
    next_eval: u64,
    evaluator: Option<Evaluator>,
    /// Relaxed-fleet theta_minus history: `(version tag, parameters)` for
    /// every tag an acting window may still legally request under
    /// `fleet_lag` staleness (rust/DESIGN.md §14). Empty whenever
    /// `fleet_lag == 0` — replicated fleets and single-process runs carry
    /// no ring, so their digests and checkpoints are byte-identical to
    /// the pre-fleet machine.
    fleet_theta_ring: Vec<(u64, Vec<f32>)>,
}

impl Machine {
    /// The "progress" checkpoint section, written and read by exactly this
    /// pair so the field lists cannot drift apart (`ByteReader::finish`
    /// catches any residual mismatch at load time).
    fn save_progress(&self, w: &mut ByteWriter) {
        w.put_u64(self.completed);
        w.put_u64(self.trains_done);
        w.put_u64(self.episodes);
        w.put_u64(self.windows_flushed);
        w.put_rng(self.draw_rng);
        w.put_u64(self.next_eval);
        w.put_usize(self.losses.len());
        for &(s, l) in &self.losses {
            w.put_u64(s);
            w.put_f32(l);
        }
        w.put_usize(self.returns.len());
        for &(s, r) in &self.returns {
            w.put_u64(s);
            w.put_f64(r);
        }
        w.put_usize(self.evals.len());
        for ev in &self.evals {
            w.put_u64(ev.step);
            w.put_f64(ev.mean_return);
            w.put_f64(ev.std_return);
            w.put_usize(ev.episodes);
        }
    }

    fn load_progress(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> Result<()> {
        self.completed = r.u64()?;
        self.trains_done = r.u64()?;
        self.episodes = r.u64()?;
        self.windows_flushed = r.u64()?;
        self.draw_rng = r.rng()?;
        self.next_eval = r.u64()?;
        let n = r.usize()?;
        self.losses = (0..n).map(|_| Ok((r.u64()?, r.f32()?))).collect::<Result<_>>()?;
        let n = r.usize()?;
        self.returns = (0..n).map(|_| Ok((r.u64()?, r.f64()?))).collect::<Result<_>>()?;
        let n = r.usize()?;
        self.evals = (0..n)
            .map(|_| {
                Ok(EvalPoint {
                    step: r.u64()?,
                    mean_return: r.f64()?,
                    std_return: r.f64()?,
                    episodes: r.usize()?,
                })
            })
            .collect::<Result<_>>()?;
        Ok(())
    }
}

/// The launcher-facing coordinator.
pub struct Coordinator {
    cfg: ExperimentConfig,
    qnet: Arc<QNet>,
    device: Arc<Device>,
    timers: Arc<PhaseTimers>,
    gantt: Option<Arc<GanttTrace>>,
    run_eval: bool,
    machine: Option<Machine>,
    ckpt_dir: Option<PathBuf>,
    ckpt_period: u64,
}

impl Coordinator {
    /// Load artifacts (or the builtin manifest when none exist) and build
    /// the full stack for `cfg`.
    pub fn new(cfg: ExperimentConfig, artifact_dir: &std::path::Path) -> Result<Coordinator> {
        // Validate BEFORE sizing the compute pool: the learner_threads cap
        // must reject absurd widths while they are still just a number,
        // not a thread-spawn loop.
        cfg.validate()?;
        let manifest = Manifest::load_or_builtin(artifact_dir)?;
        // The engine's persistent compute pool is sized here; any width
        // yields bit-identical math (rust/DESIGN.md §9).
        let device = Arc::new(Device::cpu_with_opts(cfg.learner_threads, cfg.kernel_mode)?);
        let qnet = Arc::new(
            QNet::load_with_head(
                device.clone(),
                &manifest,
                &cfg.net,
                cfg.double,
                cfg.minibatch,
                cfg.head_spec(),
            )
            .context("loading Q-network artifacts")?,
        );
        Self::with_qnet(cfg, device, qnet)
    }

    /// Build around an existing device/network (artifact reuse in sweeps).
    pub fn with_qnet(cfg: ExperimentConfig, device: Arc<Device>, qnet: Arc<QNet>) -> Result<Coordinator> {
        cfg.validate()?;
        // Sanity: the env's action count must fit the compiled head.
        let probe = make_env(&cfg.game, 0)?;
        if probe.num_actions() > qnet.spec().actions {
            anyhow::bail!(
                "game {:?} has {} actions but artifacts were compiled for {}",
                cfg.game, probe.num_actions(), qnet.spec().actions
            );
        }
        // Sanity: the loaded infer entries must cover the largest batch the
        // drivers will request — all W×B streams at once in synchronized
        // modes, B per sampler thread otherwise. Failing here beats failing
        // mid-run after prepopulation and thread spawn.
        let largest = if cfg.mode.synchronized_execution() {
            cfg.streams()
        } else {
            cfg.envs_per_thread
        };
        qnet.infer_batch_for(largest).with_context(|| {
            format!(
                "mode {} needs one inference batch covering {largest} states \
                 (threads={} x envs_per_thread={}); reduce W x B or compile larger infer entries",
                cfg.mode.name(), cfg.threads, cfg.envs_per_thread
            )
        })?;
        let ckpt_dir = cfg.ckpt_dir.clone().map(PathBuf::from);
        let ckpt_period = cfg.ckpt_period;
        Ok(Coordinator {
            cfg,
            qnet,
            device,
            timers: Arc::new(PhaseTimers::new()),
            gantt: None,
            run_eval: true,
            machine: None,
            ckpt_dir,
            ckpt_period,
        })
    }

    pub fn with_gantt(mut self, trace: Arc<GanttTrace>) -> Self {
        self.gantt = Some(trace);
        self
    }

    pub fn without_eval(mut self) -> Self {
        self.run_eval = false;
        self
    }

    /// Enable (or re-target) periodic checkpointing: one checkpoint every
    /// `period` steps (quantized up to the mode's next quiesce point) plus
    /// one at the end of every `run_for` call.
    pub fn with_checkpointing(mut self, dir: impl Into<PathBuf>, period: u64) -> Self {
        self.ckpt_dir = Some(dir.into());
        self.ckpt_period = period.max(1);
        self
    }

    pub fn timers(&self) -> &Arc<PhaseTimers> {
        &self.timers
    }

    pub fn device(&self) -> &Arc<Device> {
        &self.device
    }

    pub fn qnet(&self) -> &Arc<QNet> {
        &self.qnet
    }

    pub fn config(&self) -> &ExperimentConfig {
        &self.cfg
    }

    /// Steps completed so far (0 before the first run / resume).
    pub fn completed_steps(&self) -> u64 {
        self.machine.as_ref().map(|m| m.completed).unwrap_or(0)
    }

    /// Prepopulate the replay memory with `cfg.prepopulate` random-policy
    /// transitions, spread over all W×B streams (paper Table 5: N). Stream
    /// seeds depend only on the global stream id, so the fill is identical
    /// for any (W, B) factorization of the same stream count — and for B=1
    /// it is exactly the per-thread fill of the one-env-per-thread machine.
    fn prepopulate(&self, replay: &RwLock<ReplayMemory>) -> Result<()> {
        let streams = self.cfg.streams();
        let mut replay = replay.write().unwrap();
        let per_stream = self.cfg.prepopulate.div_ceil(streams);
        for stream in 0..streams {
            let mut env =
                make_env(&self.cfg.game, self.cfg.seed.wrapping_add(0xF00D + stream as u64))?;
            let mut policy = EpsGreedy::new(self.cfg.seed, 0xBEEF ^ stream as u64, env.num_actions());
            let mut frame = vec![0u8; NET_FRAME];
            let mut start = true;
            for _ in 0..per_stream {
                frame.copy_from_slice(env.latest_plane());
                let a = policy.random();
                let r = env.step(a);
                replay.push(stream, &frame, a as u8, r.reward, r.done, start);
                start = false;
                if r.done {
                    env.reset();
                    start = true;
                }
            }
        }
        Ok(())
    }

    /// Build a fresh machine (optionally skipping prepopulation when the
    /// replay contents are about to be overwritten by a checkpoint).
    fn build_machine(&self, prepopulate: bool) -> Result<Machine> {
        let cfg = &self.cfg;
        let mut memory = ReplayMemory::new(
            cfg.replay_capacity,
            cfg.streams(),
            NET_FRAME,
            crate::env::STACK,
            cfg.seed,
        )?;
        if cfg.replay_strategy == ReplayStrategy::Proportional {
            // Before any push, so prepopulated transitions get their
            // max-priority seeds through the same per-push path a live
            // run uses.
            memory.enable_priorities();
        }
        let replay = RwLock::new(memory);
        if prepopulate {
            self.prepopulate(&replay)?;
        }
        let ctxs = (0..cfg.threads)
            .map(|slot| SamplerCtx::new(cfg, slot))
            .collect::<Result<Vec<_>>>()?;
        let evaluator = if self.run_eval && cfg.eval_period < cfg.total_steps {
            Some(Evaluator::new(&cfg.game, cfg.eval_seed, cfg.eval_episodes, cfg.eval_eps)?)
        } else {
            None
        };
        Ok(Machine {
            replay,
            ctxs,
            windows_flushed: 0,
            draw_rng: IndexSampler::new(cfg.seed).rng_state(),
            completed: 0,
            trains_done: 0,
            episodes: 0,
            losses: Vec::new(),
            returns: Vec::new(),
            evals: Vec::new(),
            next_eval: cfg.eval_period,
            evaluator,
            fleet_theta_ring: Vec::new(),
        })
    }

    /// Smallest valid quiesce bound >= `step` for the configured mode:
    /// window-aligned (multiple of C) when Concurrent Training is on,
    /// B-block-aligned for the async standard driver; the synchronized
    /// drivers quantize to whole W×B rounds on their own.
    fn quantize_bound(&self, step: u64) -> u64 {
        let cfg = &self.cfg;
        let total = cfg.total_steps;
        if step >= total {
            return total;
        }
        let step = step.max(1);
        let q = if cfg.mode.concurrent_training() {
            step.div_ceil(cfg.target_update_period) * cfg.target_update_period
        } else if cfg.mode.synchronized_execution() {
            step
        } else {
            let b = cfg.envs_per_thread as u64;
            step.div_ceil(b) * b
        };
        q.min(total)
    }

    /// Run the experiment to completion and return the collected stats.
    pub fn run(&mut self) -> Result<TrainResult> {
        self.run_for(None)
    }

    /// Run at most `limit` further steps (quantized up to the mode's next
    /// quiesce point), or to `total_steps` when `None`. The machine
    /// persists across calls, so a campaign can interleave legs; with
    /// checkpointing enabled every segment boundary (period targets and
    /// the final bound) writes a checkpoint.
    pub fn run_for(&mut self, limit: Option<u64>) -> Result<TrainResult> {
        if self.machine.is_none() {
            self.machine = Some(self.build_machine(true)?);
        }
        if self.ckpt_dir.is_some() {
            self.validate_ckpt_config()?;
        }
        self.device.stats.reset();
        self.timers.reset();
        let start_step = self.machine.as_ref().unwrap().completed;
        let total = self.cfg.total_steps;
        let end = match limit {
            None => total,
            Some(n) => self.quantize_bound(start_step.saturating_add(n)),
        };

        let t0 = Instant::now();
        while self.machine.as_ref().unwrap().completed < end {
            let completed = self.machine.as_ref().unwrap().completed;
            let mut until = end;
            if self.ckpt_dir.is_some() {
                until = until.min(self.quantize_bound(completed.saturating_add(self.ckpt_period)));
            }
            self.run_segment(until)?;
            if self.ckpt_dir.is_some() {
                self.save_checkpoint()?;
            }
        }
        let wall_s = t0.elapsed().as_secs_f64();

        let m = self.machine.as_ref().unwrap();
        let mut losses = m.losses.clone();
        losses.sort_unstable_by_key(|(s, _)| *s);
        let mut returns = m.returns.clone();
        returns.sort_unstable_by_key(|(s, _)| *s);
        Ok(TrainResult {
            steps: m.completed,
            episodes: m.episodes,
            trains: m.trains_done,
            target_syncs: self.qnet.target_syncs.load(Ordering::SeqCst),
            wall_s,
            steps_per_sec: (m.completed - start_step) as f64 / wall_s.max(1e-9),
            losses,
            returns,
            evals: m.evals.clone(),
            bus: self.device.stats.snapshot(),
            timers_report: self.timers.report(),
        })
    }

    /// One driver invocation from the machine's current step to `until`.
    fn run_segment(&mut self, until: u64) -> Result<()> {
        let cfg = self.cfg.clone();
        let qnet = self.qnet.clone();
        let timers = self.timers.clone();
        let gantt = self.gantt.clone();
        let m = self.machine.as_mut().unwrap();
        let at = ResumePoint {
            completed: m.completed,
            trains_done: m.trains_done,
            episodes: m.episodes,
        };
        let mut seg = SegmentState {
            until,
            windows_flushed: m.windows_flushed,
            draw_rng: m.draw_rng,
        };
        let Machine { replay, ctxs, evaluator, evals, next_eval, .. } = m;
        let shared = Shared::resumed(&cfg, &qnet, replay, &timers, gantt.as_deref(), at);
        {
            let eval_period = cfg.eval_period;
            let qnet = &qnet;
            let on_progress = |completed: u64| {
                if let Some(ev) = evaluator.as_mut() {
                    // Catch up on every period the segment crossed; in
                    // windowed modes this only runs at quiesce points, so
                    // theta is frozen and the recorded step deterministic.
                    while completed >= *next_eval {
                        if let Ok(point) = ev.run(qnet, completed) {
                            evals.push(point);
                        }
                        *next_eval = next_eval.saturating_add(eval_period);
                    }
                }
            };
            match cfg.mode {
                ExecMode::Standard => async_exec::run_async(&shared, false, ctxs, &mut seg, on_progress)?,
                ExecMode::Concurrent => async_exec::run_async(&shared, true, ctxs, &mut seg, on_progress)?,
                ExecMode::Synchronized => sync_exec::run_sync(&shared, false, ctxs, &mut seg, on_progress)?,
                ExecMode::Both => sync_exec::run_sync(&shared, true, ctxs, &mut seg, on_progress)?,
            }
        }
        let completed = shared.completed.load(Ordering::SeqCst);
        let trains_done = shared.trains_done.load(Ordering::SeqCst);
        let episodes = shared.episodes.load(Ordering::SeqCst);
        let new_losses = std::mem::take(&mut *shared.losses.lock().unwrap());
        let new_returns = std::mem::take(&mut *shared.returns.lock().unwrap());
        drop(shared);
        let m = self.machine.as_mut().unwrap();
        m.windows_flushed = seg.windows_flushed;
        m.draw_rng = seg.draw_rng;
        m.completed = completed;
        m.trains_done = trains_done;
        m.episodes = episodes;
        m.losses.extend(new_losses);
        m.returns.extend(new_returns);
        Ok(())
    }

    /// Checkpointing needs deterministic quiesce states; reject the one
    /// degenerate geometry where the synchronized both-mode driver cannot
    /// provide them (rounds that span more than one target window).
    fn validate_ckpt_config(&self) -> Result<()> {
        if self.ckpt_period == 0 {
            bail!("ckpt_period must be >= 1 step");
        }
        if self.cfg.mode == ExecMode::Both
            && (self.cfg.streams() as u64) > self.cfg.target_update_period
        {
            bail!(
                "checkpointing in mode 'both' requires C >= W*B (a round must not span \
                 multiple target windows); got C={} < W*B={}",
                self.cfg.target_update_period,
                self.cfg.streams()
            );
        }
        Ok(())
    }

    // ---- checkpoint save/restore -----------------------------------------

    /// Config fields a checkpoint must agree on to resume bit-exactly.
    /// (learner_threads / prefetch_batches are excluded on purpose: both
    /// are bit-exact knobs, rust/DESIGN.md §9. total_steps is excluded so
    /// a resumed run may extend or shorten the budget.)
    fn config_fingerprint(&self) -> Json {
        config_fingerprint(&self.cfg)
    }

    fn check_compat(&self, meta: &Json) -> Result<()> {
        let want = self.config_fingerprint();
        let saved = meta.get("config").ok_or_else(|| {
            anyhow!("checkpoint manifest has no config fingerprint")
        })?;
        let (Json::Obj(want), Json::Obj(saved)) = (&want, saved) else {
            bail!("checkpoint manifest: malformed config fingerprint");
        };
        // Checkpoints written before the replay-strategy layer (§11) lack
        // its fingerprint keys; they were produced by the uniform/n=1
        // machine, so resuming them is bit-exact exactly when this run
        // uses those defaults — accept that case instead of stranding
        // every pre-upgrade checkpoint. (Encodings mirror
        // `config_fingerprint`.)
        let dflt = ExperimentConfig::default();
        let legacy_defaults = [
            ("replay_strategy", Json::Str(dflt.replay_strategy.name().to_string())),
            ("per_alpha", Json::Str(format!("{:016x}", dflt.per_alpha.to_bits()))),
            ("per_beta0", Json::Str(format!("{:016x}", dflt.per_beta0.to_bits()))),
            ("per_beta_anneal", Json::Num(dflt.per_beta_anneal as f64)),
            ("n_step", Json::Num(dflt.n_step as f64)),
            // Pre-§12 checkpoints predate the kernel_mode knob; they were
            // produced by the deterministic tier, so resuming is bit-exact
            // exactly when this run is deterministic too.
            ("kernel_mode", Json::Str(dflt.kernel_mode.name().to_string())),
            // Pre-§14 checkpoints predate the fleet layer; they carry no
            // theta_minus ring, which is exactly a fleet_lag = 0 machine.
            ("fleet_lag", Json::Num(dflt.fleet_lag as f64)),
            // Pre-§16 checkpoints predate the head knob; they were all
            // produced by the dqn tail, so resuming is bit-exact exactly
            // when this run uses the default head (and the C51 support
            // knobs at their inert defaults).
            ("head", Json::Str(dflt.head.name().to_string())),
            ("atoms", Json::Num(dflt.atoms as f64)),
            ("v_min", Json::Str(format!("{:016x}", dflt.v_min.to_bits()))),
            ("v_max", Json::Str(format!("{:016x}", dflt.v_max.to_bits()))),
        ];
        let mut mismatches = Vec::new();
        for (key, want_v) in want {
            match saved.get(key) {
                Some(saved_v) if saved_v == want_v => {}
                Some(saved_v) => mismatches.push(format!(
                    "{key}: checkpoint {}, this run {}",
                    saved_v.to_string(),
                    want_v.to_string()
                )),
                None => {
                    let legacy_ok =
                        legacy_defaults.iter().any(|(k, d)| k == key && want_v == d);
                    if !legacy_ok {
                        mismatches.push(format!("{key}: missing from checkpoint"));
                    }
                }
            }
        }
        if !mismatches.is_empty() {
            bail!(
                "checkpoint was written under a different configuration; refusing to resume \
                 (bit-exact resume is impossible):\n  {}",
                mismatches.join("\n  ")
            );
        }
        Ok(())
    }

    /// Atomically write a checkpoint of the current quiesced machine into
    /// the configured (or given) directory. Returns the checkpoint path.
    pub fn save_checkpoint(&self) -> Result<PathBuf> {
        let dir = self
            .ckpt_dir
            .clone()
            .ok_or_else(|| anyhow!("no checkpoint directory configured (--ckpt-dir)"))?;
        let m = self
            .machine
            .as_ref()
            .ok_or_else(|| anyhow!("nothing to checkpoint: the machine has not run yet"))?;

        let mut wtr = CheckpointWriter::new(m.completed);
        wtr.meta("config", self.config_fingerprint());
        wtr.meta("total_steps", Json::Num(self.cfg.total_steps as f64));
        wtr.add(&QNetSnapshot(self.qnet.as_ref()))?;
        wtr.add(&*m.replay.read().unwrap())?;

        let mut w = ByteWriter::new();
        w.put_usize(m.ctxs.len());
        for ctx in &m.ctxs {
            ctx.save_state(&mut w);
        }
        wtr.add_raw("samplers", 1, w.into_bytes())?;

        let mut w = ByteWriter::new();
        m.save_progress(&mut w);
        wtr.add_raw("progress", 1, w.into_bytes())?;

        if self.cfg.replay_strategy == ReplayStrategy::Proportional {
            // The sum-tree / β-anneal section (rust/DESIGN.md §11): the
            // PER hyperparameters (redundant with the config fingerprint,
            // cross-checked on restore) plus every stored transition's
            // latent priority and generation in logical order. β itself
            // needs no extra state — it is a pure function of the
            // progress section's trains_done.
            let mut w = ByteWriter::new();
            w.put_f64(self.cfg.per_alpha);
            w.put_f64(self.cfg.per_beta0);
            w.put_u64(self.cfg.per_beta_anneal);
            w.put_usize(self.cfg.n_step);
            m.replay.read().unwrap().save_priorities(&mut w)?;
            wtr.add_raw("priorities", 1, w.into_bytes())?;
        }

        if self.cfg.fleet_lag > 0 {
            // The relaxed-fleet theta_minus ring (rust/DESIGN.md §14): a
            // resumed learner must re-offer every parameter version a
            // sampler's first window may still act with. Conditional on the
            // knob (like the "priorities" section), so lag-0 checkpoints
            // stay byte-identical to pre-fleet ones.
            let mut w = ByteWriter::new();
            w.put_u64(self.cfg.fleet_lag);
            w.put_usize(m.fleet_theta_ring.len());
            for (tag, theta) in &m.fleet_theta_ring {
                w.put_u64(*tag);
                w.put_f32_slice(theta);
            }
            wtr.add_raw("fleet", 1, w.into_bytes())?;
        }

        if let Some(ev) = &m.evaluator {
            wtr.add(ev)?;
        }
        wtr.write(&dir)
    }

    /// Reconstruct the machine from a checkpoint: `dir` may be a specific
    /// `step_<N>` directory or a checkpoint root (the newest step is used).
    /// Returns the resumed step. The configuration must match the one the
    /// checkpoint was written under (see `config_fingerprint`).
    pub fn resume_from(&mut self, dir: &Path) -> Result<u64> {
        let path = if dir.join("manifest.json").exists() {
            dir.to_path_buf()
        } else {
            latest_checkpoint(dir)?
                .ok_or_else(|| anyhow!("no checkpoint found under {}", dir.display()))?
        };
        let rdr = CheckpointReader::open(&path)?;
        self.check_compat(rdr.meta())?;

        let mut m = self.build_machine(false)?;
        rdr.restore(&mut QNetSnapshot(self.qnet.as_ref()))?;
        rdr.restore(&mut *m.replay.write().unwrap())?;

        let mut r = rdr.read_section("samplers", 1)?;
        let n = r.usize()?;
        if n != m.ctxs.len() {
            bail!("checkpoint has {n} sampler contexts, this machine has {}", m.ctxs.len());
        }
        for ctx in &mut m.ctxs {
            ctx.load_state(&mut r)?;
        }
        r.finish().context("restoring checkpoint section \"samplers\"")?;

        let mut r = rdr.read_section("progress", 1)?;
        m.load_progress(&mut r)?;
        r.finish().context("restoring checkpoint section \"progress\"")?;

        if self.cfg.replay_strategy == ReplayStrategy::Proportional {
            // Must run AFTER the replay contents restore: the priority
            // overlay addresses the re-based ring's physical leaves.
            let mut r = rdr.read_section("priorities", 1)?;
            let (alpha, beta0, anneal, n_step) = (r.f64()?, r.f64()?, r.u64()?, r.usize()?);
            if alpha.to_bits() != self.cfg.per_alpha.to_bits()
                || beta0.to_bits() != self.cfg.per_beta0.to_bits()
                || anneal != self.cfg.per_beta_anneal
                || n_step != self.cfg.n_step
            {
                bail!(
                    "checkpoint priorities section was written under different PER \
                     hyperparameters (α {alpha}, β₀ {beta0}, anneal {anneal}, n {n_step})"
                );
            }
            m.replay.write().unwrap().load_priorities(&mut r)?;
            r.finish().context("restoring checkpoint section \"priorities\"")?;
        }

        if self.cfg.fleet_lag > 0 {
            // Fingerprint equality above guarantees the checkpoint was
            // written under the same fleet_lag, so the section is present
            // exactly when the knob says it is.
            let mut r = rdr.read_section("fleet", 1)?;
            let lag = r.u64()?;
            if lag != self.cfg.fleet_lag {
                bail!(
                    "checkpoint fleet section was written under fleet_lag {lag}, \
                     this run uses {}",
                    self.cfg.fleet_lag
                );
            }
            let n = r.usize()?;
            m.fleet_theta_ring =
                (0..n).map(|_| Ok((r.u64()?, r.f32_vec()?))).collect::<Result<_>>()?;
            r.finish().context("restoring checkpoint section \"fleet\"")?;
        }

        if let Some(ev) = m.evaluator.as_mut() {
            if rdr.has_section("evaluator") {
                rdr.restore(ev)?;
            }
            // else: the checkpointed run had no evaluator (its budget never
            // crossed eval_period, or it ran without_eval), so no eval ever
            // consumed evaluator state — the pristine evaluator built above
            // is exactly what the uninterrupted longer run would carry here,
            // and next_eval was restored from the progress section. This is
            // what lets `--resume` extend a run's budget across the
            // eval_period threshold.
        }
        if m.completed != rdr.step() {
            bail!(
                "checkpoint {}: manifest step {} disagrees with progress section step {}",
                path.display(),
                rdr.step(),
                m.completed
            );
        }
        self.machine = Some(m);
        Ok(rdr.step())
    }

    /// FNV-1a digest over the core machine state (parameters, optimizer
    /// accumulators, target net, replay contents, sampler contexts, RNG
    /// positions, progress counters). Two machines on the same trajectory
    /// digest identically — the resume-smoke comparison hash.
    ///
    /// Deliberately a curated subset, NOT `save_progress`: loss/return
    /// samples carry step tags read from a racing counter in concurrent
    /// modes, so hashing them would make the digest nondeterministic. Keep
    /// this list in sync with the bit-exactness guarantee in
    /// rust/DESIGN.md §10 when adding machine state.
    pub fn state_digest(&self) -> Result<u64> {
        let m = self
            .machine
            .as_ref()
            .ok_or_else(|| anyhow!("no machine state yet (run or resume first)"))?;
        let mut w = ByteWriter::new();
        QNetSnapshot(self.qnet.as_ref()).save(&mut w);
        {
            let replay = m.replay.read().unwrap();
            replay.save(&mut w);
            if self.cfg.replay_strategy == ReplayStrategy::Proportional {
                // Priorities are trajectory state too: two proportional
                // machines on the same trajectory carry identical trees.
                replay.save_priorities(&mut w)?;
            }
        }
        for ctx in &m.ctxs {
            ctx.save_state(&mut w);
        }
        w.put_rng(m.draw_rng);
        w.put_u64(m.completed);
        w.put_u64(m.trains_done);
        w.put_u64(m.episodes);
        w.put_u64(m.windows_flushed);
        for ev in &m.evals {
            w.put_u64(ev.step);
            w.put_f64(ev.mean_return);
            w.put_f64(ev.std_return);
        }
        // Relaxed-fleet theta ring (empty — zero bytes — unless
        // fleet_lag > 0, so every historical digest is unchanged).
        for (tag, theta) in &m.fleet_theta_ring {
            w.put_u64(*tag);
            w.put_f32_slice(theta);
        }
        Ok(crate::ckpt::fnv1a(&w.into_bytes()))
    }
}

/// The trajectory-identity fingerprint of a configuration: every field two
/// machines must agree on to walk the same trajectory bit-for-bit. Used in
/// two places with one key list so they cannot drift: checkpoint resume
/// (`Coordinator::check_compat`) and the fleet handshake (a sampler's
/// `hello` carries this object as text; the learner refuses mismatches
/// field-by-field, by name — rust/DESIGN.md §14). `fleet_samplers` and
/// `fleet_timeout_ms` are deliberately absent (topology and wall-clock
/// knobs — a replicated fleet run IS the single-process trajectory);
/// `fleet_lag` is present because staleness changes what is learned.
pub(crate) fn config_fingerprint(c: &ExperimentConfig) -> Json {
    obj(vec![
        ("game", Json::Str(c.game.clone())),
        ("mode", Json::Str(c.mode.name().to_string())),
        ("threads", Json::Num(c.threads as f64)),
        ("envs_per_thread", Json::Num(c.envs_per_thread as f64)),
        ("seed", Json::Str(format!("{:016x}", c.seed))),
        ("net", Json::Str(c.net.clone())),
        ("double", Json::Bool(c.double)),
        ("head", Json::Str(c.head.name().to_string())),
        ("atoms", Json::Num(c.atoms as f64)),
        ("v_min", Json::Str(format!("{:016x}", c.v_min.to_bits()))),
        ("v_max", Json::Str(format!("{:016x}", c.v_max.to_bits()))),
        ("minibatch", Json::Num(c.minibatch as f64)),
        ("replay_capacity", Json::Num(c.replay_capacity as f64)),
        ("target_update_period", Json::Num(c.target_update_period as f64)),
        ("train_period", Json::Num(c.train_period as f64)),
        ("gamma", Json::Str(format!("{:016x}", c.gamma.to_bits()))),
        ("prepopulate", Json::Num(c.prepopulate as f64)),
        ("lr", Json::Str(format!("{:016x}", c.lr.to_bits()))),
        ("eps_start", Json::Str(format!("{:016x}", c.eps.start.to_bits()))),
        ("eps_end", Json::Str(format!("{:016x}", c.eps.end.to_bits()))),
        ("eps_decay_steps", Json::Num(c.eps.decay_steps as f64)),
        ("eval_period", Json::Str(format!("{:016x}", c.eval_period))),
        ("eval_episodes", Json::Num(c.eval_episodes as f64)),
        ("eval_eps", Json::Str(format!("{:016x}", c.eval_eps.to_bits()))),
        ("eval_seed", Json::Str(format!("{:016x}", c.eval_seed))),
        ("replay_strategy", Json::Str(c.replay_strategy.name().to_string())),
        ("per_alpha", Json::Str(format!("{:016x}", c.per_alpha.to_bits()))),
        ("per_beta0", Json::Str(format!("{:016x}", c.per_beta0.to_bits()))),
        ("per_beta_anneal", Json::Num(c.per_beta_anneal as f64)),
        ("n_step", Json::Num(c.n_step as f64)),
        ("kernel_mode", Json::Str(c.kernel_mode.name().to_string())),
        ("fleet_lag", Json::Num(c.fleet_lag as f64)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn recent_mean_return_edge_cases() {
        let mut res = TrainResult::default();
        // Empty history: always 0, for any n.
        assert_eq!(res.recent_mean_return(0), 0.0);
        assert_eq!(res.recent_mean_return(5), 0.0);

        res.returns = vec![(10, 1.0), (20, 2.0), (30, 6.0)];
        // n = 0 is defined as 0.0, not a division by zero.
        assert_eq!(res.recent_mean_return(0), 0.0);
        // Exact tail.
        assert_eq!(res.recent_mean_return(1), 6.0);
        assert_eq!(res.recent_mean_return(2), 4.0);
        // n > history length averages the whole history, not n slots.
        assert_eq!(res.recent_mean_return(3), 3.0);
        assert_eq!(res.recent_mean_return(100), 3.0);
        assert_eq!(res.recent_mean_return(usize::MAX), 3.0);
    }

    #[test]
    fn quantize_bound_respects_mode_alignment() {
        let mut cfg = ExperimentConfig::preset("smoke").unwrap();
        cfg.game = "seeker".into();
        cfg.total_steps = 1_000;
        cfg.target_update_period = 100;
        cfg.envs_per_thread = 4;
        let artifact = crate::runtime::default_artifact_dir();

        cfg.mode = ExecMode::Both;
        let c = Coordinator::new(cfg.clone(), &artifact).unwrap();
        assert_eq!(c.quantize_bound(1), 100, "windowed modes align to C");
        assert_eq!(c.quantize_bound(100), 100);
        assert_eq!(c.quantize_bound(101), 200);
        assert_eq!(c.quantize_bound(5_000), 1_000, "clamped to total");

        cfg.mode = ExecMode::Standard;
        let c = Coordinator::new(cfg.clone(), &artifact).unwrap();
        assert_eq!(c.quantize_bound(1), 4, "async standard aligns to B");
        assert_eq!(c.quantize_bound(9), 12);

        cfg.mode = ExecMode::Synchronized;
        let c = Coordinator::new(cfg, &artifact).unwrap();
        assert_eq!(c.quantize_bound(9), 9, "sync rounds self-quantize");
    }
}
