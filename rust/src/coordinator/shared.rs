//! State shared between the main thread, sampler threads, and the trainer
//! thread, plus the two synchronization devices the paper's execution
//! models are built from:
//!
//! * [`TrainInterlock`] — the *sequential dependency* of standard DQN
//!   (paper §3): acting at step t requires floor(t/F) completed minibatch
//!   updates, because action selection depends on the freshly-updated
//!   theta. Disabling Concurrent Training means enforcing this interlock.
//!
//! * [`WindowGate`] — Concurrent Training's replacement: steps may proceed
//!   freely until the end of the current C-step target window; crossing
//!   threads park until the main thread flushes staging, syncs theta_minus,
//!   and opens the next window.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use anyhow::Result;

use crate::agent::EpsGreedy;
use crate::config::ExperimentConfig;
use crate::env::{make_env, AtariEnv, NET_FRAME, STATE_BYTES};
use crate::metrics::{GanttTrace, Phase, PhaseTimers};
use crate::replay::ReplayMemory;
use crate::runtime::{QNet, TrainBatch};

/// Everything the worker threads share by reference (threads are scoped).
pub struct Shared<'a> {
    pub cfg: &'a ExperimentConfig,
    pub qnet: &'a QNet,
    pub replay: &'a Mutex<ReplayMemory>,
    pub timers: &'a PhaseTimers,
    pub gantt: Option<&'a GanttTrace>,
    /// Steps claimed by samplers (monotone ticket counter).
    pub claimed: AtomicU64,
    /// Steps fully executed.
    pub completed: AtomicU64,
    pub stop: AtomicBool,
    /// Minibatch updates completed.
    pub trains_done: AtomicU64,
    pub losses: Mutex<Vec<(u64, f32)>>,
    pub returns: Mutex<Vec<(u64, f64)>>,
    pub episodes: AtomicU64,
    pub error: Mutex<Option<String>>,
}

impl<'a> Shared<'a> {
    pub fn new(
        cfg: &'a ExperimentConfig,
        qnet: &'a QNet,
        replay: &'a Mutex<ReplayMemory>,
        timers: &'a PhaseTimers,
        gantt: Option<&'a GanttTrace>,
    ) -> Self {
        Shared {
            cfg,
            qnet,
            replay,
            timers,
            gantt,
            claimed: AtomicU64::new(0),
            completed: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            trains_done: AtomicU64::new(0),
            losses: Mutex::new(Vec::new()),
            returns: Mutex::new(Vec::new()),
            episodes: AtomicU64::new(0),
            error: Mutex::new(None),
        }
    }

    /// Record a worker error and stop the run.
    pub fn fail(&self, err: impl std::fmt::Display) {
        let mut slot = self.error.lock().unwrap();
        if slot.is_none() {
            *slot = Some(err.to_string());
        }
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// True only when a worker recorded an error (hard abort).
    pub fn aborted(&self) -> bool {
        self.error.lock().unwrap().is_some()
    }

    /// Time `f` under `phase`, also recording a Gantt span on `lane` when
    /// tracing is enabled (the Figure 2 reproduction).
    pub fn span<T>(&self, lane: usize, phase: Phase, f: impl FnOnce() -> T) -> T {
        match self.gantt {
            Some(g) => {
                let start = g.now_ns();
                let out = self.timers.time(phase, f);
                g.record(lane, phase, start, g.now_ns());
                out
            }
            None => self.timers.time(phase, f),
        }
    }

    /// Gantt lane for the trainer thread (samplers use 0..threads).
    pub fn trainer_lane(&self) -> usize {
        self.cfg.threads
    }

    /// Gantt lane for the main/dispatch thread.
    pub fn main_lane(&self) -> usize {
        self.cfg.threads + 1
    }

    /// Sample a minibatch and run one training step, recording the loss.
    pub fn do_one_train(&self, batch: &mut TrainBatch) -> Result<()> {
        let lane = self.trainer_lane();
        self.span(lane, Phase::Sample, || -> Result<()> {
            let mut replay = self.replay.lock().unwrap();
            replay.sample(self.cfg.minibatch, batch)
        })?;
        let loss = self
            .span(lane, Phase::Train, || self.qnet.train_step(batch, self.cfg.lr as f32))?;
        let t = self.trains_done.fetch_add(1, Ordering::SeqCst);
        // Record a bounded loss curve (every 16th update after warm-up).
        if t % 16 == 0 {
            self.losses
                .lock()
                .unwrap()
                .push((self.completed.load(Ordering::Relaxed), loss));
        }
        Ok(())
    }
}

/// Standard DQN's training/sampling interlock (Concurrent Training OFF).
#[derive(Default)]
pub struct TrainInterlock {
    gate: Mutex<bool>, // training duty claimed?
    cv: Condvar,
}

impl TrainInterlock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Block until `trains_done >= t / F`, training ourselves if the duty
    /// is free. Called by a sampler before acting at step `t`.
    pub fn ensure_trained(&self, shared: &Shared<'_>, t: u64, batch: &mut TrainBatch) {
        let f = shared.cfg.train_period;
        let required = t / f;
        loop {
            if shared.trains_done.load(Ordering::SeqCst) >= required || shared.should_stop() {
                return;
            }
            let mut claimed = self.gate.lock().unwrap();
            if !*claimed {
                *claimed = true;
                drop(claimed);
                while shared.trains_done.load(Ordering::SeqCst) < required && !shared.should_stop() {
                    if let Err(e) = shared.do_one_train(batch) {
                        shared.fail(format!("train: {e}"));
                    }
                }
                *self.gate.lock().unwrap() = false;
                self.cv.notify_all();
            } else {
                // Someone else is training; wait for progress.
                let (c, timeout) = self
                    .cv
                    .wait_timeout(claimed, std::time::Duration::from_millis(1))
                    .unwrap();
                drop(c);
                let _ = timeout;
            }
        }
    }
}

/// Concurrent Training's C-step window gate.
pub struct WindowGate {
    state: Mutex<u64>, // current window end (exclusive step bound)
    cv: Condvar,
}

impl WindowGate {
    pub fn new(initial_end: u64) -> Self {
        WindowGate { state: Mutex::new(initial_end), cv: Condvar::new() }
    }

    /// Sampler-side: park until step `t` falls inside the open window.
    pub fn wait_for_step(&self, shared: &Shared<'_>, t: u64) {
        let mut end = self.state.lock().unwrap();
        while t >= *end && !shared.should_stop() {
            let (e, _) = self
                .cv
                .wait_timeout(end, std::time::Duration::from_millis(1))
                .unwrap();
            end = e;
        }
    }

    /// Main-side: open the window up to `new_end` steps.
    pub fn advance(&self, new_end: u64) {
        *self.state.lock().unwrap() = new_end;
        self.cv.notify_all();
    }

    pub fn current_end(&self) -> u64 {
        *self.state.lock().unwrap()
    }
}

/// Sampler-owned per-thread context: its environment, policy stream, and
/// scratch buffers (allocation-free hot loop).
pub struct SamplerCtx {
    pub slot: usize,
    pub env: AtariEnv,
    pub policy: EpsGreedy,
    pub state_buf: Vec<u8>,
    pub frame_buf: Vec<u8>,
    pub pending_start: bool,
}

impl SamplerCtx {
    pub fn new(cfg: &ExperimentConfig, slot: usize) -> Result<Self> {
        let env = make_env(&cfg.game, cfg.seed.wrapping_add(slot as u64 * 7919))?;
        let actions = env.num_actions();
        Ok(SamplerCtx {
            slot,
            env,
            policy: EpsGreedy::new(cfg.seed, slot as u64, actions),
            state_buf: vec![0u8; STATE_BYTES],
            frame_buf: vec![0u8; NET_FRAME],
            pending_start: true,
        })
    }

    /// Act on `q` (one row) at global step `t`: select the action, step the
    /// env, and hand the resulting transition to `sink`. Returns `done`.
    pub fn act<F>(&mut self, shared: &Shared<'_>, t: u64, q: &[f32], mut sink: F) -> bool
    where
        F: FnMut(&[u8], u8, f32, bool, bool),
    {
        let eps = shared.cfg.eps.at(t);
        let action = self.policy.select(q, eps);
        self.frame_buf.copy_from_slice(self.env.latest_plane());
        let r = shared.span(self.slot, Phase::EnvStep, || self.env.step(action));
        sink(&self.frame_buf, action as u8, r.reward, r.done, self.pending_start);
        self.pending_start = false;
        if r.done {
            let ret = self.env.episode_raw_return();
            shared.returns.lock().unwrap().push((t, ret));
            shared.episodes.fetch_add(1, Ordering::Relaxed);
            self.env.reset();
            self.pending_start = true;
        }
        shared.completed.fetch_add(1, Ordering::SeqCst);
        r.done
    }

    /// Write the current stacked state into `state_buf` and return it.
    pub fn refresh_state(&mut self) -> &[u8] {
        self.env.write_state(&mut self.state_buf);
        &self.state_buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn window_gate_blocks_and_advances() {
        let gate = WindowGate::new(10);
        assert_eq!(gate.current_end(), 10);
        gate.advance(20);
        assert_eq!(gate.current_end(), 20);
    }

    #[test]
    fn sampler_ctx_round_trip() {
        let mut cfg = ExperimentConfig::preset("smoke").unwrap();
        cfg.game = "seeker".into();
        let mut s = SamplerCtx::new(&cfg, 0).unwrap();
        let st = s.refresh_state();
        assert_eq!(st.len(), STATE_BYTES);
    }
}
