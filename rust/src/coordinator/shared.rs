//! The shared sampler-loop core: state shared between the main thread,
//! sampler threads, and the trainer thread, plus the synchronization
//! devices the paper's execution models are built from. Both drivers
//! (`async_exec`, `sync_exec`) are thin strategies over this module — they
//! differ only in *how Q-values are obtained* (per-thread inference vs. the
//! batched slot mailbox); everything else (stream bookkeeping, action
//! selection, staging, the trainer window protocol, the sync point) lives
//! here once.
//!
//! Synchronization devices:
//!
//! * [`TrainInterlock`] — the *sequential dependency* of standard DQN
//!   (paper §3): acting at step t requires floor(t/F) completed minibatch
//!   updates, because action selection depends on the freshly-updated
//!   theta. Disabling Concurrent Training means enforcing this interlock.
//!
//! * [`WindowGate`] — Concurrent Training's replacement: steps may proceed
//!   freely until the end of the current C-step target window; crossing
//!   threads park until the main thread flushes staging, syncs theta_minus,
//!   and opens the next window.
//!
//! * [`WindowCtrl`] — the trainer thread's window protocol (dispatch a
//!   window's C/F minibatches, wait for them at the barrier), previously
//!   duplicated in both drivers.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Condvar, Mutex, RwLock};

use anyhow::Result;

use crate::agent::{policy::select_rows, EpsGreedy};
use crate::config::ExperimentConfig;
use crate::env::{VecEnv, NET_FRAME, STATE_BYTES};
use crate::metrics::{GanttTrace, Phase, PhaseTimers};
use crate::replay::{BatchSource, ReplayMemory, StagingSet, StrategyPlan};
use crate::runtime::{QNet, TrainBatch};

/// The replay-strategy parameters of `cfg` as the replay layer's plain
/// carrier (both drivers build their segment's strategy from this; the
/// replay crate stays independent of the launcher config).
///
/// `spec_gamma` is the *network spec's* discount — the γ the engine's
/// legacy 1-step entry bakes in — not `cfg.gamma`: n-step assembly and
/// the per-sample bootstrap discounts must use the exact same scalar the
/// engine would, or `n_step = 1` would stop reproducing the one-step
/// targets whenever the config knob and the manifest disagree.
pub fn strategy_plan(cfg: &ExperimentConfig, spec_gamma: f64) -> StrategyPlan {
    StrategyPlan {
        kind: cfg.replay_strategy,
        per_alpha: cfg.per_alpha,
        per_beta0: cfg.per_beta0,
        per_beta_anneal: cfg.per_beta_anneal,
        n_step: cfg.n_step,
        gamma: spec_gamma,
    }
}

/// Everything the worker threads share by reference (threads are scoped).
/// Replay sits behind a `RwLock`: samplers and the staging flush take the
/// write half; batch assembly (trainer / prefetch worker) only reads.
pub struct Shared<'a> {
    pub cfg: &'a ExperimentConfig,
    pub qnet: &'a QNet,
    pub replay: &'a RwLock<ReplayMemory>,
    pub timers: &'a PhaseTimers,
    pub gantt: Option<&'a GanttTrace>,
    /// Steps fully executed.
    pub completed: AtomicU64,
    pub stop: AtomicBool,
    /// Minibatch updates completed.
    pub trains_done: AtomicU64,
    pub losses: Mutex<Vec<(u64, f32)>>,
    pub returns: Mutex<Vec<(u64, f64)>>,
    pub episodes: AtomicU64,
    pub error: Mutex<Option<String>>,
}

impl<'a> Shared<'a> {
    pub fn new(
        cfg: &'a ExperimentConfig,
        qnet: &'a QNet,
        replay: &'a RwLock<ReplayMemory>,
        timers: &'a PhaseTimers,
        gantt: Option<&'a GanttTrace>,
    ) -> Self {
        Self::resumed(cfg, qnet, replay, timers, gantt, ResumePoint::default())
    }

    /// [`Shared::new`] with the monotone progress counters pre-loaded from
    /// a checkpoint (or a previous segment of the same run). Each driver
    /// derives its block schedule from `completed` (absolute steps), so no
    /// other sampler position survives a segment boundary.
    pub fn resumed(
        cfg: &'a ExperimentConfig,
        qnet: &'a QNet,
        replay: &'a RwLock<ReplayMemory>,
        timers: &'a PhaseTimers,
        gantt: Option<&'a GanttTrace>,
        at: ResumePoint,
    ) -> Self {
        Shared {
            cfg,
            qnet,
            replay,
            timers,
            gantt,
            completed: AtomicU64::new(at.completed),
            stop: AtomicBool::new(false),
            trains_done: AtomicU64::new(at.trains_done),
            losses: Mutex::new(Vec::new()),
            returns: Mutex::new(Vec::new()),
            episodes: AtomicU64::new(at.episodes),
            error: Mutex::new(None),
        }
    }

    /// Record a worker error and stop the run.
    pub fn fail(&self, err: impl std::fmt::Display) {
        let mut slot = self.error.lock().unwrap();
        if slot.is_none() {
            *slot = Some(err.to_string());
        }
        self.stop.store(true, Ordering::SeqCst);
    }

    pub fn should_stop(&self) -> bool {
        self.stop.load(Ordering::SeqCst)
    }

    /// True only when a worker recorded an error (hard abort).
    pub fn aborted(&self) -> bool {
        self.error.lock().unwrap().is_some()
    }

    /// Time `f` under `phase`, also recording a Gantt span on `lane` when
    /// tracing is enabled (the Figure 2 reproduction).
    pub fn span<T>(&self, lane: usize, phase: Phase, f: impl FnOnce() -> T) -> T {
        match self.gantt {
            Some(g) => {
                let start = g.now_ns();
                let out = self.timers.time(phase, f);
                g.record(lane, phase, start, g.now_ns());
                out
            }
            None => self.timers.time(phase, f),
        }
    }

    /// Gantt lane for the trainer thread (samplers use 0..threads).
    pub fn trainer_lane(&self) -> usize {
        self.cfg.threads
    }

    /// Gantt lane for the main/dispatch thread.
    pub fn main_lane(&self) -> usize {
        self.cfg.threads + 1
    }

    /// Pull a minibatch from `source` and run one training step, recording
    /// the loss and handing the TD errors back to the sampling strategy
    /// (priority updates; a no-op under uniform replay). Returns
    /// `Ok(false)` when the source reports a clean stop (run shutting down
    /// before another batch arrives).
    pub fn do_one_train(&self, source: &dyn BatchSource, batch: &mut TrainBatch) -> Result<bool> {
        let lane = self.trainer_lane();
        // With prefetch this span measures only the O(1) buffer swap (plus
        // any wait for the worker) — the point of the pipeline.
        let got = self
            .span(lane, Phase::Sample, || source.next_batch(batch, &|| self.should_stop()))?;
        if !got {
            return Ok(false);
        }
        let outcome = self
            .span(lane, Phase::Train, || self.qnet.train_step_td(batch, self.cfg.lr as f32))?;
        source.record_td(&outcome.td_errors);
        let t = self.trains_done.fetch_add(1, Ordering::SeqCst);
        // Record a bounded loss curve (every 16th update after warm-up).
        if t % 16 == 0 {
            self.losses
                .lock()
                .unwrap()
                .push((self.completed.load(Ordering::Relaxed), outcome.loss));
        }
        Ok(true)
    }

    /// Synchronization point (paper Algorithm 1, line "synchronize"):
    /// flush all staged transitions into replay, then theta_minus <- theta.
    /// Shared by both drivers. Safe against the prefetch pipeline by
    /// construction: the flush only runs after the trainer consumed every
    /// granted batch, so no assembly holds the read lock or is pending.
    pub fn sync_point(&self, staging: &StagingSet) {
        self.span(self.main_lane(), Phase::Sync, || {
            let mut replay = self.replay.write().unwrap();
            staging.flush_into(&mut replay);
            self.qnet.sync_target();
        });
    }
}

/// Monotone progress counters carried across segments / checkpoints.
#[derive(Clone, Copy, Debug, Default)]
pub struct ResumePoint {
    pub completed: u64,
    pub trains_done: u64,
    pub episodes: u64,
}

/// Cross-segment driver state. A *segment* is one driver invocation that
/// runs from the machine's current step to a quiesce bound and tears its
/// threads down with every layer quiesced — the unit between checkpoints.
///
/// `until` must be a valid quiesce bound for the mode: `cfg.total_steps`,
/// or (for a mid-run checkpoint) a C-aligned window boundary in concurrent
/// modes / a B-aligned step in async-standard; the synchronized drivers
/// additionally round to whole W×B rounds on their own.
#[derive(Clone, Copy, Debug)]
pub struct SegmentState {
    /// Step bound of this segment (see above).
    pub until: u64,
    /// Synchronization points performed so far (windowed modes): the next
    /// window dispatched covers steps `windows_flushed*C .. +C`.
    pub windows_flushed: u64,
    /// Trainer draw-stream position (the sampling strategy's RNG — the
    /// same "REPL" stream for uniform and proportional;
    /// [`crate::replay::SamplingStrategy::rng_state`]), written back at
    /// segment exit. All other strategy state lives in the replay memory's
    /// priority index (persistent across segments) or is derived from
    /// `trains_done` (β anneal), so this is the strategy's whole
    /// per-segment carry.
    pub draw_rng: [u64; 4],
}

/// Standard DQN's training/sampling interlock (Concurrent Training OFF).
#[derive(Default)]
pub struct TrainInterlock {
    gate: Mutex<bool>, // training duty claimed?
    cv: Condvar,
}

impl TrainInterlock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Block until `trains_done >= t / F`, training ourselves if the duty
    /// is free. Called by a sampler before acting at step `t` (for a block
    /// of B steps, `t` is the block's last step).
    pub fn ensure_trained(
        &self,
        shared: &Shared<'_>,
        source: &dyn BatchSource,
        t: u64,
        batch: &mut TrainBatch,
    ) {
        let f = shared.cfg.train_period;
        let required = t / f;
        loop {
            if shared.trains_done.load(Ordering::SeqCst) >= required || shared.should_stop() {
                return;
            }
            let mut claimed = self.gate.lock().unwrap();
            if !*claimed {
                *claimed = true;
                drop(claimed);
                while shared.trains_done.load(Ordering::SeqCst) < required && !shared.should_stop() {
                    match shared.do_one_train(source, batch) {
                        Ok(true) => {}
                        Ok(false) => break,
                        Err(e) => shared.fail(format!("train: {e}")),
                    }
                }
                *self.gate.lock().unwrap() = false;
                self.cv.notify_all();
            } else {
                // Someone else is training; wait for progress.
                let (c, timeout) = self
                    .cv
                    .wait_timeout(claimed, std::time::Duration::from_millis(1))
                    .unwrap();
                drop(c);
                let _ = timeout;
            }
        }
    }
}

/// Concurrent Training's C-step window gate.
pub struct WindowGate {
    state: Mutex<u64>, // current window end (exclusive step bound)
    cv: Condvar,
}

impl WindowGate {
    pub fn new(initial_end: u64) -> Self {
        WindowGate { state: Mutex::new(initial_end), cv: Condvar::new() }
    }

    /// Sampler-side: park until step `t` falls inside the open window.
    pub fn wait_for_step(&self, shared: &Shared<'_>, t: u64) {
        let mut end = self.state.lock().unwrap();
        while t >= *end && !shared.should_stop() {
            let (e, _) = self
                .cv
                .wait_timeout(end, std::time::Duration::from_millis(1))
                .unwrap();
            end = e;
        }
    }

    /// Main-side: open the window up to `new_end` steps.
    pub fn advance(&self, new_end: u64) {
        *self.state.lock().unwrap() = new_end;
        self.cv.notify_all();
    }

    pub fn current_end(&self) -> u64 {
        *self.state.lock().unwrap()
    }
}

/// The trainer thread's window protocol (Concurrent Training ON): the main
/// thread dispatches one window at a time; the trainer runs C/F minibatches
/// per dispatched window; the main thread waits for it at the window
/// barrier. Identical in both drivers, so it lives here.
#[derive(Default)]
pub struct WindowCtrl {
    dispatched: AtomicU64,
    done: AtomicU64,
    lock: Mutex<()>,
    cv: Condvar,
}

impl WindowCtrl {
    pub fn new() -> Self {
        Self::default()
    }

    /// Main-side: dispatch one window's worth of training.
    pub fn dispatch(&self) {
        self.dispatched.fetch_add(1, Ordering::SeqCst);
        self.cv.notify_all();
    }

    /// True when the trainer has finished every dispatched window.
    pub fn caught_up(&self) -> bool {
        self.done.load(Ordering::SeqCst) >= self.dispatched.load(Ordering::SeqCst)
    }

    /// Wake the trainer so it can observe `stop` (shutdown paths).
    pub fn notify_all(&self) {
        self.cv.notify_all();
    }

    /// Main-side: spin-wait until the trainer caught up (or the run stops).
    pub fn wait_caught_up(&self, shared: &Shared<'_>) {
        self.wait_caught_up_while(shared, || {});
    }

    /// [`WindowCtrl::wait_caught_up`] with a periodic callback (~1 ms
    /// cadence) while waiting — the fleet learner uses it to keep
    /// heartbeats flowing to its samplers through a long trainer barrier.
    pub fn wait_caught_up_while(&self, shared: &Shared<'_>, mut tick: impl FnMut()) {
        let mut spins = 0u32;
        while !self.caught_up() {
            if shared.should_stop() {
                break;
            }
            std::thread::sleep(std::time::Duration::from_micros(100));
            spins += 1;
            if spins % 10 == 0 {
                tick();
            }
        }
    }

    /// The trainer thread's body: for every dispatched window, run
    /// `batches_per_window()` minibatch updates pulled from `source`, then
    /// report done. With a prefetch source, batch t+1 assembles while the
    /// compute pool grinds through batch t.
    pub fn trainer_loop(&self, shared: &Shared<'_>, source: &dyn BatchSource) {
        let mut batch = TrainBatch::default();
        loop {
            // Wait for a dispatched window (or stop).
            loop {
                if shared.should_stop() {
                    return;
                }
                if self.done.load(Ordering::SeqCst) < self.dispatched.load(Ordering::SeqCst) {
                    break;
                }
                let g = self.lock.lock().unwrap();
                let _ = self
                    .cv
                    .wait_timeout(g, std::time::Duration::from_millis(1))
                    .unwrap();
            }
            for _ in 0..shared.cfg.batches_per_window() {
                if shared.should_stop() {
                    return;
                }
                match shared.do_one_train(source, &mut batch) {
                    Ok(true) => {}
                    Ok(false) => return,
                    Err(e) => return shared.fail(format!("trainer: {e}")),
                }
            }
            self.done.fetch_add(1, Ordering::SeqCst);
            self.cv.notify_all();
        }
    }
}

/// Sampler-owned per-thread context: its B environment streams, their
/// policy RNG streams, and scratch buffers (allocation-free hot loop).
///
/// Stream `slot*B + j` owns environment j of this context; its seed,
/// policy stream, and replay stream are all derived from that global id,
/// so B=1 reproduces the one-env-per-thread layout bit-for-bit.
pub struct SamplerCtx {
    pub slot: usize,
    /// Global id of this context's first stream (`slot * B`).
    pub base_stream: usize,
    pub envs: VecEnv,
    pub policies: Vec<EpsGreedy>,
    /// All B stacked states, contiguous (`B * STATE_BYTES`).
    pub states_buf: Vec<u8>,
    frame_buf: Vec<u8>,
    actions_buf: Vec<usize>,
    pending_start: Vec<bool>,
}

impl SamplerCtx {
    pub fn new(cfg: &ExperimentConfig, slot: usize) -> Result<Self> {
        let b = cfg.envs_per_thread;
        let base_stream = slot * b;
        let seeds: Vec<u64> = (0..b)
            .map(|j| cfg.seed.wrapping_add((base_stream + j) as u64 * 7919))
            .collect();
        let envs = VecEnv::new(&cfg.game, &seeds)?;
        let actions = envs.num_actions();
        let policies = (0..b)
            .map(|j| EpsGreedy::new(cfg.seed, (base_stream + j) as u64, actions))
            .collect();
        Ok(SamplerCtx {
            slot,
            base_stream,
            envs,
            policies,
            states_buf: vec![0u8; b * STATE_BYTES],
            frame_buf: vec![0u8; NET_FRAME],
            actions_buf: Vec::with_capacity(b),
            pending_start: vec![true; b],
        })
    }

    /// Number of environment streams in this context (B).
    pub fn width(&self) -> usize {
        self.envs.len()
    }

    /// Checkpoint this context: its B environments, policy RNG streams,
    /// and episode-start flags. Scratch buffers are rebuilt on use.
    pub fn save_state(&self, w: &mut crate::ckpt::ByteWriter) {
        w.put_usize(self.slot);
        w.put_usize(self.base_stream);
        self.envs.save_state(w);
        w.put_usize(self.policies.len());
        for p in &self.policies {
            w.put_rng(p.rng_state());
        }
        w.put_bool_slice(&self.pending_start);
    }

    /// Restore a context written by [`SamplerCtx::save_state`].
    pub fn load_state(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> Result<()> {
        let slot = r.usize()?;
        let base = r.usize()?;
        if slot != self.slot || base != self.base_stream {
            anyhow::bail!(
                "checkpoint sampler context (slot {slot}, base stream {base}) does not match \
                 this machine (slot {}, base stream {}) — W×B layout changed?",
                self.slot, self.base_stream
            );
        }
        self.envs.load_state(r)?;
        let n = r.usize()?;
        if n != self.policies.len() {
            anyhow::bail!("checkpoint has {n} policy streams, this context has {}", self.policies.len());
        }
        for p in &mut self.policies {
            p.set_rng_state(r.rng()?);
        }
        let pending = r.bool_vec()?;
        if pending.len() != self.pending_start.len() {
            anyhow::bail!("checkpoint pending-start flags do not match B");
        }
        self.pending_start = pending;
        Ok(())
    }

    /// Write all B stacked states into `states_buf` and return it — the
    /// zero-copy input of one batched inference.
    pub fn refresh_states(&mut self) -> &[u8] {
        self.envs.write_states(&mut self.states_buf);
        &self.states_buf
    }

    /// Act on the first `n` of this context's B Q-rows at base step
    /// `t_base`: batch-select the actions (one per stream, each from its
    /// own RNG stream), then step each environment, handing every
    /// transition to `sink` as `(stream, frame, action, reward, done,
    /// start)`. Stream j acts at global step `t_base + j`. `n < B` is used
    /// by the async drivers to clamp the final block to the step budget;
    /// `q` is always the full B-row buffer.
    pub fn act_block<F>(&mut self, shared: &Shared<'_>, t_base: u64, q: &[f32], n: usize, mut sink: F)
    where
        F: FnMut(usize, &[u8], u8, f32, bool, bool),
    {
        let b = self.width();
        let n = n.min(b);
        debug_assert_eq!(q.len() % b, 0);
        let stride = q.len() / b;
        let eps = &shared.cfg.eps;
        select_rows(
            &mut self.policies[..n],
            &q[..n * stride],
            stride,
            |j| eps.at(t_base + j as u64),
            &mut self.actions_buf,
        );
        for j in 0..n {
            let t = t_base + j as u64;
            let action = self.actions_buf[j];
            self.frame_buf.copy_from_slice(self.envs.latest_plane(j));
            let r = shared.span(self.slot, Phase::EnvStep, || self.envs.step(j, action));
            sink(
                self.base_stream + j,
                &self.frame_buf,
                action as u8,
                r.reward,
                r.done,
                self.pending_start[j],
            );
            self.pending_start[j] = false;
            if r.done {
                let ret = self.envs.env(j).episode_raw_return();
                shared.returns.lock().unwrap().push((t, ret));
                shared.episodes.fetch_add(1, Ordering::Relaxed);
                self.envs.reset(j);
                self.pending_start[j] = true;
            }
            shared.completed.fetch_add(1, Ordering::SeqCst);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ExperimentConfig;

    #[test]
    fn window_gate_blocks_and_advances() {
        let gate = WindowGate::new(10);
        assert_eq!(gate.current_end(), 10);
        gate.advance(20);
        assert_eq!(gate.current_end(), 20);
    }

    #[test]
    fn window_ctrl_counts_windows() {
        let ctrl = WindowCtrl::new();
        assert!(ctrl.caught_up());
        ctrl.dispatch();
        assert!(!ctrl.caught_up());
        ctrl.done.fetch_add(1, Ordering::SeqCst);
        assert!(ctrl.caught_up());
    }

    #[test]
    fn sampler_ctx_round_trip() {
        let mut cfg = ExperimentConfig::preset("smoke").unwrap();
        cfg.game = "seeker".into();
        let mut s = SamplerCtx::new(&cfg, 0).unwrap();
        assert_eq!(s.width(), 1);
        let st = s.refresh_states();
        assert_eq!(st.len(), STATE_BYTES);
    }

    #[test]
    fn sampler_ctx_vectorized_streams() {
        let mut cfg = ExperimentConfig::preset("smoke").unwrap();
        cfg.game = "seeker".into();
        cfg.envs_per_thread = 4;
        let mut s = SamplerCtx::new(&cfg, 1).unwrap();
        assert_eq!(s.width(), 4);
        assert_eq!(s.base_stream, 4);
        let st = s.refresh_states();
        assert_eq!(st.len(), 4 * STATE_BYTES);
    }

    #[test]
    fn b1_ctx_matches_seed_stream_layout() {
        // With B=1, thread `slot` must own exactly the env seed and policy
        // stream the one-env-per-thread coordinator used: seed + slot*7919
        // and policy stream id = slot.
        let mut cfg = ExperimentConfig::preset("smoke").unwrap();
        cfg.game = "seeker".into();
        cfg.seed = 123;
        let ctx = SamplerCtx::new(&cfg, 3).unwrap();
        assert_eq!(ctx.base_stream, 3);
        let expect = crate::env::make_env("seeker", 123u64.wrapping_add(3 * 7919)).unwrap();
        assert_eq!(ctx.envs.env(0).state_vec(), expect.state_vec());
    }
}
