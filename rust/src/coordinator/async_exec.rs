//! Asynchronous-execution driver (Synchronized Execution OFF).
//!
//! W sampler threads each own an environment and compute their own size-1
//! Q-inference on the shared device — the contention regime of the paper's
//! Figure 3(a). Two variants:
//!
//! * **standard** (Concurrent Training OFF): original DQN semantics — a
//!   sampler may not act at step t until floor(t/F) minibatch updates have
//!   completed ([`TrainInterlock`]); acting uses theta.
//! * **concurrent** (Concurrent Training ON, paper §3): acting uses
//!   theta_minus, a dedicated trainer thread runs C/F minibatches per
//!   C-step window, transitions stage per-thread and flush only at the
//!   window barrier, where theta_minus <- theta.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};

use anyhow::{anyhow, Result};

use crate::metrics::Phase;
use crate::replay::StagingBuffer;
use crate::runtime::{Policy, TrainBatch};

use super::shared::{SamplerCtx, Shared, TrainInterlock, WindowGate};

/// Run the async driver. `concurrent` selects the variant.
/// `on_progress` is invoked from the main thread with the completed-step
/// count (eval hooks / logging).
pub fn run_async(
    shared: &Shared<'_>,
    concurrent: bool,
    mut on_progress: impl FnMut(u64) + Send,
) -> Result<()> {
    let w = shared.cfg.threads;
    let total = shared.cfg.total_steps;
    let c = shared.cfg.target_update_period;

    let interlock = TrainInterlock::new();
    let gate = WindowGate::new(if concurrent { c.min(total) } else { u64::MAX });
    let stagings: Vec<Mutex<StagingBuffer>> =
        (0..w).map(|_| Mutex::new(StagingBuffer::new())).collect();

    // Trainer-thread window protocol (concurrent only).
    let dispatched = AtomicU64::new(0);
    let trainer_done = AtomicU64::new(0);
    let trainer_cv = (Mutex::new(()), Condvar::new());

    std::thread::scope(|scope| -> Result<()> {
        // ---- sampler threads --------------------------------------------
        for slot in 0..w {
            let shared = &shared;
            let gate = &gate;
            let interlock = &interlock;
            let stagings = &stagings;
            scope.spawn(move || {
                let mut ctx = match SamplerCtx::new(shared.cfg, slot) {
                    Ok(c) => c,
                    Err(e) => return shared.fail(format!("sampler {slot}: {e}")),
                };
                let mut train_batch = TrainBatch::default();
                loop {
                    if shared.should_stop() {
                        break;
                    }
                    let t = shared.claimed.fetch_add(1, Ordering::SeqCst);
                    if t >= total {
                        shared.stop.store(true, Ordering::SeqCst);
                        break;
                    }
                    if concurrent {
                        gate.wait_for_step(shared, t);
                    } else {
                        interlock.ensure_trained(shared, t, &mut train_batch);
                    }
                    // After claiming a valid step we must complete it (the
                    // window accounting depends on it); only a worker error
                    // aborts mid-step.
                    if shared.aborted() {
                        break;
                    }
                    ctx.refresh_state();
                    let policy =
                        if concurrent { Policy::ThetaMinus } else { Policy::Theta };
                    let q = match shared
                        .span(slot, Phase::Infer, || shared.qnet.infer(policy, &ctx.state_buf, 1))
                    {
                        Ok(q) => q,
                        Err(e) => return shared.fail(format!("infer: {e}")),
                    };
                    if concurrent {
                        let staging = &stagings[slot];
                        ctx.act(shared, t, &q, |frame, a, r, done, start| {
                            staging.lock().unwrap().push(frame, a, r, done, start);
                        });
                    } else {
                        let replay = shared.replay;
                        ctx.act(shared, t, &q, |frame, a, r, done, start| {
                            replay.lock().unwrap().push(slot, frame, a, r, done, start);
                        });
                    }
                }
            });
        }

        // ---- trainer thread (concurrent only) ---------------------------
        if concurrent {
            let shared = &shared;
            let dispatched = &dispatched;
            let trainer_done = &trainer_done;
            let trainer_cv = &trainer_cv;
            scope.spawn(move || {
                let mut batch = TrainBatch::default();
                loop {
                    // Wait for a dispatched window (or stop).
                    loop {
                        if shared.should_stop() {
                            return;
                        }
                        if trainer_done.load(Ordering::SeqCst)
                            < dispatched.load(Ordering::SeqCst)
                        {
                            break;
                        }
                        let g = trainer_cv.0.lock().unwrap();
                        let _ = trainer_cv
                            .1
                            .wait_timeout(g, std::time::Duration::from_millis(1))
                            .unwrap();
                    }
                    let batches = shared.cfg.batches_per_window();
                    for _ in 0..batches {
                        if shared.should_stop() {
                            return;
                        }
                        if let Err(e) = shared.do_one_train(&mut batch) {
                            return shared.fail(format!("trainer: {e}"));
                        }
                    }
                    trainer_done.fetch_add(1, Ordering::SeqCst);
                    trainer_cv.1.notify_all();
                }
            });
        }

        // ---- main thread: window orchestration (Algorithm 1's role) -----
        if concurrent {
            let mut window_end = c.min(total);
            // Dispatch the first training window immediately (it trains on
            // the prepopulated replay while samplers collect window 0).
            dispatched.fetch_add(1, Ordering::SeqCst);
            trainer_cv.1.notify_all();
            loop {
                // Wait for samplers to finish the window AND the trainer to
                // finish its batches.
                loop {
                    if shared.aborted() {
                        return Err(anyhow!("worker failed"));
                    }
                    let samplers_done = shared.completed.load(Ordering::SeqCst) >= window_end;
                    let trainer_caught_up = trainer_done.load(Ordering::SeqCst)
                        >= dispatched.load(Ordering::SeqCst);
                    if samplers_done && trainer_caught_up {
                        break;
                    }
                    // Normal termination: a sampler claimed the final step
                    // and set `stop`; the trainer exits without finishing
                    // its (forfeited) final-window quota.
                    if samplers_done && shared.should_stop() {
                        break;
                    }
                    on_progress(shared.completed.load(Ordering::SeqCst));
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                // Synchronization point: flush staging, update target net.
                shared.span(shared.main_lane(), Phase::Sync, || {
                    let mut replay = shared.replay.lock().unwrap();
                    for (slot, staging) in stagings.iter().enumerate() {
                        staging.lock().unwrap().flush_into(&mut replay, slot);
                    }
                    shared.qnet.sync_target();
                });
                on_progress(shared.completed.load(Ordering::SeqCst));
                if window_end >= total {
                    shared.stop.store(true, Ordering::SeqCst);
                    gate.advance(u64::MAX); // release parked samplers to exit
                    trainer_cv.1.notify_all();
                    break;
                }
                // Open the next window and dispatch its training batches.
                window_end = (window_end + c).min(total);
                dispatched.fetch_add(1, Ordering::SeqCst);
                trainer_cv.1.notify_all();
                gate.advance(window_end);
            }
        } else {
            // Standard: main thread only monitors progress.
            loop {
                if shared.should_stop() {
                    break;
                }
                let done = shared.completed.load(Ordering::SeqCst);
                on_progress(done);
                if done >= total {
                    shared.stop.store(true, Ordering::SeqCst);
                    break;
                }
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        Ok(())
    })?;

    if let Some(err) = shared.error.lock().unwrap().take() {
        return Err(anyhow!(err));
    }
    Ok(())
}
