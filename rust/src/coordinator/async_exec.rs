//! Asynchronous-execution driver (Synchronized Execution OFF).
//!
//! W sampler threads each own B environment streams and compute their own
//! size-B Q-inference on the shared device — the contention regime of the
//! paper's Figure 3(a) (at B=1, exactly the paper's machine; at B>1 each
//! thread amortizes its transaction over B steps). Two variants:
//!
//! * **standard** (Concurrent Training OFF): original DQN semantics — a
//!   sampler may not act at step t until floor(t/F) minibatch updates have
//!   completed ([`TrainInterlock`]); acting uses theta.
//! * **concurrent** (Concurrent Training ON, paper §3): acting uses
//!   theta_minus, a dedicated trainer thread runs C/F minibatches per
//!   C-step window ([`WindowCtrl`]), transitions stage per-stream and flush
//!   only at the window barrier, where theta_minus <- theta.
//!
//! Steps execute in blocks of B under a *static schedule*: block k
//! (steps k·B .. k·B+B, clamped to the step budget) belongs to slot
//! k mod W — an absolute assignment that is a pure function of the step
//! index, not of thread timing. (Earlier revisions claimed blocks from a
//! shared ticket counter, which made the stream↔step pairing
//! scheduling-dependent at W > 1; the static schedule removes the
//! counter, so the concurrent variant is now deterministic at any W, and
//! a fleet sampler process can reproduce its slots' blocks remotely —
//! rust/DESIGN.md §14.) At W=1 both schedules degenerate to the same
//! one-thread block loop, so historical digests are unchanged. Windows
//! quantize to blocks: a block whose base step falls inside the window
//! completes all its steps before parking, and the window barrier waits
//! for that block-rounded coverage before flushing staging — so the
//! flush never races a sampler that is mid-block across the boundary.
//!
//! **Segments & quiesce points** (rust/DESIGN.md §10): one invocation runs
//! from the machine's current step to `seg.until` and exits with every
//! layer quiesced. In concurrent mode a sampler whose next scheduled block
//! starts at or past the bound *parks at the window gate instead of stopping
//! the run*,
//! so the main thread always waits out the trainer's full final-window
//! quota before the last flush — the final `trains_done` is deterministic,
//! which both the bit-exact-resume guarantee and the uninterrupted-vs-
//! resumed comparison depend on. Sampler contexts live outside the driver
//! (`ctxs`) and the trainer's draw-stream position is written back to
//! `seg.draw_rng`, so the next segment (same process or a `--resume` of a
//! checkpoint) continues the exact trajectory. Evaluation fires only at
//! window barriers in concurrent mode, where the trainer is provably idle
//! and theta is frozen.

use std::sync::atomic::Ordering;

use anyhow::{anyhow, Result};

use crate::metrics::Phase;
use crate::replay::{build_strategy, BatchSource, StagingSet, TrainerSource};
use crate::runtime::{Policy, TrainBatch};

use super::shared::{
    strategy_plan, SamplerCtx, SegmentState, Shared, TrainInterlock, WindowCtrl, WindowGate,
};

/// Run one async segment. `concurrent` selects the variant. `on_progress`
/// is invoked from the main thread with the completed-step count — at
/// window barriers only in concurrent mode (quiesced trainer), on a
/// monitoring poll in standard mode.
pub fn run_async(
    shared: &Shared<'_>,
    concurrent: bool,
    ctxs: &mut [SamplerCtx],
    seg: &mut SegmentState,
    mut on_progress: impl FnMut(u64) + Send,
) -> Result<()> {
    let w = shared.cfg.threads;
    let b = shared.cfg.envs_per_thread;
    let bs = b as u64;
    let total = shared.cfg.total_steps;
    let until = seg.until.min(total);
    let c = shared.cfg.target_update_period;
    let bpw = shared.cfg.batches_per_window();
    debug_assert_eq!(ctxs.len(), w, "one persistent SamplerCtx per thread");

    let interlock = TrainInterlock::new();
    // Segment start (absolute). Always block-aligned except at the true end
    // of the run: fresh runs start at 0, and every quiesce bound is either
    // block-rounded (concurrent window targets) or B-aligned (standard).
    let start = shared.completed.load(Ordering::SeqCst);
    let first_window_end = ((seg.windows_flushed + 1) * c).min(until);
    let gate = WindowGate::new(if concurrent { first_window_end } else { u64::MAX });
    let staging = StagingSet::new(w * b);
    let winctrl = WindowCtrl::new();

    // Batch source for the training path: prefetch pipeline for the
    // windowed trainer (concurrent mode) when enabled, inline sampling
    // otherwise (TrainerSource owns the eligibility rule). The configured
    // sampling strategy resumes at the segment's saved draw position and
    // β-anneal clock.
    let source = TrainerSource::with_strategy(
        shared.replay,
        build_strategy(
            &strategy_plan(shared.cfg, shared.qnet.spec().gamma),
            seg.draw_rng,
            shared.trains_done.load(Ordering::SeqCst),
        ),
        shared.cfg.minibatch,
        shared.cfg.prefetch_batches,
        concurrent,
    );

    let result = std::thread::scope(|scope| -> Result<()> {
        // ---- prefetch worker (concurrent + prefetch only) ---------------
        if let Some(pipeline) = source.pipeline() {
            let shared = &shared;
            scope.spawn(move || pipeline.worker_loop(&|| shared.should_stop()));
        }
        // ---- sampler threads --------------------------------------------
        for ctx in ctxs.iter_mut() {
            let shared = &shared;
            let gate = &gate;
            let interlock = &interlock;
            let staging = &staging;
            let source: &dyn BatchSource = &source;
            scope.spawn(move || {
                let slot = ctx.slot;
                let mut train_batch = TrainBatch::default();
                // First block index of this segment, then the first of those
                // (or later) that the static schedule assigns to this slot.
                let first_block = start / bs;
                let mut block =
                    first_block + (slot as u64 + w as u64 - first_block % w as u64) % w as u64;
                loop {
                    if shared.should_stop() {
                        break;
                    }
                    let t = block * bs;
                    block += w as u64;
                    if t >= until {
                        if concurrent {
                            // Park instead of stopping the run: the main
                            // thread must still wait out the trainer's full
                            // final-window quota (deterministic quiesce).
                            // The segment-ending flush sets `stop` and opens
                            // the gate. The next segment re-derives every
                            // slot's schedule from `completed`, so nothing
                            // is forfeited.
                            gate.wait_for_step(shared, t);
                        } else {
                            // Do NOT stop the run: other slots may still own
                            // unexecuted blocks below the bound. The main
                            // thread's monitor loop stops the run once
                            // `completed` reaches it.
                        }
                        break;
                    }
                    // Clamp only at the TRUE end of the run, never at a
                    // mid-run segment bound: the uninterrupted run executes
                    // every scheduled block whole (windows are block-rounded),
                    // so truncating at `until` would step a strict prefix of
                    // the block's streams and break bit-exact resume when
                    // C is not a multiple of B. Blocks whose base is past
                    // `until` parked above; blocks that straddle it run to
                    // completion exactly as the uninterrupted machine does.
                    let width = bs.min(total - t) as usize;
                    if concurrent {
                        gate.wait_for_step(shared, t);
                    } else {
                        // The interlock gates the *last* step of the block.
                        interlock.ensure_trained(shared, source, t + width as u64 - 1, &mut train_batch);
                    }
                    // After claiming a valid block we must complete it (the
                    // window accounting depends on it); only a worker error
                    // aborts mid-block.
                    if shared.aborted() {
                        break;
                    }
                    ctx.refresh_states();
                    let policy =
                        if concurrent { Policy::ThetaMinus } else { Policy::Theta };
                    let q = match shared
                        .span(slot, Phase::Infer, || shared.qnet.infer(policy, &ctx.states_buf, b))
                    {
                        Ok(q) => q,
                        Err(e) => return shared.fail(format!("infer: {e}")),
                    };
                    if concurrent {
                        ctx.act_block(shared, t, &q, width, |stream, frame, a, r, done, start| {
                            staging.push(stream, frame, a, r, done, start);
                        });
                    } else {
                        let replay = shared.replay;
                        ctx.act_block(shared, t, &q, width, |stream, frame, a, r, done, start| {
                            replay.write().unwrap().push(stream, frame, a, r, done, start);
                        });
                    }
                }
            });
        }

        // ---- trainer thread (concurrent only) ---------------------------
        if concurrent {
            let shared = &shared;
            let winctrl = &winctrl;
            let source: &dyn BatchSource = &source;
            scope.spawn(move || winctrl.trainer_loop(shared, source));
        }

        // ---- main thread: window orchestration (Algorithm 1's role) -----
        if concurrent {
            let mut window_end = first_window_end;
            // Dispatch the first training window of this segment immediately
            // (a fresh run trains on the prepopulated replay while samplers
            // collect window 0; a resumed run re-creates exactly the
            // dispatch the uninterrupted run issued after its last flush).
            // The grant rides with every dispatch so the prefetch worker
            // may assemble exactly this window's batches and no more.
            winctrl.dispatch();
            source.grant(bpw);
            loop {
                // A window boundary that falls inside a B-step block is only
                // safe to flush once that whole block has executed (its tail
                // steps stage into THIS window); wait for coverage of the
                // block-rounded window, clamped to the TRUE step budget (not
                // the segment bound — see the width clamp above).
                let window_target = (window_end.div_ceil(bs) * bs).min(total);
                // Wait for samplers to finish the window AND the trainer to
                // finish its batches. The trainer never sees `stop` early,
                // so it always completes its dispatched quota — the final
                // window included (deterministic quiesce state).
                loop {
                    if shared.aborted() {
                        return Err(anyhow!("worker failed"));
                    }
                    if shared.completed.load(Ordering::SeqCst) >= window_target
                        && winctrl.caught_up()
                    {
                        break;
                    }
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                // Synchronization point: flush staging, update target net,
                // then apply the window's queued TD-error priority updates
                // (generation-guarded against slots the flush overwrote;
                // rust/DESIGN.md §11) before the next window's grant.
                shared.sync_point(&staging);
                source.barrier_update();
                seg.windows_flushed += 1;
                // Quiesce point: trainer idle, theta frozen, staging empty —
                // the only place evaluation (and checkpointing, one level
                // up) may observe the machine.
                on_progress(shared.completed.load(Ordering::SeqCst));
                if window_end >= until {
                    shared.stop.store(true, Ordering::SeqCst);
                    gate.advance(u64::MAX); // release parked samplers to exit
                    winctrl.notify_all();
                    break;
                }
                // Open the next window and dispatch its training batches
                // (grant AFTER the sync_point flush above: prefetched draws
                // must only ever see post-flush replay contents).
                window_end = (window_end + c).min(until);
                winctrl.dispatch();
                source.grant(bpw);
                gate.advance(window_end);
            }
        } else {
            // Standard: main thread only monitors progress.
            loop {
                if shared.should_stop() {
                    break;
                }
                let done = shared.completed.load(Ordering::SeqCst);
                on_progress(done);
                if done >= until {
                    shared.stop.store(true, Ordering::SeqCst);
                    break;
                }
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        Ok(())
    });
    // Write the draw stream back for the next segment / checkpoint (safe:
    // all threads joined, the source is quiesced).
    seg.draw_rng = source.sampler_state();
    result?;

    if let Some(err) = shared.error.lock().unwrap().take() {
        return Err(anyhow!(err));
    }
    Ok(())
}
