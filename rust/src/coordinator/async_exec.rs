//! Asynchronous-execution driver (Synchronized Execution OFF).
//!
//! W sampler threads each own B environment streams and compute their own
//! size-B Q-inference on the shared device — the contention regime of the
//! paper's Figure 3(a) (at B=1, exactly the paper's machine; at B>1 each
//! thread amortizes its transaction over B steps). Two variants:
//!
//! * **standard** (Concurrent Training OFF): original DQN semantics — a
//!   sampler may not act at step t until floor(t/F) minibatch updates have
//!   completed ([`TrainInterlock`]); acting uses theta.
//! * **concurrent** (Concurrent Training ON, paper §3): acting uses
//!   theta_minus, a dedicated trainer thread runs C/F minibatches per
//!   C-step window ([`WindowCtrl`]), transitions stage per-stream and flush
//!   only at the window barrier, where theta_minus <- theta.
//!
//! Step tickets are claimed in blocks of B: a thread that claims base
//! ticket t acts at steps t..t+B-1, clamped to the step budget (for B=1
//! this degenerates to the original one-ticket-per-step loop). Windows
//! therefore quantize to blocks: a block whose base step falls inside the
//! window completes all its steps before parking, and the window barrier
//! waits for that block-rounded coverage before flushing staging — so the
//! flush never races a sampler that is mid-block across the boundary.

use std::sync::atomic::Ordering;

use anyhow::{anyhow, Result};

use crate::metrics::Phase;
use crate::replay::{BatchSource, StagingSet, TrainerSource};
use crate::runtime::{Policy, TrainBatch};

use super::shared::{SamplerCtx, Shared, TrainInterlock, WindowCtrl, WindowGate};

/// Run the async driver. `concurrent` selects the variant.
/// `on_progress` is invoked from the main thread with the completed-step
/// count (eval hooks / logging).
pub fn run_async(
    shared: &Shared<'_>,
    concurrent: bool,
    mut on_progress: impl FnMut(u64) + Send,
) -> Result<()> {
    let w = shared.cfg.threads;
    let b = shared.cfg.envs_per_thread;
    let bs = b as u64;
    let total = shared.cfg.total_steps;
    let c = shared.cfg.target_update_period;
    let bpw = shared.cfg.batches_per_window();

    let interlock = TrainInterlock::new();
    let gate = WindowGate::new(if concurrent { c.min(total) } else { u64::MAX });
    let staging = StagingSet::new(w * b);
    let winctrl = WindowCtrl::new();

    // Batch source for the training path: prefetch pipeline for the
    // windowed trainer (concurrent mode) when enabled, inline sampling
    // otherwise (TrainerSource owns the eligibility rule).
    let source = TrainerSource::new(
        shared.replay,
        shared.cfg.seed,
        shared.cfg.minibatch,
        shared.cfg.prefetch_batches,
        concurrent,
    );

    std::thread::scope(|scope| -> Result<()> {
        // ---- prefetch worker (concurrent + prefetch only) ---------------
        if let Some(pipeline) = source.pipeline() {
            let shared = &shared;
            scope.spawn(move || pipeline.worker_loop(&|| shared.should_stop()));
        }
        // ---- sampler threads --------------------------------------------
        for slot in 0..w {
            let shared = &shared;
            let gate = &gate;
            let interlock = &interlock;
            let staging = &staging;
            let source: &dyn BatchSource = &source;
            scope.spawn(move || {
                let mut ctx = match SamplerCtx::new(shared.cfg, slot) {
                    Ok(c) => c,
                    Err(e) => return shared.fail(format!("sampler {slot}: {e}")),
                };
                let mut train_batch = TrainBatch::default();
                loop {
                    if shared.should_stop() {
                        break;
                    }
                    let t = shared.claimed.fetch_add(bs, Ordering::SeqCst);
                    if t >= total {
                        shared.stop.store(true, Ordering::SeqCst);
                        break;
                    }
                    // Clamp the final block to the step budget so completed
                    // lands on exactly `total`, as the B=1 machine did.
                    let width = bs.min(total - t) as usize;
                    if concurrent {
                        gate.wait_for_step(shared, t);
                    } else {
                        // The interlock gates the *last* step of the block.
                        interlock.ensure_trained(shared, source, t + width as u64 - 1, &mut train_batch);
                    }
                    // After claiming a valid block we must complete it (the
                    // window accounting depends on it); only a worker error
                    // aborts mid-block.
                    if shared.aborted() {
                        break;
                    }
                    ctx.refresh_states();
                    let policy =
                        if concurrent { Policy::ThetaMinus } else { Policy::Theta };
                    let q = match shared
                        .span(slot, Phase::Infer, || shared.qnet.infer(policy, &ctx.states_buf, b))
                    {
                        Ok(q) => q,
                        Err(e) => return shared.fail(format!("infer: {e}")),
                    };
                    if concurrent {
                        ctx.act_block(shared, t, &q, width, |stream, frame, a, r, done, start| {
                            staging.push(stream, frame, a, r, done, start);
                        });
                    } else {
                        let replay = shared.replay;
                        ctx.act_block(shared, t, &q, width, |stream, frame, a, r, done, start| {
                            replay.write().unwrap().push(stream, frame, a, r, done, start);
                        });
                    }
                }
            });
        }

        // ---- trainer thread (concurrent only) ---------------------------
        if concurrent {
            let shared = &shared;
            let winctrl = &winctrl;
            let source: &dyn BatchSource = &source;
            scope.spawn(move || winctrl.trainer_loop(shared, source));
        }

        // ---- main thread: window orchestration (Algorithm 1's role) -----
        if concurrent {
            let mut window_end = c.min(total);
            // Dispatch the first training window immediately (it trains on
            // the prepopulated replay while samplers collect window 0).
            // The grant rides with every dispatch so the prefetch worker
            // may assemble exactly this window's batches and no more.
            winctrl.dispatch();
            source.grant(bpw);
            loop {
                // A window boundary that falls inside a B-step block is only
                // safe to flush once that whole block has executed (its tail
                // steps stage into THIS window); wait for coverage of the
                // block-rounded window, clamped to the step budget.
                let window_target = (window_end.div_ceil(bs) * bs).min(total);
                // Wait for samplers to finish the window AND the trainer to
                // finish its batches.
                loop {
                    if shared.aborted() {
                        return Err(anyhow!("worker failed"));
                    }
                    let samplers_done =
                        shared.completed.load(Ordering::SeqCst) >= window_target;
                    if samplers_done && winctrl.caught_up() {
                        break;
                    }
                    // Normal termination: a sampler claimed the final block
                    // and set `stop`; the trainer exits without finishing
                    // its (forfeited) final-window quota.
                    if samplers_done && shared.should_stop() {
                        break;
                    }
                    on_progress(shared.completed.load(Ordering::SeqCst));
                    std::thread::sleep(std::time::Duration::from_micros(200));
                }
                // Synchronization point: flush staging, update target net.
                shared.sync_point(&staging);
                on_progress(shared.completed.load(Ordering::SeqCst));
                if window_end >= total {
                    shared.stop.store(true, Ordering::SeqCst);
                    gate.advance(u64::MAX); // release parked samplers to exit
                    winctrl.notify_all();
                    break;
                }
                // Open the next window and dispatch its training batches
                // (grant AFTER the sync_point flush above: prefetched draws
                // must only ever see post-flush replay contents).
                window_end = (window_end + c).min(total);
                winctrl.dispatch();
                source.grant(bpw);
                gate.advance(window_end);
            }
        } else {
            // Standard: main thread only monitors progress.
            loop {
                if shared.should_stop() {
                    break;
                }
                let done = shared.completed.load(Ordering::SeqCst);
                on_progress(done);
                if done >= total {
                    shared.stop.store(true, Ordering::SeqCst);
                    break;
                }
                std::thread::sleep(std::time::Duration::from_micros(200));
            }
        }
        Ok(())
    })?;

    if let Some(err) = shared.error.lock().unwrap().take() {
        return Err(anyhow!(err));
    }
    Ok(())
}
