//! Artifact manifest: the ABI contract emitted by `python/compile/aot.py`.
//!
//! `manifest.json` describes, per network config, the flat parameter count,
//! frame shape, the HLO entry points with their input signatures, and the
//! deterministic init-parameter blob. The Rust runtime refuses to run if the
//! manifest disagrees with what the coordinator expects — shape errors
//! surface at load time, not inside an engine call.
//!
//! When no artifact directory exists (the native engine needs none),
//! [`Manifest::builtin`] synthesizes the equivalent manifest for the three
//! known architectures, and [`Manifest::init_params`] generates the
//! deterministic init blob in-process (rust/DESIGN.md §2).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use super::engine::Head;
use crate::util::json::Json;

/// Element type of one executable input (mirrors the numpy dtype strings).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dtype {
    F32,
    U8,
    I32,
}

impl Dtype {
    fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "float32" => Dtype::F32,
            "uint8" => Dtype::U8,
            "int32" => Dtype::I32,
            other => bail!("unsupported dtype in manifest: {other}"),
        })
    }

    pub fn size_bytes(self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 => 4,
            Dtype::U8 => 1,
        }
    }
}

/// Input signature of one lowered entry point.
#[derive(Clone, Debug)]
pub struct InputSig {
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl InputSig {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
    pub fn bytes(&self) -> usize {
        self.elements() * self.dtype.size_bytes()
    }
}

/// One HLO entry point (infer_bN / train_bN / train_double_bN).
#[derive(Clone, Debug)]
pub struct Entry {
    pub file: PathBuf,
    pub inputs: Vec<InputSig>,
}

/// One parameter tensor in the flat layout (diagnostics / checkpointing).
#[derive(Clone, Debug)]
pub struct ParamTensor {
    pub name: String,
    pub shape: Vec<usize>,
}

/// Everything the runtime needs to know about one network config.
#[derive(Clone, Debug)]
pub struct NetSpec {
    pub name: String,
    pub param_count: usize,
    pub frame: [usize; 3],
    pub actions: usize,
    pub gamma: f64,
    pub init_params_file: PathBuf,
    pub param_spec: Vec<ParamTensor>,
    pub entries: BTreeMap<String, Entry>,
    /// Q-head variant this spec's parameter layout was built for. Artifact
    /// manifests always describe `dqn`; head variants are derived views
    /// ([`Manifest::config_with_head`]).
    pub head: Head,
}

impl NetSpec {
    pub fn frame_elems(&self) -> usize {
        self.frame.iter().product()
    }

    /// Head-qualified network identity used for engine keys and checkpoint
    /// snapshots: the bare config name for `dqn` (byte-identical to the
    /// pre-head convention), `name+head` otherwise.
    pub fn runtime_name(&self) -> String {
        self.head.qualify(&self.name)
    }

    /// Infer batch sizes available in the artifacts, ascending.
    pub fn infer_batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .keys()
            .filter_map(|k| k.strip_prefix("infer_b").and_then(|b| b.parse().ok()))
            .collect();
        v.sort_unstable();
        v
    }

    /// Train batch sizes available (non-double), ascending.
    pub fn train_batches(&self) -> Vec<usize> {
        let mut v: Vec<usize> = self
            .entries
            .keys()
            .filter_map(|k| k.strip_prefix("train_b").and_then(|b| b.parse().ok()))
            .collect();
        v.sort_unstable();
        v
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .get(name)
            .ok_or_else(|| anyhow!("config {:?} has no entry {name:?}; available: {:?}",
                                   self.name, self.entries.keys().collect::<Vec<_>>()))
    }
}

/// The parsed manifest for the whole artifact directory.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub version: usize,
    pub actions: usize,
    pub configs: BTreeMap<String, NetSpec>,
    /// True for the synthesized artifact-free manifest ([`Manifest::builtin`]):
    /// init params are generated in-process instead of read from blobs.
    pub synthetic: bool,
}

/// Batched infer entry points the builtin manifest advertises. The runtime
/// pads any batch up to the next size, so this caps W×B at 256 streams per
/// device transaction (plenty beyond the paper's W<=8 grid).
pub const BUILTIN_INFER_BATCHES: [usize; 9] = [1, 2, 4, 8, 16, 32, 64, 128, 256];

/// Train minibatch size the builtin manifest advertises (paper Table 5).
pub const BUILTIN_TRAIN_BATCH: usize = 32;

impl Manifest {
    /// Load `dir/manifest.json` if present, otherwise fall back to the
    /// builtin manifest (native engine; no artifacts required). A manifest
    /// that exists but fails to load is an error, not a fallback — silently
    /// substituting synthesized init params for the artifact blob would
    /// change the network behind the user's back.
    pub fn load_or_builtin(dir: &Path) -> Result<Manifest> {
        if dir.join("manifest.json").exists() {
            Self::load(dir)
        } else {
            Ok(Self::builtin())
        }
    }

    /// Synthesize the manifest the AOT pipeline would emit for the three
    /// known architectures, without touching the filesystem.
    pub fn builtin() -> Manifest {
        let dir = PathBuf::from("<builtin>");
        let mut configs = BTreeMap::new();
        for name in ["tiny", "small", "nature"] {
            let arch = crate::runtime::native::NetArch::by_name(name, 6)
                .expect("builtin architectures are always known");
            let p = arch.param_count();
            let [h, w, c] = arch.frame;
            let pvec = InputSig { dtype: Dtype::F32, shape: vec![p] };
            let mut entries = BTreeMap::new();
            for &b in &BUILTIN_INFER_BATCHES {
                entries.insert(
                    format!("infer_b{b}"),
                    Entry {
                        file: dir.join(format!("{name}_infer_b{b}.hlo.txt")),
                        inputs: vec![
                            pvec.clone(),
                            InputSig { dtype: Dtype::U8, shape: vec![b, h, w, c] },
                        ],
                    },
                );
            }
            let tb = BUILTIN_TRAIN_BATCH;
            for tag in [format!("train_b{tb}"), format!("train_double_b{tb}")] {
                entries.insert(
                    tag.clone(),
                    Entry {
                        file: dir.join(format!("{name}_{tag}.hlo.txt")),
                        inputs: vec![
                            pvec.clone(),
                            pvec.clone(),
                            pvec.clone(),
                            pvec.clone(),
                            InputSig { dtype: Dtype::U8, shape: vec![tb, h, w, c] },
                            InputSig { dtype: Dtype::I32, shape: vec![tb] },
                            InputSig { dtype: Dtype::F32, shape: vec![tb] },
                            InputSig { dtype: Dtype::U8, shape: vec![tb, h, w, c] },
                            InputSig { dtype: Dtype::F32, shape: vec![tb] },
                            InputSig { dtype: Dtype::F32, shape: vec![] },
                        ],
                    },
                );
            }
            configs.insert(
                name.to_string(),
                NetSpec {
                    name: name.to_string(),
                    param_count: p,
                    frame: arch.frame,
                    actions: arch.actions,
                    gamma: 0.99,
                    init_params_file: PathBuf::from(format!("{name}_init.bin")),
                    param_spec: arch
                        .param_spec()
                        .into_iter()
                        .map(|(n, s)| ParamTensor { name: n, shape: s })
                        .collect(),
                    entries,
                    head: Head::Dqn,
                },
            );
        }
        Manifest { dir, version: 2, actions: 6, configs, synthetic: true }
    }

    /// Initial parameters for `spec`: the deterministic in-process init
    /// (seed 0, matching `aot.py --seed 0`'s role as the canonical
    /// default) for the synthetic manifest, the artifact blob otherwise.
    /// A real manifest whose blob file is missing is an error — silently
    /// substituting synthesized parameters would change the network
    /// behind the user's back.
    pub fn init_params(&self, spec: &NetSpec) -> Result<Vec<f32>> {
        if self.synthetic {
            let arch = crate::runtime::native::NetArch::from_spec(spec)?;
            return Ok(crate::runtime::native::init_params(&arch, 0));
        }
        self.load_init_params(spec)
    }

    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", path.display()))?;
        let json = Json::parse(&text).map_err(|e| anyhow!("{}: {e}", path.display()))?;
        Self::from_json(dir, &json)
    }

    pub fn from_json(dir: &Path, json: &Json) -> Result<Manifest> {
        let version = json.at(&["version"])?.as_usize().ok_or_else(|| anyhow!("bad version"))?;
        if version != 2 {
            bail!("manifest version {version} unsupported (expected 2); rebuild artifacts");
        }
        let actions = json.at(&["actions"])?.as_usize().ok_or_else(|| anyhow!("bad actions"))?;
        let mut configs = BTreeMap::new();
        for (name, c) in json.at(&["configs"])?.as_obj().ok_or_else(|| anyhow!("bad configs"))? {
            configs.insert(name.clone(), parse_netspec(dir, name, c)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), version, actions, configs, synthetic: false })
    }

    pub fn config(&self, name: &str) -> Result<&NetSpec> {
        self.configs.get(name).ok_or_else(|| {
            anyhow!("no config {name:?} in manifest; available: {:?}",
                    self.configs.keys().collect::<Vec<_>>())
        })
    }

    /// A head-adjusted view of config `name`. `dqn` is the stored spec
    /// verbatim (identical struct, identical code path downstream). Other
    /// heads change the dense tail and therefore the flat parameter count:
    /// the derived spec rewrites `param_count`, `param_spec`, and every
    /// parameter-vector entry input to the new length. Only the synthetic
    /// manifest can do this — AOT artifact directories bake the dqn layout
    /// into their HLO, so a non-dqn head is refused by name rather than
    /// silently mis-executed.
    pub fn config_with_head(&self, name: &str, head: Head) -> Result<NetSpec> {
        let base = self.config(name)?;
        if matches!(head, Head::Dqn) {
            return Ok(base.clone());
        }
        if !self.synthetic {
            bail!(
                "artifact manifest {} only lowers the dqn head; config {name:?} cannot serve \
                 head {:?} (use the native engine without an artifact dir)",
                self.dir.display(),
                head.tag()
            );
        }
        let mut arch = crate::runtime::native::NetArch::from_spec(base)?;
        arch.head = head;
        let tensors = arch.param_spec();
        let p: usize = tensors.iter().map(|(_, s)| s.iter().product::<usize>()).sum();
        let base_p = base.param_count;
        let mut spec = base.clone();
        spec.head = head;
        spec.param_count = p;
        spec.param_spec = tensors
            .into_iter()
            .map(|(n, s)| ParamTensor { name: n, shape: s })
            .collect();
        for entry in spec.entries.values_mut() {
            for sig in entry.inputs.iter_mut() {
                if sig.dtype == Dtype::F32 && sig.shape == [base_p] {
                    sig.shape = vec![p];
                }
            }
        }
        Ok(spec)
    }

    /// Read the deterministic init-parameter blob for a config.
    pub fn load_init_params(&self, spec: &NetSpec) -> Result<Vec<f32>> {
        let path = self.dir.join(&spec.init_params_file);
        let bytes = std::fs::read(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        if bytes.len() != spec.param_count * 4 {
            bail!("{}: expected {} bytes ({} f32 params), got {}",
                  path.display(), spec.param_count * 4, spec.param_count, bytes.len());
        }
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

fn parse_netspec(dir: &Path, name: &str, c: &Json) -> Result<NetSpec> {
    let param_count = c.at(&["param_count"])?.as_usize().ok_or_else(|| anyhow!("bad param_count"))?;
    let frame_v = c.at(&["frame"])?.as_f64_vec().ok_or_else(|| anyhow!("bad frame"))?;
    if frame_v.len() != 3 {
        bail!("config {name}: frame must have 3 dims");
    }
    let frame = [frame_v[0] as usize, frame_v[1] as usize, frame_v[2] as usize];
    let actions = c.at(&["actions"])?.as_usize().ok_or_else(|| anyhow!("bad actions"))?;
    let gamma = c.at(&["gamma"])?.as_f64().ok_or_else(|| anyhow!("bad gamma"))?;
    let init = c.at(&["init_params"])?.as_str().ok_or_else(|| anyhow!("bad init_params"))?;

    let mut param_tensors = Vec::new();
    for p in c.at(&["param_spec"])?.as_arr().ok_or_else(|| anyhow!("bad param_spec"))? {
        param_tensors.push(ParamTensor {
            name: p.at(&["name"])?.as_str().ok_or_else(|| anyhow!("bad name"))?.to_string(),
            shape: p.at(&["shape"])?.as_f64_vec().ok_or_else(|| anyhow!("bad shape"))?
                .into_iter().map(|d| d as usize).collect(),
        });
    }

    let mut entries = BTreeMap::new();
    for (ename, e) in c.at(&["entries"])?.as_obj().ok_or_else(|| anyhow!("bad entries"))? {
        let file = e.at(&["file"])?.as_str().ok_or_else(|| anyhow!("bad file"))?;
        let mut inputs = Vec::new();
        for sig in e.at(&["inputs"])?.as_arr().ok_or_else(|| anyhow!("bad inputs"))? {
            inputs.push(InputSig {
                dtype: Dtype::parse(sig.at(&["dtype"])?.as_str().ok_or_else(|| anyhow!("bad dtype"))?)?,
                shape: sig.at(&["shape"])?.as_f64_vec().ok_or_else(|| anyhow!("bad shape"))?
                    .into_iter().map(|d| d as usize).collect(),
            });
        }
        entries.insert(ename.clone(), Entry { file: dir.join(file), inputs });
    }

    // Cross-check the flat layout adds up.
    let total: usize = param_tensors.iter().map(|t| t.shape.iter().product::<usize>()).sum();
    if total != param_count {
        bail!("config {name}: param_spec sums to {total}, manifest says {param_count}");
    }

    Ok(NetSpec {
        name: name.to_string(),
        param_count,
        frame,
        actions,
        gamma,
        init_params_file: PathBuf::from(init),
        param_spec: param_tensors,
        entries,
        head: Head::Dqn,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_json() -> Json {
        Json::parse(
            r#"{
          "version": 2, "actions": 6,
          "train_abi": {"inputs": [], "outputs": []},
          "configs": {
            "tiny": {
              "param_count": 10,
              "frame": [4, 4, 2],
              "actions": 6,
              "gamma": 0.99,
              "init_params": "tiny_init.bin",
              "init_sha256": "x",
              "param_spec": [
                 {"name": "w", "shape": [2, 4]},
                 {"name": "b", "shape": [2]}
              ],
              "entries": {
                "infer_b1": {"file": "tiny_infer_b1.hlo.txt",
                  "inputs": [{"dtype": "float32", "shape": [10]},
                             {"dtype": "uint8", "shape": [1, 4, 4, 2]}]},
                "infer_b8": {"file": "tiny_infer_b8.hlo.txt",
                  "inputs": [{"dtype": "float32", "shape": [10]},
                             {"dtype": "uint8", "shape": [8, 4, 4, 2]}]},
                "train_b32": {"file": "tiny_train_b32.hlo.txt", "inputs": []}
              }
            }
          }
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_manifest() {
        let m = Manifest::from_json(Path::new("/art"), &sample_json()).unwrap();
        let spec = m.config("tiny").unwrap();
        assert_eq!(spec.param_count, 10);
        assert_eq!(spec.frame, [4, 4, 2]);
        assert_eq!(spec.infer_batches(), vec![1, 8]);
        assert_eq!(spec.train_batches(), vec![32]);
        let e = spec.entry("infer_b8").unwrap();
        assert_eq!(e.inputs[1].shape, vec![8, 4, 4, 2]);
        assert_eq!(e.inputs[1].dtype, Dtype::U8);
        assert_eq!(e.inputs[1].bytes(), 8 * 4 * 4 * 2);
        assert!(e.file.starts_with("/art"));
    }

    #[test]
    fn rejects_bad_param_sum() {
        let mut text = sample_json().to_string();
        text = text.replace("\"param_count\":10", "\"param_count\":11");
        let json = Json::parse(&text).unwrap();
        assert!(Manifest::from_json(Path::new("/a"), &json).is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let text = sample_json().to_string().replace("\"version\":2", "\"version\":1");
        let json = Json::parse(&text).unwrap();
        assert!(Manifest::from_json(Path::new("/a"), &json).is_err());
    }

    #[test]
    fn builtin_manifest_is_complete_and_self_consistent() {
        let m = Manifest::builtin();
        for name in ["tiny", "small", "nature"] {
            let spec = m.config(name).unwrap();
            assert_eq!(spec.frame, [84, 84, 4]);
            assert_eq!(spec.infer_batches(), BUILTIN_INFER_BATCHES.to_vec());
            assert_eq!(spec.train_batches(), vec![BUILTIN_TRAIN_BATCH]);
            let train = spec.entry("train_b32").unwrap();
            assert_eq!(train.inputs.len(), 10);
            assert_eq!(train.inputs[0].shape, vec![spec.param_count]);
            // Param spec must sum to the declared count.
            let total: usize = spec.param_spec.iter().map(|t| t.shape.iter().product::<usize>()).sum();
            assert_eq!(total, spec.param_count);
            // Init is synthesized deterministically when no blob exists.
            let init = m.init_params(spec).unwrap();
            assert_eq!(init.len(), spec.param_count);
            assert_eq!(init, m.init_params(spec).unwrap());
        }
    }

    #[test]
    fn load_or_builtin_falls_back_only_when_absent() {
        let m = Manifest::load_or_builtin(Path::new("/definitely/not/a/dir")).unwrap();
        assert!(m.config("tiny").is_ok());
        // A present-but-broken manifest.json must surface its error.
        let dir = std::env::temp_dir().join("tempo_dqn_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("manifest.json"), b"{ not json").unwrap();
        assert!(Manifest::load_or_builtin(&dir).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn config_with_head_rewrites_param_layout() {
        let m = Manifest::builtin();
        let base = m.config("tiny").unwrap().clone();
        // dqn view is the stored spec verbatim.
        let dqn = m.config_with_head("tiny", Head::Dqn).unwrap();
        assert_eq!(dqn.param_count, base.param_count);
        assert_eq!(dqn.runtime_name(), "tiny");

        let duel = m.config_with_head("tiny", Head::Dueling).unwrap();
        assert_eq!(duel.runtime_name(), "tiny+dueling");
        assert_ne!(duel.param_count, base.param_count);
        let total: usize =
            duel.param_spec.iter().map(|t| t.shape.iter().product::<usize>()).sum();
        assert_eq!(total, duel.param_count);
        // Every parameter-vector input follows the new length; frames don't.
        let train = duel.entry("train_b32").unwrap();
        for sig in &train.inputs[..4] {
            assert_eq!(sig.shape, vec![duel.param_count]);
        }
        assert_eq!(train.inputs[4].shape, vec![32, 84, 84, 4]);
        // Head-adjusted init params synthesize at the new length.
        let init = m.init_params(&duel).unwrap();
        assert_eq!(init.len(), duel.param_count);

        let c51 = m
            .config_with_head("tiny", Head::C51 { atoms: 51, v_min: -10.0, v_max: 10.0 })
            .unwrap();
        assert_eq!(c51.runtime_name(), "tiny+c51[51,-10,10]");
        let total: usize = c51.param_spec.iter().map(|t| t.shape.iter().product::<usize>()).sum();
        assert_eq!(total, c51.param_count);

        // Artifact (non-synthetic) manifests refuse head variants by name.
        let real = Manifest::from_json(Path::new("/a"), &sample_json()).unwrap();
        let err = real.config_with_head("tiny", Head::Dueling).unwrap_err().to_string();
        assert!(err.contains("dueling"), "{err}");
    }

    #[test]
    fn missing_config_error_lists_available() {
        let m = Manifest::from_json(Path::new("/a"), &sample_json()).unwrap();
        let err = m.config("nope").unwrap_err().to_string();
        assert!(err.contains("tiny"), "{err}");
    }
}
