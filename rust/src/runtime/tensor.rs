//! Host-side tensors exchanged with an [`ExecutionEngine`].
//!
//! The engine boundary deliberately traffics in plain host memory — a dtype
//! tag, a shape, and a flat buffer — so engines are interchangeable: the
//! native reference engine consumes the buffers directly, while the
//! (feature-gated) XLA engine converts them to `xla::Literal`s at the edge.
//! Inputs are borrowed ([`TensorView`], zero-copy from the caller's
//! buffers); outputs are owned ([`HostTensor`], moved into the caller).
//!
//! [`ExecutionEngine`]: super::engine::ExecutionEngine

use anyhow::{bail, Result};

/// Borrowed input tensor (shape is owned — it is a handful of usizes).
#[derive(Clone, Debug)]
pub struct TensorView<'a> {
    pub data: DataView<'a>,
    pub shape: Vec<usize>,
}

#[derive(Clone, Copy, Debug)]
pub enum DataView<'a> {
    F32(&'a [f32]),
    U8(&'a [u8]),
    I32(&'a [i32]),
}

impl<'a> TensorView<'a> {
    pub fn f32(data: &'a [f32], shape: &[usize]) -> TensorView<'a> {
        TensorView { data: DataView::F32(data), shape: shape.to_vec() }
    }

    pub fn u8(data: &'a [u8], shape: &[usize]) -> TensorView<'a> {
        TensorView { data: DataView::U8(data), shape: shape.to_vec() }
    }

    pub fn i32(data: &'a [i32], shape: &[usize]) -> TensorView<'a> {
        TensorView { data: DataView::I32(data), shape: shape.to_vec() }
    }

    /// Rank-0 f32 (hyperparameters like the learning rate).
    pub fn scalar(v: &'a [f32; 1]) -> TensorView<'a> {
        TensorView { data: DataView::F32(&v[..]), shape: Vec::new() }
    }

    pub fn elements(&self) -> usize {
        match self.data {
            DataView::F32(d) => d.len(),
            DataView::U8(d) => d.len(),
            DataView::I32(d) => d.len(),
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self.data {
            DataView::F32(d) => d.len() * 4,
            DataView::U8(d) => d.len(),
            DataView::I32(d) => d.len() * 4,
        }
    }

    /// The f32 slice, or an ABI error naming `what`.
    pub fn as_f32(&self, what: &str) -> Result<&'a [f32]> {
        match self.data {
            DataView::F32(d) => Ok(d),
            _ => bail!("{what}: expected f32 tensor"),
        }
    }

    pub fn as_u8(&self, what: &str) -> Result<&'a [u8]> {
        match self.data {
            DataView::U8(d) => Ok(d),
            _ => bail!("{what}: expected u8 tensor"),
        }
    }

    pub fn as_i32(&self, what: &str) -> Result<&'a [i32]> {
        match self.data {
            DataView::I32(d) => Ok(d),
            _ => bail!("{what}: expected i32 tensor"),
        }
    }
}

/// Owned output tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub data: DataVec,
    pub shape: Vec<usize>,
}

#[derive(Clone, Debug, PartialEq)]
pub enum DataVec {
    F32(Vec<f32>),
    U8(Vec<u8>),
    I32(Vec<i32>),
}

impl HostTensor {
    pub fn f32(data: Vec<f32>, shape: Vec<usize>) -> HostTensor {
        HostTensor { data: DataVec::F32(data), shape }
    }

    pub fn scalar_f32(v: f32) -> HostTensor {
        HostTensor { data: DataVec::F32(vec![v]), shape: Vec::new() }
    }

    pub fn size_bytes(&self) -> usize {
        match &self.data {
            DataVec::F32(d) => d.len() * 4,
            DataVec::U8(d) => d.len(),
            DataVec::I32(d) => d.len() * 4,
        }
    }

    /// Take the f32 buffer out (no copy), or an ABI error naming `what`.
    pub fn into_f32(self, what: &str) -> Result<Vec<f32>> {
        match self.data {
            DataVec::F32(d) => Ok(d),
            _ => bail!("{what}: expected f32 output"),
        }
    }

    /// First f32 element (scalar outputs such as the loss).
    pub fn first_f32(&self, what: &str) -> Result<f32> {
        match &self.data {
            DataVec::F32(d) if !d.is_empty() => Ok(d[0]),
            _ => bail!("{what}: expected non-empty f32 output"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn views_report_sizes_and_dtypes() {
        let f = [1.0f32, 2.0];
        let v = TensorView::f32(&f, &[2]);
        assert_eq!(v.size_bytes(), 8);
        assert_eq!(v.elements(), 2);
        assert!(v.as_f32("x").is_ok());
        assert!(v.as_u8("x").is_err());

        let u = [3u8; 5];
        assert_eq!(TensorView::u8(&u, &[5]).size_bytes(), 5);

        let lr = [0.1f32];
        let s = TensorView::scalar(&lr);
        assert!(s.shape.is_empty());
        assert_eq!(s.as_f32("lr").unwrap()[0], 0.1);
    }

    #[test]
    fn host_tensor_extraction() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0], vec![3]);
        assert_eq!(t.size_bytes(), 12);
        assert_eq!(t.first_f32("t").unwrap(), 1.0);
        assert_eq!(t.into_f32("t").unwrap(), vec![1.0, 2.0, 3.0]);
        let s = HostTensor::scalar_f32(7.0);
        assert_eq!(s.first_f32("s").unwrap(), 7.0);
    }
}
