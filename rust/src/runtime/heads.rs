//! Head-variant execution: dueling and distributional (C51) dense tails on
//! the shared conv trunk (rust/DESIGN.md §16).
//!
//! The dqn head keeps its dedicated code path in `runtime/native.rs`
//! untouched (bit-identity by code-path identity). This module executes
//! every *other* head through a **dense plan**: an ordered list of dense
//! layers, each naming its weight tensor, dimensions, activation, and
//! input (the conv-trunk features or an earlier plan layer). Dueling is a
//! plan with two parallel streams; C51 is the dqn plan with an `A × atoms`
//! output layer plus softmax/expectation post-processing.
//!
//! **Determinism contract** — identical to the dqn path's (DESIGN.md §9):
//! Phase A shards the minibatch into contiguous sample ranges and computes
//! everything per-sample (forward caches, targets, deltas); Phase B
//! partitions each parameter tensor's rows and walks ALL samples in
//! ascending global order with the same sparsity skips as the serial
//! kernels (`Deterministic`) or the [`FAST_RANK`]-wide global-order
//! grouping (`Fast`). Every head reduction with more than one contributor
//! (the dueling mean-subtraction, the trunk delta fed by both streams, the
//! C51 softmax/expectation/projection folds) runs in one fixed serial
//! order, so results are bit-identical for every `learner_threads` /
//! `prefetch` setting and across kill-and-resume — pinned by
//! `tests/head_equivalence.rs`.
//!
//! [`FAST_RANK`]: super::kernels::FAST_RANK

use anyhow::{bail, Result};

use super::engine::Head;
use super::kernels::{
    conv2d_forward_mode, conv2d_input_grad_mode, conv2d_weight_grad_chunk_mode, matmul_a_bt_mode,
    matmul_acc_mode, KernelMode,
};
use super::native::{fast_weight_chunk, huber, huber_grad, NetArch};
use super::pool::{split_ranges, ComputePool};

/// Where a dense layer reads its input.
#[derive(Clone, Copy, Debug)]
enum LayerIn {
    /// The flattened conv-trunk features.
    Trunk,
    /// The post-activation output of an earlier plan layer.
    Layer(usize),
}

/// One dense layer of a head's tail. `w` is the param tensor index of the
/// `[in_dim, out_dim]` weight; the bias is always tensor `w + 1`.
#[derive(Clone, Copy, Debug)]
struct DenseLayer {
    w: usize,
    in_dim: usize,
    out_dim: usize,
    relu: bool,
    input: LayerIn,
}

/// The dense tail of `arch` as an ordered plan. Plan order equals the
/// param-tensor order after the conv tensors, and every layer's input
/// appears earlier in the plan (so a single reverse walk backpropagates).
fn dense_plan(arch: &NetArch) -> Vec<DenseLayer> {
    let base = 2 * arch.convs.len();
    let trunk = arch.trunk_dim();
    let n_fc = arch.hidden.len();
    let mut plan = Vec::new();
    match arch.head {
        Head::Dqn | Head::C51 { .. } => {
            let out_dim = match arch.head {
                Head::C51 { atoms, .. } => arch.actions * atoms,
                _ => arch.actions,
            };
            let mut dim = trunk;
            for (i, &width) in arch.hidden.iter().enumerate() {
                plan.push(DenseLayer {
                    w: base + 2 * i,
                    in_dim: dim,
                    out_dim: width,
                    relu: true,
                    input: if i == 0 { LayerIn::Trunk } else { LayerIn::Layer(i - 1) },
                });
                dim = width;
            }
            plan.push(DenseLayer {
                w: base + 2 * n_fc,
                in_dim: dim,
                out_dim,
                relu: false,
                input: if n_fc == 0 { LayerIn::Trunk } else { LayerIn::Layer(n_fc - 1) },
            });
        }
        Head::Dueling => {
            // Parallel value/advantage streams, interleaved per level to
            // match `NetArch::param_spec` (val{i}, adv{i}, ..., val_out,
            // adv_out).
            let mut dim = trunk;
            for (i, &width) in arch.hidden.iter().enumerate() {
                let (iv, ia) = if i == 0 {
                    (LayerIn::Trunk, LayerIn::Trunk)
                } else {
                    (LayerIn::Layer(2 * (i - 1)), LayerIn::Layer(2 * (i - 1) + 1))
                };
                plan.push(DenseLayer { w: base + 4 * i, in_dim: dim, out_dim: width, relu: true, input: iv });
                plan.push(DenseLayer { w: base + 4 * i + 2, in_dim: dim, out_dim: width, relu: true, input: ia });
                dim = width;
            }
            let (iv, ia) = if n_fc == 0 {
                (LayerIn::Trunk, LayerIn::Trunk)
            } else {
                (LayerIn::Layer(2 * (n_fc - 1)), LayerIn::Layer(2 * (n_fc - 1) + 1))
            };
            plan.push(DenseLayer { w: base + 4 * n_fc, in_dim: dim, out_dim: 1, relu: false, input: iv });
            plan.push(DenseLayer {
                w: base + 4 * n_fc + 2,
                in_dim: dim,
                out_dim: arch.actions,
                relu: false,
                input: ia,
            });
        }
    }
    plan
}

/// Flat parameter accessor (the head twin of `native::Params`).
struct P<'a> {
    flat: &'a [f32],
    off: Vec<(usize, usize)>,
}

impl<'a> P<'a> {
    fn new(arch: &NetArch, flat: &'a [f32]) -> Result<P<'a>> {
        if flat.len() != arch.param_count() {
            bail!("params: got {} values, want {}", flat.len(), arch.param_count());
        }
        Ok(P { flat, off: arch.offsets() })
    }

    fn t(&self, idx: usize) -> &'a [f32] {
        let (o, n) = self.off[idx];
        &self.flat[o..o + n]
    }
}

/// Conv-trunk activations for one shard.
struct TrunkFwd {
    /// Normalized input `[rows, H, W, C]` (kept only when `keep`).
    x0: Vec<f32>,
    /// Post-ReLU output of each conv layer (kept only when `keep`).
    conv_out: Vec<Vec<f32>>,
    /// Flattened trunk features `[rows, trunk_dim]`.
    feats: Vec<f32>,
}

/// Conv trunk forward, patch-free per sample — byte-for-byte the conv loop
/// of `native::forward_shard`, factored so head tails can share it.
fn trunk_forward(
    arch: &NetArch,
    p: &P<'_>,
    states: &[u8],
    rows: usize,
    keep: bool,
    mode: KernelMode,
) -> Result<TrunkFwd> {
    let [h0, w0, c0] = arch.frame;
    if states.len() != rows * h0 * w0 * c0 {
        bail!("states: got {} bytes, want {}", states.len(), rows * h0 * w0 * c0);
    }
    let mut x: Vec<f32> = states.iter().map(|&v| v as f32 / 255.0).collect();
    let hw = arch.conv_out_hw();
    let mut conv_out: Vec<Vec<f32>> = Vec::with_capacity(arch.convs.len());
    let mut x0_keep: Vec<f32> = Vec::new();
    if arch.convs.is_empty() && keep {
        x0_keep = x.clone();
    }
    let (mut h, mut w, mut c) = (h0, w0, c0);
    for (i, conv) in arch.convs.iter().enumerate() {
        let (oh, ow) = hw[i];
        let wmat = p.t(2 * i);
        let bias = p.t(2 * i + 1);
        let in_sz = h * w * c;
        let out_sz = oh * ow * conv.filters;
        let mut y = vec![0.0f32; rows * out_sz];
        for bi in 0..rows {
            conv2d_forward_mode(
                mode,
                &x[bi * in_sz..(bi + 1) * in_sz],
                wmat,
                &mut y[bi * out_sz..(bi + 1) * out_sz],
                h,
                w,
                c,
                conv.kernel,
                conv.stride,
                conv.filters,
            );
        }
        for (j, v) in y.iter_mut().enumerate() {
            let withb = *v + bias[j % conv.filters];
            *v = if withb > 0.0 { withb } else { 0.0 };
        }
        if i == 0 && keep {
            x0_keep = std::mem::replace(&mut x, y);
        } else {
            x = y;
        }
        (h, w, c) = (oh, ow, conv.filters);
        if keep {
            conv_out.push(x.clone());
        }
    }
    Ok(TrunkFwd { x0: x0_keep, conv_out, feats: x })
}

/// One shard's forward state for a head tail.
struct HeadFwd {
    x0: Vec<f32>,
    conv_out: Vec<Vec<f32>>,
    /// Post-activation output of each plan layer (cleared unless `keep`).
    acts: Vec<Vec<f32>>,
    /// Head Q-values `[rows, A]` (expected values for C51).
    q: Vec<f32>,
    /// C51 only: per-(sample, action) softmax probabilities
    /// `[rows, A * atoms]`; empty for other heads.
    probs: Vec<f32>,
}

/// Forward over `rows` consecutive samples through the dense plan plus the
/// head's aggregation. Per-sample throughout (every cross-term folds in a
/// fixed serial order), so sharding never changes a bit.
fn forward_head(
    arch: &NetArch,
    p: &P<'_>,
    plan: &[DenseLayer],
    states: &[u8],
    rows: usize,
    keep: bool,
    mode: KernelMode,
) -> Result<HeadFwd> {
    let trunk = trunk_forward(arch, p, states, rows, keep, mode)?;
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(plan.len());
    for layer in plan {
        let xin: &[f32] = match layer.input {
            LayerIn::Trunk => &trunk.feats,
            LayerIn::Layer(j) => &acts[j],
        };
        let wmat = p.t(layer.w);
        let bias = p.t(layer.w + 1);
        let mut y = vec![0.0f32; rows * layer.out_dim];
        matmul_acc_mode(mode, xin, wmat, &mut y, rows, layer.in_dim, layer.out_dim);
        if layer.relu {
            for (j, v) in y.iter_mut().enumerate() {
                let withb = *v + bias[j % layer.out_dim];
                *v = if withb > 0.0 { withb } else { 0.0 };
            }
        } else {
            for (j, v) in y.iter_mut().enumerate() {
                *v += bias[j % layer.out_dim];
            }
        }
        acts.push(y);
    }

    let a = arch.actions;
    let mut q = vec![0.0f32; rows * a];
    let mut probs = Vec::new();
    match arch.head {
        Head::Dqn => q.copy_from_slice(acts.last().expect("plan is never empty")),
        Head::Dueling => {
            // Q(s,a) = V(s) + A(s,a) − mean_a' A(s,a'); the mean folds in
            // ascending action order.
            let val = &acts[acts.len() - 2]; // [rows, 1]
            let adv = &acts[acts.len() - 1]; // [rows, A]
            for r in 0..rows {
                let arow = &adv[r * a..(r + 1) * a];
                let mut mean = 0.0f32;
                for &v in arow {
                    mean += v;
                }
                mean /= a as f32;
                let v = val[r];
                for (k, &av) in arow.iter().enumerate() {
                    q[r * a + k] = v + av - mean;
                }
            }
        }
        Head::C51 { atoms, v_min, v_max } => {
            // Per-(sample, action) softmax over the fixed support, then the
            // expected value — every fold in ascending atom order
            // (max-subtracted for stability).
            let logits = acts.last().expect("plan is never empty");
            probs = vec![0.0f32; rows * a * atoms];
            let dz = (v_max - v_min) / (atoms as f32 - 1.0);
            for r in 0..rows {
                for k in 0..a {
                    let lrow = &logits[(r * a + k) * atoms..(r * a + k + 1) * atoms];
                    let prow = &mut probs[(r * a + k) * atoms..(r * a + k + 1) * atoms];
                    let mut m = f32::NEG_INFINITY;
                    for &v in lrow {
                        if v > m {
                            m = v;
                        }
                    }
                    let mut sum = 0.0f32;
                    for (pv, &v) in prow.iter_mut().zip(lrow.iter()) {
                        *pv = (v - m).exp();
                        sum += *pv;
                    }
                    let mut ev = 0.0f32;
                    for (i, pv) in prow.iter_mut().enumerate() {
                        *pv /= sum;
                        ev += *pv * (v_min + dz * i as f32);
                    }
                    q[r * a + k] = ev;
                }
            }
        }
    }
    if !keep {
        acts.clear();
    }
    Ok(HeadFwd { x0: trunk.x0, conv_out: trunk.conv_out, acts, q, probs })
}

/// Head Q-values, serial, deterministic tier (tests and references).
pub fn infer_head(arch: &NetArch, params: &[f32], states: &[u8], batch: usize) -> Result<Vec<f32>> {
    let p = P::new(arch, params)?;
    let plan = dense_plan(arch);
    Ok(forward_head(arch, &p, &plan, states, batch, false, KernelMode::Deterministic)?.q)
}

/// Head Q-values sharded over the pool — bit-identical across pool widths
/// in either kernel mode (the forward pass is per-sample).
pub fn infer_pooled_head(
    arch: &NetArch,
    params: &[f32],
    states: &[u8],
    batch: usize,
    pool: &ComputePool,
    mode: KernelMode,
) -> Result<Vec<f32>> {
    let p = P::new(arch, params)?;
    let plan = dense_plan(arch);
    let frame = arch.frame_elems();
    if states.len() != batch * frame {
        bail!("states: got {} bytes, want {}", states.len(), batch * frame);
    }
    let ranges = split_ranges(batch, pool.threads());
    if ranges.len() <= 1 {
        return Ok(forward_head(arch, &p, &plan, states, batch, false, mode)?.q);
    }
    let a = arch.actions;
    let mut q = vec![0.0f32; batch * a];
    let mut errs: Vec<Option<String>> = Vec::new();
    errs.resize(ranges.len(), None);

    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut q_rest: &mut [f32] = &mut q;
    for ((lo, hi), err) in ranges.iter().copied().zip(errs.iter_mut()) {
        let (chunk, tail) = std::mem::take(&mut q_rest).split_at_mut((hi - lo) * a);
        q_rest = tail;
        let p = &p;
        let plan = &plan[..];
        let rows_states = &states[lo * frame..hi * frame];
        tasks.push(Box::new(move || {
            match forward_head(arch, p, plan, rows_states, hi - lo, false, mode) {
                Ok(fwd) => chunk.copy_from_slice(&fwd.q),
                Err(e) => *err = Some(e.to_string()),
            }
        }));
    }
    pool.scope(tasks);
    if let Some(e) = errs.into_iter().flatten().next() {
        bail!("{e}");
    }
    Ok(q)
}

/// Project the Bellman-shifted support `reward + scale · z_j` of a target
/// distribution `p_target` onto the fixed support, accumulating into `m`
/// (which the caller zeroes). Ascending atom order; `scale` is
/// `γ_bootstrap · (1 − done)`, so terminal samples collapse the whole mass
/// onto `clamp(reward)`.
pub(crate) fn project_distribution(
    p_target: &[f32],
    reward: f32,
    scale: f32,
    atoms: usize,
    v_min: f32,
    v_max: f32,
    m: &mut [f32],
) {
    let dz = (v_max - v_min) / (atoms as f32 - 1.0);
    for (j, &pj) in p_target.iter().enumerate() {
        let tz = (reward + scale * (v_min + dz * j as f32)).clamp(v_min, v_max);
        let pos = ((tz - v_min) / dz).clamp(0.0, (atoms - 1) as f32);
        let l = pos.floor() as usize;
        let u = pos.ceil() as usize;
        if l == u {
            m[l] += pj;
        } else {
            m[l] += pj * (u as f32 - pos);
            m[u] += pj * (pos - l as f32);
        }
    }
}

/// Everything Phase A produces for one contiguous sample range.
#[derive(Default)]
struct HeadSlot {
    lo: usize,
    hi: usize,
    x0: Vec<f32>,
    conv_out: Vec<Vec<f32>>,
    /// Post-activation output per plan layer.
    acts: Vec<Vec<f32>>,
    /// Masked (post-ReLU) delta per plan layer, already scaled by the IS
    /// weight and 1/batch.
    deltas: Vec<Vec<f32>>,
    /// Masked deltas per conv layer.
    dconv: Vec<Vec<f32>>,
    /// Per-sample (weighted) losses.
    losses: Vec<f32>,
    /// Per-sample priority signal (pre-weight): the raw TD error for
    /// dueling, the projected cross-entropy for C51.
    td: Vec<f32>,
    err: Option<String>,
}

impl HeadSlot {
    fn rows(&self) -> usize {
        self.hi - self.lo
    }
}

/// Phase A body for one shard: forwards, targets, head deltas, and the
/// reverse plan/conv backward.
#[allow(clippy::too_many_arguments)]
fn shard_phase_a_head(
    arch: &NetArch,
    p: &P<'_>,
    pt: &P<'_>,
    plan: &[DenseLayer],
    states: &[u8],
    actions: &[i32],
    rewards: &[f32],
    next_states: &[u8],
    dones: &[f32],
    gamma: f32,
    weights: Option<&[f32]>,
    boot_gammas: Option<&[f32]>,
    double: bool,
    batch_total: usize,
    mode: KernelMode,
    slot: &mut HeadSlot,
) -> Result<()> {
    let rows = slot.rows();
    let (lo, hi) = (slot.lo, slot.hi);
    let frame = arch.frame_elems();
    let a = arch.actions;

    let fwd = forward_head(arch, p, plan, &states[lo * frame..hi * frame], rows, true, mode)?;
    let next_rows = &next_states[lo * frame..hi * frame];
    let tgt = forward_head(arch, pt, plan, next_rows, rows, false, mode)?;
    let online_next = if double {
        Some(forward_head(arch, p, plan, next_rows, rows, false, mode)?)
    } else {
        None
    };
    // Bootstrap action selection: Double-DQN selects by the online net's
    // next-state Q-row, standard by the target net's — first index wins
    // ties (strictly-greater scan), matching the dqn path.
    let argmax_row = |qs: &[f32], r: usize| -> usize {
        let row = &qs[r * a..(r + 1) * a];
        let mut best = 0;
        for (i, &v) in row.iter().enumerate().skip(1) {
            if v > row[best] {
                best = i;
            }
        }
        best
    };

    let n_dense = plan.len();
    let mut deltas: Vec<Vec<f32>> =
        plan.iter().map(|l| vec![0.0f32; rows * l.out_dim]).collect();
    let mut losses = vec![0.0f32; rows];
    let mut td = vec![0.0f32; rows];

    match arch.head {
        Head::Dqn | Head::Dueling => {
            // Scalar TD on the head's Q-values (mean Huber), exactly the
            // dqn expression shape; dueling then splits dL/dq into the
            // value/advantage stream deltas.
            let mut dq = vec![0.0f32; rows * a];
            for r in 0..rows {
                let b = lo + r;
                let act = actions[b];
                if act < 0 || act as usize >= a {
                    bail!("train: action {act} out of range 0..{a}");
                }
                let bootstrap = match &online_next {
                    Some(on) => tgt.q[r * a + argmax_row(&on.q, r)],
                    None => tgt.q[r * a..(r + 1) * a]
                        .iter()
                        .copied()
                        .fold(f32::NEG_INFINITY, f32::max),
                };
                let bg = boot_gammas.map_or(gamma, |g| g[b]);
                let target = rewards[b] + bg * (1.0 - dones[b]) * bootstrap;
                let d = fwd.q[r * a + act as usize] - target;
                td[r] = d;
                let w = weights.map_or(1.0, |ws| ws[b]);
                losses[r] = w * huber(d);
                dq[r * a + act as usize] = w * huber_grad(d) / batch_total as f32;
            }
            match arch.head {
                Head::Dueling => {
                    // dV[r] = Σ_k dq[r,k]; dA[r,k] = dq[r,k] − dV[r]/A.
                    // (Only the selected action's dq is nonzero, but the
                    // general expression keeps the math auditable.)
                    for r in 0..rows {
                        let row = &dq[r * a..(r + 1) * a];
                        let mut s = 0.0f32;
                        for &v in row {
                            s += v;
                        }
                        deltas[n_dense - 2][r] = s;
                        for (k, &v) in row.iter().enumerate() {
                            deltas[n_dense - 1][r * a + k] = v - s / a as f32;
                        }
                    }
                }
                _ => deltas[n_dense - 1] = dq,
            }
        }
        Head::C51 { atoms, v_min, v_max } => {
            let dl = &mut deltas[n_dense - 1]; // [rows, A*atoms] logit deltas
            let mut m = vec![0.0f32; atoms];
            for r in 0..rows {
                let b = lo + r;
                let act = actions[b];
                if act < 0 || act as usize >= a {
                    bail!("train: action {act} out of range 0..{a}");
                }
                let astar = match &online_next {
                    Some(on) => argmax_row(&on.q, r),
                    None => argmax_row(&tgt.q, r),
                };
                let pt_row = &tgt.probs[(r * a + astar) * atoms..(r * a + astar + 1) * atoms];
                let bg = boot_gammas.map_or(gamma, |g| g[b]);
                let scale = bg * (1.0 - dones[b]);
                m.iter_mut().for_each(|v| *v = 0.0);
                project_distribution(pt_row, rewards[b], scale, atoms, v_min, v_max, &mut m);
                // Cross-entropy against the projected target; the gradient
                // w.r.t. the selected action's logits is (p − m)·w/B.
                let p_sel = &fwd.probs[(r * a + act as usize) * atoms
                    ..(r * a + act as usize + 1) * atoms];
                let mut ce = 0.0f32;
                for (mi, &pv) in m.iter().zip(p_sel.iter()) {
                    ce -= mi * pv.max(1e-12).ln();
                }
                td[r] = ce;
                let w = weights.map_or(1.0, |ws| ws[b]);
                losses[r] = w * ce;
                let drow = &mut dl[(r * a + act as usize) * atoms
                    ..(r * a + act as usize + 1) * atoms];
                for ((dv, &pv), &mi) in drow.iter_mut().zip(p_sel.iter()).zip(m.iter()) {
                    *dv = w * (pv - mi) / batch_total as f32;
                }
            }
        }
    }

    // Reverse plan walk: mask each layer's delta by its own post-activation
    // (ReLU layers), then propagate to its input. A layer's input always
    // precedes it in the plan, so each delta is complete before it is
    // consumed. The trunk delta accumulates its (possibly two) stream
    // contributions in fixed reverse-plan order.
    let trunk_dim = arch.trunk_dim();
    let mut dtrunk = vec![0.0f32; rows * trunk_dim];
    for li in (0..n_dense).rev() {
        let layer = plan[li];
        let (before, rest) = deltas.split_at_mut(li);
        let d = &mut rest[0];
        if layer.relu {
            for (dv, &v) in d.iter_mut().zip(fwd.acts[li].iter()) {
                if v <= 0.0 {
                    *dv = 0.0;
                }
            }
        }
        let wmat = p.t(layer.w);
        let mut dprev = vec![0.0f32; rows * layer.in_dim];
        matmul_a_bt_mode(mode, d, wmat, &mut dprev, rows, layer.out_dim, layer.in_dim);
        match layer.input {
            LayerIn::Trunk => {
                for (o, v) in dtrunk.iter_mut().zip(dprev) {
                    *o += v;
                }
            }
            LayerIn::Layer(j) => {
                for (o, v) in before[j].iter_mut().zip(dprev) {
                    *o += v;
                }
            }
        }
    }

    // Conv backward — byte-for-byte the dqn path's loop.
    let n_conv = arch.convs.len();
    let hw = arch.conv_out_hw();
    let mut dx = dtrunk;
    let mut dconv: Vec<Vec<f32>> = vec![Vec::new(); n_conv];
    for i in (0..n_conv).rev() {
        let conv = arch.convs[i];
        let (oh, ow) = hw[i];
        let (in_h, in_w, in_c) = if i > 0 {
            (hw[i - 1].0, hw[i - 1].1, arch.convs[i - 1].filters)
        } else {
            (arch.frame[0], arch.frame[1], arch.frame[2])
        };
        let f = conv.filters;
        for (dv, &v) in dx.iter_mut().zip(fwd.conv_out[i].iter()) {
            if v <= 0.0 {
                *dv = 0.0;
            }
        }
        let need_dx = i > 0;
        let wmat = p.t(2 * i);
        let in_sz = in_h * in_w * in_c;
        let mut dprev = if need_dx { vec![0.0f32; rows * in_sz] } else { Vec::new() };
        if need_dx {
            for bi in 0..rows {
                let dy = &dx[bi * oh * ow * f..(bi + 1) * oh * ow * f];
                conv2d_input_grad_mode(
                    mode,
                    dy,
                    wmat,
                    &mut dprev[bi * in_sz..(bi + 1) * in_sz],
                    in_h,
                    in_w,
                    in_c,
                    conv.kernel,
                    conv.stride,
                    f,
                );
            }
        }
        dconv[i] = std::mem::replace(&mut dx, dprev);
    }

    slot.x0 = fwd.x0;
    slot.conv_out = fwd.conv_out;
    slot.acts = fwd.acts;
    slot.deltas = deltas;
    slot.dconv = dconv;
    slot.losses = losses;
    slot.td = td;
    Ok(())
}

/// TD/CE loss + full parameter gradient for a head variant (the train
/// entry minus the optimizer), two-phase like `native::td_grads_opts`.
/// Returns (grad, loss, per-sample priority signal). Bit-identical for
/// every pool width in both kernel tiers (see module docs).
#[allow(clippy::too_many_arguments)]
pub fn td_grads_head(
    arch: &NetArch,
    theta: &[f32],
    target_theta: &[f32],
    states: &[u8],
    actions: &[i32],
    rewards: &[f32],
    next_states: &[u8],
    dones: &[f32],
    gamma: f32,
    weights: Option<&[f32]>,
    boot_gammas: Option<&[f32]>,
    double: bool,
    pool: &ComputePool,
    mode: KernelMode,
) -> Result<(Vec<f32>, f32, Vec<f32>)> {
    let batch = actions.len();
    if batch == 0 {
        bail!("train: empty minibatch");
    }
    if let Some(w) = weights {
        if w.len() != batch {
            bail!("train: {} weights for a {batch}-sample minibatch", w.len());
        }
    }
    if let Some(g) = boot_gammas {
        if g.len() != batch {
            bail!("train: {} bootstrap discounts for a {batch}-sample minibatch", g.len());
        }
    }
    let p = P::new(arch, theta)?;
    let pt = P::new(arch, target_theta)?;
    let plan = dense_plan(arch);

    // ---- Phase A: per-sample work over contiguous shards -----------------
    let ranges = split_ranges(batch, pool.threads());
    let mut slots: Vec<HeadSlot> = ranges
        .iter()
        .map(|&(lo, hi)| HeadSlot { lo, hi, ..HeadSlot::default() })
        .collect();
    {
        let p = &p;
        let pt = &pt;
        let plan = &plan[..];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .iter_mut()
            .map(|slot| {
                Box::new(move || {
                    if let Err(e) = shard_phase_a_head(
                        arch, p, pt, plan, states, actions, rewards, next_states, dones,
                        gamma, weights, boot_gammas, double, batch, mode, slot,
                    ) {
                        slot.err = Some(e.to_string());
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
    }
    for slot in slots.iter() {
        if let Some(e) = &slot.err {
            bail!("{e}");
        }
    }

    // Mean loss and priority signal, in global sample order.
    let mut loss = 0.0f32;
    for slot in slots.iter() {
        for &l in &slot.losses {
            loss += l;
        }
    }
    loss /= batch as f32;
    let mut td_all = vec![0.0f32; batch];
    for slot in slots.iter() {
        td_all[slot.lo..slot.hi].copy_from_slice(&slot.td);
    }

    // ---- Phase B: parameter reductions in global sample order ------------
    let n_conv = arch.convs.len();
    let hw = arch.conv_out_hw();
    let threads = pool.threads();
    let mut grad = vec![0.0f32; arch.param_count()];
    let mut tensor_slices: Vec<&mut [f32]> = Vec::new();
    {
        let mut rest: &mut [f32] = &mut grad;
        for (_, shape) in arch.param_spec() {
            let n: usize = shape.iter().product();
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(n);
            tensor_slices.push(head);
            rest = tail;
        }
    }

    let slots_ref: &[HeadSlot] = &slots;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    let mut slice_iter = tensor_slices.into_iter();

    // Conv tensors — the dqn path's chunking verbatim.
    for i in 0..n_conv {
        let conv = arch.convs[i];
        let (oh, ow) = hw[i];
        let f = conv.filters;
        let (in_h, in_w, in_c) = if i > 0 {
            (hw[i - 1].0, hw[i - 1].1, arch.convs[i - 1].filters)
        } else {
            (arch.frame[0], arch.frame[1], arch.frame[2])
        };
        let kdim = conv.kernel * conv.kernel * in_c;
        let in_sz = in_h * in_w * in_c;
        let wslice = slice_iter.next().unwrap();
        let bslice = slice_iter.next().unwrap();

        let chunk_rows = kdim.div_ceil(threads);
        let mut k_lo = 0;
        for chunk in wslice.chunks_mut(chunk_rows * f) {
            let k_hi = k_lo + chunk.len() / f;
            tasks.push(Box::new(move || {
                for slot in slots_ref {
                    let rows = slot.rows();
                    let dcv = &slot.dconv[i];
                    let xin: &[f32] = if i > 0 { &slot.conv_out[i - 1] } else { &slot.x0 };
                    for bi in 0..rows {
                        let dy = &dcv[bi * oh * ow * f..(bi + 1) * oh * ow * f];
                        let xs = &xin[bi * in_sz..(bi + 1) * in_sz];
                        conv2d_weight_grad_chunk_mode(
                            mode,
                            xs,
                            dy,
                            chunk,
                            k_lo,
                            k_hi,
                            in_h,
                            in_w,
                            in_c,
                            conv.kernel,
                            conv.stride,
                            f,
                        );
                    }
                }
            }));
            k_lo = k_hi;
        }
        tasks.push(Box::new(move || {
            for slot in slots_ref {
                let rows = slot.rows();
                let dcv = &slot.dconv[i];
                for bi in 0..rows {
                    let dy = &dcv[bi * oh * ow * f..(bi + 1) * oh * ow * f];
                    for row in 0..oh * ow {
                        for (o, &dv) in bslice.iter_mut().zip(dy[row * f..(row + 1) * f].iter()) {
                            *o += dv;
                        }
                    }
                }
            }
        }));
    }

    // Dense plan tensors: one uniform loop — weight rows chunked over
    // in_dim, every chunk walking all samples in ascending global order
    // (ascending-kk + sparsity skip in the deterministic tier, FAST_RANK
    // global-order grouping in fast).
    for (li, &layer) in plan.iter().enumerate() {
        let width = layer.out_dim;
        let in_dim = layer.in_dim;
        let wslice = slice_iter.next().unwrap();
        let bslice = slice_iter.next().unwrap();
        let slot_input = move |slot: &'_ HeadSlot| -> &'_ [f32] {
            match layer.input {
                LayerIn::Trunk => {
                    if n_conv > 0 {
                        &slot.conv_out[n_conv - 1]
                    } else {
                        &slot.x0
                    }
                }
                LayerIn::Layer(j) => &slot.acts[j],
            }
        };

        let chunk_rows = in_dim.div_ceil(threads);
        let mut k_lo = 0;
        for chunk in wslice.chunks_mut(chunk_rows * width) {
            let k_hi = k_lo + chunk.len() / width;
            tasks.push(Box::new(move || match mode {
                KernelMode::Deterministic => {
                    for slot in slots_ref {
                        let rows = slot.rows();
                        let xin = slot_input(slot);
                        let dxl = &slot.deltas[li];
                        for r in 0..rows {
                            let xrow = &xin[r * in_dim..(r + 1) * in_dim];
                            let drow = &dxl[r * width..(r + 1) * width];
                            for kk in k_lo..k_hi {
                                let av = xrow[kk];
                                if av == 0.0 {
                                    continue;
                                }
                                let orow = &mut chunk[(kk - k_lo) * width..(kk - k_lo + 1) * width];
                                for (o, &dv) in orow.iter_mut().zip(drow.iter()) {
                                    *o += av * dv;
                                }
                            }
                        }
                    }
                }
                KernelMode::Fast => {
                    let xrows: Vec<&[f32]> = slots_ref
                        .iter()
                        .flat_map(|slot| {
                            let xin = slot_input(slot);
                            (0..slot.rows()).map(move |r| &xin[r * in_dim..(r + 1) * in_dim])
                        })
                        .collect();
                    let drows: Vec<&[f32]> = slots_ref
                        .iter()
                        .flat_map(|slot| {
                            let dxl: &[f32] = &slot.deltas[li];
                            (0..slot.rows()).map(move |r| &dxl[r * width..(r + 1) * width])
                        })
                        .collect();
                    fast_weight_chunk(chunk, width, k_lo, k_hi, &xrows, &drows);
                }
            }));
            k_lo = k_hi;
        }
        tasks.push(Box::new(move || {
            for slot in slots_ref {
                let rows = slot.rows();
                let dxl = &slot.deltas[li];
                for r in 0..rows {
                    for (o, &dv) in bslice.iter_mut().zip(dxl[r * width..(r + 1) * width].iter()) {
                        *o += dv;
                    }
                }
            }
        }));
    }
    pool.scope(tasks);

    Ok((grad, loss, td_all))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::golden;
    use crate::runtime::native::{init_params, ConvSpec};
    use crate::util::rng::Rng;

    fn micro(head: Head) -> NetArch {
        NetArch {
            name: "micro".into(),
            frame: [8, 8, 2],
            convs: vec![ConvSpec { filters: 2, kernel: 4, stride: 4 }],
            hidden: vec![8],
            actions: 3,
            head,
        }
    }

    fn c51_head() -> Head {
        Head::C51 { atoms: 5, v_min: -2.0, v_max: 2.0 }
    }

    fn micro_batch(
        arch: &NetArch,
        rng: &mut Rng,
    ) -> (Vec<u8>, Vec<i32>, Vec<f32>, Vec<u8>, Vec<f32>) {
        let b = 4;
        let fe = arch.frame_elems();
        let states: Vec<u8> = (0..b * fe).map(|_| rng.below(256) as u8).collect();
        let next: Vec<u8> = (0..b * fe).map(|_| rng.below(256) as u8).collect();
        let actions: Vec<i32> = (0..b).map(|_| rng.below(arch.actions as u32) as i32).collect();
        let rewards: Vec<f32> = (0..b).map(|_| rng.f32() - 0.5).collect();
        let dones: Vec<f32> = (0..b).map(|i| if i == 1 { 1.0 } else { 0.0 }).collect();
        (states, actions, rewards, next, dones)
    }

    #[test]
    fn head_param_specs_are_consistent() {
        for head in [Head::Dueling, c51_head()] {
            let arch = micro(head);
            let total: usize =
                arch.param_spec().iter().map(|(_, s)| s.iter().product::<usize>()).sum();
            assert_eq!(total, arch.param_count());
            let plan = dense_plan(&arch);
            // Plan tensors tile the param spec after the convs, in order.
            let mut expect = 2 * arch.convs.len();
            for l in &plan {
                assert_eq!(l.w, expect, "plan order must match param order");
                expect += 2;
            }
            assert_eq!(expect, arch.param_spec().len());
        }
    }

    #[test]
    fn dueling_q_aggregates_value_and_advantage() {
        let arch = micro(Head::Dueling);
        let theta = init_params(&arch, 3);
        let mut rng = Rng::new(5);
        let states: Vec<u8> = (0..2 * arch.frame_elems()).map(|_| rng.below(256) as u8).collect();
        let q = infer_head(&arch, &theta, &states, 2).unwrap();
        assert_eq!(q.len(), 2 * arch.actions);
        // Mean-subtracted aggregation ⇒ mean_a Q(s,a) == V(s); verify the
        // identity Σ_a (Q − mean Q) reproduces the advantage residuals.
        for r in 0..2 {
            let row = &q[r * arch.actions..(r + 1) * arch.actions];
            let mean: f32 = row.iter().sum::<f32>() / arch.actions as f32;
            let resid: f32 = row.iter().map(|v| v - mean).sum();
            assert!(resid.abs() < 1e-4, "row {r}: residual {resid}");
        }
    }

    #[test]
    fn c51_probabilities_normalize_and_bound_q() {
        let arch = micro(c51_head());
        let theta = init_params(&arch, 4);
        let mut rng = Rng::new(6);
        let states: Vec<u8> = (0..3 * arch.frame_elems()).map(|_| rng.below(256) as u8).collect();
        let p = P::new(&arch, &theta).unwrap();
        let plan = dense_plan(&arch);
        let fwd =
            forward_head(&arch, &p, &plan, &states, 3, false, KernelMode::Deterministic).unwrap();
        let Head::C51 { atoms, v_min, v_max } = arch.head else { unreachable!() };
        for ra in 0..3 * arch.actions {
            let sum: f32 = fwd.probs[ra * atoms..(ra + 1) * atoms].iter().sum();
            assert!((sum - 1.0).abs() < 1e-5, "row {ra}: probs sum {sum}");
        }
        for &qv in &fwd.q {
            assert!(qv >= v_min && qv <= v_max, "expected value {qv} outside support");
        }
    }

    #[test]
    fn projection_matches_hand_computed_case() {
        // atoms=3 support {-1, 0, 1}, dz=1. Target dist (0.5, 0.25, 0.25),
        // reward 0.2, scale 0.5: Tz = {-0.3, 0.2, 0.7}.
        let mut m = vec![0.0f32; 3];
        project_distribution(&[0.5, 0.25, 0.25], 0.2, 0.5, 3, -1.0, 1.0, &mut m);
        // -0.3 → 0.3/0.7 split between atoms 0,1 of mass .5;
        //  0.2 → 0.8/0.2 split between atoms 1,2 of mass .25;
        //  0.7 → 0.3/0.7 split between atoms 1,2 of mass .25.
        let expect = [
            0.5 * 0.3,
            0.5 * 0.7 + 0.25 * 0.8 + 0.25 * 0.3,
            0.25 * 0.2 + 0.25 * 0.7,
        ];
        for (got, want) in m.iter().zip(expect.iter()) {
            assert!((got - want).abs() < 1e-6, "{m:?} vs {expect:?}");
        }
        assert!((m.iter().sum::<f32>() - 1.0).abs() < 1e-6);

        // Terminal sample (scale 0): whole mass lands on clamp(reward).
        let mut m = vec![0.0f32; 3];
        project_distribution(&[0.2, 0.3, 0.5], 5.0, 0.0, 3, -1.0, 1.0, &mut m);
        assert_eq!(m, vec![0.0, 0.0, 1.0]);
    }

    #[test]
    fn head_infer_is_pool_and_chunk_invariant() {
        for head in [Head::Dueling, c51_head()] {
            let arch = micro(head);
            let theta = init_params(&arch, 9);
            let mut rng = Rng::new(31);
            let batch = 7;
            let states: Vec<u8> =
                (0..batch * arch.frame_elems()).map(|_| rng.below(256) as u8).collect();
            let serial = infer_head(&arch, &theta, &states, batch).unwrap();
            for mode in [KernelMode::Deterministic, KernelMode::Fast] {
                let base = infer_pooled_head(&arch, &theta, &states, batch, &ComputePool::new(1), mode)
                    .unwrap();
                for threads in [2usize, 3, 4] {
                    let pool = ComputePool::new(threads);
                    let q = infer_pooled_head(&arch, &theta, &states, batch, &pool, mode).unwrap();
                    assert_eq!(
                        base.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        q.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{head:?} {mode:?} threads {threads}"
                    );
                }
                if mode == KernelMode::Deterministic {
                    assert_eq!(
                        serial.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        base.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{head:?} pooled-vs-serial"
                    );
                }
            }
        }
    }

    #[test]
    fn head_infer_matches_golden_reference() {
        for head in [Head::Dueling, c51_head()] {
            let arch = micro(head);
            let theta = init_params(&arch, 13);
            let mut rng = Rng::new(37);
            let batch = 5;
            let states: Vec<u8> =
                (0..batch * arch.frame_elems()).map(|_| rng.below(256) as u8).collect();
            let ours = infer_head(&arch, &theta, &states, batch).unwrap();
            let golden = golden::reference_infer_head(&arch, &theta, &states, batch).unwrap();
            assert_eq!(
                ours.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                golden.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{head:?}"
            );
        }
    }

    fn fd_check(head: Head, double: bool, seed: u64, probe: &[usize]) {
        let arch = micro(head);
        let mut rng = Rng::new(seed);
        let theta = init_params(&arch, seed.wrapping_add(1));
        let target = init_params(&arch, seed.wrapping_add(2));
        let (states, actions, rewards, next, dones) = micro_batch(&arch, &mut rng);
        let pool = ComputePool::new(1);
        let (grad, loss, td) = td_grads_head(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, None,
            None, double, &pool, KernelMode::Deterministic,
        )
        .unwrap();
        assert_eq!(td.len(), actions.len());
        // Loss agrees with the independent golden implementation.
        let ref_loss = golden::reference_loss_head(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, double,
        )
        .unwrap();
        assert!(
            (loss - ref_loss).abs() < 1e-6,
            "{head:?} double={double}: loss {loss} vs golden {ref_loss}"
        );

        let eps = 1e-3f32;
        for &i in probe {
            let mut tp = theta.clone();
            tp[i] += eps;
            let lp = golden::reference_loss_head(
                &arch, &tp, &target, &states, &actions, &rewards, &next, &dones, 0.9, double,
            )
            .unwrap();
            tp[i] = theta[i] - eps;
            let lm = golden::reference_loss_head(
                &arch, &tp, &target, &states, &actions, &rewards, &next, &dones, 0.9, double,
            )
            .unwrap();
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[i]).abs() < 2e-3,
                "{head:?} double={double} param {i}: finite-diff {fd} vs analytic {}",
                grad[i]
            );
        }
    }

    #[test]
    fn dueling_gradients_match_finite_differences() {
        let arch = micro(Head::Dueling);
        let n = arch.param_count();
        // Probe conv w/b, both streams' hidden layers, and both out layers.
        fd_check(Head::Dueling, false, 51, &[0, 30, 64, 65, 70, 140, 210, n - 28, n - 5, n - 1]);
        fd_check(Head::Dueling, true, 52, &[1, 66, 139, 211, n - 27, n - 2]);
    }

    #[test]
    fn c51_gradients_match_finite_differences() {
        let arch = micro(c51_head());
        let n = arch.param_count();
        fd_check(c51_head(), false, 53, &[0, 30, 64, 65, 70, 130, n - 136, n - 16, n - 1]);
        fd_check(c51_head(), true, 54, &[1, 66, 131, n - 100, n - 2]);
    }

    #[test]
    fn head_gradients_are_bit_identical_across_pool_widths() {
        for head in [Head::Dueling, c51_head()] {
            let arch = micro(head);
            let mut rng = Rng::new(61);
            let theta = init_params(&arch, 62);
            let target = init_params(&arch, 63);
            let (states, actions, rewards, next, dones) = micro_batch(&arch, &mut rng);
            let weights: Vec<f32> = (0..actions.len()).map(|i| 0.5 + 0.25 * i as f32).collect();
            let boots: Vec<f32> = (0..actions.len()).map(|i| 0.9f32.powi(1 + (i % 3) as i32)).collect();
            for mode in [KernelMode::Deterministic, KernelMode::Fast] {
                let baseline = td_grads_head(
                    &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9,
                    Some(&weights), Some(&boots), true, &ComputePool::new(1), mode,
                )
                .unwrap();
                for threads in [2usize, 3, 4] {
                    let pool = ComputePool::new(threads);
                    let got = td_grads_head(
                        &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9,
                        Some(&weights), Some(&boots), true, &pool, mode,
                    )
                    .unwrap();
                    assert_eq!(
                        baseline.0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        got.0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        "{head:?} {mode:?} threads {threads}: grads diverged"
                    );
                    assert_eq!(baseline.1.to_bits(), got.1.to_bits());
                    assert_eq!(
                        baseline.2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                        got.2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                    );
                }
            }
        }
    }

    #[test]
    fn unit_weights_and_scalar_gammas_are_degenerate() {
        // weights = 1 and boot_gammas = γ multiply/substitute identically,
        // so the extended call must be bitwise equal to the basic one.
        for head in [Head::Dueling, c51_head()] {
            let arch = micro(head);
            let mut rng = Rng::new(71);
            let theta = init_params(&arch, 72);
            let target = init_params(&arch, 73);
            let (states, actions, rewards, next, dones) = micro_batch(&arch, &mut rng);
            let ones = vec![1.0f32; actions.len()];
            let gammas = vec![0.9f32; actions.len()];
            let pool = ComputePool::new(2);
            let base = td_grads_head(
                &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, None,
                None, false, &pool, KernelMode::Deterministic,
            )
            .unwrap();
            let ext = td_grads_head(
                &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9,
                Some(&ones), Some(&gammas), false, &pool, KernelMode::Deterministic,
            )
            .unwrap();
            assert_eq!(
                base.0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                ext.0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "{head:?}"
            );
            assert_eq!(base.1.to_bits(), ext.1.to_bits());
        }
    }
}
