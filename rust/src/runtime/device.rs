//! The shared accelerator ("GPU") abstraction.
//!
//! The paper's hardware model (§2.2) is a heterogeneous machine with W CPU
//! threads and ONE coprocessor suitable only for neural-network inference
//! and training. `Device` models the coprocessor's *bus*: which backend does
//! the math is a pluggable [`ExecutionEngine`] (see rust/DESIGN.md §2). Two
//! properties of a real GPU matter to the paper's argument, and both are
//! preserved regardless of engine:
//!
//! 1. **Serialized transaction bus** — every host<->device interaction is a
//!    transaction on a shared bus. We model this with a single `Mutex`
//!    around the engine: threads attempting simultaneous device access
//!    contend exactly as the paper's Figure 3(a) describes.
//! 2. **Batching amplifies throughput** — one batched call is far cheaper
//!    than W size-1 calls (true for every engine: per-call dispatch and
//!    transfer overhead dominates at batch 1).
//!
//! Every transaction is counted (count, bytes in/out, nanoseconds held) so
//! the Figure 3 reproduction can report bus pressure directly.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::Result;

use super::engine::ExecutionEngine;
use super::kernels::KernelMode;
use super::manifest::NetSpec;
use super::native::NativeEngine;
use super::tensor::{HostTensor, TensorView};

/// Bus / transaction statistics for the Figure 3 reproduction.
#[derive(Debug, Default)]
pub struct BusStats {
    /// Number of device transactions (execute calls).
    pub transactions: AtomicU64,
    /// Host->device bytes moved.
    pub bytes_in: AtomicU64,
    /// Device->host bytes moved.
    pub bytes_out: AtomicU64,
    /// Nanoseconds the device lock was held for execution.
    pub busy_ns: AtomicU64,
    /// Nanoseconds threads spent waiting to acquire the device.
    pub wait_ns: AtomicU64,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BusSnapshot {
    pub transactions: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub busy_ns: u64,
    pub wait_ns: u64,
}

impl BusStats {
    pub fn snapshot(&self) -> BusSnapshot {
        BusSnapshot {
            transactions: self.transactions.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            wait_ns: self.wait_ns.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.transactions.store(0, Ordering::Relaxed);
        self.bytes_in.store(0, Ordering::Relaxed);
        self.bytes_out.store(0, Ordering::Relaxed);
        self.busy_ns.store(0, Ordering::Relaxed);
        self.wait_ns.store(0, Ordering::Relaxed);
    }
}

/// The single shared accelerator: one engine behind one bus mutex.
pub struct Device {
    engine: Mutex<Box<dyn ExecutionEngine>>,
    pub stats: BusStats,
    platform: String,
}

impl Device {
    /// The default CPU device (native reference engine, serial learner).
    /// The name is kept from the PJRT era so call sites read the same
    /// either way.
    pub fn cpu() -> Result<Device> {
        Self::cpu_with_threads(1)
    }

    /// CPU device whose native engine shards learner work over a
    /// persistent `learner_threads`-lane compute pool. Results are
    /// bit-identical for every thread count (rust/DESIGN.md §9).
    pub fn cpu_with_threads(learner_threads: usize) -> Result<Device> {
        Self::cpu_with_opts(learner_threads, KernelMode::Deterministic)
    }

    /// CPU device with an explicit kernel tier (the `kernel_mode` knob;
    /// rust/DESIGN.md §12). `Deterministic` is bit-pinned; `Fast` trades
    /// bit-identity vs that pin for vectorized kernels under a bounded,
    /// property-tested divergence contract — while remaining bit-identical
    /// run-to-run and across `learner_threads`.
    pub fn cpu_with_opts(learner_threads: usize, kernel_mode: KernelMode) -> Result<Device> {
        Ok(Self::with_engine(Box::new(NativeEngine::with_options(learner_threads, kernel_mode))))
    }

    /// The PJRT/XLA device executing AOT-compiled HLO artifacts.
    #[cfg(feature = "xla")]
    pub fn xla() -> Result<Device> {
        Ok(Self::with_engine(Box::new(super::xla_engine::XlaEngine::new()?)))
    }

    /// Wrap an arbitrary engine (tests, future backends).
    pub fn with_engine(engine: Box<dyn ExecutionEngine>) -> Device {
        let platform = engine.platform_name().to_string();
        Device { engine: Mutex::new(engine), stats: BusStats::default(), platform }
    }

    pub fn platform_name(&self) -> &str {
        &self.platform
    }

    /// Prepare `entry_name` of `spec` for execution under `key`.
    /// Idempotent per key.
    pub fn load_entry(&self, key: &str, spec: &NetSpec, entry_name: &str) -> Result<()> {
        self.engine.lock().unwrap().load_entry(key, spec, entry_name)
    }

    pub fn is_loaded(&self, key: &str) -> bool {
        self.engine.lock().unwrap().is_loaded(key)
    }

    /// Execute entry `key`; returns the entry's outputs.
    ///
    /// One call == one bus transaction. The device lock is held for the
    /// entire upload-execute-download, mirroring a synchronous accelerator
    /// round trip.
    pub fn execute(&self, key: &str, args: &[TensorView<'_>]) -> Result<Vec<HostTensor>> {
        let t_wait = Instant::now();
        let mut engine = self.engine.lock().unwrap();
        self.stats
            .wait_ns
            .fetch_add(t_wait.elapsed().as_nanos() as u64, Ordering::Relaxed);

        let bytes_in: usize = args.iter().map(|a| a.size_bytes()).sum();
        let t0 = Instant::now();
        let outputs = engine.execute(key, args)?;
        let busy = t0.elapsed().as_nanos() as u64;
        let bytes_out: usize = outputs.iter().map(|o| o.size_bytes()).sum();

        self.stats.transactions.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_in.fetch_add(bytes_in as u64, Ordering::Relaxed);
        self.stats.bytes_out.fetch_add(bytes_out as u64, Ordering::Relaxed);
        self.stats.busy_ns.fetch_add(busy, Ordering::Relaxed);
        Ok(outputs)
    }
}
