//! The shared accelerator ("GPU") abstraction.
//!
//! The paper's hardware model (§2.2) is a heterogeneous machine with W CPU
//! threads and ONE coprocessor suitable only for neural-network inference
//! and training. Here the coprocessor is the PJRT CPU client executing the
//! AOT-compiled HLO artifacts. Two properties of a real GPU matter to the
//! paper's argument, and both are preserved:
//!
//! 1. **Serialized transaction bus** — every host<->device interaction is a
//!    transaction on a shared bus. We model this with a single `Mutex`
//!    around the client: threads attempting simultaneous device access
//!    contend exactly as the paper's Figure 3(a) describes.
//! 2. **Batching amplifies throughput** — one batched call is far cheaper
//!    than W size-1 calls (true on the CPU backend as well: dispatch and
//!    transfer overhead is per-call).
//!
//! Every transaction is counted (count, bytes in/out, nanoseconds held) so
//! the Figure 3 reproduction can report bus pressure directly.
//!
//! # Safety
//!
//! `PjRtClient`, `PjRtLoadedExecutable`, and `Literal` hold raw pointers and
//! internal `Rc`s, so the crate does not mark them `Send`/`Sync`. The
//! underlying XLA objects are plain heap allocations; the only hazards are
//! (a) unsynchronized `Rc` refcount updates and (b) concurrent mutation.
//! `Device` prevents both by construction: the client, all executables, and
//! every literal that crosses threads are owned by `DeviceInner`, reachable
//! only through one `Mutex`, and no `Rc` clone or XLA call ever happens
//! outside that lock. Hence the manual `unsafe impl Send + Sync`.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use anyhow::{anyhow, Context, Result};

/// Bus / transaction statistics for the Figure 3 reproduction.
#[derive(Debug, Default)]
pub struct BusStats {
    /// Number of device transactions (execute calls).
    pub transactions: AtomicU64,
    /// Host->device bytes moved.
    pub bytes_in: AtomicU64,
    /// Device->host bytes moved.
    pub bytes_out: AtomicU64,
    /// Nanoseconds the device lock was held for execution.
    pub busy_ns: AtomicU64,
    /// Nanoseconds threads spent waiting to acquire the device.
    pub wait_ns: AtomicU64,
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BusSnapshot {
    pub transactions: u64,
    pub bytes_in: u64,
    pub bytes_out: u64,
    pub busy_ns: u64,
    pub wait_ns: u64,
}

impl BusStats {
    pub fn snapshot(&self) -> BusSnapshot {
        BusSnapshot {
            transactions: self.transactions.load(Ordering::Relaxed),
            bytes_in: self.bytes_in.load(Ordering::Relaxed),
            bytes_out: self.bytes_out.load(Ordering::Relaxed),
            busy_ns: self.busy_ns.load(Ordering::Relaxed),
            wait_ns: self.wait_ns.load(Ordering::Relaxed),
        }
    }

    pub fn reset(&self) {
        self.transactions.store(0, Ordering::Relaxed);
        self.bytes_in.store(0, Ordering::Relaxed);
        self.bytes_out.store(0, Ordering::Relaxed);
        self.busy_ns.store(0, Ordering::Relaxed);
        self.wait_ns.store(0, Ordering::Relaxed);
    }
}

struct DeviceInner {
    client: xla::PjRtClient,
    executables: BTreeMap<String, xla::PjRtLoadedExecutable>,
}

/// The single shared accelerator. See module docs for the safety argument.
pub struct Device {
    inner: Mutex<DeviceInner>,
    pub stats: BusStats,
    platform: String,
}

unsafe impl Send for Device {}
unsafe impl Sync for Device {}

impl Device {
    /// Create the PJRT CPU device.
    pub fn cpu() -> Result<Device> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PjRtClient::cpu: {e}"))?;
        let platform = client.platform_name();
        Ok(Device {
            inner: Mutex::new(DeviceInner { client, executables: BTreeMap::new() }),
            stats: BusStats::default(),
            platform,
        })
    }

    pub fn platform_name(&self) -> &str {
        &self.platform
    }

    /// Load + compile an HLO-text artifact under `key`. Idempotent per key.
    pub fn load_hlo(&self, key: &str, path: &Path) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if inner.executables.contains_key(key) {
            return Ok(());
        }
        let path_str = path
            .to_str()
            .ok_or_else(|| anyhow!("non-utf8 artifact path {path:?}"))?;
        let proto = xla::HloModuleProto::from_text_file(path_str)
            .map_err(|e| anyhow!("parsing {}: {e}", path.display()))
            .with_context(|| "run `make artifacts` to (re)build HLO artifacts")?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = inner
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling {}: {e}", path.display()))?;
        inner.executables.insert(key.to_string(), exe);
        Ok(())
    }

    pub fn is_loaded(&self, key: &str) -> bool {
        self.inner.lock().unwrap().executables.contains_key(key)
    }

    /// Execute entry `key` with host literals; returns the untupled outputs.
    ///
    /// One call == one bus transaction. The device lock is held for the
    /// entire upload-execute-download, mirroring a synchronous accelerator
    /// round trip.
    pub fn execute(&self, key: &str, args: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let t_wait = Instant::now();
        let inner = self.inner.lock().unwrap();
        self.stats
            .wait_ns
            .fetch_add(t_wait.elapsed().as_nanos() as u64, Ordering::Relaxed);

        let exe = inner
            .executables
            .get(key)
            .ok_or_else(|| anyhow!("executable {key:?} not loaded"))?;

        let bytes_in: usize = args.iter().map(|l| l.size_bytes()).sum();
        let t0 = Instant::now();
        // Upload inputs as Rust-owned device buffers and use `execute_b`.
        // NOTE: the crate's `execute(&[Literal])` path leaks every input
        // device buffer (its C++ shim `release()`s the uploads and never
        // frees them after Execute) — ~13 MB per train step. Owning the
        // `PjRtBuffer`s here lets Drop reclaim them (EXPERIMENTS.md §Perf).
        let mut buffers = Vec::with_capacity(args.len());
        for lit in args {
            buffers.push(
                inner
                    .client
                    .buffer_from_host_literal(None, lit)
                    .map_err(|e| anyhow!("upload {key:?}: {e}"))?,
            );
        }
        let result = exe
            .execute_b::<xla::PjRtBuffer>(&buffers)
            .map_err(|e| anyhow!("execute {key:?}: {e}"))?;
        let buffer = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| anyhow!("execute {key:?}: empty result"))?;
        let tuple = buffer
            .to_literal_sync()
            .map_err(|e| anyhow!("download {key:?}: {e}"))?;
        let busy = t0.elapsed().as_nanos() as u64;

        let mut tuple = tuple;
        let outputs = tuple
            .decompose_tuple()
            .map_err(|e| anyhow!("untuple {key:?}: {e}"))?;
        let bytes_out: usize = outputs.iter().map(|l| l.size_bytes()).sum();

        self.stats.transactions.fetch_add(1, Ordering::Relaxed);
        self.stats.bytes_in.fetch_add(bytes_in as u64, Ordering::Relaxed);
        self.stats.bytes_out.fetch_add(bytes_out as u64, Ordering::Relaxed);
        self.stats.busy_ns.fetch_add(busy, Ordering::Relaxed);
        Ok(outputs)
    }
}
