//! Serial golden reference for the native engine.
//!
//! This module preserves the engine's original single-threaded,
//! whole-batch, naive-kernel math *verbatim* and serves two purposes:
//!
//! 1. **Golden generator** — `tests/runtime_golden.rs` pins the full
//!    runtime pipeline (`QNet` → `Device` → pooled/tiled `NativeEngine`)
//!    bit-for-bit against the numbers this module produces. It replaces
//!    the retired python-generated `golden.json` pins (which required
//!    `make artifacts` plus the `--features xla` engine and therefore
//!    never ran offline).
//! 2. **Refactor anchor** — the sharded learner (rust/DESIGN.md §9) claims
//!    bit-identity with the serial math for every `learner_threads` value;
//!    this module *is* that serial math, kept free of pooling and tiling
//!    so the claim stays falsifiable.
//!
//! Nothing here is on the training hot path; only tests and the golden
//! tooling call it.

use anyhow::{bail, Result};

use super::engine::Head;
use super::kernels::{col2im_sample, im2col_sample, matmul_a_bt, matmul_acc, matmul_at_b_acc};
use super::native::{huber, huber_grad, NetArch, RMSPROP_ALPHA, RMSPROP_EPS};
use super::qnet::TrainBatch;

fn tensor<'a>(flat: &'a [f32], offsets: &[(usize, usize)], idx: usize) -> &'a [f32] {
    let (off, n) = offsets[idx];
    &flat[off..off + n]
}

/// Activations retained for the backward pass.
struct ForwardCache {
    /// Normalized input `[B, H, W, C]` (f32, /255).
    x0: Vec<f32>,
    /// Post-ReLU output of each conv layer, `[B, OH, OW, F]`.
    conv_out: Vec<Vec<f32>>,
    /// Post-ReLU output of each hidden layer, `[B, width]`.
    fc_out: Vec<Vec<f32>>,
    /// Q-values `[B, A]`.
    q: Vec<f32>,
}

/// Whole-batch forward pass with naive kernels (the original engine's).
fn forward(arch: &NetArch, flat: &[f32], states: &[u8], batch: usize, keep: bool) -> Result<ForwardCache> {
    if flat.len() != arch.param_count() {
        bail!("params: got {} values, want {}", flat.len(), arch.param_count());
    }
    let offs = arch.offsets();
    let [h0, w0, c0] = arch.frame;
    if states.len() != batch * h0 * w0 * c0 {
        bail!("states: got {} bytes, want {}", states.len(), batch * h0 * w0 * c0);
    }
    let x0: Vec<f32> = states.iter().map(|&v| v as f32 / 255.0).collect();
    let kept_x0 = if keep { x0.clone() } else { Vec::new() };

    let hw = arch.conv_out_hw();
    let mut conv_out: Vec<Vec<f32>> = Vec::with_capacity(arch.convs.len());
    let (mut h, mut w, mut c) = (h0, w0, c0);
    let mut x = x0;
    let mut tensor_idx = 0;
    for (i, conv) in arch.convs.iter().enumerate() {
        let (oh, ow) = hw[i];
        let kdim = conv.kernel * conv.kernel * c;
        let wmat = tensor(flat, &offs, tensor_idx); // [kdim, F]
        let bias = tensor(flat, &offs, tensor_idx + 1);
        tensor_idx += 2;
        let mut y = vec![0.0f32; batch * oh * ow * conv.filters];
        let mut patches = vec![0.0f32; oh * ow * kdim];
        for bi in 0..batch {
            im2col_sample(&x[bi * h * w * c..(bi + 1) * h * w * c], h, w, c, conv.kernel, conv.stride, &mut patches);
            let yrows = &mut y[bi * oh * ow * conv.filters..(bi + 1) * oh * ow * conv.filters];
            matmul_acc(&patches, wmat, yrows, oh * ow, kdim, conv.filters);
        }
        // Bias + ReLU in one pass.
        for (j, v) in y.iter_mut().enumerate() {
            let withb = *v + bias[j % conv.filters];
            *v = if withb > 0.0 { withb } else { 0.0 };
        }
        x = y;
        (h, w, c) = (oh, ow, conv.filters);
        if keep {
            conv_out.push(x.clone());
        }
    }

    // Hidden layers (x is now [B, dim]).
    let mut dim = h * w * c;
    let mut fc_out: Vec<Vec<f32>> = Vec::with_capacity(arch.hidden.len());
    for &width in arch.hidden.iter() {
        let wmat = tensor(flat, &offs, tensor_idx);
        let bias = tensor(flat, &offs, tensor_idx + 1);
        tensor_idx += 2;
        let mut y = vec![0.0f32; batch * width];
        matmul_acc(&x, wmat, &mut y, batch, dim, width);
        for (j, v) in y.iter_mut().enumerate() {
            let withb = *v + bias[j % width];
            *v = if withb > 0.0 { withb } else { 0.0 };
        }
        x = y;
        dim = width;
        if keep {
            fc_out.push(x.clone());
        }
    }

    // Output head (no activation).
    let wmat = tensor(flat, &offs, tensor_idx);
    let bias = tensor(flat, &offs, tensor_idx + 1);
    let mut q = vec![0.0f32; batch * arch.actions];
    matmul_acc(&x, wmat, &mut q, batch, dim, arch.actions);
    for (j, v) in q.iter_mut().enumerate() {
        *v += bias[j % arch.actions];
    }

    Ok(ForwardCache { x0: kept_x0, conv_out, fc_out, q })
}

/// Q-values only — the serial reference for the infer entry.
pub fn reference_infer(arch: &NetArch, params: &[f32], states: &[u8], batch: usize) -> Result<Vec<f32>> {
    Ok(forward(arch, params, states, batch, false)?.q)
}

/// TD loss + full parameter gradient — the serial reference for the train
/// entry minus the optimizer. Returns (grad, loss).
#[allow(clippy::too_many_arguments)]
pub fn reference_td_grads(
    arch: &NetArch,
    theta: &[f32],
    target_theta: &[f32],
    states: &[u8],
    actions: &[i32],
    rewards: &[f32],
    next_states: &[u8],
    dones: &[f32],
    gamma: f32,
    double: bool,
) -> Result<(Vec<f32>, f32)> {
    let batch = actions.len();
    let cache = forward(arch, theta, states, batch, true)?;
    let qn_target = forward(arch, target_theta, next_states, batch, false)?.q;
    let a = arch.actions;
    let offs = arch.offsets();

    // Bootstrap values (never differentiated — stop_gradient in the model).
    let mut bootstrap = vec![0.0f32; batch];
    if double {
        let qn_online = forward(arch, theta, next_states, batch, false)?.q;
        for b in 0..batch {
            let row = &qn_online[b * a..(b + 1) * a];
            let mut best = 0;
            for (i, &v) in row.iter().enumerate().skip(1) {
                if v > row[best] {
                    best = i;
                }
            }
            bootstrap[b] = qn_target[b * a + best];
        }
    } else {
        for b in 0..batch {
            bootstrap[b] = qn_target[b * a..(b + 1) * a].iter().copied().fold(f32::NEG_INFINITY, f32::max);
        }
    }

    // Per-sample TD error -> loss and dL/dq.
    let mut loss = 0.0f32;
    let mut dq = vec![0.0f32; batch * a];
    for b in 0..batch {
        let act = actions[b];
        if act < 0 || act as usize >= a {
            bail!("train: action {act} out of range 0..{a}");
        }
        let q_sel = cache.q[b * a + act as usize];
        let target = rewards[b] + gamma * (1.0 - dones[b]) * bootstrap[b];
        let d = q_sel - target;
        loss += huber(d);
        dq[b * a + act as usize] = huber_grad(d) / batch as f32;
    }
    loss /= batch as f32;

    // ---- backward ---------------------------------------------------------
    let mut grad = vec![0.0f32; arch.param_count()];
    let n_conv = arch.convs.len();
    let n_fc = arch.hidden.len();
    let hw = arch.conv_out_hw();
    let (last_h, last_w) = hw.last().copied().unwrap_or((arch.frame[0], arch.frame[1]));
    let last_c = arch.convs.last().map(|c| c.filters).unwrap_or(arch.frame[2]);
    let flat_dim = last_h * last_w * last_c;

    // Output head.
    let head_in: &[f32] = if n_fc > 0 { &cache.fc_out[n_fc - 1] } else { &cache.conv_out[n_conv - 1] };
    let head_dim = if n_fc > 0 { arch.hidden[n_fc - 1] } else { flat_dim };
    let widx = 2 * n_conv + 2 * n_fc; // out_w tensor index
    {
        let (off_w, n_w) = offs[widx];
        matmul_at_b_acc(head_in, &dq, &mut grad[off_w..off_w + n_w], batch, head_dim, a);
        let (off_b, _) = offs[widx + 1];
        for b in 0..batch {
            for j in 0..a {
                grad[off_b + j] += dq[b * a + j];
            }
        }
    }
    let out_w = tensor(theta, &offs, widx);
    let mut dx = vec![0.0f32; batch * head_dim];
    matmul_a_bt(&dq, out_w, &mut dx, batch, a, head_dim);

    // Hidden layers, reversed.
    for i in (0..n_fc).rev() {
        let width = arch.hidden[i];
        let post = &cache.fc_out[i];
        // ReLU mask.
        for (d, &v) in dx.iter_mut().zip(post.iter()) {
            if v <= 0.0 {
                *d = 0.0;
            }
        }
        let in_dim = if i > 0 { arch.hidden[i - 1] } else { flat_dim };
        let xin: &[f32] = if i > 0 { &cache.fc_out[i - 1] } else { &cache.conv_out[n_conv - 1] };
        let tidx = 2 * n_conv + 2 * i;
        let (off_w, n_w) = offs[tidx];
        matmul_at_b_acc(xin, &dx, &mut grad[off_w..off_w + n_w], batch, in_dim, width);
        let (off_b, _) = offs[tidx + 1];
        for b in 0..batch {
            for j in 0..width {
                grad[off_b + j] += dx[b * width + j];
            }
        }
        let wmat = tensor(theta, &offs, tidx);
        let mut dprev = vec![0.0f32; batch * in_dim];
        matmul_a_bt(&dx, wmat, &mut dprev, batch, width, in_dim);
        dx = dprev;
    }

    // Conv layers, reversed. dx currently holds d(conv_out[last]) [B,OH,OW,F].
    for i in (0..n_conv).rev() {
        let conv = arch.convs[i];
        let (oh, ow) = hw[i];
        let (in_h, in_w, in_c) = if i > 0 {
            (hw[i - 1].0, hw[i - 1].1, arch.convs[i - 1].filters)
        } else {
            (arch.frame[0], arch.frame[1], arch.frame[2])
        };
        let kdim = conv.kernel * conv.kernel * in_c;
        let f = conv.filters;
        let post = &cache.conv_out[i];
        for (d, &v) in dx.iter_mut().zip(post.iter()) {
            if v <= 0.0 {
                *d = 0.0;
            }
        }
        let tidx = 2 * i;
        let (off_w, n_w) = offs[tidx];
        let (off_b, _) = offs[tidx + 1];
        let wmat = tensor(theta, &offs, tidx);
        let xin_all: &[f32] = if i > 0 { &cache.conv_out[i - 1] } else { &cache.x0 };
        let in_sz = in_h * in_w * in_c;
        let need_dx = i > 0;
        let mut dprev = if need_dx { vec![0.0f32; batch * in_sz] } else { Vec::new() };
        let mut patches = vec![0.0f32; oh * ow * kdim];
        let mut dpatches = vec![0.0f32; oh * ow * kdim];
        for bi in 0..batch {
            let dy = &dx[bi * oh * ow * f..(bi + 1) * oh * ow * f];
            // grad_b
            for row in 0..oh * ow {
                for j in 0..f {
                    grad[off_b + j] += dy[row * f + j];
                }
            }
            // grad_w via recomputed patches
            im2col_sample(&xin_all[bi * in_sz..(bi + 1) * in_sz], in_h, in_w, in_c, conv.kernel, conv.stride, &mut patches);
            matmul_at_b_acc(&patches, dy, &mut grad[off_w..off_w + n_w], oh * ow, kdim, f);
            // d(input) for upstream layers
            if need_dx {
                matmul_a_bt(dy, wmat, &mut dpatches, oh * ow, f, kdim);
                col2im_sample(&dpatches, in_h, in_w, in_c, conv.kernel, conv.stride, &mut dprev[bi * in_sz..(bi + 1) * in_sz]);
            }
        }
        dx = dprev;
    }

    Ok((grad, loss))
}

// ---- Head-variant references (rust/DESIGN.md §16) -------------------------
//
// Written independently of `runtime/heads.rs` (whole-batch, naive im2col
// kernels, its own projection code) so the two implementations can check
// each other: `heads` pins its forward bitwise against these and its
// analytic gradients against finite differences of `reference_loss_head`.

/// Conv trunk only: flattened features `[B, trunk_dim]`, naive kernels.
fn conv_trunk(arch: &NetArch, flat: &[f32], states: &[u8], batch: usize) -> Result<Vec<f32>> {
    if flat.len() != arch.param_count() {
        bail!("params: got {} values, want {}", flat.len(), arch.param_count());
    }
    let offs = arch.offsets();
    let [h0, w0, c0] = arch.frame;
    if states.len() != batch * h0 * w0 * c0 {
        bail!("states: got {} bytes, want {}", states.len(), batch * h0 * w0 * c0);
    }
    let mut x: Vec<f32> = states.iter().map(|&v| v as f32 / 255.0).collect();
    let hw = arch.conv_out_hw();
    let (mut h, mut w, mut c) = (h0, w0, c0);
    for (i, conv) in arch.convs.iter().enumerate() {
        let (oh, ow) = hw[i];
        let kdim = conv.kernel * conv.kernel * c;
        let wmat = tensor(flat, &offs, 2 * i);
        let bias = tensor(flat, &offs, 2 * i + 1);
        let mut y = vec![0.0f32; batch * oh * ow * conv.filters];
        let mut patches = vec![0.0f32; oh * ow * kdim];
        for bi in 0..batch {
            im2col_sample(&x[bi * h * w * c..(bi + 1) * h * w * c], h, w, c, conv.kernel, conv.stride, &mut patches);
            let yrows = &mut y[bi * oh * ow * conv.filters..(bi + 1) * oh * ow * conv.filters];
            matmul_acc(&patches, wmat, yrows, oh * ow, kdim, conv.filters);
        }
        for (j, v) in y.iter_mut().enumerate() {
            let withb = *v + bias[j % conv.filters];
            *v = if withb > 0.0 { withb } else { 0.0 };
        }
        x = y;
        (h, w, c) = (oh, ow, conv.filters);
    }
    Ok(x)
}

/// One dense layer, whole batch: `y = x @ w + b`, optional ReLU.
fn dense_naive(x: &[f32], wmat: &[f32], bias: &[f32], batch: usize, in_dim: usize, out_dim: usize, relu: bool) -> Vec<f32> {
    let mut y = vec![0.0f32; batch * out_dim];
    matmul_acc(x, wmat, &mut y, batch, in_dim, out_dim);
    for (j, v) in y.iter_mut().enumerate() {
        let withb = *v + bias[j % out_dim];
        *v = if relu && withb <= 0.0 { 0.0 } else { withb };
    }
    y
}

/// C51 forward: (expected-value Q `[B, A]`, probabilities `[B, A*atoms]`).
fn c51_forward(arch: &NetArch, flat: &[f32], states: &[u8], batch: usize) -> Result<(Vec<f32>, Vec<f32>)> {
    let Head::C51 { atoms, v_min, v_max } = arch.head else {
        bail!("c51_forward on a {:?} head", arch.head);
    };
    let offs = arch.offsets();
    let mut x = conv_trunk(arch, flat, states, batch)?;
    let mut dim = x.len() / batch;
    let mut tidx = 2 * arch.convs.len();
    for &width in arch.hidden.iter() {
        x = dense_naive(&x, tensor(flat, &offs, tidx), tensor(flat, &offs, tidx + 1), batch, dim, width, true);
        dim = width;
        tidx += 2;
    }
    let a = arch.actions;
    let logits = dense_naive(&x, tensor(flat, &offs, tidx), tensor(flat, &offs, tidx + 1), batch, dim, a * atoms, false);
    let dz = (v_max - v_min) / (atoms as f32 - 1.0);
    let mut q = vec![0.0f32; batch * a];
    let mut probs = vec![0.0f32; batch * a * atoms];
    for ra in 0..batch * a {
        let lrow = &logits[ra * atoms..(ra + 1) * atoms];
        let prow = &mut probs[ra * atoms..(ra + 1) * atoms];
        let mut m = f32::NEG_INFINITY;
        for &v in lrow {
            if v > m {
                m = v;
            }
        }
        let mut sum = 0.0f32;
        for (pv, &v) in prow.iter_mut().zip(lrow.iter()) {
            *pv = (v - m).exp();
            sum += *pv;
        }
        let mut ev = 0.0f32;
        for (i, pv) in prow.iter_mut().enumerate() {
            *pv /= sum;
            ev += *pv * (v_min + dz * i as f32);
        }
        q[ra] = ev;
    }
    Ok((q, probs))
}

/// Serial whole-batch Q-values for any head — the infer oracle the head
/// subsystem pins against bitwise.
pub fn reference_infer_head(arch: &NetArch, params: &[f32], states: &[u8], batch: usize) -> Result<Vec<f32>> {
    match arch.head {
        Head::Dqn => reference_infer(arch, params, states, batch),
        Head::Dueling => {
            let offs = arch.offsets();
            let mut val = conv_trunk(arch, params, states, batch)?;
            let mut adv = val.clone();
            let mut dim = val.len() / batch;
            let mut tidx = 2 * arch.convs.len();
            for &width in arch.hidden.iter() {
                val = dense_naive(&val, tensor(params, &offs, tidx), tensor(params, &offs, tidx + 1), batch, dim, width, true);
                adv = dense_naive(&adv, tensor(params, &offs, tidx + 2), tensor(params, &offs, tidx + 3), batch, dim, width, true);
                dim = width;
                tidx += 4;
            }
            let a = arch.actions;
            let v = dense_naive(&val, tensor(params, &offs, tidx), tensor(params, &offs, tidx + 1), batch, dim, 1, false);
            let ad = dense_naive(&adv, tensor(params, &offs, tidx + 2), tensor(params, &offs, tidx + 3), batch, dim, a, false);
            let mut q = vec![0.0f32; batch * a];
            for b in 0..batch {
                let arow = &ad[b * a..(b + 1) * a];
                let mut mean = 0.0f32;
                for &av in arow {
                    mean += av;
                }
                mean /= a as f32;
                for (k, &av) in arow.iter().enumerate() {
                    q[b * a + k] = v[b] + av - mean;
                }
            }
            Ok(q)
        }
        Head::C51 { .. } => Ok(c51_forward(arch, params, states, batch)?.0),
    }
}

/// Mean training loss for any head (Huber TD for dqn/dueling, projected
/// cross-entropy for C51) — the finite-difference baseline for the head
/// subsystem's analytic gradients. Unweighted, scalar `gamma`.
#[allow(clippy::too_many_arguments)]
pub fn reference_loss_head(
    arch: &NetArch,
    theta: &[f32],
    target_theta: &[f32],
    states: &[u8],
    actions: &[i32],
    rewards: &[f32],
    next_states: &[u8],
    dones: &[f32],
    gamma: f32,
    double: bool,
) -> Result<f32> {
    let batch = actions.len();
    let a = arch.actions;
    let argmax = |qs: &[f32], b: usize| -> usize {
        let row = &qs[b * a..(b + 1) * a];
        let mut best = 0;
        for (i, &v) in row.iter().enumerate().skip(1) {
            if v > row[best] {
                best = i;
            }
        }
        best
    };
    match arch.head {
        Head::Dqn | Head::Dueling => {
            let q = reference_infer_head(arch, theta, states, batch)?;
            let qn_target = reference_infer_head(arch, target_theta, next_states, batch)?;
            let qn_online = if double {
                Some(reference_infer_head(arch, theta, next_states, batch)?)
            } else {
                None
            };
            let mut loss = 0.0f32;
            for b in 0..batch {
                let act = actions[b];
                if act < 0 || act as usize >= a {
                    bail!("train: action {act} out of range 0..{a}");
                }
                let bootstrap = match &qn_online {
                    Some(on) => qn_target[b * a + argmax(on, b)],
                    None => qn_target[b * a..(b + 1) * a].iter().copied().fold(f32::NEG_INFINITY, f32::max),
                };
                let target = rewards[b] + gamma * (1.0 - dones[b]) * bootstrap;
                loss += huber(q[b * a + act as usize] - target);
            }
            Ok(loss / batch as f32)
        }
        Head::C51 { atoms, v_min, v_max } => {
            let (_, probs) = c51_forward(arch, theta, states, batch)?;
            let (qn_target, probs_target) = c51_forward(arch, target_theta, next_states, batch)?;
            let qn_online = if double {
                Some(c51_forward(arch, theta, next_states, batch)?.0)
            } else {
                None
            };
            let dz = (v_max - v_min) / (atoms as f32 - 1.0);
            let mut loss = 0.0f32;
            for b in 0..batch {
                let act = actions[b];
                if act < 0 || act as usize >= a {
                    bail!("train: action {act} out of range 0..{a}");
                }
                let astar = match &qn_online {
                    Some(on) => argmax(on, b),
                    None => argmax(&qn_target, b),
                };
                let pt = &probs_target[(b * a + astar) * atoms..(b * a + astar + 1) * atoms];
                let scale = gamma * (1.0 - dones[b]);
                // Independent projection (not heads::project_distribution).
                let mut m = vec![0.0f32; atoms];
                for (j, &pj) in pt.iter().enumerate() {
                    let tz = (rewards[b] + scale * (v_min + dz * j as f32)).clamp(v_min, v_max);
                    let pos = ((tz - v_min) / dz).clamp(0.0, (atoms - 1) as f32);
                    let l = pos.floor() as usize;
                    let u = pos.ceil() as usize;
                    if l == u {
                        m[l] += pj;
                    } else {
                        m[l] += pj * (u as f32 - pos);
                        m[u] += pj * (pos - l as f32);
                    }
                }
                let p_sel = &probs[(b * a + act as usize) * atoms..(b * a + act as usize + 1) * atoms];
                for (mi, &pv) in m.iter().zip(p_sel.iter()) {
                    loss -= mi * pv.max(1e-12).ln();
                }
            }
            Ok(loss / batch as f32)
        }
    }
}

/// Outputs of one reference train step.
pub struct ReferenceTrainOut {
    pub theta: Vec<f32>,
    pub g: Vec<f32>,
    pub s: Vec<f32>,
    pub loss: f32,
}

/// Full serial train step (TD gradients + centered RMSProp), matching the
/// train entry's ABI semantics on host vectors.
#[allow(clippy::too_many_arguments)]
pub fn reference_train_step(
    arch: &NetArch,
    theta: &[f32],
    target_theta: &[f32],
    g: &[f32],
    s: &[f32],
    batch: &TrainBatch,
    gamma: f32,
    double: bool,
    lr: f32,
) -> Result<ReferenceTrainOut> {
    let (grad, loss) = reference_td_grads(
        arch,
        theta,
        target_theta,
        &batch.states,
        &batch.actions,
        &batch.rewards,
        &batch.next_states,
        &batch.dones,
        gamma,
        double,
    )?;
    let mut theta2 = theta.to_vec();
    let mut g2 = g.to_vec();
    let mut s2 = s.to_vec();
    for i in 0..theta2.len() {
        let gr = grad[i];
        g2[i] = RMSPROP_ALPHA * g2[i] + (1.0 - RMSPROP_ALPHA) * gr;
        s2[i] = RMSPROP_ALPHA * s2[i] + (1.0 - RMSPROP_ALPHA) * gr * gr;
        theta2[i] -= lr * gr / (s2[i] - g2[i] * g2[i] + RMSPROP_EPS).sqrt();
    }
    Ok(ReferenceTrainOut { theta: theta2, g: g2, s: s2, loss })
}
