//! Serial golden reference for the native engine.
//!
//! This module preserves the engine's original single-threaded,
//! whole-batch, naive-kernel math *verbatim* and serves two purposes:
//!
//! 1. **Golden generator** — `tests/runtime_golden.rs` pins the full
//!    runtime pipeline (`QNet` → `Device` → pooled/tiled `NativeEngine`)
//!    bit-for-bit against the numbers this module produces. It replaces
//!    the retired python-generated `golden.json` pins (which required
//!    `make artifacts` plus the `--features xla` engine and therefore
//!    never ran offline).
//! 2. **Refactor anchor** — the sharded learner (rust/DESIGN.md §9) claims
//!    bit-identity with the serial math for every `learner_threads` value;
//!    this module *is* that serial math, kept free of pooling and tiling
//!    so the claim stays falsifiable.
//!
//! Nothing here is on the training hot path; only tests and the golden
//! tooling call it.

use anyhow::{bail, Result};

use super::kernels::{col2im_sample, im2col_sample, matmul_a_bt, matmul_acc, matmul_at_b_acc};
use super::native::{huber, huber_grad, NetArch, RMSPROP_ALPHA, RMSPROP_EPS};
use super::qnet::TrainBatch;

fn tensor<'a>(flat: &'a [f32], offsets: &[(usize, usize)], idx: usize) -> &'a [f32] {
    let (off, n) = offsets[idx];
    &flat[off..off + n]
}

/// Activations retained for the backward pass.
struct ForwardCache {
    /// Normalized input `[B, H, W, C]` (f32, /255).
    x0: Vec<f32>,
    /// Post-ReLU output of each conv layer, `[B, OH, OW, F]`.
    conv_out: Vec<Vec<f32>>,
    /// Post-ReLU output of each hidden layer, `[B, width]`.
    fc_out: Vec<Vec<f32>>,
    /// Q-values `[B, A]`.
    q: Vec<f32>,
}

/// Whole-batch forward pass with naive kernels (the original engine's).
fn forward(arch: &NetArch, flat: &[f32], states: &[u8], batch: usize, keep: bool) -> Result<ForwardCache> {
    if flat.len() != arch.param_count() {
        bail!("params: got {} values, want {}", flat.len(), arch.param_count());
    }
    let offs = arch.offsets();
    let [h0, w0, c0] = arch.frame;
    if states.len() != batch * h0 * w0 * c0 {
        bail!("states: got {} bytes, want {}", states.len(), batch * h0 * w0 * c0);
    }
    let x0: Vec<f32> = states.iter().map(|&v| v as f32 / 255.0).collect();
    let kept_x0 = if keep { x0.clone() } else { Vec::new() };

    let hw = arch.conv_out_hw();
    let mut conv_out: Vec<Vec<f32>> = Vec::with_capacity(arch.convs.len());
    let (mut h, mut w, mut c) = (h0, w0, c0);
    let mut x = x0;
    let mut tensor_idx = 0;
    for (i, conv) in arch.convs.iter().enumerate() {
        let (oh, ow) = hw[i];
        let kdim = conv.kernel * conv.kernel * c;
        let wmat = tensor(flat, &offs, tensor_idx); // [kdim, F]
        let bias = tensor(flat, &offs, tensor_idx + 1);
        tensor_idx += 2;
        let mut y = vec![0.0f32; batch * oh * ow * conv.filters];
        let mut patches = vec![0.0f32; oh * ow * kdim];
        for bi in 0..batch {
            im2col_sample(&x[bi * h * w * c..(bi + 1) * h * w * c], h, w, c, conv.kernel, conv.stride, &mut patches);
            let yrows = &mut y[bi * oh * ow * conv.filters..(bi + 1) * oh * ow * conv.filters];
            matmul_acc(&patches, wmat, yrows, oh * ow, kdim, conv.filters);
        }
        // Bias + ReLU in one pass.
        for (j, v) in y.iter_mut().enumerate() {
            let withb = *v + bias[j % conv.filters];
            *v = if withb > 0.0 { withb } else { 0.0 };
        }
        x = y;
        (h, w, c) = (oh, ow, conv.filters);
        if keep {
            conv_out.push(x.clone());
        }
    }

    // Hidden layers (x is now [B, dim]).
    let mut dim = h * w * c;
    let mut fc_out: Vec<Vec<f32>> = Vec::with_capacity(arch.hidden.len());
    for &width in arch.hidden.iter() {
        let wmat = tensor(flat, &offs, tensor_idx);
        let bias = tensor(flat, &offs, tensor_idx + 1);
        tensor_idx += 2;
        let mut y = vec![0.0f32; batch * width];
        matmul_acc(&x, wmat, &mut y, batch, dim, width);
        for (j, v) in y.iter_mut().enumerate() {
            let withb = *v + bias[j % width];
            *v = if withb > 0.0 { withb } else { 0.0 };
        }
        x = y;
        dim = width;
        if keep {
            fc_out.push(x.clone());
        }
    }

    // Output head (no activation).
    let wmat = tensor(flat, &offs, tensor_idx);
    let bias = tensor(flat, &offs, tensor_idx + 1);
    let mut q = vec![0.0f32; batch * arch.actions];
    matmul_acc(&x, wmat, &mut q, batch, dim, arch.actions);
    for (j, v) in q.iter_mut().enumerate() {
        *v += bias[j % arch.actions];
    }

    Ok(ForwardCache { x0: kept_x0, conv_out, fc_out, q })
}

/// Q-values only — the serial reference for the infer entry.
pub fn reference_infer(arch: &NetArch, params: &[f32], states: &[u8], batch: usize) -> Result<Vec<f32>> {
    Ok(forward(arch, params, states, batch, false)?.q)
}

/// TD loss + full parameter gradient — the serial reference for the train
/// entry minus the optimizer. Returns (grad, loss).
#[allow(clippy::too_many_arguments)]
pub fn reference_td_grads(
    arch: &NetArch,
    theta: &[f32],
    target_theta: &[f32],
    states: &[u8],
    actions: &[i32],
    rewards: &[f32],
    next_states: &[u8],
    dones: &[f32],
    gamma: f32,
    double: bool,
) -> Result<(Vec<f32>, f32)> {
    let batch = actions.len();
    let cache = forward(arch, theta, states, batch, true)?;
    let qn_target = forward(arch, target_theta, next_states, batch, false)?.q;
    let a = arch.actions;
    let offs = arch.offsets();

    // Bootstrap values (never differentiated — stop_gradient in the model).
    let mut bootstrap = vec![0.0f32; batch];
    if double {
        let qn_online = forward(arch, theta, next_states, batch, false)?.q;
        for b in 0..batch {
            let row = &qn_online[b * a..(b + 1) * a];
            let mut best = 0;
            for (i, &v) in row.iter().enumerate().skip(1) {
                if v > row[best] {
                    best = i;
                }
            }
            bootstrap[b] = qn_target[b * a + best];
        }
    } else {
        for b in 0..batch {
            bootstrap[b] = qn_target[b * a..(b + 1) * a].iter().copied().fold(f32::NEG_INFINITY, f32::max);
        }
    }

    // Per-sample TD error -> loss and dL/dq.
    let mut loss = 0.0f32;
    let mut dq = vec![0.0f32; batch * a];
    for b in 0..batch {
        let act = actions[b];
        if act < 0 || act as usize >= a {
            bail!("train: action {act} out of range 0..{a}");
        }
        let q_sel = cache.q[b * a + act as usize];
        let target = rewards[b] + gamma * (1.0 - dones[b]) * bootstrap[b];
        let d = q_sel - target;
        loss += huber(d);
        dq[b * a + act as usize] = huber_grad(d) / batch as f32;
    }
    loss /= batch as f32;

    // ---- backward ---------------------------------------------------------
    let mut grad = vec![0.0f32; arch.param_count()];
    let n_conv = arch.convs.len();
    let n_fc = arch.hidden.len();
    let hw = arch.conv_out_hw();
    let (last_h, last_w) = hw.last().copied().unwrap_or((arch.frame[0], arch.frame[1]));
    let last_c = arch.convs.last().map(|c| c.filters).unwrap_or(arch.frame[2]);
    let flat_dim = last_h * last_w * last_c;

    // Output head.
    let head_in: &[f32] = if n_fc > 0 { &cache.fc_out[n_fc - 1] } else { &cache.conv_out[n_conv - 1] };
    let head_dim = if n_fc > 0 { arch.hidden[n_fc - 1] } else { flat_dim };
    let widx = 2 * n_conv + 2 * n_fc; // out_w tensor index
    {
        let (off_w, n_w) = offs[widx];
        matmul_at_b_acc(head_in, &dq, &mut grad[off_w..off_w + n_w], batch, head_dim, a);
        let (off_b, _) = offs[widx + 1];
        for b in 0..batch {
            for j in 0..a {
                grad[off_b + j] += dq[b * a + j];
            }
        }
    }
    let out_w = tensor(theta, &offs, widx);
    let mut dx = vec![0.0f32; batch * head_dim];
    matmul_a_bt(&dq, out_w, &mut dx, batch, a, head_dim);

    // Hidden layers, reversed.
    for i in (0..n_fc).rev() {
        let width = arch.hidden[i];
        let post = &cache.fc_out[i];
        // ReLU mask.
        for (d, &v) in dx.iter_mut().zip(post.iter()) {
            if v <= 0.0 {
                *d = 0.0;
            }
        }
        let in_dim = if i > 0 { arch.hidden[i - 1] } else { flat_dim };
        let xin: &[f32] = if i > 0 { &cache.fc_out[i - 1] } else { &cache.conv_out[n_conv - 1] };
        let tidx = 2 * n_conv + 2 * i;
        let (off_w, n_w) = offs[tidx];
        matmul_at_b_acc(xin, &dx, &mut grad[off_w..off_w + n_w], batch, in_dim, width);
        let (off_b, _) = offs[tidx + 1];
        for b in 0..batch {
            for j in 0..width {
                grad[off_b + j] += dx[b * width + j];
            }
        }
        let wmat = tensor(theta, &offs, tidx);
        let mut dprev = vec![0.0f32; batch * in_dim];
        matmul_a_bt(&dx, wmat, &mut dprev, batch, width, in_dim);
        dx = dprev;
    }

    // Conv layers, reversed. dx currently holds d(conv_out[last]) [B,OH,OW,F].
    for i in (0..n_conv).rev() {
        let conv = arch.convs[i];
        let (oh, ow) = hw[i];
        let (in_h, in_w, in_c) = if i > 0 {
            (hw[i - 1].0, hw[i - 1].1, arch.convs[i - 1].filters)
        } else {
            (arch.frame[0], arch.frame[1], arch.frame[2])
        };
        let kdim = conv.kernel * conv.kernel * in_c;
        let f = conv.filters;
        let post = &cache.conv_out[i];
        for (d, &v) in dx.iter_mut().zip(post.iter()) {
            if v <= 0.0 {
                *d = 0.0;
            }
        }
        let tidx = 2 * i;
        let (off_w, n_w) = offs[tidx];
        let (off_b, _) = offs[tidx + 1];
        let wmat = tensor(theta, &offs, tidx);
        let xin_all: &[f32] = if i > 0 { &cache.conv_out[i - 1] } else { &cache.x0 };
        let in_sz = in_h * in_w * in_c;
        let need_dx = i > 0;
        let mut dprev = if need_dx { vec![0.0f32; batch * in_sz] } else { Vec::new() };
        let mut patches = vec![0.0f32; oh * ow * kdim];
        let mut dpatches = vec![0.0f32; oh * ow * kdim];
        for bi in 0..batch {
            let dy = &dx[bi * oh * ow * f..(bi + 1) * oh * ow * f];
            // grad_b
            for row in 0..oh * ow {
                for j in 0..f {
                    grad[off_b + j] += dy[row * f + j];
                }
            }
            // grad_w via recomputed patches
            im2col_sample(&xin_all[bi * in_sz..(bi + 1) * in_sz], in_h, in_w, in_c, conv.kernel, conv.stride, &mut patches);
            matmul_at_b_acc(&patches, dy, &mut grad[off_w..off_w + n_w], oh * ow, kdim, f);
            // d(input) for upstream layers
            if need_dx {
                matmul_a_bt(dy, wmat, &mut dpatches, oh * ow, f, kdim);
                col2im_sample(&dpatches, in_h, in_w, in_c, conv.kernel, conv.stride, &mut dprev[bi * in_sz..(bi + 1) * in_sz]);
            }
        }
        dx = dprev;
    }

    Ok((grad, loss))
}

/// Outputs of one reference train step.
pub struct ReferenceTrainOut {
    pub theta: Vec<f32>,
    pub g: Vec<f32>,
    pub s: Vec<f32>,
    pub loss: f32,
}

/// Full serial train step (TD gradients + centered RMSProp), matching the
/// train entry's ABI semantics on host vectors.
#[allow(clippy::too_many_arguments)]
pub fn reference_train_step(
    arch: &NetArch,
    theta: &[f32],
    target_theta: &[f32],
    g: &[f32],
    s: &[f32],
    batch: &TrainBatch,
    gamma: f32,
    double: bool,
    lr: f32,
) -> Result<ReferenceTrainOut> {
    let (grad, loss) = reference_td_grads(
        arch,
        theta,
        target_theta,
        &batch.states,
        &batch.actions,
        &batch.rewards,
        &batch.next_states,
        &batch.dones,
        gamma,
        double,
    )?;
    let mut theta2 = theta.to_vec();
    let mut g2 = g.to_vec();
    let mut s2 = s.to_vec();
    for i in 0..theta2.len() {
        let gr = grad[i];
        g2[i] = RMSPROP_ALPHA * g2[i] + (1.0 - RMSPROP_ALPHA) * gr;
        s2[i] = RMSPROP_ALPHA * s2[i] + (1.0 - RMSPROP_ALPHA) * gr * gr;
        theta2[i] -= lr * gr / (s2[i] - g2[i] * g2[i] + RMSPROP_EPS).sqrt();
    }
    Ok(ReferenceTrainOut { theta: theta2, g: g2, s: s2, loss })
}
