//! `ComputePool`: the persistent learner thread pool.
//!
//! The native engine shards its minibatch math over this pool
//! (rust/DESIGN.md §9). Design constraints, in order:
//!
//! 1. **Determinism is owned by the caller, not the pool.** The pool makes
//!    no ordering promises beyond "every task runs exactly once and
//!    [`scope`] returns only after all of them finished". The engine only
//!    submits task sets whose outputs are bitwise independent of execution
//!    order (disjoint output slices, per-element reduction order fixed by
//!    construction), so any interleaving produces identical bits.
//! 2. **`threads = 1` is the serial engine.** No worker threads are
//!    spawned; `scope` runs the tasks inline, in submission order, on the
//!    caller — zero synchronization on the hot path.
//! 3. **Persistent workers.** `threads - 1` workers are spawned once at
//!    engine construction and live until drop; a training run issues
//!    hundreds of thousands of scopes, so per-scope thread spawning would
//!    dominate small-network train steps.
//!
//! Safety: tasks may borrow caller-stack data (`'t` lifetime). [`scope`]
//! erases the lifetime to hand boxes to the persistent workers, which is
//! sound because it blocks until the last task completed (a panicking task
//! still counts down before the panic is rethrown on the caller).
//!
//! [`scope`]: ComputePool::scope

use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

type Task = Box<dyn FnOnce() + Send + 'static>;

/// Queue shared between the submitting thread and the workers.
struct Queue {
    tasks: Mutex<VecDeque<Task>>,
    cv: Condvar,
    shutdown: AtomicBool,
}

impl Queue {
    /// Pop one task, blocking until one arrives or shutdown.
    fn pop_blocking(&self) -> Option<Task> {
        let mut q = self.tasks.lock().unwrap();
        loop {
            if let Some(t) = q.pop_front() {
                return Some(t);
            }
            if self.shutdown.load(Ordering::SeqCst) {
                return None;
            }
            let (guard, _) = self.cv.wait_timeout(q, Duration::from_millis(10)).unwrap();
            q = guard;
        }
    }

    /// Pop one task without blocking.
    fn try_pop(&self) -> Option<Task> {
        self.tasks.lock().unwrap().pop_front()
    }
}

/// Completion tracker for one `scope` call.
struct ScopeState {
    remaining: AtomicUsize,
    panicked: AtomicBool,
    lock: Mutex<()>,
    cv: Condvar,
}

impl ScopeState {
    fn finish_one(&self) {
        if self.remaining.fetch_sub(1, Ordering::SeqCst) == 1 {
            let _g = self.lock.lock().unwrap();
            self.cv.notify_all();
        }
    }
}

/// Persistent worker pool for the native engine's sharded learner.
pub struct ComputePool {
    threads: usize,
    queue: Arc<Queue>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl ComputePool {
    /// A pool of `threads` compute lanes: the caller plus `threads - 1`
    /// persistent workers. `threads = 1` (or 0) spawns nothing and runs
    /// every scope inline.
    pub fn new(threads: usize) -> ComputePool {
        let threads = threads.max(1);
        let queue = Arc::new(Queue {
            tasks: Mutex::new(VecDeque::new()),
            cv: Condvar::new(),
            shutdown: AtomicBool::new(false),
        });
        let workers = (1..threads)
            .map(|i| {
                let queue = queue.clone();
                std::thread::Builder::new()
                    .name(format!("learner-{i}"))
                    .spawn(move || {
                        while let Some(task) = queue.pop_blocking() {
                            task();
                        }
                    })
                    .expect("spawning learner pool worker")
            })
            .collect();
        ComputePool { threads, queue, workers }
    }

    /// Number of compute lanes (caller included).
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run every task exactly once, concurrently on the pool, and return
    /// once ALL have finished. The caller participates, so a 1-thread pool
    /// degenerates to running the tasks inline in submission order.
    ///
    /// Panics in a task are re-raised here after the remaining tasks
    /// completed (the scope never returns with borrows still live).
    pub fn scope<'t>(&self, tasks: Vec<Box<dyn FnOnce() + Send + 't>>) {
        if tasks.is_empty() {
            return;
        }
        if self.threads <= 1 || tasks.len() == 1 {
            for task in tasks {
                task();
            }
            return;
        }

        let state = Arc::new(ScopeState {
            remaining: AtomicUsize::new(tasks.len()),
            panicked: AtomicBool::new(false),
            lock: Mutex::new(()),
            cv: Condvar::new(),
        });
        {
            let mut q = self.queue.tasks.lock().unwrap();
            for task in tasks {
                let st = state.clone();
                let wrapped: Box<dyn FnOnce() + Send + 't> = Box::new(move || {
                    if catch_unwind(AssertUnwindSafe(task)).is_err() {
                        st.panicked.store(true, Ordering::SeqCst);
                    }
                    st.finish_one();
                });
                // SAFETY: only the lifetime is erased. Every wrapped task is
                // either executed or drained below before `scope` returns
                // (remaining reaches 0 first), so no borrow escapes 't.
                let wrapped: Task = unsafe {
                    Box::from_raw(Box::into_raw(wrapped) as *mut (dyn FnOnce() + Send + 'static))
                };
                q.push_back(wrapped);
            }
        }
        self.queue.cv.notify_all();

        // Work-steal on the caller until the scope completes.
        loop {
            if state.remaining.load(Ordering::SeqCst) == 0 {
                break;
            }
            if let Some(task) = self.queue.try_pop() {
                task();
                continue;
            }
            let g = state.lock.lock().unwrap();
            if state.remaining.load(Ordering::SeqCst) == 0 {
                break;
            }
            let _ = state.cv.wait_timeout(g, Duration::from_millis(1)).unwrap();
        }
        if state.panicked.load(Ordering::SeqCst) {
            panic!("a learner pool task panicked");
        }
    }

}

impl Drop for ComputePool {
    fn drop(&mut self) {
        self.queue.shutdown.store(true, Ordering::SeqCst);
        self.queue.cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// `0..len` split into at most `parts` contiguous ascending ranges.
pub fn split_ranges(len: usize, parts: usize) -> Vec<(usize, usize)> {
    let parts = parts.clamp(1, len.max(1));
    let base = len / parts;
    let rem = len % parts;
    let mut out = Vec::with_capacity(parts);
    let mut lo = 0;
    for i in 0..parts {
        let hi = lo + base + usize::from(i < rem);
        if hi > lo {
            out.push((lo, hi));
        }
        lo = hi;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn single_thread_runs_inline_in_order() {
        let pool = ComputePool::new(1);
        let order = Mutex::new(Vec::new());
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..8)
            .map(|i| {
                let order = &order;
                Box::new(move || order.lock().unwrap().push(i)) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
        assert_eq!(*order.lock().unwrap(), (0..8).collect::<Vec<_>>());
    }

    #[test]
    fn multi_thread_runs_every_task_once() {
        let pool = ComputePool::new(4);
        for _ in 0..50 {
            let hits = AtomicU64::new(0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..32)
                .map(|_| {
                    let hits = &hits;
                    Box::new(move || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(tasks);
            assert_eq!(hits.load(Ordering::SeqCst), 32);
        }
    }

    #[test]
    fn disjoint_mut_chunks_are_all_written() {
        let pool = ComputePool::new(3);
        let mut data = vec![0u64; 1024];
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = data
            .chunks_mut(100)
            .enumerate()
            .map(|(i, chunk)| {
                Box::new(move || {
                    for (j, v) in chunk.iter_mut().enumerate() {
                        *v = (i * 1000 + j) as u64;
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
        for (i, &v) in data.iter().enumerate() {
            assert_eq!(v, ((i / 100) * 1000 + i % 100) as u64);
        }
    }

    #[test]
    fn scope_is_reusable_and_blocks_until_done() {
        let pool = ComputePool::new(2);
        for round in 0..20u64 {
            let sum = AtomicU64::new(0);
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..10)
                .map(|i| {
                    let sum = &sum;
                    Box::new(move || {
                        sum.fetch_add(round * 10 + i, Ordering::SeqCst);
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(tasks);
            // Visible immediately after scope returns: the barrier held.
            assert_eq!(sum.load(Ordering::SeqCst), round * 100 + 45);
        }
    }

    #[test]
    fn task_panic_propagates_after_scope_drains() {
        let pool = ComputePool::new(2);
        let result = std::panic::catch_unwind(AssertUnwindSafe(|| {
            let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = (0..4)
                .map(|i| {
                    Box::new(move || {
                        if i == 2 {
                            panic!("boom");
                        }
                    }) as Box<dyn FnOnce() + Send + '_>
                })
                .collect();
            pool.scope(tasks);
        }));
        assert!(result.is_err(), "panic must propagate to the caller");
        // The pool must still be usable afterwards.
        let ok = AtomicU64::new(0);
        pool.scope(vec![Box::new(|| {
            ok.fetch_add(1, Ordering::SeqCst);
        }) as Box<dyn FnOnce() + Send + '_>]);
        assert_eq!(ok.load(Ordering::SeqCst), 1);
    }

    #[test]
    fn split_ranges_partitions_exactly() {
        assert_eq!(split_ranges(10, 3), vec![(0, 4), (4, 7), (7, 10)]);
        assert_eq!(split_ranges(2, 4), vec![(0, 1), (1, 2)]);
        assert_eq!(split_ranges(0, 4), Vec::<(usize, usize)>::new());
        let r = split_ranges(32, 4);
        assert_eq!(r, vec![(0, 8), (8, 16), (16, 24), (24, 32)]);
    }
}
