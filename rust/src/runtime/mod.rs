//! Runtime layer: loads and executes the AOT-compiled HLO artifacts via the
//! PJRT CPU client (the "GPU" of the paper's hardware model).
//!
//! Pipeline: `python/compile/aot.py` lowers the JAX/Pallas model to HLO text
//! -> `Manifest` describes the ABI -> `Device` compiles + executes ->
//! `QNet` owns parameter state and exposes infer / train / sync-target.

pub mod device;
pub mod manifest;
pub mod qnet;

pub use device::{BusSnapshot, BusStats, Device};
pub use manifest::{Dtype, Entry, InputSig, Manifest, NetSpec};
pub use qnet::{Policy, QNet, SharedLiteral, TrainBatch};

use std::path::PathBuf;

/// Locate the artifacts directory: `$TEMPO_ARTIFACTS` or `./artifacts`
/// relative to the workspace root.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("TEMPO_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Walk up from CWD looking for artifacts/manifest.json (tests run from
    // target dirs, examples from the repo root).
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
