//! Runtime layer: the pluggable execution engine behind the paper's
//! "one accelerator" hardware model.
//!
//! Pipeline: `Manifest` describes the network ABI (from `manifest.json`
//! when artifacts exist, synthesized otherwise) -> `Device` serializes
//! transactions onto one [`ExecutionEngine`] -> `QNet` owns parameter state
//! and exposes infer / train / sync-target. Engines:
//!
//! * [`native::NativeEngine`] — pure-Rust reference implementation
//!   (default; no artifacts or external deps needed).
//! * `xla_engine::XlaEngine` — PJRT executing the AOT-compiled HLO text
//!   from `python/compile/aot.py` (`--features xla`).
//!
//! rust/DESIGN.md §2 documents the seam and the trade-offs.

pub mod device;
pub mod engine;
pub mod golden;
pub mod heads;
pub mod kernels;
pub mod manifest;
pub mod native;
pub mod pool;
pub mod qnet;
pub mod tensor;
#[cfg(feature = "xla")]
pub mod xla_engine;

pub use device::{BusSnapshot, BusStats, Device};
pub use engine::{EntryField, EntryOp, EntrySchema, ExecutionEngine, Head};
pub use kernels::KernelMode;
pub use manifest::{Dtype, Entry, InputSig, Manifest, NetSpec};
pub use native::{NativeEngine, NetArch};
pub use pool::ComputePool;
pub use qnet::{Policy, QNet, QNetSnapshot, QNetTheta, TrainBatch, TrainOutcome};
pub use tensor::{DataVec, DataView, HostTensor, TensorView};

use std::path::PathBuf;

/// Locate the artifacts directory: `$TEMPO_ARTIFACTS` or `./artifacts`
/// relative to the workspace root. The directory is allowed to be absent —
/// `Manifest::load_or_builtin` then synthesizes the manifest for the
/// native engine.
pub fn default_artifact_dir() -> PathBuf {
    if let Ok(dir) = std::env::var("TEMPO_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    // Walk up from CWD looking for artifacts/manifest.json (tests run from
    // target dirs, examples from the repo root).
    let mut cur = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = cur.join("artifacts");
        if cand.join("manifest.json").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}
