//! Native execution engine: a pure-Rust reference implementation of the
//! compiled entry points, parallelized over the persistent [`ComputePool`].
//!
//! Mirrors `python/compile/model.py` operation-for-operation — VALID
//! convolutions, ReLU MLP head, mean-Huber TD loss (standard and Double-DQN
//! targets), hand-derived backprop, and the fused centered-RMSProp update
//! from `python/compile/kernels/ref.py` (alpha=0.95, eps=0.01). The conv
//! layers run **patch-free** (rust/DESIGN.md §13): the implicit-GEMM
//! kernels in `runtime/kernels.rs` walk the im2col geometry in place, so
//! no `[OH·OW, k²·C]` patch matrix is ever materialized — while preserving
//! each output element's accumulation order, so results are bit-identical
//! to the historical im2col+matmul pipeline (which `runtime/golden.rs`
//! still implements as the independent oracle).
//!
//! **Parallel determinism** (rust/DESIGN.md §9): the train entry runs in
//! two phases. Phase A shards the minibatch into contiguous sample ranges
//! and computes, per shard, everything that is per-sample (forward caches,
//! bootstrap targets, TD errors, backward deltas). Phase B partitions each
//! parameter tensor's *output elements* across the pool; every element
//! accumulates its cross-sample reduction in the fixed global sample order
//! with the same sparsity skips as the serial kernels. Because each output
//! element's f32 accumulation sequence never depends on the partitioning,
//! gradients are **bit-identical for every `learner_threads` value** — and
//! bit-identical to the serial golden reference (`runtime/golden.rs`),
//! which preserves the original whole-batch math. The hot matmuls are
//! cache-tiled (`runtime/kernels.rs`), also without changing any
//! per-element accumulation order.
//!
//! **Kernel modes** (rust/DESIGN.md §12): every dense kernel call goes
//! through the `matmul_*_mode` dispatchers, selected by the engine's
//! [`KernelMode`]. `Deterministic` (default) is the serial-order tiled
//! path above — bit-pinned against the golden reference. `Fast` swaps in
//! the lane-reordered kernels and, in Phase B, fuses cross-sample
//! reductions four rows at a time; the grouping is always relative to
//! *global* sample order (never shard boundaries), so fast mode is still
//! bit-identical across `learner_threads` — it diverges (boundedly) only
//! from the deterministic tier.
//!
//! This engine needs no artifacts: architecture comes from the manifest's
//! config name (the same three variants `model.make_config` defines), and
//! initial parameters use the same scheme (zero biases, uniform
//! ±1/sqrt(fan_in) weights) driven by the in-tree deterministic RNG.
//!
//! Memory note: inference runs patch-free — per sample the conv stack
//! touches only the `[H·W·C]` input and its activations, no im2col
//! scratch. The train entry retains the normalized input (`x0`) and the
//! per-layer activations/deltas so Phase B can re-walk samples in global
//! order; for the `nature` net that is ~112 KB per sample where the
//! retained patch matrices used to cost ~690 KB (a ~6× cut in the
//! minibatch working set). The engine recycles the per-step allocations
//! that remain — the retained `x0` buffers and the gradient staging
//! vector — through a persistent [`TrainScratch`] (buffer identity only;
//! contents are fully rewritten each step, so reuse is bitwise
//! invisible).

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::metrics::{TrainPhase, TrainTimers};
use crate::util::rng::Rng;

use super::engine::{EntryOp, EntrySchema, ExecutionEngine, Head};
use super::heads;
use super::kernels::{
    axpy4, conv2d_forward_mode, conv2d_input_grad_mode, conv2d_weight_grad_chunk_mode,
    matmul_a_bt_mode, matmul_acc_mode, KernelMode, FAST_LANES, FAST_RANK,
};
use super::manifest::NetSpec;
use super::pool::{split_ranges, ComputePool};
use super::tensor::{HostTensor, TensorView};

pub(crate) const RMSPROP_ALPHA: f32 = 0.95;
pub(crate) const RMSPROP_EPS: f32 = 0.01;

/// One conv layer: `filters` output channels, `kernel`×`kernel` window,
/// `stride` step, VALID padding (matches `model.ConvSpec`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvSpec {
    pub filters: usize,
    pub kernel: usize,
    pub stride: usize,
}

/// Architecture of one Q-network variant (matches `model.NetConfig`, plus
/// the head variant selecting the dense tail; rust/DESIGN.md §16).
#[derive(Clone, Debug, PartialEq)]
pub struct NetArch {
    pub name: String,
    pub frame: [usize; 3], // (H, W, stacked channels)
    pub convs: Vec<ConvSpec>,
    pub hidden: Vec<usize>,
    pub actions: usize,
    pub head: Head,
}

impl NetArch {
    /// The three supported architectures (`model.make_config`).
    pub fn by_name(name: &str, actions: usize) -> Result<NetArch> {
        let (convs, hidden): (Vec<ConvSpec>, Vec<usize>) = match name {
            "nature" => (
                vec![
                    ConvSpec { filters: 32, kernel: 8, stride: 4 },
                    ConvSpec { filters: 64, kernel: 4, stride: 2 },
                    ConvSpec { filters: 64, kernel: 3, stride: 1 },
                ],
                vec![512],
            ),
            "small" => (
                vec![
                    ConvSpec { filters: 16, kernel: 8, stride: 4 },
                    ConvSpec { filters: 32, kernel: 4, stride: 2 },
                ],
                vec![256],
            ),
            "tiny" => (vec![ConvSpec { filters: 4, kernel: 8, stride: 8 }], vec![64]),
            other => bail!("native engine knows no architecture named {other:?}"),
        };
        Ok(NetArch {
            name: name.to_string(),
            frame: [84, 84, 4],
            convs,
            hidden,
            actions,
            head: Head::Dqn,
        })
    }

    /// Resolve and cross-check the architecture for a manifest config
    /// (including its head — head variants change the dense tail and the
    /// flat parameter count, so the cross-check runs head-aware).
    pub fn from_spec(spec: &NetSpec) -> Result<NetArch> {
        let mut arch = Self::by_name(&spec.name, spec.actions)?;
        arch.head = spec.head;
        if arch.frame != spec.frame {
            bail!(
                "config {:?}: manifest frame {:?} != architecture frame {:?}",
                spec.name, spec.frame, arch.frame
            );
        }
        if arch.param_count() != spec.param_count {
            bail!(
                "config {:?}: manifest has {} params, architecture implies {}",
                spec.name, spec.param_count, arch.param_count()
            );
        }
        Ok(arch)
    }

    /// (OH, OW) after each conv layer.
    pub fn conv_out_hw(&self) -> Vec<(usize, usize)> {
        let [mut h, mut w, _] = self.frame;
        self.convs
            .iter()
            .map(|c| {
                h = (h - c.kernel) / c.stride + 1;
                w = (w - c.kernel) / c.stride + 1;
                (h, w)
            })
            .collect()
    }

    /// Flattened conv-trunk output dimension (input to the dense tail).
    pub(crate) fn trunk_dim(&self) -> usize {
        let c_out = self.convs.last().map(|c| c.filters).unwrap_or(self.frame[2]);
        let (h, w) = self.conv_out_hw().last().copied().unwrap_or((self.frame[0], self.frame[1]));
        h * w * c_out
    }

    /// Ordered (name, shape) list defining the flat parameter layout. The
    /// `dqn` arm is identical to `model.param_spec`; head variants append
    /// their own dense tails after the shared conv trunk (DESIGN.md §16).
    pub fn param_spec(&self) -> Vec<(String, Vec<usize>)> {
        let mut spec = Vec::new();
        let mut c_in = self.frame[2];
        for (i, conv) in self.convs.iter().enumerate() {
            spec.push((format!("conv{i}_w"), vec![conv.kernel, conv.kernel, c_in, conv.filters]));
            spec.push((format!("conv{i}_b"), vec![conv.filters]));
            c_in = conv.filters;
        }
        let mut dim = self.trunk_dim();
        match self.head {
            Head::Dqn => {
                for (i, &width) in self.hidden.iter().enumerate() {
                    spec.push((format!("fc{i}_w"), vec![dim, width]));
                    spec.push((format!("fc{i}_b"), vec![width]));
                    dim = width;
                }
                spec.push(("out_w".to_string(), vec![dim, self.actions]));
                spec.push(("out_b".to_string(), vec![self.actions]));
            }
            Head::Dueling => {
                // Two parallel streams off the trunk, same widths as the
                // dqn hidden stack, interleaved val/adv per layer.
                for (i, &width) in self.hidden.iter().enumerate() {
                    spec.push((format!("val{i}_w"), vec![dim, width]));
                    spec.push((format!("val{i}_b"), vec![width]));
                    spec.push((format!("adv{i}_w"), vec![dim, width]));
                    spec.push((format!("adv{i}_b"), vec![width]));
                    dim = width;
                }
                spec.push(("val_out_w".to_string(), vec![dim, 1]));
                spec.push(("val_out_b".to_string(), vec![1]));
                spec.push(("adv_out_w".to_string(), vec![dim, self.actions]));
                spec.push(("adv_out_b".to_string(), vec![self.actions]));
            }
            Head::C51 { atoms, .. } => {
                for (i, &width) in self.hidden.iter().enumerate() {
                    spec.push((format!("fc{i}_w"), vec![dim, width]));
                    spec.push((format!("fc{i}_b"), vec![width]));
                    dim = width;
                }
                spec.push(("out_w".to_string(), vec![dim, self.actions * atoms]));
                spec.push(("out_b".to_string(), vec![self.actions * atoms]));
            }
        }
        spec
    }

    pub fn param_count(&self) -> usize {
        self.param_spec().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Byte offsets of each tensor in the flat vector (shared with the
    /// golden reference so the layout logic exists exactly once).
    pub(crate) fn offsets(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut off = 0;
        for (_, shape) in self.param_spec() {
            let n: usize = shape.iter().product();
            out.push((off, n));
            off += n;
        }
        out
    }

    pub fn frame_elems(&self) -> usize {
        self.frame.iter().product()
    }
}

/// Deterministic initial parameters: zero biases, uniform ±1/sqrt(fan_in)
/// weights — the same scheme as `model.init_params`, driven by the in-tree
/// RNG (one independent stream per tensor, so layouts are stable).
pub fn init_params(arch: &NetArch, seed: u64) -> Vec<f32> {
    let mut flat = Vec::with_capacity(arch.param_count());
    for (idx, (name, shape)) in arch.param_spec().iter().enumerate() {
        let n: usize = shape.iter().product();
        if name.ends_with("_b") {
            flat.extend(std::iter::repeat(0.0f32).take(n));
        } else {
            let fan_in: usize = shape[..shape.len() - 1].iter().product();
            let bound = 1.0 / (fan_in as f32).sqrt();
            let mut rng = Rng::stream(seed, 0x1217 ^ idx as u64);
            flat.extend((0..n).map(|_| rng.range_f32(-bound, bound)));
        }
    }
    flat
}

pub(crate) fn huber(x: f32) -> f32 {
    let ax = x.abs();
    if ax <= 1.0 {
        0.5 * x * x
    } else {
        ax - 0.5
    }
}

pub(crate) fn huber_grad(x: f32) -> f32 {
    x.clamp(-1.0, 1.0)
}

/// Run `f`, attributing its duration to `phase` when timers are attached
/// (the `speedtest --breakdown` hook). Timing never touches the math.
#[inline]
fn timed<T>(timers: Option<&TrainTimers>, phase: TrainPhase, f: impl FnOnce() -> T) -> T {
    match timers {
        Some(t) => t.time(phase, f),
        None => f(),
    }
}

// ---------------------------------------------------------------------------
// Forward (per shard)
// ---------------------------------------------------------------------------

struct Params<'a> {
    flat: &'a [f32],
    offsets: Vec<(usize, usize)>,
}

impl<'a> Params<'a> {
    fn new(arch: &NetArch, flat: &'a [f32]) -> Result<Params<'a>> {
        if flat.len() != arch.param_count() {
            bail!("params: got {} values, want {}", flat.len(), arch.param_count());
        }
        Ok(Params { flat, offsets: arch.offsets() })
    }

    fn tensor(&self, idx: usize) -> &'a [f32] {
        let (off, n) = self.offsets[idx];
        &self.flat[off..off + n]
    }
}

/// Activations of one shard's forward pass (rows are the shard's samples).
struct Fwd {
    /// Normalized input `[rows, H, W, C]` (the /255 values); empty unless
    /// retained for the gradient phase — Phase B's conv0 weight gradients
    /// read the patch geometry directly out of it (no im2col buffer).
    x0: Vec<f32>,
    /// Post-ReLU output of each conv layer, `[rows, OH, OW, F]`.
    conv_out: Vec<Vec<f32>>,
    /// Post-ReLU output of each hidden layer, `[rows, width]`.
    fc_out: Vec<Vec<f32>>,
    /// Q-values `[rows, A]`.
    q: Vec<f32>,
}

/// Forward over `rows` consecutive samples, patch-free: each conv layer
/// runs the implicit-GEMM kernel per sample, so no im2col matrix is ever
/// materialized (bit-identical to the historical im2col+matmul pipeline;
/// rust/DESIGN.md §13). `keep` retains the normalized input and all
/// activations for backprop. `x0_recycle` donates a previously retained
/// input buffer so steady-state training reuses its capacity; contents
/// are fully rewritten, so recycling never changes a result bit.
#[allow(clippy::too_many_arguments)]
fn forward_shard(
    arch: &NetArch,
    p: &Params<'_>,
    states: &[u8],
    rows: usize,
    keep: bool,
    mode: KernelMode,
    x0_recycle: Vec<f32>,
    timers: Option<&TrainTimers>,
) -> Result<Fwd> {
    let [h0, w0, c0] = arch.frame;
    if states.len() != rows * h0 * w0 * c0 {
        bail!("states: got {} bytes, want {}", states.len(), rows * h0 * w0 * c0);
    }
    let mut x = x0_recycle;
    x.clear();
    x.extend(states.iter().map(|&v| v as f32 / 255.0));

    let hw = arch.conv_out_hw();
    let mut conv_out: Vec<Vec<f32>> = Vec::with_capacity(arch.convs.len());
    let mut x0_keep: Vec<f32> = Vec::new();
    let (mut h, mut w, mut c) = (h0, w0, c0);
    let mut tensor_idx = 0;
    let t_conv = timers.map(|_| std::time::Instant::now());
    for (i, conv) in arch.convs.iter().enumerate() {
        let (oh, ow) = hw[i];
        let wmat = p.tensor(tensor_idx); // [k*k*C, F]
        let bias = p.tensor(tensor_idx + 1);
        tensor_idx += 2;
        let in_sz = h * w * c;
        let out_sz = oh * ow * conv.filters;
        let mut y = vec![0.0f32; rows * out_sz];
        for bi in 0..rows {
            conv2d_forward_mode(
                mode,
                &x[bi * in_sz..(bi + 1) * in_sz],
                wmat,
                &mut y[bi * out_sz..(bi + 1) * out_sz],
                h,
                w,
                c,
                conv.kernel,
                conv.stride,
                conv.filters,
            );
        }
        // Bias + ReLU in one pass.
        for (j, v) in y.iter_mut().enumerate() {
            let withb = *v + bias[j % conv.filters];
            *v = if withb > 0.0 { withb } else { 0.0 };
        }
        if i == 0 && keep {
            x0_keep = std::mem::replace(&mut x, y);
        } else {
            x = y;
        }
        (h, w, c) = (oh, ow, conv.filters);
        if keep {
            conv_out.push(x.clone());
        }
    }
    if let (Some(tm), Some(t0)) = (timers, t_conv) {
        tm.record(TrainPhase::ConvForward, t0.elapsed().as_nanos() as u64);
    }

    // Hidden layers (x is now [rows, dim]).
    let t_dense = timers.map(|_| std::time::Instant::now());
    let mut dim = h * w * c;
    let mut fc_out: Vec<Vec<f32>> = Vec::with_capacity(arch.hidden.len());
    for &width in arch.hidden.iter() {
        let wmat = p.tensor(tensor_idx);
        let bias = p.tensor(tensor_idx + 1);
        tensor_idx += 2;
        let mut y = vec![0.0f32; rows * width];
        matmul_acc_mode(mode, &x, wmat, &mut y, rows, dim, width);
        for (j, v) in y.iter_mut().enumerate() {
            let withb = *v + bias[j % width];
            *v = if withb > 0.0 { withb } else { 0.0 };
        }
        x = y;
        dim = width;
        if keep {
            fc_out.push(x.clone());
        }
    }

    // Output head (no activation).
    let wmat = p.tensor(tensor_idx);
    let bias = p.tensor(tensor_idx + 1);
    let mut q = vec![0.0f32; rows * arch.actions];
    matmul_acc_mode(mode, &x, wmat, &mut q, rows, dim, arch.actions);
    for (j, v) in q.iter_mut().enumerate() {
        *v += bias[j % arch.actions];
    }
    if let (Some(tm), Some(t0)) = (timers, t_dense) {
        tm.record(TrainPhase::Dense, t0.elapsed().as_nanos() as u64);
    }

    Ok(Fwd { x0: x0_keep, conv_out, fc_out, q })
}

/// Q-values only, computed serially with the deterministic kernel tier
/// (tests, the golden-style references, and small batches).
pub fn infer(arch: &NetArch, params: &[f32], states: &[u8], batch: usize) -> Result<Vec<f32>> {
    let p = Params::new(arch, params)?;
    Ok(forward_shard(arch, &p, states, batch, false, KernelMode::Deterministic, Vec::new(), None)?.q)
}

/// Q-values with the batch sharded over the pool (bit-identical across
/// pool widths in either kernel mode: the forward pass is per-sample).
pub fn infer_pooled(
    arch: &NetArch,
    params: &[f32],
    states: &[u8],
    batch: usize,
    pool: &ComputePool,
    mode: KernelMode,
) -> Result<Vec<f32>> {
    let p = Params::new(arch, params)?;
    let frame = arch.frame_elems();
    if states.len() != batch * frame {
        bail!("states: got {} bytes, want {}", states.len(), batch * frame);
    }
    let ranges = split_ranges(batch, pool.threads());
    if ranges.len() <= 1 {
        return Ok(forward_shard(arch, &p, states, batch, false, mode, Vec::new(), None)?.q);
    }
    let a = arch.actions;
    let mut q = vec![0.0f32; batch * a];
    let mut errs: Vec<Option<String>> = Vec::with_capacity(ranges.len());
    errs.resize(ranges.len(), None);

    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let mut q_rest: &mut [f32] = &mut q;
    for ((lo, hi), err) in ranges.iter().copied().zip(errs.iter_mut()) {
        let (chunk, tail) = std::mem::take(&mut q_rest).split_at_mut((hi - lo) * a);
        q_rest = tail;
        let p = &p;
        let rows_states = &states[lo * frame..hi * frame];
        tasks.push(Box::new(move || {
            match forward_shard(arch, p, rows_states, hi - lo, false, mode, Vec::new(), None) {
                Ok(fwd) => chunk.copy_from_slice(&fwd.q),
                Err(e) => *err = Some(e.to_string()),
            }
        }));
    }
    pool.scope(tasks);
    if let Some(e) = errs.into_iter().flatten().next() {
        bail!("{e}");
    }
    Ok(q)
}

// ---------------------------------------------------------------------------
// Training: Phase A (per-sample work, sharded) + Phase B (per-parameter
// reductions in global sample order, partitioned)
// ---------------------------------------------------------------------------

/// Everything Phase A produces for one contiguous sample range.
#[derive(Default)]
struct ShardSlot {
    lo: usize,
    hi: usize,
    /// Normalized input `[rows, H, W, C]` (conv0's weight-gradient source).
    x0: Vec<f32>,
    conv_out: Vec<Vec<f32>>,
    fc_out: Vec<Vec<f32>>,
    /// dL/dq rows, already scaled by 1/batch (and the IS weight, when
    /// weighted).
    dq: Vec<f32>,
    /// Per-sample (weighted) Huber losses (summed in global order by the
    /// caller).
    losses: Vec<f32>,
    /// Raw per-sample TD errors `q(s,a) - target` (pre-weight; the
    /// proportional replay strategy's priority signal).
    td: Vec<f32>,
    /// Masked (post-ReLU) deltas per hidden layer, `[rows, width]`.
    dfc: Vec<Vec<f32>>,
    /// Masked deltas per conv layer, `[rows, OH, OW, F]`.
    dconv: Vec<Vec<f32>>,
    err: Option<String>,
}

impl ShardSlot {
    fn rows(&self) -> usize {
        self.hi - self.lo
    }
}

/// Phase A body for one shard: forward passes, TD errors, backward deltas.
#[allow(clippy::too_many_arguments)]
fn shard_phase_a(
    arch: &NetArch,
    p: &Params<'_>,
    pt: &Params<'_>,
    states: &[u8],
    actions: &[i32],
    rewards: &[f32],
    next_states: &[u8],
    dones: &[f32],
    gamma: f32,
    weights: Option<&[f32]>,
    boot_gammas: Option<&[f32]>,
    double: bool,
    batch_total: usize,
    mode: KernelMode,
    timers: Option<&TrainTimers>,
    slot: &mut ShardSlot,
) -> Result<()> {
    let rows = slot.rows();
    let (lo, hi) = (slot.lo, slot.hi);
    let frame = arch.frame_elems();
    let a = arch.actions;

    // Donate last step's retained input buffer back to the forward pass.
    let x0_recycle = std::mem::take(&mut slot.x0);
    let fwd =
        forward_shard(arch, p, &states[lo * frame..hi * frame], rows, true, mode, x0_recycle, timers)?;
    let next_rows = &next_states[lo * frame..hi * frame];
    let qn_target = forward_shard(arch, pt, next_rows, rows, false, mode, Vec::new(), timers)?.q;

    // Bootstrap values (never differentiated — stop_gradient in the model).
    let mut bootstrap = vec![0.0f32; rows];
    if double {
        let qn_online = forward_shard(arch, p, next_rows, rows, false, mode, Vec::new(), timers)?.q;
        for r in 0..rows {
            let row = &qn_online[r * a..(r + 1) * a];
            let mut best = 0;
            for (i, &v) in row.iter().enumerate().skip(1) {
                if v > row[best] {
                    best = i;
                }
            }
            bootstrap[r] = qn_target[r * a + best];
        }
    } else {
        for r in 0..rows {
            bootstrap[r] = qn_target[r * a..(r + 1) * a].iter().copied().fold(f32::NEG_INFINITY, f32::max);
        }
    }

    // Per-sample TD error -> per-sample loss and dL/dq. The unweighted
    // arm below is byte-for-byte the historical computation (the weighted
    // arm multiplies the IS weight in, and substitutes the per-sample
    // bootstrap discount γᵐ for the scalar γ — identical expression shape,
    // so `boot_gammas = [γ; B]` reproduces the scalar path bitwise).
    let mut dq = vec![0.0f32; rows * a];
    let mut losses = vec![0.0f32; rows];
    let mut td = vec![0.0f32; rows];
    for r in 0..rows {
        let b = lo + r;
        let act = actions[b];
        if act < 0 || act as usize >= a {
            bail!("train: action {act} out of range 0..{a}");
        }
        let q_sel = fwd.q[r * a + act as usize];
        let bg = boot_gammas.map_or(gamma, |g| g[b]);
        let target = rewards[b] + bg * (1.0 - dones[b]) * bootstrap[r];
        let d = q_sel - target;
        td[r] = d;
        match weights {
            None => {
                losses[r] = huber(d);
                dq[r * a + act as usize] = huber_grad(d) / batch_total as f32;
            }
            Some(ws) => {
                let w = ws[b];
                losses[r] = w * huber(d);
                dq[r * a + act as usize] = w * huber_grad(d) / batch_total as f32;
            }
        }
    }

    // ---- backward deltas (per-sample; weight grads come in Phase B) ------
    let n_conv = arch.convs.len();
    let n_fc = arch.hidden.len();
    let hw = arch.conv_out_hw();
    let (last_h, last_w) = hw.last().copied().unwrap_or((arch.frame[0], arch.frame[1]));
    let last_c = arch.convs.last().map(|c| c.filters).unwrap_or(arch.frame[2]);
    let flat_dim = last_h * last_w * last_c;
    let head_dim = if n_fc > 0 { arch.hidden[n_fc - 1] } else { flat_dim };

    let t_dense = timers.map(|_| std::time::Instant::now());
    let out_w = p.tensor(2 * n_conv + 2 * n_fc);
    let mut dx = vec![0.0f32; rows * head_dim];
    matmul_a_bt_mode(mode, &dq, out_w, &mut dx, rows, a, head_dim);

    let mut dfc: Vec<Vec<f32>> = vec![Vec::new(); n_fc];
    for i in (0..n_fc).rev() {
        let width = arch.hidden[i];
        let post = &fwd.fc_out[i];
        // ReLU mask.
        for (d, &v) in dx.iter_mut().zip(post.iter()) {
            if v <= 0.0 {
                *d = 0.0;
            }
        }
        let in_dim = if i > 0 { arch.hidden[i - 1] } else { flat_dim };
        let wmat = p.tensor(2 * n_conv + 2 * i);
        let mut dprev = vec![0.0f32; rows * in_dim];
        matmul_a_bt_mode(mode, &dx, wmat, &mut dprev, rows, width, in_dim);
        dfc[i] = std::mem::replace(&mut dx, dprev);
    }
    if let (Some(tm), Some(t0)) = (timers, t_dense) {
        tm.record(TrainPhase::Dense, t0.elapsed().as_nanos() as u64);
    }

    // dx now holds d(conv_out[last]) as [rows, OH, OW, F]. Input gradients
    // run patch-free: no dpatches staging, no col2im scatter — the
    // implicit-GEMM kernel adds the identical dot products in the
    // identical scatter order (rust/DESIGN.md §13).
    let t_conv = timers.map(|_| std::time::Instant::now());
    let mut dconv: Vec<Vec<f32>> = vec![Vec::new(); n_conv];
    for i in (0..n_conv).rev() {
        let conv = arch.convs[i];
        let (oh, ow) = hw[i];
        let (in_h, in_w, in_c) = if i > 0 {
            (hw[i - 1].0, hw[i - 1].1, arch.convs[i - 1].filters)
        } else {
            (arch.frame[0], arch.frame[1], arch.frame[2])
        };
        let f = conv.filters;
        let post = &fwd.conv_out[i];
        for (d, &v) in dx.iter_mut().zip(post.iter()) {
            if v <= 0.0 {
                *d = 0.0;
            }
        }
        let need_dx = i > 0;
        let wmat = p.tensor(2 * i);
        let in_sz = in_h * in_w * in_c;
        let mut dprev = if need_dx { vec![0.0f32; rows * in_sz] } else { Vec::new() };
        if need_dx {
            for bi in 0..rows {
                let dy = &dx[bi * oh * ow * f..(bi + 1) * oh * ow * f];
                conv2d_input_grad_mode(
                    mode,
                    dy,
                    wmat,
                    &mut dprev[bi * in_sz..(bi + 1) * in_sz],
                    in_h,
                    in_w,
                    in_c,
                    conv.kernel,
                    conv.stride,
                    f,
                );
            }
        }
        dconv[i] = std::mem::replace(&mut dx, dprev);
    }
    if let (Some(tm), Some(t0)) = (timers, t_conv) {
        tm.record(TrainPhase::ConvBackward, t0.elapsed().as_nanos() as u64);
    }

    slot.x0 = fwd.x0;
    slot.conv_out = fwd.conv_out;
    slot.fc_out = fwd.fc_out;
    slot.dq = dq;
    slot.losses = losses;
    slot.td = td;
    slot.dfc = dfc;
    slot.dconv = dconv;
    Ok(())
}

/// Fast Phase-B reduction for dense-layer weight gradients: `chunk` holds
/// output rows `k_lo..k_hi` of a `[in_dim, width]` gradient; `xrows` and
/// `drows` are per-sample activation/delta rows **in global sample order**
/// (gathered across shard slots by the caller, so the [`FAST_RANK`]-wide
/// grouping never depends on where shard boundaries fall).
pub(crate) fn fast_weight_chunk(
    chunk: &mut [f32],
    width: usize,
    k_lo: usize,
    k_hi: usize,
    xrows: &[&[f32]],
    drows: &[&[f32]],
) {
    let b = xrows.len();
    let mut s = 0;
    while s + FAST_RANK <= b {
        let (x0, x1, x2, x3) = (xrows[s], xrows[s + 1], xrows[s + 2], xrows[s + 3]);
        let (d0, d1, d2, d3) = (drows[s], drows[s + 1], drows[s + 2], drows[s + 3]);
        for kk in k_lo..k_hi {
            let c = [x0[kk], x1[kk], x2[kk], x3[kk]];
            if c != [0.0; FAST_RANK] {
                axpy4(&mut chunk[(kk - k_lo) * width..(kk - k_lo + 1) * width], c, d0, d1, d2, d3);
            }
        }
        s += FAST_RANK;
    }
    for r in s..b {
        let (xrow, drow) = (xrows[r], drows[r]);
        for kk in k_lo..k_hi {
            let av = xrow[kk];
            if av == 0.0 {
                continue;
            }
            let orow = &mut chunk[(kk - k_lo) * width..(kk - k_lo + 1) * width];
            for (o, &dv) in orow.iter_mut().zip(drow.iter()) {
                *o += av * dv;
            }
        }
    }
}

/// Reusable cross-step buffers for [`td_grads_opts`]: the Phase A shard
/// slots (whose retained normalized-input buffers recycle across steps)
/// and the gradient staging vector. Contents are fully rewritten each
/// step — only capacity is carried over — so a shared scratch is bitwise
/// indistinguishable from a fresh one (pinned in this module's tests and
/// by the golden pipeline test). Optionally carries [`TrainTimers`] that
/// the train path attributes its phases to (`speedtest --breakdown`).
#[derive(Default)]
pub struct TrainScratch {
    slots: Vec<ShardSlot>,
    grad: Vec<f32>,
    timers: Option<Arc<TrainTimers>>,
}

impl TrainScratch {
    /// Hand a gradient vector's capacity back for the next step (the
    /// engine calls this after the optimizer has consumed the gradient).
    pub fn recycle_grad(&mut self, grad: Vec<f32>) {
        self.grad = grad;
    }

    /// Attach per-phase timers; every subsequent [`td_grads_opts`] call
    /// through this scratch records into them. Sharded phases accumulate
    /// per-worker durations (aggregate CPU time, not wall-clock).
    pub fn set_timers(&mut self, timers: Arc<TrainTimers>) {
        self.timers = Some(timers);
    }
}

/// TD loss + full parameter gradient (the train entry minus the optimizer),
/// sharded over `pool`, with the deterministic kernel tier and one-shot
/// scratch. Returns (grad, loss, per-sample TD errors). With
/// `weights`/`boot_gammas` absent this is bit-identical to
/// `golden::reference_td_grads` for every pool width — see the module docs
/// for why the two-phase split preserves the serial accumulation order.
/// `weights` scales each sample's loss/gradient (PER importance sampling);
/// `boot_gammas` substitutes a per-sample bootstrap discount γᵐ for the
/// entry's scalar γ (n-step returns, rust/DESIGN.md §11).
#[allow(clippy::too_many_arguments)]
pub fn td_grads(
    arch: &NetArch,
    theta: &[f32],
    target_theta: &[f32],
    states: &[u8],
    actions: &[i32],
    rewards: &[f32],
    next_states: &[u8],
    dones: &[f32],
    gamma: f32,
    weights: Option<&[f32]>,
    boot_gammas: Option<&[f32]>,
    double: bool,
    pool: &ComputePool,
) -> Result<(Vec<f32>, f32, Vec<f32>)> {
    let mut scratch = TrainScratch::default();
    td_grads_opts(
        arch, theta, target_theta, states, actions, rewards, next_states, dones, gamma, weights,
        boot_gammas, double, pool, KernelMode::Deterministic, &mut scratch,
    )
}

/// [`td_grads`] with an explicit kernel mode and persistent scratch (the
/// engine's entry point). In `Fast` mode the Phase B reductions group
/// rows/samples in [`FAST_RANK`]-wide blocks of the *global* order, so
/// results remain bit-identical across pool widths.
#[allow(clippy::too_many_arguments)]
pub fn td_grads_opts(
    arch: &NetArch,
    theta: &[f32],
    target_theta: &[f32],
    states: &[u8],
    actions: &[i32],
    rewards: &[f32],
    next_states: &[u8],
    dones: &[f32],
    gamma: f32,
    weights: Option<&[f32]>,
    boot_gammas: Option<&[f32]>,
    double: bool,
    pool: &ComputePool,
    mode: KernelMode,
    scratch: &mut TrainScratch,
) -> Result<(Vec<f32>, f32, Vec<f32>)> {
    let batch = actions.len();
    if batch == 0 {
        bail!("train: empty minibatch");
    }
    if let Some(w) = weights {
        if w.len() != batch {
            bail!("train: {} weights for a {batch}-sample minibatch", w.len());
        }
    }
    if let Some(g) = boot_gammas {
        if g.len() != batch {
            bail!("train: {} bootstrap discounts for a {batch}-sample minibatch", g.len());
        }
    }
    let p = Params::new(arch, theta)?;
    let pt = Params::new(arch, target_theta)?;
    let timers_arc = scratch.timers.clone();
    let timers: Option<&TrainTimers> = timers_arc.as_deref();

    // ---- Phase A: per-sample work over contiguous shards -----------------
    // Shard slots come from the scratch so their retained input buffers
    // (and any other capacity) survive across steps.
    let ranges = split_ranges(batch, pool.threads());
    scratch.slots.resize_with(ranges.len(), ShardSlot::default);
    let slots: &mut [ShardSlot] = &mut scratch.slots;
    for (slot, (lo, hi)) in slots.iter_mut().zip(ranges) {
        slot.lo = lo;
        slot.hi = hi;
        slot.err = None;
    }
    {
        let p = &p;
        let pt = &pt;
        let tasks: Vec<Box<dyn FnOnce() + Send + '_>> = slots
            .iter_mut()
            .map(|slot| {
                Box::new(move || {
                    if let Err(e) = shard_phase_a(
                        arch, p, pt, states, actions, rewards, next_states, dones, gamma,
                        weights, boot_gammas, double, batch, mode, timers, slot,
                    ) {
                        slot.err = Some(e.to_string());
                    }
                }) as Box<dyn FnOnce() + Send + '_>
            })
            .collect();
        pool.scope(tasks);
    }
    for slot in slots.iter() {
        if let Some(e) = &slot.err {
            bail!("{e}");
        }
    }

    // Mean loss, summed in global sample order (identical to the serial
    // whole-batch accumulation: shards are contiguous and ascending).
    let mut loss = 0.0f32;
    for slot in slots.iter() {
        for &l in &slot.losses {
            loss += l;
        }
    }
    loss /= batch as f32;

    // Per-sample TD errors, stitched back in global order.
    let mut td_all = vec![0.0f32; batch];
    for slot in slots.iter() {
        td_all[slot.lo..slot.hi].copy_from_slice(&slot.td);
    }

    // ---- Phase B: parameter reductions in global sample order ------------
    // Each task owns a disjoint row range of one tensor and walks ALL
    // samples in ascending order, so every grad element's accumulation
    // sequence is exactly the serial kernel's regardless of partitioning.
    let n_conv = arch.convs.len();
    let n_fc = arch.hidden.len();
    let hw = arch.conv_out_hw();
    let (last_h, last_w) = hw.last().copied().unwrap_or((arch.frame[0], arch.frame[1]));
    let last_c = arch.convs.last().map(|c| c.filters).unwrap_or(arch.frame[2]);
    let flat_dim = last_h * last_w * last_c;
    let head_dim = if n_fc > 0 { arch.hidden[n_fc - 1] } else { flat_dim };
    let a = arch.actions;
    let threads = pool.threads();

    // Gradient staging reuses the scratch vector's capacity; clear+resize
    // rewrites every element to 0.0, so history never leaks into a result.
    let mut grad = std::mem::take(&mut scratch.grad);
    grad.clear();
    grad.resize(arch.param_count(), 0.0);
    let mut tensor_slices: Vec<&mut [f32]> = Vec::new();
    {
        let mut rest: &mut [f32] = &mut grad;
        for (_, shape) in arch.param_spec() {
            let n: usize = shape.iter().product();
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(n);
            tensor_slices.push(head);
            rest = tail;
        }
    }

    let slots_ref: &[ShardSlot] = slots;
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::new();
    let mut slice_iter = tensor_slices.into_iter();

    // Conv layers: weight [kdim, F] chunked over kdim rows, bias [F] whole.
    // Weight gradients read the patch geometry straight out of the layer's
    // retained input (x0 for conv0, the previous conv's activations after
    // that) — no retained patch matrices. The implicit-GEMM chunk kernels
    // reproduce the retained-patch accumulation orders exactly, per tier:
    // ascending kk with the sparsity skip (deterministic), patch rows
    // grouped FAST_RANK-wide *within the sample* (fast — independent of
    // shard layout, so fast mode stays width-invariant).
    for i in 0..n_conv {
        let conv = arch.convs[i];
        let (oh, ow) = hw[i];
        let f = conv.filters;
        let (in_h, in_w, in_c) = if i > 0 {
            (hw[i - 1].0, hw[i - 1].1, arch.convs[i - 1].filters)
        } else {
            (arch.frame[0], arch.frame[1], arch.frame[2])
        };
        let kdim = conv.kernel * conv.kernel * in_c;
        let in_sz = in_h * in_w * in_c;
        let wslice = slice_iter.next().unwrap();
        let bslice = slice_iter.next().unwrap();

        let chunk_rows = kdim.div_ceil(threads);
        let mut k_lo = 0;
        for chunk in wslice.chunks_mut(chunk_rows * f) {
            let k_hi = k_lo + chunk.len() / f;
            tasks.push(Box::new(move || {
                timed(timers, TrainPhase::ConvBackward, || {
                    for slot in slots_ref {
                        let rows = slot.rows();
                        let dcv = &slot.dconv[i];
                        let xin: &[f32] =
                            if i > 0 { &slot.conv_out[i - 1] } else { &slot.x0 };
                        for bi in 0..rows {
                            let dy = &dcv[bi * oh * ow * f..(bi + 1) * oh * ow * f];
                            let xs = &xin[bi * in_sz..(bi + 1) * in_sz];
                            conv2d_weight_grad_chunk_mode(
                                mode,
                                xs,
                                dy,
                                chunk,
                                k_lo,
                                k_hi,
                                in_h,
                                in_w,
                                in_c,
                                conv.kernel,
                                conv.stride,
                                f,
                            );
                        }
                    }
                })
            }));
            k_lo = k_hi;
        }
        tasks.push(Box::new(move || {
            timed(timers, TrainPhase::ConvBackward, || {
                for slot in slots_ref {
                    let rows = slot.rows();
                    let dcv = &slot.dconv[i];
                    for bi in 0..rows {
                        let dy = &dcv[bi * oh * ow * f..(bi + 1) * oh * ow * f];
                        for row in 0..oh * ow {
                            for (o, &dv) in
                                bslice.iter_mut().zip(dy[row * f..(row + 1) * f].iter())
                            {
                                *o += dv;
                            }
                        }
                    }
                }
            })
        }));
    }

    // Hidden layers: weight [in_dim, width] chunked over in_dim rows.
    for i in 0..n_fc {
        let width = arch.hidden[i];
        let in_dim = if i > 0 { arch.hidden[i - 1] } else { flat_dim };
        let wslice = slice_iter.next().unwrap();
        let bslice = slice_iter.next().unwrap();

        let chunk_rows = in_dim.div_ceil(threads);
        let mut k_lo = 0;
        for chunk in wslice.chunks_mut(chunk_rows * width) {
            let k_hi = k_lo + chunk.len() / width;
            tasks.push(Box::new(move || {
                timed(timers, TrainPhase::Dense, || match mode {
                    KernelMode::Deterministic => {
                        for slot in slots_ref {
                            let rows = slot.rows();
                            let xin: &[f32] = if i > 0 {
                                &slot.fc_out[i - 1]
                            } else {
                                &slot.conv_out[n_conv - 1]
                            };
                            let dxl = &slot.dfc[i];
                            for r in 0..rows {
                                let xrow = &xin[r * in_dim..(r + 1) * in_dim];
                                let drow = &dxl[r * width..(r + 1) * width];
                                for kk in k_lo..k_hi {
                                    let av = xrow[kk];
                                    if av == 0.0 {
                                        continue;
                                    }
                                    let orow =
                                        &mut chunk[(kk - k_lo) * width..(kk - k_lo + 1) * width];
                                    for (o, &dv) in orow.iter_mut().zip(drow.iter()) {
                                        *o += av * dv;
                                    }
                                }
                            }
                        }
                    }
                    KernelMode::Fast => {
                        let xrows: Vec<&[f32]> = slots_ref
                            .iter()
                            .flat_map(|slot| {
                                let xin: &[f32] = if i > 0 {
                                    &slot.fc_out[i - 1]
                                } else {
                                    &slot.conv_out[n_conv - 1]
                                };
                                (0..slot.rows()).map(move |r| &xin[r * in_dim..(r + 1) * in_dim])
                            })
                            .collect();
                        let drows: Vec<&[f32]> = slots_ref
                            .iter()
                            .flat_map(|slot| {
                                let dxl: &[f32] = &slot.dfc[i];
                                (0..slot.rows()).map(move |r| &dxl[r * width..(r + 1) * width])
                            })
                            .collect();
                        fast_weight_chunk(chunk, width, k_lo, k_hi, &xrows, &drows);
                    }
                })
            }));
            k_lo = k_hi;
        }
        tasks.push(Box::new(move || {
            timed(timers, TrainPhase::Dense, || {
                for slot in slots_ref {
                    let rows = slot.rows();
                    let dxl = &slot.dfc[i];
                    for r in 0..rows {
                        for (o, &dv) in
                            bslice.iter_mut().zip(dxl[r * width..(r + 1) * width].iter())
                        {
                            *o += dv;
                        }
                    }
                }
            })
        }));
    }

    // Output head: weight [head_dim, A] chunked over head_dim rows.
    {
        let wslice = slice_iter.next().unwrap();
        let bslice = slice_iter.next().unwrap();
        let chunk_rows = head_dim.div_ceil(threads);
        let mut k_lo = 0;
        for chunk in wslice.chunks_mut(chunk_rows * a) {
            let k_hi = k_lo + chunk.len() / a;
            tasks.push(Box::new(move || {
                timed(timers, TrainPhase::Dense, || match mode {
                    KernelMode::Deterministic => {
                        for slot in slots_ref {
                            let rows = slot.rows();
                            let xin: &[f32] = if n_fc > 0 {
                                &slot.fc_out[n_fc - 1]
                            } else {
                                &slot.conv_out[n_conv - 1]
                            };
                            for r in 0..rows {
                                let xrow = &xin[r * head_dim..(r + 1) * head_dim];
                                let drow = &slot.dq[r * a..(r + 1) * a];
                                for kk in k_lo..k_hi {
                                    let av = xrow[kk];
                                    if av == 0.0 {
                                        continue;
                                    }
                                    let orow = &mut chunk[(kk - k_lo) * a..(kk - k_lo + 1) * a];
                                    for (o, &dv) in orow.iter_mut().zip(drow.iter()) {
                                        *o += av * dv;
                                    }
                                }
                            }
                        }
                    }
                    KernelMode::Fast => {
                        let xrows: Vec<&[f32]> = slots_ref
                            .iter()
                            .flat_map(|slot| {
                                let xin: &[f32] = if n_fc > 0 {
                                    &slot.fc_out[n_fc - 1]
                                } else {
                                    &slot.conv_out[n_conv - 1]
                                };
                                (0..slot.rows())
                                    .map(move |r| &xin[r * head_dim..(r + 1) * head_dim])
                            })
                            .collect();
                        let drows: Vec<&[f32]> = slots_ref
                            .iter()
                            .flat_map(|slot| {
                                (0..slot.rows()).map(move |r| &slot.dq[r * a..(r + 1) * a])
                            })
                            .collect();
                        fast_weight_chunk(chunk, a, k_lo, k_hi, &xrows, &drows);
                    }
                })
            }));
            k_lo = k_hi;
        }
        tasks.push(Box::new(move || {
            timed(timers, TrainPhase::Dense, || {
                for slot in slots_ref {
                    let rows = slot.rows();
                    for r in 0..rows {
                        for (o, &dv) in bslice.iter_mut().zip(slot.dq[r * a..(r + 1) * a].iter()) {
                            *o += dv;
                        }
                    }
                }
            })
        }));
    }
    pool.scope(tasks);

    Ok((grad, loss, td_all))
}

// ---------------------------------------------------------------------------
// Optimizer
// ---------------------------------------------------------------------------

/// Centered RMSProp (the L1 fused kernel's semantics, `rmsprop_ref`).
fn rmsprop(theta: &mut [f32], grad: &[f32], g: &mut [f32], s: &mut [f32], lr: f32) {
    for i in 0..theta.len() {
        let gr = grad[i];
        g[i] = RMSPROP_ALPHA * g[i] + (1.0 - RMSPROP_ALPHA) * gr;
        s[i] = RMSPROP_ALPHA * s[i] + (1.0 - RMSPROP_ALPHA) * gr * gr;
        theta[i] -= lr * gr / (s[i] - g[i] * g[i] + RMSPROP_EPS).sqrt();
    }
}

/// [`rmsprop`] with the body [`FAST_LANES`]-wide unrolled: the update is
/// elementwise and every element evaluates the identical expression, so
/// this is **bit-identical** to the serial loop (pinned in tests) — the
/// unroll exists purely to hand the autovectorizer a branch-free block of
/// independent lanes.
fn rmsprop_fast(theta: &mut [f32], grad: &[f32], g: &mut [f32], s: &mut [f32], lr: f32) {
    let n = theta.len();
    let mut i = 0;
    while i + FAST_LANES <= n {
        for l in 0..FAST_LANES {
            let j = i + l;
            let gr = grad[j];
            g[j] = RMSPROP_ALPHA * g[j] + (1.0 - RMSPROP_ALPHA) * gr;
            s[j] = RMSPROP_ALPHA * s[j] + (1.0 - RMSPROP_ALPHA) * gr * gr;
            theta[j] -= lr * gr / (s[j] - g[j] * g[j] + RMSPROP_EPS).sqrt();
        }
        i += FAST_LANES;
    }
    for j in i..n {
        let gr = grad[j];
        g[j] = RMSPROP_ALPHA * g[j] + (1.0 - RMSPROP_ALPHA) * gr;
        s[j] = RMSPROP_ALPHA * s[j] + (1.0 - RMSPROP_ALPHA) * gr * gr;
        theta[j] -= lr * gr / (s[j] - g[j] * g[j] + RMSPROP_EPS).sqrt();
    }
}

/// [`rmsprop`] with the (elementwise, hence trivially order-invariant)
/// update partitioned over the pool and dispatched by kernel tier.
fn rmsprop_pooled(
    pool: &ComputePool,
    mode: KernelMode,
    theta: &mut [f32],
    grad: &[f32],
    g: &mut [f32],
    s: &mut [f32],
    lr: f32,
) {
    let step: fn(&mut [f32], &[f32], &mut [f32], &mut [f32], f32) = match mode {
        KernelMode::Deterministic => rmsprop,
        KernelMode::Fast => rmsprop_fast,
    };
    if pool.threads() <= 1 {
        return step(theta, grad, g, s, lr);
    }
    let ranges = split_ranges(theta.len(), pool.threads());
    let mut tasks: Vec<Box<dyn FnOnce() + Send + '_>> = Vec::with_capacity(ranges.len());
    let (mut t_rest, mut g_rest, mut s_rest): (&mut [f32], &mut [f32], &mut [f32]) = (theta, g, s);
    for (lo, hi) in ranges {
        let (tc, tt) = std::mem::take(&mut t_rest).split_at_mut(hi - lo);
        let (gc, gt) = std::mem::take(&mut g_rest).split_at_mut(hi - lo);
        let (sc, st) = std::mem::take(&mut s_rest).split_at_mut(hi - lo);
        (t_rest, g_rest, s_rest) = (tt, gt, st);
        let grc = &grad[lo..hi];
        tasks.push(Box::new(move || step(tc, grc, gc, sc, lr)));
    }
    pool.scope(tasks);
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

struct LoadedEntry {
    arch: Arc<NetArch>,
    schema: EntrySchema,
    gamma: f32,
}

/// Pure-Rust [`ExecutionEngine`]; see module docs.
pub struct NativeEngine {
    entries: BTreeMap<String, LoadedEntry>,
    archs: BTreeMap<String, Arc<NetArch>>,
    pool: ComputePool,
    mode: KernelMode,
    scratch: TrainScratch,
}

impl Default for NativeEngine {
    fn default() -> Self {
        NativeEngine::new()
    }
}

impl NativeEngine {
    /// Serial engine (1 compute lane) — byte-for-byte the original engine.
    pub fn new() -> NativeEngine {
        NativeEngine::with_threads(1)
    }

    /// Engine backed by a persistent `learner_threads`-lane [`ComputePool`]
    /// with the deterministic kernel tier. Outputs are bit-identical for
    /// every thread count.
    pub fn with_threads(learner_threads: usize) -> NativeEngine {
        NativeEngine::with_options(learner_threads, KernelMode::Deterministic)
    }

    /// Engine with an explicit kernel tier (rust/DESIGN.md §12).
    pub fn with_options(learner_threads: usize, mode: KernelMode) -> NativeEngine {
        NativeEngine {
            entries: BTreeMap::new(),
            archs: BTreeMap::new(),
            pool: ComputePool::new(learner_threads),
            mode,
            scratch: TrainScratch::default(),
        }
    }

    pub fn learner_threads(&self) -> usize {
        self.pool.threads()
    }

    pub fn kernel_mode(&self) -> KernelMode {
        self.mode
    }

    /// Attach per-phase train timers (the `speedtest --breakdown` hook).
    /// Timing is observational only — it never changes a result bit.
    pub fn set_train_timers(&mut self, timers: Arc<TrainTimers>) {
        self.scratch.set_timers(timers);
    }

    fn arch_for(&mut self, spec: &NetSpec) -> Result<Arc<NetArch>> {
        // Keyed by the head-qualified runtime name: two heads of the same
        // base config are distinct architectures and must not collide.
        let key = spec.runtime_name();
        if let Some(a) = self.archs.get(&key) {
            return Ok(a.clone());
        }
        let arch = Arc::new(NetArch::from_spec(spec)?);
        self.archs.insert(key, arch.clone());
        Ok(arch)
    }
}

impl ExecutionEngine for NativeEngine {
    fn platform_name(&self) -> &str {
        "native-cpu"
    }

    fn load_entry(&mut self, key: &str, spec: &NetSpec, entry_name: &str) -> Result<()> {
        if self.entries.contains_key(key) {
            return Ok(());
        }
        let schema = EntrySchema::derive(spec, entry_name)?;
        let arch = self.arch_for(spec)?;
        self.entries.insert(
            key.to_string(),
            LoadedEntry { arch, schema, gamma: spec.gamma as f32 },
        );
        Ok(())
    }

    fn is_loaded(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    fn execute(&mut self, key: &str, args: &[TensorView<'_>]) -> Result<Vec<HostTensor>> {
        let entry = self
            .entries
            .get(key)
            .ok_or_else(|| anyhow!("entry {key:?} not loaded"))?;
        let arch = &entry.arch;
        // Every transaction is validated against the entry's named schema
        // before any math runs: a bad call is refused by entry and field
        // name, identically across engines.
        entry.schema.validate_args(args)?;
        let batch = entry.schema.batch;
        match entry.schema.op {
            EntryOp::Infer => {
                let params = args[0].as_f32("infer params")?;
                let states = args[1].as_u8("infer states")?;
                let q = match arch.head {
                    Head::Dqn => infer_pooled(arch, params, states, batch, &self.pool, self.mode)?,
                    _ => heads::infer_pooled_head(arch, params, states, batch, &self.pool, self.mode)?,
                };
                Ok(vec![HostTensor::f32(q, vec![batch, arch.actions])])
            }
            EntryOp::Train { double } => {
                let theta = args[0].as_f32("train theta")?;
                let target = args[1].as_f32("train target")?;
                let g = args[2].as_f32("train g")?;
                let s = args[3].as_f32("train s")?;
                let states = args[4].as_u8("train states")?;
                let actions = args[5].as_i32("train actions")?;
                let rewards = args[6].as_f32("train rewards")?;
                let next_states = args[7].as_u8("train next_states")?;
                let dones = args[8].as_f32("train dones")?;
                let lr = args[9].as_f32("train lr")?;
                let (weights, boot_gammas) = if args.len() == 12 {
                    (
                        Some(args[10].as_f32("train weights")?),
                        Some(args[11].as_f32("train boot_gammas")?),
                    )
                } else {
                    (None, None)
                };
                if actions.len() != batch || rewards.len() != batch || dones.len() != batch {
                    bail!("train {key:?}: batch vectors must have length {batch}");
                }
                if lr.len() != 1 {
                    bail!("train {key:?}: lr must be a scalar");
                }
                let (grad, loss, td) = match arch.head {
                    Head::Dqn => td_grads_opts(
                        arch, theta, target, states, actions, rewards, next_states, dones,
                        entry.gamma, weights, boot_gammas, double, &self.pool, self.mode,
                        &mut self.scratch,
                    )?,
                    _ => heads::td_grads_head(
                        arch, theta, target, states, actions, rewards, next_states, dones,
                        entry.gamma, weights, boot_gammas, double, &self.pool, self.mode,
                    )?,
                };
                let mut theta2 = theta.to_vec();
                let mut g2 = g.to_vec();
                let mut s2 = s.to_vec();
                timed(self.scratch.timers.as_deref(), TrainPhase::Rmsprop, || {
                    rmsprop_pooled(&self.pool, self.mode, &mut theta2, &grad, &mut g2, &mut s2, lr[0])
                });
                self.scratch.recycle_grad(grad);
                let p = arch.param_count();
                Ok(vec![
                    HostTensor::f32(theta2, vec![p]),
                    HostTensor::f32(g2, vec![p]),
                    HostTensor::f32(s2, vec![p]),
                    HostTensor::scalar_f32(loss),
                    HostTensor::f32(td, vec![batch]),
                ])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_model_py() {
        let tiny = NetArch::by_name("tiny", 6).unwrap();
        assert_eq!(tiny.param_count(), 27_082);
        let small = NetArch::by_name("small", 6).unwrap();
        assert_eq!(small.param_count(), 677_686);
        let nature = NetArch::by_name("nature", 6).unwrap();
        assert_eq!(nature.param_count(), 1_687_206);
        assert!(NetArch::by_name("bogus", 6).is_err());
    }

    #[test]
    fn conv_geometry_matches_model_py() {
        let nature = NetArch::by_name("nature", 6).unwrap();
        assert_eq!(nature.conv_out_hw(), vec![(20, 20), (9, 9), (7, 7)]);
        let tiny = NetArch::by_name("tiny", 6).unwrap();
        assert_eq!(tiny.conv_out_hw(), vec![(10, 10)]);
    }

    /// A miniature architecture so finite-difference checks stay cheap.
    fn micro_arch() -> NetArch {
        NetArch {
            name: "micro".into(),
            frame: [8, 8, 2],
            convs: vec![ConvSpec { filters: 2, kernel: 4, stride: 4 }],
            hidden: vec![8],
            actions: 3,
            head: Head::Dqn,
        }
    }

    fn micro_batch(arch: &NetArch, rng: &mut Rng) -> (Vec<u8>, Vec<i32>, Vec<f32>, Vec<u8>, Vec<f32>) {
        let b = 4;
        let fe = arch.frame_elems();
        let states: Vec<u8> = (0..b * fe).map(|_| rng.below(256) as u8).collect();
        let next: Vec<u8> = (0..b * fe).map(|_| rng.below(256) as u8).collect();
        let actions: Vec<i32> = (0..b).map(|_| rng.below(arch.actions as u32) as i32).collect();
        let rewards: Vec<f32> = (0..b).map(|_| rng.f32() - 0.5).collect();
        let dones: Vec<f32> = (0..b).map(|i| if i == 1 { 1.0 } else { 0.0 }).collect();
        (states, actions, rewards, next, dones)
    }

    fn micro_loss(
        arch: &NetArch,
        theta: &[f32],
        target: &[f32],
        batch: &(Vec<u8>, Vec<i32>, Vec<f32>, Vec<u8>, Vec<f32>),
        double: bool,
    ) -> f32 {
        let (states, actions, rewards, next, dones) = batch;
        let b = actions.len();
        let a = arch.actions;
        let q = infer(arch, theta, states, b).unwrap();
        let qn = infer(arch, target, next, b).unwrap();
        let mut loss = 0.0;
        for i in 0..b {
            let bootstrap = if double {
                let qo = infer(arch, theta, next, b).unwrap();
                let row = &qo[i * a..(i + 1) * a];
                let mut best = 0;
                for (j, &v) in row.iter().enumerate().skip(1) {
                    if v > row[best] {
                        best = j;
                    }
                }
                qn[i * a + best]
            } else {
                qn[i * a..(i + 1) * a].iter().copied().fold(f32::NEG_INFINITY, f32::max)
            };
            let t = rewards[i] + 0.9 * (1.0 - dones[i]) * bootstrap;
            loss += huber(q[i * a + actions[i] as usize] - t);
        }
        loss / b as f32
    }

    #[test]
    fn gradients_match_finite_differences() {
        let arch = micro_arch();
        let mut rng = Rng::new(42);
        let theta = init_params(&arch, 7);
        // A distinct target net so bootstrap != online values.
        let target = init_params(&arch, 8);
        let batch = micro_batch(&arch, &mut rng);
        let (states, actions, rewards, next, dones) = batch.clone();
        let pool = ComputePool::new(1);
        let (grad, loss, td) = td_grads(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, None,
            None, false, &pool,
        )
        .unwrap();
        assert!((micro_loss(&arch, &theta, &target, &batch, false) - loss).abs() < 1e-6);
        // TD errors: |mean Huber(d)| must reproduce the loss.
        assert_eq!(td.len(), actions.len());
        let loss_from_td: f32 = td.iter().map(|&d| huber(d)).sum::<f32>() / td.len() as f32;
        assert_eq!(loss_from_td.to_bits(), loss.to_bits(), "TD errors inconsistent with loss");

        // Central differences on a spread of parameter indices.
        let eps = 1e-3f32;
        let n = theta.len();
        for &i in &[0usize, 5, 63, 64, 65, 70, 130, n - 4, n - 1] {
            let mut tp = theta.clone();
            tp[i] += eps;
            let lp = micro_loss(&arch, &tp, &target, &batch, false);
            tp[i] = theta[i] - eps;
            let lm = micro_loss(&arch, &tp, &target, &batch, false);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[i]).abs() < 2e-3,
                "param {i}: finite-diff {fd} vs analytic {}",
                grad[i]
            );
        }
    }

    #[test]
    fn double_dqn_gradients_match_finite_differences() {
        let arch = micro_arch();
        let mut rng = Rng::new(43);
        let theta = init_params(&arch, 9);
        let target = init_params(&arch, 10);
        let batch = micro_batch(&arch, &mut rng);
        let (states, actions, rewards, next, dones) = batch.clone();
        let pool = ComputePool::new(1);
        let (grad, loss, _td) = td_grads(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, None,
            None, true, &pool,
        )
        .unwrap();
        assert!((micro_loss(&arch, &theta, &target, &batch, true) - loss).abs() < 1e-6);
        let eps = 1e-3f32;
        for &i in &[1usize, 64, 66, 131, theta.len() - 2] {
            let mut tp = theta.clone();
            tp[i] += eps;
            let lp = micro_loss(&arch, &tp, &target, &batch, true);
            tp[i] = theta[i] - eps;
            let lm = micro_loss(&arch, &tp, &target, &batch, true);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[i]).abs() < 2e-3,
                "param {i}: finite-diff {fd} vs analytic {}",
                grad[i]
            );
        }
    }

    #[test]
    fn gradients_are_bit_identical_across_pool_widths() {
        let arch = micro_arch();
        let mut rng = Rng::new(44);
        let theta = init_params(&arch, 11);
        let target = init_params(&arch, 12);
        let (states, actions, rewards, next, dones) = micro_batch(&arch, &mut rng);
        let baseline = {
            let pool = ComputePool::new(1);
            td_grads(
                &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, None,
                None, false, &pool,
            )
            .unwrap()
        };
        for threads in [2usize, 3, 4] {
            let pool = ComputePool::new(threads);
            let (grad, loss, td) = td_grads(
                &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, None,
                None, false, &pool,
            )
            .unwrap();
            assert_eq!(loss.to_bits(), baseline.1.to_bits(), "{threads} threads: loss drifted");
            let a: Vec<u32> = baseline.0.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = grad.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "{threads} threads: grads not bit-identical");
            let ta: Vec<u32> = baseline.2.iter().map(|v| v.to_bits()).collect();
            let tb: Vec<u32> = td.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ta, tb, "{threads} threads: TD errors not bit-identical");
        }
    }

    /// The extended 12-input path degenerates exactly: all-ones weights
    /// plus a constant-γ discount vector reproduce the legacy 10-input
    /// computation bit-for-bit (the uniform n-step / proportional-at-
    /// uniform-priorities cases lean on this identity).
    #[test]
    fn unit_weights_and_scalar_gamma_vector_match_legacy_bitwise() {
        let arch = micro_arch();
        let mut rng = Rng::new(45);
        let theta = init_params(&arch, 13);
        let target = init_params(&arch, 14);
        let (states, actions, rewards, next, dones) = micro_batch(&arch, &mut rng);
        let pool = ComputePool::new(2);
        let legacy = td_grads(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, None,
            None, false, &pool,
        )
        .unwrap();
        let ones = vec![1.0f32; actions.len()];
        let gammas = vec![0.9f32; actions.len()];
        let ext = td_grads(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9,
            Some(&ones), Some(&gammas), false, &pool,
        )
        .unwrap();
        assert_eq!(legacy.1.to_bits(), ext.1.to_bits(), "loss drifted");
        let a: Vec<u32> = legacy.0.iter().map(|v| v.to_bits()).collect();
        let b: Vec<u32> = ext.0.iter().map(|v| v.to_bits()).collect();
        assert_eq!(a, b, "unit-weighted grads not bit-identical to legacy");
    }

    /// IS weights scale each sample's loss contribution; halving every
    /// weight halves the loss, and a zero weight removes its sample's
    /// gradient while the TD error stays reported.
    #[test]
    fn weights_scale_loss_and_gradient() {
        let arch = micro_arch();
        let mut rng = Rng::new(46);
        let theta = init_params(&arch, 15);
        let target = init_params(&arch, 16);
        let (states, actions, rewards, next, dones) = micro_batch(&arch, &mut rng);
        let b = actions.len();
        let pool = ComputePool::new(1);
        let gammas = vec![0.9f32; b];
        let ones = vec![1.0f32; b];
        let halves = vec![0.5f32; b];
        let full = td_grads(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9,
            Some(&ones), Some(&gammas), false, &pool,
        )
        .unwrap();
        let half = td_grads(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9,
            Some(&halves), Some(&gammas), false, &pool,
        )
        .unwrap();
        assert!((half.1 - 0.5 * full.1).abs() < 1e-7, "loss must scale with weights");
        for (h, f) in half.0.iter().zip(full.0.iter()) {
            assert!((h - 0.5 * f).abs() < 1e-6, "grad must scale with weights");
        }
        // TD errors are pre-weight.
        assert_eq!(
            full.2.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            half.2.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
    }

    /// Per-sample bootstrap discounts replace the scalar γ: γᵐ = 0 turns
    /// a sample into a pure-reward target.
    #[test]
    fn boot_gammas_replace_scalar_gamma_per_sample() {
        let arch = micro_arch();
        let mut rng = Rng::new(47);
        let theta = init_params(&arch, 17);
        let target = init_params(&arch, 18);
        let (states, actions, rewards, next, dones) = micro_batch(&arch, &mut rng);
        let b = actions.len();
        let pool = ComputePool::new(1);
        let ones = vec![1.0f32; b];
        let zeros = vec![0.0f32; b];
        let (_, _, td_zero) = td_grads(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9,
            Some(&ones), Some(&zeros), false, &pool,
        )
        .unwrap();
        // With γᵐ = 0 the target is exactly the reward.
        let q = infer(&arch, &theta, &states, b).unwrap();
        for i in 0..b {
            let want = q[i * arch.actions + actions[i] as usize] - rewards[i];
            assert!((td_zero[i] - want).abs() < 1e-6, "sample {i}: {} vs {want}", td_zero[i]);
        }
    }

    #[test]
    fn pooled_infer_matches_serial() {
        let arch = micro_arch();
        let theta = init_params(&arch, 5);
        let mut rng = Rng::new(9);
        let b = 7;
        let states: Vec<u8> = (0..b * arch.frame_elems()).map(|_| rng.below(256) as u8).collect();
        let serial = infer(&arch, &theta, &states, b).unwrap();
        for threads in [2usize, 4] {
            let pool = ComputePool::new(threads);
            let pooled =
                infer_pooled(&arch, &theta, &states, b, &pool, KernelMode::Deterministic).unwrap();
            assert_eq!(serial, pooled, "{threads} threads");
        }
    }

    #[test]
    fn rmsprop_matches_reference_formula() {
        let mut theta = vec![1.0f32, -2.0];
        let grad = vec![0.5f32, -0.25];
        let mut g = vec![0.1f32, 0.0];
        let mut s = vec![0.2f32, 0.1];
        rmsprop(&mut theta, &grad, &mut g, &mut s, 0.01);
        // Hand-computed from rmsprop_ref (alpha=0.95, eps=0.01).
        let g0 = 0.95 * 0.1 + 0.05 * 0.5;
        let s0 = 0.95 * 0.2 + 0.05 * 0.25;
        let p0 = 1.0 - 0.01 * 0.5 / (s0 - g0 * g0 + 0.01f32).sqrt();
        assert!((g[0] - g0).abs() < 1e-7);
        assert!((s[0] - s0).abs() < 1e-7);
        assert!((theta[0] - p0).abs() < 1e-7);
    }

    #[test]
    fn pooled_rmsprop_matches_serial() {
        let mut rng = Rng::new(3);
        let n = 1000;
        let theta0: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
        let grad: Vec<f32> = (0..n).map(|_| rng.range_f32(-0.5, 0.5)).collect();
        let g0: Vec<f32> = (0..n).map(|_| rng.range_f32(-0.1, 0.1)).collect();
        let s0: Vec<f32> = (0..n).map(|_| rng.range_f32(0.0, 0.3)).collect();
        let (mut t1, mut g1, mut s1) = (theta0.clone(), g0.clone(), s0.clone());
        rmsprop(&mut t1, &grad, &mut g1, &mut s1, 0.01);
        let pool = ComputePool::new(3);
        let (mut t2, mut g2, mut s2) = (theta0.clone(), g0.clone(), s0.clone());
        rmsprop_pooled(&pool, KernelMode::Deterministic, &mut t2, &grad, &mut g2, &mut s2, 0.01);
        assert_eq!(t1, t2);
        assert_eq!(g1, g2);
        assert_eq!(s1, s2);
        // The fast tier is elementwise-identical: bit-equal, pooled or not.
        let (mut t3, mut g3, mut s3) = (theta0, g0, s0);
        rmsprop_pooled(&pool, KernelMode::Fast, &mut t3, &grad, &mut g3, &mut s3, 0.01);
        assert_eq!(t1, t3);
        assert_eq!(g1, g3);
        assert_eq!(s1, s3);
    }

    #[test]
    fn rmsprop_fast_is_bit_identical_to_serial() {
        let mut rng = Rng::new(21);
        for n in [1usize, 7, 8, 9, 64, 1000, 1003] {
            let theta0: Vec<f32> = (0..n).map(|_| rng.range_f32(-1.0, 1.0)).collect();
            let grad: Vec<f32> = (0..n).map(|_| rng.range_f32(-0.5, 0.5)).collect();
            let g0: Vec<f32> = (0..n).map(|_| rng.range_f32(-0.1, 0.1)).collect();
            let s0: Vec<f32> = (0..n).map(|_| rng.range_f32(0.0, 0.3)).collect();
            let (mut t1, mut g1, mut s1) = (theta0.clone(), g0.clone(), s0.clone());
            rmsprop(&mut t1, &grad, &mut g1, &mut s1, 2.5e-4);
            let (mut t2, mut g2, mut s2) = (theta0, g0, s0);
            rmsprop_fast(&mut t2, &grad, &mut g2, &mut s2, 2.5e-4);
            let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
            assert_eq!(bits(&t1), bits(&t2), "n={n}: theta");
            assert_eq!(bits(&g1), bits(&g2), "n={n}: g");
            assert_eq!(bits(&s1), bits(&s2), "n={n}: s");
        }
    }

    #[test]
    fn fast_mode_grads_are_bit_identical_across_pool_widths() {
        // The tentpole's width-invariance claim extends to the fast tier:
        // Phase B's rank-4 grouping follows global sample order, never
        // shard boundaries, so any learner_threads value is the same
        // machine.
        let arch = micro_arch();
        let mut rng = Rng::new(48);
        let theta = init_params(&arch, 19);
        let target = init_params(&arch, 20);
        let (states, actions, rewards, next, dones) = micro_batch(&arch, &mut rng);
        let run = |threads: usize| {
            let pool = ComputePool::new(threads);
            let mut scratch = TrainScratch::default();
            td_grads_opts(
                &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, None,
                None, false, &pool, KernelMode::Fast, &mut scratch,
            )
            .unwrap()
        };
        let baseline = run(1);
        for threads in [2usize, 3, 4] {
            let (grad, loss, td) = run(threads);
            assert_eq!(loss.to_bits(), baseline.1.to_bits(), "{threads} threads: loss drifted");
            let a: Vec<u32> = baseline.0.iter().map(|v| v.to_bits()).collect();
            let b: Vec<u32> = grad.iter().map(|v| v.to_bits()).collect();
            assert_eq!(a, b, "{threads} threads: fast grads not bit-identical");
            let ta: Vec<u32> = baseline.2.iter().map(|v| v.to_bits()).collect();
            let tb: Vec<u32> = td.iter().map(|v| v.to_bits()).collect();
            assert_eq!(ta, tb, "{threads} threads: fast TD errors not bit-identical");
        }
    }

    #[test]
    fn fast_mode_grads_stay_close_to_deterministic() {
        let arch = micro_arch();
        let mut rng = Rng::new(49);
        let theta = init_params(&arch, 21);
        let target = init_params(&arch, 22);
        let (states, actions, rewards, next, dones) = micro_batch(&arch, &mut rng);
        let pool = ComputePool::new(2);
        let det = td_grads(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, None,
            None, false, &pool,
        )
        .unwrap();
        let mut scratch = TrainScratch::default();
        let fast = td_grads_opts(
            &arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, None,
            None, false, &pool, KernelMode::Fast, &mut scratch,
        )
        .unwrap();
        assert!((det.1 - fast.1).abs() <= 1e-5 * det.1.abs().max(1.0), "loss diverged");
        let scale = det.0.iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for (i, (d, f)) in det.0.iter().zip(fast.0.iter()).enumerate() {
            assert!(
                (d - f).abs() <= 1e-4 * scale + 1e-7,
                "grad[{i}]: det {d} vs fast {f} (scale {scale})"
            );
        }
    }

    #[test]
    fn scratch_reuse_is_bitwise_invisible() {
        // Two consecutive steps through one persistent scratch must equal
        // the fresh-scratch results bit-for-bit, in both kernel modes —
        // the recycled patch/grad buffers carry capacity, never state.
        let arch = micro_arch();
        let mut rng = Rng::new(50);
        let theta_a = init_params(&arch, 23);
        let theta_b = init_params(&arch, 24);
        let target = init_params(&arch, 25);
        let batch_a = micro_batch(&arch, &mut rng);
        let batch_b = micro_batch(&arch, &mut rng);
        let pool = ComputePool::new(2);
        for mode in KernelMode::ALL {
            let mut shared = TrainScratch::default();
            let run = |theta: &[f32],
                           b: &(Vec<u8>, Vec<i32>, Vec<f32>, Vec<u8>, Vec<f32>),
                           scratch: &mut TrainScratch| {
                let (states, actions, rewards, next, dones) = b;
                let (grad, loss, td) = td_grads_opts(
                    &arch, theta, &target, states, actions, rewards, next, dones, 0.9, None,
                    None, false, &pool, mode, scratch,
                )
                .unwrap();
                let bits: Vec<u32> = grad.iter().map(|v| v.to_bits()).collect();
                scratch.recycle_grad(grad); // engine-style buffer hand-back
                (bits, loss.to_bits(), td.iter().map(|v| v.to_bits()).collect::<Vec<u32>>())
            };
            let first = run(&theta_a, &batch_a, &mut shared);
            let second = run(&theta_b, &batch_b, &mut shared);
            let fresh_first = run(&theta_a, &batch_a, &mut TrainScratch::default());
            let fresh_second = run(&theta_b, &batch_b, &mut TrainScratch::default());
            assert_eq!(first, fresh_first, "{mode:?}: first step drifted under reuse");
            assert_eq!(second, fresh_second, "{mode:?}: second step drifted under reuse");
        }
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let arch = NetArch::by_name("tiny", 6).unwrap();
        let a = init_params(&arch, 0);
        let b = init_params(&arch, 0);
        assert_eq!(a, b);
        let c = init_params(&arch, 1);
        assert_ne!(a, c);
        // conv0 weights: fan_in = 8*8*4 = 256 -> |w| <= 1/16.
        assert!(a[..1024].iter().all(|v| v.abs() <= 1.0 / 16.0 + 1e-6));
        // conv0 bias is zero.
        assert!(a[1024..1028].iter().all(|&v| v == 0.0));
    }
}
