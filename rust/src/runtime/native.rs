//! Native execution engine: a pure-Rust reference implementation of the
//! compiled entry points.
//!
//! Mirrors `python/compile/model.py` operation-for-operation — im2col
//! convolutions, ReLU MLP head, mean-Huber TD loss (standard and Double-DQN
//! targets), hand-derived backprop, and the fused centered-RMSProp update
//! from `python/compile/kernels/ref.py` (alpha=0.95, eps=0.01). All math is
//! plain f32 in a fixed evaluation order, so results are bit-deterministic
//! across runs and thread counts.
//!
//! This engine needs no artifacts: architecture comes from the manifest's
//! config name (the same three variants `model.make_config` defines), and
//! initial parameters use the same scheme (zero biases, uniform
//! ±1/sqrt(fan_in) weights) driven by the in-tree deterministic RNG.
//!
//! Memory note: im2col patch matrices are materialized per *sample*, never
//! per batch, so peak scratch is O(OH·OW·k²·C) regardless of batch size.

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use crate::util::rng::Rng;

use super::engine::{EntryKind, ExecutionEngine};
use super::manifest::NetSpec;
use super::tensor::{HostTensor, TensorView};

const RMSPROP_ALPHA: f32 = 0.95;
const RMSPROP_EPS: f32 = 0.01;

/// One conv layer: `filters` output channels, `kernel`×`kernel` window,
/// `stride` step, VALID padding (matches `model.ConvSpec`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvSpec {
    pub filters: usize,
    pub kernel: usize,
    pub stride: usize,
}

/// Architecture of one Q-network variant (matches `model.NetConfig`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct NetArch {
    pub name: String,
    pub frame: [usize; 3], // (H, W, stacked channels)
    pub convs: Vec<ConvSpec>,
    pub hidden: Vec<usize>,
    pub actions: usize,
}

impl NetArch {
    /// The three supported architectures (`model.make_config`).
    pub fn by_name(name: &str, actions: usize) -> Result<NetArch> {
        let (convs, hidden): (Vec<ConvSpec>, Vec<usize>) = match name {
            "nature" => (
                vec![
                    ConvSpec { filters: 32, kernel: 8, stride: 4 },
                    ConvSpec { filters: 64, kernel: 4, stride: 2 },
                    ConvSpec { filters: 64, kernel: 3, stride: 1 },
                ],
                vec![512],
            ),
            "small" => (
                vec![
                    ConvSpec { filters: 16, kernel: 8, stride: 4 },
                    ConvSpec { filters: 32, kernel: 4, stride: 2 },
                ],
                vec![256],
            ),
            "tiny" => (vec![ConvSpec { filters: 4, kernel: 8, stride: 8 }], vec![64]),
            other => bail!("native engine knows no architecture named {other:?}"),
        };
        Ok(NetArch { name: name.to_string(), frame: [84, 84, 4], convs, hidden, actions })
    }

    /// Resolve and cross-check the architecture for a manifest config.
    pub fn from_spec(spec: &NetSpec) -> Result<NetArch> {
        let arch = Self::by_name(&spec.name, spec.actions)?;
        if arch.frame != spec.frame {
            bail!(
                "config {:?}: manifest frame {:?} != architecture frame {:?}",
                spec.name, spec.frame, arch.frame
            );
        }
        if arch.param_count() != spec.param_count {
            bail!(
                "config {:?}: manifest has {} params, architecture implies {}",
                spec.name, spec.param_count, arch.param_count()
            );
        }
        Ok(arch)
    }

    /// (OH, OW) after each conv layer.
    pub fn conv_out_hw(&self) -> Vec<(usize, usize)> {
        let [mut h, mut w, _] = self.frame;
        self.convs
            .iter()
            .map(|c| {
                h = (h - c.kernel) / c.stride + 1;
                w = (w - c.kernel) / c.stride + 1;
                (h, w)
            })
            .collect()
    }

    /// Ordered (name, shape) list defining the flat parameter layout
    /// (identical to `model.param_spec`).
    pub fn param_spec(&self) -> Vec<(String, Vec<usize>)> {
        let mut spec = Vec::new();
        let mut c_in = self.frame[2];
        for (i, conv) in self.convs.iter().enumerate() {
            spec.push((format!("conv{i}_w"), vec![conv.kernel, conv.kernel, c_in, conv.filters]));
            spec.push((format!("conv{i}_b"), vec![conv.filters]));
            c_in = conv.filters;
        }
        let (h, w) = self.conv_out_hw().last().copied().unwrap_or((self.frame[0], self.frame[1]));
        let mut dim = h * w * c_in;
        for (i, &width) in self.hidden.iter().enumerate() {
            spec.push((format!("fc{i}_w"), vec![dim, width]));
            spec.push((format!("fc{i}_b"), vec![width]));
            dim = width;
        }
        spec.push(("out_w".to_string(), vec![dim, self.actions]));
        spec.push(("out_b".to_string(), vec![self.actions]));
        spec
    }

    pub fn param_count(&self) -> usize {
        self.param_spec().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Byte offsets of each tensor in the flat vector.
    fn offsets(&self) -> Vec<(usize, usize)> {
        let mut out = Vec::new();
        let mut off = 0;
        for (_, shape) in self.param_spec() {
            let n: usize = shape.iter().product();
            out.push((off, n));
            off += n;
        }
        out
    }

    pub fn frame_elems(&self) -> usize {
        self.frame.iter().product()
    }
}

/// Deterministic initial parameters: zero biases, uniform ±1/sqrt(fan_in)
/// weights — the same scheme as `model.init_params`, driven by the in-tree
/// RNG (one independent stream per tensor, so layouts are stable).
pub fn init_params(arch: &NetArch, seed: u64) -> Vec<f32> {
    let mut flat = Vec::with_capacity(arch.param_count());
    for (idx, (name, shape)) in arch.param_spec().iter().enumerate() {
        let n: usize = shape.iter().product();
        if name.ends_with("_b") {
            flat.extend(std::iter::repeat(0.0f32).take(n));
        } else {
            let fan_in: usize = shape[..shape.len() - 1].iter().product();
            let bound = 1.0 / (fan_in as f32).sqrt();
            let mut rng = Rng::stream(seed, 0x1217 ^ idx as u64);
            flat.extend((0..n).map(|_| rng.range_f32(-bound, bound)));
        }
    }
    flat
}

// ---------------------------------------------------------------------------
// Dense kernels (fixed evaluation order => bit-deterministic)
// ---------------------------------------------------------------------------

/// out[M,N] += a[M,K] @ b[K,N] (i-k-j loop order; `out` must be zeroed by
/// the caller when accumulation is not wanted).
fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // post-ReLU activations are sparse
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// out[K,N] += a[M,K]^T @ b[M,N] (weight gradients).
fn matmul_at_b_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// out[M,N] = a[M,K] @ b[N,K]^T (input gradients; row-by-row dot products).
fn matmul_a_bt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (av, bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
}

/// Extract one sample's im2col patch matrix `[OH*OW, k*k*C]`.
/// Patch column layout is `(ky*k + kx)*C + c`, matching the `[k,k,C,F]`
/// weight tensor reshaped to `[k*k*C, F]` (as in `model._im2col`).
fn im2col_sample(
    x: &[f32], // one sample, [H, W, C]
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    out: &mut [f32], // [OH*OW, kernel*kernel*c]
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kdim = kernel * kernel * c;
    debug_assert_eq!(out.len(), oh * ow * kdim);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * kdim;
            for ky in 0..kernel {
                let src = ((oy * stride + ky) * w + ox * stride) * c;
                let dst = row + ky * kernel * c;
                // kx and c are contiguous in both source and destination.
                out[dst..dst + kernel * c].copy_from_slice(&x[src..src + kernel * c]);
            }
        }
    }
}

/// Scatter-add one sample's patch gradients back to the input image
/// (transpose of [`im2col_sample`]).
fn col2im_sample(
    dpatches: &[f32], // [OH*OW, kernel*kernel*c]
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    dx: &mut [f32], // one sample, [H, W, C], caller-zeroed
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kdim = kernel * kernel * c;
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * kdim;
            for ky in 0..kernel {
                let dst = ((oy * stride + ky) * w + ox * stride) * c;
                let src = row + ky * kernel * c;
                for i in 0..kernel * c {
                    dx[dst + i] += dpatches[src + i];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Forward / backward
// ---------------------------------------------------------------------------

/// Activations retained for the backward pass.
struct ForwardCache {
    /// Normalized input `[B, H, W, C]` (f32, /255).
    x0: Vec<f32>,
    /// Post-ReLU output of each conv layer, `[B, OH, OW, F]`.
    conv_out: Vec<Vec<f32>>,
    /// Post-ReLU output of each hidden layer, `[B, width]`.
    fc_out: Vec<Vec<f32>>,
    /// Q-values `[B, A]`.
    q: Vec<f32>,
}

struct Params<'a> {
    flat: &'a [f32],
    offsets: Vec<(usize, usize)>,
}

impl<'a> Params<'a> {
    fn new(arch: &NetArch, flat: &'a [f32]) -> Result<Params<'a>> {
        if flat.len() != arch.param_count() {
            bail!("params: got {} values, want {}", flat.len(), arch.param_count());
        }
        Ok(Params { flat, offsets: arch.offsets() })
    }

    fn tensor(&self, idx: usize) -> &'a [f32] {
        let (off, n) = self.offsets[idx];
        &self.flat[off..off + n]
    }
}

/// Forward pass; `keep` controls whether activations are cached (training)
/// or dropped as soon as possible (inference).
fn forward(arch: &NetArch, p: &Params<'_>, states: &[u8], batch: usize, keep: bool) -> Result<ForwardCache> {
    let [h0, w0, c0] = arch.frame;
    if states.len() != batch * h0 * w0 * c0 {
        bail!("states: got {} bytes, want {}", states.len(), batch * h0 * w0 * c0);
    }
    let x0: Vec<f32> = states.iter().map(|&v| v as f32 / 255.0).collect();
    let kept_x0 = if keep { x0.clone() } else { Vec::new() };

    let hw = arch.conv_out_hw();
    let mut conv_out: Vec<Vec<f32>> = Vec::with_capacity(arch.convs.len());
    let (mut h, mut w, mut c) = (h0, w0, c0);
    let mut x = x0;
    let mut tensor_idx = 0;
    for (i, conv) in arch.convs.iter().enumerate() {
        let (oh, ow) = hw[i];
        let kdim = conv.kernel * conv.kernel * c;
        let wmat = p.tensor(tensor_idx); // [kdim, F]
        let bias = p.tensor(tensor_idx + 1);
        tensor_idx += 2;
        let mut y = vec![0.0f32; batch * oh * ow * conv.filters];
        let mut patches = vec![0.0f32; oh * ow * kdim];
        for bi in 0..batch {
            im2col_sample(&x[bi * h * w * c..(bi + 1) * h * w * c], h, w, c, conv.kernel, conv.stride, &mut patches);
            let yrows = &mut y[bi * oh * ow * conv.filters..(bi + 1) * oh * ow * conv.filters];
            matmul_acc(&patches, wmat, yrows, oh * ow, kdim, conv.filters);
        }
        // Bias + ReLU in one pass.
        for (j, v) in y.iter_mut().enumerate() {
            let withb = *v + bias[j % conv.filters];
            *v = if withb > 0.0 { withb } else { 0.0 };
        }
        x = y;
        (h, w, c) = (oh, ow, conv.filters);
        if keep {
            conv_out.push(x.clone());
        }
    }

    // Hidden layers (x is now [B, dim]).
    let mut dim = h * w * c;
    let mut fc_out: Vec<Vec<f32>> = Vec::with_capacity(arch.hidden.len());
    for &width in arch.hidden.iter() {
        let wmat = p.tensor(tensor_idx);
        let bias = p.tensor(tensor_idx + 1);
        tensor_idx += 2;
        let mut y = vec![0.0f32; batch * width];
        matmul_acc(&x, wmat, &mut y, batch, dim, width);
        for (j, v) in y.iter_mut().enumerate() {
            let withb = *v + bias[j % width];
            *v = if withb > 0.0 { withb } else { 0.0 };
        }
        x = y;
        dim = width;
        if keep {
            fc_out.push(x.clone());
        }
    }

    // Output head (no activation).
    let wmat = p.tensor(tensor_idx);
    let bias = p.tensor(tensor_idx + 1);
    let mut q = vec![0.0f32; batch * arch.actions];
    matmul_acc(&x, wmat, &mut q, batch, dim, arch.actions);
    for (j, v) in q.iter_mut().enumerate() {
        *v += bias[j % arch.actions];
    }

    Ok(ForwardCache { x0: kept_x0, conv_out, fc_out, q })
}

/// Q-values only (inference entry).
pub fn infer(arch: &NetArch, params: &[f32], states: &[u8], batch: usize) -> Result<Vec<f32>> {
    let p = Params::new(arch, params)?;
    Ok(forward(arch, &p, states, batch, false)?.q)
}

fn huber(x: f32) -> f32 {
    let ax = x.abs();
    if ax <= 1.0 {
        0.5 * x * x
    } else {
        ax - 0.5
    }
}

fn huber_grad(x: f32) -> f32 {
    x.clamp(-1.0, 1.0)
}

/// TD loss + full parameter gradient (the train entry minus the optimizer).
/// Returns (grad, loss).
fn td_grads(
    arch: &NetArch,
    theta: &[f32],
    target_theta: &[f32],
    states: &[u8],
    actions: &[i32],
    rewards: &[f32],
    next_states: &[u8],
    dones: &[f32],
    gamma: f32,
    double: bool,
) -> Result<(Vec<f32>, f32)> {
    let batch = actions.len();
    let p = Params::new(arch, theta)?;
    let pt = Params::new(arch, target_theta)?;
    let cache = forward(arch, &p, states, batch, true)?;
    let qn_target = forward(arch, &pt, next_states, batch, false)?.q;
    let a = arch.actions;

    // Bootstrap values (never differentiated — stop_gradient in the model).
    let mut bootstrap = vec![0.0f32; batch];
    if double {
        let qn_online = forward(arch, &p, next_states, batch, false)?.q;
        for b in 0..batch {
            let row = &qn_online[b * a..(b + 1) * a];
            let mut best = 0;
            for (i, &v) in row.iter().enumerate().skip(1) {
                if v > row[best] {
                    best = i;
                }
            }
            bootstrap[b] = qn_target[b * a + best];
        }
    } else {
        for b in 0..batch {
            bootstrap[b] = qn_target[b * a..(b + 1) * a].iter().copied().fold(f32::NEG_INFINITY, f32::max);
        }
    }

    // Per-sample TD error -> loss and dL/dq.
    let mut loss = 0.0f32;
    let mut dq = vec![0.0f32; batch * a];
    for b in 0..batch {
        let act = actions[b];
        if act < 0 || act as usize >= a {
            bail!("train: action {act} out of range 0..{a}");
        }
        let q_sel = cache.q[b * a + act as usize];
        let target = rewards[b] + gamma * (1.0 - dones[b]) * bootstrap[b];
        let d = q_sel - target;
        loss += huber(d);
        dq[b * a + act as usize] = huber_grad(d) / batch as f32;
    }
    loss /= batch as f32;

    // ---- backward ---------------------------------------------------------
    let mut grad = vec![0.0f32; arch.param_count()];
    let offsets = arch.offsets();
    let n_conv = arch.convs.len();
    let n_fc = arch.hidden.len();
    let hw = arch.conv_out_hw();
    let (last_h, last_w) = hw.last().copied().unwrap_or((arch.frame[0], arch.frame[1]));
    let last_c = arch.convs.last().map(|c| c.filters).unwrap_or(arch.frame[2]);
    let flat_dim = last_h * last_w * last_c;

    // Output head.
    let head_in: &[f32] = if n_fc > 0 { &cache.fc_out[n_fc - 1] } else { &cache.conv_out[n_conv - 1] };
    let head_dim = if n_fc > 0 { arch.hidden[n_fc - 1] } else { flat_dim };
    let widx = 2 * n_conv + 2 * n_fc; // out_w tensor index
    {
        let (off_w, n_w) = offsets[widx];
        matmul_at_b_acc(head_in, &dq, &mut grad[off_w..off_w + n_w], batch, head_dim, a);
        let (off_b, _) = offsets[widx + 1];
        for b in 0..batch {
            for j in 0..a {
                grad[off_b + j] += dq[b * a + j];
            }
        }
    }
    let out_w = p.tensor(widx);
    let mut dx = vec![0.0f32; batch * head_dim];
    matmul_a_bt(&dq, out_w, &mut dx, batch, a, head_dim);

    // Hidden layers, reversed.
    for i in (0..n_fc).rev() {
        let width = arch.hidden[i];
        let post = &cache.fc_out[i];
        // ReLU mask.
        for (d, &v) in dx.iter_mut().zip(post.iter()) {
            if v <= 0.0 {
                *d = 0.0;
            }
        }
        let in_dim = if i > 0 { arch.hidden[i - 1] } else { flat_dim };
        let xin: &[f32] = if i > 0 { &cache.fc_out[i - 1] } else { &cache.conv_out[n_conv - 1] };
        let tidx = 2 * n_conv + 2 * i;
        let (off_w, n_w) = offsets[tidx];
        matmul_at_b_acc(xin, &dx, &mut grad[off_w..off_w + n_w], batch, in_dim, width);
        let (off_b, _) = offsets[tidx + 1];
        for b in 0..batch {
            for j in 0..width {
                grad[off_b + j] += dx[b * width + j];
            }
        }
        let wmat = p.tensor(tidx);
        let mut dprev = vec![0.0f32; batch * in_dim];
        matmul_a_bt(&dx, wmat, &mut dprev, batch, width, in_dim);
        dx = dprev;
    }

    // Conv layers, reversed. dx currently holds d(conv_out[last]) [B,OH,OW,F].
    for i in (0..n_conv).rev() {
        let conv = arch.convs[i];
        let (oh, ow) = hw[i];
        let (in_h, in_w, in_c) = if i > 0 {
            (hw[i - 1].0, hw[i - 1].1, arch.convs[i - 1].filters)
        } else {
            (arch.frame[0], arch.frame[1], arch.frame[2])
        };
        let kdim = conv.kernel * conv.kernel * in_c;
        let f = conv.filters;
        let post = &cache.conv_out[i];
        for (d, &v) in dx.iter_mut().zip(post.iter()) {
            if v <= 0.0 {
                *d = 0.0;
            }
        }
        let tidx = 2 * i;
        let (off_w, n_w) = offsets[tidx];
        let (off_b, _) = offsets[tidx + 1];
        let wmat = p.tensor(tidx);
        let xin_all: &[f32] = if i > 0 { &cache.conv_out[i - 1] } else { &cache.x0 };
        let in_sz = in_h * in_w * in_c;
        let need_dx = i > 0;
        let mut dprev = if need_dx { vec![0.0f32; batch * in_sz] } else { Vec::new() };
        let mut patches = vec![0.0f32; oh * ow * kdim];
        let mut dpatches = vec![0.0f32; oh * ow * kdim];
        for bi in 0..batch {
            let dy = &dx[bi * oh * ow * f..(bi + 1) * oh * ow * f];
            // grad_b
            for row in 0..oh * ow {
                for j in 0..f {
                    grad[off_b + j] += dy[row * f + j];
                }
            }
            // grad_w via recomputed patches
            im2col_sample(&xin_all[bi * in_sz..(bi + 1) * in_sz], in_h, in_w, in_c, conv.kernel, conv.stride, &mut patches);
            matmul_at_b_acc(&patches, dy, &mut grad[off_w..off_w + n_w], oh * ow, kdim, f);
            // d(input) for upstream layers
            if need_dx {
                matmul_a_bt(dy, wmat, &mut dpatches, oh * ow, f, kdim);
                col2im_sample(&dpatches, in_h, in_w, in_c, conv.kernel, conv.stride, &mut dprev[bi * in_sz..(bi + 1) * in_sz]);
            }
        }
        dx = dprev;
    }

    Ok((grad, loss))
}

/// Centered RMSProp (the L1 fused kernel's semantics, `rmsprop_ref`).
fn rmsprop(theta: &mut [f32], grad: &[f32], g: &mut [f32], s: &mut [f32], lr: f32) {
    for i in 0..theta.len() {
        let gr = grad[i];
        g[i] = RMSPROP_ALPHA * g[i] + (1.0 - RMSPROP_ALPHA) * gr;
        s[i] = RMSPROP_ALPHA * s[i] + (1.0 - RMSPROP_ALPHA) * gr * gr;
        theta[i] -= lr * gr / (s[i] - g[i] * g[i] + RMSPROP_EPS).sqrt();
    }
}

// ---------------------------------------------------------------------------
// The engine
// ---------------------------------------------------------------------------

struct LoadedEntry {
    arch: Arc<NetArch>,
    kind: EntryKind,
    gamma: f32,
}

/// Pure-Rust [`ExecutionEngine`]; see module docs.
#[derive(Default)]
pub struct NativeEngine {
    entries: BTreeMap<String, LoadedEntry>,
    archs: BTreeMap<String, Arc<NetArch>>,
}

impl NativeEngine {
    pub fn new() -> NativeEngine {
        NativeEngine::default()
    }

    fn arch_for(&mut self, spec: &NetSpec) -> Result<Arc<NetArch>> {
        if let Some(a) = self.archs.get(&spec.name) {
            return Ok(a.clone());
        }
        let arch = Arc::new(NetArch::from_spec(spec)?);
        self.archs.insert(spec.name.clone(), arch.clone());
        Ok(arch)
    }
}

impl ExecutionEngine for NativeEngine {
    fn platform_name(&self) -> &str {
        "native-cpu"
    }

    fn load_entry(&mut self, key: &str, spec: &NetSpec, entry_name: &str) -> Result<()> {
        if self.entries.contains_key(key) {
            return Ok(());
        }
        let kind = EntryKind::parse(entry_name)?;
        let arch = self.arch_for(spec)?;
        self.entries.insert(
            key.to_string(),
            LoadedEntry { arch, kind, gamma: spec.gamma as f32 },
        );
        Ok(())
    }

    fn is_loaded(&self, key: &str) -> bool {
        self.entries.contains_key(key)
    }

    fn execute(&mut self, key: &str, args: &[TensorView<'_>]) -> Result<Vec<HostTensor>> {
        let entry = self
            .entries
            .get(key)
            .ok_or_else(|| anyhow!("entry {key:?} not loaded"))?;
        let arch = &entry.arch;
        match entry.kind {
            EntryKind::Infer { batch } => {
                if args.len() != 2 {
                    bail!("infer {key:?}: expected 2 inputs, got {}", args.len());
                }
                let params = args[0].as_f32("infer params")?;
                let states = args[1].as_u8("infer states")?;
                let q = infer(arch, params, states, batch)?;
                Ok(vec![HostTensor::f32(q, vec![batch, arch.actions])])
            }
            EntryKind::Train { batch, double } => {
                if args.len() != 10 {
                    bail!("train {key:?}: expected 10 inputs, got {}", args.len());
                }
                let theta = args[0].as_f32("train theta")?;
                let target = args[1].as_f32("train target")?;
                let g = args[2].as_f32("train g")?;
                let s = args[3].as_f32("train s")?;
                let states = args[4].as_u8("train states")?;
                let actions = args[5].as_i32("train actions")?;
                let rewards = args[6].as_f32("train rewards")?;
                let next_states = args[7].as_u8("train next_states")?;
                let dones = args[8].as_f32("train dones")?;
                let lr = args[9].as_f32("train lr")?;
                if actions.len() != batch || rewards.len() != batch || dones.len() != batch {
                    bail!("train {key:?}: batch vectors must have length {batch}");
                }
                if lr.len() != 1 {
                    bail!("train {key:?}: lr must be a scalar");
                }
                let (grad, loss) = td_grads(
                    arch, theta, target, states, actions, rewards, next_states, dones,
                    entry.gamma, double,
                )?;
                let mut theta2 = theta.to_vec();
                let mut g2 = g.to_vec();
                let mut s2 = s.to_vec();
                rmsprop(&mut theta2, &grad, &mut g2, &mut s2, lr[0]);
                let p = arch.param_count();
                Ok(vec![
                    HostTensor::f32(theta2, vec![p]),
                    HostTensor::f32(g2, vec![p]),
                    HostTensor::f32(s2, vec![p]),
                    HostTensor::scalar_f32(loss),
                ])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_counts_match_model_py() {
        let tiny = NetArch::by_name("tiny", 6).unwrap();
        assert_eq!(tiny.param_count(), 27_082);
        let small = NetArch::by_name("small", 6).unwrap();
        assert_eq!(small.param_count(), 677_686);
        let nature = NetArch::by_name("nature", 6).unwrap();
        assert_eq!(nature.param_count(), 1_687_206);
        assert!(NetArch::by_name("bogus", 6).is_err());
    }

    #[test]
    fn conv_geometry_matches_model_py() {
        let nature = NetArch::by_name("nature", 6).unwrap();
        assert_eq!(nature.conv_out_hw(), vec![(20, 20), (9, 9), (7, 7)]);
        let tiny = NetArch::by_name("tiny", 6).unwrap();
        assert_eq!(tiny.conv_out_hw(), vec![(10, 10)]);
    }

    /// A miniature architecture so finite-difference checks stay cheap.
    fn micro_arch() -> NetArch {
        NetArch {
            name: "micro".into(),
            frame: [8, 8, 2],
            convs: vec![ConvSpec { filters: 2, kernel: 4, stride: 4 }],
            hidden: vec![8],
            actions: 3,
        }
    }

    fn micro_batch(arch: &NetArch, rng: &mut Rng) -> (Vec<u8>, Vec<i32>, Vec<f32>, Vec<u8>, Vec<f32>) {
        let b = 4;
        let fe = arch.frame_elems();
        let states: Vec<u8> = (0..b * fe).map(|_| rng.below(256) as u8).collect();
        let next: Vec<u8> = (0..b * fe).map(|_| rng.below(256) as u8).collect();
        let actions: Vec<i32> = (0..b).map(|_| rng.below(arch.actions as u32) as i32).collect();
        let rewards: Vec<f32> = (0..b).map(|_| rng.f32() - 0.5).collect();
        let dones: Vec<f32> = (0..b).map(|i| if i == 1 { 1.0 } else { 0.0 }).collect();
        (states, actions, rewards, next, dones)
    }

    fn micro_loss(
        arch: &NetArch,
        theta: &[f32],
        target: &[f32],
        batch: &(Vec<u8>, Vec<i32>, Vec<f32>, Vec<u8>, Vec<f32>),
        double: bool,
    ) -> f32 {
        let (states, actions, rewards, next, dones) = batch;
        let b = actions.len();
        let a = arch.actions;
        let q = infer(arch, theta, states, b).unwrap();
        let qn = infer(arch, target, next, b).unwrap();
        let mut loss = 0.0;
        for i in 0..b {
            let bootstrap = if double {
                let qo = infer(arch, theta, next, b).unwrap();
                let row = &qo[i * a..(i + 1) * a];
                let mut best = 0;
                for (j, &v) in row.iter().enumerate().skip(1) {
                    if v > row[best] {
                        best = j;
                    }
                }
                qn[i * a + best]
            } else {
                qn[i * a..(i + 1) * a].iter().copied().fold(f32::NEG_INFINITY, f32::max)
            };
            let t = rewards[i] + 0.9 * (1.0 - dones[i]) * bootstrap;
            loss += huber(q[i * a + actions[i] as usize] - t);
        }
        loss / b as f32
    }

    #[test]
    fn gradients_match_finite_differences() {
        let arch = micro_arch();
        let mut rng = Rng::new(42);
        let theta = init_params(&arch, 7);
        // A distinct target net so bootstrap != online values.
        let target = init_params(&arch, 8);
        let batch = micro_batch(&arch, &mut rng);
        let (states, actions, rewards, next, dones) = batch.clone();
        let (grad, loss) =
            td_grads(&arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, false)
                .unwrap();
        assert!((micro_loss(&arch, &theta, &target, &batch, false) - loss).abs() < 1e-6);

        // Central differences on a spread of parameter indices.
        let eps = 1e-3f32;
        let n = theta.len();
        for &i in &[0usize, 5, 63, 64, 65, 70, 130, n - 4, n - 1] {
            let mut tp = theta.clone();
            tp[i] += eps;
            let lp = micro_loss(&arch, &tp, &target, &batch, false);
            tp[i] = theta[i] - eps;
            let lm = micro_loss(&arch, &tp, &target, &batch, false);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[i]).abs() < 2e-3,
                "param {i}: finite-diff {fd} vs analytic {}",
                grad[i]
            );
        }
    }

    #[test]
    fn double_dqn_gradients_match_finite_differences() {
        let arch = micro_arch();
        let mut rng = Rng::new(43);
        let theta = init_params(&arch, 9);
        let target = init_params(&arch, 10);
        let batch = micro_batch(&arch, &mut rng);
        let (states, actions, rewards, next, dones) = batch.clone();
        let (grad, loss) =
            td_grads(&arch, &theta, &target, &states, &actions, &rewards, &next, &dones, 0.9, true)
                .unwrap();
        assert!((micro_loss(&arch, &theta, &target, &batch, true) - loss).abs() < 1e-6);
        let eps = 1e-3f32;
        for &i in &[1usize, 64, 66, 131, theta.len() - 2] {
            let mut tp = theta.clone();
            tp[i] += eps;
            let lp = micro_loss(&arch, &tp, &target, &batch, true);
            tp[i] = theta[i] - eps;
            let lm = micro_loss(&arch, &tp, &target, &batch, true);
            let fd = (lp - lm) / (2.0 * eps);
            assert!(
                (fd - grad[i]).abs() < 2e-3,
                "param {i}: finite-diff {fd} vs analytic {}",
                grad[i]
            );
        }
    }

    #[test]
    fn rmsprop_matches_reference_formula() {
        let mut theta = vec![1.0f32, -2.0];
        let grad = vec![0.5f32, -0.25];
        let mut g = vec![0.1f32, 0.0];
        let mut s = vec![0.2f32, 0.1];
        rmsprop(&mut theta, &grad, &mut g, &mut s, 0.01);
        // Hand-computed from rmsprop_ref (alpha=0.95, eps=0.01).
        let g0 = 0.95 * 0.1 + 0.05 * 0.5;
        let s0 = 0.95 * 0.2 + 0.05 * 0.25;
        let p0 = 1.0 - 0.01 * 0.5 / (s0 - g0 * g0 + 0.01f32).sqrt();
        assert!((g[0] - g0).abs() < 1e-7);
        assert!((s[0] - s0).abs() < 1e-7);
        assert!((theta[0] - p0).abs() < 1e-7);
    }

    #[test]
    fn init_is_deterministic_and_bounded() {
        let arch = NetArch::by_name("tiny", 6).unwrap();
        let a = init_params(&arch, 0);
        let b = init_params(&arch, 0);
        assert_eq!(a, b);
        let c = init_params(&arch, 1);
        assert_ne!(a, c);
        // conv0 weights: fan_in = 8*8*4 = 256 -> |w| <= 1/16.
        assert!(a[..1024].iter().all(|v| v.abs() <= 1.0 / 16.0 + 1e-6));
        // conv0 bias is zero.
        assert!(a[1024..1028].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn im2col_col2im_roundtrip_shapes() {
        // 4x4x1 image, k=2, s=2 -> 2x2 output, kdim 4.
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut patches = vec![0.0f32; 4 * 4];
        im2col_sample(&x, 4, 4, 1, 2, 2, &mut patches);
        // First patch = top-left 2x2 block.
        assert_eq!(&patches[..4], &[0.0, 1.0, 4.0, 5.0]);
        // Scatter ones back: non-overlapping stride => all-ones image.
        let dp = vec![1.0f32; 16];
        let mut dx = vec![0.0f32; 16];
        col2im_sample(&dp, 4, 4, 1, 2, 2, &mut dx);
        assert!(dx.iter().all(|&v| v == 1.0));
    }
}
