//! Dense kernels for the native engine.
//!
//! Two implementations of each matmul:
//!
//! * **naive** — the reference loops (unchanged from the original engine);
//!   kept as the oracle the tiled versions are tested against and used by
//!   the serial golden reference (`runtime/golden.rs`).
//! * **tiled** — cache-blocked versions used on the hot path. Blocking
//!   reorders only *which output element is worked on when*; every output
//!   element's own accumulation sequence (ascending `k` for forward,
//!   ascending row index for gradient reductions, one self-contained dot
//!   for input gradients) is identical to the naive kernel, including the
//!   `av == 0.0` sparsity skip. The tiled kernels are therefore
//!   **bit-identical** to the naive ones — pinned elementwise in
//!   `tests/parallel_learner.rs`.
//!
//! All kernels evaluate f32 in a fixed order, so results are
//! bit-deterministic across runs and thread counts (rust/DESIGN.md §7).

/// k-dimension block: `TILE_K` rows of `b` (forward) / of `out` (weight
/// grads) stay cache-hot while the m dimension streams past them.
const TILE_K: usize = 128;
/// Output-column block for the dot-product kernel: `TILE_J` rows of the
/// transposed operand stay hot across all m rows.
const TILE_J: usize = 64;

// ---------------------------------------------------------------------------
// Naive reference kernels
// ---------------------------------------------------------------------------

/// out[M,N] += a[M,K] @ b[K,N] (i-k-j loop order; `out` must be zeroed by
/// the caller when accumulation is not wanted).
pub fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // post-ReLU activations are sparse
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// out[K,N] += a[M,K]^T @ b[M,N] (weight gradients).
pub fn matmul_at_b_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// out[M,N] = a[M,K] @ b[N,K]^T (input gradients; row-by-row dot products).
pub fn matmul_a_bt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (av, bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Cache-tiled kernels (bit-identical to the naive versions)
// ---------------------------------------------------------------------------

/// Tiled [`matmul_acc`]: blocks the k dimension so a `TILE_K × N` panel of
/// `b` is reused across all M rows instead of streaming the whole of `b`
/// once per row. Per output element the k order is unchanged (blocks ascend,
/// k ascends within a block), so results match the naive kernel bit-for-bit.
pub fn matmul_acc_tiled(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + TILE_K).min(k);
        for i in 0..m {
            let arow = &a[i * k + k0..i * k + k1];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kr, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[(k0 + kr) * n..(k0 + kr + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
        k0 = k1;
    }
}

/// Tiled [`matmul_at_b_acc`]: blocks the k (output-row) dimension so a
/// `TILE_K × N` panel of `out` stays hot while all M rows stream past it.
/// Each output element still accumulates in ascending m with the same
/// sparsity skip — bit-identical to the naive kernel.
pub fn matmul_at_b_acc_tiled(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + TILE_K).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let orow = &mut out[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
        k0 = k1;
    }
}

/// Tiled [`matmul_a_bt`]: blocks the output-column dimension so a
/// `TILE_J × K` panel of `b` is reused across all M rows. Every dot product
/// is self-contained, so results match the naive kernel bit-for-bit.
pub fn matmul_a_bt_tiled(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + TILE_J).min(n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for j in j0..j1 {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (av, bv) in arow.iter().zip(brow.iter()) {
                    acc += av * bv;
                }
                out[i * n + j] = acc;
            }
        }
        j0 = j1;
    }
}

// ---------------------------------------------------------------------------
// im2col / col2im (shared by the engine and the golden reference)
// ---------------------------------------------------------------------------

/// Extract one sample's im2col patch matrix `[OH*OW, k*k*C]`.
/// Patch column layout is `(ky*k + kx)*C + c`, matching the `[k,k,C,F]`
/// weight tensor reshaped to `[k*k*C, F]` (as in `model._im2col`).
pub fn im2col_sample(
    x: &[f32], // one sample, [H, W, C]
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    out: &mut [f32], // [OH*OW, kernel*kernel*c]
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kdim = kernel * kernel * c;
    debug_assert_eq!(out.len(), oh * ow * kdim);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * kdim;
            for ky in 0..kernel {
                let src = ((oy * stride + ky) * w + ox * stride) * c;
                let dst = row + ky * kernel * c;
                // kx and c are contiguous in both source and destination.
                out[dst..dst + kernel * c].copy_from_slice(&x[src..src + kernel * c]);
            }
        }
    }
}

/// Scatter-add one sample's patch gradients back to the input image
/// (transpose of [`im2col_sample`]).
pub fn col2im_sample(
    dpatches: &[f32], // [OH*OW, kernel*kernel*c]
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    dx: &mut [f32], // one sample, [H, W, C], caller-zeroed
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kdim = kernel * kernel * c;
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * kdim;
            for ky in 0..kernel {
                let dst = ((oy * stride + ky) * w + ox * stride) * c;
                let src = row + ky * kernel * c;
                for i in 0..kernel * c {
                    dx[dst + i] += dpatches[src + i];
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                // Mix in exact zeros so the sparsity-skip paths are hit.
                if rng.chance(0.25) {
                    0.0
                } else {
                    rng.range_f32(-2.0, 2.0)
                }
            })
            .collect()
    }

    #[test]
    fn tiled_kernels_match_naive_bitwise() {
        let mut rng = Rng::new(0xBEE5);
        // Shapes straddling the tile sizes in every dimension.
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 7, 5),
            (4, 128, 64),
            (5, 129, 65),
            (32, 300, 17),
            (2, 513, 130),
        ] {
            let a = randvec(&mut rng, m * k);
            let b_kn = randvec(&mut rng, k * n);
            let b_mn = randvec(&mut rng, m * n);
            let b_nk = randvec(&mut rng, n * k);
            let seed_out = randvec(&mut rng, m * n); // accumulate onto noise

            let mut naive = seed_out.clone();
            let mut tiled = seed_out.clone();
            matmul_acc(&a, &b_kn, &mut naive, m, k, n);
            matmul_acc_tiled(&a, &b_kn, &mut tiled, m, k, n);
            assert_eq!(bits(&naive), bits(&tiled), "matmul_acc {m}x{k}x{n}");

            let seed_kn = randvec(&mut rng, k * n);
            let mut naive = seed_kn.clone();
            let mut tiled = seed_kn.clone();
            matmul_at_b_acc(&a, &b_mn, &mut naive, m, k, n);
            matmul_at_b_acc_tiled(&a, &b_mn, &mut tiled, m, k, n);
            assert_eq!(bits(&naive), bits(&tiled), "matmul_at_b_acc {m}x{k}x{n}");

            let mut naive = vec![0.0f32; m * n];
            let mut tiled = vec![1.0f32; m * n]; // `=` kernel: prior junk ok
            matmul_a_bt(&a, &b_nk, &mut naive, m, k, n);
            matmul_a_bt_tiled(&a, &b_nk, &mut tiled, m, k, n);
            assert_eq!(bits(&naive), bits(&tiled), "matmul_a_bt {m}x{k}x{n}");
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn matmul_acc_small_known_answer() {
        // [1,2;3,4] @ [5,6;7,8] = [19,22;43,50]
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let mut out = [0.0f32; 4];
        matmul_acc_tiled(&a, &b, &mut out, 2, 2, 2);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn im2col_col2im_roundtrip_shapes() {
        // 4x4x1 image, k=2, s=2 -> 2x2 output, kdim 4.
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut patches = vec![0.0f32; 4 * 4];
        im2col_sample(&x, 4, 4, 1, 2, 2, &mut patches);
        // First patch = top-left 2x2 block.
        assert_eq!(&patches[..4], &[0.0, 1.0, 4.0, 5.0]);
        // Scatter ones back: non-overlapping stride => all-ones image.
        let dp = vec![1.0f32; 16];
        let mut dx = vec![0.0f32; 16];
        col2im_sample(&dp, 4, 4, 1, 2, 2, &mut dx);
        assert!(dx.iter().all(|&v| v == 1.0));
    }
}
