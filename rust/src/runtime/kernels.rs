//! Dense kernels for the native engine.
//!
//! Three tiers of each matmul (rust/DESIGN.md §12):
//!
//! * **naive** — the reference loops (unchanged from the original engine);
//!   kept as the oracle the tiled versions are tested against and used by
//!   the serial golden reference (`runtime/golden.rs`).
//! * **tiled** — cache-blocked versions used on the hot path when
//!   [`KernelMode::Deterministic`] (the default) is selected. Blocking
//!   reorders only *which output element is worked on when*; every output
//!   element's own accumulation sequence (ascending `k` for forward,
//!   ascending row index for gradient reductions, one self-contained dot
//!   for input gradients) is identical to the naive kernel, including the
//!   `av == 0.0` sparsity skip. The tiled kernels are therefore
//!   **bit-identical** to the naive ones — pinned elementwise in
//!   `tests/parallel_learner.rs`.
//! * **fast** — explicitly lane-structured versions used when
//!   [`KernelMode::Fast`] is selected. These *reassociate* each output
//!   element's reduction into a fixed number of independent accumulator
//!   lanes ([`FAST_LANES`]-wide split dots, [`FAST_RANK`]-wide fused
//!   rank-updates) so the inner loops are straight-line independent FMAs
//!   that LLVM auto-vectorizes on stable Rust (no `portable_simd`). The
//!   result is *not* bit-identical to the deterministic tier; instead it
//!   carries a **bounded-divergence contract** — property-tested against
//!   the naive oracle on random shapes (`tests/kernel_modes.rs`) with a
//!   first-order rounding bound `|fast − det| ≤ c·t·ε·Σ|termᵢ|` where `t`
//!   is the reduction length. The lane order itself is fixed, so fast
//!   kernels are still bit-deterministic run-to-run and across thread
//!   counts; only the deterministic↔fast cross-mode identity is relaxed.
//!
//! All kernels evaluate f32 in a fixed order, so results are
//! bit-deterministic across runs and thread counts (rust/DESIGN.md §7).

use anyhow::{bail, Result};

/// k-dimension block: `TILE_K` rows of `b` (forward) / of `out` (weight
/// grads) stay cache-hot while the m dimension streams past them.
const TILE_K: usize = 128;
/// Output-column block for the dot-product kernel: `TILE_J` rows of the
/// transposed operand stay hot across all m rows.
const TILE_J: usize = 64;

// ---------------------------------------------------------------------------
// Naive reference kernels
// ---------------------------------------------------------------------------

/// out[M,N] += a[M,K] @ b[K,N] (i-k-j loop order; `out` must be zeroed by
/// the caller when accumulation is not wanted).
pub fn matmul_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue; // post-ReLU activations are sparse
            }
            let brow = &b[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// out[K,N] += a[M,K]^T @ b[M,N] (weight gradients).
pub fn matmul_at_b_acc(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let brow = &b[i * n..(i + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// out[M,N] = a[M,K] @ b[N,K]^T (input gradients; row-by-row dot products).
pub fn matmul_a_bt(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        for j in 0..n {
            let brow = &b[j * k..(j + 1) * k];
            let mut acc = 0.0f32;
            for (av, bv) in arow.iter().zip(brow.iter()) {
                acc += av * bv;
            }
            out[i * n + j] = acc;
        }
    }
}

// ---------------------------------------------------------------------------
// Cache-tiled kernels (bit-identical to the naive versions)
// ---------------------------------------------------------------------------

/// Tiled [`matmul_acc`]: blocks the k dimension so a `TILE_K × N` panel of
/// `b` is reused across all M rows instead of streaming the whole of `b`
/// once per row. Per output element the k order is unchanged (blocks ascend,
/// k ascends within a block), so results match the naive kernel bit-for-bit.
pub fn matmul_acc_tiled(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + TILE_K).min(k);
        for i in 0..m {
            let arow = &a[i * k + k0..i * k + k1];
            let orow = &mut out[i * n..(i + 1) * n];
            for (kr, &av) in arow.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let brow = &b[(k0 + kr) * n..(k0 + kr + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
        k0 = k1;
    }
}

/// Tiled [`matmul_at_b_acc`]: blocks the k (output-row) dimension so a
/// `TILE_K × N` panel of `out` stays hot while all M rows stream past it.
/// Each output element still accumulates in ascending m with the same
/// sparsity skip — bit-identical to the naive kernel.
pub fn matmul_at_b_acc_tiled(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    let mut k0 = 0;
    while k0 < k {
        let k1 = (k0 + TILE_K).min(k);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            let brow = &b[i * n..(i + 1) * n];
            for kk in k0..k1 {
                let av = arow[kk];
                if av == 0.0 {
                    continue;
                }
                let orow = &mut out[kk * n..(kk + 1) * n];
                for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                    *o += av * bv;
                }
            }
        }
        k0 = k1;
    }
}

/// Tiled [`matmul_a_bt`]: blocks the output-column dimension so a
/// `TILE_J × K` panel of `b` is reused across all M rows. Every dot product
/// is self-contained, so results match the naive kernel bit-for-bit.
pub fn matmul_a_bt_tiled(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + TILE_J).min(n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for j in j0..j1 {
                let brow = &b[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for (av, bv) in arow.iter().zip(brow.iter()) {
                    acc += av * bv;
                }
                out[i * n + j] = acc;
            }
        }
        j0 = j1;
    }
}

// ---------------------------------------------------------------------------
// Kernel mode selection
// ---------------------------------------------------------------------------

/// Which kernel tier the engine dispatches to (`kernel_mode` knob).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum KernelMode {
    /// Serial-order tiled kernels — bit-identical to the naive oracle and
    /// therefore to every golden / equivalence-matrix pin. The default.
    #[default]
    Deterministic,
    /// Lane-reordered kernels — faster, bounded divergence from the
    /// deterministic tier, still bit-deterministic run-to-run.
    Fast,
}

impl KernelMode {
    pub const ALL: [KernelMode; 2] = [KernelMode::Deterministic, KernelMode::Fast];

    pub fn parse(s: &str) -> Result<KernelMode> {
        match s {
            "deterministic" | "det" => Ok(KernelMode::Deterministic),
            "fast" | "simd" => Ok(KernelMode::Fast),
            other => bail!("unknown kernel_mode '{other}' (expected deterministic|fast)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            KernelMode::Deterministic => "deterministic",
            KernelMode::Fast => "fast",
        }
    }
}

// ---------------------------------------------------------------------------
// Fast (lane-reordered) kernels
// ---------------------------------------------------------------------------

/// Independent accumulator lanes in the fast dot kernel. Eight f32 lanes is
/// one AVX2 register; the tree reduction at the end is a fixed association,
/// so the kernel stays run-to-run deterministic at any actual vector width
/// the backend picks (lane-count invariance is what the divergence tests
/// pin, not the emitted asm).
pub const FAST_LANES: usize = 8;

/// Fusion width of the fast rank-update kernels: four rank-1 updates are
/// combined into one pass over the output row, giving the autovectorizer
/// four independent FMAs per output element per loop iteration.
pub const FAST_RANK: usize = 4;

/// `out[j] += (c0·r0[j] + c1·r1[j]) + (c2·r2[j] + c3·r3[j])` — the fused
/// rank-4 step shared by the fast accumulation kernels and the fast
/// Phase-B gradient reduction in `runtime/native.rs`. The association is
/// fixed, so the result depends only on the inputs, never on the caller's
/// thread layout.
#[inline]
pub fn axpy4(out: &mut [f32], c: [f32; 4], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32]) {
    let n = out.len();
    let (r0, r1, r2, r3) = (&r0[..n], &r1[..n], &r2[..n], &r3[..n]);
    for j in 0..n {
        out[j] += (c[0] * r0[j] + c[1] * r1[j]) + (c[2] * r2[j] + c[3] * r3[j]);
    }
}

/// [`FAST_LANES`]-lane split dot product with a fixed tree reduction and a
/// serial scalar tail. Divergence from the serial dot is bounded by the
/// usual first-order reassociation error `O(k·ε·Σ|aᵢbᵢ|)`.
#[inline]
pub fn dot8(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let (a, b) = (&a[..n], &b[..n]);
    let mut lanes = [0.0f32; FAST_LANES];
    let blocks = n / FAST_LANES;
    for blk in 0..blocks {
        let base = blk * FAST_LANES;
        for (l, lane) in lanes.iter_mut().enumerate() {
            *lane += a[base + l] * b[base + l];
        }
    }
    let mut tail = 0.0f32;
    for j in blocks * FAST_LANES..n {
        tail += a[j] * b[j];
    }
    ((lanes[0] + lanes[1]) + (lanes[2] + lanes[3]))
        + ((lanes[4] + lanes[5]) + (lanes[6] + lanes[7]))
        + tail
}

/// Fast [`matmul_acc`]: k is consumed in [`FAST_RANK`]-wide blocks, each a
/// single fused pass over the output row. A block is skipped only when all
/// four coefficients are exactly zero (the post-ReLU sparsity skip,
/// coarsened to block granularity); the scalar tail keeps the serial skip.
pub fn matmul_acc_fast(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for i in 0..m {
        let arow = &a[i * k..(i + 1) * k];
        let orow = &mut out[i * n..(i + 1) * n];
        let mut kk = 0;
        while kk + FAST_RANK <= k {
            let c = [arow[kk], arow[kk + 1], arow[kk + 2], arow[kk + 3]];
            if c != [0.0; FAST_RANK] {
                axpy4(
                    orow,
                    c,
                    &b[kk * n..],
                    &b[(kk + 1) * n..],
                    &b[(kk + 2) * n..],
                    &b[(kk + 3) * n..],
                );
            }
            kk += FAST_RANK;
        }
        for kr in kk..k {
            let av = arow[kr];
            if av == 0.0 {
                continue;
            }
            let brow = &b[kr * n..(kr + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// Fast [`matmul_at_b_acc`]: samples (m) are consumed in [`FAST_RANK`]-wide
/// groups, so each pass over the `[K,N]` output fuses four rank-1 gradient
/// contributions instead of one.
pub fn matmul_at_b_acc_fast(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), m * n);
    debug_assert_eq!(out.len(), k * n);
    let mut i = 0;
    while i + FAST_RANK <= m {
        let a0 = &a[i * k..(i + 1) * k];
        let a1 = &a[(i + 1) * k..(i + 2) * k];
        let a2 = &a[(i + 2) * k..(i + 3) * k];
        let a3 = &a[(i + 3) * k..(i + 4) * k];
        let b0 = &b[i * n..(i + 1) * n];
        let b1 = &b[(i + 1) * n..(i + 2) * n];
        let b2 = &b[(i + 2) * n..(i + 3) * n];
        let b3 = &b[(i + 3) * n..(i + 4) * n];
        for kk in 0..k {
            let c = [a0[kk], a1[kk], a2[kk], a3[kk]];
            if c != [0.0; FAST_RANK] {
                axpy4(&mut out[kk * n..(kk + 1) * n], c, b0, b1, b2, b3);
            }
        }
        i += FAST_RANK;
    }
    for ir in i..m {
        let arow = &a[ir * k..(ir + 1) * k];
        let brow = &b[ir * n..(ir + 1) * n];
        for (kk, &av) in arow.iter().enumerate() {
            if av == 0.0 {
                continue;
            }
            let orow = &mut out[kk * n..(kk + 1) * n];
            for (o, &bv) in orow.iter_mut().zip(brow.iter()) {
                *o += av * bv;
            }
        }
    }
}

/// Fast [`matmul_a_bt`]: same `TILE_J` output blocking as the tiled kernel
/// (each dot is self-contained), but every dot runs through the
/// [`dot8`] lane-split reduction.
pub fn matmul_a_bt_fast(a: &[f32], b: &[f32], out: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), n * k);
    debug_assert_eq!(out.len(), m * n);
    let mut j0 = 0;
    while j0 < n {
        let j1 = (j0 + TILE_J).min(n);
        for i in 0..m {
            let arow = &a[i * k..(i + 1) * k];
            for j in j0..j1 {
                out[i * n + j] = dot8(arow, &b[j * k..(j + 1) * k]);
            }
        }
        j0 = j1;
    }
}

// ---------------------------------------------------------------------------
// Mode dispatch (the engine's single entry points)
// ---------------------------------------------------------------------------

/// [`matmul_acc`] dispatched by kernel tier.
#[inline]
pub fn matmul_acc_mode(
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    match mode {
        KernelMode::Deterministic => matmul_acc_tiled(a, b, out, m, k, n),
        KernelMode::Fast => matmul_acc_fast(a, b, out, m, k, n),
    }
}

/// [`matmul_at_b_acc`] dispatched by kernel tier.
#[inline]
pub fn matmul_at_b_acc_mode(
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    match mode {
        KernelMode::Deterministic => matmul_at_b_acc_tiled(a, b, out, m, k, n),
        KernelMode::Fast => matmul_at_b_acc_fast(a, b, out, m, k, n),
    }
}

/// [`matmul_a_bt`] dispatched by kernel tier.
#[inline]
pub fn matmul_a_bt_mode(
    mode: KernelMode,
    a: &[f32],
    b: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
) {
    match mode {
        KernelMode::Deterministic => matmul_a_bt_tiled(a, b, out, m, k, n),
        KernelMode::Fast => matmul_a_bt_fast(a, b, out, m, k, n),
    }
}

// ---------------------------------------------------------------------------
// im2col / col2im (shared by the engine and the golden reference)
// ---------------------------------------------------------------------------

/// Extract one sample's im2col patch matrix `[OH*OW, k*k*C]`.
/// Patch column layout is `(ky*k + kx)*C + c`, matching the `[k,k,C,F]`
/// weight tensor reshaped to `[k*k*C, F]` (as in `model._im2col`).
pub fn im2col_sample(
    x: &[f32], // one sample, [H, W, C]
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    out: &mut [f32], // [OH*OW, kernel*kernel*c]
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kdim = kernel * kernel * c;
    debug_assert_eq!(out.len(), oh * ow * kdim);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * kdim;
            for ky in 0..kernel {
                let src = ((oy * stride + ky) * w + ox * stride) * c;
                let dst = row + ky * kernel * c;
                // kx and c are contiguous in both source and destination.
                out[dst..dst + kernel * c].copy_from_slice(&x[src..src + kernel * c]);
            }
        }
    }
}

/// Scatter-add one sample's patch gradients back to the input image
/// (transpose of [`im2col_sample`]).
pub fn col2im_sample(
    dpatches: &[f32], // [OH*OW, kernel*kernel*c]
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    dx: &mut [f32], // one sample, [H, W, C], caller-zeroed
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kdim = kernel * kernel * c;
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * kdim;
            for ky in 0..kernel {
                let dst = ((oy * stride + ky) * w + ox * stride) * c;
                let src = row + ky * kernel * c;
                for i in 0..kernel * c {
                    dx[dst + i] += dpatches[src + i];
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Patch-free (implicit-GEMM) convolution kernels (rust/DESIGN.md §13)
// ---------------------------------------------------------------------------
//
// The kernels below walk the im2col patch geometry *in place* instead of
// materializing the `[OH*OW, k*k*C]` patch matrix. The virtual patch
// column index is `kk = (ky*k + kx)*C + c` — for a fixed `ky` the columns
// `ky*k*C .. (ky+1)*k*C` are one contiguous run of the input image, which
// is exactly the run `im2col_sample` copies. Each kernel reproduces the
// per-output-element accumulation order of its im2col+matmul counterpart
// *term for term* (including the `av == 0.0` sparsity skip and, for the
// fast tier, the rank-4 / 8-lane groupings), so the deterministic tier is
// **bitwise identical** to `im2col_sample` + the tiled matmuls, and the
// fast tier is bitwise identical to `im2col_sample` + the fast matmuls —
// only the patch buffer and its memory traffic disappear.
//
// Contract: `debug_assert!`s are hoisted to function entry; the inner
// loops carry none (CI lints this — a failed shape check must fire before
// the first multiply, and asserts inside the hot loops defeat the
// autovectorizer).

/// Patch-free conv forward for one sample: `y[OH*OW, F] += x ⊛ w`.
/// `x` is `[H, W, C]`, `wmat` is the `[k,k,C,F]` weight tensor viewed as
/// `[k*k*C, F]`, `y` is caller-zeroed (or carries an accumulation seed).
/// Per output element this accumulates over ascending `kk` with the
/// post-ReLU sparsity skip — bitwise identical to
/// [`im2col_sample`] + [`matmul_acc_tiled`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward(
    x: &[f32],
    wmat: &[f32],
    y: &mut [f32],
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    filters: usize,
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kc = kernel * c;
    debug_assert_eq!(x.len(), h * w * c);
    debug_assert_eq!(wmat.len(), kernel * kc * filters);
    debug_assert_eq!(y.len(), oh * ow * filters);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let orow = &mut y[row * filters..(row + 1) * filters];
            for ky in 0..kernel {
                let src = ((oy * stride + ky) * w + ox * stride) * c;
                let seg = &x[src..src + kc];
                let wbase = ky * kc;
                for (t, &av) in seg.iter().enumerate() {
                    if av == 0.0 {
                        continue; // post-ReLU activations are sparse
                    }
                    let wrow = &wmat[(wbase + t) * filters..(wbase + t + 1) * filters];
                    for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                        *o += av * wv;
                    }
                }
            }
        }
    }
}

/// Fast-tier [`conv2d_forward`]: consumes the virtual patch row in
/// [`FAST_RANK`]-wide blocks (skipped only when all four coefficients are
/// exactly zero) with a serial scalar tail — the same association as
/// [`matmul_acc_fast`] over the materialized patches, so the two are
/// bitwise identical.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_forward_fast(
    x: &[f32],
    wmat: &[f32],
    y: &mut [f32],
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    filters: usize,
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kc = kernel * c;
    let kdim = kernel * kc;
    debug_assert_eq!(x.len(), h * w * c);
    debug_assert_eq!(wmat.len(), kdim * filters);
    debug_assert_eq!(y.len(), oh * ow * filters);
    // Per-pixel base offsets of each kernel row's contiguous input run;
    // the virtual patch value at column kk is x[srcs[kk / kc] + kk % kc].
    let mut srcs = vec![0usize; kernel];
    for oy in 0..oh {
        for ox in 0..ow {
            for (ky, s) in srcs.iter_mut().enumerate() {
                *s = ((oy * stride + ky) * w + ox * stride) * c;
            }
            let row = oy * ow + ox;
            let orow = &mut y[row * filters..(row + 1) * filters];
            let pv = |kk: usize| x[srcs[kk / kc] + kk % kc];
            let mut kk = 0;
            while kk + FAST_RANK <= kdim {
                let cf = [pv(kk), pv(kk + 1), pv(kk + 2), pv(kk + 3)];
                if cf != [0.0; FAST_RANK] {
                    axpy4(
                        orow,
                        cf,
                        &wmat[kk * filters..],
                        &wmat[(kk + 1) * filters..],
                        &wmat[(kk + 2) * filters..],
                        &wmat[(kk + 3) * filters..],
                    );
                }
                kk += FAST_RANK;
            }
            for kr in kk..kdim {
                let av = pv(kr);
                if av == 0.0 {
                    continue;
                }
                let wrow = &wmat[kr * filters..(kr + 1) * filters];
                for (o, &wv) in orow.iter_mut().zip(wrow.iter()) {
                    *o += av * wv;
                }
            }
        }
    }
}

/// col2im-free conv input gradient for one sample:
/// `dx[H, W, C] += dy ⊛ wᵀ`. `dy` is `[OH*OW, F]`, `dx` is caller-zeroed.
/// Each scattered term is a self-contained serial dot over `f`, added in
/// `(patch row, ky, t)` order — bitwise identical to
/// [`matmul_a_bt_tiled`] + [`col2im_sample`] (the dots are value-equal
/// and the scatter-add order is exactly col2im's).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_input_grad(
    dy: &[f32],
    wmat: &[f32],
    dx: &mut [f32],
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    filters: usize,
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kc = kernel * c;
    debug_assert_eq!(dy.len(), oh * ow * filters);
    debug_assert_eq!(wmat.len(), kernel * kc * filters);
    debug_assert_eq!(dx.len(), h * w * c);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let drow = &dy[row * filters..(row + 1) * filters];
            for ky in 0..kernel {
                let dst = ((oy * stride + ky) * w + ox * stride) * c;
                let seg = &mut dx[dst..dst + kc];
                let wbase = ky * kc;
                for (t, d) in seg.iter_mut().enumerate() {
                    let wrow = &wmat[(wbase + t) * filters..(wbase + t + 1) * filters];
                    let mut acc = 0.0f32;
                    for (dv, wv) in drow.iter().zip(wrow.iter()) {
                        acc += dv * wv;
                    }
                    *d += acc;
                }
            }
        }
    }
}

/// Fast-tier [`conv2d_input_grad`]: every dot runs through the [`dot8`]
/// lane-split reduction (the association [`matmul_a_bt_fast`] uses), the
/// scatter-add order is unchanged — bitwise identical to
/// [`matmul_a_bt_fast`] + [`col2im_sample`].
#[allow(clippy::too_many_arguments)]
pub fn conv2d_input_grad_fast(
    dy: &[f32],
    wmat: &[f32],
    dx: &mut [f32],
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    filters: usize,
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kc = kernel * c;
    debug_assert_eq!(dy.len(), oh * ow * filters);
    debug_assert_eq!(wmat.len(), kernel * kc * filters);
    debug_assert_eq!(dx.len(), h * w * c);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let drow = &dy[row * filters..(row + 1) * filters];
            for ky in 0..kernel {
                let dst = ((oy * stride + ky) * w + ox * stride) * c;
                let seg = &mut dx[dst..dst + kc];
                let wbase = ky * kc;
                for (t, d) in seg.iter_mut().enumerate() {
                    *d += dot8(drow, &wmat[(wbase + t) * filters..(wbase + t + 1) * filters]);
                }
            }
        }
    }
}

/// One sample's contribution to conv weight-gradient rows
/// `[k_lo, k_hi)` of the `[k*k*C, F]` gradient (`chunk`), read directly
/// from the input image `x` (`[H, W, C]`) instead of retained patches.
/// Walks patch rows in ascending order and, within each row, ascending
/// `kk` with the sparsity skip — bitwise identical to the retained-patch
/// Phase B reduction (and, over the full `[0, k*k*C)` range, to
/// [`matmul_at_b_acc_tiled`] on the materialized patch matrix).
#[allow(clippy::too_many_arguments)]
pub fn conv2d_weight_grad_chunk(
    x: &[f32],
    dy: &[f32],
    chunk: &mut [f32],
    k_lo: usize,
    k_hi: usize,
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    filters: usize,
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kc = kernel * c;
    debug_assert!(k_lo <= k_hi && k_hi <= kernel * kc);
    debug_assert_eq!(x.len(), h * w * c);
    debug_assert_eq!(dy.len(), oh * ow * filters);
    debug_assert_eq!(chunk.len(), (k_hi - k_lo) * filters);
    let ky_lo = k_lo / kc;
    let ky_hi = k_hi.div_ceil(kc);
    for oy in 0..oh {
        for ox in 0..ow {
            let row = oy * ow + ox;
            let drow = &dy[row * filters..(row + 1) * filters];
            for ky in ky_lo..ky_hi {
                let seg_lo = (ky * kc).max(k_lo);
                let seg_hi = ((ky + 1) * kc).min(k_hi);
                let src = ((oy * stride + ky) * w + ox * stride) * c + (seg_lo - ky * kc);
                let seg = &x[src..src + (seg_hi - seg_lo)];
                for (idx, &av) in seg.iter().enumerate() {
                    if av == 0.0 {
                        continue;
                    }
                    let kk = seg_lo + idx;
                    let orow = &mut chunk[(kk - k_lo) * filters..(kk - k_lo + 1) * filters];
                    for (o, &dv) in orow.iter_mut().zip(drow.iter()) {
                        *o += av * dv;
                    }
                }
            }
        }
    }
}

/// Fast-tier [`conv2d_weight_grad_chunk`]: patch rows are consumed in
/// [`FAST_RANK`]-wide groups *within the sample* (independent of any
/// shard layout), each group a fused [`axpy4`] pass, with a serial tail —
/// the same association as the retained-patch fast Phase B arm, so the
/// two are bitwise identical.
#[allow(clippy::too_many_arguments)]
pub fn conv2d_weight_grad_chunk_fast(
    x: &[f32],
    dy: &[f32],
    chunk: &mut [f32],
    k_lo: usize,
    k_hi: usize,
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    filters: usize,
) {
    let oh = (h - kernel) / stride + 1;
    let ow = (w - kernel) / stride + 1;
    let kc = kernel * c;
    debug_assert!(k_lo <= k_hi && k_hi <= kernel * kc);
    debug_assert_eq!(x.len(), h * w * c);
    debug_assert_eq!(dy.len(), oh * ow * filters);
    debug_assert_eq!(chunk.len(), (k_hi - k_lo) * filters);
    let ky_lo = k_lo / kc;
    let ky_hi = k_hi.div_ceil(kc);
    let nrow = oh * ow;
    // Base offset of patch row `row`'s kernel-row `ky` run in `x`.
    let base = |row: usize, ky: usize| {
        let (oy, ox) = (row / ow, row % ow);
        ((oy * stride + ky) * w + ox * stride) * c
    };
    let mut row = 0;
    while row + FAST_RANK <= nrow {
        let d0 = &dy[row * filters..(row + 1) * filters];
        let d1 = &dy[(row + 1) * filters..(row + 2) * filters];
        let d2 = &dy[(row + 2) * filters..(row + 3) * filters];
        let d3 = &dy[(row + 3) * filters..(row + 4) * filters];
        for ky in ky_lo..ky_hi {
            let seg_lo = (ky * kc).max(k_lo);
            let seg_hi = ((ky + 1) * kc).min(k_hi);
            let off = seg_lo - ky * kc;
            let (b0, b1) = (base(row, ky) + off, base(row + 1, ky) + off);
            let (b2, b3) = (base(row + 2, ky) + off, base(row + 3, ky) + off);
            for idx in 0..seg_hi - seg_lo {
                let cf = [x[b0 + idx], x[b1 + idx], x[b2 + idx], x[b3 + idx]];
                if cf != [0.0; FAST_RANK] {
                    let kk = seg_lo + idx;
                    axpy4(
                        &mut chunk[(kk - k_lo) * filters..(kk - k_lo + 1) * filters],
                        cf,
                        d0,
                        d1,
                        d2,
                        d3,
                    );
                }
            }
        }
        row += FAST_RANK;
    }
    while row < nrow {
        let drow = &dy[row * filters..(row + 1) * filters];
        for ky in ky_lo..ky_hi {
            let seg_lo = (ky * kc).max(k_lo);
            let seg_hi = ((ky + 1) * kc).min(k_hi);
            let src = base(row, ky) + (seg_lo - ky * kc);
            let seg = &x[src..src + (seg_hi - seg_lo)];
            for (idx, &av) in seg.iter().enumerate() {
                if av == 0.0 {
                    continue;
                }
                let kk = seg_lo + idx;
                let orow = &mut chunk[(kk - k_lo) * filters..(kk - k_lo + 1) * filters];
                for (o, &dv) in orow.iter_mut().zip(drow.iter()) {
                    *o += av * dv;
                }
            }
        }
        row += 1;
    }
}

/// [`conv2d_forward`] dispatched by kernel tier.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn conv2d_forward_mode(
    mode: KernelMode,
    x: &[f32],
    wmat: &[f32],
    y: &mut [f32],
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    filters: usize,
) {
    match mode {
        KernelMode::Deterministic => conv2d_forward(x, wmat, y, h, w, c, kernel, stride, filters),
        KernelMode::Fast => conv2d_forward_fast(x, wmat, y, h, w, c, kernel, stride, filters),
    }
}

/// [`conv2d_input_grad`] dispatched by kernel tier.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn conv2d_input_grad_mode(
    mode: KernelMode,
    dy: &[f32],
    wmat: &[f32],
    dx: &mut [f32],
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    filters: usize,
) {
    match mode {
        KernelMode::Deterministic => {
            conv2d_input_grad(dy, wmat, dx, h, w, c, kernel, stride, filters)
        }
        KernelMode::Fast => conv2d_input_grad_fast(dy, wmat, dx, h, w, c, kernel, stride, filters),
    }
}

/// [`conv2d_weight_grad_chunk`] dispatched by kernel tier.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn conv2d_weight_grad_chunk_mode(
    mode: KernelMode,
    x: &[f32],
    dy: &[f32],
    chunk: &mut [f32],
    k_lo: usize,
    k_hi: usize,
    h: usize,
    w: usize,
    c: usize,
    kernel: usize,
    stride: usize,
    filters: usize,
) {
    match mode {
        KernelMode::Deterministic => {
            conv2d_weight_grad_chunk(x, dy, chunk, k_lo, k_hi, h, w, c, kernel, stride, filters)
        }
        KernelMode::Fast => {
            conv2d_weight_grad_chunk_fast(x, dy, chunk, k_lo, k_hi, h, w, c, kernel, stride, filters)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize) -> Vec<f32> {
        (0..n)
            .map(|_| {
                // Mix in exact zeros so the sparsity-skip paths are hit.
                if rng.chance(0.25) {
                    0.0
                } else {
                    rng.range_f32(-2.0, 2.0)
                }
            })
            .collect()
    }

    #[test]
    fn tiled_kernels_match_naive_bitwise() {
        let mut rng = Rng::new(0xBEE5);
        // Shapes straddling the tile sizes in every dimension.
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 7, 5),
            (4, 128, 64),
            (5, 129, 65),
            (32, 300, 17),
            (2, 513, 130),
        ] {
            let a = randvec(&mut rng, m * k);
            let b_kn = randvec(&mut rng, k * n);
            let b_mn = randvec(&mut rng, m * n);
            let b_nk = randvec(&mut rng, n * k);
            let seed_out = randvec(&mut rng, m * n); // accumulate onto noise

            let mut naive = seed_out.clone();
            let mut tiled = seed_out.clone();
            matmul_acc(&a, &b_kn, &mut naive, m, k, n);
            matmul_acc_tiled(&a, &b_kn, &mut tiled, m, k, n);
            assert_eq!(bits(&naive), bits(&tiled), "matmul_acc {m}x{k}x{n}");

            let seed_kn = randvec(&mut rng, k * n);
            let mut naive = seed_kn.clone();
            let mut tiled = seed_kn.clone();
            matmul_at_b_acc(&a, &b_mn, &mut naive, m, k, n);
            matmul_at_b_acc_tiled(&a, &b_mn, &mut tiled, m, k, n);
            assert_eq!(bits(&naive), bits(&tiled), "matmul_at_b_acc {m}x{k}x{n}");

            let mut naive = vec![0.0f32; m * n];
            let mut tiled = vec![1.0f32; m * n]; // `=` kernel: prior junk ok
            matmul_a_bt(&a, &b_nk, &mut naive, m, k, n);
            matmul_a_bt_tiled(&a, &b_nk, &mut tiled, m, k, n);
            assert_eq!(bits(&naive), bits(&tiled), "matmul_a_bt {m}x{k}x{n}");
        }
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn matmul_acc_small_known_answer() {
        // [1,2;3,4] @ [5,6;7,8] = [19,22;43,50]
        let a = [1.0f32, 2.0, 3.0, 4.0];
        let b = [5.0f32, 6.0, 7.0, 8.0];
        let mut out = [0.0f32; 4];
        matmul_acc_tiled(&a, &b, &mut out, 2, 2, 2);
        assert_eq!(out, [19.0, 22.0, 43.0, 50.0]);
    }

    #[test]
    fn kernel_mode_parse_and_name_roundtrip() {
        for mode in KernelMode::ALL {
            assert_eq!(KernelMode::parse(mode.name()).unwrap(), mode);
        }
        assert_eq!(KernelMode::parse("det").unwrap(), KernelMode::Deterministic);
        assert_eq!(KernelMode::parse("simd").unwrap(), KernelMode::Fast);
        assert!(KernelMode::parse("turbo").is_err());
        assert_eq!(KernelMode::default(), KernelMode::Deterministic);
    }

    /// First-order reassociation bound for a length-`t` f32 reduction whose
    /// terms have absolute sum `s`: any two summation orders agree to within
    /// `O(t·ε·s)`; the factor 4 gives slack for the product roundings.
    fn reassoc_tol(t: usize, s: f32) -> f32 {
        4.0 * (t as f32) * f32::EPSILON * s + f32::MIN_POSITIVE
    }

    #[test]
    fn fast_kernels_match_naive_within_reassociation_bound() {
        let mut rng = Rng::new(0xFA57);
        for &(m, k, n) in &[
            (1usize, 1usize, 1usize),
            (3, 7, 5),
            (4, 128, 64),
            (5, 129, 65),
            (32, 300, 17),
            (2, 513, 130),
        ] {
            let a = randvec(&mut rng, m * k);
            let b_kn = randvec(&mut rng, k * n);
            let b_mn = randvec(&mut rng, m * n);
            let b_nk = randvec(&mut rng, n * k);
            let seed_mn = randvec(&mut rng, m * n);
            let seed_kn = randvec(&mut rng, k * n);

            let mut det = seed_mn.clone();
            let mut fast = seed_mn.clone();
            matmul_acc(&a, &b_kn, &mut det, m, k, n);
            matmul_acc_fast(&a, &b_kn, &mut fast, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    let mut s = seed_mn[i * n + j].abs();
                    for kk in 0..k {
                        s += (a[i * k + kk] * b_kn[kk * n + j]).abs();
                    }
                    let (d, f) = (det[i * n + j], fast[i * n + j]);
                    assert!(
                        (d - f).abs() <= reassoc_tol(k + 1, s),
                        "matmul_acc {m}x{k}x{n} [{i},{j}]: det {d} fast {f}"
                    );
                }
            }

            let mut det = seed_kn.clone();
            let mut fast = seed_kn.clone();
            matmul_at_b_acc(&a, &b_mn, &mut det, m, k, n);
            matmul_at_b_acc_fast(&a, &b_mn, &mut fast, m, k, n);
            for kk in 0..k {
                for j in 0..n {
                    let mut s = seed_kn[kk * n + j].abs();
                    for i in 0..m {
                        s += (a[i * k + kk] * b_mn[i * n + j]).abs();
                    }
                    let (d, f) = (det[kk * n + j], fast[kk * n + j]);
                    assert!(
                        (d - f).abs() <= reassoc_tol(m + 1, s),
                        "matmul_at_b_acc {m}x{k}x{n} [{kk},{j}]: det {d} fast {f}"
                    );
                }
            }

            let mut det = vec![0.0f32; m * n];
            let mut fast = vec![f32::NAN; m * n]; // `=` kernel: junk overwritten
            matmul_a_bt(&a, &b_nk, &mut det, m, k, n);
            matmul_a_bt_fast(&a, &b_nk, &mut fast, m, k, n);
            for i in 0..m {
                for j in 0..n {
                    let mut s = 0.0f32;
                    for kk in 0..k {
                        s += (a[i * k + kk] * b_nk[j * k + kk]).abs();
                    }
                    let (d, f) = (det[i * n + j], fast[i * n + j]);
                    assert!(
                        (d - f).abs() <= reassoc_tol(k, s),
                        "matmul_a_bt {m}x{k}x{n} [{i},{j}]: det {d} fast {f}"
                    );
                }
            }
        }
    }

    #[test]
    fn fast_kernels_are_bit_deterministic_run_to_run() {
        let mut rng = Rng::new(0xD07);
        let (m, k, n) = (7, 130, 33);
        let a = randvec(&mut rng, m * k);
        let b_kn = randvec(&mut rng, k * n);
        let b_mn = randvec(&mut rng, m * n);
        let b_nk = randvec(&mut rng, n * k);
        let seed_mn = randvec(&mut rng, m * n);
        let seed_kn = randvec(&mut rng, k * n);
        for _ in 0..2 {
            let mut x1 = seed_mn.clone();
            let mut x2 = seed_mn.clone();
            matmul_acc_fast(&a, &b_kn, &mut x1, m, k, n);
            matmul_acc_fast(&a, &b_kn, &mut x2, m, k, n);
            assert_eq!(bits(&x1), bits(&x2), "matmul_acc_fast repeat");

            let mut y1 = seed_kn.clone();
            let mut y2 = seed_kn.clone();
            matmul_at_b_acc_fast(&a, &b_mn, &mut y1, m, k, n);
            matmul_at_b_acc_fast(&a, &b_mn, &mut y2, m, k, n);
            assert_eq!(bits(&y1), bits(&y2), "matmul_at_b_acc_fast repeat");

            let mut z1 = vec![0.0f32; m * n];
            let mut z2 = vec![0.0f32; m * n];
            matmul_a_bt_fast(&a, &b_nk, &mut z1, m, k, n);
            matmul_a_bt_fast(&a, &b_nk, &mut z2, m, k, n);
            assert_eq!(bits(&z1), bits(&z2), "matmul_a_bt_fast repeat");
        }
    }

    /// Generic L-lane split dot: the reference for lane-count invariance.
    fn dot_lanes<const L: usize>(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let mut lanes = [0.0f32; L];
        let blocks = n / L;
        for blk in 0..blocks {
            for (l, lane) in lanes.iter_mut().enumerate() {
                *lane += a[blk * L + l] * b[blk * L + l];
            }
        }
        let mut tail = 0.0f32;
        for j in blocks * L..n {
            tail += a[j] * b[j];
        }
        // Adjacent-pairwise tree over the lane array (L a power of two) —
        // for L = 8 this is exactly `dot8`'s fixed association.
        let mut width = L;
        while width > 1 {
            width /= 2;
            for l in 0..width {
                lanes[l] = lanes[2 * l] + lanes[2 * l + 1];
            }
        }
        lanes[0] + tail
    }

    #[test]
    fn dot8_is_lane_count_invariant_within_bound() {
        // The divergence contract may not depend on the physical vector
        // width: 4-, 8- and 16-lane splits of the same dot all agree within
        // the reassociation bound, and the 8-lane generic split reproduces
        // `dot8` exactly (same association tree).
        let mut rng = Rng::new(0x1A9E5);
        for len in [1usize, 7, 8, 9, 63, 64, 65, 300, 1024] {
            let a = randvec(&mut rng, len);
            let b = randvec(&mut rng, len);
            let s: f32 = a.iter().zip(&b).map(|(x, y)| (x * y).abs()).sum();
            let tol = reassoc_tol(len, s);
            let d8 = dot8(&a, &b);
            assert_eq!(d8.to_bits(), dot_lanes::<8>(&a, &b).to_bits(), "len {len}: dot8 tree");
            for dl in [dot_lanes::<4>(&a, &b), dot_lanes::<16>(&a, &b)] {
                assert!((d8 - dl).abs() <= tol, "len {len}: {d8} vs {dl} (tol {tol})");
            }
        }
    }

    #[test]
    fn im2col_col2im_roundtrip_shapes() {
        // 4x4x1 image, k=2, s=2 -> 2x2 output, kdim 4.
        let x: Vec<f32> = (0..16).map(|v| v as f32).collect();
        let mut patches = vec![0.0f32; 4 * 4];
        im2col_sample(&x, 4, 4, 1, 2, 2, &mut patches);
        // First patch = top-left 2x2 block.
        assert_eq!(&patches[..4], &[0.0, 1.0, 4.0, 5.0]);
        // Scatter ones back: non-overlapping stride => all-ones image.
        let dp = vec![1.0f32; 16];
        let mut dx = vec![0.0f32; 16];
        col2im_sample(&dp, 4, 4, 1, 2, 2, &mut dx);
        assert!(dx.iter().all(|&v| v == 1.0));
    }

    /// Geometries straddling the FAST_RANK / FAST_LANES boundaries in
    /// patch-row count, kdim, and filter count (incl. a single-patch-row
    /// case that exercises only the serial tails).
    const CONV_GEOMS: [(usize, usize, usize, usize, usize, usize); 5] = [
        // (h, w, c, kernel, stride, filters)
        (8, 8, 1, 3, 1, 5),   // nrow 36, kdim 9 (rank tail), odd filters
        (9, 7, 3, 2, 2, 8),   // uneven h/w, kdim 12, filters = FAST_LANES
        (10, 10, 4, 4, 2, 17), // kdim 64, filters straddle two lanes
        (6, 6, 2, 3, 3, 4),   // nrow 4 = one rank group exactly
        (5, 5, 1, 5, 1, 9),   // single patch row: serial tails only
    ];

    #[test]
    fn direct_conv_matches_im2col_pipeline_bitwise_det() {
        let mut rng = Rng::new(0xC0DE);
        for &(h, w, c, kernel, stride, filters) in &CONV_GEOMS {
            let oh = (h - kernel) / stride + 1;
            let ow = (w - kernel) / stride + 1;
            let (nrow, kdim) = (oh * ow, kernel * kernel * c);
            let x = randvec(&mut rng, h * w * c);
            let wmat = randvec(&mut rng, kdim * filters);
            let dy = randvec(&mut rng, nrow * filters);
            let mut patches = vec![0.0f32; nrow * kdim];
            im2col_sample(&x, h, w, c, kernel, stride, &mut patches);
            let tag = format!("{h}x{w}x{c} k{kernel}s{stride}f{filters}");

            // Forward: im2col + tiled matmul vs patch-free walk.
            let mut y_ref = vec![0.0f32; nrow * filters];
            matmul_acc_tiled(&patches, &wmat, &mut y_ref, nrow, kdim, filters);
            let mut y = vec![0.0f32; nrow * filters];
            conv2d_forward(&x, &wmat, &mut y, h, w, c, kernel, stride, filters);
            assert_eq!(bits(&y_ref), bits(&y), "fwd {tag}");

            // Input grad: tiled a@b^T + col2im vs col2im-free scatter.
            let mut dpatches = vec![0.0f32; nrow * kdim];
            matmul_a_bt_tiled(&dy, &wmat, &mut dpatches, nrow, filters, kdim);
            let mut dx_ref = vec![0.0f32; h * w * c];
            col2im_sample(&dpatches, h, w, c, kernel, stride, &mut dx_ref);
            let mut dx = vec![0.0f32; h * w * c];
            conv2d_input_grad(&dy, &wmat, &mut dx, h, w, c, kernel, stride, filters);
            assert_eq!(bits(&dx_ref), bits(&dx), "igrad {tag}");

            // Weight grad: tiled a^T@b on patches vs patch-free reduction,
            // full range and re-assembled from uneven row chunks (the
            // Phase B partition boundaries never hit kc multiples).
            let mut dw_ref = vec![0.0f32; kdim * filters];
            matmul_at_b_acc_tiled(&patches, &dy, &mut dw_ref, nrow, kdim, filters);
            let mut dw = vec![0.0f32; kdim * filters];
            conv2d_weight_grad_chunk(&x, &dy, &mut dw, 0, kdim, h, w, c, kernel, stride, filters);
            assert_eq!(bits(&dw_ref), bits(&dw), "wgrad {tag}");
            let splits = [0, kdim / 3, 2 * kdim / 3 + 1, kdim];
            let mut dw_chunked = vec![0.0f32; kdim * filters];
            for s in 0..3 {
                let (lo, hi) = (splits[s], splits[s + 1]);
                conv2d_weight_grad_chunk(
                    &x,
                    &dy,
                    &mut dw_chunked[lo * filters..hi * filters],
                    lo,
                    hi,
                    h,
                    w,
                    c,
                    kernel,
                    stride,
                    filters,
                );
            }
            assert_eq!(bits(&dw_ref), bits(&dw_chunked), "wgrad chunked {tag}");
        }
    }

    #[test]
    fn direct_conv_matches_im2col_pipeline_bitwise_fast() {
        let mut rng = Rng::new(0xFA57C0DE);
        for &(h, w, c, kernel, stride, filters) in &CONV_GEOMS {
            let oh = (h - kernel) / stride + 1;
            let ow = (w - kernel) / stride + 1;
            let (nrow, kdim) = (oh * ow, kernel * kernel * c);
            let x = randvec(&mut rng, h * w * c);
            let wmat = randvec(&mut rng, kdim * filters);
            let dy = randvec(&mut rng, nrow * filters);
            let mut patches = vec![0.0f32; nrow * kdim];
            im2col_sample(&x, h, w, c, kernel, stride, &mut patches);
            let tag = format!("{h}x{w}x{c} k{kernel}s{stride}f{filters}");

            let mut y_ref = vec![0.0f32; nrow * filters];
            matmul_acc_fast(&patches, &wmat, &mut y_ref, nrow, kdim, filters);
            let mut y = vec![0.0f32; nrow * filters];
            conv2d_forward_fast(&x, &wmat, &mut y, h, w, c, kernel, stride, filters);
            assert_eq!(bits(&y_ref), bits(&y), "fwd fast {tag}");

            let mut dpatches = vec![0.0f32; nrow * kdim];
            matmul_a_bt_fast(&dy, &wmat, &mut dpatches, nrow, filters, kdim);
            let mut dx_ref = vec![0.0f32; h * w * c];
            col2im_sample(&dpatches, h, w, c, kernel, stride, &mut dx_ref);
            let mut dx = vec![0.0f32; h * w * c];
            conv2d_input_grad_fast(&dy, &wmat, &mut dx, h, w, c, kernel, stride, filters);
            assert_eq!(bits(&dx_ref), bits(&dx), "igrad fast {tag}");

            let mut dw_ref = vec![0.0f32; kdim * filters];
            matmul_at_b_acc_fast(&patches, &dy, &mut dw_ref, nrow, kdim, filters);
            let mut dw = vec![0.0f32; kdim * filters];
            conv2d_weight_grad_chunk_fast(
                &x, &dy, &mut dw, 0, kdim, h, w, c, kernel, stride, filters,
            );
            assert_eq!(bits(&dw_ref), bits(&dw), "wgrad fast {tag}");
            let splits = [0, kdim / 3, 2 * kdim / 3 + 1, kdim];
            let mut dw_chunked = vec![0.0f32; kdim * filters];
            for s in 0..3 {
                let (lo, hi) = (splits[s], splits[s + 1]);
                conv2d_weight_grad_chunk_fast(
                    &x,
                    &dy,
                    &mut dw_chunked[lo * filters..hi * filters],
                    lo,
                    hi,
                    h,
                    w,
                    c,
                    kernel,
                    stride,
                    filters,
                );
            }
            assert_eq!(bits(&dw_ref), bits(&dw_chunked), "wgrad fast chunked {tag}");
        }
    }
}
