//! `QNet`: the Q-network runtime — flat parameter state + loaded entries.
//!
//! Owns the four flat parameter buffers (theta, theta_minus, RMSProp g/s) and
//! exposes exactly the operations the coordinator needs:
//!
//! * `infer`        — batched Q-values under theta or theta_minus
//! * `train_step`   — one full minibatch update (TD loss + centered RMSProp),
//!                    executed by the device's `train_b*` entry
//! * `sync_target`  — theta_minus <- theta (the target-network update)
//!
//! Concurrency model: theta_minus is an immutable snapshot swapped only at
//! sync points (`RwLock<Arc<..>>`), so W sampler threads read it without
//! contending with the trainer; the mutable train state (theta, g, s) lives
//! behind its own mutex owned by the trainer thread. This is precisely the
//! decoupling that makes the paper's Concurrent Training race-free.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, bail, Result};

use super::device::Device;
use super::engine::{EntrySchema, Head};
use super::manifest::{Manifest, NetSpec};
use super::tensor::TensorView;

struct TrainState {
    theta: Vec<f32>,
    g: Vec<f32>,
    s: Vec<f32>,
}

/// One training minibatch in host memory (assembled by the replay sampler).
///
/// `weights` and `boot_gammas` are the extended per-sample inputs of the
/// prioritized / n-step replay strategies (rust/DESIGN.md §11). Both empty
/// (the uniform 1-step path) selects the engine's historical 10-input
/// train entry — byte-for-byte the pre-strategy machine; both present
/// selects the 12-input entry: the loss and gradient of sample `b` are
/// scaled by `weights[b]`, and the bootstrap term uses the per-sample
/// discount `boot_gammas[b]` (γᵐ for an m-step window) in place of the
/// entry's scalar γ.
#[derive(Clone, Debug, Default)]
pub struct TrainBatch {
    pub states: Vec<u8>,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    pub next_states: Vec<u8>,
    pub dones: Vec<f32>,
    /// Importance-sampling weight per sample (empty = unweighted).
    pub weights: Vec<f32>,
    /// Bootstrap discount γᵐ per sample (empty = the entry's scalar γ).
    pub boot_gammas: Vec<f32>,
}

/// Result of one minibatch update.
#[derive(Clone, Debug, Default)]
pub struct TrainOutcome {
    /// Mean (weighted) Huber TD loss.
    pub loss: f32,
    /// Raw per-sample TD errors `q(s,a) - target` (priority updates).
    /// Empty when the engine does not report them.
    pub td_errors: Vec<f32>,
}

/// Which parameter set drives action selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Standard DQN: act with the online network theta.
    Theta,
    /// Concurrent Training: act with the target network theta_minus.
    ThetaMinus,
}

pub struct QNet {
    device: Arc<Device>,
    spec: NetSpec,
    train_key: String,
    train_batch: usize,
    infer_batches: Vec<usize>,
    theta_minus: RwLock<Arc<Vec<f32>>>,
    train: Mutex<TrainState>,
    pub train_steps: AtomicU64,
    pub target_syncs: AtomicU64,
}

impl QNet {
    /// Load a network config from the manifest with the default dqn head
    /// (see [`QNet::load_with_head`]).
    pub fn load(
        device: Arc<Device>,
        manifest: &Manifest,
        config: &str,
        double: bool,
        train_batch: usize,
    ) -> Result<QNet> {
        Self::load_with_head(device, manifest, config, double, train_batch, Head::Dqn)
    }

    /// Load a network config from the manifest under a head variant:
    /// registers every infer entry plus the chosen train entry with the
    /// device's engine, and initializes parameters from the manifest's
    /// deterministic blob (or the in-process equivalent when no artifacts
    /// exist). All engine keys and checkpoint identity use the
    /// head-qualified [`NetSpec::runtime_name`], so two heads over the same
    /// base config never alias.
    pub fn load_with_head(
        device: Arc<Device>,
        manifest: &Manifest,
        config: &str,
        double: bool,
        train_batch: usize,
        head: Head,
    ) -> Result<QNet> {
        let spec = manifest.config_with_head(config, head)?;
        let train_key = if double {
            format!("train_double_b{train_batch}")
        } else {
            format!("train_b{train_batch}")
        };

        // Validate the ABI before loading anything: every entry this QNet
        // will drive must exist in the manifest and agree field-for-field
        // with the named schema the engines enforce (rust/DESIGN.md §16).
        let infer_batches = spec.infer_batches();
        if infer_batches.is_empty() {
            bail!("config {config:?} has no infer entries");
        }
        for &b in &infer_batches {
            let key = format!("infer_b{b}");
            EntrySchema::derive(&spec, &key)?.validate_manifest_entry(spec.entry(&key)?)?;
        }
        EntrySchema::derive(&spec, &train_key)?.validate_manifest_entry(spec.entry(&train_key)?)?;

        let rt = spec.runtime_name();
        for &b in &infer_batches {
            let key = format!("infer_b{b}");
            device.load_entry(&qkey(&rt, &key), &spec, &key)?;
        }
        device.load_entry(&qkey(&rt, &train_key), &spec, &train_key)?;

        let theta = manifest.init_params(&spec)?;
        let p = spec.param_count;

        Ok(QNet {
            device,
            train_batch,
            infer_batches,
            theta_minus: RwLock::new(Arc::new(theta.clone())),
            train: Mutex::new(TrainState { theta, g: vec![0.0; p], s: vec![0.0; p] }),
            train_key,
            spec,
            train_steps: AtomicU64::new(0),
            target_syncs: AtomicU64::new(0),
        })
    }

    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    pub fn device(&self) -> &Arc<Device> {
        &self.device
    }

    pub fn train_batch(&self) -> usize {
        self.train_batch
    }

    /// Smallest loaded infer batch that fits `n` states.
    pub fn infer_batch_for(&self, n: usize) -> Result<usize> {
        self.infer_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| {
                anyhow!("no infer entry fits batch {n}; available: {:?}", self.infer_batches)
            })
    }

    /// Batched Q-values for `n` stacked frames (`n * H*W*C` bytes).
    ///
    /// If `n` matches no loaded batch size exactly, the input is zero-padded
    /// up to the next one and the padding rows are dropped from the output.
    /// If `n` exceeds the largest loaded entry, the request is chunked at
    /// that size across several engine transactions — all under ONE
    /// parameter snapshot taken up front, so concurrent training never
    /// splits a request across weight versions, and the concatenated rows
    /// are bitwise identical to any other chunking of the same states (the
    /// forward pass is per-sample). Returns a row-major `[n, actions]`
    /// vector.
    pub fn infer(&self, policy: Policy, states: &[u8], n: usize) -> Result<Vec<f32>> {
        let frame = self.spec.frame.iter().product::<usize>();
        if states.len() != n * frame {
            bail!("infer: got {} bytes for {} states of {} bytes", states.len(), n, frame);
        }
        let params: Arc<Vec<f32>> = match policy {
            // Snapshot the Arc so the read lock is not held during the
            // device call — samplers never block the trainer here, and
            // the parameter buffer itself is never copied.
            Policy::ThetaMinus => self.theta_minus.read().unwrap().clone(),
            // Standard DQN path: clone theta out of the train lock so
            // training and sampling contend only briefly.
            Policy::Theta => {
                let st = self.train.lock().unwrap();
                Arc::new(st.theta.clone())
            }
        };
        let largest = *self.infer_batches.iter().max().expect("load() requires infer entries");
        if n <= largest {
            return self.infer_rows(&params, states, n);
        }
        let mut q = Vec::with_capacity(n * self.spec.actions);
        let mut lo = 0;
        while lo < n {
            let hi = (lo + largest).min(n);
            q.extend(self.infer_rows(&params, &states[lo * frame..hi * frame], hi - lo)?);
            lo = hi;
        }
        Ok(q)
    }

    /// One engine transaction scoring `n <= largest_batch` rows under the
    /// given parameter snapshot (padding to the next loaded entry).
    fn infer_rows(&self, params: &[f32], states: &[u8], n: usize) -> Result<Vec<f32>> {
        let [h, w, c] = self.spec.frame;
        let frame = h * w * c;
        let batch = self.infer_batch_for(n)?;
        let mut padded;
        let data: &[u8] = if batch == n {
            states
        } else {
            padded = vec![0u8; batch * frame];
            padded[..states.len()].copy_from_slice(states);
            &padded
        };
        let shape = [batch, h, w, c];
        let key = qkey(&self.spec.runtime_name(), &format!("infer_b{batch}"));
        let outputs = self.device.execute(
            &key,
            &[TensorView::f32(params, &[self.spec.param_count]), TensorView::u8(data, &shape)],
        )?;
        let mut q = outputs
            .into_iter()
            .next()
            .ok_or_else(|| anyhow!("infer returned no outputs"))?
            .into_f32("infer q-values")?;
        q.truncate(n * self.spec.actions);
        Ok(q)
    }

    /// One gradient step on a minibatch. Returns the TD loss.
    pub fn train_step(&self, batch: &TrainBatch, lr: f32) -> Result<f32> {
        Ok(self.train_step_td(batch, lr)?.loss)
    }

    /// [`QNet::train_step`] returning the per-sample TD errors alongside
    /// the loss (the proportional replay strategy's priority signal).
    pub fn train_step_td(&self, batch: &TrainBatch, lr: f32) -> Result<TrainOutcome> {
        let b = self.train_batch;
        if batch.actions.len() != b || batch.rewards.len() != b || batch.dones.len() != b {
            bail!("train batch vectors must have length {b}");
        }
        let [h, w, c] = self.spec.frame;
        if batch.states.len() != b * h * w * c || batch.next_states.len() != b * h * w * c {
            bail!("train batch states must have {} bytes", b * h * w * c);
        }
        let extended = !batch.weights.is_empty() || !batch.boot_gammas.is_empty();
        if extended && (batch.weights.len() != b || batch.boot_gammas.len() != b) {
            bail!(
                "weighted/n-step train batch must carry {b} weights AND {b} bootstrap discounts \
                 (got {} / {})",
                batch.weights.len(),
                batch.boot_gammas.len()
            );
        }
        let p = self.spec.param_count;
        let states_shape = [b, h, w, c];
        let lr_buf = [lr];
        let tm = self.theta_minus.read().unwrap().clone();
        let key = qkey(&self.spec.runtime_name(), &self.train_key);

        let mut st = self.train.lock().unwrap();
        let mut args = vec![
            TensorView::f32(&st.theta, &[p]),
            TensorView::f32(&tm, &[p]),
            TensorView::f32(&st.g, &[p]),
            TensorView::f32(&st.s, &[p]),
            TensorView::u8(&batch.states, &states_shape),
            TensorView::i32(&batch.actions, &[b]),
            TensorView::f32(&batch.rewards, &[b]),
            TensorView::u8(&batch.next_states, &states_shape),
            TensorView::f32(&batch.dones, &[b]),
            TensorView::scalar(&lr_buf),
        ];
        if extended {
            args.push(TensorView::f32(&batch.weights, &[b]));
            args.push(TensorView::f32(&batch.boot_gammas, &[b]));
        }
        let outputs = self.device.execute(&key, &args)?;
        if outputs.len() < 4 {
            bail!("train step returned fewer than 4 outputs");
        }
        let mut it = outputs.into_iter();
        let theta = it.next().unwrap().into_f32("train theta'")?;
        let g = it.next().unwrap().into_f32("train g'")?;
        let s = it.next().unwrap().into_f32("train s'")?;
        let loss = it.next().unwrap().first_f32("train loss")?;
        let td_errors = match it.next() {
            Some(t) => t.into_f32("train td errors")?,
            // The extended ABI includes the TD-error output by definition;
            // an engine that compiled only the legacy 4-output entry (the
            // XLA artifact path) must fail loudly here, not silently
            // starve the priority updates.
            None if extended => bail!(
                "engine returned no TD-error output; the weighted/n-step train ABI \
                 requires the native engine (rust/DESIGN.md §11)"
            ),
            None => Vec::new(),
        };
        if theta.len() != p || g.len() != p || s.len() != p {
            bail!("train step returned wrong parameter sizes");
        }
        st.theta = theta;
        st.g = g;
        st.s = s;
        drop(st);
        self.train_steps.fetch_add(1, Ordering::Relaxed);
        Ok(TrainOutcome { loss, td_errors })
    }

    /// Target-network update: theta_minus <- theta.
    pub fn sync_target(&self) {
        let snap = {
            let st = self.train.lock().unwrap();
            st.theta.clone()
        };
        *self.theta_minus.write().unwrap() = Arc::new(snap);
        self.target_syncs.fetch_add(1, Ordering::Relaxed);
    }

    /// Download theta to host (checkpointing / tests).
    pub fn theta_host(&self) -> Result<Vec<f32>> {
        Ok(self.train.lock().unwrap().theta.clone())
    }

    /// Download theta_minus to host (tests).
    pub fn theta_minus_host(&self) -> Result<Vec<f32>> {
        Ok(self.theta_minus.read().unwrap().as_ref().clone())
    }

    /// Overwrite theta (checkpoint restore / tests).
    pub fn set_theta(&self, values: &[f32]) -> Result<()> {
        if values.len() != self.spec.param_count {
            bail!("set_theta: expected {} values, got {}", self.spec.param_count, values.len());
        }
        let mut st = self.train.lock().unwrap();
        st.theta = values.to_vec();
        Ok(())
    }

    /// Overwrite theta_minus (fleet parameter broadcasts: a sampler process
    /// installs the learner's acting parameters verbatim — no counter is
    /// touched, so target-sync accounting stays learner-side only).
    pub fn set_theta_minus(&self, values: &[f32]) -> Result<()> {
        if values.len() != self.spec.param_count {
            bail!(
                "set_theta_minus: expected {} values, got {}",
                self.spec.param_count,
                values.len()
            );
        }
        *self.theta_minus.write().unwrap() = Arc::new(values.to_vec());
        Ok(())
    }

    /// Download the RMSProp accumulators (g, s) to host (checkpointing).
    pub fn optimizer_host(&self) -> (Vec<f32>, Vec<f32>) {
        let st = self.train.lock().unwrap();
        (st.g.clone(), st.s.clone())
    }

    /// Overwrite the full learnable state in one shot (checkpoint restore).
    /// All four buffers must have `param_count` elements.
    pub fn import_state(
        &self,
        theta: Vec<f32>,
        g: Vec<f32>,
        s: Vec<f32>,
        theta_minus: Vec<f32>,
        train_steps: u64,
        target_syncs: u64,
    ) -> Result<()> {
        let p = self.spec.param_count;
        for (name, buf) in [("theta", &theta), ("g", &g), ("s", &s), ("theta_minus", &theta_minus)] {
            if buf.len() != p {
                bail!("import_state: {name} has {} values, want {p}", buf.len());
            }
        }
        {
            let mut st = self.train.lock().unwrap();
            st.theta = theta;
            st.g = g;
            st.s = s;
        }
        *self.theta_minus.write().unwrap() = Arc::new(theta_minus);
        self.train_steps.store(train_steps, Ordering::SeqCst);
        self.target_syncs.store(target_syncs, Ordering::SeqCst);
        Ok(())
    }
}

/// [`crate::ckpt::Snapshot`] adapter for [`QNet`]: the network lives behind
/// an `Arc` in the coordinator, so the snapshot borrows it and uses the
/// interior locks for both directions.
pub struct QNetSnapshot<'a>(pub &'a QNet);

impl crate::ckpt::Snapshot for QNetSnapshot<'_> {
    fn kind(&self) -> &'static str {
        "qnet"
    }

    fn save(&self, w: &mut crate::ckpt::ByteWriter) {
        let q = self.0;
        // The head-qualified name (e.g. "tiny+dueling"): a dqn checkpoint
        // stays byte-identical to the pre-head format, while head variants
        // are refused by name everywhere a checkpoint is offered.
        w.put_str(&q.spec.runtime_name());
        w.put_usize(q.spec.param_count);
        w.put_bool(q.train_key.contains("double"));
        let st = q.train.lock().unwrap();
        w.put_f32_slice(&st.theta);
        w.put_f32_slice(&st.g);
        w.put_f32_slice(&st.s);
        drop(st);
        w.put_f32_slice(&q.theta_minus.read().unwrap());
        w.put_u64(q.train_steps.load(Ordering::SeqCst));
        w.put_u64(q.target_syncs.load(Ordering::SeqCst));
    }

    fn load(&mut self, r: &mut crate::ckpt::ByteReader<'_>) -> Result<()> {
        let q = self.0;
        let name = r.str()?;
        let want = q.spec.runtime_name();
        if name != want {
            bail!("checkpoint network is {name:?} (config+head), this run uses {want:?}");
        }
        let p = r.usize()?;
        if p != q.spec.param_count {
            bail!("checkpoint has {p} parameters, this network has {}", q.spec.param_count);
        }
        let double = r.bool()?;
        if double != q.train_key.contains("double") {
            bail!(
                "checkpoint was trained with double-DQN = {double}, this run uses {}",
                q.train_key.contains("double")
            );
        }
        let theta = r.f32_vec()?;
        let g = r.f32_vec()?;
        let s = r.f32_vec()?;
        let theta_minus = r.f32_vec()?;
        let train_steps = r.u64()?;
        let target_syncs = r.u64()?;
        q.import_state(theta, g, s, theta_minus, train_steps, target_syncs)
    }
}

/// The theta-only prefix of a checkpoint's `"qnet"` section: what a serving
/// process needs — the online parameters plus enough identity to refuse the
/// wrong network — without materializing the optimizer accumulators or the
/// target copy (3/4 of the section at nature scale).
///
/// Decodes exactly the prefix [`QNetSnapshot::save`] writes (name,
/// param_count, double flag, theta) and then *stops*: callers must NOT
/// `finish()` the reader, because g/s/theta_minus/counters legitimately
/// remain unread.
pub struct QNetTheta {
    pub name: String,
    pub param_count: usize,
    pub double: bool,
    pub theta: Vec<f32>,
}

impl QNetTheta {
    pub fn decode(r: &mut crate::ckpt::ByteReader<'_>) -> Result<QNetTheta> {
        let name = r.str()?.to_string();
        let param_count = r.usize()?;
        let double = r.bool()?;
        let theta = r.f32_vec()?;
        if theta.len() != param_count {
            bail!(
                "qnet section declares {param_count} parameters but theta carries {}",
                theta.len()
            );
        }
        Ok(QNetTheta { name, param_count, double, theta })
    }
}

fn qkey(config: &str, entry: &str) -> String {
    format!("{config}/{entry}")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckpt::{ByteReader, ByteWriter, Snapshot};
    use crate::runtime::{default_artifact_dir, Manifest};

    #[test]
    fn theta_prefix_decodes_from_full_snapshot() {
        let device = Arc::new(Device::cpu().unwrap());
        let manifest = Manifest::load_or_builtin(&default_artifact_dir()).unwrap();
        let qnet = QNet::load(device, &manifest, "tiny", false, 32).unwrap();

        let mut w = ByteWriter::new();
        QNetSnapshot(&qnet).save(&mut w);
        let bytes = w.into_bytes();

        let mut r = ByteReader::new(&bytes);
        let t = QNetTheta::decode(&mut r).unwrap();
        assert_eq!(t.name, qnet.spec().name);
        assert_eq!(t.param_count, qnet.spec().param_count);
        assert!(!t.double);
        // Bit-exact against the live parameters; the unread suffix
        // (g/s/theta_minus/counters) is the point of the prefix decoder,
        // so finish() must fail here.
        let want: Vec<u32> = qnet.theta_host().unwrap().iter().map(|v| v.to_bits()).collect();
        let got: Vec<u32> = t.theta.iter().map(|v| v.to_bits()).collect();
        assert_eq!(got, want);
        assert!(r.finish().is_err(), "snapshot suffix should remain unread");
    }

    #[test]
    fn oversize_infer_chunks_bitwise_identically() {
        let device = Arc::new(Device::cpu().unwrap());
        let manifest = Manifest::load_or_builtin(&default_artifact_dir()).unwrap();
        let qnet = QNet::load(device, &manifest, "tiny", false, 32).unwrap();
        let frame: usize = qnet.spec().frame.iter().product();
        let largest = *qnet.spec().infer_batches().iter().max().unwrap();
        let n = largest + 4; // spans two engine transactions
        let states: Vec<u8> = (0..n * frame).map(|i| (i * 31 % 251) as u8).collect();

        let big = qnet.infer(Policy::ThetaMinus, &states, n).unwrap();
        assert_eq!(big.len(), n * qnet.spec().actions);
        // Every row must be bitwise identical to scoring that state alone —
        // chunk boundaries (row `largest`) included.
        let a = qnet.spec().actions;
        for r in [0usize, 1, largest - 1, largest, n - 1] {
            let one = qnet.infer(Policy::ThetaMinus, &states[r * frame..(r + 1) * frame], 1).unwrap();
            assert_eq!(
                big[r * a..(r + 1) * a].iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                one.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
                "row {r} diverged from single-sample infer"
            );
        }
    }
}
