//! `QNet`: the Q-network runtime — flat parameter state + compiled entries.
//!
//! Owns the four flat parameter buffers (theta, theta_minus, RMSProp g/s) and
//! exposes exactly the operations the coordinator needs:
//!
//! * `infer`        — batched Q-values under theta or theta_minus
//! * `train_step`   — one full minibatch update (TD loss + centered RMSProp),
//!                    executed by the AOT-compiled `train_b*` artifact
//! * `sync_target`  — theta_minus <- theta (the target-network update)
//!
//! Concurrency model: theta_minus is an immutable snapshot swapped only at
//! sync points (`RwLock<Arc<..>>`), so W sampler threads read it without
//! contending with the trainer; the mutable train state (theta, g, s) lives
//! behind its own mutex owned by the trainer thread. This is precisely the
//! decoupling that makes the paper's Concurrent Training race-free.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use anyhow::{anyhow, bail, Result};
use xla::{ElementType, Literal};

use super::device::Device;
use super::manifest::{Dtype, Manifest, NetSpec};

/// `xla::Literal` wrapper that may be shared across threads.
///
/// # Safety
/// The literal is host memory that is never mutated after construction and
/// is only *read* (uploaded) by `Device::execute`, which serializes all XLA
/// calls behind the device mutex.
pub struct SharedLiteral(pub Literal);
unsafe impl Send for SharedLiteral {}
unsafe impl Sync for SharedLiteral {}

struct TrainState {
    theta: Literal,
    g: Literal,
    s: Literal,
}

/// One training minibatch in host memory (assembled by the replay sampler).
#[derive(Clone, Debug, Default)]
pub struct TrainBatch {
    pub states: Vec<u8>,
    pub actions: Vec<i32>,
    pub rewards: Vec<f32>,
    pub next_states: Vec<u8>,
    pub dones: Vec<f32>,
}

/// Which parameter set drives action selection.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// Standard DQN: act with the online network theta.
    Theta,
    /// Concurrent Training: act with the target network theta_minus.
    ThetaMinus,
}

pub struct QNet {
    device: Arc<Device>,
    spec: NetSpec,
    train_key: String,
    train_batch: usize,
    infer_batches: Vec<usize>,
    theta_minus: RwLock<Arc<SharedLiteral>>,
    train: Mutex<TrainState>,
    pub train_steps: AtomicU64,
    pub target_syncs: AtomicU64,
}

// Safety: every Literal inside is reachable only through the RwLock/Mutex
// above; all XLA calls are serialized by Device's mutex. See device.rs.
unsafe impl Send for QNet {}
unsafe impl Sync for QNet {}

fn f32_literal(v: &[f32]) -> Literal {
    Literal::vec1(v)
}

fn zeros_f32(n: usize) -> Literal {
    // create_from_shape zero-initializes.
    Literal::create_from_shape(ElementType::F32.primitive_type(), &[n])
}

impl QNet {
    /// Load a network config from the manifest: compiles every infer entry
    /// plus the chosen train entry, and initializes parameters from the
    /// deterministic blob the artifacts ship.
    pub fn load(
        device: Arc<Device>,
        manifest: &Manifest,
        config: &str,
        double: bool,
        train_batch: usize,
    ) -> Result<QNet> {
        let spec = manifest.config(config)?.clone();
        let train_key = if double {
            format!("train_double_b{train_batch}")
        } else {
            format!("train_b{train_batch}")
        };

        // Validate ABI shapes before compiling anything.
        let train_entry = spec.entry(&train_key)?;
        if train_entry.inputs.len() != 10 {
            bail!("train entry {train_key} must have 10 inputs (see manifest train_abi)");
        }
        for idx in 0..4 {
            if train_entry.inputs[idx].shape != [spec.param_count]
                || train_entry.inputs[idx].dtype != Dtype::F32
            {
                bail!("train entry input {idx} must be f32[{}]", spec.param_count);
            }
        }

        let infer_batches = spec.infer_batches();
        if infer_batches.is_empty() {
            bail!("config {config:?} has no infer entries");
        }
        for &b in &infer_batches {
            let key = format!("infer_b{b}");
            device.load_hlo(&qkey(&spec.name, &key), &spec.entry(&key)?.file)?;
        }
        device.load_hlo(&qkey(&spec.name, &train_key), &train_entry.file)?;

        let init = manifest.load_init_params(&spec)?;
        let theta = f32_literal(&init);
        let theta_minus = theta.clone();
        let p = spec.param_count;

        Ok(QNet {
            device,
            train_batch,
            infer_batches,
            theta_minus: RwLock::new(Arc::new(SharedLiteral(theta_minus))),
            train: Mutex::new(TrainState { theta, g: zeros_f32(p), s: zeros_f32(p) }),
            train_key,
            spec,
            train_steps: AtomicU64::new(0),
            target_syncs: AtomicU64::new(0),
        })
    }

    pub fn spec(&self) -> &NetSpec {
        &self.spec
    }

    pub fn device(&self) -> &Arc<Device> {
        &self.device
    }

    pub fn train_batch(&self) -> usize {
        self.train_batch
    }

    /// Smallest compiled infer batch that fits `n` states.
    pub fn infer_batch_for(&self, n: usize) -> Result<usize> {
        self.infer_batches
            .iter()
            .copied()
            .find(|&b| b >= n)
            .ok_or_else(|| {
                anyhow!("no infer entry fits batch {n}; available: {:?}", self.infer_batches)
            })
    }

    fn states_literal(&self, states: &[u8], batch: usize) -> Result<Literal> {
        let [h, w, c] = self.spec.frame;
        if states.len() != batch * h * w * c {
            bail!("states buffer has {} bytes, expected {}x{}x{}x{}",
                  states.len(), batch, h, w, c);
        }
        Literal::create_from_shape_and_untyped_data(ElementType::U8, &[batch, h, w, c], states)
            .map_err(|e| anyhow!("states literal: {e}"))
    }

    /// Batched Q-values for `n` stacked frames (`n * H*W*C` bytes).
    ///
    /// If `n` is smaller than the smallest compiled batch, the input is
    /// zero-padded and the padding rows are dropped from the output.
    /// Returns a row-major `[n, actions]` vector.
    pub fn infer(&self, policy: Policy, states: &[u8], n: usize) -> Result<Vec<f32>> {
        let [h, w, c] = self.spec.frame;
        let frame = h * w * c;
        if states.len() != n * frame {
            bail!("infer: got {} bytes for {} states of {} bytes", states.len(), n, frame);
        }
        let batch = self.infer_batch_for(n)?;
        let mut padded;
        let data: &[u8] = if batch == n {
            states
        } else {
            padded = vec![0u8; batch * frame];
            padded[..states.len()].copy_from_slice(states);
            &padded
        };
        let states_lit = self.states_literal(data, batch)?;
        let key = qkey(&self.spec.name, &format!("infer_b{batch}"));

        let outputs = match policy {
            Policy::ThetaMinus => {
                // Snapshot the Arc so the read lock is not held during the
                // device call — samplers never block the trainer here.
                let snap = self.theta_minus.read().unwrap().clone();
                self.device.execute(&key, &[snap.0.clone(), states_lit])?
            }
            Policy::Theta => {
                // Standard DQN path: clone theta under the train lock.
                let theta = {
                    let st = self.train.lock().unwrap();
                    st.theta.clone()
                };
                self.device.execute(&key, &[theta, states_lit])?
            }
        };
        let q = outputs
            .first()
            .ok_or_else(|| anyhow!("infer returned no outputs"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("infer output: {e}"))?;
        Ok(q[..n * self.spec.actions].to_vec())
    }

    /// One gradient step on a minibatch. Returns the TD loss.
    pub fn train_step(&self, batch: &TrainBatch, lr: f32) -> Result<f32> {
        let b = self.train_batch;
        if batch.actions.len() != b || batch.rewards.len() != b || batch.dones.len() != b {
            bail!("train batch vectors must have length {b}");
        }
        let states = self.states_literal(&batch.states, b)?;
        let next_states = self.states_literal(&batch.next_states, b)?;
        let actions = Literal::vec1(&batch.actions)
            .reshape(&[b as i64])
            .map_err(|e| anyhow!("actions literal: {e}"))?;
        let rewards = f32_literal(&batch.rewards);
        let dones = f32_literal(&batch.dones);
        let lr_lit = Literal::scalar(lr);
        let tm = self.theta_minus.read().unwrap().clone();
        let key = qkey(&self.spec.name, &self.train_key);

        let mut st = self.train.lock().unwrap();
        let outputs = self.device.execute(
            &key,
            &[
                st.theta.clone(),
                tm.0.clone(),
                st.g.clone(),
                st.s.clone(),
                states,
                actions,
                rewards,
                next_states,
                dones,
                lr_lit,
            ],
        )?;
        let mut it = outputs.into_iter();
        let (theta, g, s, loss) = match (it.next(), it.next(), it.next(), it.next()) {
            (Some(t), Some(g), Some(s), Some(l)) => (t, g, s, l),
            _ => bail!("train step returned fewer than 4 outputs"),
        };
        st.theta = theta;
        st.g = g;
        st.s = s;
        drop(st);
        self.train_steps.fetch_add(1, Ordering::Relaxed);
        loss.get_first_element::<f32>()
            .map_err(|e| anyhow!("loss output: {e}"))
    }

    /// Target-network update: theta_minus <- theta.
    pub fn sync_target(&self) {
        let snap = {
            let st = self.train.lock().unwrap();
            st.theta.clone()
        };
        *self.theta_minus.write().unwrap() = Arc::new(SharedLiteral(snap));
        self.target_syncs.fetch_add(1, Ordering::Relaxed);
    }

    /// Download theta to host (checkpointing / tests).
    pub fn theta_host(&self) -> Result<Vec<f32>> {
        let st = self.train.lock().unwrap();
        st.theta.to_vec::<f32>().map_err(|e| anyhow!("theta download: {e}"))
    }

    /// Download theta_minus to host (tests).
    pub fn theta_minus_host(&self) -> Result<Vec<f32>> {
        let snap = self.theta_minus.read().unwrap().clone();
        snap.0.to_vec::<f32>().map_err(|e| anyhow!("theta_minus download: {e}"))
    }

    /// Overwrite theta (checkpoint restore / tests).
    pub fn set_theta(&self, values: &[f32]) -> Result<()> {
        if values.len() != self.spec.param_count {
            bail!("set_theta: expected {} values, got {}", self.spec.param_count, values.len());
        }
        let mut st = self.train.lock().unwrap();
        st.theta = f32_literal(values);
        Ok(())
    }
}

fn qkey(config: &str, entry: &str) -> String {
    format!("{config}/{entry}")
}
