//! The pluggable execution-engine boundary.
//!
//! The paper's hardware model needs *a* coprocessor that batches neural-net
//! work behind a serialized transaction bus; it does not care what executes
//! the math. [`ExecutionEngine`] is that seam: [`Device`] owns one engine
//! behind its bus mutex and forwards every transaction to it. Engines:
//!
//! * [`native`](super::native) — pure-Rust reference implementation of the
//!   compiled entry points (always available; the default).
//! * `xla_engine` — the PJRT path executing AOT-lowered HLO artifacts
//!   (`--features xla`; requires vendoring the `xla` crate).
//!
//! An entry point is named by the artifact convention the Python AOT
//! pipeline established: `infer_b{B}`, `train_b{B}`, `train_double_b{B}`.
//! [`EntryKind`] parses that convention so native engines can dispatch on
//! meaning while file-based engines just load the artifact.
//!
//! [`Device`]: super::device::Device

use anyhow::{bail, Result};

use super::manifest::NetSpec;
use super::tensor::{HostTensor, TensorView};

/// Parsed meaning of an entry-point name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntryKind {
    /// `infer_b{batch}`: (params, states) -> (q,)
    Infer { batch: usize },
    /// `train_b{batch}` / `train_double_b{batch}`:
    /// (params, target, g, s, states, actions, rewards, next_states, dones,
    ///  lr) -> (params', g', s', loss)
    Train { batch: usize, double: bool },
}

impl EntryKind {
    pub fn parse(name: &str) -> Result<EntryKind> {
        if let Some(b) = name.strip_prefix("infer_b") {
            return Ok(EntryKind::Infer { batch: parse_batch(name, b)? });
        }
        if let Some(b) = name.strip_prefix("train_double_b") {
            return Ok(EntryKind::Train { batch: parse_batch(name, b)?, double: true });
        }
        if let Some(b) = name.strip_prefix("train_b") {
            return Ok(EntryKind::Train { batch: parse_batch(name, b)?, double: false });
        }
        bail!("unrecognized entry point {name:?} (expected infer_b*/train_b*/train_double_b*)");
    }
}

fn parse_batch(name: &str, digits: &str) -> Result<usize> {
    digits
        .parse::<usize>()
        .map_err(|_| anyhow::anyhow!("entry {name:?}: bad batch size {digits:?}"))
}

/// One backend capable of executing loaded entry points.
///
/// Engines are driven exclusively through [`Device`], which serializes all
/// calls behind the bus mutex — hence `&mut self` and only `Send`.
///
/// [`Device`]: super::device::Device
pub trait ExecutionEngine: Send {
    /// Backend identity, e.g. `"native-cpu"`.
    fn platform_name(&self) -> &str;

    /// Prepare entry `entry_name` of `spec` for execution under `key`.
    /// Idempotent per key.
    fn load_entry(&mut self, key: &str, spec: &NetSpec, entry_name: &str) -> Result<()>;

    fn is_loaded(&self, key: &str) -> bool;

    /// Execute one transaction. Input/output ABI is fixed per [`EntryKind`].
    fn execute(&mut self, key: &str, args: &[TensorView<'_>]) -> Result<Vec<HostTensor>>;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_entry_names() {
        assert_eq!(EntryKind::parse("infer_b8").unwrap(), EntryKind::Infer { batch: 8 });
        assert_eq!(
            EntryKind::parse("train_b32").unwrap(),
            EntryKind::Train { batch: 32, double: false }
        );
        assert_eq!(
            EntryKind::parse("train_double_b32").unwrap(),
            EntryKind::Train { batch: 32, double: true }
        );
        assert!(EntryKind::parse("warmup_b2").is_err());
        assert!(EntryKind::parse("infer_bx").is_err());
    }
}
