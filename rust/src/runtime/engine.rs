//! The pluggable execution-engine boundary (the engine ABI).
//!
//! The paper's hardware model needs *a* coprocessor that batches neural-net
//! work behind a serialized transaction bus; it does not care what executes
//! the math. [`ExecutionEngine`] is that seam: [`Device`] owns one engine
//! behind its bus mutex and forwards every transaction to it. Engines:
//!
//! * [`native`](super::native) — pure-Rust reference implementation of the
//!   compiled entry points (always available; the default).
//! * `xla_engine` — the PJRT path executing AOT-lowered HLO artifacts
//!   (`--features xla`; requires vendoring the `xla` crate).
//!
//! An entry point is named by the artifact convention the Python AOT
//! pipeline established: `infer_b{B}`, `train_b{B}`, `train_double_b{B}`.
//! What used to be a name-parsed enum plus positional 10/12-input tensor
//! lists is now a **named entry schema**: [`EntrySchema::derive`] expands
//! an entry name against a [`NetSpec`] into named, typed, shaped input and
//! output fields, and engines validate every transaction against it — a
//! mis-shaped or missing argument is refused by *entry and field name*,
//! not by position. The schema grows with the network head
//! ([`Head`]): head variants change the parameter-vector length (and the
//! meaning of the train math) without touching the field list, which is
//! exactly what lets the fleet and serving layers reuse one ABI for
//! `dqn`, `dueling`, and `c51` checkpoints.
//!
//! [`Device`]: super::device::Device

use anyhow::{anyhow, bail, Result};

use super::manifest::{Dtype, Entry, NetSpec};
use super::tensor::{DataView, HostTensor, TensorView};

/// Q-network head variant. Selects how the dense tail after the conv trunk
/// maps features to Q-values (rust/DESIGN.md §16):
///
/// * `Dqn` — the historical single stream: hidden MLP then a `[dim, A]`
///   output layer. The default; its code path, parameter layout, and
///   checkpoint identity are untouched by the other variants.
/// * `Dueling` — separate value and advantage streams with mean-subtracted
///   aggregation `Q(s,a) = V(s) + A(s,a) − mean_a' A(s,a')`.
/// * `C51` — distributional: the output layer emits `A × atoms` logits;
///   per-action softmax over a fixed support `[v_min, v_max]`, trained by
///   projecting the Bellman-shifted target distribution onto the support
///   (cross-entropy loss). `infer` returns expected-value Q-rows, so
///   argmax/serving/eval consume the same `[B, A]` tensor as every other
///   head.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Head {
    Dqn,
    Dueling,
    C51 { atoms: usize, v_min: f32, v_max: f32 },
}

impl Head {
    /// The knob name of the variant (`net.head` in configs).
    pub fn kind_name(&self) -> &'static str {
        match self {
            Head::Dqn => "dqn",
            Head::Dueling => "dueling",
            Head::C51 { .. } => "c51",
        }
    }

    /// Canonical tag including the C51 support parameters. f32 `Display`
    /// round-trips exactly, so the tag is a faithful identity.
    pub fn tag(&self) -> String {
        match self {
            Head::Dqn => "dqn".to_string(),
            Head::Dueling => "dueling".to_string(),
            Head::C51 { atoms, v_min, v_max } => format!("c51[{atoms},{v_min},{v_max}]"),
        }
    }

    /// Network identity carried in checkpoints and engine keys: the bare
    /// config name for `dqn` (so every pre-head checkpoint byte and engine
    /// key is unchanged), `base+tag` otherwise.
    pub fn qualify(&self, base: &str) -> String {
        match self {
            Head::Dqn => base.to_string(),
            _ => format!("{base}+{}", self.tag()),
        }
    }

    /// Parse a qualified network name back into `(base_config, head)`.
    /// Names without a `+` suffix are dqn — exactly the historical names.
    pub fn split(name: &str) -> Result<(String, Head)> {
        let Some((base, tag)) = name.split_once('+') else {
            return Ok((name.to_string(), Head::Dqn));
        };
        if base.is_empty() {
            bail!("network name {name:?} has an empty base config");
        }
        let head = if tag == "dueling" {
            Head::Dueling
        } else if let Some(inner) = tag.strip_prefix("c51[").and_then(|t| t.strip_suffix(']')) {
            let parts: Vec<&str> = inner.split(',').collect();
            if parts.len() != 3 {
                bail!("network name {name:?}: c51 tag needs [atoms,v_min,v_max]");
            }
            let atoms: usize = parts[0]
                .parse()
                .map_err(|_| anyhow!("network name {name:?}: bad atom count {:?}", parts[0]))?;
            let v_min: f32 = parts[1]
                .parse()
                .map_err(|_| anyhow!("network name {name:?}: bad v_min {:?}", parts[1]))?;
            let v_max: f32 = parts[2]
                .parse()
                .map_err(|_| anyhow!("network name {name:?}: bad v_max {:?}", parts[2]))?;
            Head::C51 { atoms, v_min, v_max }
        } else {
            bail!("network name {name:?} carries unknown head tag {tag:?}");
        };
        Ok((base.to_string(), head))
    }
}

/// What an entry point does (parsed from its conventional name).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EntryOp {
    Infer,
    Train { double: bool },
}

/// One named, typed, shaped field of an entry's ABI.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EntryField {
    pub name: &'static str,
    pub dtype: Dtype,
    pub shape: Vec<usize>,
}

impl EntryField {
    fn new(name: &'static str, dtype: Dtype, shape: Vec<usize>) -> EntryField {
        EntryField { name, dtype, shape }
    }

    fn describe(&self) -> String {
        format!("{}{:?}", dtype_name(self.dtype), self.shape)
    }
}

fn dtype_name(d: Dtype) -> &'static str {
    match d {
        Dtype::F32 => "f32",
        Dtype::U8 => "u8",
        Dtype::I32 => "i32",
    }
}

fn view_dtype(v: &TensorView<'_>) -> Dtype {
    match v.data {
        DataView::F32(_) => Dtype::F32,
        DataView::U8(_) => Dtype::U8,
        DataView::I32(_) => Dtype::I32,
    }
}

/// The named schema of one entry point, derived from the entry name and the
/// (head-adjusted) [`NetSpec`]. This is the growable replacement for the
/// positional tensor-list convention: engines validate transactions against
/// it, and every refusal names the entry and the offending field.
#[derive(Clone, Debug)]
pub struct EntrySchema {
    /// Entry name (`infer_b{B}` / `train_b{B}` / `train_double_b{B}`).
    pub entry: String,
    pub op: EntryOp,
    pub batch: usize,
    /// Head the schema was derived for (the spec's head).
    pub head: Head,
    /// Required inputs, in transaction order.
    pub inputs: Vec<EntryField>,
    /// Optional trailing inputs (the extended per-sample train arrays:
    /// IS weights + bootstrap discounts). All-or-none: a transaction
    /// carries either none of them or every one.
    pub optional_inputs: Vec<EntryField>,
    /// Outputs, in transaction order.
    pub outputs: Vec<EntryField>,
}

impl EntrySchema {
    /// Expand `entry_name` against `spec` into its named schema.
    pub fn derive(spec: &NetSpec, entry_name: &str) -> Result<EntrySchema> {
        let [h, w, c] = spec.frame;
        let p = spec.param_count;
        let a = spec.actions;
        if let Some(digits) = entry_name.strip_prefix("infer_b") {
            let batch = parse_batch(entry_name, digits)?;
            return Ok(EntrySchema {
                entry: entry_name.to_string(),
                op: EntryOp::Infer,
                batch,
                head: spec.head,
                inputs: vec![
                    EntryField::new("params", Dtype::F32, vec![p]),
                    EntryField::new("states", Dtype::U8, vec![batch, h, w, c]),
                ],
                optional_inputs: Vec::new(),
                outputs: vec![EntryField::new("q", Dtype::F32, vec![batch, a])],
            });
        }
        let (digits, double) = if let Some(d) = entry_name.strip_prefix("train_double_b") {
            (d, true)
        } else if let Some(d) = entry_name.strip_prefix("train_b") {
            (d, false)
        } else {
            bail!(
                "unrecognized entry point {entry_name:?} \
                 (expected infer_b*/train_b*/train_double_b*)"
            );
        };
        let batch = parse_batch(entry_name, digits)?;
        Ok(EntrySchema {
            entry: entry_name.to_string(),
            op: EntryOp::Train { double },
            batch,
            head: spec.head,
            inputs: vec![
                EntryField::new("params", Dtype::F32, vec![p]),
                EntryField::new("target_params", Dtype::F32, vec![p]),
                EntryField::new("g", Dtype::F32, vec![p]),
                EntryField::new("s", Dtype::F32, vec![p]),
                EntryField::new("states", Dtype::U8, vec![batch, h, w, c]),
                EntryField::new("actions", Dtype::I32, vec![batch]),
                EntryField::new("rewards", Dtype::F32, vec![batch]),
                EntryField::new("next_states", Dtype::U8, vec![batch, h, w, c]),
                EntryField::new("dones", Dtype::F32, vec![batch]),
                EntryField::new("lr", Dtype::F32, vec![]),
            ],
            optional_inputs: vec![
                EntryField::new("weights", Dtype::F32, vec![batch]),
                EntryField::new("boot_gammas", Dtype::F32, vec![batch]),
            ],
            outputs: vec![
                EntryField::new("params_out", Dtype::F32, vec![p]),
                EntryField::new("g_out", Dtype::F32, vec![p]),
                EntryField::new("s_out", Dtype::F32, vec![p]),
                EntryField::new("loss", Dtype::F32, vec![]),
                EntryField::new("td_errors", Dtype::F32, vec![batch]),
            ],
        })
    }

    /// Validate one transaction's arguments. Refusals name the entry and
    /// the field: missing inputs, extra inputs, dtype and shape mismatches
    /// all say *which* field is wrong.
    pub fn validate_args(&self, args: &[TensorView<'_>]) -> Result<()> {
        let req = self.inputs.len();
        let all = req + self.optional_inputs.len();
        if args.len() < req {
            bail!(
                "entry {:?}: missing input {:?} (got {} of {} required inputs)",
                self.entry,
                self.inputs[args.len()].name,
                args.len(),
                req
            );
        }
        if args.len() > req && args.len() < all {
            bail!(
                "entry {:?}: missing input {:?} (the optional inputs {:?} are all-or-none)",
                self.entry,
                self.optional_inputs[args.len() - req].name,
                self.optional_inputs.iter().map(|f| f.name).collect::<Vec<_>>()
            );
        }
        if args.len() > all {
            bail!(
                "entry {:?}: {} inputs exceed the schema's {} ({} required + {} optional)",
                self.entry,
                args.len(),
                all,
                req,
                self.optional_inputs.len()
            );
        }
        let fields = self.inputs.iter().chain(self.optional_inputs.iter());
        for (arg, field) in args.iter().zip(fields) {
            let got = view_dtype(arg);
            if got != field.dtype {
                bail!(
                    "entry {:?}: input {:?} must be {}, got {}[{:?}]",
                    self.entry,
                    field.name,
                    field.describe(),
                    dtype_name(got),
                    arg.shape
                );
            }
            if arg.shape != field.shape {
                bail!(
                    "entry {:?}: input {:?} must have shape {:?}, got {:?}",
                    self.entry,
                    field.name,
                    field.shape,
                    arg.shape
                );
            }
            let want: usize = field.shape.iter().product();
            if arg.elements() != want {
                bail!(
                    "entry {:?}: input {:?} carries {} elements for shape {:?}",
                    self.entry,
                    field.name,
                    arg.elements(),
                    field.shape
                );
            }
        }
        Ok(())
    }

    /// Whether a transaction with `n` arguments uses the extended form
    /// (all optional inputs present). Call after [`Self::validate_args`].
    pub fn is_extended(&self, n: usize) -> bool {
        !self.optional_inputs.is_empty() && n == self.inputs.len() + self.optional_inputs.len()
    }

    /// Cross-check a manifest-declared entry against this schema (the
    /// load-time half of the ABI: artifact manifests declare the required
    /// inputs only). Mismatches name entry and field.
    pub fn validate_manifest_entry(&self, entry: &Entry) -> Result<()> {
        if entry.inputs.len() != self.inputs.len() {
            bail!(
                "entry {:?}: manifest declares {} inputs, schema has {} required ({:?})",
                self.entry,
                entry.inputs.len(),
                self.inputs.len(),
                self.inputs.iter().map(|f| f.name).collect::<Vec<_>>()
            );
        }
        for (sig, field) in entry.inputs.iter().zip(self.inputs.iter()) {
            if sig.dtype != field.dtype || sig.shape != field.shape {
                bail!(
                    "entry {:?}: manifest input {:?} is {}{:?}, schema requires {}",
                    self.entry,
                    field.name,
                    dtype_name(sig.dtype),
                    sig.shape,
                    field.describe()
                );
            }
        }
        Ok(())
    }
}

fn parse_batch(name: &str, digits: &str) -> Result<usize> {
    digits
        .parse::<usize>()
        .map_err(|_| anyhow::anyhow!("entry {name:?}: bad batch size {digits:?}"))
}

/// One backend capable of executing loaded entry points.
///
/// Engines are driven exclusively through [`Device`], which serializes all
/// calls behind the bus mutex — hence `&mut self` and only `Send`.
///
/// [`Device`]: super::device::Device
pub trait ExecutionEngine: Send {
    /// Backend identity, e.g. `"native-cpu"`.
    fn platform_name(&self) -> &str;

    /// Prepare entry `entry_name` of `spec` for execution under `key`.
    /// Idempotent per key.
    fn load_entry(&mut self, key: &str, spec: &NetSpec, entry_name: &str) -> Result<()>;

    fn is_loaded(&self, key: &str) -> bool;

    /// Execute one transaction. Arguments are validated against the
    /// entry's [`EntrySchema`].
    fn execute(&mut self, key: &str, args: &[TensorView<'_>]) -> Result<Vec<HostTensor>>;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::Manifest;

    #[test]
    fn derives_entry_schemas_from_names() {
        let m = Manifest::builtin();
        let spec = m.config("tiny").unwrap();
        let infer = EntrySchema::derive(spec, "infer_b8").unwrap();
        assert_eq!(infer.op, EntryOp::Infer);
        assert_eq!(infer.batch, 8);
        assert_eq!(infer.inputs.len(), 2);
        assert_eq!(infer.inputs[0].name, "params");
        assert_eq!(infer.inputs[0].shape, vec![spec.param_count]);
        assert_eq!(infer.inputs[1].shape, vec![8, 84, 84, 4]);
        assert_eq!(infer.outputs[0].shape, vec![8, spec.actions]);

        let train = EntrySchema::derive(spec, "train_b32").unwrap();
        assert_eq!(train.op, EntryOp::Train { double: false });
        assert_eq!(train.inputs.len(), 10);
        assert_eq!(train.optional_inputs.len(), 2);
        assert_eq!(train.optional_inputs[0].name, "weights");
        let dbl = EntrySchema::derive(spec, "train_double_b32").unwrap();
        assert_eq!(dbl.op, EntryOp::Train { double: true });

        assert!(EntrySchema::derive(spec, "warmup_b2").is_err());
        assert!(EntrySchema::derive(spec, "infer_bx").is_err());
    }

    #[test]
    fn schema_refusals_name_entry_and_field() {
        let m = Manifest::builtin();
        let spec = m.config("tiny").unwrap();
        let schema = EntrySchema::derive(spec, "infer_b2").unwrap();
        let params = vec![0.0f32; spec.param_count];
        let states = vec![0u8; 2 * spec.frame_elems()];

        // Missing input: named.
        let err = schema
            .validate_args(&[TensorView::f32(&params, &[spec.param_count])])
            .unwrap_err()
            .to_string();
        assert!(err.contains("infer_b2") && err.contains("states"), "{err}");

        // Wrong dtype: named.
        let bad = vec![0.0f32; 2 * spec.frame_elems()];
        let err = schema
            .validate_args(&[
                TensorView::f32(&params, &[spec.param_count]),
                TensorView::f32(&bad, &[2, 84, 84, 4]),
            ])
            .unwrap_err()
            .to_string();
        assert!(err.contains("states") && err.contains("u8"), "{err}");

        // Wrong shape: named.
        let err = schema
            .validate_args(&[
                TensorView::f32(&params, &[spec.param_count]),
                TensorView::u8(&states, &[1, 84, 84, 4]),
            ])
            .unwrap_err()
            .to_string();
        assert!(err.contains("states") && err.contains("shape"), "{err}");

        // Correct call passes.
        schema
            .validate_args(&[
                TensorView::f32(&params, &[spec.param_count]),
                TensorView::u8(&states, &[2, 84, 84, 4]),
            ])
            .unwrap();
    }

    #[test]
    fn train_schema_optional_inputs_are_all_or_none() {
        let m = Manifest::builtin();
        let spec = m.config("tiny").unwrap();
        let schema = EntrySchema::derive(spec, "train_b32").unwrap();
        assert!(!schema.is_extended(10));
        assert!(schema.is_extended(12));
        // 11 args = weights without boot_gammas: refused by name.
        let p = vec![0.0f32; spec.param_count];
        let st = vec![0u8; 32 * spec.frame_elems()];
        let acts = vec![0i32; 32];
        let v32 = vec![0.0f32; 32];
        let lr = [1e-4f32];
        let mut args = vec![
            TensorView::f32(&p, &[spec.param_count]),
            TensorView::f32(&p, &[spec.param_count]),
            TensorView::f32(&p, &[spec.param_count]),
            TensorView::f32(&p, &[spec.param_count]),
            TensorView::u8(&st, &[32, 84, 84, 4]),
            TensorView::i32(&acts, &[32]),
            TensorView::f32(&v32, &[32]),
            TensorView::u8(&st, &[32, 84, 84, 4]),
            TensorView::f32(&v32, &[32]),
            TensorView::scalar(&lr),
        ];
        schema.validate_args(&args).unwrap();
        args.push(TensorView::f32(&v32, &[32]));
        let err = schema.validate_args(&args).unwrap_err().to_string();
        assert!(err.contains("boot_gammas"), "{err}");
        args.push(TensorView::f32(&v32, &[32]));
        schema.validate_args(&args).unwrap();
        args.push(TensorView::f32(&v32, &[32]));
        assert!(schema.validate_args(&args).is_err());
    }

    #[test]
    fn head_names_qualify_and_split_round_trip() {
        let heads = [
            Head::Dqn,
            Head::Dueling,
            Head::C51 { atoms: 51, v_min: -10.0, v_max: 10.0 },
            Head::C51 { atoms: 21, v_min: -5.5, v_max: 7.25 },
        ];
        for head in heads {
            let name = head.qualify("tiny");
            let (base, parsed) = Head::split(&name).unwrap();
            assert_eq!(base, "tiny");
            assert_eq!(parsed, head, "{name}");
        }
        // dqn names are the bare config name — pre-head identity.
        assert_eq!(Head::Dqn.qualify("nature"), "nature");
        assert_eq!(Head::split("nature").unwrap(), ("nature".to_string(), Head::Dqn));
        assert!(Head::split("tiny+mystery").is_err());
        assert!(Head::split("tiny+c51[a,b,c]").is_err());
    }
}
